//! Memory planner: the paper's §4.2 analysis as a tool.  Given a preset's
//! manifest, predict per-rank peak memory for every schedule ± 2BP from
//! the byte classes (res1 / res2 / inter) — then, if the artifacts exist,
//! verify the prediction against a real run's byte-exact accounting.
//!
//! This is what you'd use before launching a job to answer "will 1F1B-2
//! with 2BP OOM on my devices?" (the paper hit exactly that at 16 GPUs,
//! §4.3.2).
//!
//! ```bash
//! cargo run --release --example memory_planner -- \
//!     [--preset transformer-tiny] [--budget-gb 16] [--verify]
//! ```

use std::path::Path;

use twobp::config::{P2Mode, RunConfig};
use twobp::models::Manifest;
use twobp::pipeline::train;
use twobp::schedule::{generate, ScheduleKind};
use twobp::sim::{simulate, CostModel};
use twobp::util::args::Args;
use twobp::util::stats::fmt_bytes;
use twobp::util::table::Table;

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv, &["verify"]);
    let preset = args.get_or("preset", "transformer-tiny");
    let budget =
        (args.get_f64("budget-gb", 16.0) * (1u64 << 30) as f64) as u64;
    let manifest = Manifest::load(Path::new("artifacts"), preset)?;
    let n = manifest.n_stages;
    let mem = manifest.mem_model();
    let costs = manifest.cost_model_from_flops(0.0);

    println!(
        "{}: {} stages, {} params, budget {}/device\n",
        preset, n, manifest.total_params(), fmt_bytes(budget)
    );

    let mut t = Table::new(&["schedule", "2BP", "predicted peak",
                             "increase", "fits budget", "measured peak"])
        .with_title("predicted per-rank peak memory (manifest byte classes \
                     through the schedule simulator)");
    for kind in [ScheduleKind::Naive, ScheduleKind::GPipe,
                 ScheduleKind::OneF1B1, ScheduleKind::OneF1B2,
                 ScheduleKind::OneF1B2EagerP2] {
        let mut base_peak = 0u64;
        for two_bp in [false, true] {
            if kind == ScheduleKind::OneF1B2EagerP2 && !two_bp {
                continue;
            }
            let plan = generate(kind, two_bp, n, 0, false);
            let res = simulate(&plan, &costs, Some(&mem))
                .map_err(|e| anyhow::anyhow!("{e}"))?;
            let peak = res.max_peak();
            if !two_bp {
                base_peak = peak;
            }
            let measured = if args.has("verify") {
                let cfg = RunConfig {
                    preset: preset.into(),
                    schedule: kind,
                    two_bp,
                    steps: 1,
                    p2_mode: P2Mode::Loop,
                    ..RunConfig::default()
                };
                fmt_bytes(train(&cfg)?.max_peak())
            } else {
                "-".into()
            };
            t.row(vec![
                kind.name().into(),
                if two_bp { "yes" } else { "no" }.into(),
                fmt_bytes(peak),
                if two_bp && base_peak > 0 {
                    format!("{:.2}x", peak as f64 / base_peak as f64)
                } else {
                    "1.00x".into()
                },
                if peak <= budget { "yes" } else { "NO — would OOM" }.into(),
                measured,
            ]);
        }
    }
    print!("{}", t.render());
    println!("\ncosts from manifest flops; memory from manifest byte \
              classes (res1/res2/inter per microbatch).");
    let _ = CostModel::unit(1);
    Ok(())
}
