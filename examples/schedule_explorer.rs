//! Schedule explorer: interactive reproduction of the paper's Figure 1
//! and Table 1 — render any schedule's timeline under any cost ratios
//! and see where 2BP reclaims bubble time.
//!
//! ```bash
//! cargo run --release --example schedule_explorer -- \
//!     [--ranks 4] [--microbatches 0] [--fwd 1.0] [--p1 1.2] [--p2 0.8] \
//!     [--comm 0.05] [--cols 100]
//! ```

use twobp::schedule::{generate, validate::validate, ScheduleKind};
use twobp::sim::{simulate, CostModel};
use twobp::util::args::Args;
use twobp::util::gantt;
use twobp::util::table::Table;

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv, &[]);
    let n = args.get_usize("ranks", 4);
    let m = args.get_usize("microbatches", 0);
    let cols = args.get_usize("cols", 100);
    let mut costs = CostModel::ratios(
        n,
        args.get_f64("fwd", 1.0),
        args.get_f64("p1", 1.0),
        args.get_f64("p2", 1.0),
    );
    costs.comm = args.get_f64("comm", 0.0);

    let mut summary = Table::new(&[
        "schedule", "M", "makespan", "makespan +2BP", "bubble", "bubble +2BP",
        "gain",
    ])
    .with_title(&format!(
        "schedules at N={n}, f={:.2} p1={:.2} p2={:.2} comm={:.2}",
        costs.fwd[0], costs.p1[0], costs.p2[0], costs.comm
    ));

    for kind in ScheduleKind::all() {
        let mut res = Vec::new();
        for two_bp in [false, true] {
            let plan = generate(kind, two_bp, n, m, false);
            validate(&plan).map_err(|e| anyhow::anyhow!("{e}"))?;
            let r = simulate(&plan, &costs, None)
                .map_err(|e| anyhow::anyhow!("{e}"))?;
            println!("=== {} ===  makespan {:.2}, bubble {:.3}",
                     plan.describe(), r.makespan, r.bubble_ratio);
            print!("{}", gantt::render(&r.spans, cols));
            println!();
            res.push(r);
        }
        summary.row(vec![
            kind.name().into(),
            generate(kind, false, n, m, false).n_microbatches.to_string(),
            format!("{:.2}", res[0].makespan),
            format!("{:.2}", res[1].makespan),
            format!("{:.3}", res[0].bubble_ratio),
            format!("{:.3}", res[1].bubble_ratio),
            format!("{:.3}x", res[0].makespan / res[1].makespan),
        ]);
    }
    print!("{}", summary.render());
    Ok(())
}
