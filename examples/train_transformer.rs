//! End-to-end driver: train a GPT-style transformer across a 4-stage
//! pipeline with 1F1B-1 + 2BP and log the loss curve.
//!
//! Default preset is `transformer-s` (≈12M params, 4 pipeline stages) so
//! a few hundred steps complete in minutes on this single-core host;
//! `--preset transformer-m` scales to ≈59M params (see DESIGN.md §3 for
//! the paper-scale substitution).
//!
//! ```bash
//! cargo run --release --example train_transformer -- \
//!     [--preset transformer-m] [--steps 200] [--schedule 1f1b-1] \
//!     [--no-2bp] [--data-cycle 8] [--csv loss.csv]
//! ```

use std::io::Write;

use twobp::config::RunConfig;
use twobp::metrics::run_summary;
use twobp::pipeline::train;
use twobp::util::args::Args;

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv, &["no-2bp", "verbose", "concat-p2"]);
    let mut cfg = RunConfig::from_args(&args)?;
    if args.get("preset").is_none() {
        cfg.preset = "transformer-s".into();
    }
    if args.get("steps").is_none() {
        cfg.steps = 200;
    }
    if args.get("data-cycle").is_none() {
        cfg.data_cycle = 8; // fixed synthetic corpus of 8 minibatches
    }
    cfg.verbose = true;

    println!(
        "training {} for {} steps with {}{} (data cycle {})",
        cfg.preset, cfg.steps, cfg.schedule.name(),
        if cfg.two_bp { "+2bp" } else { "" }, cfg.data_cycle
    );
    let report = train(&cfg)?;
    print!("{}", run_summary(&report));

    println!("\nloss curve:");
    for (i, l) in report.losses.iter().enumerate() {
        if i % 10 == 0 || i + 1 == report.losses.len() {
            println!("  step {i:>4}  loss {l:.4}");
        }
    }
    if let Some(path) = args.get("csv") {
        let mut f = std::fs::File::create(path)?;
        writeln!(f, "step,loss,step_seconds")?;
        for (i, (l, t)) in report
            .losses
            .iter()
            .zip(report.step_times.iter())
            .enumerate()
        {
            writeln!(f, "{i},{l},{t}")?;
        }
        println!("wrote {path}");
    }

    let first = report.losses.first().copied().unwrap_or(0.0);
    let last = report.losses.last().copied().unwrap_or(f32::MAX);
    anyhow::ensure!(last < first, "loss did not decrease ({first} -> {last})");
    println!("train_transformer OK ({first:.3} -> {last:.3})");
    Ok(())
}
