//! Quickstart: train a tiny 2-stage transformer with 1F1B + 2BP.
//!
//! ```bash
//! make artifacts            # once: AOT-compile the stage executables
//! cargo run --release --example quickstart
//! ```
//!
//! Demonstrates the whole stack: plan generation + validation, worker
//! threads with their own PJRT device contexts, 2BP greedy p2 fill,
//! loss logging, byte-exact memory accounting, and the calibrated
//! throughput replay.

use twobp::config::RunConfig;
use twobp::metrics::run_summary;
use twobp::pipeline::train;
use twobp::schedule::ScheduleKind;

fn main() -> anyhow::Result<()> {
    let cfg = RunConfig {
        preset: "transformer-tiny".into(),
        schedule: ScheduleKind::OneF1B1,
        two_bp: true,
        steps: 12,
        data_cycle: 2, // repeat 2 fixed batches so the loss curve falls
        verbose: true,
        ..RunConfig::default()
    };
    println!("training {} with {}{} ...", cfg.preset,
             cfg.schedule.name(), if cfg.two_bp { "+2bp" } else { "" });
    let report = train(&cfg)?;
    print!("{}", run_summary(&report));

    // the loss should be falling on random-but-fixed synthetic data
    let first = report.losses.first().copied().unwrap_or(0.0);
    let last = report.losses.last().copied().unwrap_or(0.0);
    println!("loss: {first:.4} -> {last:.4}");
    assert!(last < first, "loss did not decrease");
    println!("quickstart OK");
    Ok(())
}
