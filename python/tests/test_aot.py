"""AOT export tests: the flat-signature stage functions and the manifest
contract the rust runtime depends on.

These exercise the StageExport machinery numerically (tracing the flat
functions with concrete values) without writing HLO files, plus one real
end-to-end export of a tiny preset into a temp dir.
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import optim, presets
from compile.aot import StageExport, export_preset
from compile.archs import BUILDERS

jax.config.update("jax_platform_name", "cpu")

CFG = dict(dim=32, heads=2, blocks=2, seq=16, vocab=64, microbatch=2,
           stages=2, use_kernels=False)


@pytest.fixture(scope="module")
def se():
    pipe = BUILDERS["transformer"](CFG)
    step = optim.OPTIMIZERS["adam"](lr=1e-3)
    params0 = jax.eval_shape(
        lambda: pipe.stages[0].init(jax.random.PRNGKey(0)))
    y0 = jax.eval_shape(pipe.stages[0].fwd, params0, pipe.input_spec)[0]
    return StageExport(pipe.stages[1], y0, step, seed_base=7)


def _concrete(specs, seed=0):
    out = []
    for i, s in enumerate(specs):
        k = jax.random.PRNGKey(seed * 1000 + i)
        if s.dtype == jnp.int32:
            out.append(jax.random.randint(k, s.shape, 0, 8))
        else:
            out.append(jax.random.normal(k, s.shape, s.dtype))
    return out


def test_flat_roundtrip_fwd_p1_p2(se):
    """fwd -> p1 -> p2 through the *flat* signatures must equal the
    tree-level stage functions."""
    init_fn, init_specs = se.init_fn()
    params = list(init_fn(jnp.asarray(3, jnp.int32)))
    fwd_fn, fwd_specs = se.fwd_fn()
    x = _concrete([fwd_specs[-1]], seed=1)[0]
    outs = fwd_fn(*params, x)
    y = outs[0]
    n1, n2 = len(se.r1_leaves), len(se.r2_leaves)
    res1 = list(outs[1:1 + n1])
    res2 = list(outs[1 + n1:])
    assert len(res2) == n2

    gy = jax.random.normal(jax.random.PRNGKey(9), y.shape, y.dtype)
    p1_fn, _ = se.bwd_p1_fn()
    p1_out = p1_fn(*params, *res1, *res2, gy)
    gx = p1_out[0]
    inter = list(p1_out[1:])

    p2_fn, _ = se.bwd_p2_fn()
    acc = [jnp.zeros(g.shape, g.dtype) for g in se.g_leaves]
    grads = p2_fn(*res2, *inter, *acc)

    # tree-level oracle
    stage = se.stage
    ptree = jax.tree_util.tree_unflatten(se.p_tree, params)
    y_ref, r1_ref, r2_ref = stage.fwd(ptree, x)
    gx_ref, it_ref = stage.bwd_p1(ptree, r1_ref, r2_ref, gy)
    g_ref = jax.tree_util.tree_leaves(stage.bwd_p2(r2_ref, it_ref))
    np.testing.assert_allclose(y, y_ref, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(gx, gx_ref, rtol=1e-4, atol=1e-5)
    for a, b in zip(grads, g_ref):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)


def test_p2_accumulation(se):
    """bwd_p2 adds into the accumulator operand."""
    init_fn, _ = se.init_fn()
    params = list(init_fn(jnp.asarray(0, jnp.int32)))
    fwd_fn, fwd_specs = se.fwd_fn()
    x = _concrete([fwd_specs[-1]], seed=2)[0]
    outs = fwd_fn(*params, x)
    n1, n2 = len(se.r1_leaves), len(se.r2_leaves)
    res1, res2 = list(outs[1:1 + n1]), list(outs[1 + n1:])
    gy = jax.random.normal(jax.random.PRNGKey(5), outs[0].shape)
    p1_fn, _ = se.bwd_p1_fn()
    inter = list(p1_fn(*params, *res1, *res2, gy)[1:])
    p2_fn, _ = se.bwd_p2_fn()
    zeros = [jnp.zeros(g.shape, g.dtype) for g in se.g_leaves]
    once = p2_fn(*res2, *inter, *zeros)
    twice = p2_fn(*res2, *inter, *once)
    for a, b in zip(twice, once):
        np.testing.assert_allclose(a, 2.0 * np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


def test_p2_concat_equals_sum_of_loop(se):
    """The concat executable == sum of per-microbatch p2 calls (Fig 2)."""
    m = 2
    init_fn, _ = se.init_fn()
    params = list(init_fn(jnp.asarray(0, jnp.int32)))
    fwd_fn, fwd_specs = se.fwd_fn()
    p1_fn, _ = se.bwd_p1_fn()
    p2_fn, _ = se.bwd_p2_fn()
    concat_fn, _ = se.bwd_p2_concat_fn(m)

    groups = []
    acc = [jnp.zeros(g.shape, g.dtype) for g in se.g_leaves]
    for mb in range(m):
        x = _concrete([fwd_specs[-1]], seed=10 + mb)[0]
        outs = fwd_fn(*params, x)
        n1 = len(se.r1_leaves)
        res1, res2 = list(outs[1:1 + n1]), list(outs[1 + n1:])
        gy = jax.random.normal(jax.random.PRNGKey(20 + mb), outs[0].shape)
        inter = list(p1_fn(*params, *res1, *res2, gy)[1:])
        groups.append((res2, inter))
        acc = p2_fn(*res2, *inter, *acc)

    flat = []
    for res2, inter in groups:
        flat.extend(res2)
        flat.extend(inter)
    concat = concat_fn(*flat)
    for a, b in zip(concat, acc):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


def test_batch_detection_flags(se):
    """Batch-carried leaves double their leading dim at 2x microbatch;
    the SSM-style reduced leaves don't.  For the transformer stage all
    res2 leaves are batch-carried."""
    assert all(se.r2_batch)
    assert all(se.it_batch)


def test_mamba_has_reduced_inter_leaves():
    cfg = dict(dim=32, blocks=1, seq=16, vocab=64, microbatch=2, stages=1,
               use_kernels=False)
    pipe = BUILDERS["mamba"](cfg)
    step = optim.OPTIMIZERS["adamw"](lr=1e-3)
    se = StageExport(pipe.stages[0], pipe.input_spec, step, seed_base=0)
    # the SSM folds its (b,t)-reduced a_log/d grads into inter: those
    # leaves must be flagged sum-merge, not concat-merge
    assert not all(se.it_batch), "expected at least one reduced inter leaf"


def test_export_preset_writes_manifest(tmp_path):
    cfg_name = "transformer-tiny"
    man = export_preset(cfg_name, str(tmp_path), want_cost=False,
                        verbose=False)
    d = tmp_path / cfg_name
    assert (d / "manifest.json").exists()
    j = json.loads((d / "manifest.json").read_text())
    assert j["preset"] == cfg_name
    assert j["stages"] == 2
    for st in j["stage"]:
        for art in st["artifacts"].values():
            assert (d / art["file"]).exists(), art
        assert st["bytes"]["params"] > 0
        assert st["bytes"]["res2"] > 0
    assert man["loss"]["file"] == "loss.hlo.txt"
    # HLO text is parseable-ish: starts with HloModule
    head = (d / j["stage"][0]["artifacts"]["fwd"]["file"]).read_text()[:200]
    assert "HloModule" in head


def test_presets_registry_complete():
    for name in ["transformer-s", "bert-s", "mamba-s", "resnet-s",
                 "transformer-7b-paper", "resnet152-paper"]:
        cfg = presets.get(name)
        assert cfg["arch"] in BUILDERS
        assert cfg["optimizer"] in optim.OPTIMIZERS
    # paper-scale transformer matches Table 2 / §3.2
    t7b = presets.get("transformer-7b-paper")
    assert t7b["dim"] == 4096 and t7b["seq"] == 1024
    r152 = presets.get("resnet152-paper")
    assert r152["split"] == [10, 14, 14, 12]
