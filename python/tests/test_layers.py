"""L2 layer tests: every module's split backward vs jax.vjp (autograd).

The invariant the whole paper rests on: splitting backward into p1
(input grad) + p2 (weight grad) is *semantics-preserving* — together
they must equal what the fused autodiff engine produces.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import layers as L

jax.config.update("jax_platform_name", "cpu")


def _rand(seed, *shape):
    return jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32)


def check_module(mod, x, seed=0, rtol=2e-4, atol=2e-4):
    """Assert p1 ⊎ p2 ≡ jax.vjp for one module instance and input."""
    params = mod.init(jax.random.PRNGKey(seed)) if mod.has_params else {}
    y, res1, res2 = mod.fwd(params, x)
    gy = _rand(seed + 1, *y.shape)
    gx, inter = mod.bwd_p1(params, res1, res2, gy)

    if mod.has_params:
        ref_y, vjp = jax.vjp(lambda p, xx: mod.fwd(p, xx)[0], params, x)
        gp_ref, gx_ref = vjp(gy)
        grads = mod.bwd_p2(res2, inter)
        ga, _ = jax.tree_util.tree_flatten(grads)
        gb, _ = jax.tree_util.tree_flatten(gp_ref)
        assert len(ga) == len(gb)
        for a, b in zip(ga, gb):
            np.testing.assert_allclose(a, b, rtol=rtol, atol=atol)
    else:
        ref_y, vjp = jax.vjp(lambda xx: mod.fwd({}, xx)[0], x)
        (gx_ref,) = vjp(gy)
        assert inter == (), "param-free module must have empty inter"
    np.testing.assert_allclose(y, ref_y, rtol=1e-5, atol=1e-5)
    if x.dtype != jnp.int32:
        np.testing.assert_allclose(gx, gx_ref, rtol=rtol, atol=atol)
    return y


# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bias", [True, False])
def test_linear(bias):
    check_module(L.Linear(24, 40, bias=bias), _rand(0, 6, 24))


def test_linear_3d_input():
    check_module(L.Linear(16, 32), _rand(1, 4, 10, 16))


def test_embedding():
    ids = jax.random.randint(jax.random.PRNGKey(2), (4, 12), 0, 50)
    check_module(L.Embedding(50, 16), ids)


@pytest.mark.parametrize("shape", [(8, 32), (2, 16, 24)])
def test_rmsnorm(shape):
    check_module(L.RMSNorm(shape[-1], use_kernel=False), _rand(3, *shape))


def test_rmsnorm_kernel_path_matches_ref_path():
    x = _rand(4, 16, 32)
    mk = L.RMSNorm(32, use_kernel=True)
    mr = L.RMSNorm(32, use_kernel=False)
    p = mk.init(jax.random.PRNGKey(0))
    yk, _, _ = mk.fwd(p, x)
    yr, _, _ = mr.fwd(p, x)
    np.testing.assert_allclose(yk, yr, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("shape", [(8, 32), (2, 16, 24)])
def test_layernorm(shape):
    check_module(L.LayerNorm(shape[-1]), _rand(5, *shape))


def test_relu():
    check_module(L.ReLU(), _rand(6, 8, 16))


def test_gelu():
    check_module(L.GELU(), _rand(7, 8, 16))


@pytest.mark.parametrize("causal,rope", [(True, True), (True, False),
                                         (False, False)])
def test_attention(causal, rope):
    mod = L.Attention(32, 4, 16, causal=causal, rope=rope, bias=False)
    check_module(mod, _rand(8, 2, 16, 32), rtol=5e-4, atol=5e-4)


def test_attention_with_bias():
    mod = L.Attention(32, 4, 16, causal=False, rope=False, bias=True)
    check_module(mod, _rand(9, 2, 16, 32), rtol=5e-4, atol=5e-4)


def test_attention_has_no_p2_for_sdpa_core():
    """SDPA residuals (q,k,v,p) live in res1 — released after p1 (paper
    §4.2: functional ops release their activations during backward-p1)."""
    mod = L.Attention(32, 4, 16)
    p = mod.init(jax.random.PRNGKey(0))
    _, res1, res2 = mod.fwd(p, _rand(10, 2, 16, 32))
    assert len(res1) == 4          # q, k, v, attention probs
    assert len(res2) == 2          # x, o — the projection operands only


def test_swiglu():
    check_module(L.SwiGLU(24, 64), _rand(11, 4, 24), rtol=5e-4, atol=5e-4)


def test_mlp():
    check_module(L.MLP(24, 64), _rand(12, 4, 24), rtol=5e-4, atol=5e-4)


@pytest.mark.parametrize("stride,pad,k", [(1, 1, 3), (2, 1, 3), (1, 0, 1),
                                          (2, 3, 7)])
def test_conv2d(stride, pad, k):
    mod = L.Conv2d(3, 8, k, stride=stride, padding=pad)
    check_module(mod, _rand(13, 2, 3, 16, 16), rtol=5e-4, atol=5e-4)


def test_conv2d_with_bias():
    check_module(L.Conv2d(4, 6, 3, padding=1, bias=True),
                 _rand(14, 2, 4, 8, 8), rtol=5e-4, atol=5e-4)


def test_batchnorm2d():
    check_module(L.BatchNorm2d(6), _rand(15, 4, 6, 8, 8), rtol=5e-4, atol=5e-4)


def test_batchnorm_p2_simpler_than_p1():
    """Paper §4.1: BN's p2 is two reductions while p1 carries the full
    statistics chain — verify p2 equals the direct reductions."""
    mod = L.BatchNorm2d(4)
    x = _rand(16, 2, 4, 6, 6)
    p = mod.init(jax.random.PRNGKey(0))
    y, r1, r2 = mod.fwd(p, x)
    gy = _rand(17, *y.shape)
    _, inter = mod.bwd_p1(p, r1, r2, gy)
    g = mod.bwd_p2(r2, inter)
    xhat, _ = r2
    np.testing.assert_allclose(g["b"], jnp.sum(gy, axis=(0, 2, 3)),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(g["g"], jnp.sum(gy * xhat, axis=(0, 2, 3)),
                               rtol=1e-5, atol=1e-5)


def test_maxpool():
    check_module(L.MaxPool2d(3, 2, padding=1), _rand(18, 2, 3, 9, 9))


def test_global_avg_pool():
    check_module(L.GlobalAvgPool(), _rand(19, 2, 4, 6, 6))


def test_depthwise_conv1d():
    check_module(L.DepthwiseConv1d(8, 4), _rand(20, 2, 12, 8),
                 rtol=5e-4, atol=5e-4)


def test_depthwise_conv1d_is_causal():
    """Output at time t must not depend on inputs after t."""
    mod = L.DepthwiseConv1d(4, 3)
    p = mod.init(jax.random.PRNGKey(1))
    x = _rand(21, 1, 10, 4)
    y0, _, _ = mod.fwd(p, x)
    x2 = x.at[:, 7:].set(99.0)
    y1, _, _ = mod.fwd(p, x2)
    np.testing.assert_allclose(y0[:, :7], y1[:, :7], rtol=1e-6, atol=1e-6)


def test_ssm_scan():
    mod = L.SSMScan(6, 4)
    u = _rand(22, 2, 10, 6)
    delta = jax.nn.softplus(_rand(23, 2, 10, 6))
    bmat = _rand(24, 2, 10, 4)
    cmat = _rand(25, 2, 10, 4)
    params = mod.init(jax.random.PRNGKey(3))
    y, r1, r2 = mod.fwd(params, (u, delta, bmat, cmat))
    gy = _rand(26, *y.shape)
    (gu, gd, gb, gc), inter = mod.bwd_p1(params, r1, r2, gy)
    grads = mod.bwd_p2(r2, inter)

    ref_y, vjp = jax.vjp(
        lambda p, uu, dd, bb, cc: mod.fwd(p, (uu, dd, bb, cc))[0],
        params, u, delta, bmat, cmat)
    gp_ref, gu_r, gd_r, gb_r, gc_r = vjp(gy)
    np.testing.assert_allclose(y, ref_y, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(gu, gu_r, rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(gd, gd_r, rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(gb, gb_r, rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(gc, gc_r, rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(grads["a_log"], gp_ref["a_log"],
                               rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(grads["d"], gp_ref["d"], rtol=5e-4, atol=5e-4)


def test_ssm_hidden_states_stashed_in_res2():
    """The paper's Mamba memory blow-up comes from h living until p2."""
    mod = L.SSMScan(6, 4)
    u = _rand(27, 2, 10, 6)
    args = (u, jax.nn.softplus(u), _rand(28, 2, 10, 4), _rand(29, 2, 10, 4))
    _, _, res2 = mod.fwd(mod.init(jax.random.PRNGKey(0)), args)
    hs = res2[-1]
    assert hs.shape == (2, 10, 6, 4)   # [b, t, di, s] — all time steps


# ---------------------------------------------------------------------------
# hypothesis: the split-backward law on randomly shaped linear layers


@settings(max_examples=15, deadline=None)
@given(b=st.integers(1, 6), din=st.integers(1, 32), dout=st.integers(1, 32),
       bias=st.booleans())
def test_linear_split_law_hypothesis(b, din, dout, bias):
    check_module(L.Linear(din, dout, bias=bias),
                 _rand(b * 7 + din, b, din), seed=din * 31 + dout)


@settings(max_examples=10, deadline=None)
@given(rows=st.integers(2, 16), d=st.integers(2, 32))
def test_rmsnorm_split_law_hypothesis(rows, d):
    check_module(L.RMSNorm(d, use_kernel=False), _rand(rows + d, rows, d),
                 seed=rows * 13 + d)
