"""Optimizer tests: each update rule vs hand-computed references, plus
the shared flat signature contract the rust executor relies on."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import optim

jax.config.update("jax_platform_name", "cpu")


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "a": {"w": jax.random.normal(k, (4, 3)),
              "b": jnp.zeros((3,))},
        "c": {"g": jnp.ones((5,))},
    }


def _grads(seed=1):
    t = _tree(seed)
    return jax.tree_util.tree_map(lambda x: jnp.ones_like(x) * 0.5, t)


def _zeros_like(t):
    return jax.tree_util.tree_map(jnp.zeros_like, t)


def test_sgd_plain():
    p = _tree()
    g = _grads()
    step = optim.sgd(lr=0.1)
    new_p, s0, s1 = step(p, g, _zeros_like(p), _zeros_like(p),
                         jnp.asarray(1.0))
    np.testing.assert_allclose(new_p["a"]["w"], p["a"]["w"] - 0.1 * 0.5,
                               rtol=1e-6)
    # slots untouched
    assert float(jnp.sum(jnp.abs(s0["a"]["w"]))) == 0.0


def test_sgd_momentum_accumulates():
    p = _tree()
    g = _grads()
    step = optim.sgd(lr=0.1, momentum=0.9)
    z = _zeros_like(p)
    p1, m1, _ = step(p, g, z, z, jnp.asarray(1.0))
    p2, m2, _ = step(p1, g, m1, z, jnp.asarray(2.0))
    # second-step momentum: m2 = 0.9*0.5 + 0.5 = 0.95
    np.testing.assert_allclose(m2["c"]["g"], np.full(5, 0.95), rtol=1e-6)
    np.testing.assert_allclose(p2["c"]["g"],
                               p1["c"]["g"] - 0.1 * 0.95, rtol=1e-6)


def test_adam_first_step_matches_formula():
    p = _tree()
    g = _grads()
    lr, b1, b2, eps = 1e-3, 0.9, 0.999, 1e-8
    step = optim.adam(lr=lr, b1=b1, b2=b2, eps=eps)
    z = _zeros_like(p)
    new_p, m, v = step(p, g, z, z, jnp.asarray(1.0))
    # bias-corrected first step: mhat = g, vhat = g^2
    gval = 0.5
    want = p["a"]["w"] - lr * gval / (np.sqrt(gval * gval) + eps)
    np.testing.assert_allclose(new_p["a"]["w"], want, rtol=1e-5)
    np.testing.assert_allclose(m["a"]["w"],
                               np.full((4, 3), (1 - b1) * gval), rtol=1e-6)
    np.testing.assert_allclose(v["a"]["w"],
                               np.full((4, 3), (1 - b2) * gval ** 2),
                               rtol=1e-5)


def test_adamw_decoupled_decay():
    p = _tree()
    g = _grads()
    wd = 0.1
    lr = 1e-2
    plain = optim.adam(lr=lr)
    decoupled = optim.adamw(lr=lr, weight_decay=wd)
    z = _zeros_like(p)
    pa, _, _ = plain(p, g, z, z, jnp.asarray(1.0))
    pw, _, _ = decoupled(p, g, z, z, jnp.asarray(1.0))
    # adamw = adam - lr*wd*p0
    np.testing.assert_allclose(
        pw["a"]["w"], pa["a"]["w"] - lr * wd * p["a"]["w"], rtol=1e-5)


def test_adam_converges_on_quadratic():
    """End-to-end sanity: Adam minimizes a simple quadratic."""
    step = optim.adam(lr=0.05)
    p = {"x": jnp.asarray([5.0, -3.0])}
    m = {"x": jnp.zeros(2)}
    v = {"x": jnp.zeros(2)}
    for t in range(1, 300):
        g = {"x": 2.0 * p["x"]}
        p, m, v = step(p, g, m, v, jnp.asarray(float(t)))
    assert float(jnp.max(jnp.abs(p["x"]))) < 0.05


@pytest.mark.parametrize("name", ["sgd", "adam", "adamw"])
def test_uniform_signature(name):
    """All optimizers share step(p, g, s0, s1, t) -> (p, s0, s1)."""
    step = optim.OPTIMIZERS[name](lr=0.01)
    p = _tree()
    z = _zeros_like(p)
    out = step(p, _grads(), z, z, jnp.asarray(1.0))
    assert len(out) == 3
    flat_in, _ = jax.tree_util.tree_flatten(p)
    flat_out, _ = jax.tree_util.tree_flatten(out[0])
    assert len(flat_in) == len(flat_out)
    for a, b in zip(flat_in, flat_out):
        assert a.shape == b.shape
