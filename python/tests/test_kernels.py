"""L1 kernel tests: every Pallas kernel vs its pure-jnp oracle.

Hypothesis sweeps shapes (and odd, non-tile-aligned sizes) to exercise
the block-size clamping logic; fixed cases pin the MXU-shaped paths.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import kernels as K
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")

F32 = np.float32


def _rand(rng, *shape):
    return jnp.asarray(rng.normal(size=shape).astype(F32))


# ---------------------------------------------------------------------------
# matmul


@pytest.mark.parametrize("m,k,n", [(128, 128, 128), (256, 128, 64),
                                   (64, 256, 128), (32, 32, 32)])
def test_matmul_tile_aligned(m, k, n):
    rng = np.random.default_rng(0)
    x, y = _rand(rng, m, k), _rand(rng, k, n)
    np.testing.assert_allclose(K.matmul(x, y), ref.matmul(x, y),
                               rtol=2e-5, atol=2e-5)


@settings(max_examples=25, deadline=None)
@given(m=st.integers(1, 96), k=st.integers(1, 96), n=st.integers(1, 96))
def test_matmul_hypothesis(m, k, n):
    rng = np.random.default_rng(m * 10007 + k * 101 + n)
    x, y = _rand(rng, m, k), _rand(rng, k, n)
    np.testing.assert_allclose(K.matmul(x, y), ref.matmul(x, y),
                               rtol=2e-5, atol=2e-5)


def test_matmul_vmem_estimate_under_budget():
    from compile.kernels.matmul import vmem_bytes
    # paper-scale transformer dims must fit VMEM (16 MiB) per grid step
    assert vmem_bytes(4096, 4096, 4096) <= 16 * 2 ** 20


def test_matmul_mxu_utilization_full_at_model_dims():
    from compile.kernels.matmul import mxu_utilization
    assert mxu_utilization(4096, 4096, 4096) == 1.0
    assert mxu_utilization(64, 64, 64) < 1.0


# ---------------------------------------------------------------------------
# rmsnorm


@pytest.mark.parametrize("rows,d", [(128, 256), (64, 64), (256, 128)])
def test_rmsnorm_fwd(rows, d):
    rng = np.random.default_rng(1)
    x, g = _rand(rng, rows, d), _rand(rng, d)
    y, rstd = K.rmsnorm_fwd(x, g)
    yr, rr = ref.rmsnorm_fwd(x, g)
    np.testing.assert_allclose(y, yr, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(rstd, rr, rtol=1e-5, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(rows=st.integers(1, 80), d=st.integers(2, 80))
def test_rmsnorm_roundtrip_hypothesis(rows, d):
    rng = np.random.default_rng(rows * 131 + d)
    x, g = _rand(rng, rows, d), _rand(rng, d)
    gy = _rand(rng, rows, d)
    _, rstd = K.rmsnorm_fwd(x, g)
    np.testing.assert_allclose(
        K.rmsnorm_bwd_p1(x, g, rstd, gy),
        ref.rmsnorm_bwd_p1(x, g, rstd, gy), rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(
        K.rmsnorm_bwd_p2(x, rstd, gy),
        ref.rmsnorm_bwd_p2(x, rstd, gy), rtol=1e-4, atol=1e-4)


def test_rmsnorm_p1_p2_equal_autograd():
    """The split halves must jointly reproduce jax.grad of the fused op."""
    rng = np.random.default_rng(3)
    x, g = _rand(rng, 32, 48), _rand(rng, 48)
    gy = _rand(rng, 32, 48)

    def fused(x, g):
        return jnp.sum(ref.rmsnorm_fwd(x, g)[0] * gy)

    gx_ref, gg_ref = jax.grad(fused, argnums=(0, 1))(x, g)
    _, rstd = K.rmsnorm_fwd(x, g)
    np.testing.assert_allclose(K.rmsnorm_bwd_p1(x, g, rstd, gy), gx_ref,
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(K.rmsnorm_bwd_p2(x, rstd, gy), gg_ref,
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# softmax


@pytest.mark.parametrize("rows,d", [(128, 128), (64, 32), (16, 256)])
def test_softmax_fwd_bwd(rows, d):
    rng = np.random.default_rng(4)
    x, gy = _rand(rng, rows, d), _rand(rng, rows, d)
    y = K.softmax_fwd(x)
    np.testing.assert_allclose(y, ref.softmax_fwd(x), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(K.softmax_bwd(y, gy),
                               ref.softmax_bwd(ref.softmax_fwd(x), gy),
                               rtol=1e-5, atol=1e-6)


@settings(max_examples=20, deadline=None)
@given(rows=st.integers(1, 64), d=st.integers(1, 64))
def test_softmax_hypothesis(rows, d):
    rng = np.random.default_rng(rows * 977 + d)
    x = _rand(rng, rows, d)
    np.testing.assert_allclose(K.softmax_fwd(x), ref.softmax_fwd(x),
                               rtol=1e-5, atol=1e-6)


def test_softmax_rows_sum_to_one():
    rng = np.random.default_rng(5)
    y = K.softmax_fwd(_rand(rng, 64, 96))
    np.testing.assert_allclose(jnp.sum(y, axis=-1), np.ones(64),
                               rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# attention


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("h,t,hd", [(4, 64, 32), (2, 128, 16), (8, 32, 64)])
def test_attention_fwd(h, t, hd, causal):
    rng = np.random.default_rng(6)
    q, k, v = (_rand(rng, h, t, hd) for _ in range(3))
    out = K.attention_fwd(q, k, v, causal=causal, block_q=32, block_k=32)
    np.testing.assert_allclose(out, ref.attention_fwd(q, k, v, causal=causal),
                               rtol=3e-5, atol=3e-5)


@settings(max_examples=10, deadline=None)
@given(t=st.sampled_from([16, 24, 32, 48]), hd=st.sampled_from([8, 16, 32]),
       bq=st.sampled_from([8, 16, 32]))
def test_attention_blocking_invariance(t, hd, bq):
    """Output must not depend on the KV/Q blocking chosen."""
    rng = np.random.default_rng(t * 31 + hd)
    q, k, v = (_rand(rng, 2, t, hd) for _ in range(3))
    a = K.attention_fwd(q, k, v, causal=True, block_q=bq, block_k=bq)
    b = K.attention_fwd(q, k, v, causal=True, block_q=t, block_k=t)
    np.testing.assert_allclose(a, b, rtol=3e-5, atol=3e-5)


def test_attention_vmem_estimate():
    from compile.kernels.attention import vmem_bytes
    assert vmem_bytes(1024, 128) <= 16 * 2 ** 20
