"""L2 architecture tests: per-stage split backward vs autograd, stage
chaining, and the residual-class bookkeeping that drives the paper's
memory accounting.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.archs import BUILDERS
from compile.archs.common import lm_cross_entropy, class_cross_entropy, \
    split_blocks

jax.config.update("jax_platform_name", "cpu")

CFGS = {
    "transformer": dict(dim=64, heads=4, blocks=2, seq=32, vocab=128,
                        microbatch=2, stages=2, use_kernels=False),
    "bert": dict(dim=64, heads=4, blocks=2, seq=32, vocab=128,
                 microbatch=2, stages=2),
    "mamba": dict(dim=48, blocks=2, seq=32, vocab=128, microbatch=2,
                  stages=2, use_kernels=False),
    "resnet": dict(stacks=[1, 1, 1, 1], image=64, classes=10, microbatch=2,
                   stages=2),
}

TOL = {"transformer": 5e-4, "bert": 5e-4, "mamba": 5e-4, "resnet": 1e-2}


def _input_for(arch, pipe, cfg, seed=1):
    if arch == "resnet":
        return jax.random.normal(jax.random.PRNGKey(seed),
                                 pipe.input_spec.shape, jnp.float32)
    return jax.random.randint(jax.random.PRNGKey(seed),
                              pipe.input_spec.shape, 0, cfg["vocab"])


@pytest.mark.parametrize("arch", list(CFGS))
def test_stage_split_backward_equals_autograd(arch):
    cfg = CFGS[arch]
    pipe = BUILDERS[arch](cfg)
    tol = TOL[arch]
    x = _input_for(arch, pipe, cfg)
    for si, st in enumerate(pipe.stages):
        params = st.init(jax.random.PRNGKey(100 + si))
        y, r1, r2 = st.fwd(params, x)
        gy = jax.random.normal(jax.random.PRNGKey(7), y.shape, jnp.float32)
        gx, inter = st.bwd_p1(params, r1, r2, gy)
        grads = st.bwd_p2(r2, inter)
        if si == 0:
            ref_y, vjp = jax.vjp(lambda p: st.apply(p, x), params)
            (gref,) = vjp(gy)
        else:
            ref_y, vjp = jax.vjp(lambda p, xx: st.apply(p, xx), params, x)
            gref, gx_ref = vjp(gy)
            np.testing.assert_allclose(gx, gx_ref, rtol=tol, atol=tol)
        np.testing.assert_allclose(y, ref_y, rtol=1e-5, atol=1e-5)
        fa, _ = jax.tree_util.tree_flatten(grads)
        fb, _ = jax.tree_util.tree_flatten(gref)
        assert len(fa) == len(fb)
        for a, b in zip(fa, fb):
            np.testing.assert_allclose(a, b, rtol=tol, atol=tol)
        x = y


@pytest.mark.parametrize("arch", list(CFGS))
def test_full_pipeline_chain_matches_single_device(arch):
    """fwd through all stages + p1 back through all stages == one fused
    model's autograd — the cross-stage composition law."""
    cfg = CFGS[arch]
    pipe = BUILDERS[arch](cfg)
    tol = TOL[arch]
    params = [st.init(jax.random.PRNGKey(100 + i))
              for i, st in enumerate(pipe.stages)]
    x0 = _input_for(arch, pipe, cfg)

    # pipelined split run
    acts, res = [x0], []
    x = x0
    for st, p in zip(pipe.stages, params):
        x, r1, r2 = st.fwd(p, x)
        res.append((r1, r2))
        acts.append(x)
    logits = x
    if arch == "resnet":
        labels = jax.random.randint(jax.random.PRNGKey(9), (cfg["microbatch"],),
                                    0, cfg["classes"])
        loss, g = class_cross_entropy(logits, labels)
    else:
        labels = jax.random.randint(jax.random.PRNGKey(9),
                                    pipe.label_spec.shape, 0, cfg["vocab"])
        loss, g = lm_cross_entropy(logits, labels)
    all_grads = []
    for st, p, (r1, r2) in zip(pipe.stages[::-1], params[::-1], res[::-1]):
        g, inter = st.bwd_p1(p, r1, r2, g)
        all_grads.append(st.bwd_p2(r2, inter))
    all_grads = all_grads[::-1]

    # fused single-device reference
    def fused(ps):
        h = x0
        for st, p in zip(pipe.stages, ps):
            h = st.apply(p, h)
        if arch == "resnet":
            return class_cross_entropy(h, labels)[0]
        return lm_cross_entropy(h, labels)[0]

    loss_ref, vjp = jax.vjp(fused, params)
    (gp_ref,) = vjp(jnp.ones(()))
    np.testing.assert_allclose(loss, loss_ref, rtol=1e-5, atol=1e-6)
    fa, _ = jax.tree_util.tree_flatten(all_grads)
    fb, _ = jax.tree_util.tree_flatten(gp_ref)
    assert len(fa) == len(fb)
    for a, b in zip(fa, fb):
        np.testing.assert_allclose(a, b, rtol=tol, atol=tol)


def test_split_blocks_even_and_exhaustive():
    assert split_blocks(32, 4) == [8, 8, 8, 8]
    assert split_blocks(10, 4) == [3, 3, 2, 2]
    assert sum(split_blocks(50, 4)) == 50


def test_resnet_paper_split():
    """The paper's ResNet152 bottleneck split [10,14,14,12] must be
    accepted and produce 50 bottlenecks."""
    cfg = dict(stacks=[3, 8, 36, 3], image=64, classes=100, microbatch=1,
               stages=4, split=[10, 14, 14, 12])
    pipe = BUILDERS["resnet"](cfg)
    n_btl = sum(1 for st in pipe.stages for n, _ in st.modules
                if n.startswith("btl"))
    assert n_btl == 50
    assert pipe.n_stages == 4


def test_lm_cross_entropy_grad_is_autograd():
    logits = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 32))
    labels = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, 32)
    loss, g = lm_cross_entropy(logits, labels)
    ref = jax.grad(lambda l: lm_cross_entropy(l, labels)[0])(logits)
    np.testing.assert_allclose(g, ref, rtol=1e-5, atol=1e-6)
    assert loss.shape == ()


def test_transformer_kernel_and_ref_paths_agree():
    """AOT path (Pallas kernels on) must match the oracle path (off)."""
    cfg = dict(CFGS["transformer"])
    x = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, 128)
    cfg_k = dict(cfg, use_kernels=True)
    pk = BUILDERS["transformer"](cfg_k)
    pr = BUILDERS["transformer"](cfg)
    params = [st.init(jax.random.PRNGKey(100 + i))
              for i, st in enumerate(pr.stages)]
    hk = hr = x
    for stk, str_, p in zip(pk.stages, pr.stages, params):
        hk = stk.apply(p, hk)
        hr = str_.apply(p, hr)
    np.testing.assert_allclose(hk, hr, rtol=1e-4, atol=1e-4)
