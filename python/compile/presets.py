"""Named model/run configurations (the analogue of the paper's Table 2).

Two tiers:

* ``*-s``     — CPU-scale stand-ins for the paper's four benchmark models,
                sized so a 4-stage pipeline trains at interactive speed on
                one host while preserving each architecture's *profile*
                (uniform vs non-uniform graph, attention-has-no-p2,
                SSM-stash-heavy, BN-asymmetric).
* ``*-paper`` — the paper's actual hyperparameters (Table 2 + §3.2).
                Export-gated: these compile to HLO like any preset but are
                not runnable on this host (documented in DESIGN.md §3).

``*-tiny`` presets are for integration tests (seconds, 2 stages).

Every preset carries the optimizer from Table 2.  ``n_microbatches``
fixes the concat width M of the exported ``bwd_p2_concat`` artifact
(= N for 1F1B-1, 2N for 1F1B-2; rust picks loop-or-concat at runtime).
"""

PRESETS = {
    # -- integration-test tier ---------------------------------------------
    "transformer-tiny": dict(
        arch="transformer", dim=64, heads=4, blocks=4, seq=32, vocab=256,
        microbatch=2, stages=2, n_microbatches=2,
        optimizer="adam", lr=1e-3),
    "bert-tiny": dict(
        arch="bert", dim=64, heads=4, blocks=4, seq=32, vocab=256,
        microbatch=2, stages=2, n_microbatches=2,
        optimizer="adam", lr=1e-3),
    "mamba-tiny": dict(
        arch="mamba", dim=48, blocks=4, seq=32, vocab=256,
        microbatch=2, stages=2, n_microbatches=2,
        optimizer="adamw", lr=1e-3),
    "resnet-tiny": dict(
        arch="resnet", stacks=[1, 1, 1, 1], image=64, classes=10,
        microbatch=2, stages=2, n_microbatches=2,
        optimizer="sgd", lr=0.05),

    # -- CPU-scale benchmark tier (the Fig 3/4 runs on this host) -----------
    "transformer-s": dict(
        arch="transformer", dim=256, heads=8, blocks=12, seq=128, vocab=4096,
        microbatch=1, stages=4, n_microbatches=8,
        optimizer="adam", lr=3e-4),
    "bert-s": dict(
        arch="bert", dim=256, heads=8, blocks=12, seq=128, vocab=4096,
        microbatch=2, stages=4, n_microbatches=8,
        optimizer="adam", lr=3e-4),
    "mamba-s": dict(
        arch="mamba", dim=256, blocks=12, seq=128, vocab=4096,
        microbatch=2, stages=4, n_microbatches=8,
        optimizer="adamw", lr=3e-4),
    "resnet-s": dict(
        arch="resnet", stacks=[2, 3, 6, 3], image=64, classes=100,
        microbatch=8, stages=4, n_microbatches=8, split=[3, 4, 4, 3],
        optimizer="sgd", lr=0.05),

    # -- e2e training example (examples/train_transformer.rs) ---------------
    "transformer-m": dict(
        arch="transformer", dim=512, heads=8, blocks=16, seq=256, vocab=8192,
        microbatch=1, stages=4, n_microbatches=4,
        optimizer="adam", lr=3e-4),

    # -- scaling tier (Figs 6/7; BERT-like, mb 2 per the paper §4.3) --------
    "bert-scale-fixed": dict(   # 32 blocks total, vary stages 4/8/16
        arch="bert", dim=128, heads=8, blocks=32, seq=64, vocab=1024,
        microbatch=2, stages=4, n_microbatches=8,
        optimizer="adam", lr=3e-4),
    # variable-size tier: 8 blocks per stage (stages set at export)
    "bert-scale-var": dict(
        arch="bert", dim=128, heads=8, blocks=32, seq=64, vocab=1024,
        microbatch=2, stages=4, n_microbatches=8,
        optimizer="adam", lr=3e-4),

    # -- paper-scale tier (export-gated; Table 2 hyperparameters) -----------
    "transformer-7b-paper": dict(
        arch="transformer", dim=4096, heads=32, blocks=32, seq=1024,
        vocab=32000, microbatch=1, stages=4, n_microbatches=8,
        optimizer="adam", lr=3e-4),
    "bert-large-paper": dict(
        arch="bert", dim=1024, heads=16, blocks=24, seq=512, vocab=30522,
        microbatch=2, stages=4, n_microbatches=8,
        optimizer="adam", lr=1e-4),
    "mamba-1.4b-paper": dict(
        arch="mamba", dim=2048, blocks=48, seq=1024, vocab=32000,
        microbatch=2, stages=4, n_microbatches=8,
        optimizer="adamw", lr=3e-4),
    "resnet152-paper": dict(
        arch="resnet", stacks=[3, 8, 36, 3], image=224, classes=1000,
        microbatch=8, stages=4, n_microbatches=8, split=[10, 14, 14, 12],
        optimizer="sgd", lr=0.1),
}


def get(name: str, **overrides) -> dict:
    cfg = dict(PRESETS[name])
    cfg.update(overrides)
    cfg["preset"] = name
    return cfg
