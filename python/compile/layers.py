"""Manual-backward module system — the paper's torch.autograd replacement.

The paper (§3.2): *"we do not use PyTorch's automatic differentiation
engine ... Each module has a forward and a backward-p1 function; if that
module contains parameters then it also has a backward-p2 function."*

This file is the JAX equivalent.  Every module implements:

  fwd(params, x)                  -> (y, res1, res2)
  bwd_p1(params, res1, res2, gy)  -> (gx, inter)
  bwd_p2(res2, inter)             -> grads          (only if has_params)

with the residual split that drives the paper's §4.2 memory analysis:

  * ``res1``  — state needed only by backward-p1; **released after p1**
                (e.g. q/k/v/attention probabilities, ReLU masks).
  * ``res2``  — state held *across* the p1→p2 gap (e.g. linear/conv input
                activations).  Under 2BP these live until the deferred p2.
  * ``inter`` — the "intermediate derivatives" produced by p1 for p2
                (output cotangents such as gy for a linear layer).

All residuals/intermediates are flat tuples of arrays so the AOT path
can export stage functions with flat HLO signatures; byte sizes of each
class are recorded in the artifact manifest and drive both the rust
memory accountant (Fig 4/5) and the simulator's memory model (Fig 7 OOM).

Correctness contract (tested in python/tests/test_layers.py): for every
module, ``bwd_p1`` + ``bwd_p2`` must exactly reproduce ``jax.vjp`` of the
fused forward — i.e. **p1 ⊎ p2 ≡ autograd**.
"""

from __future__ import annotations

import math
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from .kernels import ref as kref

Params = Dict[str, jnp.ndarray]
Arrays = Tuple[jnp.ndarray, ...]


# ---------------------------------------------------------------------------
# helpers


def _split_key(key, n):
    return jax.random.split(key, n)


def _he(key, shape, fan_in):
    return jax.random.normal(key, shape, jnp.float32) * math.sqrt(2.0 / fan_in)


def _glorot(key, shape, fan_in, fan_out):
    lim = math.sqrt(6.0 / (fan_in + fan_out))
    return jax.random.uniform(key, shape, jnp.float32, -lim, lim)


class Module:
    """Base class: a layer with hand-written split backward.

    Subclasses override ``init``, ``fwd``, ``bwd_p1`` and (when
    ``has_params``) ``bwd_p2``.  ``param_names`` fixes a deterministic
    ordering used when stage functions are flattened for AOT export.
    """

    has_params: bool = False
    param_names: Tuple[str, ...] = ()

    def init(self, key) -> Params:
        return {}

    def fwd(self, params: Params, x):
        raise NotImplementedError

    def bwd_p1(self, params: Params, res1: Arrays, res2: Arrays, gy):
        raise NotImplementedError

    def bwd_p2(self, res2: Arrays, inter: Arrays) -> Params:
        raise NotImplementedError(f"{type(self).__name__} has no parameters")

    # fused reference (oracle + single-device baseline): default composes
    # the split halves; tests additionally compare against jax.vjp.
    def apply(self, params: Params, x):
        y, _, _ = self.fwd(params, x)
        return y


# ---------------------------------------------------------------------------
# Linear


class Linear(Module):
    """y = x @ w (+ b).  x: [..., d_in].

    res2 = (x,): input activation held until p2 (paper §4.2: "for Linear
    and Convolution layers, both the input activations and output
    derivatives need to be stored in memory for backward-p2").
    inter = (gy,): the output derivative.
    """

    has_params = True

    def __init__(self, d_in: int, d_out: int, bias: bool = True):
        self.d_in, self.d_out, self.bias = d_in, d_out, bias
        self.param_names = ("w", "b") if bias else ("w",)

    def init(self, key) -> Params:
        kw, _ = _split_key(key, 2)
        p = {"w": _glorot(kw, (self.d_in, self.d_out), self.d_in, self.d_out)}
        if self.bias:
            p["b"] = jnp.zeros((self.d_out,), jnp.float32)
        return p

    def fwd(self, params, x):
        y = x @ params["w"]
        if self.bias:
            y = y + params["b"]
        return y, (), (x,)

    def bwd_p1(self, params, res1, res2, gy):
        return gy @ params["w"].T, (gy,)

    def bwd_p2(self, res2, inter):
        (x,) = res2
        (gy,) = inter
        x2 = x.reshape(-1, self.d_in)
        g2 = gy.reshape(-1, self.d_out)
        grads = {"w": x2.T @ g2}
        if self.bias:
            grads["b"] = jnp.sum(g2, axis=0)
        return grads


# ---------------------------------------------------------------------------
# Embedding


class Embedding(Module):
    """Token embedding lookup.  Input is int32 ids; gx is not defined
    (ids are not differentiable) — bwd_p1 returns a zero cotangent so the
    pipeline plumbing stays uniform; the executor on rank 0 discards it.
    """

    has_params = True
    param_names = ("w",)

    def __init__(self, vocab: int, d: int):
        self.vocab, self.d = vocab, d

    def init(self, key) -> Params:
        return {"w": jax.random.normal(key, (self.vocab, self.d), jnp.float32) * 0.02}

    def fwd(self, params, ids):
        return params["w"][ids], (), (ids,)

    def bwd_p1(self, params, res1, res2, gy):
        return jnp.zeros_like(res2[0], dtype=jnp.float32), (gy,)

    def bwd_p2(self, res2, inter):
        (ids,) = res2
        (gy,) = inter
        dw = jnp.zeros((self.vocab, self.d), jnp.float32)
        return {"w": dw.at[ids.reshape(-1)].add(gy.reshape(-1, self.d))}


# ---------------------------------------------------------------------------
# Norms


class RMSNorm(Module):
    """RMSNorm over the last axis; fwd/p1/p2 use the fused Pallas kernels
    when the flattened row count is kernel-friendly, else the jnp oracle.
    """

    has_params = True
    param_names = ("g",)

    def __init__(self, d: int, eps: float = 1e-5, use_kernel: bool = True):
        self.d, self.eps, self.use_kernel = d, eps, use_kernel

    def init(self, key) -> Params:
        return {"g": jnp.ones((self.d,), jnp.float32)}

    def fwd(self, params, x):
        x2 = x.reshape(-1, self.d)
        if self.use_kernel:
            from .kernels import rmsnorm_fwd
            y2, rstd = rmsnorm_fwd(x2, params["g"], eps=self.eps)
        else:
            y2, rstd = kref.rmsnorm_fwd(x2, params["g"], eps=self.eps)
        # res2 = (x, rstd): both needed by p2 (dg = sum gy*x*rstd); p1 also
        # reads them, which is free — res2 is still alive at p1 time.
        return y2.reshape(x.shape), (), (x, rstd)

    def bwd_p1(self, params, res1, res2, gy):
        x, rstd = res2
        x2 = x.reshape(-1, self.d)
        gy2 = gy.reshape(-1, self.d)
        if self.use_kernel:
            from .kernels import rmsnorm_bwd_p1
            gx2 = rmsnorm_bwd_p1(x2, params["g"], rstd, gy2)
        else:
            gx2 = kref.rmsnorm_bwd_p1(x2, params["g"], rstd, gy2)
        return gx2.reshape(x.shape), (gy,)

    def bwd_p2(self, res2, inter):
        x, rstd = res2
        (gy,) = inter
        x2 = x.reshape(-1, self.d)
        gy2 = gy.reshape(-1, self.d)
        if self.use_kernel:
            from .kernels import rmsnorm_bwd_p2
            dg = rmsnorm_bwd_p2(x2, rstd, gy2)
        else:
            dg = kref.rmsnorm_bwd_p2(x2, rstd, gy2)
        return {"g": dg}


class LayerNorm(Module):
    """LayerNorm over the last axis (BERT-style, with bias)."""

    has_params = True
    param_names = ("g", "b")

    def __init__(self, d: int, eps: float = 1e-5):
        self.d, self.eps = d, eps

    def init(self, key) -> Params:
        return {"g": jnp.ones((self.d,), jnp.float32),
                "b": jnp.zeros((self.d,), jnp.float32)}

    def fwd(self, params, x):
        mu = jnp.mean(x, axis=-1, keepdims=True)
        var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
        rstd = 1.0 / jnp.sqrt(var + self.eps)
        xhat = (x - mu) * rstd
        return xhat * params["g"] + params["b"], (), (xhat, rstd)

    def bwd_p1(self, params, res1, res2, gy):
        xhat, rstd = res2
        gh = gy * params["g"]
        m1 = jnp.mean(gh, axis=-1, keepdims=True)
        m2 = jnp.mean(gh * xhat, axis=-1, keepdims=True)
        return (gh - m1 - xhat * m2) * rstd, (gy,)

    def bwd_p2(self, res2, inter):
        xhat, _ = res2
        (gy,) = inter
        d = self.d
        return {
            "g": jnp.sum((gy * xhat).reshape(-1, d), axis=0),
            "b": jnp.sum(gy.reshape(-1, d), axis=0),
        }


# ---------------------------------------------------------------------------
# elementwise activations (purely functional: res released at p1, no p2)


class ReLU(Module):
    def fwd(self, params, x):
        return jnp.maximum(x, 0.0), (x,), ()

    def bwd_p1(self, params, res1, res2, gy):
        (x,) = res1
        return gy * (x > 0).astype(gy.dtype), ()


class GELU(Module):
    """tanh-approximation GELU (BERT)."""

    _c = math.sqrt(2.0 / math.pi)

    def _inner(self, x):
        return self._c * (x + 0.044715 * x ** 3)

    def fwd(self, params, x):
        t = jnp.tanh(self._inner(x))
        return 0.5 * x * (1.0 + t), (x,), ()

    def bwd_p1(self, params, res1, res2, gy):
        (x,) = res1
        t = jnp.tanh(self._inner(x))
        dt = (1.0 - t * t) * self._c * (1.0 + 3 * 0.044715 * x * x)
        return gy * (0.5 * (1.0 + t) + 0.5 * x * dt), ()


def _silu(x):
    return x * jax.nn.sigmoid(x)


def _dsilu(x):
    s = jax.nn.sigmoid(x)
    return s * (1.0 + x * (1.0 - s))


# ---------------------------------------------------------------------------
# Rotary position embedding (param-free, orthogonal per position)


class Rotary:
    """RoPE helper applied inside Attention (not a standalone Module).

    rotate(x, inv=True) applies the transpose rotation — used to pull
    cotangents back through the embedding in backward-p1.
    """

    def __init__(self, t: int, hd: int, base: float = 10000.0):
        half = hd // 2
        freqs = base ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
        ang = jnp.arange(t, dtype=jnp.float32)[:, None] * freqs[None, :]
        self.cos = jnp.cos(ang)  # [t, hd/2]
        self.sin = jnp.sin(ang)

    def rotate(self, x, inv: bool = False):
        # x: [..., t, hd]; pairs (x1, x2) = (x[..., :half], x[..., half:])
        half = x.shape[-1] // 2
        x1, x2 = x[..., :half], x[..., half:]
        sin = -self.sin if inv else self.sin
        r1 = x1 * self.cos - x2 * sin
        r2 = x2 * self.cos + x1 * sin
        return jnp.concatenate([r1, r2], axis=-1)


# ---------------------------------------------------------------------------
# Attention (multi-head SDPA with optional RoPE / causal mask / bias)


class Attention(Module):
    """Multi-head attention block body (projections + SDPA).

    The SDPA core is purely functional — it has no backward-p2 — while
    the four projections do; this mixed profile is exactly the paper's
    example of uneven p1/p2 cost (§4.1).

    res1 = (q, k, v, p): released after p1 (the paper's "operations that
    are purely functional ... release their activations during the
    backward-p1 calls").
    res2 = (x, o): projection inputs held for p2.
    inter = (gy, gq, gk, gv): output derivatives for the projections.
    """

    has_params = True

    def __init__(self, d: int, heads: int, t: int, causal: bool = True,
                 rope: bool = True, bias: bool = False,
                 use_flash_fwd: bool = False):
        assert d % heads == 0
        self.d, self.h, self.t = d, heads, t
        self.hd = d // heads
        self.causal, self.bias = causal, bias
        self.rope = Rotary(t, self.hd) if rope else None
        self.use_flash_fwd = use_flash_fwd
        self.param_names = (
            ("wq", "wk", "wv", "wo", "bq", "bk", "bv", "bo")
            if bias else ("wq", "wk", "wv", "wo")
        )

    def init(self, key) -> Params:
        ks = _split_key(key, 4)
        p = {n: _glorot(ks[i], (self.d, self.d), self.d, self.d)
             for i, n in enumerate(("wq", "wk", "wv", "wo"))}
        if self.bias:
            for n in ("bq", "bk", "bv", "bo"):
                p[n] = jnp.zeros((self.d,), jnp.float32)
        return p

    def _heads(self, x):
        b, t, _ = x.shape
        return x.reshape(b, t, self.h, self.hd).transpose(0, 2, 1, 3)

    def _unheads(self, x):
        b, h, t, hd = x.shape
        return x.transpose(0, 2, 1, 3).reshape(b, t, h * hd)

    def _proj(self, params, x, n):
        y = x @ params["w" + n]
        if self.bias:
            y = y + params["b" + n]
        return y

    def fwd(self, params, x):
        b, t, d = x.shape
        q = self._heads(self._proj(params, x, "q"))
        k = self._heads(self._proj(params, x, "k"))
        v = self._heads(self._proj(params, x, "v"))
        if self.rope is not None:
            q, k = self.rope.rotate(q), self.rope.rotate(k)
        scale = 1.0 / math.sqrt(self.hd)
        s = jnp.einsum("bhtd,bhsd->bhts", q, k) * scale
        if self.causal:
            mask = jnp.tril(jnp.ones((t, t), dtype=bool))
            s = jnp.where(mask, s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        o_heads = jnp.einsum("bhts,bhsd->bhtd", p, v)
        o = self._unheads(o_heads)
        y = self._proj(params, o, "o")
        return y, (q, k, v, p), (x, o)

    def bwd_p1(self, params, res1, res2, gy):
        q, k, v, p = res1
        x, o = res2
        scale = 1.0 / math.sqrt(self.hd)
        go = self._heads(gy @ params["wo"].T)                    # [b,h,t,hd]
        gp = jnp.einsum("bhtd,bhsd->bhts", go, v)
        gv = jnp.einsum("bhts,bhtd->bhsd", p, go)
        gs = p * (gp - jnp.sum(gp * p, axis=-1, keepdims=True))  # softmax bwd
        gq = jnp.einsum("bhts,bhsd->bhtd", gs, k) * scale
        gk = jnp.einsum("bhts,bhtd->bhsd", gs, q) * scale
        if self.rope is not None:
            gq, gk = self.rope.rotate(gq, inv=True), self.rope.rotate(gk, inv=True)
        gqf, gkf, gvf = map(self._unheads, (gq, gk, gv))
        gx = (gqf @ params["wq"].T + gkf @ params["wk"].T
              + gvf @ params["wv"].T)
        return gx, (gy, gqf, gkf, gvf)

    def bwd_p2(self, res2, inter):
        x, o = res2
        gy, gqf, gkf, gvf = inter
        d = self.d
        x2 = x.reshape(-1, d)
        grads = {
            "wq": x2.T @ gqf.reshape(-1, d),
            "wk": x2.T @ gkf.reshape(-1, d),
            "wv": x2.T @ gvf.reshape(-1, d),
            "wo": o.reshape(-1, d).T @ gy.reshape(-1, d),
        }
        if self.bias:
            grads["bq"] = jnp.sum(gqf.reshape(-1, d), axis=0)
            grads["bk"] = jnp.sum(gkf.reshape(-1, d), axis=0)
            grads["bv"] = jnp.sum(gvf.reshape(-1, d), axis=0)
            grads["bo"] = jnp.sum(gy.reshape(-1, d), axis=0)
        return grads


# ---------------------------------------------------------------------------
# MLPs


class SwiGLU(Module):
    """LLaMa/PaLM MLP: y = (silu(x@w1) * (x@w3)) @ w2, no bias.

    res1 = (a, b): pre-activations, released after p1.
    res2 = (x, h): inputs of w1/w3 and of w2.
    inter = (gy, ga, gb).
    """

    has_params = True
    param_names = ("w1", "w2", "w3")

    def __init__(self, d: int, hidden: int):
        self.d, self.hidden = d, hidden

    def init(self, key) -> Params:
        k1, k2, k3 = _split_key(key, 3)
        return {
            "w1": _glorot(k1, (self.d, self.hidden), self.d, self.hidden),
            "w2": _glorot(k2, (self.hidden, self.d), self.hidden, self.d),
            "w3": _glorot(k3, (self.d, self.hidden), self.d, self.hidden),
        }

    def fwd(self, params, x):
        a = x @ params["w1"]
        b = x @ params["w3"]
        h = _silu(a) * b
        return h @ params["w2"], (a, b), (x, h)

    def bwd_p1(self, params, res1, res2, gy):
        a, b = res1
        gh = gy @ params["w2"].T
        ga = gh * b * _dsilu(a)
        gb = gh * _silu(a)
        gx = ga @ params["w1"].T + gb @ params["w3"].T
        return gx, (gy, ga, gb)

    def bwd_p2(self, res2, inter):
        x, h = res2
        gy, ga, gb = inter
        x2 = x.reshape(-1, self.d)
        return {
            "w1": x2.T @ ga.reshape(-1, self.hidden),
            "w3": x2.T @ gb.reshape(-1, self.hidden),
            "w2": h.reshape(-1, self.hidden).T @ gy.reshape(-1, self.d),
        }


class MLP(Module):
    """BERT-style MLP: y = gelu(x@w1+b1)@w2+b2."""

    has_params = True
    param_names = ("w1", "b1", "w2", "b2")

    def __init__(self, d: int, hidden: int):
        self.d, self.hidden = d, hidden
        self._gelu = GELU()

    def init(self, key) -> Params:
        k1, k2 = _split_key(key, 2)
        return {
            "w1": _glorot(k1, (self.d, self.hidden), self.d, self.hidden),
            "b1": jnp.zeros((self.hidden,), jnp.float32),
            "w2": _glorot(k2, (self.hidden, self.d), self.hidden, self.d),
            "b2": jnp.zeros((self.d,), jnp.float32),
        }

    def fwd(self, params, x):
        a = x @ params["w1"] + params["b1"]
        h, _, _ = self._gelu.fwd({}, a)
        return h @ params["w2"] + params["b2"], (a,), (x, h)

    def bwd_p1(self, params, res1, res2, gy):
        (a,) = res1
        gh = gy @ params["w2"].T
        ga, _ = self._gelu.bwd_p1({}, (a,), (), gh)
        gx = ga @ params["w1"].T
        return gx, (gy, ga)

    def bwd_p2(self, res2, inter):
        x, h = res2
        gy, ga = inter
        x2 = x.reshape(-1, self.d)
        return {
            "w1": x2.T @ ga.reshape(-1, self.hidden),
            "b1": jnp.sum(ga.reshape(-1, self.hidden), axis=0),
            "w2": h.reshape(-1, self.hidden).T @ gy.reshape(-1, self.d),
            "b2": jnp.sum(gy.reshape(-1, self.d), axis=0),
        }


# ---------------------------------------------------------------------------
# Convolution / BatchNorm / pooling (ResNet substrate)


class Conv2d(Module):
    """2-D convolution, NCHW / OIHW, arbitrary stride + symmetric padding.

    backward-p1 (grad w.r.t. input) and backward-p2 (grad w.r.t. the
    kernel) are obtained via ``jax.linear_transpose`` of the conv in the
    respective argument — conv is bilinear, so the transpose *is* the
    manual adjoint (no forward recomputation), expressed without
    hand-unrolling the stride/padding index algebra.
    """

    has_params = True

    def __init__(self, c_in, c_out, ksize, stride=1, padding=0, bias=False):
        self.c_in, self.c_out, self.k = c_in, c_out, ksize
        self.stride, self.padding, self.bias = stride, padding, bias
        self.param_names = ("w", "b") if bias else ("w",)
        self._dn = lax.conv_dimension_numbers(
            (1, c_in, 8, 8), (c_out, c_in, ksize, ksize),
            ("NCHW", "OIHW", "NCHW"))

    def _conv(self, x, w):
        pad = [(self.padding, self.padding)] * 2
        return lax.conv_general_dilated(
            x, w, (self.stride, self.stride), pad, dimension_numbers=self._dn)

    def init(self, key) -> Params:
        fan_in = self.c_in * self.k * self.k
        p = {"w": _he(key, (self.c_out, self.c_in, self.k, self.k), fan_in)}
        if self.bias:
            p["b"] = jnp.zeros((self.c_out,), jnp.float32)
        return p

    def fwd(self, params, x):
        y = self._conv(x, params["w"])
        if self.bias:
            y = y + params["b"][None, :, None, None]
        return y, (), (x,)

    def bwd_p1(self, params, res1, res2, gy):
        (x,) = res2
        fx = jax.linear_transpose(lambda xx: self._conv(xx, params["w"]),
                                  jnp.zeros_like(x))
        (gx,) = fx(gy)
        return gx, (gy,)

    def bwd_p2(self, res2, inter):
        (x,) = res2
        (gy,) = inter
        wz = jnp.zeros((self.c_out, self.c_in, self.k, self.k), jnp.float32)
        fw = jax.linear_transpose(lambda ww: self._conv(x, ww), wz)
        (gw,) = fw(gy)
        grads = {"w": gw}
        if self.bias:
            grads["b"] = jnp.sum(gy, axis=(0, 2, 3))
        return grads


class BatchNorm2d(Module):
    """Training-mode batch norm over NCHW (batch statistics).

    The paper uses this as the canonical asymmetric case: "for 2D batch
    normalization, the backward-p2 operation is significantly simpler
    than the backward-p1 operation" (§4.1).  p2 is two reductions; p1
    carries the full correlated-statistics chain.
    """

    has_params = True
    param_names = ("g", "b")

    def __init__(self, c: int, eps: float = 1e-5):
        self.c, self.eps = c, eps

    def init(self, key) -> Params:
        return {"g": jnp.ones((self.c,), jnp.float32),
                "b": jnp.zeros((self.c,), jnp.float32)}

    def fwd(self, params, x):
        axes = (0, 2, 3)
        mu = jnp.mean(x, axis=axes, keepdims=True)
        var = jnp.mean((x - mu) ** 2, axis=axes, keepdims=True)
        rstd = 1.0 / jnp.sqrt(var + self.eps)
        xhat = (x - mu) * rstd
        y = xhat * params["g"][None, :, None, None] + params["b"][None, :, None, None]
        return y, (), (xhat, rstd)

    def bwd_p1(self, params, res1, res2, gy):
        xhat, rstd = res2
        axes = (0, 2, 3)
        n = xhat.shape[0] * xhat.shape[2] * xhat.shape[3]
        gh = gy * params["g"][None, :, None, None]
        m1 = jnp.sum(gh, axis=axes, keepdims=True) / n
        m2 = jnp.sum(gh * xhat, axis=axes, keepdims=True) / n
        return (gh - m1 - xhat * m2) * rstd, (gy,)

    def bwd_p2(self, res2, inter):
        xhat, _ = res2
        (gy,) = inter
        return {"g": jnp.sum(gy * xhat, axis=(0, 2, 3)),
                "b": jnp.sum(gy, axis=(0, 2, 3))}


class MaxPool2d(Module):
    """k×k/stride max pool; res1 carries the argmax mask (released at p1)."""

    def __init__(self, k: int, stride: int, padding: int = 0):
        self.k, self.stride, self.padding = k, stride, padding

    def _pool(self, x):
        pad = [(0, 0), (0, 0),
               (self.padding, self.padding), (self.padding, self.padding)]
        return lax.reduce_window(
            x, -jnp.inf, lax.max, (1, 1, self.k, self.k),
            (1, 1, self.stride, self.stride), pad)

    def fwd(self, params, x):
        y = self._pool(x)
        return y, (x, y), ()

    def bwd_p1(self, params, res1, res2, gy):
        x, y = res1
        # Per-primitive adjoint of reduce_window-max (select-and-scatter).
        # jax removed the public select_and_scatter_add wrapper; taking the
        # primitive's own vjp is the same local adjoint (this is not
        # whole-graph autodiff — the 2BP split above stays hand-scheduled).
        _, vjp = jax.vjp(self._pool, x)
        (gx,) = vjp(gy)
        return gx, ()


class GlobalAvgPool(Module):
    """NCHW -> NC mean over spatial dims (ResNet head).

    Numerically p1 needs nothing saved, but the flat AOT signature wants
    the input *shape* available at p1 trace time, so res1 carries x (a
    purely-functional residual, released at p1 like the paper's ReLU/SDPA
    class).
    """

    def fwd(self, params, x):
        return jnp.mean(x, axis=(2, 3)), (x,), ()

    def bwd_p1(self, params, res1, res2, gy):
        (x,) = res1
        n, c, h, w = x.shape
        gx = jnp.broadcast_to(gy[:, :, None, None] / (h * w), (n, c, h, w))
        return gx, ()


# ---------------------------------------------------------------------------
# Mamba-style selective SSM block substrate


class SSMScan(Module):
    """Diagonal selective state-space scan (S6-style core).

    Inputs are a tuple (u, delta, B, C) packed along the last axis by the
    surrounding Mamba block; this module owns the recurrence

        h_t = exp(Δ_t ⊙ A) ⊙ h_{t-1} + (Δ_t u_t) ⊗ B_t
        y_t = (h_t · C_t) + D ⊙ u_t

    with params A_log [di, s] (A = -exp(A_log)) and D [di].

    res2 holds *all* hidden states h — the paper's Mamba runs show the
    largest 2BP memory blow-up (2.67×) precisely because this class of
    layer must keep large state until the deferred p2.
    backward-p1 is a hand-derived reverse-time adjoint scan.
    """

    has_params = True
    param_names = ("a_log", "d")

    def __init__(self, di: int, s: int):
        self.di, self.s = di, s

    def init(self, key) -> Params:
        a = jnp.tile(jnp.arange(1, self.s + 1, dtype=jnp.float32)[None, :],
                     (self.di, 1))
        return {"a_log": jnp.log(a), "d": jnp.ones((self.di,), jnp.float32)}

    def fwd(self, params, udbc):
        u, delta, bmat, cmat = udbc
        a = -jnp.exp(params["a_log"])                       # [di, s]
        abar = jnp.exp(delta[..., None] * a)                # [b,t,di,s]
        x_in = (delta * u)[..., None] * bmat[:, :, None, :]  # [b,t,di,s]

        def step(h, inp):
            ab, xi = inp
            h = ab * h + xi
            return h, h

        b = u.shape[0]
        h0 = jnp.zeros((b, self.di, self.s), jnp.float32)
        # scan over time: move t to axis 0
        _, hs = lax.scan(step, h0,
                         (abar.transpose(1, 0, 2, 3), x_in.transpose(1, 0, 2, 3)))
        hs = hs.transpose(1, 0, 2, 3)                       # [b,t,di,s]
        y = jnp.einsum("btds,bts->btd", hs, cmat) + params["d"] * u
        return y, (), (u, delta, bmat, cmat, hs)

    def bwd_p1(self, params, res1, res2, gy):
        u, delta, bmat, cmat, hs = res2
        a = -jnp.exp(params["a_log"])
        abar = jnp.exp(delta[..., None] * a)                # [b,t,di,s]
        gh_local = gy[..., None] * cmat[:, :, None, :]      # dy/dh

        # reverse adjoint: Gh_t = gh_t + abar_{t+1} * Gh_{t+1}
        def rstep(carry, inp):
            gh_l, ab_next = inp
            g = gh_l + ab_next * carry
            return g, g

        b, t = u.shape[0], u.shape[1]
        ab_next = jnp.concatenate(
            [abar[:, 1:], jnp.zeros_like(abar[:, :1])], axis=1)
        _, ghs = lax.scan(
            rstep, jnp.zeros((b, self.di, self.s), jnp.float32),
            (gh_local.transpose(1, 0, 2, 3)[::-1],
             ab_next.transpose(1, 0, 2, 3)[::-1]))
        ghs = ghs[::-1].transpose(1, 0, 2, 3)               # [b,t,di,s] = dL/dh_t (total)

        h_prev = jnp.concatenate(
            [jnp.zeros_like(hs[:, :1]), hs[:, :-1]], axis=1)
        gabar = ghs * h_prev                                 # dL/dabar_t
        gx_in = ghs                                          # dL/dx_in_t
        gdelta = (jnp.sum(gabar * abar * a, axis=-1)
                  + jnp.sum(gx_in * bmat[:, :, None, :], axis=-1) * u)
        gu = (jnp.sum(gx_in * bmat[:, :, None, :], axis=-1) * delta
              + params["d"] * gy)
        gb = jnp.einsum("btds,btd->bts", gx_in, delta * u)
        gc = jnp.einsum("btds,btd->bts", hs, gy)
        # dL/dA -> dL/da_log chained here (p2 has no access to params by
        # contract); p1 already owns every operand, so this is free.
        ga = jnp.einsum("btds,btds->ds", gabar * abar, delta[..., None]
                        * jnp.ones_like(abar))
        ga_log = ga * a  # dA/da_log = -exp(a_log) = a
        gd = jnp.sum(gy * u, axis=(0, 1))
        return (gu, gdelta, gb, gc), (ga_log, gd)

    def bwd_p2(self, res2, inter):
        # The reductions over (b, t) were fused into p1 (they fall out of
        # the adjoint scan for free); p2 only re-labels the accumulators.
        ga_log, gd = inter
        return {"a_log": ga_log, "d": gd}


class DepthwiseConv1d(Module):
    """Causal depthwise conv over time (Mamba's local mixer).

    x: [b, t, d]; kernel w: [k, d].  Causal left padding of k-1.
    """

    has_params = True
    param_names = ("w",)

    def __init__(self, d: int, k: int = 4):
        self.d, self.k = d, k

    def init(self, key) -> Params:
        return {"w": jax.random.normal(key, (self.k, self.d), jnp.float32)
                * (1.0 / math.sqrt(self.k))}

    def _shift(self, x, i):
        # x shifted so that output_t depends on x_{t-(k-1-i)}
        off = self.k - 1 - i
        if off == 0:
            return x
        return jnp.pad(x, ((0, 0), (off, 0), (0, 0)))[:, : x.shape[1]]

    def fwd(self, params, x):
        y = sum(self._shift(x, i) * params["w"][i] for i in range(self.k))
        return y, (), (x,)

    def bwd_p1(self, params, res1, res2, gy):
        # adjoint of causal shift = anti-causal shift
        def unshift(g, i):
            off = self.k - 1 - i
            if off == 0:
                return g
            return jnp.pad(g, ((0, 0), (0, off), (0, 0)))[:, off:]

        gx = sum(unshift(gy, i) * params["w"][i] for i in range(self.k))
        return gx, (gy,)

    def bwd_p2(self, res2, inter):
        (x,) = res2
        (gy,) = inter
        gw = jnp.stack(
            [jnp.sum(self._shift(x, i) * gy, axis=(0, 1))
             for i in range(self.k)], axis=0)
        return {"w": gw}
