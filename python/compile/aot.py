"""AOT export: lower every per-stage function to HLO text + manifest.

This is the entire runtime contract between Python and rust.  For each
pipeline stage i of a preset we export six executables:

    stage{i}_init         (seed)                             -> params…
    stage{i}_fwd          (params…, x)                       -> (y, res1…, res2…)
    stage{i}_bwd_p1       (params…, res1…, res2…, gy)        -> (gx, inter…)
    stage{i}_bwd_p2       (res2…, inter…, acc…)              -> grads…   [+= acc]
    stage{i}_bwd_p2_concat(⟨res2…, inter…⟩ × M)              -> grads…   [Fig 2 / Table 3]
    stage{i}_opt          (params…, grads…, s0…, s1…, t)     -> (params…, s0…, s1…)

plus one ``loss`` executable (logits, labels) -> (loss, glogits) for the
last rank.  ``manifest.json`` records every flat argument/output spec,
per-class byte totals (params / res1 / res2 / inter / grads) that drive
the rust memory accountant (Fig 4/5) and the simulator's memory model
(Fig 7 OOM), and XLA cost-analysis flops that calibrate the simulator.

Interchange is HLO **text**: the image's xla_extension 0.5.1 rejects
jax≥0.5 serialized protos (64-bit instruction ids); the text parser
reassigns ids (see /opt/xla-example/README.md).

The ``bwd_p2_concat`` merge rule: a res2/inter leaf is *batch-carried*
iff its leading dim scales with the microbatch size (detected by
eval_shape at b and 2b — no heuristics); batch-carried leaves are
concatenated along axis 0, already-reduced leaves (e.g. the SSM's
accumulated dA) are summed.  Both reproduce exactly the sum of per-mb
p2 gradients.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import optim, presets
from .archs import BUILDERS

jax.config.update("jax_platform_name", "cpu")


# ---------------------------------------------------------------------------
# lowering helpers


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-safe format)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def _spec(x) -> dict:
    return {"shape": list(x.shape), "dtype": str(x.dtype),
            "bytes": int(abs_bytes(x))}


def abs_bytes(s) -> int:
    n = 1
    for d in s.shape:
        n *= d
    return n * jnp.dtype(s.dtype).itemsize


def export(fn, specs, path: str, want_cost: bool = True):
    """Lower fn at the given ShapeDtypeStruct specs; write HLO text.

    Returns (output_specs, flops_estimate_or_None).
    """
    lowered = jax.jit(fn, keep_unused=True).lower(*specs)
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    out_specs = jax.eval_shape(fn, *specs)
    flops = None
    if want_cost:
        try:
            cost = lowered.compile().cost_analysis()
            if isinstance(cost, list):
                cost = cost[0]
            flops = float(cost.get("flops", 0.0))
        except Exception:
            flops = None
    return out_specs, flops


# ---------------------------------------------------------------------------
# stage function builders (flat signatures)


def _leaves(tree) -> list:
    return jax.tree_util.tree_flatten(tree)[0]


def _treedef(tree):
    return jax.tree_util.tree_flatten(tree)[1]


class StageExport:
    """Builds the six flat-signature functions for one pipeline stage."""

    def __init__(self, stage, x_spec, opt_step, seed_base: int):
        self.stage = stage
        self.x_spec = x_spec
        self.opt_step = opt_step
        self.seed_base = seed_base

        params_shape = jax.eval_shape(
            lambda: stage.init(jax.random.PRNGKey(0)))
        self.p_leaves = _leaves(params_shape)
        self.p_tree = _treedef(params_shape)
        self.np = len(self.p_leaves)
        self.param_names = [
            "/".join(str(getattr(k, "key", k)) for k in kp)
            for kp, _ in jax.tree_util.tree_flatten_with_path(params_shape)[0]
        ]

        # shapes of fwd outputs at microbatch b (and 2b for batch detection)
        fwd_out = jax.eval_shape(stage.fwd, params_shape, x_spec)
        self.y_spec, r1_shape, r2_shape = fwd_out
        self.r1_leaves, self.r1_tree = jax.tree_util.tree_flatten(r1_shape)
        self.r2_leaves, self.r2_tree = jax.tree_util.tree_flatten(r2_shape)

        gy_spec = self.y_spec
        p1_out = jax.eval_shape(stage.bwd_p1, params_shape, r1_shape,
                                r2_shape, gy_spec)
        self.gx_spec, inter_shape = p1_out
        self.it_leaves, self.it_tree = jax.tree_util.tree_flatten(inter_shape)

        grads_shape = jax.eval_shape(stage.bwd_p2, r2_shape, inter_shape)
        self.g_leaves = _leaves(grads_shape)
        self.g_tree = _treedef(grads_shape)

        # batch-carried detection at 2b
        x2_spec = jax.ShapeDtypeStruct(
            (x_spec.shape[0] * 2,) + tuple(x_spec.shape[1:]), x_spec.dtype)
        fwd2 = jax.eval_shape(stage.fwd, params_shape, x2_spec)
        _, r1_2, r2_2 = fwd2
        gy2 = fwd2[0]
        _, it_2 = jax.eval_shape(stage.bwd_p1, params_shape, r1_2, r2_2, gy2)
        self.r2_batch = [
            a.shape[:1] != b.shape[:1]
            for a, b in zip(self.r2_leaves, _leaves(r2_2))]
        self.it_batch = [
            a.shape[:1] != b.shape[:1]
            for a, b in zip(self.it_leaves, _leaves(it_2))]

    # -- flat functions ------------------------------------------------------

    def init_fn(self):
        seed_base = self.seed_base
        stage = self.stage

        def f(seed):
            key = jax.random.fold_in(jax.random.PRNGKey(seed_base), seed)
            return tuple(_leaves(stage.init(key)))

        return f, (jax.ShapeDtypeStruct((), jnp.int32),)

    def fwd_fn(self):
        stage, p_tree, np_ = self.stage, self.p_tree, self.np

        def f(*args):
            ps = jax.tree_util.tree_unflatten(p_tree, args[:np_])
            x = args[np_]
            y, r1, r2 = stage.fwd(ps, x)
            return (y, *_leaves(r1), *_leaves(r2))

        return f, (*self.p_leaves, self.x_spec)

    def bwd_p1_fn(self):
        stage = self.stage
        p_tree, r1_tree, r2_tree = self.p_tree, self.r1_tree, self.r2_tree
        np_, n1, n2 = self.np, len(self.r1_leaves), len(self.r2_leaves)

        def f(*args):
            ps = jax.tree_util.tree_unflatten(p_tree, args[:np_])
            r1 = jax.tree_util.tree_unflatten(
                r1_tree, args[np_:np_ + n1])
            r2 = jax.tree_util.tree_unflatten(
                r2_tree, args[np_ + n1:np_ + n1 + n2])
            gy = args[np_ + n1 + n2]
            gx, inter = stage.bwd_p1(ps, r1, r2, gy)
            return (gx, *_leaves(inter))

        return f, (*self.p_leaves, *self.r1_leaves, *self.r2_leaves,
                   self.y_spec)

    def bwd_p2_fn(self):
        stage = self.stage
        r2_tree, it_tree = self.r2_tree, self.it_tree
        n2, ni = len(self.r2_leaves), len(self.it_leaves)

        def f(*args):
            r2 = jax.tree_util.tree_unflatten(r2_tree, args[:n2])
            it = jax.tree_util.tree_unflatten(it_tree, args[n2:n2 + ni])
            acc = args[n2 + ni:]
            grads = _leaves(stage.bwd_p2(r2, it))
            return tuple(g + a for g, a in zip(grads, acc))

        return f, (*self.r2_leaves, *self.it_leaves, *self.g_leaves)

    def bwd_p2_concat_fn(self, m: int):
        stage = self.stage
        r2_tree, it_tree = self.r2_tree, self.it_tree
        n2, ni = len(self.r2_leaves), len(self.it_leaves)
        r2_batch, it_batch = self.r2_batch, self.it_batch
        per = n2 + ni

        def f(*args):
            merged = []
            for j in range(per):
                leaves = [args[k * per + j] for k in range(m)]
                batch = r2_batch[j] if j < n2 else it_batch[j - n2]
                merged.append(jnp.concatenate(leaves, axis=0) if batch
                              else sum(leaves))
            r2 = jax.tree_util.tree_unflatten(r2_tree, merged[:n2])
            it = jax.tree_util.tree_unflatten(it_tree, merged[n2:])
            return tuple(_leaves(stage.bwd_p2(r2, it)))

        specs = (*self.r2_leaves, *self.it_leaves) * m
        return f, specs

    def opt_fn(self):
        opt_step, p_tree, g_tree = self.opt_step, self.p_tree, self.g_tree
        np_ = self.np

        def f(*args):
            ps = jax.tree_util.tree_unflatten(p_tree, args[:np_])
            gs = jax.tree_util.tree_unflatten(g_tree, args[np_:2 * np_])
            s0 = jax.tree_util.tree_unflatten(p_tree, args[2 * np_:3 * np_])
            s1 = jax.tree_util.tree_unflatten(p_tree, args[3 * np_:4 * np_])
            t = args[4 * np_]
            new_p, new_s0, new_s1 = opt_step(ps, gs, s0, s1, t)
            return (*_leaves(new_p), *_leaves(new_s0), *_leaves(new_s1))

        t_spec = jax.ShapeDtypeStruct((), jnp.float32)
        return f, (*self.p_leaves, *self.g_leaves, *self.p_leaves,
                   *self.p_leaves, t_spec)


# ---------------------------------------------------------------------------
# driver


def export_preset(name: str, out_root: str, want_cost: bool = True,
                  concat_m: int | None = None, verbose: bool = True) -> dict:
    cfg = presets.get(name)
    pipe = BUILDERS[cfg["arch"]](cfg)
    m = concat_m or cfg["n_microbatches"]
    opt_step = optim.OPTIMIZERS[cfg["optimizer"]](lr=cfg["lr"])

    out_dir = os.path.join(out_root, name)
    os.makedirs(out_dir, exist_ok=True)

    manifest: Dict[str, Any] = {
        "preset": name, "arch": cfg["arch"], "stages": pipe.n_stages,
        "microbatch": cfg["microbatch"],
        "samples_per_microbatch": pipe.samples_per_microbatch,
        "n_microbatches_concat": m,
        "optimizer": cfg["optimizer"], "lr": cfg["lr"],
        "cfg": {k: v for k, v in cfg.items() if k != "preset"},
        "stage": [],
    }

    x_spec = pipe.input_spec
    for i, stage in enumerate(pipe.stages):
        se = StageExport(stage, x_spec, opt_step, seed_base=1000 + i)
        arts = {}

        def _exp(tag, fn_specs, fname):
            fn, specs = fn_specs
            path = os.path.join(out_dir, fname)
            _, flops = export(fn, specs, path, want_cost)
            arts[tag] = {"file": fname, "flops": flops}
            if verbose:
                kb = os.path.getsize(path) // 1024
                print(f"  [{name}] stage{i} {tag}: {fname} ({kb} KiB, "
                      f"flops={flops})", flush=True)

        _exp("init", se.init_fn(), f"stage{i}_init.hlo.txt")
        _exp("fwd", se.fwd_fn(), f"stage{i}_fwd.hlo.txt")
        _exp("bwd_p1", se.bwd_p1_fn(), f"stage{i}_bwd_p1.hlo.txt")
        _exp("bwd_p2", se.bwd_p2_fn(), f"stage{i}_bwd_p2.hlo.txt")
        _exp("bwd_p2_concat", se.bwd_p2_concat_fn(m),
             f"stage{i}_bwd_p2_concat.hlo.txt")
        _exp("opt", se.opt_fn(), f"stage{i}_opt.hlo.txt")

        entry = {
            "index": i,
            "params": [dict(name=n, **_spec(s))
                       for n, s in zip(se.param_names, se.p_leaves)],
            "input": _spec(x_spec),
            "output": _spec(se.y_spec),
            "gx": _spec(se.gx_spec),
            "res1": [_spec(s) for s in se.r1_leaves],
            "res2": [_spec(s) for s in se.r2_leaves],
            "inter": [_spec(s) for s in se.it_leaves],
            "res2_batch": se.r2_batch,
            "inter_batch": se.it_batch,
            "grads": [_spec(s) for s in se.g_leaves],
            "bytes": {
                "params": sum(abs_bytes(s) for s in se.p_leaves),
                "res1": sum(abs_bytes(s) for s in se.r1_leaves),
                "res2": sum(abs_bytes(s) for s in se.r2_leaves),
                "inter": sum(abs_bytes(s) for s in se.it_leaves),
                "grads": sum(abs_bytes(s) for s in se.g_leaves),
                "activation": abs_bytes(se.y_spec),
            },
            "artifacts": arts,
        }
        manifest["stage"].append(entry)
        x_spec = se.y_spec  # next stage's input

    # loss head
    loss_path = os.path.join(out_dir, "loss.hlo.txt")
    logits_spec = x_spec
    label_spec = pipe.label_spec
    _, loss_flops = export(lambda lo, la: pipe.loss_grad(lo, la),
                           (logits_spec, label_spec), loss_path, want_cost)
    manifest["loss"] = {
        "file": "loss.hlo.txt", "flops": loss_flops,
        "logits": _spec(logits_spec), "labels": _spec(label_spec),
    }

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    if verbose:
        total_params = sum(p["bytes"] for st in manifest["stage"]
                           for p in st["params"]) // 4
        print(f"[{name}] exported {pipe.n_stages} stages, "
              f"{total_params:,} params -> {out_dir}", flush=True)
    return manifest


DEFAULT_PRESETS = [
    "transformer-tiny", "bert-tiny", "mamba-tiny", "resnet-tiny",
    "transformer-s", "bert-s", "mamba-s", "resnet-s",
    "bert-scale-fixed", "transformer-m",
]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--preset", action="append", default=None,
                    help="preset name (repeatable); default: standard set")
    ap.add_argument("--no-cost", action="store_true",
                    help="skip XLA cost analysis (faster export)")
    args = ap.parse_args()
    names = args.preset or DEFAULT_PRESETS
    for n in names:
        export_preset(n, args.out, want_cost=not args.no_cost)


if __name__ == "__main__":
    main()
