"""Optimizers as pure jax functions, AOT-exported per pipeline stage.

The paper (§4): "the optimizer calculations are taken into account
during the throughput measurements" — so each stage's optimizer step is
a first-class compiled artifact executed by the rust coordinator after
the final backward-p2 of a training step.

All optimizers share one functional signature so the rust side is
uniform:

    step(params, grads, slot0, slot1, t) -> (params', slot0', slot1')

where unused slots are passed through (SGD ignores both, momentum-SGD
uses slot0, Adam/AdamW use slot0=m, slot1=v).  ``t`` is the 1-based step
counter as a float32 scalar (for Adam bias correction).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def _treemap(f, *trees):
    return jax.tree_util.tree_map(f, *trees)


def sgd(lr: float = 0.1, momentum: float = 0.0):
    """SGD (paper: ResNet152's optimizer), optional heavy-ball momentum."""

    def step(params, grads, slot0, slot1, t):
        if momentum == 0.0:
            new_p = _treemap(lambda p, g: p - lr * g, params, grads)
            return new_p, slot0, slot1
        new_m = _treemap(lambda m, g: momentum * m + g, slot0, grads)
        new_p = _treemap(lambda p, m: p - lr * m, params, new_m)
        return new_p, new_m, slot1

    return step


def adam(lr: float = 1e-4, b1: float = 0.9, b2: float = 0.999,
         eps: float = 1e-8, weight_decay: float = 0.0):
    """Adam (paper: LLaMa-7b, BERT-Large). L2-style coupled decay."""

    def step(params, grads, m, v, t):
        if weight_decay != 0.0:
            grads = _treemap(lambda g, p: g + weight_decay * p, grads, params)
        new_m = _treemap(lambda mm, g: b1 * mm + (1 - b1) * g, m, grads)
        new_v = _treemap(lambda vv, g: b2 * vv + (1 - b2) * g * g, v, grads)
        c1 = 1.0 - b1 ** t
        c2 = 1.0 - b2 ** t
        new_p = _treemap(
            lambda p, mm, vv: p - lr * (mm / c1) / (jnp.sqrt(vv / c2) + eps),
            params, new_m, new_v)
        return new_p, new_m, new_v

    return step


def adamw(lr: float = 1e-4, b1: float = 0.9, b2: float = 0.999,
          eps: float = 1e-8, weight_decay: float = 0.01):
    """AdamW (paper: Mamba-1.4b) — decoupled weight decay."""
    inner = adam(lr, b1, b2, eps, weight_decay=0.0)

    def step(params, grads, m, v, t):
        new_p, new_m, new_v = inner(params, grads, m, v, t)
        new_p = _treemap(lambda p0, p: p - lr * weight_decay * p0,
                         params, new_p)
        return new_p, new_m, new_v

    return step


OPTIMIZERS = {"sgd": sgd, "adam": adam, "adamw": adamw}
