"""Build-time Python: L2 jax model + L1 Pallas kernels + AOT export.

Never imported at runtime — `make artifacts` runs once, then the rust
coordinator is self-contained.
"""
