"""L2 model architectures, stage-partitioned for pipeline parallelism.

Each arch module exposes ``build(cfg) -> Pipeline`` where a Pipeline is a
list of Stage objects (see .common).  The four architectures mirror the
paper's benchmark set (§3.2 / Table 2):

  transformer — LLaMa/PaLM-like decoder (RMSNorm, RoPE, SwiGLU, no bias)
  bert        — BERT-Large-like bidirectional encoder (LayerNorm, GELU)
  mamba       — Mamba-like selective-SSM stack
  resnet      — ResNet-152-like bottleneck CNN (the non-uniform graph)
"""

from . import common  # noqa: F401
from . import transformer, bert, mamba, resnet  # noqa: F401

BUILDERS = {
    "transformer": transformer.build,
    "bert": bert.build,
    "mamba": mamba.build,
    "resnet": resnet.build,
}
