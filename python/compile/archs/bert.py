"""BERT-Large-like bidirectional encoder (post-LN, GELU, biased linears).

Post-norm residual blocks (original BERT):

    x = LN(x + Attn(x))
    x = LN(x + MLP(x))

Attention is bidirectional (no causal mask, no RoPE — learned absolute
position embeddings live on stage 0).  Used for the paper's BERT-Large
throughput run (Fig 3/4) and both scaling studies (Figs 6, 7).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .. import layers as L
from .common import Pipeline, Stage, lm_cross_entropy, split_blocks


class PosEmbedding(L.Module):
    """Learned absolute position embedding added to token embeddings."""

    has_params = True
    param_names = ("w",)

    def __init__(self, t: int, d: int):
        self.t, self.d = t, d

    def init(self, key):
        return {"w": jax.random.normal(key, (self.t, self.d), jnp.float32) * 0.02}

    def fwd(self, params, x):
        return x + params["w"][None, :, :], (), ()

    def bwd_p1(self, params, res1, res2, gy):
        return gy, (gy,)

    def bwd_p2(self, res2, inter):
        (gy,) = inter
        return {"w": jnp.sum(gy, axis=0)}


class BertBlock(L.Module):
    """Post-norm encoder block with hand-written split backward."""

    has_params = True

    def __init__(self, d: int, heads: int, t: int, hidden: int):
        self.attn = L.Attention(d, heads, t, causal=False, rope=False,
                                bias=True)
        self.n1 = L.LayerNorm(d)
        self.mlp = L.MLP(d, hidden)
        self.n2 = L.LayerNorm(d)
        self._children = (("attn", self.attn), ("n1", self.n1),
                          ("mlp", self.mlp), ("n2", self.n2))

    def init(self, key):
        ks = jax.random.split(key, 4)
        return {n: m.init(k) for (n, m), k in zip(self._children, ks)}

    def fwd(self, params, x):
        a, r1_at, r2_at = self.attn.fwd(params["attn"], x)
        h, r1_n1, r2_n1 = self.n1.fwd(params["n1"], x + a)
        m, r1_ml, r2_ml = self.mlp.fwd(params["mlp"], h)
        y, r1_n2, r2_n2 = self.n2.fwd(params["n2"], h + m)
        return y, (r1_at, r1_n1, r1_ml, r1_n2), (r2_at, r2_n1, r2_ml, r2_n2)

    def bwd_p1(self, params, res1, res2, gy):
        r1_at, r1_n1, r1_ml, r1_n2 = res1
        r2_at, r2_n1, r2_ml, r2_n2 = res2
        gs2, i_n2 = self.n2.bwd_p1(params["n2"], r1_n2, r2_n2, gy)
        gm_in, i_ml = self.mlp.bwd_p1(params["mlp"], r1_ml, r2_ml, gs2)
        gh = gs2 + gm_in
        gs1, i_n1 = self.n1.bwd_p1(params["n1"], r1_n1, r2_n1, gh)
        ga_in, i_at = self.attn.bwd_p1(params["attn"], r1_at, r2_at, gs1)
        gx = gs1 + ga_in
        return gx, (i_at, i_n1, i_ml, i_n2)

    def bwd_p2(self, res2, inter):
        r2_at, r2_n1, r2_ml, r2_n2 = res2
        i_at, i_n1, i_ml, i_n2 = inter
        return {
            "attn": self.attn.bwd_p2(r2_at, i_at),
            "n1": self.n1.bwd_p2(r2_n1, i_n1),
            "mlp": self.mlp.bwd_p2(r2_ml, i_ml),
            "n2": self.n2.bwd_p2(r2_n2, i_n2),
        }


def build(cfg: dict) -> Pipeline:
    """cfg keys: dim, heads, blocks, seq, vocab, hidden(opt), microbatch, stages."""
    d, heads, t = cfg["dim"], cfg["heads"], cfg["seq"]
    vocab, n_blocks = cfg["vocab"], cfg["blocks"]
    hidden = cfg.get("hidden", d * 4)
    n_stages, b = cfg["stages"], cfg["microbatch"]

    per_stage = split_blocks(n_blocks, n_stages)
    stages = []
    bi = 0
    for s in range(n_stages):
        mods = []
        if s == 0:
            mods.append(("embed", L.Embedding(vocab, d)))
            mods.append(("pos", PosEmbedding(t, d)))
        for _ in range(per_stage[s]):
            mods.append((f"block{bi}", BertBlock(d, heads, t, hidden)))
            bi += 1
        if s == n_stages - 1:
            mods.append(("head", L.Linear(d, vocab, bias=True)))
        stages.append(Stage(mods))

    return Pipeline(
        name="bert",
        stages=stages,
        loss_grad=lm_cross_entropy,
        input_spec=jax.ShapeDtypeStruct((b, t), jnp.int32),
        label_spec=jax.ShapeDtypeStruct((b, t), jnp.int32),
        samples_per_microbatch=b,
    )
