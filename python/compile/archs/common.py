"""Stage / pipeline abstractions shared by all architectures.

A ``Stage`` is an ordered list of named modules executed sequentially —
the unit that lives on one accelerator.  Its three split-backward
functions are what ``aot.py`` lowers to per-stage HLO artifacts:

    fwd(params, x)                 -> (y, res1, res2)
    bwd_p1(params, res1, res2, gy) -> (gx, inter)
    bwd_p2(res2, inter)            -> grads

Residuals/intermediates are pytrees (tuples keyed by module position);
``aot.py`` flattens them into the flat HLO signature and records the
layout in the manifest.
"""

from __future__ import annotations

from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from .. import layers as L


class Stage:
    """One pipeline stage: a named sequence of modules on one device."""

    def __init__(self, modules: Sequence[Tuple[str, L.Module]]):
        names = [n for n, _ in modules]
        assert len(names) == len(set(names)), f"duplicate module names: {names}"
        self.modules: List[Tuple[str, L.Module]] = list(modules)

    # -- params ------------------------------------------------------------
    def init(self, key) -> Dict[str, dict]:
        keys = jax.random.split(key, max(len(self.modules), 2))
        out = {}
        for (name, mod), k in zip(self.modules, keys):
            if mod.has_params:
                out[name] = mod.init(k)
        return out

    def param_count(self, params) -> int:
        return sum(int(x.size) for x in jax.tree_util.tree_leaves(params))

    # -- split backward ------------------------------------------------------
    def fwd(self, params, x):
        res1, res2 = [], []
        for name, mod in self.modules:
            x, r1, r2 = mod.fwd(params.get(name, {}), x)
            res1.append(r1)
            res2.append(r2)
        return x, tuple(res1), tuple(res2)

    def bwd_p1(self, params, res1, res2, gy):
        inters: List = [None] * len(self.modules)
        for i in range(len(self.modules) - 1, -1, -1):
            name, mod = self.modules[i]
            if mod.has_params:
                gy, inter = mod.bwd_p1(params.get(name, {}), res1[i], res2[i], gy)
            else:
                gy, inter = mod.bwd_p1({}, res1[i], res2[i], gy)
            inters[i] = inter
        return gy, tuple(inters)

    def bwd_p2(self, res2, inter):
        grads = {}
        for i, (name, mod) in enumerate(self.modules):
            if mod.has_params:
                grads[name] = mod.bwd_p2(res2[i], inter[i])
        return grads

    # -- fused oracle (single-device reference; == autograd baseline) -------
    def apply(self, params, x):
        for name, mod in self.modules:
            x, _, _ = mod.fwd(params.get(name, {}), x)
        return x


class Pipeline:
    """A stage-partitioned model plus its loss head."""

    def __init__(self, name: str, stages: List[Stage],
                 loss_grad: Callable, input_spec, label_spec,
                 samples_per_microbatch: int):
        self.name = name
        self.stages = stages
        self.loss_grad = loss_grad          # (logits, labels) -> (loss, glogits)
        self.input_spec = input_spec        # ShapeDtypeStruct of stage-0 input
        self.label_spec = label_spec
        self.samples_per_microbatch = samples_per_microbatch

    @property
    def n_stages(self) -> int:
        return len(self.stages)


# ---------------------------------------------------------------------------
# loss heads


def lm_cross_entropy(logits, labels):
    """Token-level CE for LM-style heads. logits [b,t,v], labels [b,t] int32.

    Returns (mean loss, d loss / d logits) fused in one executable — this
    seeds backward-p1 on the last pipeline rank.
    """
    v = logits.shape[-1]
    flat = logits.reshape(-1, v)
    lab = labels.reshape(-1)
    m = jnp.max(flat, axis=-1, keepdims=True)
    lse = m[:, 0] + jnp.log(jnp.sum(jnp.exp(flat - m), axis=-1))
    picked = jnp.take_along_axis(flat, lab[:, None], axis=-1)[:, 0]
    n = flat.shape[0]
    loss = jnp.sum(lse - picked) / n
    p = jnp.exp(flat - m) / jnp.sum(jnp.exp(flat - m), axis=-1, keepdims=True)
    g = (p - jax.nn.one_hot(lab, v, dtype=logits.dtype)) / n
    return loss, g.reshape(logits.shape)


def class_cross_entropy(logits, labels):
    """Image-classification CE. logits [b,c], labels [b] int32."""
    return lm_cross_entropy(logits[:, None, :], labels[:, None])[0], \
        lm_cross_entropy(logits[:, None, :], labels[:, None])[1][:, 0, :]


def split_blocks(n_blocks: int, n_stages: int) -> List[int]:
    """Even block split (paper: "distributed the number of blocks equally")."""
    base = n_blocks // n_stages
    rem = n_blocks % n_stages
    return [base + (1 if i < rem else 0) for i in range(n_stages)]
