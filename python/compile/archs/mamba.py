"""Mamba-like selective-SSM stack (the paper's Mamba-1.4b stand-in).

Block structure (simplified S6, faithful to the memory profile the paper
measures — the SSM scan must stash *all* hidden states [b,t,di,s] until
backward-p2, which is why Mamba shows the paper's largest 2BP memory
blow-up, 2.67× under 1F1B-2):

    x ─ RMSNorm ─ in_proj ──┬─ u ── causal dwconv ── silu ── SSM ──┐
                            └─ gate ──────────────── silu ─────── * ── out_proj ─ (+x)

with input-dependent Δ (softplus, low-rank), B, C projections feeding
the diagonal selective scan (layers.SSMScan).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .. import layers as L
from ..layers import _dsilu, _silu
from .common import Pipeline, Stage, lm_cross_entropy, split_blocks


class MambaBlock(L.Module):
    """Pre-norm Mamba block with hand-written split backward."""

    has_params = True

    def __init__(self, d: int, expand: int = 2, state: int = 16,
                 conv_k: int = 4, t: int = 0, use_kernels: bool = True):
        self.d = d
        self.di = d * expand
        self.s = state
        self.dt_rank = max(d // 16, 1)
        self.norm = L.RMSNorm(d, use_kernel=use_kernels)
        self.in_proj = L.Linear(d, 2 * self.di, bias=False)
        self.conv = L.DepthwiseConv1d(self.di, conv_k)
        self.x_proj = L.Linear(self.di, self.dt_rank + 2 * self.s, bias=False)
        self.dt_proj = L.Linear(self.dt_rank, self.di, bias=True)
        self.ssm = L.SSMScan(self.di, self.s)
        self.out_proj = L.Linear(self.di, d, bias=False)
        self._children = (
            ("norm", self.norm), ("in_proj", self.in_proj),
            ("conv", self.conv), ("x_proj", self.x_proj),
            ("dt_proj", self.dt_proj), ("ssm", self.ssm),
            ("out_proj", self.out_proj))

    def init(self, key):
        ks = jax.random.split(key, len(self._children))
        return {n: m.init(k) for (n, m), k in zip(self._children, ks)}

    def fwd(self, params, x):
        r1, r2 = {}, {}
        xn, r1["norm"], r2["norm"] = self.norm.fwd(params["norm"], x)
        ug, r1["in_proj"], r2["in_proj"] = self.in_proj.fwd(params["in_proj"], xn)
        u, gate = jnp.split(ug, 2, axis=-1)
        uc, r1["conv"], r2["conv"] = self.conv.fwd(params["conv"], u)
        us = _silu(uc)
        dbc, r1["x_proj"], r2["x_proj"] = self.x_proj.fwd(params["x_proj"], us)
        dt_lr = dbc[..., : self.dt_rank]
        bmat = dbc[..., self.dt_rank: self.dt_rank + self.s]
        cmat = dbc[..., self.dt_rank + self.s:]
        dt_pre, r1["dt_proj"], r2["dt_proj"] = self.dt_proj.fwd(
            params["dt_proj"], dt_lr)
        delta = jax.nn.softplus(dt_pre)
        y_ssm, r1["ssm"], r2["ssm"] = self.ssm.fwd(
            params["ssm"], (us, delta, bmat, cmat))
        gs = _silu(gate)
        yg = y_ssm * gs
        y, r1["out_proj"], r2["out_proj"] = self.out_proj.fwd(
            params["out_proj"], yg)
        # functional pre-activations (released after p1):
        r1["_act"] = (uc, gate, dt_pre, y_ssm)
        order = [n for n, _ in self._children] + ["_act"]
        return x + y, tuple(r1[n] for n in order), \
            tuple(r2.get(n, ()) for n in order)

    def _unpack(self, res):
        order = [n for n, _ in self._children] + ["_act"]
        return dict(zip(order, res))

    def bwd_p1(self, params, res1, res2, gy):
        r1, r2 = self._unpack(res1), self._unpack(res2)
        uc, gate, dt_pre, y_ssm = r1["_act"]
        inter = {}
        gyg, inter["out_proj"] = self.out_proj.bwd_p1(
            params["out_proj"], r1["out_proj"], r2["out_proj"], gy)
        gs = _silu(gate)
        gy_ssm = gyg * gs
        ggate = gyg * y_ssm * _dsilu(gate)
        (gus_ssm, gdelta, gb, gc), inter["ssm"] = self.ssm.bwd_p1(
            params["ssm"], r1["ssm"], r2["ssm"], gy_ssm)
        gdt_pre = gdelta * jax.nn.sigmoid(dt_pre)  # softplus'
        gdt_lr, inter["dt_proj"] = self.dt_proj.bwd_p1(
            params["dt_proj"], r1["dt_proj"], r2["dt_proj"], gdt_pre)
        gdbc = jnp.concatenate([gdt_lr, gb, gc], axis=-1)
        gus_proj, inter["x_proj"] = self.x_proj.bwd_p1(
            params["x_proj"], r1["x_proj"], r2["x_proj"], gdbc)
        gus = gus_ssm + gus_proj
        guc = gus * _dsilu(uc)
        gu, inter["conv"] = self.conv.bwd_p1(
            params["conv"], r1["conv"], r2["conv"], guc)
        gug = jnp.concatenate([gu, ggate], axis=-1)
        gxn, inter["in_proj"] = self.in_proj.bwd_p1(
            params["in_proj"], r1["in_proj"], r2["in_proj"], gug)
        gx_n, inter["norm"] = self.norm.bwd_p1(
            params["norm"], r1["norm"], r2["norm"], gxn)
        order = [n for n, _ in self._children]
        return gy + gx_n, tuple(inter[n] for n in order)

    def bwd_p2(self, res2, inter):
        r2 = self._unpack(res2)
        order = [n for n, _ in self._children]
        it = dict(zip(order, inter))
        return {n: m.bwd_p2(r2[n], it[n]) for n, m in self._children}


def build(cfg: dict) -> Pipeline:
    """cfg keys: dim, blocks, seq, vocab, expand(opt), state(opt),
    microbatch, stages."""
    d, n_blocks, t = cfg["dim"], cfg["blocks"], cfg["seq"]
    vocab = cfg["vocab"]
    expand = cfg.get("expand", 2)
    state = cfg.get("state", 16)
    n_stages, b = cfg["stages"], cfg["microbatch"]
    use_kernels = cfg.get("use_kernels", True)

    per_stage = split_blocks(n_blocks, n_stages)
    stages = []
    bi = 0
    for s in range(n_stages):
        mods = []
        if s == 0:
            mods.append(("embed", L.Embedding(vocab, d)))
        for _ in range(per_stage[s]):
            mods.append((f"block{bi}", MambaBlock(d, expand, state, t=t, use_kernels=use_kernels)))
            bi += 1
        if s == n_stages - 1:
            mods.append(("norm_f", L.RMSNorm(d, use_kernel=use_kernels)))
            mods.append(("head", L.Linear(d, vocab, bias=False)))
        stages.append(Stage(mods))

    return Pipeline(
        name="mamba",
        stages=stages,
        loss_grad=lm_cross_entropy,
        input_spec=jax.ShapeDtypeStruct((b, t), jnp.int32),
        label_spec=jax.ShapeDtypeStruct((b, t), jnp.int32),
        samples_per_microbatch=b,
    )
