"""LLaMa/PaLM-like decoder transformer (the paper's Transformer-7b).

Per §3.2: rotary embedding, SwiGLU MLP, RMSNorm, no linear bias.
Pre-norm residual blocks:

    x = x + Attn(RMSNorm(x))
    x = x + SwiGLU(RMSNorm(x))

Stage 0 additionally holds the token embedding; the last stage holds the
final RMSNorm and the (untied) LM head.  Blocks are split evenly across
stages (paper: "all models ... distributed the number of blocks equally
amongst the 4 GPUs (excluding the embedding blocks and prediction heads
where appropriate)").
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .. import layers as L
from .common import Pipeline, Stage, lm_cross_entropy, split_blocks


class TransformerBlock(L.Module):
    """Pre-norm decoder block with hand-written split backward."""

    has_params = True

    def __init__(self, d: int, heads: int, t: int, hidden: int,
                 use_flash_fwd: bool = False, use_kernels: bool = True):
        self.n1 = L.RMSNorm(d, use_kernel=use_kernels)
        self.attn = L.Attention(d, heads, t, causal=True, rope=True,
                                bias=False, use_flash_fwd=use_flash_fwd)
        self.n2 = L.RMSNorm(d, use_kernel=use_kernels)
        self.mlp = L.SwiGLU(d, hidden)
        self._children = (("n1", self.n1), ("attn", self.attn),
                          ("n2", self.n2), ("mlp", self.mlp))

    def init(self, key):
        ks = jax.random.split(key, 4)
        return {n: m.init(k) for (n, m), k in zip(self._children, ks)}

    def fwd(self, params, x):
        a_in, r1_n1, r2_n1 = self.n1.fwd(params["n1"], x)
        a, r1_at, r2_at = self.attn.fwd(params["attn"], a_in)
        x1 = x + a
        m_in, r1_n2, r2_n2 = self.n2.fwd(params["n2"], x1)
        m, r1_ml, r2_ml = self.mlp.fwd(params["mlp"], m_in)
        y = x1 + m
        return y, (r1_n1, r1_at, r1_n2, r1_ml), (r2_n1, r2_at, r2_n2, r2_ml)

    def bwd_p1(self, params, res1, res2, gy):
        r1_n1, r1_at, r1_n2, r1_ml = res1
        r2_n1, r2_at, r2_n2, r2_ml = res2
        # y = x1 + mlp(n2(x1))
        gm = gy
        gm_in, i_ml = self.mlp.bwd_p1(params["mlp"], r1_ml, r2_ml, gm)
        gx1_n, i_n2 = self.n2.bwd_p1(params["n2"], r1_n2, r2_n2, gm_in)
        gx1 = gy + gx1_n
        # x1 = x + attn(n1(x))
        ga_in, i_at = self.attn.bwd_p1(params["attn"], r1_at, r2_at, gx1)
        gx_n, i_n1 = self.n1.bwd_p1(params["n1"], r1_n1, r2_n1, ga_in)
        gx = gx1 + gx_n
        return gx, (i_n1, i_at, i_n2, i_ml)

    def bwd_p2(self, res2, inter):
        r2_n1, r2_at, r2_n2, r2_ml = res2
        i_n1, i_at, i_n2, i_ml = inter
        return {
            "n1": self.n1.bwd_p2(r2_n1, i_n1),
            "attn": self.attn.bwd_p2(r2_at, i_at),
            "n2": self.n2.bwd_p2(r2_n2, i_n2),
            "mlp": self.mlp.bwd_p2(r2_ml, i_ml),
        }


def build(cfg: dict) -> Pipeline:
    """cfg keys: dim, heads, blocks, seq, vocab, hidden (opt), microbatch,
    stages, use_flash_fwd (opt)."""
    d = cfg["dim"]
    heads = cfg["heads"]
    n_blocks = cfg["blocks"]
    t = cfg["seq"]
    vocab = cfg["vocab"]
    hidden = cfg.get("hidden", d * 8 // 3)
    n_stages = cfg["stages"]
    b = cfg["microbatch"]
    flash = cfg.get("use_flash_fwd", False)
    use_kernels = cfg.get("use_kernels", True)

    per_stage = split_blocks(n_blocks, n_stages)
    stages = []
    bi = 0
    for s in range(n_stages):
        mods = []
        if s == 0:
            mods.append(("embed", L.Embedding(vocab, d)))
        for _ in range(per_stage[s]):
            mods.append((f"block{bi}",
                         TransformerBlock(d, heads, t, hidden, flash, use_kernels)))
            bi += 1
        if s == n_stages - 1:
            mods.append(("norm_f", L.RMSNorm(d, use_kernel=use_kernels)))
            mods.append(("head", L.Linear(d, vocab, bias=False)))
        stages.append(Stage(mods))

    return Pipeline(
        name="transformer",
        stages=stages,
        loss_grad=lm_cross_entropy,
        input_spec=jax.ShapeDtypeStruct((b, t), jnp.int32),
        label_spec=jax.ShapeDtypeStruct((b, t), jnp.int32),
        samples_per_microbatch=b,
    )
