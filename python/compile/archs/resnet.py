"""ResNet-152-like bottleneck CNN — the paper's *non-uniform* compute graph.

Activations change shape down the network (the paper: "a model who's
activations do not share a constant shape throughout the model"), which
is exactly why ResNet shows the smallest 2BP gain (1.10×, §4.1): a
deferred backward-p2 slab may exceed the bubble it is slotted into.

Structure: stem (7×7/2 conv + BN + ReLU + 3×3/2 maxpool), then bottleneck
stacks with channel plan (64,128,256,512)×4 and stride-2 transitions,
then GAP + FC head.  The paper splits ResNet152's 50 bottlenecks as
[10, 14, 14, 12] across 4 GPUs with the stem on GPU 0 and the head on
GPU 3 — ``build`` honors an explicit ``split`` list for this.
"""

from __future__ import annotations

from typing import List, Optional

import jax
import jax.numpy as jnp

from .. import layers as L
from .common import Pipeline, Stage, class_cross_entropy


class Bottleneck(L.Module):
    """1×1 -> 3×3 -> 1×1 bottleneck with BN + ReLU and projection skip."""

    has_params = True

    def __init__(self, c_in: int, c_mid: int, stride: int = 1):
        c_out = c_mid * 4
        self.conv1 = L.Conv2d(c_in, c_mid, 1)
        self.bn1 = L.BatchNorm2d(c_mid)
        self.conv2 = L.Conv2d(c_mid, c_mid, 3, stride=stride, padding=1)
        self.bn2 = L.BatchNorm2d(c_mid)
        self.conv3 = L.Conv2d(c_mid, c_out, 1)
        self.bn3 = L.BatchNorm2d(c_out)
        self.relu = L.ReLU()
        self.down: Optional[L.Conv2d] = None
        self.down_bn: Optional[L.BatchNorm2d] = None
        if stride != 1 or c_in != c_out:
            self.down = L.Conv2d(c_in, c_out, 1, stride=stride)
            self.down_bn = L.BatchNorm2d(c_out)
        names = ["conv1", "bn1", "conv2", "bn2", "conv3", "bn3"]
        mods = [self.conv1, self.bn1, self.conv2, self.bn2,
                self.conv3, self.bn3]
        if self.down is not None:
            names += ["down", "down_bn"]
            mods += [self.down, self.down_bn]
        self._children = tuple(zip(names, mods))

    def init(self, key):
        ks = jax.random.split(key, len(self._children))
        return {n: m.init(k) for (n, m), k in zip(self._children, ks)}

    def fwd(self, params, x):
        r1, r2 = {}, {}
        h, r1["conv1"], r2["conv1"] = self.conv1.fwd(params["conv1"], x)
        h, r1["bn1"], r2["bn1"] = self.bn1.fwd(params["bn1"], h)
        a1 = h
        h = jnp.maximum(h, 0.0)
        h, r1["conv2"], r2["conv2"] = self.conv2.fwd(params["conv2"], h)
        h, r1["bn2"], r2["bn2"] = self.bn2.fwd(params["bn2"], h)
        a2 = h
        h = jnp.maximum(h, 0.0)
        h, r1["conv3"], r2["conv3"] = self.conv3.fwd(params["conv3"], h)
        h, r1["bn3"], r2["bn3"] = self.bn3.fwd(params["bn3"], h)
        if self.down is not None:
            sk, r1["down"], r2["down"] = self.down.fwd(params["down"], x)
            sk, r1["down_bn"], r2["down_bn"] = self.down_bn.fwd(
                params["down_bn"], sk)
        else:
            sk = x
        pre = h + sk
        y = jnp.maximum(pre, 0.0)
        r1["_act"] = (a1, a2, pre)
        order = [n for n, _ in self._children] + ["_act"]
        return y, tuple(r1[n] for n in order), \
            tuple(r2.get(n, ()) for n in order)

    def _unpack(self, res):
        order = [n for n, _ in self._children] + ["_act"]
        return dict(zip(order, res))

    def bwd_p1(self, params, res1, res2, gy):
        r1, r2 = self._unpack(res1), self._unpack(res2)
        a1, a2, pre = r1["_act"]
        inter = {}
        g = gy * (pre > 0)
        gsk = g
        gh, inter["bn3"] = self.bn3.bwd_p1(params["bn3"], r1["bn3"], r2["bn3"], g)
        gh, inter["conv3"] = self.conv3.bwd_p1(
            params["conv3"], r1["conv3"], r2["conv3"], gh)
        gh = gh * (a2 > 0)
        gh, inter["bn2"] = self.bn2.bwd_p1(params["bn2"], r1["bn2"], r2["bn2"], gh)
        gh, inter["conv2"] = self.conv2.bwd_p1(
            params["conv2"], r1["conv2"], r2["conv2"], gh)
        gh = gh * (a1 > 0)
        gh, inter["bn1"] = self.bn1.bwd_p1(params["bn1"], r1["bn1"], r2["bn1"], gh)
        gx, inter["conv1"] = self.conv1.bwd_p1(
            params["conv1"], r1["conv1"], r2["conv1"], gh)
        if self.down is not None:
            gd, inter["down_bn"] = self.down_bn.bwd_p1(
                params["down_bn"], r1["down_bn"], r2["down_bn"], gsk)
            gd, inter["down"] = self.down.bwd_p1(
                params["down"], r1["down"], r2["down"], gd)
            gx = gx + gd
        else:
            gx = gx + gsk
        order = [n for n, _ in self._children]
        return gx, tuple(inter[n] for n in order)

    def bwd_p2(self, res2, inter):
        r2 = self._unpack(res2)
        order = [n for n, _ in self._children]
        it = dict(zip(order, inter))
        return {n: m.bwd_p2(r2[n], it[n]) for n, m in self._children}


class Stem(L.Module):
    """7×7/2 conv + BN + ReLU + 3×3/2 maxpool (ImageNet-style stem)."""

    has_params = True

    def __init__(self, c_out: int = 64):
        self.conv = L.Conv2d(3, c_out, 7, stride=2, padding=3)
        self.bn = L.BatchNorm2d(c_out)
        self.pool = L.MaxPool2d(3, 2, padding=1)
        self._children = (("conv", self.conv), ("bn", self.bn),
                          ("pool", self.pool))

    def init(self, key):
        ks = jax.random.split(key, 3)
        return {n: m.init(k) for (n, m), k in zip(self._children, ks)
                if m.has_params}

    def fwd(self, params, x):
        h, r1c, r2c = self.conv.fwd(params["conv"], x)
        h, r1b, r2b = self.bn.fwd(params["bn"], h)
        a = h
        h = jnp.maximum(h, 0.0)
        y, r1p, r2p = self.pool.fwd({}, h)
        return y, (r1c, r1b, r1p, (a,)), (r2c, r2b, r2p)

    def bwd_p1(self, params, res1, res2, gy):
        r1c, r1b, r1p, (a,) = res1
        r2c, r2b, r2p = res2
        g, _ = self.pool.bwd_p1({}, r1p, r2p, gy)
        g = g * (a > 0)
        g, ib = self.bn.bwd_p1(params["bn"], r1b, r2b, g)
        g, ic = self.conv.bwd_p1(params["conv"], r1c, r2c, g)
        return g, (ic, ib)

    def bwd_p2(self, res2, inter):
        r2c, r2b, _ = res2
        ic, ib = inter
        return {"conv": self.conv.bwd_p2(r2c, ic),
                "bn": self.bn.bwd_p2(r2b, ib)}


class Head(L.Module):
    """GlobalAvgPool + FC classification head."""

    has_params = True

    def __init__(self, c_in: int, classes: int):
        self.gap = L.GlobalAvgPool()
        self.fc = L.Linear(c_in, classes, bias=True)

    def init(self, key):
        return {"fc": self.fc.init(key)}

    def fwd(self, params, x):
        p, r1g, r2g = self.gap.fwd({}, x)
        y, r1f, r2f = self.fc.fwd(params["fc"], p)
        return y, (r1g, r1f), (r2g, r2f)

    def bwd_p1(self, params, res1, res2, gy):
        r1g, r1f = res1
        r2g, r2f = res2
        g, i_f = self.fc.bwd_p1(params["fc"], r1f, r2f, gy)
        g, _ = self.gap.bwd_p1({}, r1g, r2g, g)
        return g, (i_f,)

    def bwd_p2(self, res2, inter):
        _, r2f = res2
        (i_f,) = inter
        return {"fc": self.fc.bwd_p2(r2f, i_f)}


def bottleneck_plan(blocks_per_stack: List[int]):
    """Expand a (n1,n2,n3,n4) stack plan into (c_in, c_mid, stride) specs."""
    plan = []
    c_in = 64
    for si, n in enumerate(blocks_per_stack):
        c_mid = 64 * (2 ** si)
        for bi in range(n):
            stride = 2 if (bi == 0 and si > 0) else 1
            plan.append((c_in, c_mid, stride))
            c_in = c_mid * 4
    return plan


def build(cfg: dict) -> Pipeline:
    """cfg keys: stacks (e.g. [3,8,36,3] for ResNet-152), image, classes,
    microbatch, stages, split (optional explicit bottleneck split)."""
    stacks = cfg.get("stacks", [3, 8, 36, 3])
    img = cfg["image"]
    classes = cfg["classes"]
    n_stages, b = cfg["stages"], cfg["microbatch"]

    plan = bottleneck_plan(stacks)
    n_blocks = len(plan)
    if "split" in cfg:
        split = cfg["split"]
        assert sum(split) == n_blocks, (split, n_blocks)
    else:
        base, rem = divmod(n_blocks, n_stages)
        split = [base + (1 if i < rem else 0) for i in range(n_stages)]

    stages = []
    bi = 0
    for s in range(n_stages):
        mods = []
        if s == 0:
            mods.append(("stem", Stem(64)))
        for _ in range(split[s]):
            c_in, c_mid, stride = plan[bi]
            mods.append((f"btl{bi}", Bottleneck(c_in, c_mid, stride)))
            bi += 1
        if s == n_stages - 1:
            mods.append(("head", Head(plan[-1][1] * 4, classes)))
        stages.append(Stage(mods))

    return Pipeline(
        name="resnet",
        stages=stages,
        loss_grad=class_cross_entropy,
        input_spec=jax.ShapeDtypeStruct((b, 3, img, img), jnp.float32),
        label_spec=jax.ShapeDtypeStruct((b,), jnp.int32),
        samples_per_microbatch=b,
    )
