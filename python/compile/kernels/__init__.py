"""L1: Pallas kernels for the paper's compute hot-spots (+ pure-jnp oracles).

All kernels lower with interpret=True (CPU PJRT cannot execute Mosaic
custom-calls); on real TPUs the same BlockSpecs compile natively.
"""
from . import ref  # noqa: F401
from .matmul import matmul  # noqa: F401
from .rmsnorm import rmsnorm_fwd, rmsnorm_bwd_p1, rmsnorm_bwd_p2  # noqa: F401
from .softmax import softmax_fwd, softmax_bwd  # noqa: F401
from .attention import attention_fwd  # noqa: F401
