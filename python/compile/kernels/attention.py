"""Flash-style scalar dot-product attention forward as a Pallas kernel.

TPU rethink of the CUDA flash-attention pattern (DESIGN.md
§Hardware-Adaptation): the KV sequence is walked as the innermost grid
axis with an *online softmax* — running max `m`, normalizer `l` and
un-normalized accumulator `acc` live in VMEM-resident blocks that are
revisited across KV steps, so the full [t, t] score matrix never
materializes in HBM.  The CUDA version staged K/V tiles through shared
memory per threadblock; here BlockSpec's index maps express the same
HBM->VMEM schedule declaratively.

SDPA is purely functional — no parameters, hence **no backward-p2**
(paper §4.1 calls this out as a driver of per-architecture 2BP gain
variation).  backward-p1 is composed from the softmax/matmul primitives
in the layer library.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pick(dim: int, target: int) -> int:
    b = min(dim, target)
    while dim % b != 0:
        b -= 1
    return b


def _attn_fwd_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
                     *, scale: float, causal: bool, bq: int, bk: int, nk: int):
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, -1e30)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0]            # [bq, hd]
    k = k_ref[0]            # [bk, hd]
    v = v_ref[0]            # [bk, hd]
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale

    if causal:
        iq = pl.program_id(1)
        rows = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        cols = kk * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jnp.where(rows >= cols, s, -1e30)

    m_prev = m_ref[...]                                   # [bq, 1]
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)                                # [bq, bk]
    alpha = jnp.exp(m_prev - m_new)                       # rescale old state
    l_new = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_new = acc_ref[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new
    l_ref[...] = l_new
    acc_ref[...] = acc_new

    @pl.when(kk == nk - 1)
    def _final():
        o_ref[0] = (acc_new / l_new).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k"))
def attention_fwd(q, k, v, causal: bool = True,
                  block_q: int = 128, block_k: int = 128):
    """Flash-style attention forward.

    q,k,v: [h, t, hd] (h = flattened batch*heads).  Returns [h, t, hd].
    """
    h, t, hd = q.shape
    bq = _pick(t, block_q)
    bk = _pick(t, block_k)
    nk = t // bk
    scale = 1.0 / (hd ** 0.5)
    grid = (h, t // bq, nk)
    qspec = pl.BlockSpec((1, bq, hd), lambda ih, iq, kk: (ih, iq, 0))
    kvspec = pl.BlockSpec((1, bk, hd), lambda ih, iq, kk: (ih, kk, 0))
    out, _, _, _ = pl.pallas_call(
        functools.partial(_attn_fwd_kernel, scale=scale, causal=causal,
                          bq=bq, bk=bk, nk=nk),
        grid=grid,
        in_specs=[qspec, kvspec, kvspec],
        out_specs=[
            pl.BlockSpec((1, bq, hd), lambda ih, iq, kk: (ih, iq, 0)),
            pl.BlockSpec((bq, hd), lambda ih, iq, kk: (iq, 0)),
            pl.BlockSpec((bq, 1), lambda ih, iq, kk: (iq, 0)),
            pl.BlockSpec((bq, 1), lambda ih, iq, kk: (iq, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((h, t, hd), q.dtype),
            jax.ShapeDtypeStruct((t, hd), jnp.float32),  # acc scratch
            jax.ShapeDtypeStruct((t, 1), jnp.float32),   # running max
            jax.ShapeDtypeStruct((t, 1), jnp.float32),   # normalizer
        ],
        interpret=True,
    )(q, k, v)
    return out


def vmem_bytes(t: int, hd: int, bq=128, bk=128, itemsize=4):
    """Static VMEM estimate per grid step (DESIGN.md §8)."""
    bq, bk = _pick(t, bq), _pick(t, bk)
    return (bq * hd + 2 * bk * hd + bq * bk + bq * hd + 2 * bq) * itemsize
