"""Fused row-softmax as Pallas kernels (forward + backward).

Softmax backward was the paper's second torch.jit.script target (§3.2).
Each kernel instance owns a block of rows in VMEM and fuses the
max/exp/sum/scale chain (fwd) or the y*(gy - sum(gy*y)) chain (bwd) in a
single pass.  Note softmax is *purely functional* — it has no
backward-p2 (the paper singles this class of op out in §4.1/§4.2: its
saved state is released at backward-p1).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pick_rows(rows: int, target: int) -> int:
    b = min(rows, target)
    while rows % b != 0:
        b -= 1
    return b


def _fwd_kernel(x_ref, y_ref):
    x = x_ref[...]
    m = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - m)
    y_ref[...] = e / jnp.sum(e, axis=-1, keepdims=True)


@functools.partial(jax.jit, static_argnames=("block_rows",))
def softmax_fwd(x, block_rows: int = 128):
    """Fused row softmax over the last axis of a 2-D [rows, d] input."""
    rows, d = x.shape
    br = _pick_rows(rows, block_rows)
    return pl.pallas_call(
        _fwd_kernel,
        grid=(rows // br,),
        in_specs=[pl.BlockSpec((br, d), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        interpret=True,
    )(x)


def _bwd_kernel(y_ref, gy_ref, gx_ref):
    y = y_ref[...]
    gy = gy_ref[...]
    s = jnp.sum(gy * y, axis=-1, keepdims=True)
    gx_ref[...] = y * (gy - s)


@functools.partial(jax.jit, static_argnames=("block_rows",))
def softmax_bwd(y, gy, block_rows: int = 128):
    """Fused softmax backward (this is a backward-p1; softmax has no p2)."""
    rows, d = y.shape
    br = _pick_rows(rows, block_rows)
    return pl.pallas_call(
        _bwd_kernel,
        grid=(rows // br,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((br, d), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), y.dtype),
        interpret=True,
    )(y, gy)
