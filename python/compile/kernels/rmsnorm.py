"""Fused RMSNorm as Pallas kernels: forward, backward-p1, backward-p2.

The paper torch.jit.script-compiled RMSNorm's backward because it was a
hot spot (§3.2).  Here the same role is played by fused Pallas kernels:
each kernel processes a block of rows entirely in VMEM, fusing the
square/mean/rsqrt/scale chain into one pass (VPU row reductions instead
of CUDA warp shuffles — DESIGN.md §Hardware-Adaptation).

backward-p2 (the *weight* grad, dg = sum_rows gy*xhat) is a cross-row
reduction, so its grid walks row-blocks sequentially accumulating into
the single [d] output block — the 2BP-deferred stage of this layer.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pick_rows(rows: int, target: int) -> int:
    b = min(rows, target)
    while rows % b != 0:
        b -= 1
    return b


def _fwd_kernel(x_ref, g_ref, y_ref, rstd_ref, *, eps: float):
    x = x_ref[...]
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    rstd = 1.0 / jnp.sqrt(ms + eps)
    y_ref[...] = x * rstd * g_ref[...]
    rstd_ref[...] = rstd


@functools.partial(jax.jit, static_argnames=("eps", "block_rows"))
def rmsnorm_fwd(x, g, eps: float = 1e-5, block_rows: int = 128):
    """Fused RMSNorm forward. x: [rows, d], g: [d] -> (y, rstd [rows,1])."""
    rows, d = x.shape
    br = _pick_rows(rows, block_rows)
    return pl.pallas_call(
        functools.partial(_fwd_kernel, eps=eps),
        grid=(rows // br,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((br, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows, d), x.dtype),
            jax.ShapeDtypeStruct((rows, 1), x.dtype),
        ],
        interpret=True,
    )(x, g)


def _bwd_p1_kernel(x_ref, g_ref, rstd_ref, gy_ref, gx_ref):
    x = x_ref[...]
    rstd = rstd_ref[...]
    xhat = x * rstd
    gyg = gy_ref[...] * g_ref[...]
    m = jnp.mean(gyg * xhat, axis=-1, keepdims=True)
    gx_ref[...] = (gyg - xhat * m) * rstd


@functools.partial(jax.jit, static_argnames=("block_rows",))
def rmsnorm_bwd_p1(x, g, rstd, gy, block_rows: int = 128):
    """Fused input-grad (backward-p1): the inter-stage critical path."""
    rows, d = x.shape
    br = _pick_rows(rows, block_rows)
    return pl.pallas_call(
        _bwd_p1_kernel,
        grid=(rows // br,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((br, 1), lambda i: (i, 0)),
            pl.BlockSpec((br, d), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        interpret=True,
    )(x, g, rstd, gy)


def _bwd_p2_kernel(x_ref, rstd_ref, gy_ref, dg_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        dg_ref[...] = jnp.zeros_like(dg_ref)

    dg_ref[...] += jnp.sum(gy_ref[...] * x_ref[...] * rstd_ref[...], axis=0)


@functools.partial(jax.jit, static_argnames=("block_rows",))
def rmsnorm_bwd_p2(x, rstd, gy, block_rows: int = 128):
    """Fused weight-grad (backward-p2): the 2BP-deferrable stage.

    Cross-row reduction: row-blocks are walked sequentially and
    accumulated into the single resident [d] output tile.
    """
    rows, d = x.shape
    br = _pick_rows(rows, block_rows)
    return pl.pallas_call(
        _bwd_p2_kernel,
        grid=(rows // br,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((br, 1), lambda i: (i, 0)),
            pl.BlockSpec((br, d), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((d,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((d,), x.dtype),
        interpret=True,
    )(x, rstd, gy)
