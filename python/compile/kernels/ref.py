"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth the kernels are tested against (pytest +
hypothesis sweeps in python/tests/test_kernels.py). They are also used
directly by the layer library when a shape falls outside a kernel's tile
constraints (e.g. tiny test configs).
"""

import jax.numpy as jnp


def matmul(x, y):
    """Plain f32 matmul, [m,k]@[k,n] -> [m,n]."""
    return jnp.matmul(x, y)


def rmsnorm_fwd(x, g, eps=1e-5):
    """RMSNorm forward.

    x: [rows, d], g: [d].  Returns (y, rstd) where rstd: [rows, 1] is the
    reciprocal RMS saved for the backward pass.
    """
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    rstd = 1.0 / jnp.sqrt(ms + eps)
    return x * rstd * g, rstd


def rmsnorm_bwd_p1(x, g, rstd, gy):
    """Grad of RMSNorm w.r.t. its *input* (backward-p1).

    gx = rstd * (gy*g - xhat * mean(gy*g*xhat))  with xhat = x*rstd.
    """
    xhat = x * rstd
    gyg = gy * g
    m = jnp.mean(gyg * xhat, axis=-1, keepdims=True)
    return (gyg - xhat * m) * rstd


def rmsnorm_bwd_p2(x, rstd, gy):
    """Grad of RMSNorm w.r.t. its *weight* (backward-p2): dg = sum(gy*xhat)."""
    return jnp.sum(gy * x * rstd, axis=0)


def softmax_fwd(x):
    """Row softmax over the last axis."""
    m = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - m)
    return e / jnp.sum(e, axis=-1, keepdims=True)


def softmax_bwd(y, gy):
    """Softmax backward given the forward output y: gx = y*(gy - sum(gy*y))."""
    s = jnp.sum(gy * y, axis=-1, keepdims=True)
    return y * (gy - s)


def attention_fwd(q, k, v, causal=True):
    """Scalar dot-product attention forward.

    q,k,v: [heads, t, hd] (flattened batch*heads leading axis).
    Returns the attention output [heads, t, hd].
    """
    hd = q.shape[-1]
    s = jnp.einsum("htd,hsd->hts", q, k) / jnp.sqrt(jnp.asarray(hd, q.dtype))
    if causal:
        t = q.shape[-2]
        mask = jnp.tril(jnp.ones((t, t), dtype=bool))
        s = jnp.where(mask, s, jnp.asarray(-1e30, s.dtype))
    p = softmax_fwd(s)
    return jnp.einsum("hts,hsd->htd", p, v)
