"""Blocked matmul as a Pallas kernel.

TPU adaptation of the paper's CUDA hot path (see DESIGN.md
§Hardware-Adaptation): the output is tiled into MXU-shaped (bm, bn)
blocks, with the contraction dimension walked as the innermost grid axis
so each (i, j) output tile stays resident in VMEM while partial products
accumulate into it.  ``BlockSpec`` expresses the HBM->VMEM schedule that
the CUDA version expressed with threadblocks + shared-memory staging.

Always lowered with ``interpret=True`` — the CPU PJRT plugin cannot run
Mosaic custom-calls; on a real TPU the same BlockSpecs compile natively.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(x_ref, y_ref, o_ref, *, nk: int):
    """One (i, j, k) grid step: o[i,j] (+)= x[i,k] @ y[k,j]."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=o_ref.dtype
    )


def _pick_block(dim: int, target: int) -> int:
    """Largest divisor of `dim` that is <= target (keeps tiles MXU-friendly)."""
    b = min(dim, target)
    while dim % b != 0:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matmul(x, y, bm: int = 128, bn: int = 128, bk: int = 128):
    """Blocked Pallas matmul: x [m,k] @ y [k,n] -> [m,n].

    Block sizes are clamped to divisors of the problem dims so tiny test
    shapes still work; at the paper's model dims (multiples of 128) the
    tiles are exactly MXU-shaped 128x128.
    """
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    bm = _pick_block(m, bm)
    bn = _pick_block(n, bn)
    bk = _pick_block(k, bk)
    nk = k // bk
    grid = (m // bm, n // bn, nk)
    return pl.pallas_call(
        functools.partial(_matmul_kernel, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=True,
    )(x, y)


def vmem_bytes(m: int, n: int, k: int, bm=128, bn=128, bk=128, itemsize=4):
    """Static VMEM footprint estimate for one grid step (DESIGN.md §8)."""
    bm, bn, bk = _pick_block(m, bm), _pick_block(n, bn), _pick_block(k, bk)
    return (bm * bk + bk * bn + bm * bn) * itemsize


def mxu_utilization(m: int, n: int, k: int, bm=128, bn=128, bk=128):
    """Fraction of MXU 128x128 MAC slots a tile actually fills."""
    bm, bn, bk = _pick_block(m, bm), _pick_block(n, bn), _pick_block(k, bk)
    return min(bm, 128) * min(bn, 128) / (128.0 * 128.0)
