//! SplitMix64 PRNG (substrate: the `rand` crate is unavailable offline).
//!
//! Deterministic, seedable, fast — used for synthetic training data (the
//! paper trains on randomly generated samples, §3.2) and for the fuzzing
//! harness in [`crate::util::proptest`].

#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform f32 in [0, 1).
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [0, bound).
    pub fn below(&mut self, bound: u64) -> u64 {
        // multiply-shift; bias is negligible for bound << 2^64
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-300);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Fill a buffer with int32 token ids in [0, vocab).
    pub fn fill_tokens(&mut self, buf: &mut [i32], vocab: i32) {
        for v in buf {
            *v = self.below(vocab as u64) as i32;
        }
    }

    /// Fill a buffer with standard-normal f32s.
    pub fn fill_normal(&mut self, buf: &mut [f32]) {
        for v in buf {
            *v = self.normal() as f32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let a: Vec<u64> = {
            let mut r = SplitMix64::new(7);
            (0..8).map(|_| r.next_u64()).collect()
        };
        let b: Vec<u64> = {
            let mut r = SplitMix64::new(7);
            (0..8).map(|_| r.next_u64()).collect()
        };
        assert_eq!(a, b);
        let c = SplitMix64::new(8).next_u64();
        assert_ne!(a[0], c);
    }

    #[test]
    fn below_in_range() {
        let mut r = SplitMix64::new(1);
        for _ in 0..1000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = SplitMix64::new(2);
        let n = 20000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.05, "mean {}", mean);
        assert!((var - 1.0).abs() < 0.1, "var {}", var);
    }

    #[test]
    fn tokens_in_vocab() {
        let mut r = SplitMix64::new(3);
        let mut buf = vec![0i32; 256];
        r.fill_tokens(&mut buf, 50);
        assert!(buf.iter().all(|&t| (0..50).contains(&t)));
    }
}
