//! Chrome Trace Event Format export for pipeline timelines.
//!
//! Converts per-rank [`Span`] timelines — Tier-B simulator output
//! (`sim::SimResult::spans`) and executed runs
//! (`pipeline::RunReport::spans` + the comm lane) — into the JSON
//! format that Perfetto (<https://ui.perfetto.dev>) and
//! `chrome://tracing` open directly: a `{"traceEvents": [...]}` object
//! of `"X"` (complete) events plus `"M"` (metadata) naming events.
//!
//! Layout convention (see docs/OBSERVABILITY.md):
//!
//! * one **process per (timeline group, rank)** — predicted rank r is
//!   pid [`PREDICTED_PID_BASE`]` + r`, executed rank r is
//!   [`EXECUTED_PID_BASE`]` + r`, so the two timelines stack as
//!   separate process groups for visual diffing;
//! * two **threads per process** — tid [`TID_COMPUTE`] carries
//!   fwd/p1/p2/opt/loss spans, tid [`TID_COMM`] carries [`SpanKind::Comm`]
//!   send spans (the executor's comm lane; the simulator emits none);
//! * timestamps are **microseconds** (`ts`/`dur = seconds × 1e6`), the
//!   Trace Event spec's native unit.
//!
//! Determinism: the builder writes no wall-clock, hostnames, or ids —
//! the output is a pure function of the span lists, so identical runs
//! produce byte-identical traces (a CI-gated property; see ci.yml).

use std::io;
use std::path::Path;

use crate::util::gantt::{Span, SpanKind};
use crate::util::json::{obj, Json};

/// pid of predicted (simulator) rank 0; rank r is `base + r`.
pub const PREDICTED_PID_BASE: usize = 1;
/// pid of executed (real run) rank 0 — offset far enough that no
/// plausible rank count collides with the predicted group.
pub const EXECUTED_PID_BASE: usize = 1001;
/// tid carrying compute spans (fwd / bwd-p1 / bwd-p2 / opt / loss).
pub const TID_COMPUTE: usize = 0;
/// tid carrying communication (send) spans.
pub const TID_COMM: usize = 1;

/// Short machine-readable name for a span kind (event `name`/`cat`).
pub fn kind_name(kind: SpanKind) -> &'static str {
    match kind {
        SpanKind::Fwd => "fwd",
        SpanKind::BwdP1 => "bwd_p1",
        SpanKind::BwdP2 => "bwd_p2",
        SpanKind::Opt => "opt",
        SpanKind::Comm => "comm",
        SpanKind::Loss => "loss",
    }
}

/// Accumulates trace events; serialize with [`TraceBuilder::render`] or
/// [`TraceBuilder::write`].
#[derive(Debug, Default)]
pub struct TraceBuilder {
    events: Vec<Json>,
}

impl TraceBuilder {
    pub fn new() -> TraceBuilder {
        TraceBuilder::default()
    }

    /// Number of events accumulated so far (metadata + spans).
    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Add one timeline group (e.g. `"predicted"` / `"executed"`): one
    /// process per rank at `pid_base + rank`, spans routed to the
    /// compute or comm thread by [`SpanKind`].  Ranks with no spans
    /// still get their process metadata, so predicted and executed
    /// groups always show the same rank set.
    pub fn add_timeline(
        &mut self,
        group: &str,
        pid_base: usize,
        ranks: &[Vec<Span>],
    ) {
        for (rank, spans) in ranks.iter().enumerate() {
            let pid = pid_base + rank;
            self.meta(pid, None, "process_name", |a| {
                a.push((
                    "name",
                    Json::Str(format!("{group} rank {rank}")),
                ));
            });
            self.meta(pid, None, "process_sort_index", |a| {
                a.push(("sort_index", Json::Num(pid as f64)));
            });
            self.meta(pid, Some(TID_COMPUTE), "thread_name", |a| {
                a.push(("name", Json::Str("compute".into())));
            });
            if spans.iter().any(|s| s.label == SpanKind::Comm) {
                self.meta(pid, Some(TID_COMM), "thread_name", |a| {
                    a.push(("name", Json::Str("comm".into())));
                });
            }
            for s in spans {
                let tid = if s.label == SpanKind::Comm {
                    TID_COMM
                } else {
                    TID_COMPUTE
                };
                self.events.push(obj(vec![
                    (
                        "name",
                        Json::Str(format!(
                            "{} mb{}",
                            kind_name(s.label),
                            s.mb
                        )),
                    ),
                    ("cat", Json::Str(kind_name(s.label).into())),
                    ("ph", Json::Str("X".into())),
                    ("ts", Json::Num(s.start * 1e6)),
                    ("dur", Json::Num((s.end - s.start) * 1e6)),
                    ("pid", Json::Num(pid as f64)),
                    ("tid", Json::Num(tid as f64)),
                    (
                        "args",
                        obj(vec![("mb", Json::Num(s.mb as f64))]),
                    ),
                ]));
            }
        }
    }

    fn meta(
        &mut self,
        pid: usize,
        tid: Option<usize>,
        name: &str,
        fill_args: impl FnOnce(&mut Vec<(&'static str, Json)>),
    ) {
        let mut args = Vec::new();
        fill_args(&mut args);
        let mut fields = vec![
            ("name", Json::Str(name.into())),
            ("ph", Json::Str("M".into())),
            ("pid", Json::Num(pid as f64)),
            ("args", obj(args)),
        ];
        if let Some(tid) = tid {
            fields.push(("tid", Json::Num(tid as f64)));
        }
        self.events.push(obj(fields));
    }

    /// The complete trace document.
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("displayTimeUnit", Json::Str("ms".into())),
            ("traceEvents", Json::Arr(self.events.clone())),
        ])
    }

    /// Compact JSON text of the trace document.
    pub fn render(&self) -> String {
        self.to_json().to_string()
    }

    /// Write the trace to `path` (overwrites).
    pub fn write(&self, path: &Path) -> io::Result<()> {
        std::fs::write(path, self.render())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::{generate, ScheduleKind};
    use crate::sim::{simulate, CostModel};

    fn x_events(doc: &Json) -> Vec<&Json> {
        doc.get("traceEvents")
            .and_then(Json::as_arr)
            .unwrap()
            .iter()
            .filter(|e| {
                e.get("ph").and_then(Json::as_str) == Some("X")
            })
            .collect()
    }

    #[test]
    fn round_trips_a_sim_result() {
        let plan = generate(ScheduleKind::OneF1B1, true, 4, 0, false);
        let costs = CostModel::ratios(4, 1.0, 1.05, 0.95);
        let res = simulate(&plan, &costs, None).unwrap();
        let n_spans: usize = res.spans.iter().map(Vec::len).sum();

        let mut tb = TraceBuilder::new();
        tb.add_timeline("predicted", PREDICTED_PID_BASE, &res.spans);
        let doc = Json::parse(&tb.render()).unwrap();

        assert_eq!(
            doc.get("displayTimeUnit").and_then(Json::as_str),
            Some("ms")
        );
        let xs = x_events(&doc);
        assert_eq!(xs.len(), n_spans, "one X event per sim span");

        // per-rank pid mapping: rank r's spans all land on pid base+r
        for (rank, spans) in res.spans.iter().enumerate() {
            let pid = (PREDICTED_PID_BASE + rank) as f64;
            let on_pid = xs
                .iter()
                .filter(|e| e.get("pid").and_then(Json::as_f64) == Some(pid))
                .count();
            assert_eq!(on_pid, spans.len(), "rank {rank}");
        }

        // per (pid, tid): ts monotone, spans non-overlapping
        let mut keys: Vec<(u64, u64)> = xs
            .iter()
            .map(|e| {
                (
                    e.get("pid").and_then(Json::as_u64).unwrap(),
                    e.get("tid").and_then(Json::as_u64).unwrap(),
                )
            })
            .collect();
        keys.sort_unstable();
        keys.dedup();
        for (pid, tid) in keys {
            let mut prev_end = f64::NEG_INFINITY;
            for e in xs.iter().filter(|e| {
                e.get("pid").and_then(Json::as_u64) == Some(pid)
                    && e.get("tid").and_then(Json::as_u64) == Some(tid)
            }) {
                let ts = e.get("ts").and_then(Json::as_f64).unwrap();
                let dur = e.get("dur").and_then(Json::as_f64).unwrap();
                assert!(
                    ts >= prev_end - 1e-6,
                    "overlap on pid {pid} tid {tid}: \
                     ts {ts} < prev end {prev_end}"
                );
                prev_end = ts + dur;
            }
        }
    }

    #[test]
    fn groups_get_distinct_pids_and_comm_goes_to_tid_1() {
        let predicted = vec![vec![Span {
            start: 0.0,
            end: 1.0,
            label: SpanKind::Fwd,
            mb: 0,
        }]];
        let executed = vec![vec![
            Span { start: 0.0, end: 0.9, label: SpanKind::Fwd, mb: 0 },
            Span { start: 0.9, end: 1.0, label: SpanKind::Comm, mb: 0 },
        ]];
        let mut tb = TraceBuilder::new();
        tb.add_timeline("predicted", PREDICTED_PID_BASE, &predicted);
        tb.add_timeline("executed", EXECUTED_PID_BASE, &executed);
        let doc = Json::parse(&tb.render()).unwrap();
        let evs = doc.get("traceEvents").and_then(Json::as_arr).unwrap();

        let names: Vec<&str> = evs
            .iter()
            .filter(|e| {
                e.get("name").and_then(Json::as_str)
                    == Some("process_name")
            })
            .filter_map(|e| {
                e.get("args")
                    .and_then(|a| a.get("name"))
                    .and_then(Json::as_str)
            })
            .collect();
        assert_eq!(names, vec!["predicted rank 0", "executed rank 0"]);

        let xs = x_events(&doc);
        let comm: Vec<&&Json> = xs
            .iter()
            .filter(|e| {
                e.get("cat").and_then(Json::as_str) == Some("comm")
            })
            .collect();
        assert_eq!(comm.len(), 1);
        assert_eq!(
            comm[0].get("tid").and_then(Json::as_u64),
            Some(TID_COMM as u64)
        );
        assert_eq!(
            comm[0].get("pid").and_then(Json::as_u64),
            Some(EXECUTED_PID_BASE as u64)
        );

        // µs scaling: the 0.9s fwd span is 900000 µs long
        let fwd_exec = xs
            .iter()
            .find(|e| {
                e.get("pid").and_then(Json::as_u64)
                    == Some(EXECUTED_PID_BASE as u64)
                    && e.get("cat").and_then(Json::as_str) == Some("fwd")
            })
            .unwrap();
        assert_eq!(fwd_exec.get("dur").and_then(Json::as_f64), Some(9e5));
    }

    #[test]
    fn identical_inputs_render_identically() {
        let spans = vec![vec![Span {
            start: 0.25,
            end: 0.75,
            label: SpanKind::BwdP2,
            mb: 3,
        }]];
        let mut a = TraceBuilder::new();
        a.add_timeline("predicted", PREDICTED_PID_BASE, &spans);
        let mut b = TraceBuilder::new();
        b.add_timeline("predicted", PREDICTED_PID_BASE, &spans);
        assert_eq!(a.render(), b.render());
        assert!(!a.is_empty());
        assert_eq!(a.len(), 3 + 1); // 3 metadata + 1 span
    }
}
