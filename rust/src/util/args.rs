//! Tiny CLI argument parser (substrate: clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional args.

use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse argv (excluding the program name). `flag_names` lists options
    /// that take no value.
    pub fn parse(argv: &[String], flag_names: &[&str]) -> Args {
        let mut out = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some(eq) = stripped.find('=') {
                    out.options.insert(
                        stripped[..eq].to_string(),
                        stripped[eq + 1..].to_string(),
                    );
                } else if flag_names.contains(&stripped) {
                    out.flags.push(stripped.to_string());
                } else if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    out.options.insert(stripped.to_string(), argv[i + 1].clone());
                    i += 1;
                } else {
                    out.flags.push(stripped.to_string());
                }
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        out
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} must be an integer")))
            .unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} must be a number")))
            .unwrap_or(default)
    }

    /// Comma-separated integer list, e.g. `--ranks 2,4,8`.  Returns
    /// `default` when the option is absent; errors (rather than
    /// panicking like the scalar getters) because sweep grids are easy
    /// to typo.
    pub fn get_usize_list(
        &self,
        key: &str,
        default: &[usize],
    ) -> Result<Vec<usize>, String> {
        match self.get(key) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|s| s.trim())
                .filter(|s| !s.is_empty())
                .map(|s| {
                    s.parse::<usize>().map_err(|_| {
                        format!("--{key}: '{s}' is not an integer")
                    })
                })
                .collect(),
        }
    }

    /// Parse `--key value` through `FromStr`, attributing failures to
    /// the flag: `Ok(None)` when the option is absent, otherwise
    /// `Err("--key: <the type's own parse error>")`.  This is how
    /// domain types with descriptive errors (e.g.
    /// [`crate::schedule::ScheduleKind`], which lists its valid names)
    /// surface those messages on the CLI instead of a bare panic.
    pub fn get_parsed<T: std::str::FromStr>(
        &self,
        key: &str,
    ) -> Result<Option<T>, String>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(key) {
            None => Ok(None),
            Some(v) => v
                .parse::<T>()
                .map(Some)
                .map_err(|e| format!("--{key}: {e}")),
        }
    }

    pub fn has(&self, flag: &str) -> bool {
        self.flags.iter().any(|f| f == flag)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(v: &[&str]) -> Vec<String> {
        v.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_mixed() {
        let a = Args::parse(
            &sv(&["run", "--preset", "bert-s", "--steps=10", "--verbose"]),
            &["verbose"],
        );
        assert_eq!(a.positional, vec!["run"]);
        assert_eq!(a.get("preset"), Some("bert-s"));
        assert_eq!(a.get_usize("steps", 0), 10);
        assert!(a.has("verbose"));
    }

    #[test]
    fn trailing_flag_without_value() {
        let a = Args::parse(&sv(&["--x"]), &[]);
        assert!(a.has("x"));
    }

    #[test]
    fn defaults() {
        let a = Args::parse(&sv(&[]), &[]);
        assert_eq!(a.get_or("k", "d"), "d");
        assert_eq!(a.get_usize("n", 7), 7);
    }

    #[test]
    fn get_parsed_threads_domain_errors() {
        use crate::schedule::ScheduleKind;
        let a = Args::parse(&sv(&["--schedule", "1f1b-2"]), &[]);
        assert_eq!(
            a.get_parsed::<ScheduleKind>("schedule").unwrap(),
            Some(ScheduleKind::OneF1B2)
        );
        assert_eq!(a.get_parsed::<ScheduleKind>("absent").unwrap(), None);
        let bad = Args::parse(&sv(&["--schedule", "zigzag"]), &[]);
        let err = bad.get_parsed::<ScheduleKind>("schedule").unwrap_err();
        assert!(err.starts_with("--schedule:"), "{err}");
        assert!(err.contains("zigzag") && err.contains("1f1b-2"), "{err}");
    }

    #[test]
    fn usize_lists() {
        let a = Args::parse(&sv(&["--ranks", "2,4, 8"]), &[]);
        assert_eq!(a.get_usize_list("ranks", &[1]).unwrap(), vec![2, 4, 8]);
        assert_eq!(a.get_usize_list("mults", &[1, 2]).unwrap(), vec![1, 2]);
        let bad = Args::parse(&sv(&["--ranks", "2,x"]), &[]);
        assert!(bad.get_usize_list("ranks", &[1]).is_err());
    }
}
