//! ASCII Gantt renderer for pipeline timelines (regenerates the paper's
//! Figure 1 schedule diagrams as text).

/// One executed span on one rank's timeline.
#[derive(Debug, Clone)]
pub struct Span {
    pub start: f64,
    pub end: f64,
    pub label: SpanKind,
    pub mb: u32,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpanKind {
    Fwd,
    BwdP1,
    BwdP2,
    Opt,
    Comm,
    /// Loss + initial-gradient computation (last rank only; the real
    /// executor times it separately from BwdP1 so measured cost models
    /// can populate `CostModel::loss` instead of inflating p1).
    Loss,
}

impl SpanKind {
    fn ch(&self) -> char {
        match self {
            SpanKind::Fwd => 'F',
            SpanKind::BwdP1 => '1',
            SpanKind::BwdP2 => '2',
            SpanKind::Opt => 'O',
            SpanKind::Comm => '·',
            SpanKind::Loss => 'L',
        }
    }
}

/// Render per-rank spans as an ASCII chart, `cols` characters wide.
/// Digits/letters show which op occupies each time slice; '.' is idle.
pub fn render(ranks: &[Vec<Span>], cols: usize) -> String {
    let makespan = ranks
        .iter()
        .flat_map(|r| r.iter().map(|s| s.end))
        .fold(0.0f64, f64::max);
    if makespan <= 0.0 {
        return String::new();
    }
    let scale = cols as f64 / makespan;
    let mut out = String::new();
    for (ri, spans) in ranks.iter().enumerate() {
        let mut line = vec!['.'; cols];
        for s in spans {
            let mut a = (s.start * scale).floor() as usize;
            let mut b = ((s.end * scale).ceil() as usize).min(cols);
            // a sub-cell span whose floor(start) == ceil(end) after the
            // clamp would paint zero cells and vanish from the chart;
            // guarantee every span occupies at least one cell (shifted
            // left when it sits exactly on the right edge)
            if b <= a {
                b = (a + 1).min(cols);
                a = b - 1;
            }
            for cell in line.iter_mut().take(b).skip(a) {
                *cell = s.label.ch();
            }
        }
        out.push_str(&format!("rank {:>2} |{}|\n", ri, line.iter().collect::<String>()));
    }
    out.push_str(&format!(
        "          makespan = {:.2}  (F=fwd 1=bwd-p1 2=bwd-p2 O=opt \
         L=loss .=idle)\n",
        makespan
    ));
    out
}

/// [`render`], prefixed (when a partition is present) with one header
/// line per rank — `rank R: layers a-b  dp=k` — so a chart of a
/// partitioned plan says which model layers each stage owns and how
/// many replicas of the whole pipeline run.  With `part == None` the
/// output is byte-identical to [`render`], so partition-less callers
/// (`twobp gantt` on v1 plans, the generator path) are untouched.
pub fn render_with_partition(
    ranks: &[Vec<Span>],
    cols: usize,
    part: Option<&crate::schedule::Partition>,
) -> String {
    let chart = render(ranks, cols);
    let part = match part {
        Some(p) => p,
        None => return chart,
    };
    let mut out = String::new();
    for s in 0..part.n_stages().min(ranks.len()) {
        let r = part.layers(s);
        out.push_str(&format!(
            "rank {:>2}: layers {}-{}  dp={}\n",
            s,
            r.start,
            r.end - 1,
            part.dp
        ));
    }
    out.push_str(&chart);
    out
}

/// CSV export: rank,kind,mb,start,end (for external plotting).
pub fn to_csv(ranks: &[Vec<Span>]) -> String {
    let mut out = String::from("rank,kind,microbatch,start,end\n");
    for (ri, spans) in ranks.iter().enumerate() {
        for s in spans {
            out.push_str(&format!(
                "{},{:?},{},{:.6},{:.6}\n",
                ri, s.label, s.mb, s.start, s.end
            ));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_spans() {
        let ranks = vec![
            vec![
                Span { start: 0.0, end: 1.0, label: SpanKind::Fwd, mb: 0 },
                Span { start: 2.0, end: 4.0, label: SpanKind::BwdP1, mb: 0 },
            ],
            vec![Span { start: 1.0, end: 2.0, label: SpanKind::Fwd, mb: 0 }],
        ];
        let s = render(&ranks, 40);
        assert!(s.contains("rank  0"));
        assert!(s.contains('F'));
        assert!(s.contains('1'));
        assert!(s.contains("makespan = 4.00"));
    }

    #[test]
    fn sub_pixel_span_still_paints_a_cell() {
        // cols == makespan, so scale = 1 and a zero-duration span at an
        // integer boundary hits floor(start) == ceil(end) — the old
        // renderer painted it zero cells wide and it vanished
        let ranks = vec![vec![
            Span { start: 0.0, end: 4.0, label: SpanKind::Fwd, mb: 0 },
            Span { start: 2.0, end: 2.0, label: SpanKind::Opt, mb: 0 },
        ]];
        let s = render(&ranks, 4);
        assert!(s.contains('O'), "sub-pixel span vanished:\n{s}");
        // same at the right edge: the clamp must shift left, not drop
        let ranks = vec![vec![
            Span { start: 0.0, end: 4.0, label: SpanKind::Fwd, mb: 0 },
            Span { start: 4.0, end: 4.0, label: SpanKind::Opt, mb: 0 },
        ]];
        let s = render(&ranks, 4);
        assert!(s.contains('O'), "right-edge span vanished:\n{s}");
    }

    #[test]
    fn partition_header_prefixes_the_chart() {
        use crate::schedule::Partition;
        let ranks = vec![
            vec![Span { start: 0.0, end: 1.0, label: SpanKind::Fwd, mb: 0 }],
            vec![Span { start: 1.0, end: 2.0, label: SpanKind::Fwd, mb: 0 }],
        ];
        // None is byte-identical to the plain renderer
        assert_eq!(
            render_with_partition(&ranks, 20, None),
            render(&ranks, 20)
        );
        let part = Partition { cuts: vec![0, 3, 7], dp: 2 };
        let s = render_with_partition(&ranks, 20, Some(&part));
        assert!(s.starts_with("rank  0: layers 0-2  dp=2\n"), "{s}");
        assert!(s.contains("rank  1: layers 3-6  dp=2\n"), "{s}");
        assert!(s.ends_with(&render(&ranks, 20)), "chart body changed");
    }

    #[test]
    fn csv_has_rows() {
        let ranks = vec![vec![Span {
            start: 0.0, end: 1.5, label: SpanKind::BwdP2, mb: 3,
        }]];
        let csv = to_csv(&ranks);
        assert!(csv.contains("0,BwdP2,3,0.000000,1.500000"));
    }
}
