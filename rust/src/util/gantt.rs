//! ASCII Gantt renderer for pipeline timelines (regenerates the paper's
//! Figure 1 schedule diagrams as text).

/// One executed span on one rank's timeline.
#[derive(Debug, Clone)]
pub struct Span {
    pub start: f64,
    pub end: f64,
    pub label: SpanKind,
    pub mb: u32,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpanKind {
    Fwd,
    BwdP1,
    BwdP2,
    Opt,
    Comm,
    /// Loss + initial-gradient computation (last rank only; the real
    /// executor times it separately from BwdP1 so measured cost models
    /// can populate `CostModel::loss` instead of inflating p1).
    Loss,
}

impl SpanKind {
    fn ch(&self) -> char {
        match self {
            SpanKind::Fwd => 'F',
            SpanKind::BwdP1 => '1',
            SpanKind::BwdP2 => '2',
            SpanKind::Opt => 'O',
            SpanKind::Comm => '·',
            SpanKind::Loss => 'L',
        }
    }
}

/// Render per-rank spans as an ASCII chart, `cols` characters wide.
/// Digits/letters show which op occupies each time slice; '.' is idle.
pub fn render(ranks: &[Vec<Span>], cols: usize) -> String {
    let makespan = ranks
        .iter()
        .flat_map(|r| r.iter().map(|s| s.end))
        .fold(0.0f64, f64::max);
    if makespan <= 0.0 {
        return String::new();
    }
    let scale = cols as f64 / makespan;
    let mut out = String::new();
    for (ri, spans) in ranks.iter().enumerate() {
        let mut line = vec!['.'; cols];
        for s in spans {
            let mut a = (s.start * scale).floor() as usize;
            let mut b = ((s.end * scale).ceil() as usize).min(cols);
            // a sub-cell span whose floor(start) == ceil(end) after the
            // clamp would paint zero cells and vanish from the chart;
            // guarantee every span occupies at least one cell (shifted
            // left when it sits exactly on the right edge)
            if b <= a {
                b = (a + 1).min(cols);
                a = b - 1;
            }
            for cell in line.iter_mut().take(b).skip(a) {
                *cell = s.label.ch();
            }
        }
        out.push_str(&format!("rank {:>2} |{}|\n", ri, line.iter().collect::<String>()));
    }
    out.push_str(&format!(
        "          makespan = {:.2}  (F=fwd 1=bwd-p1 2=bwd-p2 O=opt \
         L=loss .=idle)\n",
        makespan
    ));
    out
}

/// CSV export: rank,kind,mb,start,end (for external plotting).
pub fn to_csv(ranks: &[Vec<Span>]) -> String {
    let mut out = String::from("rank,kind,microbatch,start,end\n");
    for (ri, spans) in ranks.iter().enumerate() {
        for s in spans {
            out.push_str(&format!(
                "{},{:?},{},{:.6},{:.6}\n",
                ri, s.label, s.mb, s.start, s.end
            ));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_spans() {
        let ranks = vec![
            vec![
                Span { start: 0.0, end: 1.0, label: SpanKind::Fwd, mb: 0 },
                Span { start: 2.0, end: 4.0, label: SpanKind::BwdP1, mb: 0 },
            ],
            vec![Span { start: 1.0, end: 2.0, label: SpanKind::Fwd, mb: 0 }],
        ];
        let s = render(&ranks, 40);
        assert!(s.contains("rank  0"));
        assert!(s.contains('F'));
        assert!(s.contains('1'));
        assert!(s.contains("makespan = 4.00"));
    }

    #[test]
    fn sub_pixel_span_still_paints_a_cell() {
        // cols == makespan, so scale = 1 and a zero-duration span at an
        // integer boundary hits floor(start) == ceil(end) — the old
        // renderer painted it zero cells wide and it vanished
        let ranks = vec![vec![
            Span { start: 0.0, end: 4.0, label: SpanKind::Fwd, mb: 0 },
            Span { start: 2.0, end: 2.0, label: SpanKind::Opt, mb: 0 },
        ]];
        let s = render(&ranks, 4);
        assert!(s.contains('O'), "sub-pixel span vanished:\n{s}");
        // same at the right edge: the clamp must shift left, not drop
        let ranks = vec![vec![
            Span { start: 0.0, end: 4.0, label: SpanKind::Fwd, mb: 0 },
            Span { start: 4.0, end: 4.0, label: SpanKind::Opt, mb: 0 },
        ]];
        let s = render(&ranks, 4);
        assert!(s.contains('O'), "right-edge span vanished:\n{s}");
    }

    #[test]
    fn csv_has_rows() {
        let ranks = vec![vec![Span {
            start: 0.0, end: 1.5, label: SpanKind::BwdP2, mb: 3,
        }]];
        let csv = to_csv(&ranks);
        assert!(csv.contains("0,BwdP2,3,0.000000,1.500000"));
    }
}
