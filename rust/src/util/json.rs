//! Minimal JSON parser + writer (substrate: serde is unavailable offline).
//!
//! Supports the full JSON grammar needed by the artifact manifests:
//! objects, arrays, strings (with escapes), numbers, booleans, null.
//! Numbers are kept as f64; integer accessors check exactness.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing garbage"));
        }
        Ok(v)
    }

    // -- typed accessors (panic-free) ---------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(a) => a.get(i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as u64),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Num(n) if n.fract() == 0.0 => Some(*n as i64),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Serialize back to compact JSON text.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{}", n));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.i }
    }

    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.ws();
            a.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(
                                &self.b[self.i + 1..self.i + 5],
                            )
                            .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 code point
                    let start = self.i;
                    self.i += 1;
                    while self.i < self.b.len()
                        && (self.b[self.i] & 0xC0) == 0x80
                    {
                        self.i += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }
}

/// Convenience builder for writing result files.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        let src = r#"{"a": [1, 2.5, -3], "b": {"c": "x\n\"y"}, "d": true, "e": null}"#;
        let v = Json::parse(src).unwrap();
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn accessors() {
        let v = Json::parse(r#"{"n": 42, "s": "hi", "a": [0, 1]}"#).unwrap();
        assert_eq!(v.get("n").unwrap().as_u64(), Some(42));
        assert_eq!(v.get("s").unwrap().as_str(), Some("hi"));
        assert_eq!(v.get("a").unwrap().idx(1).unwrap().as_u64(), Some(1));
        assert!(v.get("missing").is_none());
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("01x").is_err());
        assert!(Json::parse(r#"{"a":1} trailing"#).is_err());
    }

    #[test]
    fn unicode_escape() {
        let v = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str(), Some("Aé"));
    }

    #[test]
    fn nested_deep() {
        let v = Json::parse("[[[[[1]]]]]").unwrap();
        assert_eq!(
            v.idx(0).and_then(|v| v.idx(0)).and_then(|v| v.idx(0))
                .and_then(|v| v.idx(0)).and_then(|v| v.idx(0))
                .and_then(|v| v.as_u64()),
            Some(1)
        );
    }
}
