//! Small statistics helpers for the bench harness (criterion is
//! unavailable offline — see DESIGN.md §4 S14), plus the
//! machine-readable `BENCH_sim.json` recorder that tracks the perf
//! trajectory across PRs.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

use super::json::Json;

/// Summary statistics over a sample of measurements.
#[derive(Debug, Clone, Copy)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub median: f64,
}

pub fn summarize(xs: &[f64]) -> Summary {
    assert!(!xs.is_empty());
    let n = xs.len();
    let mean = xs.iter().sum::<f64>() / n as f64;
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
        / n.max(2).saturating_sub(1) as f64;
    let mut s = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Summary {
        n,
        mean,
        std: var.sqrt(),
        min: s[0],
        max: s[n - 1],
        median: s[n / 2],
    }
}

/// Time a closure over `iters` runs after `warmup` runs; returns seconds
/// per iteration for each measured run.
pub fn bench<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Vec<f64> {
    for _ in 0..warmup {
        f();
    }
    (0..iters)
        .map(|_| {
            let t0 = Instant::now();
            f();
            t0.elapsed().as_secs_f64()
        })
        .collect()
}

pub fn fmt_duration(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1} ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.1} µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2} ms", secs * 1e3)
    } else {
        format!("{:.3} s", secs)
    }
}

/// Parse a byte count: a plain integer, or a binary-suffixed value
/// (`4G`, `4GiB`, `512MiB`, `1.5g`, `300kb` — K/M/G/T, all 1024-based,
/// case-insensitive).  The inverse-ish of [`fmt_bytes`], for CLI flags
/// like `twobp tune --budget`.
pub fn parse_bytes(s: &str) -> Result<u64, String> {
    let t = s.trim().to_ascii_lowercase();
    let strip = |sufs: &[&str]| -> Option<String> {
        sufs.iter()
            .find_map(|suf| t.strip_suffix(suf))
            .map(|p| p.trim().to_string())
    };
    let (digits, mult): (String, f64) =
        if let Some(p) = strip(&["tib", "tb"]) {
            (p, (1u64 << 40) as f64)
        } else if let Some(p) = strip(&["gib", "gb"]) {
            (p, (1u64 << 30) as f64)
        } else if let Some(p) = strip(&["mib", "mb"]) {
            (p, (1u64 << 20) as f64)
        } else if let Some(p) = strip(&["kib", "kb"]) {
            (p, 1024.0)
        } else if let Some(p) = strip(&["t"]) {
            (p, (1u64 << 40) as f64)
        } else if let Some(p) = strip(&["g"]) {
            (p, (1u64 << 30) as f64)
        } else if let Some(p) = strip(&["m"]) {
            (p, (1u64 << 20) as f64)
        } else if let Some(p) = strip(&["k"]) {
            (p, 1024.0)
        } else if let Some(p) = strip(&["b"]) {
            (p, 1.0)
        } else {
            (t.clone(), 1.0)
        };
    let v: f64 = digits.parse().map_err(|_| {
        format!("'{s}' is not a byte count (examples: 4G, 512MiB, 1073741824)")
    })?;
    if !(v.is_finite() && v >= 0.0) {
        return Err(format!("'{s}' is not a non-negative byte count"));
    }
    Ok((v * mult).round() as u64)
}

pub fn fmt_bytes(b: u64) -> String {
    const K: f64 = 1024.0;
    let b = b as f64;
    if b < K {
        format!("{} B", b)
    } else if b < K * K {
        format!("{:.1} KiB", b / K)
    } else if b < K * K * K {
        format!("{:.1} MiB", b / K / K)
    } else {
        format!("{:.2} GiB", b / K / K / K)
    }
}

impl Summary {
    /// `{n, mean, std, min, max, median}` for the bench recorder.
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("n".into(), Json::Num(self.n as f64));
        m.insert("mean".into(), Json::Num(self.mean));
        m.insert("std".into(), Json::Num(self.std));
        m.insert("min".into(), Json::Num(self.min));
        m.insert("max".into(), Json::Num(self.max));
        m.insert("median".into(), Json::Num(self.median));
        Json::Obj(m)
    }
}

/// Accumulates named bench measurements and writes them as one JSON
/// object (default file: `BENCH_sim.json`).  Existing entries from a
/// previous run are kept and merged, so several bench binaries
/// (`sweep_throughput`, `hotpath_micro`, ...) can contribute to the
/// same machine-readable perf record.
#[derive(Debug)]
pub struct BenchRecorder {
    path: PathBuf,
    root: BTreeMap<String, Json>,
}

impl BenchRecorder {
    /// Open (or start) the record at `path`, keeping any parseable
    /// existing entries.
    pub fn open(path: &Path) -> BenchRecorder {
        let root = std::fs::read_to_string(path)
            .ok()
            .and_then(|text| Json::parse(&text).ok())
            .and_then(|v| match v {
                Json::Obj(m) => Some(m),
                _ => None,
            })
            .unwrap_or_default();
        BenchRecorder { path: path.to_path_buf(), root }
    }

    /// The conventional cross-PR record next to the crate root.
    pub fn default_file() -> BenchRecorder {
        BenchRecorder::open(Path::new("BENCH_sim.json"))
    }

    /// Insert/overwrite one named entry.
    pub fn record(&mut self, name: &str, value: Json) {
        self.root.insert(name.to_string(), value);
    }

    /// Insert a timing summary under `name`.
    pub fn record_summary(&mut self, name: &str, s: &Summary) {
        self.record(name, s.to_json());
    }

    /// Write the merged record back to disk.
    pub fn write(&self) -> std::io::Result<()> {
        std::fs::write(&self.path, Json::Obj(self.root.clone()).to_string())
    }
}

/// A simple wall-clock stopwatch accumulating named spans (profiling
/// substrate for the §Perf pass).
#[derive(Debug, Default)]
pub struct Stopwatch {
    spans: Vec<(String, Duration)>,
}

impl Stopwatch {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn time<T>(&mut self, name: &str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.spans.push((name.to_string(), t0.elapsed()));
        out
    }

    /// Total per unique span name, sorted descending.
    pub fn totals(&self) -> Vec<(String, Duration)> {
        let mut acc: Vec<(String, Duration)> = Vec::new();
        for (n, d) in &self.spans {
            match acc.iter_mut().find(|(an, _)| an == n) {
                Some((_, ad)) => *ad += *d,
                None => acc.push((n.clone(), *d)),
            }
        }
        acc.sort_by(|a, b| b.1.cmp(&a.1));
        acc
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = summarize(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.n, 4);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
    }

    #[test]
    fn fmt_helpers() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert!(fmt_bytes(2048).contains("KiB"));
        assert!(fmt_duration(0.002).contains("ms"));
    }

    #[test]
    fn parse_bytes_forms() {
        assert_eq!(parse_bytes("1073741824"), Ok(1u64 << 30));
        assert_eq!(parse_bytes("1g"), Ok(1u64 << 30));
        assert_eq!(parse_bytes("4GiB"), Ok(4u64 << 30));
        assert_eq!(parse_bytes("512MiB"), Ok(512u64 << 20));
        assert_eq!(parse_bytes("300kb"), Ok(300 * 1024));
        assert_eq!(parse_bytes(" 2 T "), Ok(2u64 << 40));
        assert_eq!(parse_bytes("1.5k"), Ok(1536));
        assert_eq!(parse_bytes("0"), Ok(0));
        assert!(parse_bytes("").is_err());
        assert!(parse_bytes("g").is_err());
        assert!(parse_bytes("-4g").is_err());
        assert!(parse_bytes("4x").is_err());
    }

    #[test]
    fn bench_recorder_merges_across_opens() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!(
            "twobp_bench_rec_{}.json",
            std::process::id()
        ));
        let _ = std::fs::remove_file(&path);
        let mut a = BenchRecorder::open(&path);
        a.record_summary("alpha", &summarize(&[1.0, 2.0, 3.0]));
        a.write().unwrap();
        let mut b = BenchRecorder::open(&path);
        b.record("beta", crate::util::json::obj(vec![
            ("cells", Json::Num(100.0)),
            ("cells_per_sec", Json::Num(123.5)),
        ]));
        b.write().unwrap();
        let v = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(v.get("alpha").and_then(|a| a.get("n"))
                       .and_then(|n| n.as_u64()), Some(3));
        assert_eq!(v.get("beta").and_then(|b| b.get("cells"))
                       .and_then(|c| c.as_u64()), Some(100));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn stopwatch_accumulates() {
        let mut sw = Stopwatch::new();
        sw.time("a", || std::thread::sleep(Duration::from_millis(1)));
        sw.time("a", || ());
        sw.time("b", || ());
        let t = sw.totals();
        assert_eq!(t.len(), 2);
        assert_eq!(t[0].0, "a");
    }
}
