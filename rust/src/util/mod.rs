//! Utility substrates built from scratch for the offline environment
//! (no serde / clap / criterion / proptest / rand crates available — see
//! DESIGN.md §4 S14).

pub mod args;
pub mod gantt;
pub mod json;
pub mod prng;
pub mod proptest;
pub mod stats;
pub mod table;
pub mod trace;
