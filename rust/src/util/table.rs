//! ASCII table renderer for the bench harness output (every paper
//! table/figure is printed as rows through this).

pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
    title: Option<String>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Self {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            title: None,
        }
    }

    pub fn with_title(mut self, t: &str) -> Self {
        self.title = Some(t.to_string());
        self
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "column count mismatch");
        self.rows.push(cells);
        self
    }

    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> =
            self.headers.iter().map(|h| h.chars().count()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.chars().count());
            }
        }
        let sep = {
            let mut s = String::from("+");
            for w in &widths {
                s.push_str(&"-".repeat(w + 2));
                s.push('+');
            }
            s
        };
        let fmt_row = |cells: &[String]| {
            let mut s = String::from("|");
            for i in 0..ncol {
                let pad = widths[i] - cells[i].chars().count();
                s.push(' ');
                s.push_str(&cells[i]);
                s.push_str(&" ".repeat(pad + 1));
                s.push('|');
            }
            s
        };
        let mut out = String::new();
        if let Some(t) = &self.title {
            out.push_str(t);
            out.push('\n');
        }
        out.push_str(&sep);
        out.push('\n');
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for r in &self.rows {
            out.push_str(&fmt_row(r));
            out.push('\n');
        }
        out.push_str(&sep);
        out.push('\n');
        out
    }

    /// CSV form (for plotting outside).
    pub fn to_csv(&self) -> String {
        let esc = |c: &str| {
            if c.contains(',') || c.contains('"') {
                format!("\"{}\"", c.replace('"', "\"\""))
            } else {
                c.to_string()
            }
        };
        let mut out = self
            .headers
            .iter()
            .map(|h| esc(h))
            .collect::<Vec<_>>()
            .join(",");
        out.push('\n');
        for r in &self.rows {
            out.push_str(&r.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["model", "tput"]);
        t.row(vec!["transformer-7b".into(), "7120.88".into()]);
        t.row(vec!["bert".into(), "40427.41".into()]);
        let s = t.render();
        assert!(s.contains("| transformer-7b |"));
        let widths: Vec<usize> =
            s.lines().map(|l| l.chars().count()).collect();
        assert!(widths.windows(2).all(|w| w[0] == w[1]));
    }

    #[test]
    fn csv_escapes() {
        let mut t = Table::new(&["a"]);
        t.row(vec!["x,y".into()]);
        assert_eq!(t.to_csv(), "a\n\"x,y\"\n");
    }

    #[test]
    #[should_panic]
    fn wrong_arity_panics() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["only-one".into()]);
    }
}
