//! Minimal property-testing harness (substrate: proptest is unavailable
//! offline).  Runs a property over many PRNG-generated cases and, on
//! failure, retries with a simple halving shrink over the generator's
//! integer seeds to report a small counterexample.

use super::prng::SplitMix64;

/// Run `prop` over `cases` random inputs produced by `gen`.
/// Panics with the failing case's debug representation.
pub fn check<T: std::fmt::Debug>(
    name: &str,
    cases: usize,
    mut gen: impl FnMut(&mut SplitMix64) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    let mut rng = SplitMix64::new(0x2B9_2024);
    for i in 0..cases {
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property '{}' failed on case {}/{}:\n  input: {:?}\n  error: {}",
                name, i + 1, cases, input, msg
            );
        }
    }
}

/// Generator helpers.
pub mod gen {
    use super::SplitMix64;

    pub fn usize_in(rng: &mut SplitMix64, lo: usize, hi: usize) -> usize {
        lo + rng.below((hi - lo + 1) as u64) as usize
    }

    pub fn bool(rng: &mut SplitMix64) -> bool {
        rng.next_u64() & 1 == 1
    }

    pub fn pick<'a, T>(rng: &mut SplitMix64, xs: &'a [T]) -> &'a T {
        &xs[rng.below(xs.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_valid_property() {
        check("add-commutes", 100,
              |r| (r.below(1000), r.below(1000)),
              |&(a, b)| if a + b == b + a { Ok(()) } else { Err("!".into()) });
    }

    #[test]
    #[should_panic(expected = "always-fails")]
    fn reports_failure() {
        check("always-fails", 10, |r| r.below(10), |_| Err("boom".into()));
    }
}
