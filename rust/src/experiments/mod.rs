//! Experiment harness: one entry point per paper table/figure.
//!
//! Shared by the `twobp bench` CLI subcommand and the `cargo bench`
//! targets in `rust/benches/` (each bench target is a thin wrapper).
//! See DESIGN.md §5 for the experiment index.
//!
//! Pure-simulator experiments (`table1`, `fig1`, `schedule_space`, the
//! checkpoint ablation) always build; the measured ones (`fig3`–`fig5`,
//! `table3`, `fig6_fig7`) and the stub-backend end-to-end smoke
//! (`synthetic`) need the runtime and sit behind the `pjrt` feature
//! (which now builds offline against the vendored stub in
//! `vendor/xla-stub`).  Grid-shaped experiments fan their independent
//! sim cells out over [`sweep::run_grid`].

pub mod sweep;

use std::collections::HashMap;
use std::time::Instant;

use anyhow::{anyhow, Result};

#[cfg(feature = "pjrt")]
use anyhow::Context;
#[cfg(feature = "pjrt")]
use crate::config::{P2Mode, RunConfig, BENCH_PRESETS};
use crate::metrics::observer::{NullObserver, Observer};
#[cfg(feature = "pjrt")]
use crate::metrics::{memory_table, throughput_table, MemoryRow, ThroughputRow};
use crate::models::Manifest;
#[cfg(feature = "pjrt")]
use crate::pipeline::train;
use crate::schedule::{generate, validate::validate, ScheduleKind};
use crate::sim::{simulate, CostModel};
use crate::util::gantt;
use crate::util::table::Table;

/// Table 1: analytic bubble ratios vs simulated, for N = 2..16.
/// The (schedule × N) cells are independent sims — swept in parallel.
pub fn table1() -> String {
    let mut t = Table::new(&[
        "schedule", "N", "bubble (sim)", "bubble (paper formula)",
        "2BP bubble (sim)", "2BP bubble (formula)", "gain (sim)",
        "gain (formula)",
    ])
    .with_title("Table 1: bubble ratios and throughput gains \
                 (equal fwd/p1/p2 cost, sim vs closed form)");
    let cells: Vec<(ScheduleKind, usize)> = ScheduleKind::all()
        .into_iter()
        .flat_map(|kind| [2usize, 4, 8, 16].into_iter().map(move |n| (kind, n)))
        .collect();
    let rows = sweep::run_grid(
        &cells,
        sweep::default_threads(),
        |_, &(kind, n)| -> Vec<String> {
            let nf = n as f64;
            // paper closed forms
            let (b0f, b1f) = match kind {
                ScheduleKind::Naive => (
                    (nf - 1.0) / nf,
                    2.0 * (nf - 1.0) / (2.0 * nf + 1.0),
                ),
                ScheduleKind::GPipe => (
                    (nf - 1.0) / (2.0 * nf - 1.0),
                    2.0 * (nf - 1.0) / (2.0 * (nf - 1.0) + 3.0 * nf),
                ),
                ScheduleKind::OneF1B1 => (
                    (nf - 1.0) / (2.0 * nf - 1.0),
                    (nf - 1.0) / (nf - 1.0 + 3.0 * nf),
                ),
                ScheduleKind::OneF1B2 | ScheduleKind::OneF1B2EagerP2 => (
                    (nf - 1.0) / (3.0 * nf - 1.0),
                    (nf - 1.0) / (nf - 1.0 + 6.0 * nf),
                ),
            };
            let m = if kind == ScheduleKind::Naive { 1 } else { 0 };
            let sim_b = |two_bp: bool| -> f64 {
                let plan = generate(kind, two_bp, n, m, false);
                simulate(&plan, &CostModel::unit(n), None)
                    .expect("sim")
                    .bubble_ratio
            };
            let (b0, b1) = (sim_b(false), sim_b(true));
            vec![
                kind.name().into(),
                n.to_string(),
                format!("{b0:.4}"),
                format!("{b0f:.4}"),
                format!("{b1:.4}"),
                format!("{b1f:.4}"),
                format!("{:.3}x", (1.0 - b1) / (1.0 - b0)),
                format!("{:.3}x", (1.0 - b1f) / (1.0 - b0f)),
            ]
        },
    );
    for row in rows {
        t.row(row);
    }
    t.render()
}

/// Fig 1: ASCII schedule timelines for all schedules ± 2BP (unit costs).
pub fn fig1(n: usize, cols: usize) -> String {
    let mut out = String::new();
    for kind in ScheduleKind::all() {
        for two_bp in [false, true] {
            let m = if kind == ScheduleKind::Naive { 1 } else { 0 };
            let plan = generate(kind, two_bp, n, m, false);
            let res = simulate(&plan, &CostModel::unit(n), None).expect("sim");
            out.push_str(&format!(
                "--- {} ---  bubble ratio {:.3}\n",
                plan.describe(),
                res.bubble_ratio
            ));
            out.push_str(&gantt::render(&res.spans, cols));
            out.push('\n');
        }
    }
    out
}

/// Schedule-space exploration (the ROADMAP's "as many scenarios as you
/// can imagine", PipeDream-style): sweep every schedule variant ± 2BP
/// over a (ranks × microbatch-multiplier × cost-ratio × comm) grid in
/// parallel, and report, per variant, the bubble-ratio envelope and
/// where 2BP pays off the most against the fused-autograd baseline.
pub fn schedule_space(
    ranks: &[usize],
    m_mults: &[usize],
    threads: usize,
) -> String {
    let ratios = [(1.0, 1.0, 1.0), (1.0, 1.2, 0.8), (1.0, 0.6, 1.4)];
    let comms = [0.0, 0.1];
    let cells = sweep::grid(ranks, m_mults, &ratios, &comms);
    let threads = if threads == 0 {
        sweep::default_threads()
    } else {
        threads
    };
    let t0 = Instant::now();
    // Tier A scoring fast path: one reusable Scratch per worker
    let outs = sweep::run_grid_with(&cells, threads, crate::sim::Scratch::new,
                                    |s, _, c| sweep::eval_scored(c, s));
    let dt = t0.elapsed().as_secs_f64();

    // fused-autograd baselines for gain pairing, keyed by everything but
    // the 2BP flag (the eager variant's baseline is plain 1F1B-2)
    type Key = (&'static str, usize, usize, u64, u64, u64, u64);
    let key = |c: &sweep::Cell, kind: ScheduleKind| -> Key {
        (kind.name(), c.n_ranks, c.n_microbatches, c.fwd.to_bits(),
         c.p1.to_bits(), c.p2.to_bits(), c.comm.to_bits())
    };
    let mut base: HashMap<Key, f64> = HashMap::new();
    for (c, o) in cells.iter().zip(&outs) {
        if !c.two_bp {
            base.insert(key(c, c.kind), o.makespan);
        }
    }

    struct Agg {
        cells: usize,
        bubble_sum: f64,
        bubble_min: f64,
        min_cell: usize,
        best_gain: f64,
        best_gain_cell: Option<usize>,
    }
    let combos = sweep::combos();
    let mut aggs: Vec<Agg> = combos
        .iter()
        .map(|_| Agg {
            cells: 0,
            bubble_sum: 0.0,
            bubble_min: f64::INFINITY,
            min_cell: 0,
            best_gain: 0.0,
            best_gain_cell: None,
        })
        .collect();

    for (i, (c, o)) in cells.iter().zip(&outs).enumerate() {
        let slot = combos
            .iter()
            .position(|&(k, b)| k == c.kind && b == c.two_bp)
            .expect("cell outside combo set");
        let a = &mut aggs[slot];
        a.cells += 1;
        a.bubble_sum += o.bubble_ratio;
        if o.bubble_ratio < a.bubble_min {
            a.bubble_min = o.bubble_ratio;
            a.min_cell = i;
        }
        if c.two_bp {
            let base_kind = if c.kind == ScheduleKind::OneF1B2EagerP2 {
                ScheduleKind::OneF1B2
            } else {
                c.kind
            };
            if let Some(ms0) = base.get(&key(c, base_kind)) {
                let gain = ms0 / o.makespan;
                if gain > a.best_gain {
                    a.best_gain = gain;
                    a.best_gain_cell = Some(i);
                }
            }
        }
    }

    let mut t = Table::new(&[
        "schedule", "cells", "mean bubble", "min bubble", "best 2BP gain",
        "best-gain cell",
    ])
    .with_title("Schedule-space sweep: bubble envelope and 2BP payoff \
                 per schedule variant");
    for (slot, &(kind, two_bp)) in combos.iter().enumerate() {
        let a = &aggs[slot];
        if a.cells == 0 {
            continue;
        }
        t.row(vec![
            format!("{}{}", kind.name(), if two_bp { "+2bp" } else { "" }),
            a.cells.to_string(),
            format!("{:.4}", a.bubble_sum / a.cells as f64),
            format!("{:.4} ({})", a.bubble_min,
                    cells[a.min_cell].describe()),
            match a.best_gain_cell {
                Some(_) => format!("{:.3}x", a.best_gain),
                None => "-".into(),
            },
            match a.best_gain_cell {
                Some(i) => cells[i].describe(),
                None => "-".into(),
            },
        ]);
    }
    let mut out = t.render();
    out.push_str(&format!(
        "{} cells in {:.3}s — {:.0} cells/s on {} threads \
         (event-driven engine, scoring fast path)\n",
        cells.len(),
        dt,
        cells.len() as f64 / dt.max(1e-9),
        threads,
    ));
    out
}

/// Sweep a **directory of `.plan` files** — the DSL-file counterpart of
/// the generator-grid [`schedule_space`] (`twobp sweep --plans <dir>`).
/// Every `*.plan` file is parsed, fully validated once, and then
/// evaluated through the Tier A scoring fast path under the shared
/// `--fwd/--p1/--p2/--comm` cost shape (per-plan rank counts may
/// differ; each plan gets a cost model of its own width).  Files are
/// processed in name order and fan out over the parallel runner with
/// one `Scratch` per worker, so results are deterministic regardless
/// of thread count.
///
/// Unparseable or invalid files fail the sweep with the file named;
/// valid-but-deadlocked plans are reported per row rather than
/// aborting the rest (liveness is a property of the plan, and knowing
/// which plan in a corpus deadlocks is the point of sweeping it).
pub fn plan_space(
    dir: &std::path::Path,
    ratios: (f64, f64, f64),
    comm: f64,
    threads: usize,
) -> Result<String> {
    use crate::schedule::plan_io;
    use crate::schedule::Plan;

    let mut paths: Vec<std::path::PathBuf> = std::fs::read_dir(dir)
        .map_err(|e| anyhow!("reading {}: {e}", dir.display()))?
        .filter_map(|entry| entry.ok().map(|e| e.path()))
        .filter(|p| {
            p.extension().map(|ext| ext == "plan").unwrap_or(false)
        })
        .collect();
    paths.sort();
    if paths.is_empty() {
        return Err(anyhow!(
            "no .plan files in {} (write one with `twobp tune --out`, \
             grammar in docs/PLAN_FORMAT.md)",
            dir.display()
        ));
    }

    let mut cells: Vec<(String, Plan)> = Vec::with_capacity(paths.len());
    for path in &paths {
        let name = path
            .file_name()
            .map(|n| n.to_string_lossy().into_owned())
            .unwrap_or_else(|| path.display().to_string());
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("reading {}: {e}", path.display()))?;
        let plan = plan_io::parse(&text)
            .map_err(|e| anyhow!("{}: {e}", path.display()))?;
        // the one full validate of each plan's lifetime — after this
        // the scoring path may assume structural validity
        validate(&plan).map_err(|e| anyhow!("{}: {e}", path.display()))?;
        cells.push((name, plan));
    }

    let threads = if threads == 0 {
        sweep::default_threads()
    } else {
        threads
    };
    let (f, p1, p2) = ratios;
    let t0 = Instant::now();
    let outs = sweep::run_grid_with(
        &cells,
        threads,
        crate::sim::Scratch::new,
        |scratch, _, (_, plan)| {
            let mut cm = CostModel::ratios(plan.n_ranks, f, p1, p2);
            cm.comm = comm;
            crate::sim::score_plan(plan, &cm, None, None, scratch)
        },
    );
    let dt = t0.elapsed().as_secs_f64();

    let mut t = Table::new(&[
        "file", "plan", "ops", "makespan", "bubble", "note",
    ])
    .with_title(&format!(
        "Plan-file sweep: {} ({} plans, f:p1:p2={f}:{p1}:{p2} comm={comm}, \
         scoring fast path)",
        dir.display(),
        cells.len(),
    ));
    for ((name, plan), out) in cells.iter().zip(&outs) {
        match out {
            Ok(score) => t.row(vec![
                name.clone(),
                plan.describe(),
                plan.total_ops().to_string(),
                format!("{:.4}", score.makespan),
                format!("{:.4}", score.bubble_ratio),
                String::new(),
            ]),
            Err(e) => t.row(vec![
                name.clone(),
                plan.describe(),
                plan.total_ops().to_string(),
                "-".into(),
                "-".into(),
                format!("{e}"),
            ]),
        };
    }
    let mut out = t.render();
    out.push_str(&format!(
        "{} plans in {:.3}s on {} threads — render one with \
         `twobp gantt --plan <file>`\n",
        cells.len(),
        dt,
        threads,
    ));
    Ok(out)
}

/// Planner search (the tentpole of the `planner/` subsystem): tune the
/// LLaMa-like profile at `n_ranks` across a ladder of per-rank memory
/// budgets — from unconstrained down to well past the 2BP OOM boundary
/// (Fig 7's regime) — and report, per budget, the best *named*
/// (generator) schedule that fits next to the planner's winner.  Each
/// tune run fans its candidate evaluations out over
/// [`sweep::run_grid`]; the whole experiment is deterministic in
/// `seed`.
pub fn planner_search(n_ranks: usize, threads: usize, seed: u64) -> String {
    use crate::planner::{tune, BeamConfig, TuneProfile};
    use crate::util::stats::fmt_bytes;

    let profile = TuneProfile::llama_like(n_ranks);
    let cfg = |budget: Option<u64>| BeamConfig {
        budget_bytes: budget,
        seed,
        threads,
        ..BeamConfig::default()
    };

    let mut t = Table::new(&[
        "budget/rank", "best named (fits)", "named tput", "named peak",
        "planner winner", "tput", "peak", "gain",
    ])
    .with_title(&format!(
        "Planner search: memory-constrained schedule tuning \
         ({} profile, N={n_ranks}, samples/s; budgets derived from the \
         unconstrained winner's peak)",
        profile.name
    ));

    let unconstrained = match tune(&profile, n_ranks, &cfg(None)) {
        Ok(r) => r,
        Err(e) => return format!("planner_search failed: {e}\n"),
    };
    let full_peak = unconstrained.best.max_peak;
    let budgets: Vec<Option<u64>> = std::iter::once(None)
        .chain(
            [95u64, 85, 70, 55]
                .into_iter()
                .map(|pct| Some(full_peak * pct / 100)),
        )
        .collect();

    let mut out_lines: Vec<String> = Vec::new();
    for budget in budgets {
        let report = if budget.is_none() {
            Ok(unconstrained.clone())
        } else {
            tune(&profile, n_ranks, &cfg(budget))
        };
        let budget_str =
            budget.map(|b| fmt_bytes(b)).unwrap_or_else(|| "∞".into());
        match report {
            Err(_) => {
                t.row(vec![
                    budget_str, "-".into(), "-".into(), "-".into(),
                    "nothing fits".into(), "-".into(), "-".into(), "-".into(),
                ]);
            }
            Ok(r) => {
                let (nname, ntput, npeak) = match &r.named_best {
                    Some(nb) => (
                        nb.plan.describe(),
                        format!("{:.4}", nb.throughput),
                        fmt_bytes(nb.max_peak),
                    ),
                    None => ("-".into(), "-".into(), "-".into()),
                };
                t.row(vec![
                    budget_str,
                    nname,
                    ntput,
                    npeak,
                    format!("{} [{}]", r.best.plan.describe(), r.best.origin),
                    format!("{:.4}", r.best.throughput),
                    fmt_bytes(r.best.max_peak),
                    r.gain_vs_named()
                        .map(|g| format!("{g:.3}x"))
                        .unwrap_or_else(|| "-".into()),
                ]);
                out_lines.push(format!(
                    "  budget {}: {} evaluated, {} over budget, {} \
                     sim-rejected, {} generations",
                    budget.map(fmt_bytes).unwrap_or_else(|| "∞".into()),
                    r.evaluated, r.rejected_budget, r.rejected_sim,
                    r.generations_run,
                ));
            }
        }
    }
    let mut out = t.render();
    out.push_str("search effort per budget:\n");
    for line in out_lines {
        out.push_str(&line);
        out.push('\n');
    }
    out.push_str(
        "Reading: with memory to spare the planner matches or beats the \
         best named schedule via deeper microbatching; as the budget \
         tightens it inserts partial flush points (generalized Fig 5) to \
         stay under the OOM line while giving up as little throughput as \
         possible.  Export a winner with `twobp tune --out <file.plan>`.\n",
    );
    out
}

/// `twobp bench partition`: joint partition × schedule co-search over
/// the DP×PP divisor grid (the `planner/cosearch` subsystem) on a
/// pure-sim **skewed** per-layer model — layer 0 several times hotter
/// than its peers, so the balanced contiguous split is *not* optimal
/// and the boundary hill-climb has real work to do.  Deterministic in
/// `seed`.
pub fn partition_search(devices: usize, seed: u64) -> String {
    use crate::planner::{
        co_search, BeamConfig, CoSearchConfig, ModelProfile, TuneProfile,
    };
    use crate::util::stats::fmt_bytes;

    let layers = 2 * devices;
    let mut model =
        ModelProfile::from_profile(&TuneProfile::llama_like(layers));
    model.allreduce_per_byte = 2e-11;
    model.layers[0].fwd *= 5.0;
    model.layers[0].p1 *= 5.0;
    model.layers[0].p2 *= 5.0;
    let beam = BeamConfig { seed, ..BeamConfig::default() };
    let cfg = CoSearchConfig::new(devices, beam);
    let rep = match co_search(&model, &cfg, &mut NullObserver) {
        Ok(r) => r,
        Err(e) => return format!("partition_search failed: {e}\n"),
    };

    let mut t = Table::new(&[
        "dp × pp", "partition", "step time", "samples/s", "peak",
        "migrations",
    ])
    .with_title(&format!(
        "Partition co-search: {devices} devices over {layers} layers \
         (layer 0 ×5 hot; {} per-layer profile)",
        rep.model_name,
    ));
    for c in &rep.cells {
        t.row(vec![
            format!("{} × {}", c.dp, c.pp),
            c.partition.describe(),
            format!("{:.4}", c.step_time),
            format!("{:.4}", c.throughput),
            fmt_bytes(c.max_peak),
            c.migrations.to_string(),
        ]);
    }
    for (dp, pp, e) in &rep.infeasible {
        t.row(vec![
            format!("{dp} × {pp}"),
            format!("infeasible: {e}"),
            "-".into(),
            "-".into(),
            "-".into(),
            "-".into(),
        ]);
    }
    let mut out = t.render();
    let b = rep.best();
    out.push_str(&format!(
        "winner: dp={} pp={}  {}  [{}] — step time {:.4} = makespan \
         {:.4} + allreduce {:.4}\n",
        b.dp,
        b.pp,
        b.partition.describe(),
        b.candidate.plan.describe(),
        b.step_time,
        b.makespan,
        b.allreduce_s,
    ));
    out.push_str(
        "Reading: every cell starts from the balanced contiguous split; \
         deep-pipeline cells migrate layer boundaries off the hot layer \
         (migrations column), while dp cells trade pipeline bubble for a \
         gradient-allreduce term on their fattest stage.  Cells rank on \
         effective throughput dp·samples/step.  Export the winner with \
         `twobp tune --co-search --out <file.plan>`.\n",
    );
    out
}

/// `twobp bench robustness`: brittle-vs-robust tuning across a
/// perturbation grid.  The brittle winner optimizes the clean-world
/// makespan (one tune, perturbation-independent); per grid cell a
/// robust winner optimizes p95 makespan under that cell's seeded
/// jitter/straggler model ([`crate::planner::RobustObjective`]).  Both
/// winners are then evaluated under the *same* perturbation draws
/// (common random numbers, more trials than the search used), so the
/// p95 comparison is paired and honest — the robust column should win
/// or tie every cell, with the margin growing as the perturbation gets
/// nastier.
pub fn bench_robustness(threads: usize, seed: u64) -> String {
    use crate::planner::{tune, BeamConfig, RobustObjective, TuneProfile};
    use crate::sim::{score_plan_robust, Perturbation, RobustScratch};

    const TUNE_TRIALS: usize = 24;
    const EVAL_TRIALS: usize = 64;
    let n_ranks = 4;
    let profile = TuneProfile::llama_like(n_ranks);
    let beam = |robust: Option<RobustObjective>| BeamConfig {
        seed,
        threads,
        generations: 6,
        robust,
        ..BeamConfig::default()
    };
    let brittle = match tune(&profile, n_ranks, &beam(None)) {
        Ok(r) => r,
        Err(e) => return format!("bench robustness failed: {e}\n"),
    };

    let mut t = Table::new(&[
        "jitter", "straggler", "brittle winner", "brittle p95",
        "robust winner", "robust p95", "p95 ratio",
    ])
    .with_title(&format!(
        "Robustness sweep ({} profile, N={n_ranks}): mean-objective vs \
         p95-objective winners, both evaluated at {EVAL_TRIALS} common \
         perturbation draws",
        profile.name
    ));
    let mut wins = 0usize;
    let mut ties = 0usize;
    let mut cells = 0usize;
    let mut scratch = RobustScratch::new();
    for &jitter in &[0.03, 0.08] {
        for &mult in &[1.0f64, 1.5, 2.0] {
            let pert = Perturbation {
                jitter,
                stragglers: if mult == 1.0 {
                    Vec::new()
                } else {
                    vec![(1, mult)]
                },
                ..Perturbation::default()
            };
            let robust = match tune(
                &profile,
                n_ranks,
                &beam(Some(RobustObjective {
                    pert: pert.clone(),
                    trials: TUNE_TRIALS,
                })),
            ) {
                Ok(r) => r,
                Err(e) => return format!("bench robustness failed: {e}\n"),
            };
            let eval = |plan: &crate::schedule::Plan,
                        scratch: &mut RobustScratch| {
                score_plan_robust(
                    plan, &profile.costs, Some(&profile.mem), None, &pert,
                    EVAL_TRIALS, scratch,
                )
            };
            let bp = match eval(&brittle.best.plan, &mut scratch) {
                Ok(s) => s.p95,
                Err(e) => return format!("bench robustness failed: {e}\n"),
            };
            let rp = match eval(&robust.best.plan, &mut scratch) {
                Ok(s) => s.p95,
                Err(e) => return format!("bench robustness failed: {e}\n"),
            };
            cells += 1;
            if rp < bp {
                wins += 1;
            } else if rp == bp {
                ties += 1;
            }
            t.row(vec![
                format!("{jitter:.2}"),
                if mult == 1.0 {
                    "-".into()
                } else {
                    format!("r1 x{mult:.1}")
                },
                brittle.best.plan.describe(),
                format!("{bp:.4}"),
                robust.best.plan.describe(),
                format!("{rp:.4}"),
                format!("{:.4}", rp / bp),
            ]);
        }
    }
    let mut out = t.render();
    out.push_str(&format!(
        "robust objective wins (strictly lower p95) in {wins}/{cells} \
         cells, ties {ties} — paired draws (common random numbers), \
         p95 ratio < 1 favors robust\n",
    ));
    out.push_str(
        "Reading: under mild noise both objectives often pick the same \
         plan (a tie); once a straggler skews the cost surface the mean \
         objective keeps packing against the clean profile while the \
         p95 objective trades a little median makespan for tail \
         headroom.\n",
    );
    out
}

/// End-to-end smoke of the vendored stub backend (`twobp bench
/// synthetic`): generate a synthetic manifest in-process
/// (`models::synthetic`), drive the real executor through
/// (GPipe, 1F1B-1) × (±2BP) against one persistent cluster, verify
/// every run's executed op order and byte-exact memory accounting
/// against the simulator, and tabulate throughput + peak memory.
#[cfg(feature = "pjrt")]
pub fn synthetic_smoke(steps: usize) -> Result<String> {
    use crate::models::synthetic::{with_temp_artifacts, SyntheticSpec};
    use crate::pipeline::verify_report_against_sim;

    let spec = SyntheticSpec::tiny();
    let (rows, mem_rows) = with_temp_artifacts(
        "synth-smoke",
        &spec,
        |root, manifest| {
            let base = RunConfig {
                preset: spec.preset.clone(),
                artifacts: root.to_path_buf(),
                steps: steps.max(2),
                ..RunConfig::default()
            };
            let cluster = crate::pipeline::Cluster::new(&base)?;
            let mut rows = Vec::new();
            let mut mem_rows = Vec::new();
            for kind in [ScheduleKind::GPipe, ScheduleKind::OneF1B1] {
                let cell = |two_bp: bool| -> Result<(f64, u64)> {
                    let cfg =
                        RunConfig { schedule: kind, two_bp, ..base.clone() };
                    let report = cluster.run(&cfg)?;
                    verify_report_against_sim(&report, manifest, cfg.steps)
                        .with_context(|| {
                            format!("verifying {}", report.plan.describe())
                        })?;
                    Ok((report.simulated_throughput()?, report.max_peak()))
                };
                let (t0, m0) = cell(false)?;
                let (t1, m1) = cell(true)?;
                rows.push(ThroughputRow {
                    model: spec.preset.clone(),
                    schedule: kind.name().into(),
                    without_2bp: t0,
                    with_2bp: t1,
                });
                mem_rows.push(MemoryRow {
                    model: spec.preset.clone(),
                    schedule: kind.name().into(),
                    without_2bp: m0,
                    with_2bp: m1,
                });
            }
            Ok((rows, mem_rows))
        },
    )?;
    let mut out = throughput_table(
        &rows,
        "Synthetic stub smoke: throughput (stub op costs replayed through \
         the simulator; every run verified op-by-op against the sim)",
    )
    .render();
    out.push('\n');
    out.push_str(
        &memory_table(
            &mem_rows,
            "Synthetic stub smoke: max per-rank peak memory (byte-exact \
             accountant, replay-verified against Manifest::mem_model)",
        )
        .render(),
    );
    Ok(out)
}

/// One budget point of the measured-cost calibration loop: the beam
/// search ran against a **measured** profile, and the winning plan was
/// executed back on the real executor.
#[cfg(feature = "pjrt")]
#[derive(Debug)]
pub struct CalibratedTune {
    /// The beam-search report under the measured profile.
    pub report: crate::planner::TuneReport,
    /// The winner's one-step makespan under the calibration cost model
    /// (what the planner optimized), seconds.
    pub predicted_makespan: f64,
    /// Mean wall seconds per step of the real winner run, measured from
    /// its recorded spans (max span end − min span start across ranks,
    /// divided by the step count).
    pub executed_makespan: f64,
    /// The verified winner run itself — kept so callers can export its
    /// executed timeline (`RunReport::trace_spans`) next to the
    /// predicted one (`twobp tune --trace-out`).
    pub executed: crate::pipeline::RunReport,
}

/// Record one calibration pass into a metrics registry: per-stage
/// measured costs as `calib.stage` events, the loss/comm floors as
/// gauges, and run/step counters.  Every measured second hides under
/// `"wall"` (see [`crate::metrics::registry`]); the rank set, event
/// order, and counters are pure functions of the run shape.
#[cfg(feature = "pjrt")]
pub fn record_calibration(
    m: &mut dyn Observer,
    costs: &CostModel,
    steps: usize,
) {
    m.counter_add("calib.runs", 1);
    m.counter_add("calib.steps", steps as u64);
    for rank in 0..costs.fwd.len() {
        m.event_mixed(
            "calib.stage",
            vec![("rank", rank.into())],
            vec![
                ("fwd_s", costs.fwd[rank]),
                ("p1_s", costs.p1[rank]),
                ("p2_s", costs.p2[rank]),
                ("opt_s", costs.opt[rank]),
            ],
        );
    }
    m.gauge_set_wall("calib.loss_s", costs.loss);
    m.gauge_set_wall("calib.comm_floor_s", costs.comm);
}

#[cfg(feature = "pjrt")]
fn verdict_slug(v: crate::pipeline::Verdict) -> &'static str {
    use crate::pipeline::Verdict;
    match v {
        Verdict::Ok => "ok",
        Verdict::Drifting => "drifting",
        Verdict::Replan => "replan",
        Verdict::Exhausted => "exhausted",
    }
}

/// Record one drift observation (a measured step makespan judged
/// against the active plan's prediction) as a `drift.step` event plus
/// a `drift.verdict.*` counter bump.  Shared by the live replan loop
/// ([`tune_replan`]) and the passive path ([`record_passive_drift`]).
#[cfg(feature = "pjrt")]
fn record_drift_step(
    m: &mut dyn Observer,
    step: usize,
    measured: f64,
    predicted: f64,
    verdict: crate::pipeline::Verdict,
) {
    m.counter_add(&format!("drift.verdict.{}", verdict_slug(verdict)), 1);
    m.event_mixed(
        "drift.step",
        vec![
            ("step", step.into()),
            ("verdict", format!("{verdict:?}").into()),
        ],
        vec![
            ("measured_s", measured),
            ("predicted_s", predicted),
            ("ratio", measured / predicted.max(1e-12)),
        ],
    );
}

/// Passive drift telemetry for an already-executed run (the non-replan
/// calibrated path): replay its per-step makespans
/// ([`crate::pipeline::RunReport::step_makespans`]) through a
/// [`DriftMonitor`](crate::pipeline::DriftMonitor) against the
/// planner's predicted makespan, emitting the same `drift.step` events
/// and verdict counters the live loop does — without acting on any
/// verdict.  `drift.replan_events` is seeded at 0 so the key exists in
/// every run log that watched for drift.
#[cfg(feature = "pjrt")]
pub fn record_passive_drift(
    m: &mut dyn Observer,
    report: &crate::pipeline::RunReport,
    predicted: f64,
    cfg: crate::pipeline::DriftConfig,
) {
    let mut monitor = crate::pipeline::DriftMonitor::new(cfg, predicted);
    m.counter_add("drift.replan_events", 0);
    for (step, measured) in report.step_makespans().into_iter().enumerate() {
        let verdict = monitor.observe(measured);
        record_drift_step(m, step, measured, monitor.predicted(), verdict);
    }
}

/// Tune against an already-measured [`crate::planner::TuneProfile`]
/// (see `Cluster::calibrate` + `TuneProfile::from_measured`), then
/// close the loop: execute the winning plan back on the executor via
/// `Cluster::run_plan`, verify its op order + byte-exact memory
/// accounting against the simulator, and report predicted-vs-executed
/// makespan.  `exec_cfg` carries the winner run's step count, seed,
/// and data cycling (pass the calibration config with `steps`
/// overridden so the execution half sees the same data stream the
/// calibration measured); its schedule fields are ignored — the tuned
/// plan is the schedule.  Candidate evaluation inside the tune fans
/// out over the parallel sweep runner
/// ([`sweep::run_grid_with_pool`]).  Telemetry flows through the
/// [`Observer`] sink — pass a `MetricsRegistry` to record, a
/// [`NullObserver`] to run silent.
#[cfg(feature = "pjrt")]
pub fn tune_and_execute(
    cluster: &crate::pipeline::Cluster,
    manifest: &Manifest,
    profile: &crate::planner::TuneProfile,
    cfg: &crate::planner::BeamConfig,
    exec_cfg: &RunConfig,
    obs: &mut dyn Observer,
) -> Result<CalibratedTune> {
    use crate::pipeline::verify_report_against_sim;

    let report =
        crate::planner::TuneRequest::new(profile, manifest.n_stages,
                                         cfg.clone())
            .run(obs)
            .map_err(|e| anyhow!("planner: {e}"))?;
    let exec_steps = exec_cfg.steps.max(1);
    let exec_cfg = RunConfig { steps: exec_steps, ..exec_cfg.clone() };
    let exec = cluster.run_plan(&report.best.plan, &exec_cfg)?;
    verify_report_against_sim(&exec, manifest, exec_steps)
        .context("verifying the executed winner against the simulator")?;
    Ok(CalibratedTune {
        predicted_makespan: report.best.makespan,
        executed_makespan: step_makespan(&exec, exec_steps),
        executed: exec,
        report,
    })
}

/// Mean wall seconds per step measured from a run's recorded spans:
/// (max span end − min span start) across all ranks, over `steps`.
#[cfg(feature = "pjrt")]
fn step_makespan(report: &crate::pipeline::RunReport, steps: usize) -> f64 {
    let spans = report.spans();
    let t0 = spans
        .iter()
        .flatten()
        .map(|s| s.start)
        .fold(f64::INFINITY, f64::min);
    let t1 = spans.iter().flatten().map(|s| s.end).fold(0.0f64, f64::max);
    if t1 > t0 {
        (t1 - t0) / steps.max(1) as f64
    } else {
        0.0
    }
}

/// The calibration-loop experiment (`twobp bench tune-calibrated`):
/// generate the deliberately depth-imbalanced synthetic preset
/// ([`crate::models::synthetic::SyntheticSpec::skewed`] — per-stage
/// stub op costs skewed up to 4x), measure real per-stage costs with a
/// contention-free calibration run, tune against the measured profile
/// at an unconstrained and a binding budget, execute each winner back
/// on the executor, and tabulate predicted-vs-executed makespan.  The
/// budget rows run serially against the one shared cluster; each tune
/// fans its candidates out over the sweep runner.
#[cfg(feature = "pjrt")]
pub fn tune_calibrated(steps: usize) -> Result<String> {
    use crate::models::synthetic::{with_temp_artifacts, SyntheticSpec};
    use crate::planner::{BeamConfig, TuneProfile};
    use crate::util::stats::{fmt_bytes, fmt_duration};

    let spec = SyntheticSpec::skewed();
    with_temp_artifacts("tune-calib", &spec, |root, manifest| {
        let base = RunConfig {
            preset: spec.preset.clone(),
            artifacts: root.to_path_buf(),
            steps: steps.max(2),
            n_microbatches: manifest.n_stages,
            ..RunConfig::default()
        };
        let cluster = crate::pipeline::Cluster::new(&base)?;
        let (costs, _calib) = cluster.calibrate(&base)?;
        let profile = TuneProfile::from_measured(
            format!("measured:{}", manifest.preset),
            costs.clone(),
            manifest.mem_model(),
            manifest.samples_per_microbatch,
        )
        .map_err(|e| anyhow!(e))?;
        let beam = |budget: Option<u64>| BeamConfig {
            budget_bytes: budget,
            seed: 0x2B9,
            generations: 6,
            ..BeamConfig::default()
        };

        let mut rows: Vec<(Option<u64>, CalibratedTune)> = Vec::new();
        let un = tune_and_execute(&cluster, manifest, &profile,
                                  &beam(None), &base, &mut NullObserver)?;
        let full_peak = un.report.best.max_peak;
        rows.push((None, un));
        let budget = full_peak * 85 / 100;
        let bounded =
            tune_and_execute(&cluster, manifest, &profile,
                             &beam(Some(budget)), &base,
                             &mut NullObserver)?;
        rows.push((Some(budget), bounded));

        let mut t = Table::new(&[
            "budget/rank", "winner", "tput (samples/s)", "gain vs named",
            "predicted step", "executed step", "exec/pred",
        ])
        .with_title(&format!(
            "Calibrated tuning loop ({}, N={}): measured costs -> beam \
             search -> winner executed back on the stub executor",
            profile.name, manifest.n_stages,
        ));
        for (budget, ct) in &rows {
            let r = &ct.report;
            t.row(vec![
                budget.map(fmt_bytes).unwrap_or_else(|| "∞".into()),
                format!("{} [{}]", r.best.plan.describe(), r.best.origin),
                format!("{:.2}", r.best.throughput),
                r.gain_vs_named()
                    .map(|g| format!("{g:.3}x"))
                    .unwrap_or_else(|| "-".into()),
                fmt_duration(ct.predicted_makespan),
                fmt_duration(ct.executed_makespan),
                format!("{:.2}",
                        ct.executed_makespan
                            / ct.predicted_makespan.max(1e-12)),
            ]);
        }
        let mut out = t.render();
        out.push_str(&format!(
            "calibration ({} naive steps) measured fwd per stage: {} | \
             loss {:.2}ms\n",
            base.steps,
            costs
                .fwd
                .iter()
                .map(|c| format!("{:.2}ms", c * 1e3))
                .collect::<Vec<_>>()
                .join(" "),
            costs.loss * 1e3,
        ));
        out.push_str(
            "Reading: the winner is >= every named schedule under the \
             measured model by construction (all generator combos are \
             seeded); exec/pred near 1.0 means the schedule the planner \
             chose from measurements is the schedule the executor \
             actually runs — the executor→planner→executor circle, \
             closed offline on the stub backend.\n",
        );
        Ok(out)
    })
}

/// The self-healing calibration loop (`twobp tune --synthetic
/// --replan`, `twobp bench replan`): calibrate → tune → execute the
/// winner in one-step chunks, feeding each measured step makespan to a
/// [`DriftMonitor`](crate::pipeline::DriftMonitor).  The synthetic
/// preset is [`SyntheticSpec::skewed_drifting`]: the stub's `drift`
/// directive multiplies backward-p2 cost ×6 after a fixed call count,
/// so mid-run the measured makespan provably pulls away from the
/// prediction.  On [`Verdict::Replan`](crate::pipeline::Verdict) the
/// loop re-calibrates (measuring the *drifted* costs), re-tunes, swaps
/// the plan, and re-arms the monitor — bounded by the config's replan
/// budget, so a cluster that stays slow never thrashes the tuner.
/// After the chunked run the **stale** original winner is re-executed
/// under the same drifted costs; the replanned plan should beat it
/// (both tunes share one microbatch ceiling so step makespans compare
/// like for like).  The `replan events: N` line is the CI contract:
/// the drifting preset must trigger exactly one replan.
#[cfg(feature = "pjrt")]
pub fn tune_replan(
    steps: usize,
    drift_cfg: crate::pipeline::DriftConfig,
    obs: &mut dyn Observer,
) -> Result<String> {
    use crate::models::synthetic::{with_temp_artifacts, SyntheticSpec};
    use crate::pipeline::{verify_report_against_sim, DriftMonitor, Verdict};
    use crate::planner::{BeamConfig, TuneProfile};
    use crate::util::stats::fmt_duration;

    let spec = SyntheticSpec::skewed_drifting();
    let exec_steps = steps.max(8);
    with_temp_artifacts("tune-replan", &spec, move |root, manifest| {
        let base = RunConfig {
            preset: spec.preset.clone(),
            artifacts: root.to_path_buf(),
            steps: 2,
            n_microbatches: manifest.n_stages,
            ..RunConfig::default()
        };
        let cluster = crate::pipeline::Cluster::new(&base)?;
        // One shared microbatch ceiling: the initial and the post-drift
        // tune must pick from the same m grid, else the stale-vs-
        // replanned makespan comparison mixes batch sizes.
        let beam = BeamConfig {
            seed: 0x2B9,
            generations: 6,
            max_microbatches: 2 * manifest.n_stages,
            ..BeamConfig::default()
        };
        let retune = |label: &str,
                      obs: &mut dyn Observer|
         -> Result<crate::planner::TuneReport> {
            let (costs, _) = cluster.calibrate(&base)?;
            record_calibration(obs, &costs, base.steps);
            let profile = TuneProfile::from_measured(
                format!("measured:{}:{label}", manifest.preset),
                costs,
                manifest.mem_model(),
                manifest.samples_per_microbatch,
            )
            .map_err(|e| anyhow!(e))?;
            crate::planner::TuneRequest::new(&profile, manifest.n_stages,
                                             beam.clone())
                .run(obs)
                .map_err(|e| anyhow!("planner: {e}"))
        };

        let initial = retune("t0", &mut *obs)?;
        let stale_plan = initial.best.plan.clone();
        let mut plan = initial.best.plan.clone();
        let mut monitor = DriftMonitor::new(drift_cfg.clone(),
                                            initial.best.makespan);
        let chunk = RunConfig { steps: 1, ..base.clone() };

        let mut t = Table::new(&[
            "step", "plan", "measured", "predicted", "ratio", "verdict",
        ])
        .with_title(&format!(
            "Drift replan loop ({}, N={}): per-step makespan vs the \
             active plan's prediction (threshold {:.0}%, window {}, \
             replan budget {})",
            manifest.preset,
            manifest.n_stages,
            drift_cfg.threshold * 100.0,
            drift_cfg.window,
            drift_cfg.max_replans,
        ));
        let mut post: Vec<f64> = Vec::new();
        let mut retuned: Option<crate::planner::TuneReport> = None;
        let mut verify_next = true;
        for step in 0..exec_steps {
            let rep = cluster.run_plan(&plan, &chunk)?;
            if verify_next {
                // op order + byte-exact memory accounting of the active
                // plan, once per plan swap (drift moves timing, never
                // structure, so one check per plan suffices)
                verify_report_against_sim(&rep, manifest, 1)
                    .context("verifying the active plan on the executor")?;
                verify_next = false;
            }
            let measured = step_makespan(&rep, 1);
            let verdict = monitor.observe(measured);
            obs.counter_add("drift.replan_events", 0);
            record_drift_step(
                &mut *obs, step, measured, monitor.predicted(), verdict,
            );
            if verdict == Verdict::Replan {
                obs.counter_add("drift.replan_events", 1);
            }
            t.row(vec![
                step.to_string(),
                plan.describe(),
                fmt_duration(measured),
                fmt_duration(monitor.predicted()),
                format!("{:.2}",
                        measured / monitor.predicted().max(1e-12)),
                format!("{verdict:?}"),
            ]);
            if retuned.is_some() {
                post.push(measured);
            }
            if verdict == Verdict::Replan {
                let report =
                    retune(&format!("t{}", step + 1), &mut *obs)?;
                plan = report.best.plan.clone();
                monitor.rearm(report.best.makespan);
                retuned = Some(report);
                verify_next = true;
            }
        }

        let mut out = t.render();
        out.push_str(&format!("replan events: {}\n", monitor.replans()));
        match (&retuned, post.is_empty()) {
            (Some(report), false) => {
                let stale_steps = 3usize;
                let stale = cluster.run_plan(
                    &stale_plan,
                    &RunConfig { steps: stale_steps, ..base.clone() },
                )?;
                let stale_ms = step_makespan(&stale, stale_steps);
                let post_ms =
                    post.iter().sum::<f64>() / post.len() as f64;
                let tput = |p: &crate::schedule::Plan, ms: f64| {
                    manifest.samples_per_microbatch as f64
                        * p.n_microbatches as f64
                        / ms.max(1e-12)
                };
                out.push_str(&format!(
                    "stale plan under drifted costs:  {} /step \
                     ({:.2} samples/s) [{}]\n",
                    fmt_duration(stale_ms),
                    tput(&stale_plan, stale_ms),
                    stale_plan.describe(),
                ));
                out.push_str(&format!(
                    "replanned plan, same costs:      {} /step \
                     ({:.2} samples/s) [{}]\n",
                    fmt_duration(post_ms),
                    tput(&report.best.plan, post_ms),
                    report.best.plan.describe(),
                ));
                out.push_str(&format!(
                    "post-replan speedup vs stale: {:.2}x\n",
                    stale_ms / post_ms.max(1e-12),
                ));
            }
            (Some(_), true) => out.push_str(
                "replan fired on the final step — no post-replan steps \
                 to compare; raise the step count\n",
            ),
            (None, _) => out.push_str(
                "no drift detected — initial plan kept for the whole \
                 run\n",
            ),
        }
        Ok(out)
    })
}

/// The fault-recovery harness (`twobp bench faults`): for every
/// (rank × kind) cell, inject a deterministic fault into one rank's
/// forward stage at step 1 via the stub's `fault` directive, assert the
/// cluster fails **fast** with the typed [`RunError`] the supervision
/// layer promises, salvage the last complete per-rank checkpoint set
/// from the wreck, resume on clean artifacts, and prove the recovered
/// parameters are bit-identical to an uninterrupted reference run
/// (`RunReport::param_digests`).
///
/// Determinism contract for the metrics log (CI diffs two same-seed
/// runs): `fault.cell` events carry only the **injected** rank/step and
/// the detected failure *kind* — never the detecting rank, because for
/// a stall either neighbor of the stalled rank may hit its deadline
/// first.  Detection latency, recovery overhead, and goodput are
/// wall-clock and hide under `"wall"` (docs/OBSERVABILITY.md).
#[cfg(feature = "pjrt")]
pub fn fault_sweep(
    steps: usize,
    obs: &mut dyn Observer,
) -> Result<String> {
    use anyhow::{bail, ensure};

    use crate::models::synthetic::{
        with_temp_artifacts, write_artifacts, StubFaultSpec, SyntheticSpec,
    };
    use crate::pipeline::{checkpoint, Cluster, RunError};
    use crate::util::stats::fmt_duration;

    let spec = SyntheticSpec::tiny();
    let total_steps = steps.max(3);
    with_temp_artifacts("faults", &spec, |root, manifest| {
        let n = manifest.n_stages;
        let base = RunConfig {
            preset: spec.preset.clone(),
            artifacts: root.to_path_buf(),
            steps: total_steps,
            ..RunConfig::default()
        };
        let m = base.microbatches(n);
        // Step 1's first forward is call `m` (0-based; calls 0..m are
        // step 0's microbatches): late enough that every rank finishes
        // step 0 — and checkpoints it — before anyone can observe the
        // failure, so the salvaged step count is deterministic.
        let fault_step = 1usize;
        let fault_call = (m * fault_step) as u64;

        // The uninterrupted reference: the bit pattern every recovered
        // run must reproduce.  The clean cluster is reused for the
        // recovery legs (the *faulty* cluster is poisoned and rebuilt
        // per cell, which is the real recovery story).
        let clean = Cluster::new(&base)?;
        let reference = clean.run(&base)?.param_digests();

        let kinds =
            [("fail", "fail".to_string()),
             ("stall", format!("stall-{}", 1_000_000_000u64))];
        let mut t = Table::new(&[
            "cell", "injected", "detected as", "observed at", "ckpt step",
            "detect", "recover", "params",
        ])
        .with_title(&format!(
            "Fault-recovery sweep ({}, N={n}, m={m}): inject at step \
             {fault_step}, fail fast, resume from the salvaged \
             checkpoint, verify bit-identical parameters vs a clean \
             {total_steps}-step run",
            spec.preset,
        ));
        let mut cell_idx = 0usize;
        let mut goodputs = Vec::new();
        for rank in [1, n / 2] {
            for (kind_slug, directive_kind) in &kinds {
                let fault = StubFaultSpec {
                    rank,
                    kind: directive_kind.clone(),
                    at_call: fault_call,
                };
                let faulty_spec = SyntheticSpec::tiny_faulty(fault);
                // overwrites the previous cell's faulty preset in full,
                // so exactly one fwd stage carries a directive at a time
                write_artifacts(root, &faulty_spec)?;
                let ckpt_dir = root.join(format!("ckpt-c{cell_idx}"));
                let faulty_cfg = RunConfig {
                    preset: faulty_spec.preset.clone(),
                    checkpoint_every: 1,
                    checkpoint_dir: Some(ckpt_dir.clone()),
                    comm_timeout_ms: 200,
                    ..base.clone()
                };
                let faulty = Cluster::new(&faulty_cfg)?;
                let t0 = Instant::now();
                let err = match faulty.run(&faulty_cfg) {
                    Ok(_) => bail!(
                        "cell {cell_idx}: injected {kind_slug} on rank \
                         {rank} but the run succeeded"
                    ),
                    Err(e) => e,
                };
                let detect_s = t0.elapsed().as_secs_f64();
                let run_err = err
                    .downcast_ref::<RunError>()
                    .cloned()
                    .ok_or_else(|| anyhow!(
                        "cell {cell_idx}: failure was not a typed \
                         RunError: {err:#}"
                    ))?;
                let detected_as = match (*kind_slug, &run_err) {
                    ("fail", RunError::RankFailed { rank: r, step, .. }) => {
                        ensure!(
                            *r == rank && *step == fault_step,
                            "cell {cell_idx}: injected fail on rank \
                             {rank} step {fault_step}, detected {run_err}"
                        );
                        "rank_failed"
                    }
                    // which neighbor of the stalled rank hits its
                    // deadline first is a race — assert the kind only
                    ("stall", RunError::CommTimeout { .. }) => "comm_timeout",
                    _ => bail!(
                        "cell {cell_idx}: injected {kind_slug}, got the \
                         wrong failure class: {run_err}"
                    ),
                };
                let resume_dir = checkpoint::resolve_resume_dir(&ckpt_dir)
                    .with_context(|| format!(
                        "cell {cell_idx}: no checkpoint salvaged from \
                         the failed run"
                    ))?;
                let steps_before = checkpoint::load(&resume_dir, n)?[0].step;
                ensure!(
                    steps_before == fault_step,
                    "cell {cell_idx}: salvaged {steps_before} steps, \
                     expected {fault_step}"
                );
                let t1 = Instant::now();
                let recovery_cfg = RunConfig {
                    steps: total_steps - steps_before,
                    resume: Some(resume_dir),
                    ..base.clone()
                };
                let recovered = clean.run(&recovery_cfg)?;
                let recovery_s = t1.elapsed().as_secs_f64();
                ensure!(
                    recovered.param_digests() == reference,
                    "cell {cell_idx}: recovered parameters diverge from \
                     the uninterrupted reference run"
                );
                let goodput =
                    total_steps as f64 / (detect_s + recovery_s).max(1e-12);
                goodputs.push(goodput);
                obs.counter_add("fault.cells", 1);
                obs.counter_add(
                    &format!("fault.injected.{kind_slug}"), 1);
                obs.counter_add(
                    &format!("fault.detected.{detected_as}"), 1);
                obs.counter_add("fault.recovered", 1);
                if obs.enabled() {
                    obs.event_mixed(
                        "fault.cell",
                        vec![
                            ("cell", cell_idx.into()),
                            ("rank", rank.into()),
                            ("step", fault_step.into()),
                            // "kind" would collide with the line's own
                            // kind=event discriminator — duplicate JSON
                            // keys — so the injected kind gets its own
                            // field name
                            ("injected", (*kind_slug).into()),
                            ("detected_as", detected_as.into()),
                            ("steps_before", steps_before.into()),
                            ("recovered", true.into()),
                        ],
                        vec![
                            ("detect_s", detect_s),
                            ("recovery_s", recovery_s),
                            ("goodput_steps_per_s", goodput),
                        ],
                    );
                }
                t.row(vec![
                    cell_idx.to_string(),
                    format!("r{rank} {kind_slug}@step {fault_step}"),
                    detected_as.to_string(),
                    // human-facing only: for stalls this names the racy
                    // *detecting* rank, which never enters the metrics
                    format!("r{} step {}", run_err.rank(), run_err.step()),
                    steps_before.to_string(),
                    fmt_duration(detect_s),
                    fmt_duration(recovery_s),
                    "bit-identical".into(),
                ]);
                cell_idx += 1;
            }
        }
        let mut out = t.render();
        out.push_str(&format!(
            "all {cell_idx} cells recovered to the reference digests; \
             mean goodput {:.1} steps/s (detect + resume wall time)\n",
            goodputs.iter().sum::<f64>() / goodputs.len().max(1) as f64,
        ));
        Ok(out)
    })
}

/// Per-preset measured run for one (schedule, 2bp) cell against a
/// persistent cluster: trains for `steps` real steps and returns
/// (throughput samples/s via calibrated replay, max per-rank peak bytes).
#[cfg(feature = "pjrt")]
fn run_cell(
    cluster: &crate::pipeline::Cluster,
    preset: &str,
    kind: ScheduleKind,
    two_bp: bool,
    steps: usize,
    p2_mode: P2Mode,
) -> Result<(f64, u64)> {
    let cfg = RunConfig {
        preset: preset.into(),
        schedule: kind,
        two_bp,
        steps,
        p2_mode,
        ..RunConfig::default()
    };
    let report = cluster.run(&cfg)?;
    Ok((report.simulated_throughput()?, report.max_peak()))
}

#[cfg(feature = "pjrt")]
fn cluster_for(preset: &str) -> Result<crate::pipeline::Cluster> {
    crate::pipeline::Cluster::new(&RunConfig {
        preset: preset.into(),
        ..RunConfig::default()
    })
}

/// Fig 3: sample throughput for the four models × four schedules ± 2BP.
///
/// Methodology note (single-core host): per-op costs are measured once
/// per preset under the *naive* schedule, whose ops never overlap across
/// ranks — measuring inside overlapped schedules double-counts CPU
/// contention between rank threads and biases exactly the schedules 2BP
/// helps.  The calibrated costs (real f:p1:p2 ratios per rank) are then
/// replayed through every schedule ± 2BP; the real runs still execute
/// (memory accounting + correctness), only their *timing* is taken from
/// the clean calibration.  See DESIGN.md §3.
#[cfg(feature = "pjrt")]
pub fn fig3(steps: usize, presets: &[&str]) -> Result<String> {
    let mut rows = Vec::new();
    let mut mem_rows = Vec::new();
    for preset in presets {
        eprintln!("[fig3] building cluster for {preset}...");
        let cluster = cluster_for(preset)?;
        eprintln!("[fig3] {preset}: calibrating op costs (naive)...");
        let calib = cluster.run(&RunConfig {
            preset: preset.to_string(),
            schedule: ScheduleKind::Naive,
            two_bp: false,
            steps: steps.max(2),
            ..RunConfig::default()
        })?;
        let costs = calib.measured_costs()?;
        let samples = cluster.manifest().samples_per_microbatch;
        for kind in ScheduleKind::all() {
            eprintln!("[fig3] {preset} / {}", kind.name());
            let mut cell = |two_bp: bool| -> Result<(f64, u64)> {
                let cfg = RunConfig {
                    preset: preset.to_string(),
                    schedule: kind,
                    two_bp,
                    steps,
                    ..RunConfig::default()
                };
                let report = cluster.run(&cfg)?;
                let plan = &report.plan;
                let sim = simulate(plan, &costs, None)
                    .map_err(|e| anyhow!("{e}"))?;
                Ok((sim.throughput(samples, plan.n_microbatches),
                    report.max_peak()))
            };
            let (t0, m0) = cell(false)?;
            let (t1, m1) = cell(true)?;
            rows.push(ThroughputRow {
                model: preset.to_string(),
                schedule: kind.name().into(),
                without_2bp: t0,
                with_2bp: t1,
            });
            mem_rows.push(MemoryRow {
                model: preset.to_string(),
                schedule: kind.name().into(),
                without_2bp: m0,
                with_2bp: m1,
            });
        }
    }
    let mut out = throughput_table(
        &rows,
        "Fig 3: sample throughput (samples/s, measured op costs replayed \
         through the pipeline simulator)",
    )
    .render();
    out.push('\n');
    out.push_str(
        &memory_table(
            &mem_rows,
            "Fig 4: max per-rank peak memory (byte-exact stash accounting \
             from the same runs)",
        )
        .render(),
    );
    Ok(out)
}

/// Fig 4 standalone (memory only, all four models).
#[cfg(feature = "pjrt")]
pub fn fig4(steps: usize, presets: &[&str]) -> Result<String> {
    let mut mem_rows = Vec::new();
    for preset in presets {
        eprintln!("[fig4] building cluster for {preset}...");
        let cluster = cluster_for(preset)?;
        for kind in ScheduleKind::all() {
            let (_, m0) =
                run_cell(&cluster, preset, kind, false, steps, P2Mode::Loop)?;
            let (_, m1) =
                run_cell(&cluster, preset, kind, true, steps, P2Mode::Loop)?;
            mem_rows.push(MemoryRow {
                model: preset.to_string(),
                schedule: kind.name().into(),
                without_2bp: m0,
                with_2bp: m1,
            });
        }
    }
    Ok(memory_table(&mem_rows, "Fig 4: max per-rank peak memory").render())
}

/// Fig 5: eager-p2 1F1B-2 variant vs plain 1F1B-2 (+2BP) memory.
#[cfg(feature = "pjrt")]
pub fn fig5(steps: usize, preset: &str) -> Result<String> {
    let cluster = cluster_for(preset)?;
    let (t_plain, m_plain) = run_cell(
        &cluster, preset, ScheduleKind::OneF1B2, true, steps, P2Mode::Loop)?;
    let (t_eager, m_eager) = run_cell(
        &cluster, preset, ScheduleKind::OneF1B2EagerP2, true, steps,
        P2Mode::Loop)?;
    let (_, m_base) = run_cell(
        &cluster, preset, ScheduleKind::OneF1B2, false, steps, P2Mode::Loop)?;
    let mut t = Table::new(&["variant", "samples/s", "max peak bytes",
                             "peak vs non-2BP"])
        .with_title(&format!(
            "Fig 5: memory-efficient eager-p2 schedule ({preset})"));
    t.row(vec!["1f1b-2 (no 2BP)".into(), "-".into(),
               m_base.to_string(), "1.00x".into()]);
    t.row(vec!["1f1b-2 + 2BP".into(), format!("{t_plain:.2}"),
               m_plain.to_string(),
               format!("{:.2}x", m_plain as f64 / m_base as f64)]);
    t.row(vec!["1f1b-2 + 2BP eager-p2".into(), format!("{t_eager:.2}"),
               m_eager.to_string(),
               format!("{:.2}x", m_eager as f64 / m_base as f64)]);
    Ok(t.render())
}

/// Table 3: concat vs loop backward-p2 under 1F1B-1 + 2BP.
#[cfg(feature = "pjrt")]
pub fn table3(steps: usize, presets: &[&str]) -> Result<String> {
    let mut t = Table::new(&["model", "tput w/ concat", "tput w/o concat",
                             "ratio"])
        .with_title("Table 3: average throughput with and without \
                     concatenating microbatches during backward-p2 \
                     (1F1B-1 + 2BP)");
    for preset in presets {
        eprintln!("[table3] building cluster for {preset}...");
        let cluster = cluster_for(preset)?;
        let (tc, _) = run_cell(&cluster, preset, ScheduleKind::OneF1B1, true,
                               steps, P2Mode::Concat)?;
        let (tl, _) = run_cell(&cluster, preset, ScheduleKind::OneF1B1, true,
                               steps, P2Mode::Loop)?;
        t.row(vec![
            preset.to_string(),
            format!("{tc:.2}"),
            format!("{tl:.2}"),
            format!("{:.3}", tc / tl),
        ]);
    }
    Ok(t.render())
}

/// Figs 6/7: scaling. Uses measured per-op costs from a real N=4 run of
/// `preset`, then scales block counts per stage in the simulator:
/// fixed-size (32 blocks split over N) and variable-size (8 blocks per
/// stage), with an inter-node comm penalty above 4 ranks/node.  The
/// (figure × schedule × N) sim cells run in parallel via the sweep
/// runner; only the one calibration run is serial.
#[cfg(feature = "pjrt")]
pub fn fig6_fig7(steps: usize, preset: &str) -> Result<String> {
    // calibrate per-block costs from a real contention-free (naive) run
    let cfg = RunConfig {
        preset: preset.into(),
        schedule: ScheduleKind::Naive,
        two_bp: false,
        steps: steps.max(2),
        ..RunConfig::default()
    };
    let report = train(&cfg)?;
    let measured = report.measured_costs()?;
    let manifest = Manifest::load(&cfg.artifacts, preset)?;
    // blocks per stage in the calibration preset
    let blocks_total = manifest
        .stages
        .iter()
        .map(|s| {
            s.params
                .iter()
                .filter_map(|p| p.name.as_deref())
                .filter(|n| n.contains("block") && n.ends_with("attn/wq"))
                .count()
        })
        .collect::<Vec<_>>();
    let blocks_cal: f64 = blocks_total.iter().sum::<usize>() as f64
        / blocks_total.len() as f64;
    let per_block = |xs: &[f64]| -> f64 {
        xs.iter().sum::<f64>() / xs.len() as f64 / blocks_cal.max(1.0)
    };
    let (f_b, p1_b, p2_b) = (
        per_block(&measured.fwd),
        per_block(&measured.p1),
        per_block(&measured.p2),
    );
    // comm cost: activation bytes / assumed 10 GB/s intra-node link
    let act_bytes = manifest.stages[0].bytes.activation as f64;
    let comm = act_bytes / 10e9;
    let comm_inter = act_bytes / 1e9; // 10x slower across nodes

    let mut t = Table::new(&["figure", "schedule", "N", "blocks/stage",
                             "tput", "tput +2BP", "gain", "note"])
        .with_title(&format!(
            "Figs 6/7: scaling (per-block costs calibrated from {preset}: \
             f={f_b:.2e}s p1={p1_b:.2e}s p2={p2_b:.2e}s/block)"));
    let mem = manifest.mem_model();

    let mut sim_cells: Vec<(&'static str, bool, ScheduleKind, usize)> =
        Vec::new();
    for (figure, fixed) in [("fig6-fixed", true), ("fig7-variable", false)] {
        for kind in [ScheduleKind::OneF1B1, ScheduleKind::OneF1B2] {
            for n in [4usize, 8, 16] {
                sim_cells.push((figure, fixed, kind, n));
            }
        }
    }
    let rows = sweep::run_grid(
        &sim_cells,
        sweep::default_threads(),
        |_, &(figure, fixed, kind, n)| -> Result<Vec<String>> {
            let blocks_per_stage = if fixed { (32 + n - 1) / n } else { 8 };
            let scale = blocks_per_stage as f64;
            let cm = CostModel {
                fwd: vec![f_b * scale; n],
                p1: vec![p1_b * scale; n],
                p2: vec![p2_b * scale; n],
                opt: vec![measured.opt[0]; n],
                loss: 0.0,
                comm,
                comm_inter_node: comm_inter,
                ranks_per_node: 4,
                concat_factor: 1.0,
            };
            let mm = crate::sim::MemModel {
                static_bytes: vec![
                    (mem.static_bytes.iter().sum::<u64>() as f64
                        / mem.static_bytes.len() as f64
                        * scale / blocks_cal) as u64; n],
                res1: vec![(mem.res1[0] as f64 * scale
                    / blocks_cal.max(1.0)) as u64; n],
                res2: vec![(mem.res2[0] as f64 * scale
                    / blocks_cal.max(1.0)) as u64; n],
                inter: vec![(mem.inter[0] as f64 * scale
                    / blocks_cal.max(1.0)) as u64; n],
            };
            let samples = manifest.samples_per_microbatch;
            let run = |two_bp: bool| -> Result<(f64, u64)> {
                let plan = generate(kind, two_bp, n, 0, false);
                validate(&plan).map_err(|e| anyhow!("{e}"))?;
                let res = simulate(&plan, &cm, Some(&mm))
                    .map_err(|e| anyhow!("{e}"))?;
                Ok((res.throughput(samples, plan.n_microbatches),
                    res.max_peak()))
            };
            let (t0, _) = run(false)?;
            let (t1, peak1) = run(true)?;
            // Fig 7's OOM: 16 GB per device at paper scale; flag when
            // the scaled stash exceeds a 2 GiB budget on this scale
            let oom = !fixed && peak1 > 2 * (1 << 30);
            Ok(vec![
                figure.into(),
                kind.name().into(),
                n.to_string(),
                blocks_per_stage.to_string(),
                format!("{t0:.2}"),
                if oom { "OOM".into() } else { format!("{t1:.2}") },
                if oom { "-".into() }
                else { format!("{:.2}x", t1 / t0) },
                if oom { "stash exceeds budget (paper: OOM at N=16)".into() }
                else { String::new() },
            ])
        },
    );
    for row in rows {
        t.row(row?);
    }
    Ok(t.render())
}

/// `twobp bench <exp>` dispatcher (telemetry-free: runs every
/// experiment against a [`NullObserver`]).
pub fn run_experiment(name: &str, steps: usize) -> Result<String> {
    run_experiment_with(name, steps, &mut NullObserver)
}

/// [`run_experiment`] with a metrics [`Observer`] (`twobp bench faults
/// --metrics-out` passes the registry); experiments that record
/// nothing ignore it.
pub fn run_experiment_with(
    name: &str,
    steps: usize,
    obs: &mut dyn Observer,
) -> Result<String> {
    let _ = &obs;
    match name {
        "table1" => Ok(table1()),
        "fig1" => Ok(fig1(4, 96)),
        "sweep" | "schedule-space" => {
            Ok(schedule_space(&[2, 4, 8, 16, 32], &[1, 2], 0))
        }
        "planner" | "planner-search" => Ok(planner_search(4, 0, 0x2B9)),
        "partition" | "cosearch" | "co-search" => {
            Ok(partition_search(4, 0x2B9))
        }
        "robustness" | "robust" => Ok(bench_robustness(0, 0x2B9)),
        "ckpt" | "ablation" => ablation_checkpoint("bert-s", 4),
        #[cfg(feature = "pjrt")]
        "synthetic" | "stub" => synthetic_smoke(steps),
        #[cfg(feature = "pjrt")]
        "tune-calibrated" | "tune_calibrated" => tune_calibrated(steps),
        #[cfg(feature = "pjrt")]
        "replan" | "drift" => tune_replan(
            steps,
            crate::pipeline::DriftConfig::default(),
            &mut NullObserver,
        ),
        #[cfg(feature = "pjrt")]
        "faults" | "fault" => fault_sweep(steps, obs),
        #[cfg(feature = "pjrt")]
        "fig3" | "fig4" => fig3(steps, &BENCH_PRESETS.to_vec()),
        #[cfg(feature = "pjrt")]
        "fig5" => fig5(steps, "bert-s"),
        #[cfg(feature = "pjrt")]
        "table3" => table3(steps, &BENCH_PRESETS.to_vec()),
        #[cfg(feature = "pjrt")]
        "fig6" | "fig7" | "scaling" => fig6_fig7(steps, "bert-scale-fixed"),
        #[cfg(not(feature = "pjrt"))]
        "synthetic" | "stub" | "tune-calibrated" | "tune_calibrated"
        | "replan" | "drift" | "faults" | "fault" | "fig3" | "fig4"
        | "fig5" | "table3" | "fig6" | "fig7" | "scaling" => {
            let _ = steps;
            Err(anyhow!(
                "experiment '{name}' needs the real runtime; rebuild with \
                 `--features pjrt` (built offline against the vendored \
                 stub backend in vendor/xla-stub)"
            ))
        }
        other => Err(anyhow!("unknown experiment '{other}' \
            (table1|fig1|synthetic|tune-calibrated|replan|faults|\
             robustness|fig3|fig4|fig5|table3|fig6|fig7|ckpt|sweep|\
             planner|partition)")),
    }
}

/// §5 ablation — intermediate-derivative checkpointing (the paper's
/// first proposed future-work memory mitigation): instead of stashing
/// the intermediate derivatives ∂L/∂z between p1 and p2, recompute them
/// during p2 ("applied to the intermediate derivates ... recalculations
/// could potentially be overlapped with idle compute").
///
/// Model: checkpointing drops `inter` from the stash (memory) and adds
/// a recompute surcharge to every p2 — `p2' = p2 + α·p1`, where α is
/// the share of backward-p1 that must be replayed to rebuild the
/// intermediates.  Sweeping α maps the throughput/memory trade-off the
/// paper wants to investigate, using the same calibrated byte classes
/// and the 1F1B-2 + 2BP schedule (its worst memory case).  The α cells
/// are independent sims and run through the parallel sweep runner.
pub fn ablation_checkpoint(preset: &str, n: usize) -> Result<String> {
    let manifest = Manifest::load(std::path::Path::new("artifacts"), preset)?;
    let mem = manifest.mem_model();
    let base_costs = manifest.cost_model_from_flops(0.0);
    let samples = manifest.samples_per_microbatch;

    let mut t = Table::new(&["alpha (recompute share)", "tput (samples/s)",
                             "tput vs no-ckpt", "max peak", "peak vs no-ckpt"])
        .with_title(&format!(
            "§5 ablation: intermediate-derivative checkpointing under \
             1f1b-2+2bp ({preset}, N={n}; costs/bytes from the manifest)"));

    let plan = generate(ScheduleKind::OneF1B2, true, n, 0, false);
    validate(&plan).map_err(|e| anyhow!("{e}"))?;
    let costs_n = {
        let mut c = base_costs.clone();
        if c.fwd.len() != n {
            let rep = |v: &Vec<f64>| vec![v[0]; n];
            c.fwd = rep(&c.fwd);
            c.p1 = rep(&c.p1);
            c.p2 = rep(&c.p2);
            c.opt = rep(&c.opt);
        }
        c
    };
    let mm_n = crate::sim::MemModel {
        static_bytes: vec![mem.static_bytes[0]; n],
        res1: vec![mem.res1[0]; n],
        res2: vec![mem.res2[0]; n],
        inter: vec![mem.inter[0]; n],
    };
    let base = simulate(&plan, &costs_n, Some(&mm_n))
        .map_err(|e| anyhow!("{e}"))?;
    let base_tput = base.throughput(samples, plan.n_microbatches);
    let base_peak = base.max_peak();

    let alphas = [0.0, 0.25, 0.5, 0.75, 1.0];
    let rows = sweep::run_grid(
        &alphas,
        sweep::default_threads(),
        |_, &alpha| -> Result<Vec<String>> {
            let mut cm = costs_n.clone();
            for r in 0..n {
                cm.p2[r] += alpha * cm.p1[r];
            }
            // checkpointing: inter is not stashed
            let mm = crate::sim::MemModel {
                inter: vec![0; n],
                ..mm_n.clone()
            };
            let res =
                simulate(&plan, &cm, Some(&mm)).map_err(|e| anyhow!("{e}"))?;
            let tput = res.throughput(samples, plan.n_microbatches);
            Ok(vec![
                format!("{alpha:.2}"),
                format!("{tput:.2}"),
                format!("{:.3}x", tput / base_tput),
                crate::util::stats::fmt_bytes(res.max_peak()),
                format!("{:.3}x", res.max_peak() as f64 / base_peak as f64),
            ])
        },
    );
    for row in rows {
        t.row(row?);
    }
    let mut out = t.render();
    out.push_str(&format!(
        "baseline (stash inter): {base_tput:.2} samples/s, peak {}\n\
         Reading: the memory win is the full `inter` class; it is free \
         while the recompute fits the bubbles (small α), and costs \
         throughput once p2' extends past them — the overlap condition \
         the paper conjectures in §5.\n",
        crate::util::stats::fmt_bytes(base_peak)));
    Ok(out)
}
