//! Parallel grid sweeps over pure simulator cells.
//!
//! Every paper artifact is, at heart, a grid of independent
//! `(plan, cost model) -> SimResult` evaluations.  [`run_grid`] is the
//! generic runner: scoped worker threads pull cell indices from a
//! shared atomic cursor and results are returned **in cell order**, so
//! parallel and sequential runs are byte-identical.  `table1`,
//! `fig6_fig7`, and `ablation_checkpoint` are built on it, as are the
//! `schedule_space` experiment and the `sweep_throughput` bench.
//!
//! Cells must be pure (no interior mutability, no I/O): the runner
//! gives no ordering guarantee *during* execution, only for results.
//! [`run_grid_with`] adds per-worker mutable state on top — the hook
//! that gives every worker its own [`crate::sim::Scratch`] so bulk
//! evaluation rides the Tier A scoring fast path (see the two-tier
//! contract in [`crate::sim`]) with zero per-cell allocation.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::schedule::{generate, Plan, ScheduleKind};
use crate::sim::{score_plan, simulate, simulate_naive, CostModel, Scratch,
                 SimResult};

/// How many workers to use when the caller doesn't say: one per
/// available core (the sweep is embarrassingly parallel and CPU-bound).
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Evaluate `f` over every cell, `threads` at a time, returning results
/// ordered by cell index (deterministic regardless of thread count).
///
/// A worker panic propagates out of the scope, so a failing cell fails
/// the whole sweep loudly rather than yielding a partial grid.
pub fn run_grid<C, R, F>(cells: &[C], threads: usize, f: F) -> Vec<R>
where
    C: Sync,
    R: Send,
    F: Fn(usize, &C) -> R + Sync,
{
    run_grid_with(cells, threads, || (), |_state: &mut (), i, c| f(i, c))
}

/// [`run_grid`] with **per-worker mutable state**: each worker thread
/// calls `init` exactly once and threads the value through every cell
/// it evaluates.  This is how the scoring fast path rides the parallel
/// runner — `init` builds a [`crate::sim::Scratch`] per worker, so
/// every worker reuses its own simulation buffers across thousands of
/// cells with no sharing and no per-cell allocation.
///
/// Cells must stay pure with respect to *results*: the state may cache
/// and be mutated freely, but `f`'s return value for cell `i` must not
/// depend on which worker ran it or what ran before (the scratch
/// contract).  Results are returned in cell order, so thread count
/// never changes the output.
pub fn run_grid_with<C, R, S, I, F>(
    cells: &[C],
    threads: usize,
    init: I,
    f: F,
) -> Vec<R>
where
    C: Sync,
    R: Send,
    S: Send,
    I: Fn() -> S + Sync,
    F: Fn(&mut S, usize, &C) -> R + Sync,
{
    run_grid_with_pool(cells, threads, &mut Vec::new(), init, f)
}

/// [`run_grid_with`] against a **caller-owned state pool**: worker
/// states are borrowed from `pool` (topped up with `init` to the
/// worker count) instead of being rebuilt per call, so a long-lived
/// caller — the `serve` engine scoring job after job — pays the
/// scratch warm-up once and every later grid reuses the grown
/// buffers.  States the pool holds beyond the worker count are left
/// untouched.  The per-worker state contract is unchanged: results
/// must not depend on which state evaluated a cell, and they return
/// in cell order regardless of thread count.
pub fn run_grid_with_pool<C, R, S, I, F>(
    cells: &[C],
    threads: usize,
    pool: &mut Vec<S>,
    init: I,
    f: F,
) -> Vec<R>
where
    C: Sync,
    R: Send,
    S: Send,
    I: Fn() -> S,
    F: Fn(&mut S, usize, &C) -> R + Sync,
{
    let n = cells.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = threads.max(1).min(n);
    while pool.len() < workers {
        pool.push(init());
    }
    if workers == 1 {
        let state = &mut pool[0];
        return cells
            .iter()
            .enumerate()
            .map(|(i, c)| f(state, i, c))
            .collect();
    }

    let cursor = AtomicUsize::new(0);
    let collected: Mutex<Vec<(usize, R)>> = Mutex::new(Vec::with_capacity(n));
    {
        let cursor = &cursor;
        let collected = &collected;
        let f = &f;
        std::thread::scope(|scope| {
            for state in pool.iter_mut().take(workers) {
                scope.spawn(move || {
                    let mut local: Vec<(usize, R)> = Vec::new();
                    loop {
                        let i = cursor.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        local.push((i, f(state, i, &cells[i])));
                    }
                    collected.lock().unwrap().extend(local);
                });
            }
        });
    }
    let mut got = collected.into_inner().unwrap();
    debug_assert_eq!(got.len(), n, "sweep lost cells");
    got.sort_by_key(|(i, _)| *i);
    got.into_iter().map(|(_, r)| r).collect()
}

/// One point of a schedule-space grid: which schedule, at what scale,
/// under which relative op costs.
#[derive(Debug, Clone)]
pub struct Cell {
    pub kind: ScheduleKind,
    pub two_bp: bool,
    pub n_ranks: usize,
    /// 0 = the schedule's paper-default microbatch count.
    pub n_microbatches: usize,
    /// Relative op costs fwd : bwd-p1 : bwd-p2.
    pub fwd: f64,
    pub p1: f64,
    pub p2: f64,
    /// Activation/gradient hop latency (same units as op costs).
    pub comm: f64,
}

impl Cell {
    pub fn plan(&self) -> Plan {
        generate(self.kind, self.two_bp, self.n_ranks, self.n_microbatches,
                 false)
    }

    pub fn cost_model(&self) -> CostModel {
        let mut cm = CostModel::ratios(self.n_ranks, self.fwd, self.p1,
                                       self.p2);
        cm.comm = self.comm;
        cm
    }

    /// e.g. `1f1b-2+2bp n=8 m=16 f:p1:p2=1:1.2:0.8 comm=0.1`
    pub fn describe(&self) -> String {
        format!(
            "{}{} n={} m={} f:p1:p2={}:{}:{} comm={}",
            self.kind.name(),
            if self.two_bp { "+2bp" } else { "" },
            self.n_ranks,
            if self.n_microbatches == 0 {
                self.kind.default_microbatches(self.n_ranks)
            } else {
                self.n_microbatches
            },
            self.fwd, self.p1, self.p2, self.comm,
        )
    }
}

/// What a sweep keeps per cell (the full [`SimResult`] span lists would
/// dominate memory at 10k+ cells).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CellOut {
    pub makespan: f64,
    pub bubble_ratio: f64,
    /// Plan op count (`Plan::total_ops`): a Flush counts as one op and
    /// greedy p2 fills are not included, so this understates dispatched
    /// events for 2BP plans — a grid-size proxy, not a work measure.
    pub total_ops: usize,
}

fn shrink(plan: &Plan, res: &SimResult) -> CellOut {
    CellOut {
        makespan: res.makespan,
        bubble_ratio: res.bubble_ratio,
        total_ops: plan.total_ops(),
    }
}

/// Evaluate one cell with the event-driven engine (Tier B: records and
/// then discards spans — kept as the mid-fidelity reference point the
/// bench compares; sweeps themselves ride [`eval_scored`]).
pub fn eval(cell: &Cell) -> CellOut {
    let plan = cell.plan();
    let res = simulate(&plan, &cell.cost_model(), None)
        .unwrap_or_else(|e| panic!("cell {}: {e}", cell.describe()));
    shrink(&plan, &res)
}

/// Evaluate one cell through the Tier A scoring fast path: span-free
/// and allocation-free across calls via the caller's `scratch` (pair
/// with [`run_grid_with`] for one scratch per worker).  Bit-identical
/// to [`eval`] on makespan and bubble ratio.
pub fn eval_scored(cell: &Cell, scratch: &mut Scratch) -> CellOut {
    let plan = cell.plan();
    let score = score_plan(&plan, &cell.cost_model(), None, None, scratch)
        .unwrap_or_else(|e| panic!("cell {}: {e}", cell.describe()));
    CellOut {
        makespan: score.makespan,
        bubble_ratio: score.bubble_ratio,
        total_ops: plan.total_ops(),
    }
}

/// Evaluate one cell with the linear-scan reference engine (the bench
/// baseline; results must equal [`eval`]'s exactly).
pub fn eval_naive(cell: &Cell) -> CellOut {
    let plan = cell.plan();
    let res = simulate_naive(&plan, &cell.cost_model(), None)
        .unwrap_or_else(|e| panic!("cell {}: {e}", cell.describe()));
    shrink(&plan, &res)
}

/// The (schedule variant, 2BP) combinations a sweep covers: every
/// paper schedule ± 2BP plus the eager-p2 variant (2BP-only).  Shared
/// by [`grid`] and the `schedule_space` aggregation so the two can
/// never drift apart.
pub fn combos() -> Vec<(ScheduleKind, bool)> {
    let mut combos: Vec<(ScheduleKind, bool)> = Vec::new();
    for kind in ScheduleKind::all() {
        combos.push((kind, false));
        combos.push((kind, true));
    }
    combos.push((ScheduleKind::OneF1B2EagerP2, true));
    combos
}

/// The DP×PP device grid the partition co-search sweeps
/// (DAPPLE-style): every `(dp, pp)` with `dp · pp == devices` and
/// `pp <= max_pp` (a pipeline can't be deeper than the model has
/// layers), ascending in dp.  Deterministic divisor order, so the
/// co-search report is stable.
pub fn dp_pp_cells(devices: usize, max_pp: usize) -> Vec<(u32, usize)> {
    let mut cells = Vec::new();
    for dp in 1..=devices {
        if devices % dp == 0 {
            let pp = devices / dp;
            if pp <= max_pp {
                cells.push((dp as u32, pp));
            }
        }
    }
    cells
}

/// Build the cross product
/// (every schedule variant ± 2BP) × ranks × microbatch multiplier ×
/// (fwd, p1, p2) ratio × comm.  The eager-p2 variant only exists with
/// 2BP; microbatch counts are `mult × paper default` for the kind.
pub fn grid(
    ranks: &[usize],
    m_mults: &[usize],
    ratios: &[(f64, f64, f64)],
    comms: &[f64],
) -> Vec<Cell> {
    let combos = combos();
    let mut cells = Vec::with_capacity(
        combos.len() * ranks.len() * m_mults.len() * ratios.len()
            * comms.len(),
    );
    for &(kind, two_bp) in &combos {
        for &n in ranks {
            for &mult in m_mults {
                for &(f, p1, p2) in ratios {
                    for &comm in comms {
                        cells.push(Cell {
                            kind,
                            two_bp,
                            n_ranks: n,
                            n_microbatches: mult
                                * kind.default_microbatches(n),
                            fwd: f,
                            p1,
                            p2,
                            comm,
                        });
                    }
                }
            }
        }
    }
    cells
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dp_pp_cells_enumerate_divisors_capped_by_layers() {
        assert_eq!(
            dp_pp_cells(12, 12),
            vec![(1, 12), (2, 6), (3, 4), (4, 3), (6, 2), (12, 1)]
        );
        // max_pp caps pipeline depth at the layer count
        assert_eq!(dp_pp_cells(12, 4), vec![(3, 4), (4, 3), (6, 2), (12, 1)]);
        assert_eq!(dp_pp_cells(7, 2), vec![(7, 1)]); // prime, shallow model
        assert!(dp_pp_cells(0, 8).is_empty());
    }

    #[test]
    fn run_grid_preserves_cell_order() {
        let cells: Vec<usize> = (0..97).collect();
        let out = run_grid(&cells, 8, |i, &c| {
            assert_eq!(i, c);
            c * 3
        });
        assert_eq!(out, (0..97).map(|c| c * 3).collect::<Vec<_>>());
    }

    #[test]
    fn run_grid_parallel_matches_sequential() {
        let cells = grid(&[2, 4], &[1], &[(1.0, 1.2, 0.8)], &[0.0, 0.1]);
        let seq = run_grid(&cells, 1, |_, c| eval(c));
        let par = run_grid(&cells, 4, |_, c| eval(c));
        assert_eq!(seq.len(), par.len());
        for (i, (a, b)) in seq.iter().zip(&par).enumerate() {
            assert_eq!(a.makespan.to_bits(), b.makespan.to_bits(),
                       "cell {i} ({})", cells[i].describe());
            assert_eq!(a.bubble_ratio.to_bits(), b.bubble_ratio.to_bits());
        }
    }

    #[test]
    fn run_grid_empty_and_single() {
        let empty: Vec<u32> = Vec::new();
        assert!(run_grid(&empty, 4, |_, &c| c).is_empty());
        assert_eq!(run_grid(&[7u32], 4, |_, &c| c + 1), vec![8]);
    }

    #[test]
    fn run_grid_with_reuses_per_worker_state() {
        // each worker's state counts the cells it saw; results must be
        // independent of that partitioning and stay in cell order
        let cells: Vec<usize> = (0..53).collect();
        for threads in [1usize, 4] {
            let out = run_grid_with(
                &cells,
                threads,
                || 0usize,
                |seen: &mut usize, i, &c| {
                    *seen += 1;
                    assert!(*seen <= cells.len());
                    assert_eq!(i, c);
                    c * 2
                },
            );
            assert_eq!(out, (0..53).map(|c| c * 2).collect::<Vec<_>>());
        }
    }

    #[test]
    fn run_grid_with_pool_reuses_and_tops_up_states() {
        let cells: Vec<usize> = (0..23).collect();
        let mut pool: Vec<usize> = Vec::new();
        // first call builds exactly `workers` states...
        let out = run_grid_with_pool(&cells, 4, &mut pool, || 0usize,
                                     |seen, _, &c| {
                                         *seen += 1;
                                         c * 2
                                     });
        assert_eq!(out, (0..23).map(|c| c * 2).collect::<Vec<_>>());
        assert_eq!(pool.len(), 4);
        let warm: usize = pool.iter().sum();
        assert_eq!(warm, 23, "every cell touched exactly one state");
        // ...later calls reuse them (no re-init: counts keep growing)
        let out = run_grid_with_pool(&cells, 4, &mut pool, || 0usize,
                                     |seen, _, &c| {
                                         *seen += 1;
                                         c * 2
                                     });
        assert_eq!(out, (0..23).map(|c| c * 2).collect::<Vec<_>>());
        assert_eq!(pool.len(), 4);
        assert_eq!(pool.iter().sum::<usize>(), 46);
        // single-worker calls use pool[0] and leave the rest alone
        let before = pool.clone();
        run_grid_with_pool(&cells, 1, &mut pool, || 0usize,
                           |seen, _, &c| {
                               *seen += 1;
                               c
                           });
        assert_eq!(pool[0], before[0] + 23);
        assert_eq!(&pool[1..], &before[1..]);
    }

    #[test]
    fn eval_scored_matches_eval_with_one_scratch() {
        let cells = grid(&[1, 2, 4, 5], &[1, 2],
                         &[(1.0, 1.0, 1.0), (1.0, 0.6, 1.4)], &[0.0, 0.1]);
        let full = run_grid(&cells, 1, |_, c| eval(c));
        let scored = run_grid_with(&cells, 1, Scratch::new,
                                   |s, _, c| eval_scored(c, s));
        for (i, (a, b)) in full.iter().zip(&scored).enumerate() {
            assert_eq!(a.makespan.to_bits(), b.makespan.to_bits(),
                       "cell {i} ({})", cells[i].describe());
            assert_eq!(a.bubble_ratio.to_bits(), b.bubble_ratio.to_bits(),
                       "cell {i} ({})", cells[i].describe());
            assert_eq!(a.total_ops, b.total_ops);
        }
    }

    #[test]
    fn grid_covers_all_variants() {
        let cells = grid(&[2, 4, 8], &[1, 2], &[(1.0, 1.0, 1.0)], &[0.0]);
        // 9 (kind, 2bp) combos × 3 ranks × 2 mults × 1 ratio × 1 comm
        assert_eq!(cells.len(), 9 * 3 * 2);
        assert!(cells.iter().any(
            |c| c.kind == ScheduleKind::OneF1B2EagerP2 && c.two_bp));
        assert!(cells.iter().all(
            |c| c.kind != ScheduleKind::OneF1B2EagerP2 || c.two_bp));
    }

    #[test]
    fn engines_agree_across_a_small_grid() {
        let cells = grid(&[2, 3, 5], &[1, 2],
                         &[(1.0, 1.0, 1.0), (1.0, 0.6, 1.4)], &[0.0, 0.2]);
        let a = run_grid(&cells, default_threads(), |_, c| eval(c));
        let b = run_grid(&cells, 1, |_, c| eval_naive(c));
        for (i, (x, y)) in a.iter().zip(&b).enumerate() {
            assert_eq!(x.makespan.to_bits(), y.makespan.to_bits(),
                       "cell {i}: {}", cells[i].describe());
            assert_eq!(x.bubble_ratio.to_bits(), y.bubble_ratio.to_bits(),
                       "cell {i}: {}", cells[i].describe());
        }
    }
}
