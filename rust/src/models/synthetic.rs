//! In-process synthetic manifests for the stub PJRT backend.
//!
//! Writes a complete artifact set — `manifest.json` plus one stub-HLO
//! signature file per executable — describing a tiny transformer-shaped
//! pipeline, so the real executor (`pipeline/`) can be driven end to
//! end with no Python AOT step and no network (`twobp train
//! --synthetic`, `rust/tests/pjrt_stub.rs`, CI).
//!
//! The generated model is shape-consistent with every contract
//! `pipeline::stage` enforces:
//!
//! * stage r's `output` equals stage r+1's `input` (activations wire up);
//! * `gx` has the input's shape (the upstream gradient message);
//! * `fwd` outputs `[y, res1..., res2...]`, `bwd_p1` outputs
//!   `[gx, inter...]`, `bwd_p2` accumulates into `grads`, `opt` returns
//!   `params/m/v`, the last stage's `loss` returns `[scalar, dlogits]`;
//! * the per-class byte totals match the spec shapes exactly, so the
//!   byte-exact memory accountant and `Manifest::mem_model` agree.
//!
//! The `bwd_p2` file uses the stub's `acc` mode and `bwd_p2_concat`
//! its `group` mode **with the same seed**, which makes gradient
//! accumulation commutative and concat-vs-loop bit-identical — the
//! properties the cross-schedule equivalence tests assert.

use std::path::Path;

use anyhow::{Context, Result};

use super::{DType, Manifest};

/// Parameters of the generated pipeline (all dimensions tiny: the stub
/// fills tensors with PRNG output, so size only costs memcpy time).
#[derive(Debug, Clone)]
pub struct SyntheticSpec {
    /// Preset name (directory under the artifacts root).
    pub preset: String,
    /// Pipeline depth = rank count.
    pub n_stages: usize,
    /// Samples per microbatch (leading tensor dimension).
    pub batch: usize,
    /// Sequence length.
    pub seq: usize,
    /// Hidden width.
    pub hidden: usize,
    /// Vocabulary size (last stage's logits width).
    pub vocab: usize,
    /// Microbatch count a `bwd_p2_concat` call covers.
    pub concat_m: usize,
    /// Base seed every stub executable's seed derives from.
    pub seed: u64,
    /// Per-stage hidden widths (non-uniform stage *shapes*): stage `i`
    /// computes in width `hidden_per_stage[i]`, taking its input at
    /// stage `i-1`'s width, so the pipeline still wires up.  Empty =
    /// uniform `hidden` everywhere (the classic tiny spec).
    pub hidden_per_stage: Vec<usize>,
    /// Per-stage flops multipliers for the manifest's cost entries.
    /// Empty = the mild default ramp `1 + i/4`.
    pub stage_cost_scale: Vec<f64>,
    /// Nanoseconds of stub busy-delay per declared flop (the stub's
    /// `cost` directive).  0 = no cost lines: ops run as fast as the
    /// stub computes, and measured timings reflect only overhead.
    /// Non-zero makes measured per-op costs *proportional to the
    /// manifest flops*, which is what gives measured-cost calibration
    /// (`twobp tune --synthetic`) real per-stage skew to find.
    pub cost_ns_per_flop: f64,
    /// Mid-run cost drift (the stub's `drift` directive): after
    /// `after_calls` executions of a compiled fwd/p1/p2 executable its
    /// busy-delay switches to the drifted multiple of its base cost.
    /// `None` = no drift lines (every other preset).
    pub drift: Option<DriftSpec>,
    /// Deterministic fault injection (the stub's `fault` directive):
    /// lands on one rank's **fwd** executable, so the fault fires at a
    /// predictable call index and the downstream rank observes its peer
    /// going quiet.  `None` = no fault lines (every other preset).
    pub fault: Option<StubFaultSpec>,
}

/// One injected stub fault: which rank, what kind, and when.
///
/// `kind` is the stub directive's kind token (`fail` or `stall-<ns>`),
/// kept textual so one spec string flows from `--fault` through the
/// manifest writer to the stub parser, which validates it on the
/// manifest's load-back self check.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct StubFaultSpec {
    /// Pipeline rank (= stage) whose fwd executable carries the fault.
    pub rank: usize,
    /// Stub fault kind token: `fail` or `stall-<ns>`.
    pub kind: String,
    /// 0-based fwd-executable call index the fault fires from (with m
    /// microbatches, call `m * s + k` is step s's microbatch k).
    pub at_call: u64,
}

impl StubFaultSpec {
    /// Parse the CLI form `<rank>:<kind>@<call>`, e.g. `1:fail@3` or
    /// `2:stall-50000000@0` (`twobp train --synthetic --fault ...`).
    pub fn parse(s: &str) -> Result<StubFaultSpec> {
        let parsed = s.split_once(':').and_then(|(rank, rest)| {
            let (kind, at) = rest.split_once('@')?;
            Some(StubFaultSpec {
                rank: rank.parse().ok()?,
                kind: kind.to_string(),
                at_call: at.parse().ok()?,
            })
        });
        let spec = parsed.ok_or_else(|| {
            anyhow::anyhow!(
                "bad fault spec '{s}': expected <rank>:<kind>@<call>, \
                 e.g. 1:fail@3 or 2:stall-50000000@0"
            )
        })?;
        if spec.kind != "fail"
            && spec
                .kind
                .strip_prefix("stall-")
                .and_then(|ns| ns.parse::<u64>().ok())
                .is_none()
        {
            anyhow::bail!(
                "bad fault kind '{}': want fail or stall-<ns>",
                spec.kind
            );
        }
        Ok(spec)
    }

    /// The stub directive value this spec writes (`<kind>@<call>`).
    pub fn directive(&self) -> String {
        format!("{}@{}", self.kind, self.at_call)
    }
}

/// Cost drift applied to a synthetic manifest's compute executables —
/// the offline stand-in for a cluster whose per-stage times wander away
/// from their calibrated profile mid-run (the replan smoke's trigger).
#[derive(Debug, Clone)]
pub struct DriftSpec {
    /// Per-executable execution count after which the drifted cost
    /// applies (counted independently per compiled executable, i.e.
    /// per rank per role).
    pub after_calls: u64,
    /// Drift call count for the *concat* p2 executable, which loop-mode
    /// calibration never runs and a concat plan calls only once per
    /// step — the per-microbatch `after_calls` would never be reached
    /// there, and a concat-p2 winner would dodge the drift entirely.
    /// Counted in steps, pick it to land about where the per-microbatch
    /// executables cross `after_calls` mid-run.
    pub after_calls_concat: u64,
    /// Post-drift cost multipliers per backward/forward role.  A
    /// *role-asymmetric* drift (e.g. p2-heavy) both raises the step
    /// makespan (detectable) and shifts the deferral economics the
    /// planner tuned for (re-tunable) — a uniform slowdown would only
    /// do the former.
    pub fwd_mult: f64,
    pub p1_mult: f64,
    pub p2_mult: f64,
}

impl Default for SyntheticSpec {
    fn default() -> Self {
        SyntheticSpec {
            preset: "synthetic".to_string(),
            n_stages: 4,
            batch: 2,
            seq: 4,
            hidden: 8,
            vocab: 16,
            concat_m: 4,
            seed: 0x2B9_57AB,
            hidden_per_stage: Vec::new(),
            stage_cost_scale: Vec::new(),
            cost_ns_per_flop: 0.0,
            drift: None,
            fault: None,
        }
    }
}

impl SyntheticSpec {
    /// The default tiny 4-stage pipeline used by CI and the tests.
    pub fn tiny() -> SyntheticSpec {
        SyntheticSpec::default()
    }

    /// The tiny pipeline with a fault injected on one rank's fwd
    /// executable — the workload of the fault-supervision tests and
    /// `twobp bench faults`.
    pub fn tiny_faulty(fault: StubFaultSpec) -> SyntheticSpec {
        SyntheticSpec {
            preset: "synthetic-fault".to_string(),
            fault: Some(fault),
            ..SyntheticSpec::tiny()
        }
    }

    /// A deliberately depth-imbalanced pipeline for measured-cost
    /// calibration: per-stage flops skewed up to 4x (with matching
    /// non-uniform hidden widths), and every op carrying a stub `cost`
    /// busy-delay proportional to its flops — so `measured_costs()` on
    /// a real run recovers the manifest's cost shape from wall time,
    /// not from metadata.  Op costs sit in the 1–10 ms range: long
    /// enough to dominate stub compute/dispatch overhead (~tens of µs),
    /// short enough that calibration + winner replay stay a sub-minute
    /// CI smoke.
    pub fn skewed() -> SyntheticSpec {
        SyntheticSpec {
            preset: "synthetic-skewed".to_string(),
            hidden_per_stage: vec![6, 16, 8, 12],
            stage_cost_scale: vec![1.0, 4.0, 2.0, 3.0],
            cost_ns_per_flop: 12_000.0,
            ..SyntheticSpec::default()
        }
    }

    /// The skewed spec with a p2-heavy mid-run cost drift — the
    /// drift-replan smoke's workload (`twobp tune --synthetic
    /// --replan`).  `after_calls` is tuned so calibration (2 steps × 4
    /// microbatches = 8 calls per compute executable) and the first
    /// executed steps run at the calibrated costs, and the drift lands
    /// while the tuned plan is running — so the monitor sees measured
    /// step makespans diverge from a prediction that *was* accurate.
    /// The drifted p2 is ~6× dearer, which moves the plan optimum
    /// (deferred-p2 packing stops paying) as well as the makespan.
    pub fn skewed_drifting() -> SyntheticSpec {
        SyntheticSpec {
            preset: "synthetic-drift".to_string(),
            drift: Some(DriftSpec {
                after_calls: 20,
                after_calls_concat: 2,
                fwd_mult: 1.0,
                p1_mult: 1.0,
                p2_mult: 6.0,
            }),
            ..SyntheticSpec::skewed()
        }
    }

    /// Stage `i`'s hidden width.
    fn stage_hidden(&self, i: usize) -> usize {
        self.hidden_per_stage.get(i).copied().unwrap_or(self.hidden)
    }

    /// Stage `i`'s flops multiplier.
    fn cost_scale(&self, i: usize) -> f64 {
        self.stage_cost_scale
            .get(i)
            .copied()
            .unwrap_or(1.0 + i as f64 * 0.25)
    }

    /// Stub `cost` directive (ns) for an op of `flops` declared flops.
    fn cost_ns(&self, flops: f64) -> u64 {
        (flops * self.cost_ns_per_flop) as u64
    }

    /// Stub `drift` directive for an op of `flops` declared flops whose
    /// role carries post-drift multiplier `mult`, switching after
    /// `after_calls` executions (None without drift).
    fn drift_ns(
        &self,
        after_calls: u64,
        flops: f64,
        mult: f64,
    ) -> Option<(u64, u64)> {
        self.drift
            .as_ref()
            .map(|_| (after_calls, self.cost_ns(flops * mult)))
    }
}

/// Tensor-spec JSON object matching `models::TensorSpec::from_json`.
fn tensor_json(name: Option<&str>, dtype: DType, shape: &[usize]) -> String {
    let dims = shape
        .iter()
        .map(|d| d.to_string())
        .collect::<Vec<_>>()
        .join(", ");
    let bytes = shape.iter().product::<usize>() * dtype.itemsize();
    let dt = match dtype {
        DType::F32 => "float32",
        DType::I32 => "int32",
    };
    match name {
        Some(n) => format!(
            "{{\"name\": \"{n}\", \"shape\": [{dims}], \"dtype\": \"{dt}\", \
             \"bytes\": {bytes}}}"
        ),
        None => format!(
            "{{\"shape\": [{dims}], \"dtype\": \"{dt}\", \"bytes\": {bytes}}}"
        ),
    }
}

fn spec_list(specs: &[(Option<&str>, DType, Vec<usize>)]) -> String {
    specs
        .iter()
        .map(|(n, dt, sh)| tensor_json(*n, *dt, sh))
        .collect::<Vec<_>>()
        .join(", ")
}

fn bytes_of(specs: &[(Option<&str>, DType, Vec<usize>)]) -> u64 {
    specs
        .iter()
        .map(|(_, dt, sh)| (sh.iter().product::<usize>() * dt.itemsize()) as u64)
        .sum()
}

/// Per-file stub seed: a pure function of the base seed, stage, and
/// role.  `bwd_p2` and `bwd_p2_concat` share a role id on purpose —
/// identical delta streams are what make concat == loop bit for bit.
fn file_seed(base: u64, stage: usize, role: u64) -> u64 {
    base ^ (stage as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15)
        ^ role.wrapping_mul(0xD1B5_4A32_D192_ED03)
}

fn dtype_tok(dt: DType) -> &'static str {
    match dt {
        DType::F32 => "f32",
        DType::I32 => "s32",
    }
}

/// Write one stub-HLO signature file.
#[allow(clippy::too_many_arguments)]
fn write_stub(
    dir: &Path,
    file: &str,
    module: &str,
    seed: u64,
    acc: usize,
    group: usize,
    cost_ns: u64,
    drift: Option<(u64, u64)>,
    fault: Option<&StubFaultSpec>,
    outs: &[(DType, Vec<usize>)],
) -> Result<()> {
    let mut text = String::from("stub-hlo v1\n");
    text.push_str(&format!("module {module}\n"));
    text.push_str(&format!("seed {seed}\n"));
    if acc > 0 {
        text.push_str(&format!("acc {acc}\n"));
    }
    if group > 0 {
        text.push_str(&format!("group {group}\n"));
    }
    if cost_ns > 0 {
        text.push_str(&format!("cost {cost_ns}\n"));
    }
    if let Some((calls, ns)) = drift {
        text.push_str(&format!("drift {calls}:{ns}\n"));
    }
    if let Some(f) = fault {
        text.push_str(&format!("fault {}\n", f.directive()));
    }
    for (dt, shape) in outs {
        let dims = shape
            .iter()
            .map(|d| d.to_string())
            .collect::<Vec<_>>()
            .join(",");
        text.push_str(&format!("out {}[{dims}]\n", dtype_tok(*dt)));
    }
    let path = dir.join(file);
    std::fs::write(&path, text)
        .with_context(|| format!("writing {}", path.display()))
}

/// Generate `<root>/<preset>/manifest.json` plus every stub-HLO
/// executable, then load the result back through [`Manifest::load`] (a
/// built-in self check) and return it.
pub fn write_artifacts(root: &Path, spec: &SyntheticSpec) -> Result<Manifest> {
    assert!(spec.n_stages >= 1, "need at least one stage");
    assert!(
        spec.hidden_per_stage.is_empty()
            || spec.hidden_per_stage.len() == spec.n_stages,
        "hidden_per_stage must be empty or one width per stage"
    );
    assert!(
        spec.stage_cost_scale.is_empty()
            || spec.stage_cost_scale.len() == spec.n_stages,
        "stage_cost_scale must be empty or one multiplier per stage"
    );
    if let Some(f) = &spec.fault {
        anyhow::ensure!(
            f.rank < spec.n_stages,
            "fault rank {} out of range: the pipeline has {} stages",
            f.rank,
            spec.n_stages
        );
    }
    let dir = root.join(&spec.preset);
    std::fs::create_dir_all(&dir)
        .with_context(|| format!("creating {}", dir.display()))?;

    let (n, b, s, v) = (spec.n_stages, spec.batch, spec.seq, spec.vocab);
    type Spec<'a> = (Option<&'a str>, DType, Vec<usize>);

    let mut stage_objs: Vec<String> = Vec::with_capacity(n);
    for i in 0..n {
        let last = i == n - 1;
        // stage i computes in its own width; its input arrives at the
        // upstream stage's width (non-uniform shapes still wire up)
        let h = spec.stage_hidden(i);
        let hid = vec![b, s, h];
        let input: Spec = if i == 0 {
            (None, DType::I32, vec![b, s])
        } else {
            (None, DType::F32, vec![b, s, spec.stage_hidden(i - 1)])
        };
        let output: Spec = if last {
            (None, DType::F32, vec![b, s, v])
        } else {
            (None, DType::F32, hid.clone())
        };
        let gx: Spec = (None, DType::F32, input.2.clone());
        let params: Vec<Spec> = vec![
            (Some("w"), DType::F32, vec![h, h]),
            (Some("bias"), DType::F32, vec![h]),
        ];
        let res1: Vec<Spec> = vec![(None, DType::F32, hid.clone())];
        let res2: Vec<Spec> = vec![
            (None, DType::F32, hid.clone()),
            (None, DType::I32, vec![b, s]),
        ];
        let inter: Vec<Spec> = vec![(None, DType::F32, hid.clone())];
        let grads: Vec<Spec> = vec![
            (None, DType::F32, vec![h, h]),
            (None, DType::F32, vec![h]),
        ];

        // stub signature files (out lists follow the executor's arity
        // contract; see the module docs)
        let param_outs: Vec<(DType, Vec<usize>)> =
            params.iter().map(|(_, dt, sh)| (*dt, sh.clone())).collect();
        let grad_outs: Vec<(DType, Vec<usize>)> =
            grads.iter().map(|(_, dt, sh)| (*dt, sh.clone())).collect();
        let mut fwd_outs: Vec<(DType, Vec<usize>)> =
            vec![(output.1, output.2.clone())];
        fwd_outs.extend(res1.iter().map(|(_, dt, sh)| (*dt, sh.clone())));
        fwd_outs.extend(res2.iter().map(|(_, dt, sh)| (*dt, sh.clone())));
        let mut p1_outs: Vec<(DType, Vec<usize>)> = vec![(gx.1, gx.2.clone())];
        p1_outs.extend(inter.iter().map(|(_, dt, sh)| (*dt, sh.clone())));
        let mut opt_outs = param_outs.clone();
        opt_outs.extend(param_outs.clone());
        opt_outs.extend(param_outs.clone());
        let group = res2.len() + inter.len();

        // flops vary per stage so the derived cost model is non-uniform,
        // like a real depth-imbalanced pipeline; with a non-zero
        // cost_ns_per_flop the stub files carry matching `cost`
        // busy-delays, so *measured* costs reflect the same skew
        let scale = spec.cost_scale(i);
        let (fwd_fl, p1_fl, p2_fl, opt_fl) =
            (100.0 * scale, 110.0 * scale, 90.0 * scale, 5.0 * scale);
        let p2c_fl = p2_fl * spec.concat_m as f64;

        // drift (if any) hits the compute roles via their per-role
        // multipliers; init/opt stay steady.  An injected fault lands
        // on this stage's fwd executable only (see StubFaultSpec)
        let d = spec.drift.as_ref();
        let fault = spec.fault.as_ref().filter(|f| f.rank == i);
        let m = |role: &str| format!("{}/s{i}_{role}", spec.preset);
        write_stub(&dir, &format!("s{i}_init.hlo.txt"), &m("init"),
                   file_seed(spec.seed, i, 1), 0, 0, 0, None, None,
                   &param_outs)?;
        write_stub(&dir, &format!("s{i}_fwd.hlo.txt"), &m("fwd"),
                   file_seed(spec.seed, i, 2), 0, 0, spec.cost_ns(fwd_fl),
                   d.and_then(|d| spec.drift_ns(d.after_calls, fwd_fl,
                                                d.fwd_mult)),
                   fault, &fwd_outs)?;
        write_stub(&dir, &format!("s{i}_p1.hlo.txt"), &m("p1"),
                   file_seed(spec.seed, i, 3), 0, 0, spec.cost_ns(p1_fl),
                   d.and_then(|d| spec.drift_ns(d.after_calls, p1_fl,
                                                d.p1_mult)),
                   None, &p1_outs)?;
        write_stub(&dir, &format!("s{i}_p2.hlo.txt"), &m("p2"),
                   file_seed(spec.seed, i, 4), grad_outs.len(), 0,
                   spec.cost_ns(p2_fl),
                   d.and_then(|d| spec.drift_ns(d.after_calls, p2_fl,
                                                d.p2_mult)),
                   None, &grad_outs)?;
        write_stub(&dir, &format!("s{i}_p2c.hlo.txt"), &m("p2c"),
                   file_seed(spec.seed, i, 4), 0, group,
                   spec.cost_ns(p2c_fl),
                   d.and_then(|d| spec.drift_ns(d.after_calls_concat,
                                                p2c_fl, d.p2_mult)),
                   None, &grad_outs)?;
        write_stub(&dir, &format!("s{i}_opt.hlo.txt"), &m("opt"),
                   file_seed(spec.seed, i, 5), 0, 0, spec.cost_ns(opt_fl),
                   None, None, &opt_outs)?;

        let art = |file: &str, flops: f64| -> String {
            format!("{{\"file\": \"{file}\", \"flops\": {flops:.1}}}")
        };
        let out_bytes = bytes_of(std::slice::from_ref(&output));
        stage_objs.push(format!(
            "{{\n    \"index\": {i},\n    \"params\": [{}],\n    \
             \"input\": {},\n    \"output\": {},\n    \"gx\": {},\n    \
             \"res1\": [{}],\n    \"res2\": [{}],\n    \"inter\": [{}],\n    \
             \"grads\": [{}],\n    \"bytes\": {{\"params\": {}, \"res1\": {}, \
             \"res2\": {}, \"inter\": {}, \"grads\": {}, \
             \"activation\": {}}},\n    \"artifacts\": {{\n      \
             \"init\": {},\n      \"fwd\": {},\n      \"bwd_p1\": {},\n      \
             \"bwd_p2\": {},\n      \"bwd_p2_concat\": {},\n      \
             \"opt\": {}\n    }}\n  }}",
            spec_list(&params),
            tensor_json(None, input.1, &input.2),
            tensor_json(None, output.1, &output.2),
            tensor_json(None, gx.1, &gx.2),
            spec_list(&res1),
            spec_list(&res2),
            spec_list(&inter),
            spec_list(&grads),
            bytes_of(&params),
            bytes_of(&res1),
            bytes_of(&res2),
            bytes_of(&inter),
            bytes_of(&grads),
            out_bytes,
            art(&format!("s{i}_init.hlo.txt"), scale),
            art(&format!("s{i}_fwd.hlo.txt"), fwd_fl),
            art(&format!("s{i}_p1.hlo.txt"), p1_fl),
            art(&format!("s{i}_p2.hlo.txt"), p2_fl),
            art(&format!("s{i}_p2c.hlo.txt"), p2c_fl),
            art(&format!("s{i}_opt.hlo.txt"), opt_fl),
        ));
    }

    // loss executable: [scalar loss, dlogits]
    let logits = vec![b, s, v];
    let labels = vec![b, s];
    write_stub(
        &dir,
        "loss.hlo.txt",
        &format!("{}/loss", spec.preset),
        file_seed(spec.seed, n, 6),
        0,
        0,
        spec.cost_ns(7.0),
        None,
        None,
        &[(DType::F32, Vec::new()), (DType::F32, logits.clone())],
    )?;

    let manifest_json = format!(
        "{{\n  \"preset\": \"{}\",\n  \"arch\": \"stub\",\n  \
         \"stages\": {n},\n  \"microbatch\": {b},\n  \
         \"samples_per_microbatch\": {b},\n  \
         \"n_microbatches_concat\": {},\n  \"optimizer\": \"adam\",\n  \
         \"lr\": 0.001,\n  \"stage\": [{}],\n  \
         \"loss\": {{\"file\": \"loss.hlo.txt\", \"flops\": 7.0,\n    \
         \"logits\": {},\n    \"labels\": {}}}\n}}\n",
        spec.preset,
        spec.concat_m,
        stage_objs.join(", "),
        tensor_json(None, DType::F32, &logits),
        tensor_json(None, DType::I32, &labels),
    );
    let path = dir.join("manifest.json");
    std::fs::write(&path, manifest_json)
        .with_context(|| format!("writing {}", path.display()))?;

    // self check: the generated manifest must round-trip the parser
    Manifest::load(root, &spec.preset)
        .context("reloading the generated synthetic manifest")
}

/// Write a synthetic artifact set into a fresh per-process temp
/// directory, run `f` against it, and remove the directory afterwards
/// (also on error) — the shared plumbing behind `twobp train
/// --synthetic` and `twobp bench synthetic`.
pub fn with_temp_artifacts<T>(
    tag: &str,
    spec: &SyntheticSpec,
    f: impl FnOnce(&Path, &Manifest) -> Result<T>,
) -> Result<T> {
    // Drop guard: the executor's designed failure mode is a panic
    // (accountant underflow asserts, step-balance checks), which must
    // still remove the directory on unwind.
    struct Cleanup(std::path::PathBuf);
    impl Drop for Cleanup {
        fn drop(&mut self) {
            let _ = std::fs::remove_dir_all(&self.0);
        }
    }
    let root = std::env::temp_dir()
        .join(format!("twobp-{tag}-{}", std::process::id()));
    let _cleanup = Cleanup(root.clone());
    write_artifacts(&root, spec).and_then(|manifest| f(&root, &manifest))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(tag: &str) -> std::path::PathBuf {
        std::env::temp_dir()
            .join(format!("twobp-synth-unit-{tag}-{}", std::process::id()))
    }

    #[test]
    fn generated_manifest_round_trips() {
        let root = tmp("roundtrip");
        let spec = SyntheticSpec::tiny();
        let m = write_artifacts(&root, &spec).expect("write");
        assert_eq!(m.n_stages, spec.n_stages);
        assert_eq!(m.stages.len(), spec.n_stages);
        assert_eq!(m.concat_m, spec.concat_m);
        assert_eq!(m.samples_per_microbatch, spec.batch);
        assert_eq!(*m.logits.shape.last().unwrap(), spec.vocab);
        assert_eq!(m.labels.dtype, DType::I32);
        for (i, st) in m.stages.iter().enumerate() {
            assert_eq!(st.index, i);
            assert!(st.fwd.file.exists(), "stage {i} fwd file missing");
            assert!(st.bwd_p2_concat.file.exists());
            // byte classes match the spec shapes exactly
            let sum = |xs: &[crate::models::TensorSpec]| -> u64 {
                xs.iter().map(|t| t.bytes).sum()
            };
            assert_eq!(st.bytes.params, sum(&st.params));
            assert_eq!(st.bytes.res1, sum(&st.res1));
            assert_eq!(st.bytes.res2, sum(&st.res2));
            assert_eq!(st.bytes.inter, sum(&st.inter));
            assert_eq!(st.bytes.grads, sum(&st.grads));
        }
        // stage outputs wire to the next stage's inputs
        for w in m.stages.windows(2) {
            assert_eq!(w[0].output.shape, w[1].input.shape);
            assert_eq!(w[1].gx.shape, w[1].input.shape);
        }
        // derived models are well-formed
        let mm = m.mem_model();
        assert_eq!(mm.static_bytes.len(), spec.n_stages);
        let cm = m.cost_model_from_flops(0.0);
        assert_eq!(cm.fwd.len(), spec.n_stages);
        assert!(cm.p1[0] > cm.fwd[0], "p1 should cost more than fwd");
        let _ = std::fs::remove_dir_all(&root);
    }

    /// The skewed calibration spec: non-uniform widths still wire up,
    /// the derived cost model carries the declared skew exactly (and is
    /// mean-normalized), and the stub files carry matching `cost`
    /// busy-delay directives.
    #[test]
    fn skewed_manifest_is_nonuniform_and_wires_up() {
        let root = tmp("skewed");
        let spec = SyntheticSpec::skewed();
        let m = write_artifacts(&root, &spec).expect("write");
        assert_eq!(m.n_stages, spec.n_stages);
        for w in m.stages.windows(2) {
            assert_eq!(w[0].output.shape, w[1].input.shape);
            assert_eq!(w[1].gx.shape, w[1].input.shape);
        }
        // byte classes really differ across stages (non-uniform widths)
        let mm = m.mem_model();
        assert!(mm.res1.iter().any(|&x| x != mm.res1[0]));
        // the flops-derived cost model carries the 4x skew, normalized
        // so the mean fwd cost is exactly 1.0
        let cm = m.cost_model_from_flops(0.0);
        assert!((cm.fwd[1] / cm.fwd[0] - 4.0).abs() < 1e-9);
        assert!((cm.p2[3] / cm.p2[0] - 3.0).abs() < 1e-9);
        let mean: f64 = cm.fwd.iter().sum::<f64>() / cm.fwd.len() as f64;
        assert!((mean - 1.0).abs() < 1e-12, "fwd mean {mean}");
        // cost directives landed, proportional to the declared flops
        let text = std::fs::read_to_string(&m.stages[1].fwd.file).unwrap();
        assert!(text.contains("cost 4800000"), "{text}");
        let loss_text = std::fs::read_to_string(&m.loss.file).unwrap();
        assert!(loss_text.contains("cost 84000"), "{loss_text}");
        // the tiny spec stays cost-free (fast CI fuzz runs)
        let tiny_root = tmp("skewed-tiny");
        let tiny = write_artifacts(&tiny_root, &SyntheticSpec::tiny())
            .expect("write tiny");
        let tiny_text =
            std::fs::read_to_string(&tiny.stages[0].fwd.file).unwrap();
        assert!(!tiny_text.contains("cost "), "{tiny_text}");
        let _ = std::fs::remove_dir_all(&root);
        let _ = std::fs::remove_dir_all(&tiny_root);
    }

    /// The drifting spec emits stub `drift` directives on the compute
    /// roles with the per-role multipliers applied, and nowhere else.
    #[test]
    fn drifting_manifest_carries_role_asymmetric_drift() {
        let root = tmp("drift");
        let spec = SyntheticSpec::skewed_drifting();
        let m = write_artifacts(&root, &spec).expect("write");
        let read = |p: &std::path::Path| std::fs::read_to_string(p).unwrap();
        // stage 1 (scale 4): p2 base 90*4 flops * 12000 ns = 4.32 ms,
        // drifted *6 = 25.92 ms; fwd multiplier 1.0 leaves ns unchanged
        let p2 = read(&m.stages[1].bwd_p2.file);
        assert!(p2.contains("cost 4320000"), "{p2}");
        assert!(p2.contains("drift 20:25920000"), "{p2}");
        let fwd = read(&m.stages[1].fwd.file);
        assert!(fwd.contains("drift 20:4800000"), "{fwd}");
        // concat p2 drifts in proportion (covers concat_m microbatches)
        // but on its own step-scale call count: calibration never runs
        // it and a concat plan calls it once per step
        let p2c = read(&m.stages[1].bwd_p2_concat.file);
        assert!(p2c.contains("drift 2:103680000"), "{p2c}");
        // steady roles carry no drift directive
        for f in [&m.stages[1].init.file, &m.stages[1].opt.file, &m.loss.file]
        {
            assert!(!read(f).contains("drift "), "{}", f.display());
        }
        // the plain skewed preset stays drift-free
        let root2 = tmp("drift-skewed");
        let plain = write_artifacts(&root2, &SyntheticSpec::skewed())
            .expect("write skewed");
        assert!(!read(&plain.stages[1].bwd_p2.file).contains("drift "));
        let _ = std::fs::remove_dir_all(&root);
        let _ = std::fs::remove_dir_all(&root2);
    }

    #[test]
    fn fault_spec_parses_and_rejects_garbage() {
        let f = StubFaultSpec::parse("1:fail@3").unwrap();
        assert_eq!(f, StubFaultSpec { rank: 1,
                                      kind: "fail".to_string(),
                                      at_call: 3 });
        assert_eq!(f.directive(), "fail@3");
        let s = StubFaultSpec::parse("2:stall-50000000@0").unwrap();
        assert_eq!(s.kind, "stall-50000000");
        assert_eq!(s.directive(), "stall-50000000@0");
        for bad in ["", "fail@3", "1:fail", "x:fail@3", "1:fail@y",
                    "1:explode@3", "1:stall-x@3"] {
            assert!(StubFaultSpec::parse(bad).is_err(), "{bad:?}");
        }
    }

    /// The faulty preset lands the directive on exactly the chosen
    /// rank's fwd executable and still round-trips the manifest loader.
    #[test]
    fn faulty_manifest_carries_the_directive_on_one_fwd() {
        let root = tmp("fault");
        let spec = SyntheticSpec::tiny_faulty(
            StubFaultSpec::parse("1:fail@3").unwrap(),
        );
        let m = write_artifacts(&root, &spec).expect("write");
        let read = |p: &std::path::Path| std::fs::read_to_string(p).unwrap();
        assert!(read(&m.stages[1].fwd.file).contains("fault fail@3"));
        for (i, st) in m.stages.iter().enumerate() {
            if i != 1 {
                assert!(!read(&st.fwd.file).contains("fault "), "rank {i}");
            }
            for f in [&st.init.file, &st.bwd_p1.file, &st.bwd_p2.file,
                      &st.bwd_p2_concat.file, &st.opt.file] {
                assert!(!read(f).contains("fault "), "{}", f.display());
            }
        }
        // a rank past the pipeline end is rejected, not silently ignored
        let oob = SyntheticSpec::tiny_faulty(
            StubFaultSpec::parse("9:fail@0").unwrap(),
        );
        assert!(write_artifacts(&root, &oob).is_err());
        let _ = std::fs::remove_dir_all(&root);
    }

    /// Every generated stub file parses, and its declared output arity
    /// matches the executor's contract for that role.
    #[cfg(feature = "pjrt")]
    #[test]
    fn stub_files_parse_with_executor_arity() {
        let root = tmp("arity");
        let spec = SyntheticSpec::tiny();
        let m = write_artifacts(&root, &spec).expect("write");
        let outs = |p: &std::path::Path| -> usize {
            let text = std::fs::read_to_string(p).expect("read stub");
            text.lines().filter(|l| l.trim().starts_with("out ")).count()
        };
        for st in &m.stages {
            assert_eq!(outs(&st.init.file), st.params.len());
            assert_eq!(outs(&st.fwd.file),
                       1 + st.res1.len() + st.res2.len());
            assert_eq!(outs(&st.bwd_p1.file), 1 + st.inter.len());
            assert_eq!(outs(&st.bwd_p2.file), st.grads.len());
            assert_eq!(outs(&st.bwd_p2_concat.file), st.grads.len());
            assert_eq!(outs(&st.opt.file), 3 * st.params.len());
            // and they compile through the stub client
            for f in [&st.init.file, &st.fwd.file, &st.bwd_p1.file,
                      &st.bwd_p2.file, &st.bwd_p2_concat.file, &st.opt.file] {
                let proto = xla::HloModuleProto::from_text_file(f)
                    .unwrap_or_else(|e| panic!("{}: {e:?}", f.display()));
                assert!(!proto.name().is_empty());
            }
        }
        assert_eq!(outs(&m.loss.file), 2);
        let _ = std::fs::remove_dir_all(&root);
    }
}
