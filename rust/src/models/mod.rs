//! Artifact manifest parsing — the rust mirror of `python/compile/aot.py`.
//!
//! The manifest is the entire runtime contract: flat argument/output
//! specs for every per-stage executable, per-class byte totals (the
//! paper's §4.2 memory taxonomy: res1 / res2 / inter), and XLA
//! cost-analysis flops used to calibrate the simulator.

pub mod synthetic;

use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

/// Element type of a tensor in the manifest.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "float32" => Ok(DType::F32),
            "int32" => Ok(DType::I32),
            other => bail!("unsupported dtype {other}"),
        }
    }

    pub fn itemsize(&self) -> usize {
        4
    }
}

/// Shape + dtype + byte size of one tensor.
#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: DType,
    pub bytes: u64,
    pub name: Option<String>,
}

impl TensorSpec {
    fn from_json(v: &Json) -> Result<Self> {
        let shape = v
            .get("shape")
            .and_then(|s| s.as_arr())
            .ok_or_else(|| anyhow!("missing shape"))?
            .iter()
            .map(|d| d.as_u64().map(|d| d as usize))
            .collect::<Option<Vec<_>>>()
            .ok_or_else(|| anyhow!("bad shape"))?;
        let dtype = DType::parse(
            v.get("dtype").and_then(|d| d.as_str()).unwrap_or("float32"),
        )?;
        let bytes = v.get("bytes").and_then(|b| b.as_u64()).unwrap_or_else(|| {
            (shape.iter().product::<usize>() * dtype.itemsize()) as u64
        });
        let name = v.get("name").and_then(|n| n.as_str()).map(String::from);
        Ok(TensorSpec { shape, dtype, bytes, name })
    }

    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Byte totals per residency class for one stage (drives the memory
/// accountant and the simulator's MemModel).
#[derive(Debug, Clone, Copy, Default)]
pub struct ByteClasses {
    pub params: u64,
    pub res1: u64,
    pub res2: u64,
    pub inter: u64,
    pub grads: u64,
    pub activation: u64,
}

/// One executable's entry (file + flops estimate).
#[derive(Debug, Clone)]
pub struct Artifact {
    pub file: PathBuf,
    pub flops: Option<f64>,
}

/// Everything known about one pipeline stage.
#[derive(Debug, Clone)]
pub struct StageInfo {
    pub index: usize,
    pub params: Vec<TensorSpec>,
    pub input: TensorSpec,
    pub output: TensorSpec,
    pub gx: TensorSpec,
    pub res1: Vec<TensorSpec>,
    pub res2: Vec<TensorSpec>,
    pub inter: Vec<TensorSpec>,
    pub grads: Vec<TensorSpec>,
    pub bytes: ByteClasses,
    pub init: Artifact,
    pub fwd: Artifact,
    pub bwd_p1: Artifact,
    pub bwd_p2: Artifact,
    pub bwd_p2_concat: Artifact,
    pub opt: Artifact,
}

impl StageInfo {
    pub fn param_count(&self) -> usize {
        self.params.iter().map(|p| p.elems()).sum()
    }
}

/// A parsed manifest for one preset.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub preset: String,
    pub arch: String,
    pub n_stages: usize,
    pub microbatch: usize,
    pub samples_per_microbatch: usize,
    pub concat_m: usize,
    pub optimizer: String,
    pub stages: Vec<StageInfo>,
    pub loss: Artifact,
    pub logits: TensorSpec,
    pub labels: TensorSpec,
    pub dir: PathBuf,
}

impl Manifest {
    /// Load `artifacts/<preset>/manifest.json`.
    pub fn load(artifacts_root: &Path, preset: &str) -> Result<Manifest> {
        let dir = artifacts_root.join(preset);
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let v = Json::parse(&text)
            .map_err(|e| anyhow!("parsing {}: {e}", path.display()))?;
        Self::from_json(&v, &dir)
    }

    fn from_json(v: &Json, dir: &Path) -> Result<Manifest> {
        let s = |k: &str| -> Result<String> {
            v.get(k)
                .and_then(|x| x.as_str())
                .map(String::from)
                .ok_or_else(|| anyhow!("missing {k}"))
        };
        let u = |k: &str| -> Result<usize> {
            v.get(k)
                .and_then(|x| x.as_u64())
                .map(|x| x as usize)
                .ok_or_else(|| anyhow!("missing {k}"))
        };
        let art = |av: &Json| -> Result<Artifact> {
            Ok(Artifact {
                file: dir.join(
                    av.get("file")
                        .and_then(|f| f.as_str())
                        .ok_or_else(|| anyhow!("artifact missing file"))?,
                ),
                flops: av.get("flops").and_then(|f| f.as_f64()),
            })
        };

        let mut stages = Vec::new();
        for sv in v
            .get("stage")
            .and_then(|x| x.as_arr())
            .ok_or_else(|| anyhow!("missing stage array"))?
        {
            let specs = |k: &str| -> Result<Vec<TensorSpec>> {
                sv.get(k)
                    .and_then(|x| x.as_arr())
                    .ok_or_else(|| anyhow!("stage missing {k}"))?
                    .iter()
                    .map(TensorSpec::from_json)
                    .collect()
            };
            let one = |k: &str| -> Result<TensorSpec> {
                TensorSpec::from_json(
                    sv.get(k).ok_or_else(|| anyhow!("stage missing {k}"))?,
                )
            };
            let arts = sv
                .get("artifacts")
                .ok_or_else(|| anyhow!("stage missing artifacts"))?;
            let a = |k: &str| -> Result<Artifact> {
                art(arts.get(k).ok_or_else(|| anyhow!("missing artifact {k}"))?)
            };
            let bv = sv.get("bytes").ok_or_else(|| anyhow!("missing bytes"))?;
            let bu = |k: &str| -> u64 {
                bv.get(k).and_then(|x| x.as_u64()).unwrap_or(0)
            };
            stages.push(StageInfo {
                index: sv
                    .get("index")
                    .and_then(|i| i.as_u64())
                    .ok_or_else(|| anyhow!("stage missing index"))?
                    as usize,
                params: specs("params")?,
                input: one("input")?,
                output: one("output")?,
                gx: one("gx")?,
                res1: specs("res1")?,
                res2: specs("res2")?,
                inter: specs("inter")?,
                grads: specs("grads")?,
                bytes: ByteClasses {
                    params: bu("params"),
                    res1: bu("res1"),
                    res2: bu("res2"),
                    inter: bu("inter"),
                    grads: bu("grads"),
                    activation: bu("activation"),
                },
                init: a("init")?,
                fwd: a("fwd")?,
                bwd_p1: a("bwd_p1")?,
                bwd_p2: a("bwd_p2")?,
                bwd_p2_concat: a("bwd_p2_concat")?,
                opt: a("opt")?,
            });
        }
        let lv = v.get("loss").ok_or_else(|| anyhow!("missing loss"))?;
        Ok(Manifest {
            preset: s("preset")?,
            arch: s("arch")?,
            n_stages: u("stages")?,
            microbatch: u("microbatch")?,
            samples_per_microbatch: u("samples_per_microbatch")?,
            concat_m: u("n_microbatches_concat")?,
            optimizer: s("optimizer")?,
            stages,
            loss: art(lv)?,
            logits: TensorSpec::from_json(
                lv.get("logits").ok_or_else(|| anyhow!("loss missing logits"))?,
            )?,
            labels: TensorSpec::from_json(
                lv.get("labels").ok_or_else(|| anyhow!("loss missing labels"))?,
            )?,
            dir: dir.to_path_buf(),
        })
    }

    /// Total parameter count across stages.
    pub fn total_params(&self) -> usize {
        self.stages.iter().map(|s| s.param_count()).sum()
    }

    /// Simulator memory model (per-microbatch byte classes).
    pub fn mem_model(&self) -> crate::sim::MemModel {
        crate::sim::MemModel {
            // params + grads + 2 opt slots (m, v) — resident all step
            static_bytes: self
                .stages
                .iter()
                .map(|s| s.bytes.params * 3 + s.bytes.grads)
                .collect(),
            res1: self.stages.iter().map(|s| s.bytes.res1).collect(),
            res2: self.stages.iter().map(|s| s.bytes.res2).collect(),
            inter: self.stages.iter().map(|s| s.bytes.inter).collect(),
        }
    }

    /// Simulator cost model from the manifest's XLA flops estimates,
    /// normalized so the mean fwd cost is 1.0 (relative shape is what
    /// matters; calibrate absolute scale with measured seconds/flop).
    /// Normalization divides by the **true** mean fwd flops, whatever
    /// its magnitude — only a degenerate non-positive mean (all flops
    /// missing or zero) falls back to unit scale.  (Clamping the mean
    /// up to 1.0, as this once did, silently left every manifest with
    /// sub-1.0 mean fwd flops — e.g. tiny synthetic presets —
    /// *unnormalized*.)
    pub fn cost_model_from_flops(&self, comm: f64) -> crate::sim::CostModel {
        let f: Vec<f64> = self
            .stages
            .iter()
            .map(|s| s.fwd.flops.unwrap_or(1.0))
            .collect();
        let mean = f.iter().sum::<f64>() / f.len() as f64;
        let scale = if mean > 0.0 { 1.0 / mean } else { 1.0 };
        let get = |sel: fn(&StageInfo) -> &Artifact| -> Vec<f64> {
            self.stages
                .iter()
                .map(|s| sel(s).flops.unwrap_or(1.0) * scale)
                .collect()
        };
        crate::sim::CostModel {
            fwd: get(|s| &s.fwd),
            p1: get(|s| &s.bwd_p1),
            p2: get(|s| &s.bwd_p2),
            opt: get(|s| &s.opt),
            loss: self.loss.flops.unwrap_or(0.0) * scale,
            comm,
            comm_inter_node: 0.0,
            ranks_per_node: usize::MAX,
            concat_factor: 1.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "preset": "t", "arch": "transformer", "stages": 1, "microbatch": 2,
      "samples_per_microbatch": 2, "n_microbatches_concat": 4,
      "optimizer": "adam", "lr": 0.001,
      "stage": [{
        "index": 0,
        "params": [{"name": "w", "shape": [4, 4], "dtype": "float32", "bytes": 64}],
        "input": {"shape": [2, 8], "dtype": "int32", "bytes": 64},
        "output": {"shape": [2, 8, 4], "dtype": "float32", "bytes": 256},
        "gx": {"shape": [2, 8], "dtype": "float32", "bytes": 64},
        "res1": [], "res2": [{"shape": [2, 8], "dtype": "int32", "bytes": 64}],
        "inter": [{"shape": [2, 8, 4], "dtype": "float32", "bytes": 256}],
        "res2_batch": [true], "inter_batch": [true],
        "grads": [{"shape": [4, 4], "dtype": "float32", "bytes": 64}],
        "bytes": {"params": 64, "res1": 0, "res2": 64, "inter": 256,
                  "grads": 64, "activation": 256},
        "artifacts": {
          "init": {"file": "s0_init.hlo.txt", "flops": 10},
          "fwd": {"file": "s0_fwd.hlo.txt", "flops": 100},
          "bwd_p1": {"file": "s0_p1.hlo.txt", "flops": 110},
          "bwd_p2": {"file": "s0_p2.hlo.txt", "flops": 90},
          "bwd_p2_concat": {"file": "s0_p2c.hlo.txt", "flops": 360},
          "opt": {"file": "s0_opt.hlo.txt", "flops": 5}
        }
      }],
      "loss": {"file": "loss.hlo.txt", "flops": 7,
               "logits": {"shape": [2, 8, 4], "dtype": "float32", "bytes": 256},
               "labels": {"shape": [2, 8], "dtype": "int32", "bytes": 64}}
    }"#;

    #[test]
    fn parses_sample_manifest() {
        let v = Json::parse(SAMPLE).unwrap();
        let m = Manifest::from_json(&v, Path::new("/tmp/x")).unwrap();
        assert_eq!(m.arch, "transformer");
        assert_eq!(m.stages.len(), 1);
        let st = &m.stages[0];
        assert_eq!(st.param_count(), 16);
        assert_eq!(st.bytes.res2, 64);
        assert_eq!(st.fwd.flops, Some(100.0));
        assert!(st.fwd.file.ends_with("s0_fwd.hlo.txt"));
        assert_eq!(m.labels.dtype, DType::I32);
    }

    #[test]
    fn cost_model_normalizes() {
        let v = Json::parse(SAMPLE).unwrap();
        let m = Manifest::from_json(&v, Path::new("/tmp/x")).unwrap();
        let cm = m.cost_model_from_flops(0.0);
        assert!((cm.fwd[0] - 1.0).abs() < 1e-12);
        assert!((cm.p1[0] - 1.1).abs() < 1e-12);
    }

    /// Regression: manifests whose mean fwd flops are below 1.0 used to
    /// escape normalization entirely (the scale denominator was clamped
    /// with `.max(1.0)`); the relative cost *shape* must be identical no
    /// matter the absolute flops magnitude.
    #[test]
    fn cost_model_normalizes_sub_unit_flops_manifests() {
        let tiny = SAMPLE
            .replace("\"flops\": 100", "\"flops\": 0.100")
            .replace("\"flops\": 110", "\"flops\": 0.110")
            .replace("\"flops\": 90", "\"flops\": 0.090")
            .replace("\"flops\": 360", "\"flops\": 0.360")
            .replace("\"flops\": 7", "\"flops\": 0.007")
            .replace("\"flops\": 5", "\"flops\": 0.005");
        let v = Json::parse(&tiny).unwrap();
        let m = Manifest::from_json(&v, Path::new("/tmp/x")).unwrap();
        let cm = m.cost_model_from_flops(0.0);
        // mean fwd == 1.0 even though the raw mean flops are 0.1
        assert!((cm.fwd[0] - 1.0).abs() < 1e-12, "fwd {}", cm.fwd[0]);
        assert!((cm.p1[0] - 1.1).abs() < 1e-12, "p1 {}", cm.p1[0]);
        assert!((cm.p2[0] - 0.9).abs() < 1e-12, "p2 {}", cm.p2[0]);
        assert!((cm.loss - 0.07).abs() < 1e-12, "loss {}", cm.loss);
        // and the shape matches the full-size manifest's exactly
        let big = Manifest::from_json(&Json::parse(SAMPLE).unwrap(),
                                      Path::new("/tmp/x"))
            .unwrap()
            .cost_model_from_flops(0.0);
        for (a, b) in cm.fwd.iter().zip(&big.fwd) {
            assert!((a - b).abs() < 1e-12);
        }
        // degenerate all-zero flops fall back to unit scale, not NaN/inf
        let zeroed = SAMPLE
            .replace("\"flops\": 100", "\"flops\": 0")
            .replace("\"flops\": 110", "\"flops\": 0");
        let v = Json::parse(&zeroed).unwrap();
        let m = Manifest::from_json(&v, Path::new("/tmp/x")).unwrap();
        let cm = m.cost_model_from_flops(0.0);
        assert!(cm.fwd[0].is_finite());
        assert_eq!(cm.fwd[0], 0.0);
    }

    #[test]
    fn mem_model_classes() {
        let v = Json::parse(SAMPLE).unwrap();
        let m = Manifest::from_json(&v, Path::new("/tmp/x")).unwrap();
        let mm = m.mem_model();
        assert_eq!(mm.static_bytes[0], 64 * 3 + 64);
        assert_eq!(mm.res2[0], 64);
        assert_eq!(mm.inter[0], 256);
    }
}
