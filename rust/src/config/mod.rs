//! Run configuration: what to train, with which schedule, for how long.
//!
//! Presets mirror the paper's Table 2 (see `python/compile/presets.py`,
//! which owns the model hyperparameters; this side owns the *run*
//! parameters and resolves artifact locations).

use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Result};

use crate::planner::RobustObjective;
use crate::schedule::ScheduleKind;
use crate::sim::Perturbation;
use crate::util::args::Args;

/// Reject every orphaned flag of a gated cluster in one place: if the
/// gate flag is absent (as a boolean or a valued flag) but some member
/// of `group` was passed, the error names the offending flag *and*
/// lists the whole group, so a typo'd invocation explains the cluster
/// at once.  All three knob clusters below (robust, drift/replan,
/// comm-fault) parse through this helper.
fn require_gate(args: &Args, gate: &str, group: &[&str]) -> Result<()> {
    if args.has(gate) || args.get(gate).is_some() {
        return Ok(());
    }
    for k in group {
        if args.get(k).is_some() {
            let listed = group
                .iter()
                .map(|g| format!("--{g}"))
                .collect::<Vec<_>>()
                .join(", ");
            bail!(
                "--{k} only applies with --{gate} \
                 ({gate} flag group: {listed})"
            );
        }
    }
    Ok(())
}

/// How backward-p2 work is issued (paper Fig 2 / Table 3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum P2Mode {
    /// One `bwd_p2` call per microbatch (accumulating).
    Loop,
    /// Single `bwd_p2_concat` call over all pending microbatches.
    Concat,
}

/// A full run configuration.
#[derive(Debug, Clone)]
pub struct RunConfig {
    pub preset: String,
    pub artifacts: PathBuf,
    pub schedule: ScheduleKind,
    pub two_bp: bool,
    pub n_microbatches: usize,
    pub p2_mode: P2Mode,
    pub steps: usize,
    pub warmup_steps: usize,
    pub seed: u64,
    /// Steps cycle over this many distinct synthetic batches (0 = fresh
    /// random data every step, the paper's throughput setting).
    pub data_cycle: usize,
    /// Print per-step losses/timings.
    pub verbose: bool,
    /// Generate a synthetic stub-backend manifest in-process instead of
    /// loading AOT artifacts (`twobp train --synthetic`; see
    /// `models::synthetic`).
    pub synthetic: bool,
    /// Snapshot per-rank state (params + Adam slots + step counters)
    /// every N steps into `checkpoint_dir` (0 = never).
    pub checkpoint_every: usize,
    /// Where `--checkpoint-every` writes its `step-{N}` directories.
    pub checkpoint_dir: Option<PathBuf>,
    /// Resume from a checkpoint directory before running: either a
    /// `step-{N}` dir itself or a base dir, whose latest step is used.
    pub resume: Option<PathBuf>,
    /// How long a rank may wait *idle* for a peer tensor before
    /// declaring the peer stalled (`RunError::CommTimeout`).
    pub comm_timeout_ms: u64,
    /// Receive poll tick: the latency with which a rank observes a
    /// failure elsewhere in the cluster.
    pub comm_backoff_ms: u64,
    /// Deterministic stub fault injection, `<rank>:<kind>@<call>` with
    /// kind `fail` or `stall-<ns>` (synthetic runs only; the directive
    /// lands on that rank's fwd executable — see docs/ROBUSTNESS.md §6).
    pub fault: Option<String>,
    /// Seeded comm-layer injection: probability each p2p send is
    /// silently dropped (0 disables).
    pub comm_drop_prob: f64,
    /// Seeded comm-layer injection: fixed delay per delivered send.
    pub comm_delay_ns: u64,
    /// Seed for the comm-layer injector (drops/delays are a pure
    /// function of this seed, the link, and the send index).
    pub comm_fault_seed: u64,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            preset: "transformer-tiny".into(),
            artifacts: PathBuf::from("artifacts"),
            schedule: ScheduleKind::OneF1B1,
            two_bp: true,
            n_microbatches: 0, // 0 = schedule default (paper convention)
            p2_mode: P2Mode::Loop,
            steps: 4,
            warmup_steps: 1,
            seed: 0,
            data_cycle: 0,
            verbose: false,
            synthetic: false,
            checkpoint_every: 0,
            checkpoint_dir: None,
            resume: None,
            comm_timeout_ms: 5000,
            comm_backoff_ms: 10,
            fault: None,
            comm_drop_prob: 0.0,
            comm_delay_ns: 0,
            comm_fault_seed: 0,
        }
    }
}

impl RunConfig {
    /// Build from parsed CLI args (shared by `twobp` subcommands).
    pub fn from_args(args: &Args) -> Result<RunConfig> {
        let mut cfg = RunConfig {
            preset: args.get_or("preset", "transformer-tiny").to_string(),
            artifacts: PathBuf::from(args.get_or("artifacts", "artifacts")),
            steps: args.get_usize("steps", 4),
            warmup_steps: args.get_usize("warmup", 1),
            n_microbatches: args.get_usize("microbatches", 0),
            seed: args.get_usize("seed", 0) as u64,
            data_cycle: args.get_usize("data-cycle", 0),
            two_bp: !args.has("no-2bp"),
            verbose: args.has("verbose"),
            synthetic: args.has("synthetic"),
            checkpoint_every: args.get_usize("checkpoint-every", 0),
            checkpoint_dir: args.get("checkpoint-dir").map(PathBuf::from),
            resume: args.get("resume").map(PathBuf::from),
            comm_timeout_ms: args.get_usize("comm-timeout-ms", 5000) as u64,
            comm_backoff_ms: args.get_usize("comm-backoff-ms", 10) as u64,
            fault: args.get("fault").map(String::from),
            ..RunConfig::default()
        };
        let comm_fault = CommFaultConfig::from_args(args)?;
        cfg.comm_drop_prob = comm_fault.drop_prob;
        cfg.comm_delay_ns = comm_fault.delay_ns;
        cfg.comm_fault_seed = comm_fault.seed;
        if let Some(kind) = args
            .get_parsed::<ScheduleKind>("schedule")
            .map_err(|e| anyhow::anyhow!(e))?
        {
            cfg.schedule = kind;
        }
        if args.has("concat-p2") {
            cfg.p2_mode = P2Mode::Concat;
        }
        if cfg.checkpoint_every > 0 && cfg.checkpoint_dir.is_none() {
            bail!("--checkpoint-every requires --checkpoint-dir <dir>");
        }
        if cfg.checkpoint_every == 0 && cfg.checkpoint_dir.is_some() {
            bail!("--checkpoint-dir only applies with --checkpoint-every");
        }
        if cfg.fault.is_some() && !cfg.synthetic {
            bail!(
                "--fault injects into the in-process synthetic preset; \
                 it needs --synthetic"
            );
        }
        Ok(cfg)
    }

    pub fn microbatches(&self, n_ranks: usize) -> usize {
        if self.n_microbatches == 0 {
            self.schedule.default_microbatches(n_ranks)
        } else {
            self.n_microbatches
        }
    }
}

/// The seeded comm-chaos knob cluster
/// (`--comm-drop-prob/--comm-delay-ns/--comm-fault-seed`), parsed as a
/// unit.  A seed with nothing to seed is a typo'd run, so an orphaned
/// `--comm-fault-seed` is rejected with the whole group named.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct CommFaultConfig {
    /// Probability each p2p send is silently dropped (0 disables).
    pub drop_prob: f64,
    /// Fixed delay per delivered send, nanoseconds.
    pub delay_ns: u64,
    /// Seed for the injector (drops/delays are a pure function of this
    /// seed, the link, and the send index).
    pub seed: u64,
}

impl CommFaultConfig {
    pub fn from_args(args: &Args) -> Result<CommFaultConfig> {
        let cfg = CommFaultConfig {
            drop_prob: args.get_f64("comm-drop-prob", 0.0),
            delay_ns: args.get_usize("comm-delay-ns", 0) as u64,
            seed: args.get_usize("comm-fault-seed", 0) as u64,
        };
        if !(0.0..=1.0).contains(&cfg.drop_prob) {
            bail!("--comm-drop-prob must be in [0, 1]");
        }
        if args.get("comm-fault-seed").is_some()
            && cfg.drop_prob == 0.0
            && cfg.delay_ns == 0
        {
            bail!(
                "--comm-fault-seed only applies with --comm-drop-prob \
                 or --comm-delay-ns (comm-fault flag group: \
                 --comm-drop-prob, --comm-delay-ns, --comm-fault-seed)"
            );
        }
        Ok(cfg)
    }
}

/// Parse `--straggler <rank>:<mult>[,<rank>:<mult>...]` into the
/// per-rank slowdown pairs of [`Perturbation::stragglers`].
pub fn parse_stragglers(s: &str) -> Result<Vec<(usize, f64)>> {
    s.split(',')
        .map(|part| {
            let (r, m) = part.split_once(':').ok_or_else(|| {
                anyhow!("bad --straggler '{part}': expected <rank>:<mult>")
            })?;
            let rank = r
                .trim()
                .parse::<usize>()
                .map_err(|e| anyhow!("bad --straggler rank '{r}': {e}"))?;
            let mult = m
                .trim()
                .parse::<f64>()
                .map_err(|e| anyhow!("bad --straggler mult '{m}': {e}"))?;
            if mult <= 0.0 {
                return Err(anyhow!(
                    "bad --straggler mult '{m}': must be > 0"
                ));
            }
            Ok((rank, mult))
        })
        .collect()
}

/// Which flags the `--robust` gate unlocks (shared by the parser, its
/// rejection messages, and the serve daemon's docs).
pub const ROBUST_FLAG_GROUP: [&str; 6] = [
    "jitter", "straggler", "spike-prob", "spike-mult", "pert-seed",
    "trials",
];

/// The `--robust` tail-objective flag cluster, parsed as a unit:
/// `objective` is `None` without the gate flag (orphaned perturbation
/// knobs rejected through [`require_gate`] with the whole group
/// listed), `Some` with it — jitter defaulting to 0.05 and the rest to
/// the [`Perturbation`]/[`RobustObjective`] defaults.
#[derive(Debug, Clone, Default)]
pub struct RobustConfig {
    pub objective: Option<RobustObjective>,
}

impl RobustConfig {
    pub fn from_args(args: &Args) -> Result<RobustConfig> {
        require_gate(args, "robust", &ROBUST_FLAG_GROUP)?;
        if !args.has("robust") {
            return Ok(RobustConfig::default());
        }
        let base = Perturbation::default();
        let pert = Perturbation {
            jitter: args.get_f64("jitter", 0.05),
            stragglers: match args.get("straggler") {
                Some(s) => parse_stragglers(s)?,
                None => Vec::new(),
            },
            comm_spike_prob: args.get_f64("spike-prob", base.comm_spike_prob),
            comm_spike_mult: args.get_f64("spike-mult", base.comm_spike_mult),
            seed: args.get_usize("pert-seed", base.seed as usize) as u64,
        };
        if !(0.0..=1.0).contains(&pert.comm_spike_prob) {
            return Err(anyhow!("--spike-prob must be in [0, 1]"));
        }
        let defaults = RobustObjective::default();
        Ok(RobustConfig {
            objective: Some(RobustObjective {
                pert,
                trials: args.get_usize("trials", defaults.trials).max(1),
            }),
        })
    }
}

/// The `--replan` drift-monitor knob cluster
/// (`--drift-threshold/--drift-window/--max-replans/--drift-cooldown`),
/// parsed as a unit and kept as raw values so `twobp tune` parses
/// without the pjrt feature; `pipeline::DriftConfig` mirrors the
/// fields.  Orphaned knobs are rejected through [`require_gate`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DriftFlags {
    /// Relative slowdown that counts as a slow step.
    pub threshold: f64,
    /// Consecutive slow steps before replanning (>= 1).
    pub window: usize,
    /// Replans allowed per run.
    pub max_replans: usize,
    /// Post-replan steps ignored by the monitor.
    pub cooldown: usize,
}

impl Default for DriftFlags {
    fn default() -> Self {
        // mirrors pipeline::DriftConfig::default()
        DriftFlags { threshold: 0.3, window: 2, max_replans: 1, cooldown: 1 }
    }
}

impl DriftFlags {
    pub fn from_args(args: &Args) -> Result<DriftFlags> {
        require_gate(
            args,
            "replan",
            &["drift-threshold", "drift-window", "max-replans",
              "drift-cooldown"],
        )?;
        let d = DriftFlags::default();
        let cfg = DriftFlags {
            threshold: args.get_f64("drift-threshold", d.threshold),
            window: args.get_usize("drift-window", d.window).max(1),
            max_replans: args.get_usize("max-replans", d.max_replans),
            cooldown: args.get_usize("drift-cooldown", d.cooldown),
        };
        if cfg.threshold <= 0.0 {
            bail!("--drift-threshold must be > 0");
        }
        Ok(cfg)
    }
}

/// Which flags the `--co-search` gate unlocks (shared by the parser,
/// its rejection messages, and the serve daemon's docs).
pub const CO_SEARCH_FLAG_GROUP: [&str; 4] =
    ["devices", "layers", "allreduce-per-byte", "migrations"];

/// The `--co-search` partition-search flag cluster, parsed as a unit
/// (orphaned members rejected through [`require_gate`]).  Raw values
/// only — `planner::CoSearchConfig` is built at the call site, where
/// the per-layer [`crate::planner::ModelProfile`] and the inner
/// [`crate::planner::BeamConfig`] are known.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CoSearchFlags {
    /// `--co-search` was passed; the other fields only matter then.
    pub enabled: bool,
    /// Total devices to split dp × pp (`--devices`, default 4).
    pub devices: usize,
    /// Model layer count (`--layers`; 0 = 2 × devices, a grid with
    /// room for every pipeline depth up to `devices`).
    pub layers: usize,
    /// Ring-allreduce seconds per gradient byte
    /// (`--allreduce-per-byte`, default 2e-11 ≈ 50 GB/s links).
    pub allreduce_per_byte: f64,
    /// Boundary-migration budget per cell (`--migrations`, default 8).
    pub migrations: usize,
}

impl Default for CoSearchFlags {
    fn default() -> Self {
        CoSearchFlags {
            enabled: false,
            devices: 4,
            layers: 0,
            allreduce_per_byte: 2e-11,
            migrations: 8,
        }
    }
}

impl CoSearchFlags {
    pub fn from_args(args: &Args) -> Result<CoSearchFlags> {
        require_gate(args, "co-search", &CO_SEARCH_FLAG_GROUP)?;
        let d = CoSearchFlags::default();
        let cfg = CoSearchFlags {
            enabled: args.has("co-search"),
            devices: args.get_usize("devices", d.devices),
            layers: args.get_usize("layers", d.layers),
            allreduce_per_byte: args
                .get_f64("allreduce-per-byte", d.allreduce_per_byte),
            migrations: args.get_usize("migrations", d.migrations),
        };
        if cfg.enabled {
            if cfg.devices == 0 {
                bail!("--devices must be >= 1");
            }
            if cfg.allreduce_per_byte < 0.0 {
                bail!("--allreduce-per-byte must be >= 0");
            }
        }
        Ok(cfg)
    }

    /// The resolved layer count (`--layers`, defaulting to 2 × devices).
    pub fn layer_count(&self) -> usize {
        if self.layers == 0 {
            2 * self.devices
        } else {
            self.layers
        }
    }
}

/// Configuration of the measured-cost calibration loop (`twobp tune
/// --synthetic` / `--manifest <preset-dir>`): how many executor steps
/// to calibrate on, and how many to execute the tuned winner for.
#[derive(Debug, Clone)]
pub struct CalibConfig {
    /// Tune on an in-process skewed synthetic preset
    /// (`models::synthetic::SyntheticSpec::skewed`) — no artifacts
    /// needed, fully offline against the stub backend.
    pub synthetic: bool,
    /// Explicit preset directory (`<artifacts-root>/<preset>`) to
    /// calibrate against instead.
    pub manifest_dir: Option<PathBuf>,
    /// Calibration steps under the contention-free naive schedule
    /// (clamped to at least 2 so per-op means have >= 2 samples).
    pub calib_steps: usize,
    /// Steps to execute the tuned winner for (predicted-vs-executed).
    pub exec_steps: usize,
    pub seed: u64,
    /// Run the self-healing loop (`--replan`): execute in one-step
    /// chunks under a drift monitor, re-calibrating + re-tuning when
    /// measured makespans pull away from the prediction.
    pub replan: bool,
    /// The drift-monitor knob cluster (parsed via
    /// [`DriftFlags::from_args`], gated on `--replan`).
    pub drift: DriftFlags,
}

impl CalibConfig {
    /// Build from `twobp tune` args; errors unless exactly one of
    /// `--synthetic` / `--manifest <dir>` selects the cost source.
    pub fn from_args(args: &Args) -> Result<CalibConfig> {
        let synthetic = args.has("synthetic");
        let manifest_dir = args.get("manifest").map(PathBuf::from);
        if synthetic && manifest_dir.is_some() {
            bail!(
                "--synthetic generates its own preset; drop --manifest \
                 (or drop --synthetic to calibrate on real artifacts)"
            );
        }
        if !synthetic && manifest_dir.is_none() {
            bail!(
                "measured-cost tuning needs a cost source: --synthetic \
                 or --manifest <preset-dir>"
            );
        }
        let replan = args.has("replan");
        if replan && !synthetic {
            bail!(
                "--replan needs --synthetic: the drift-replan loop runs \
                 against the self-drifting synthetic preset (real \
                 manifests don't change cost mid-run offline)"
            );
        }
        Ok(CalibConfig {
            synthetic,
            manifest_dir,
            calib_steps: args.get_usize("calib-steps", 2).max(2),
            exec_steps: args.get_usize("steps", 2).max(1),
            seed: args.get_usize("seed", 0) as u64,
            replan,
            drift: DriftFlags::from_args(args)?,
        })
    }

    /// Split a `--manifest <artifacts-root>/<preset>` path into the
    /// (artifacts root, preset name) pair `Manifest::load` expects.
    pub fn split_manifest(dir: &Path) -> Result<(PathBuf, String)> {
        let preset = dir
            .file_name()
            .and_then(|n| n.to_str())
            .ok_or_else(|| {
                anyhow!(
                    "--manifest needs a preset directory path, got {}",
                    dir.display()
                )
            })?
            .to_string();
        let root = match dir.parent() {
            Some(p) if p.as_os_str().is_empty() => PathBuf::from("."),
            Some(p) => p.to_path_buf(),
            None => PathBuf::from("."),
        };
        Ok((root, preset))
    }
}

/// The four benchmark models of the paper's Fig 3/4, in CPU-scale form.
pub const BENCH_PRESETS: [&str; 4] =
    ["transformer-s", "bert-s", "mamba-s", "resnet-s"];

/// The paper's Table 2, rendered for `twobp config --list`.
pub fn table2() -> crate::util::table::Table {
    let mut t = crate::util::table::Table::new(
        &["Model", "Data type", "Micro-Batch size", "Optimizer",
          "CPU-scale preset"],
    )
    .with_title("Table 2: model hyperparameters used for benchmarking");
    t.row(vec!["Mamba-1.4b".into(), "fp16→f32".into(), "2".into(),
               "AdamW".into(), "mamba-s".into()]);
    t.row(vec!["LLaMa-7b".into(), "fp16→f32".into(), "1".into(),
               "Adam".into(), "transformer-s".into()]);
    t.row(vec!["ResNet152".into(), "fp32".into(), "8".into(),
               "SGD".into(), "resnet-s".into()]);
    t.row(vec!["BERT-Large".into(), "fp16→f32".into(), "2".into(),
               "Adam".into(), "bert-s".into()]);
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(v: &[&str]) -> Vec<String> {
        v.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn from_args_full() {
        let args = Args::parse(
            &sv(&["--preset", "bert-s", "--schedule", "1f1b-2",
                  "--steps", "7", "--no-2bp", "--concat-p2", "--synthetic"]),
            &["no-2bp", "concat-p2", "verbose", "synthetic"],
        );
        let cfg = RunConfig::from_args(&args).unwrap();
        assert_eq!(cfg.preset, "bert-s");
        assert_eq!(cfg.schedule, ScheduleKind::OneF1B2);
        assert_eq!(cfg.steps, 7);
        assert!(!cfg.two_bp);
        assert_eq!(cfg.p2_mode, P2Mode::Concat);
        assert!(cfg.synthetic);
    }

    #[test]
    fn fault_and_checkpoint_flags_parse_and_are_gated() {
        let flags = ["synthetic"];
        let cfg = RunConfig::from_args(&Args::parse(
            &sv(&["--synthetic", "--checkpoint-every", "2",
                  "--checkpoint-dir", "/tmp/ck", "--resume", "/tmp/ck",
                  "--fault", "1:fail@3", "--comm-timeout-ms", "250",
                  "--comm-backoff-ms", "5", "--comm-drop-prob", "0.25",
                  "--comm-delay-ns", "1000", "--comm-fault-seed", "7"]),
            &flags,
        ))
        .unwrap();
        assert_eq!(cfg.checkpoint_every, 2);
        assert_eq!(cfg.checkpoint_dir, Some(PathBuf::from("/tmp/ck")));
        assert_eq!(cfg.resume, Some(PathBuf::from("/tmp/ck")));
        assert_eq!(cfg.fault.as_deref(), Some("1:fail@3"));
        assert_eq!(cfg.comm_timeout_ms, 250);
        assert_eq!(cfg.comm_backoff_ms, 5);
        assert_eq!(cfg.comm_drop_prob, 0.25);
        assert_eq!(cfg.comm_delay_ns, 1000);
        assert_eq!(cfg.comm_fault_seed, 7);
        // defaults: supervision on, injection off
        let d = RunConfig::from_args(&Args::parse(&sv(&[]), &flags)).unwrap();
        assert_eq!(d.checkpoint_every, 0);
        assert_eq!(d.comm_timeout_ms, 5000);
        assert_eq!(d.comm_drop_prob, 0.0);
        for argv in [
            // checkpointing needs both halves
            vec!["--checkpoint-every", "2"],
            vec!["--checkpoint-dir", "/tmp/ck"],
            // stub faults only exist on the synthetic preset
            vec!["--fault", "1:fail@3"],
            // probability out of range
            vec!["--synthetic", "--comm-drop-prob", "1.5"],
            // a seed with nothing to seed is a typo'd run
            vec!["--comm-fault-seed", "7"],
        ] {
            assert!(
                RunConfig::from_args(&Args::parse(&sv(&argv), &flags))
                    .is_err(),
                "{argv:?}"
            );
        }
    }

    #[test]
    fn rejects_bad_schedule() {
        let args = Args::parse(&sv(&["--schedule", "zigzag"]), &[]);
        assert!(RunConfig::from_args(&args).is_err());
    }

    #[test]
    fn calib_config_needs_exactly_one_source() {
        let flags = ["synthetic"];
        let none = Args::parse(&sv(&[]), &flags);
        assert!(CalibConfig::from_args(&none).is_err());
        let synth = Args::parse(
            &sv(&["--synthetic", "--calib-steps", "1", "--steps", "3"]),
            &flags,
        );
        let c = CalibConfig::from_args(&synth).unwrap();
        assert!(c.synthetic);
        assert_eq!(c.calib_steps, 2, "clamped to >= 2 samples");
        assert_eq!(c.exec_steps, 3);
        let both = Args::parse(
            &sv(&["--synthetic", "--manifest", "artifacts/x"]),
            &flags,
        );
        assert!(CalibConfig::from_args(&both).is_err());
        let man = Args::parse(&sv(&["--manifest", "artifacts/bert-s"]),
                              &flags);
        let c = CalibConfig::from_args(&man).unwrap();
        assert!(!c.synthetic);
        let (root, preset) =
            CalibConfig::split_manifest(c.manifest_dir.as_ref().unwrap())
                .unwrap();
        assert_eq!(root, PathBuf::from("artifacts"));
        assert_eq!(preset, "bert-s");
        let bare = CalibConfig::split_manifest(Path::new("solo")).unwrap();
        assert_eq!(bare.0, PathBuf::from("."));
        assert_eq!(bare.1, "solo");
    }

    #[test]
    fn co_search_knobs_parse_and_are_gated() {
        let flags = ["co-search"];
        let c = CoSearchFlags::from_args(&Args::parse(
            &sv(&["--co-search", "--devices", "8", "--layers", "24",
                  "--allreduce-per-byte", "1e-10", "--migrations", "3"]),
            &flags,
        ))
        .unwrap();
        assert!(c.enabled);
        assert_eq!(c.devices, 8);
        assert_eq!(c.layer_count(), 24);
        assert_eq!(c.allreduce_per_byte, 1e-10);
        assert_eq!(c.migrations, 3);
        // defaults: 4 devices, 2 × devices layers
        let d = CoSearchFlags::from_args(&Args::parse(
            &sv(&["--co-search"]), &flags,
        ))
        .unwrap();
        assert_eq!(d.devices, 4);
        assert_eq!(d.layer_count(), 8);
        assert!(!CoSearchFlags::from_args(&Args::parse(&sv(&[]), &flags))
            .unwrap()
            .enabled);
        // orphaned members are rejected, naming the whole group
        for k in CO_SEARCH_FLAG_GROUP {
            let argv = vec![format!("--{k}"), "2".to_string()];
            let err = CoSearchFlags::from_args(&Args::parse(
                &argv, &flags,
            ))
            .unwrap_err()
            .to_string();
            assert!(
                err.contains(&format!("--{k} only applies with --co-search")),
                "{k}: {err}"
            );
            assert!(err.contains("--allreduce-per-byte"), "{k}: {err}");
        }
        // degenerate values
        assert!(CoSearchFlags::from_args(&Args::parse(
            &sv(&["--co-search", "--devices", "0"]),
            &flags,
        ))
        .is_err());
    }

    #[test]
    fn replan_knobs_parse_and_are_gated() {
        let flags = ["synthetic", "replan"];
        let c = CalibConfig::from_args(&Args::parse(
            &sv(&["--synthetic", "--replan", "--drift-threshold", "0.5",
                  "--drift-window", "3", "--max-replans", "2",
                  "--drift-cooldown", "0"]),
            &flags,
        ))
        .unwrap();
        assert!(c.replan);
        assert_eq!(c.drift.threshold, 0.5);
        assert_eq!(c.drift.window, 3);
        assert_eq!(c.drift.max_replans, 2);
        assert_eq!(c.drift.cooldown, 0);
        // defaults mirror pipeline::DriftConfig::default()
        let d = CalibConfig::from_args(&Args::parse(
            &sv(&["--synthetic", "--replan"]),
            &flags,
        ))
        .unwrap();
        assert_eq!(d.drift, DriftFlags::default());
        // --replan needs --synthetic; drift knobs need --replan
        for argv in [
            vec!["--manifest", "artifacts/bert-s", "--replan"],
            vec!["--synthetic", "--drift-window", "3"],
            vec!["--synthetic", "--replan", "--drift-threshold", "0"],
        ] {
            assert!(
                CalibConfig::from_args(&Args::parse(&sv(&argv), &flags))
                    .is_err(),
                "{argv:?}"
            );
        }
    }

    #[test]
    fn drift_knobs_rejected_with_group_message() {
        // one rejection per knob in the cluster, each naming the group
        for k in ["drift-threshold", "drift-window", "max-replans",
                  "drift-cooldown"] {
            let argv = vec![format!("--{k}"), "2".to_string()];
            let args = Args::parse(&argv, &["replan"]);
            let err = DriftFlags::from_args(&args).unwrap_err().to_string();
            assert!(
                err.contains(&format!("--{k} only applies with --replan")),
                "{k}: {err}"
            );
            assert!(err.contains("replan flag group:"), "{k}: {err}");
            assert!(err.contains("--drift-cooldown"), "{k}: {err}");
        }
    }

    #[test]
    fn robust_config_parses_the_cluster() {
        let flags = ["robust"];
        // without the gate: no objective
        let none =
            RobustConfig::from_args(&Args::parse(&sv(&[]), &flags)).unwrap();
        assert!(none.objective.is_none());
        // gate alone: library defaults with the CLI's 5% jitter
        let bare =
            RobustConfig::from_args(&Args::parse(&sv(&["--robust"]), &flags))
                .unwrap()
                .objective
                .unwrap();
        assert_eq!(bare.pert.jitter, 0.05);
        assert!(bare.pert.stragglers.is_empty());
        assert!(bare.trials >= 1);
        // full cluster
        let full = RobustConfig::from_args(&Args::parse(
            &sv(&["--robust", "--jitter", "0.1", "--straggler",
                  "1:1.5,3:2.0", "--spike-prob", "0.2", "--spike-mult",
                  "8", "--pert-seed", "7", "--trials", "5"]),
            &flags,
        ))
        .unwrap()
        .objective
        .unwrap();
        assert_eq!(full.pert.jitter, 0.1);
        assert_eq!(full.pert.stragglers, vec![(1, 1.5), (3, 2.0)]);
        assert_eq!(full.pert.comm_spike_prob, 0.2);
        assert_eq!(full.pert.comm_spike_mult, 8.0);
        assert_eq!(full.pert.seed, 7);
        assert_eq!(full.trials, 5);
        // --trials 0 is clamped, not an error
        let clamped = RobustConfig::from_args(&Args::parse(
            &sv(&["--robust", "--trials", "0"]),
            &flags,
        ))
        .unwrap()
        .objective
        .unwrap();
        assert_eq!(clamped.trials, 1);
    }

    #[test]
    fn robust_knobs_rejected_with_group_message() {
        for k in ROBUST_FLAG_GROUP {
            let argv = vec![format!("--{k}"), "1".to_string()];
            let args = Args::parse(&argv, &["robust"]);
            let err = RobustConfig::from_args(&args).unwrap_err().to_string();
            assert!(
                err.contains(&format!("--{k} only applies with --robust")),
                "{k}: {err}"
            );
            assert!(err.contains("robust flag group:"), "{k}: {err}");
            assert!(err.contains("--pert-seed"), "{k}: {err}");
        }
        // malformed members of the cluster still fail under the gate
        for argv in [
            vec!["--robust", "--straggler", "nonsense"],
            vec!["--robust", "--straggler", "1:0"],
            vec!["--robust", "--spike-prob", "1.5"],
        ] {
            assert!(
                RobustConfig::from_args(&Args::parse(&sv(&argv),
                                                     &["robust"]))
                    .is_err(),
                "{argv:?}"
            );
        }
    }

    #[test]
    fn comm_fault_config_parses_and_gates_the_seed() {
        let cfg = CommFaultConfig::from_args(&Args::parse(
            &sv(&["--comm-drop-prob", "0.25", "--comm-delay-ns", "1000",
                  "--comm-fault-seed", "7"]),
            &[],
        ))
        .unwrap();
        assert_eq!(cfg, CommFaultConfig {
            drop_prob: 0.25, delay_ns: 1000, seed: 7,
        });
        assert_eq!(
            CommFaultConfig::from_args(&Args::parse(&sv(&[]), &[])).unwrap(),
            CommFaultConfig::default(),
        );
        // orphaned seed: rejected, message lists the group
        let err = CommFaultConfig::from_args(&Args::parse(
            &sv(&["--comm-fault-seed", "7"]),
            &[],
        ))
        .unwrap_err()
        .to_string();
        assert!(err.contains("--comm-fault-seed only applies"), "{err}");
        assert!(err.contains("comm-fault flag group:"), "{err}");
        // out-of-range probability
        assert!(CommFaultConfig::from_args(&Args::parse(
            &sv(&["--comm-drop-prob", "1.5"]),
            &[],
        ))
        .is_err());
    }

    #[test]
    fn default_microbatches_follow_schedule() {
        let cfg = RunConfig { schedule: ScheduleKind::OneF1B2,
                              ..RunConfig::default() };
        assert_eq!(cfg.microbatches(4), 8);
    }
}
