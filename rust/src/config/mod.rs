//! Run configuration: what to train, with which schedule, for how long.
//!
//! Presets mirror the paper's Table 2 (see `python/compile/presets.py`,
//! which owns the model hyperparameters; this side owns the *run*
//! parameters and resolves artifact locations).

use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Result};

use crate::schedule::ScheduleKind;
use crate::util::args::Args;

/// How backward-p2 work is issued (paper Fig 2 / Table 3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum P2Mode {
    /// One `bwd_p2` call per microbatch (accumulating).
    Loop,
    /// Single `bwd_p2_concat` call over all pending microbatches.
    Concat,
}

/// A full run configuration.
#[derive(Debug, Clone)]
pub struct RunConfig {
    pub preset: String,
    pub artifacts: PathBuf,
    pub schedule: ScheduleKind,
    pub two_bp: bool,
    pub n_microbatches: usize,
    pub p2_mode: P2Mode,
    pub steps: usize,
    pub warmup_steps: usize,
    pub seed: u64,
    /// Steps cycle over this many distinct synthetic batches (0 = fresh
    /// random data every step, the paper's throughput setting).
    pub data_cycle: usize,
    /// Print per-step losses/timings.
    pub verbose: bool,
    /// Generate a synthetic stub-backend manifest in-process instead of
    /// loading AOT artifacts (`twobp train --synthetic`; see
    /// `models::synthetic`).
    pub synthetic: bool,
    /// Snapshot per-rank state (params + Adam slots + step counters)
    /// every N steps into `checkpoint_dir` (0 = never).
    pub checkpoint_every: usize,
    /// Where `--checkpoint-every` writes its `step-{N}` directories.
    pub checkpoint_dir: Option<PathBuf>,
    /// Resume from a checkpoint directory before running: either a
    /// `step-{N}` dir itself or a base dir, whose latest step is used.
    pub resume: Option<PathBuf>,
    /// How long a rank may wait *idle* for a peer tensor before
    /// declaring the peer stalled (`RunError::CommTimeout`).
    pub comm_timeout_ms: u64,
    /// Receive poll tick: the latency with which a rank observes a
    /// failure elsewhere in the cluster.
    pub comm_backoff_ms: u64,
    /// Deterministic stub fault injection, `<rank>:<kind>@<call>` with
    /// kind `fail` or `stall-<ns>` (synthetic runs only; the directive
    /// lands on that rank's fwd executable — see docs/ROBUSTNESS.md §6).
    pub fault: Option<String>,
    /// Seeded comm-layer injection: probability each p2p send is
    /// silently dropped (0 disables).
    pub comm_drop_prob: f64,
    /// Seeded comm-layer injection: fixed delay per delivered send.
    pub comm_delay_ns: u64,
    /// Seed for the comm-layer injector (drops/delays are a pure
    /// function of this seed, the link, and the send index).
    pub comm_fault_seed: u64,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            preset: "transformer-tiny".into(),
            artifacts: PathBuf::from("artifacts"),
            schedule: ScheduleKind::OneF1B1,
            two_bp: true,
            n_microbatches: 0, // 0 = schedule default (paper convention)
            p2_mode: P2Mode::Loop,
            steps: 4,
            warmup_steps: 1,
            seed: 0,
            data_cycle: 0,
            verbose: false,
            synthetic: false,
            checkpoint_every: 0,
            checkpoint_dir: None,
            resume: None,
            comm_timeout_ms: 5000,
            comm_backoff_ms: 10,
            fault: None,
            comm_drop_prob: 0.0,
            comm_delay_ns: 0,
            comm_fault_seed: 0,
        }
    }
}

impl RunConfig {
    /// Build from parsed CLI args (shared by `twobp` subcommands).
    pub fn from_args(args: &Args) -> Result<RunConfig> {
        let mut cfg = RunConfig {
            preset: args.get_or("preset", "transformer-tiny").to_string(),
            artifacts: PathBuf::from(args.get_or("artifacts", "artifacts")),
            steps: args.get_usize("steps", 4),
            warmup_steps: args.get_usize("warmup", 1),
            n_microbatches: args.get_usize("microbatches", 0),
            seed: args.get_usize("seed", 0) as u64,
            data_cycle: args.get_usize("data-cycle", 0),
            two_bp: !args.has("no-2bp"),
            verbose: args.has("verbose"),
            synthetic: args.has("synthetic"),
            checkpoint_every: args.get_usize("checkpoint-every", 0),
            checkpoint_dir: args.get("checkpoint-dir").map(PathBuf::from),
            resume: args.get("resume").map(PathBuf::from),
            comm_timeout_ms: args.get_usize("comm-timeout-ms", 5000) as u64,
            comm_backoff_ms: args.get_usize("comm-backoff-ms", 10) as u64,
            fault: args.get("fault").map(String::from),
            comm_drop_prob: args.get_f64("comm-drop-prob", 0.0),
            comm_delay_ns: args.get_usize("comm-delay-ns", 0) as u64,
            comm_fault_seed: args.get_usize("comm-fault-seed", 0) as u64,
            ..RunConfig::default()
        };
        if let Some(kind) = args
            .get_parsed::<ScheduleKind>("schedule")
            .map_err(|e| anyhow::anyhow!(e))?
        {
            cfg.schedule = kind;
        }
        if args.has("concat-p2") {
            cfg.p2_mode = P2Mode::Concat;
        }
        if cfg.checkpoint_every > 0 && cfg.checkpoint_dir.is_none() {
            bail!("--checkpoint-every requires --checkpoint-dir <dir>");
        }
        if cfg.checkpoint_every == 0 && cfg.checkpoint_dir.is_some() {
            bail!("--checkpoint-dir only applies with --checkpoint-every");
        }
        if cfg.fault.is_some() && !cfg.synthetic {
            bail!(
                "--fault injects into the in-process synthetic preset; \
                 it needs --synthetic"
            );
        }
        if !(0.0..=1.0).contains(&cfg.comm_drop_prob) {
            bail!("--comm-drop-prob must be in [0, 1]");
        }
        if args.get("comm-fault-seed").is_some()
            && cfg.comm_drop_prob == 0.0
            && cfg.comm_delay_ns == 0
        {
            bail!(
                "--comm-fault-seed only applies with --comm-drop-prob \
                 or --comm-delay-ns"
            );
        }
        Ok(cfg)
    }

    pub fn microbatches(&self, n_ranks: usize) -> usize {
        if self.n_microbatches == 0 {
            self.schedule.default_microbatches(n_ranks)
        } else {
            self.n_microbatches
        }
    }
}

/// Configuration of the measured-cost calibration loop (`twobp tune
/// --synthetic` / `--manifest <preset-dir>`): how many executor steps
/// to calibrate on, and how many to execute the tuned winner for.
#[derive(Debug, Clone)]
pub struct CalibConfig {
    /// Tune on an in-process skewed synthetic preset
    /// (`models::synthetic::SyntheticSpec::skewed`) — no artifacts
    /// needed, fully offline against the stub backend.
    pub synthetic: bool,
    /// Explicit preset directory (`<artifacts-root>/<preset>`) to
    /// calibrate against instead.
    pub manifest_dir: Option<PathBuf>,
    /// Calibration steps under the contention-free naive schedule
    /// (clamped to at least 2 so per-op means have >= 2 samples).
    pub calib_steps: usize,
    /// Steps to execute the tuned winner for (predicted-vs-executed).
    pub exec_steps: usize,
    pub seed: u64,
    /// Run the self-healing loop (`--replan`): execute in one-step
    /// chunks under a drift monitor, re-calibrating + re-tuning when
    /// measured makespans pull away from the prediction.  The knobs
    /// below mirror `pipeline::DriftConfig` (kept as raw values here
    /// so `twobp tune --help` parses without the pjrt feature).
    pub replan: bool,
    /// Relative slowdown that counts as a slow step (`--drift-threshold`).
    pub drift_threshold: f64,
    /// Consecutive slow steps before replanning (`--drift-window`).
    pub drift_window: usize,
    /// Replans allowed per run (`--max-replans`).
    pub max_replans: usize,
    /// Post-replan steps ignored by the monitor (`--drift-cooldown`).
    pub drift_cooldown: usize,
}

impl CalibConfig {
    /// Build from `twobp tune` args; errors unless exactly one of
    /// `--synthetic` / `--manifest <dir>` selects the cost source.
    pub fn from_args(args: &Args) -> Result<CalibConfig> {
        let synthetic = args.has("synthetic");
        let manifest_dir = args.get("manifest").map(PathBuf::from);
        if synthetic && manifest_dir.is_some() {
            bail!(
                "--synthetic generates its own preset; drop --manifest \
                 (or drop --synthetic to calibrate on real artifacts)"
            );
        }
        if !synthetic && manifest_dir.is_none() {
            bail!(
                "measured-cost tuning needs a cost source: --synthetic \
                 or --manifest <preset-dir>"
            );
        }
        let replan = args.has("replan");
        if replan && !synthetic {
            bail!(
                "--replan needs --synthetic: the drift-replan loop runs \
                 against the self-drifting synthetic preset (real \
                 manifests don't change cost mid-run offline)"
            );
        }
        let cfg = CalibConfig {
            synthetic,
            manifest_dir,
            calib_steps: args.get_usize("calib-steps", 2).max(2),
            exec_steps: args.get_usize("steps", 2).max(1),
            seed: args.get_usize("seed", 0) as u64,
            replan,
            drift_threshold: args.get_f64("drift-threshold", 0.3),
            drift_window: args.get_usize("drift-window", 2).max(1),
            max_replans: args.get_usize("max-replans", 1),
            drift_cooldown: args.get_usize("drift-cooldown", 1),
        };
        if !replan {
            for (flag, set) in [
                ("drift-threshold", args.get("drift-threshold").is_some()),
                ("drift-window", args.get("drift-window").is_some()),
                ("max-replans", args.get("max-replans").is_some()),
                ("drift-cooldown", args.get("drift-cooldown").is_some()),
            ] {
                if set {
                    bail!("--{flag} only applies with --replan");
                }
            }
        }
        if cfg.drift_threshold <= 0.0 {
            bail!("--drift-threshold must be > 0");
        }
        Ok(cfg)
    }

    /// Split a `--manifest <artifacts-root>/<preset>` path into the
    /// (artifacts root, preset name) pair `Manifest::load` expects.
    pub fn split_manifest(dir: &Path) -> Result<(PathBuf, String)> {
        let preset = dir
            .file_name()
            .and_then(|n| n.to_str())
            .ok_or_else(|| {
                anyhow!(
                    "--manifest needs a preset directory path, got {}",
                    dir.display()
                )
            })?
            .to_string();
        let root = match dir.parent() {
            Some(p) if p.as_os_str().is_empty() => PathBuf::from("."),
            Some(p) => p.to_path_buf(),
            None => PathBuf::from("."),
        };
        Ok((root, preset))
    }
}

/// The four benchmark models of the paper's Fig 3/4, in CPU-scale form.
pub const BENCH_PRESETS: [&str; 4] =
    ["transformer-s", "bert-s", "mamba-s", "resnet-s"];

/// The paper's Table 2, rendered for `twobp config --list`.
pub fn table2() -> crate::util::table::Table {
    let mut t = crate::util::table::Table::new(
        &["Model", "Data type", "Micro-Batch size", "Optimizer",
          "CPU-scale preset"],
    )
    .with_title("Table 2: model hyperparameters used for benchmarking");
    t.row(vec!["Mamba-1.4b".into(), "fp16→f32".into(), "2".into(),
               "AdamW".into(), "mamba-s".into()]);
    t.row(vec!["LLaMa-7b".into(), "fp16→f32".into(), "1".into(),
               "Adam".into(), "transformer-s".into()]);
    t.row(vec!["ResNet152".into(), "fp32".into(), "8".into(),
               "SGD".into(), "resnet-s".into()]);
    t.row(vec!["BERT-Large".into(), "fp16→f32".into(), "2".into(),
               "Adam".into(), "bert-s".into()]);
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(v: &[&str]) -> Vec<String> {
        v.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn from_args_full() {
        let args = Args::parse(
            &sv(&["--preset", "bert-s", "--schedule", "1f1b-2",
                  "--steps", "7", "--no-2bp", "--concat-p2", "--synthetic"]),
            &["no-2bp", "concat-p2", "verbose", "synthetic"],
        );
        let cfg = RunConfig::from_args(&args).unwrap();
        assert_eq!(cfg.preset, "bert-s");
        assert_eq!(cfg.schedule, ScheduleKind::OneF1B2);
        assert_eq!(cfg.steps, 7);
        assert!(!cfg.two_bp);
        assert_eq!(cfg.p2_mode, P2Mode::Concat);
        assert!(cfg.synthetic);
    }

    #[test]
    fn fault_and_checkpoint_flags_parse_and_are_gated() {
        let flags = ["synthetic"];
        let cfg = RunConfig::from_args(&Args::parse(
            &sv(&["--synthetic", "--checkpoint-every", "2",
                  "--checkpoint-dir", "/tmp/ck", "--resume", "/tmp/ck",
                  "--fault", "1:fail@3", "--comm-timeout-ms", "250",
                  "--comm-backoff-ms", "5", "--comm-drop-prob", "0.25",
                  "--comm-delay-ns", "1000", "--comm-fault-seed", "7"]),
            &flags,
        ))
        .unwrap();
        assert_eq!(cfg.checkpoint_every, 2);
        assert_eq!(cfg.checkpoint_dir, Some(PathBuf::from("/tmp/ck")));
        assert_eq!(cfg.resume, Some(PathBuf::from("/tmp/ck")));
        assert_eq!(cfg.fault.as_deref(), Some("1:fail@3"));
        assert_eq!(cfg.comm_timeout_ms, 250);
        assert_eq!(cfg.comm_backoff_ms, 5);
        assert_eq!(cfg.comm_drop_prob, 0.25);
        assert_eq!(cfg.comm_delay_ns, 1000);
        assert_eq!(cfg.comm_fault_seed, 7);
        // defaults: supervision on, injection off
        let d = RunConfig::from_args(&Args::parse(&sv(&[]), &flags)).unwrap();
        assert_eq!(d.checkpoint_every, 0);
        assert_eq!(d.comm_timeout_ms, 5000);
        assert_eq!(d.comm_drop_prob, 0.0);
        for argv in [
            // checkpointing needs both halves
            vec!["--checkpoint-every", "2"],
            vec!["--checkpoint-dir", "/tmp/ck"],
            // stub faults only exist on the synthetic preset
            vec!["--fault", "1:fail@3"],
            // probability out of range
            vec!["--synthetic", "--comm-drop-prob", "1.5"],
            // a seed with nothing to seed is a typo'd run
            vec!["--comm-fault-seed", "7"],
        ] {
            assert!(
                RunConfig::from_args(&Args::parse(&sv(&argv), &flags))
                    .is_err(),
                "{argv:?}"
            );
        }
    }

    #[test]
    fn rejects_bad_schedule() {
        let args = Args::parse(&sv(&["--schedule", "zigzag"]), &[]);
        assert!(RunConfig::from_args(&args).is_err());
    }

    #[test]
    fn calib_config_needs_exactly_one_source() {
        let flags = ["synthetic"];
        let none = Args::parse(&sv(&[]), &flags);
        assert!(CalibConfig::from_args(&none).is_err());
        let synth = Args::parse(
            &sv(&["--synthetic", "--calib-steps", "1", "--steps", "3"]),
            &flags,
        );
        let c = CalibConfig::from_args(&synth).unwrap();
        assert!(c.synthetic);
        assert_eq!(c.calib_steps, 2, "clamped to >= 2 samples");
        assert_eq!(c.exec_steps, 3);
        let both = Args::parse(
            &sv(&["--synthetic", "--manifest", "artifacts/x"]),
            &flags,
        );
        assert!(CalibConfig::from_args(&both).is_err());
        let man = Args::parse(&sv(&["--manifest", "artifacts/bert-s"]),
                              &flags);
        let c = CalibConfig::from_args(&man).unwrap();
        assert!(!c.synthetic);
        let (root, preset) =
            CalibConfig::split_manifest(c.manifest_dir.as_ref().unwrap())
                .unwrap();
        assert_eq!(root, PathBuf::from("artifacts"));
        assert_eq!(preset, "bert-s");
        let bare = CalibConfig::split_manifest(Path::new("solo")).unwrap();
        assert_eq!(bare.0, PathBuf::from("."));
        assert_eq!(bare.1, "solo");
    }

    #[test]
    fn replan_knobs_parse_and_are_gated() {
        let flags = ["synthetic", "replan"];
        let c = CalibConfig::from_args(&Args::parse(
            &sv(&["--synthetic", "--replan", "--drift-threshold", "0.5",
                  "--drift-window", "3", "--max-replans", "2",
                  "--drift-cooldown", "0"]),
            &flags,
        ))
        .unwrap();
        assert!(c.replan);
        assert_eq!(c.drift_threshold, 0.5);
        assert_eq!(c.drift_window, 3);
        assert_eq!(c.max_replans, 2);
        assert_eq!(c.drift_cooldown, 0);
        // defaults mirror pipeline::DriftConfig::default()
        let d = CalibConfig::from_args(&Args::parse(
            &sv(&["--synthetic", "--replan"]),
            &flags,
        ))
        .unwrap();
        assert_eq!(d.drift_threshold, 0.3);
        assert_eq!(d.drift_window, 2);
        assert_eq!(d.max_replans, 1);
        assert_eq!(d.drift_cooldown, 1);
        // --replan needs --synthetic; drift knobs need --replan
        for argv in [
            vec!["--manifest", "artifacts/bert-s", "--replan"],
            vec!["--synthetic", "--drift-window", "3"],
            vec!["--synthetic", "--replan", "--drift-threshold", "0"],
        ] {
            assert!(
                CalibConfig::from_args(&Args::parse(&sv(&argv), &flags))
                    .is_err(),
                "{argv:?}"
            );
        }
    }

    #[test]
    fn default_microbatches_follow_schedule() {
        let cfg = RunConfig { schedule: ScheduleKind::OneF1B2,
                              ..RunConfig::default() };
        assert_eq!(cfg.microbatches(4), 8);
    }
}
