//! The simulation kernels.
//!
//! Three entry points share one op-semantics core ([`op_ready`],
//! [`exec_op`], [`run_p2`]) and, for the two event-driven ones, one
//! dispatch driver ([`drive_events`]):
//!
//! * [`simulate`] — **Tier B** (rendering): the event-driven kernel
//!   with full per-op [`Span`] recording, O(1) amortized examinations
//!   per op.  See the module docs in [`crate::sim`] for the event-queue
//!   invariants and the two-tier evaluation contract.
//! * [`score_plan`] — **Tier A** (scoring): the same event-driven
//!   kernel compiled without span recording, running entirely inside a
//!   caller-owned [`Scratch`] workspace so that evaluating thousands of
//!   candidate plans performs no per-call heap allocation.  Returns
//!   only the numbers a search ranks on ([`Score`]).
//! * [`reference::simulate_naive`] — the original linear-scan loop
//!   (rescan every rank after every dispatched action), kept as the
//!   differential oracle and as the baseline the `sweep_throughput`
//!   bench measures speedup against.
//!
//! All three realize the same semantics: global earliest-start
//! scheduling over per-rank op cursors, with the 2BP greedy-p2 fill
//! rule (run deferred weight-grad work whenever a rank would otherwise
//! idle — non-preemptive, exactly like the real executor's
//! poll-then-fill loop), and the non-2BP fused-pair send rule (the
//! input gradient is released only after the paired backward-p2).
//! Differential proptests at the bottom of this file hold
//! `simulate == simulate_naive` bit-for-bit on every output field, and
//! `score_plan == simulate` bit-for-bit on makespan, total busy time,
//! bubble ratio, and peak bytes.

use std::cmp::{Ordering, Reverse};
use std::collections::{BinaryHeap, VecDeque};

use super::{CostModel, MemModel, Score, SimResult};
use crate::schedule::{Op, Plan};
use crate::util::gantt::{Span, SpanKind};

#[derive(Debug)]
pub struct SimError(pub String);

impl std::fmt::Display for SimError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "simulation error: {}", self.0)
    }
}

impl std::error::Error for SimError {}

/// Per-rank mutable simulation state.  Spans are *not* stored here —
/// they live in a separate `Vec<Vec<Span>>` owned by the Tier B
/// callers, so the Tier A scoring path carries no span storage at all.
struct RankState {
    t: f64,
    next: usize,
    /// p1-done microbatches whose p2 hasn't run (FIFO by p1 completion).
    pending_p2: VecDeque<u32>,
    busy: f64,
    // memory accounting
    live: u64,
    peak: u64,
}

impl RankState {
    fn new(static_b: u64) -> RankState {
        RankState {
            t: 0.0,
            next: 0,
            pending_p2: VecDeque::new(),
            busy: 0.0,
            live: static_b,
            peak: static_b,
        }
    }

    /// Restore exactly the state [`RankState::new`] produces, keeping
    /// allocations.  `new` and `reset` are the only two initializers —
    /// a field added to one must be added to the other, which is why
    /// they sit side by side (and why the scratch-reuse differential
    /// proptest fuzzes fresh-vs-reused equality).
    fn reset(&mut self, static_b: u64) {
        self.t = 0.0;
        self.next = 0;
        self.pending_p2.clear();
        self.busy = 0.0;
        self.live = static_b;
        self.peak = static_b;
    }
}

/// What a rank does next.  The discriminant order encodes the dispatch
/// tie-break: at equal start times a real (plan-cursor) op beats a
/// greedy p2 fill, matching the reference engine's scan rule.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
enum Action {
    Real = 0,
    FillP2 = 1,
}

/// Flat (rank × microbatch) completion-time tables, stride
/// `m = n_microbatches`.  `f64::INFINITY` = not yet happened.
/// `fwd_done[r][mb]` is the end of `Fwd(mb)` on rank r; `grad_sent`
/// is the time the input-grad for mb becomes available to rank r-1.
struct Tables<'a> {
    fwd_done: &'a mut [f64],
    grad_sent: &'a mut [f64],
    m: usize,
}

impl Tables<'_> {
    /// Flat index for (rank, microbatch).  The debug assert is the
    /// moral equivalent of the old `Vec<Vec<f64>>` inner bounds check:
    /// with the flattened layout an out-of-range `mb` would otherwise
    /// silently alias into the next rank's row.  Release builds rely
    /// on the caller contract (validated plans only — see
    /// [`score_plan`]).
    #[inline]
    fn at(&self, r: usize, mb: u32) -> usize {
        debug_assert!(
            (mb as usize) < self.m,
            "microbatch {mb} out of range (m = {}); plan not validated?",
            self.m
        );
        r * self.m + mb as usize
    }

    #[inline]
    fn fd(&self, r: usize, mb: u32) -> f64 {
        self.fwd_done[self.at(r, mb)]
    }

    #[inline]
    fn gs(&self, r: usize, mb: u32) -> f64 {
        self.grad_sent[self.at(r, mb)]
    }

    #[inline]
    fn set_fd(&mut self, r: usize, mb: u32, t: f64) {
        let i = self.at(r, mb);
        self.fwd_done[i] = t;
    }

    #[inline]
    fn set_gs(&mut self, r: usize, mb: u32, t: f64) {
        let i = self.at(r, mb);
        self.grad_sent[i] = t;
    }
}

fn make_states(plan: &Plan, mem: Option<&MemModel>) -> Vec<RankState> {
    (0..plan.n_ranks)
        .map(|r| {
            RankState::new(mem.map(|mm| mm.static_bytes[r]).unwrap_or(0))
        })
        .collect()
}

/// The scalar reductions both tiers report — one implementation shared
/// by [`finish`] (Tier B) and [`score_plan`] (Tier A), so the
/// advertised bit-identity between them is structural rather than two
/// copies kept in sync by convention.  Returns
/// `(makespan, total_busy, bubble_ratio)`.
fn reduce(n: usize, ranks: &[RankState]) -> (f64, f64, f64) {
    let makespan = ranks.iter().map(|s| s.t).fold(0.0, f64::max);
    let total_busy: f64 = ranks.iter().map(|s| s.busy).sum();
    let bubble_ratio = if makespan > 0.0 {
        1.0 - total_busy / (n as f64 * makespan)
    } else {
        0.0
    };
    (makespan, total_busy, bubble_ratio)
}

/// Assemble the Tier B result.  The span vectors are **moved** into the
/// [`SimResult`] (they were recorded into this exact `Vec<Vec<Span>>`),
/// so finishing a simulation copies nothing.
fn finish(n: usize, ranks: &[RankState], spans: Vec<Vec<Span>>) -> SimResult {
    let (makespan, _total_busy, bubble_ratio) = reduce(n, ranks);
    SimResult {
        makespan,
        bubble_ratio,
        spans,
        peak_bytes: ranks.iter().map(|s| s.peak).collect(),
        busy: ranks.iter().map(|s| s.busy).collect(),
    }
}

fn deadlock_error(plan: &Plan, ranks: &[RankState], done: usize,
                  total: usize) -> SimError {
    SimError(format!(
        "deadlock: {done}/{total} ops done; next ops: {:?}",
        (0..plan.n_ranks)
            .map(|r| plan.ranks[r].get(ranks[r].next))
            .collect::<Vec<_>>()
    ))
}

/// The per-rank dispatch decision (shared by all engines): when can
/// rank `r` act next, and is that action its next plan op or a greedy
/// p2 fill?  `None` = blocked with nothing to fill.
fn candidate(
    r: usize,
    plan: &Plan,
    costs: &CostModel,
    ranks: &[RankState],
    tb: &Tables<'_>,
) -> Option<(f64, Action)> {
    let st = &ranks[r];
    if st.next >= plan.ranks[r].len() {
        return None;
    }
    let op = &plan.ranks[r][st.next];
    let ready = op_ready(op, r, plan.n_ranks, costs, tb);
    // Greedy 2BP fill rule: if the next op's input either doesn't exist
    // yet or arrives only after this rank's current time, the real
    // executor's poll fails and it starts a pending p2 instead
    // (non-preemptive — it may overshoot the arrival, which is the
    // paper's non-uniform-graph caveat in §3.2).
    let can_fill = plan.greedy_p2 && !st.pending_p2.is_empty();
    match ready {
        Some(dep_t) if dep_t <= st.t => Some((st.t, Action::Real)),
        Some(dep_t) => {
            if can_fill {
                Some((st.t, Action::FillP2))
            } else {
                Some((dep_t, Action::Real))
            }
        }
        None => can_fill.then_some((st.t, Action::FillP2)),
    }
}

/// Dependency-readiness of `op` on rank `r`: Some(t) when its external
/// input is available at time t, None when the input doesn't exist yet.
/// Local ordering is implied by the per-rank cursor.
fn op_ready(
    op: &Op,
    r: usize,
    n: usize,
    costs: &CostModel,
    tb: &Tables<'_>,
) -> Option<f64> {
    match op {
        Op::Fwd { mb } => {
            if r == 0 {
                Some(0.0)
            } else {
                let t = tb.fd(r - 1, *mb);
                t.is_finite().then(|| t + costs.hop(r - 1, r))
            }
        }
        Op::BwdP1 { mb } => {
            if r == n - 1 {
                let t = tb.fd(r, *mb);
                // loss runs on the last rank right before its first p1 use
                t.is_finite().then(|| t + costs.loss)
            } else {
                let t = tb.gs(r + 1, *mb);
                t.is_finite().then(|| t + costs.hop(r, r + 1))
            }
        }
        // local-only ops: plan order + validator guarantee inputs exist
        Op::BwdP2 { .. } | Op::Flush { .. } | Op::OptStep => Some(0.0),
    }
    .filter(|t| t.is_finite())
}

/// Execute one plan op on rank `r` at `start`, updating its timeline,
/// memory accounting, and the completion tables.  Returns the neighbor
/// rank (if any) whose next op may have just become ready — the wakeup
/// edge the event-driven engine subscribes to.
///
/// `SPANS` selects span recording at compile time: the Tier A scoring
/// path instantiates `SPANS = false` with an empty `spans` slice, and
/// every span push (the only thing that would index it) folds away.
/// `flush_buf` is a caller-owned staging buffer for `Flush` targets so
/// the hot path never allocates.
#[allow(clippy::too_many_arguments)]
fn exec_op<const SPANS: bool>(
    op: &Op,
    r: usize,
    n: usize,
    plan: &Plan,
    costs: &CostModel,
    mem: Option<&MemModel>,
    start: f64,
    ranks: &mut [RankState],
    tb: &mut Tables<'_>,
    spans: &mut [Vec<Span>],
    flush_buf: &mut Vec<u32>,
) -> Option<usize> {
    let mut wake = None;
    match op {
        Op::Fwd { mb } => {
            let st = &mut ranks[r];
            let end = start + costs.fwd[r];
            if SPANS {
                spans[r].push(Span { start, end, label: SpanKind::Fwd,
                                     mb: *mb });
            }
            st.busy += end - start;
            st.t = end;
            tb.set_fd(r, *mb, end);
            if let Some(mm) = mem {
                st.live += mm.res1[r] + mm.res2[r];
                st.peak = st.peak.max(st.live);
            }
            if r + 1 < n {
                wake = Some(r + 1);
            }
        }
        Op::BwdP1 { mb } => {
            let end = start + costs.p1[r];
            let st = &mut ranks[r];
            if SPANS {
                spans[r].push(Span { start, end, label: SpanKind::BwdP1,
                                     mb: *mb });
            }
            st.busy += end - start;
            st.t = end;
            st.pending_p2.push_back(*mb);
            if let Some(mm) = mem {
                st.live = st.live - mm.res1[r] + mm.inter[r];
                st.peak = st.peak.max(st.live);
            }
            // 2BP: grad leaves right after p1.  Fused (non-2BP): the
            // following BwdP2 op updates grad_sent instead.
            if plan.two_bp && r > 0 {
                tb.set_gs(r, *mb, end);
                wake = Some(r - 1);
            }
            if !plan.two_bp {
                // fused pair: mark sent tentatively; BwdP2 will overwrite
                tb.set_gs(r, *mb, f64::INFINITY);
            }
        }
        Op::BwdP2 { mbs, concat } => {
            run_p2::<SPANS>(&mut ranks[r], spans, r, mbs, *concat, start,
                            costs, mem);
            if !plan.two_bp {
                // fused semantics: the grad for this mb is released only now
                let t_end = ranks[r].t;
                for mb in mbs {
                    tb.set_gs(r, *mb, t_end);
                }
                if r > 0 {
                    wake = Some(r - 1);
                }
            }
            let st = &mut ranks[r];
            st.pending_p2.retain(|mb| !mbs.contains(mb));
        }
        Op::Flush { upto, concat } => {
            let st = &mut ranks[r];
            flush_buf.clear();
            flush_buf.extend(
                st.pending_p2
                    .iter()
                    .copied()
                    .filter(|mb| upto.map(|u| *mb <= u).unwrap_or(true)),
            );
            flush_buf.sort_unstable();
            st.pending_p2.retain(|mb| !flush_buf.contains(mb));
            if !flush_buf.is_empty() {
                run_p2::<SPANS>(st, spans, r, flush_buf, *concat, start,
                                costs, mem);
            }
        }
        Op::OptStep => {
            let st = &mut ranks[r];
            let end = start + costs.opt[r];
            if SPANS {
                spans[r].push(Span { start, end, label: SpanKind::Opt,
                                     mb: 0 });
            }
            st.busy += end - start;
            st.t = end;
        }
    }
    wake
}

fn run_p2<const SPANS: bool>(
    st: &mut RankState,
    spans: &mut [Vec<Span>],
    r: usize,
    mbs: &[u32],
    concat: bool,
    start: f64,
    costs: &CostModel,
    mem: Option<&MemModel>,
) {
    let k = mbs.len() as f64;
    let dur = if concat && mbs.len() > 1 {
        k * costs.p2[r] * costs.concat_factor
    } else {
        k * costs.p2[r]
    };
    let end = start + dur;
    if SPANS {
        spans[r].push(Span {
            start,
            end,
            label: SpanKind::BwdP2,
            mb: mbs[0],
        });
    }
    st.busy += dur;
    st.t = end;
    if let Some(mm) = mem {
        st.live -= (mm.res2[r] + mm.inter[r]) * mbs.len() as u64;
        st.peak = st.peak.max(st.live);
    }
}

// ---------------------------------------------------------------------------
// Event-driven engine
// ---------------------------------------------------------------------------

/// One queued dispatch opportunity.  Ordered ascending by
/// (start, action, rank) — exactly the reference engine's pick rule:
/// earliest start wins, a real op beats a fill at equal time, lowest
/// rank breaks remaining ties.  `gen` is a per-rank staleness stamp and
/// takes no part in the ordering.
#[derive(Clone, Copy)]
struct Event {
    start: f64,
    act: Action,
    rank: u32,
    gen: u32,
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}

impl Eq for Event {}

impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Event {
    fn cmp(&self, other: &Self) -> Ordering {
        // starts are finite by construction (op_ready filters infinities)
        self.start
            .partial_cmp(&other.start)
            .unwrap_or(Ordering::Equal)
            .then_with(|| self.act.cmp(&other.act))
            .then_with(|| self.rank.cmp(&other.rank))
    }
}

/// The event-driven dispatch loop shared by [`simulate`] (Tier B,
/// `SPANS = true`) and [`score_plan`] (Tier A, `SPANS = false`).  All
/// storage is caller-owned; the loop itself allocates nothing beyond
/// heap growth (bounded by ~2 events per rank, retained across calls
/// by the scoring scratch).
#[allow(clippy::too_many_arguments)]
fn drive_events<const SPANS: bool>(
    plan: &Plan,
    costs: &CostModel,
    mem: Option<&MemModel>,
    ranks: &mut [RankState],
    tb: &mut Tables<'_>,
    heap: &mut BinaryHeap<Reverse<Event>>,
    gen: &mut [u32],
    spans: &mut [Vec<Span>],
    flush_buf: &mut Vec<u32>,
) -> Result<(), SimError> {
    let n = plan.n_ranks;
    let total_ops = plan.total_ops();
    let mut done_ops = 0usize;

    let push = |heap: &mut BinaryHeap<Reverse<Event>>,
                ranks: &[RankState],
                tb: &Tables<'_>,
                r: usize,
                gen_r: u32|
     -> bool {
        if let Some((start, act)) = candidate(r, plan, costs, ranks, tb) {
            heap.push(Reverse(Event { start, act, rank: r as u32,
                                      gen: gen_r }));
            true
        } else {
            false
        }
    };

    for r in 0..n {
        push(heap, ranks, tb, r, gen[r]);
    }

    while done_ops < total_ops {
        // pop the earliest still-valid event (stale stamps are skipped)
        let ev = loop {
            match heap.pop() {
                Some(Reverse(e)) if e.gen == gen[e.rank as usize] => {
                    break Some(e)
                }
                Some(_) => continue,
                None => break None,
            }
        };
        let ev = match ev {
            Some(e) => e,
            None => {
                // Defensive full rescan.  With complete wakeup edges an
                // empty heap means no rank has a candidate (deadlock);
                // rebuilding from scratch keeps release builds exact
                // even if an edge were ever missed.
                let mut found = false;
                for r in 0..n {
                    gen[r] = gen[r].wrapping_add(1);
                    if push(heap, ranks, tb, r, gen[r]) {
                        found = true;
                    }
                }
                debug_assert!(
                    !found,
                    "event heap starved while candidates were runnable"
                );
                if found {
                    continue;
                }
                return Err(deadlock_error(plan, ranks, done_ops, total_ops));
            }
        };

        let r = ev.rank as usize;
        let wake = match ev.act {
            Action::FillP2 => {
                let mb = ranks[r]
                    .pending_p2
                    .pop_front()
                    .expect("fill event with empty pending queue");
                run_p2::<SPANS>(&mut ranks[r], spans, r, &[mb], false,
                                ev.start, costs, mem);
                None
            }
            Action::Real => {
                // `op` borrows `plan`, not the mutable sim state, so no
                // per-dispatch clone on the sweep hot path
                let op = &plan.ranks[r][ranks[r].next];
                let wake = exec_op::<SPANS>(
                    op, r, n, plan, costs, mem, ev.start,
                    ranks, tb, spans, flush_buf,
                );
                ranks[r].next += 1;
                done_ops += 1;
                wake
            }
        };

        // the executed rank always needs a fresh candidate; a woken
        // neighbor re-evaluates because a dependency it may be blocked
        // on (fwd activation from r-1, input-grad from r+1) just landed
        gen[r] = gen[r].wrapping_add(1);
        push(heap, ranks, tb, r, gen[r]);
        if let Some(w) = wake {
            gen[w] = gen[w].wrapping_add(1);
            push(heap, ranks, tb, w, gen[w]);
        }
    }

    Ok(())
}

/// Simulate one training step of `plan` under `costs` (+ optional memory
/// model) with the event-driven kernel, recording per-op spans — the
/// **Tier B** (rendering) entry point of the two-tier contract in
/// [`crate::sim`].
///
/// Fused (non-2BP) backward pairs are handled by the send rule: the
/// upstream rank's p1 readiness waits for the *pair* end on this rank,
/// because in plan order BwdP2 immediately follows BwdP1 and the
/// grad-send timestamp is taken after the following BwdP2 when the plan
/// is non-2BP.
pub fn simulate(
    plan: &Plan,
    costs: &CostModel,
    mem: Option<&MemModel>,
) -> Result<SimResult, SimError> {
    let n = plan.n_ranks;
    assert_eq!(costs.fwd.len(), n, "cost model rank count mismatch");

    let inf = f64::INFINITY;
    let m = plan.n_microbatches;
    let mut fwd_done = vec![inf; n * m];
    let mut grad_sent = vec![inf; n * m];
    let mut tb = Tables { fwd_done: &mut fwd_done, grad_sent: &mut grad_sent,
                          m };
    let mut ranks = make_states(plan, mem);
    let mut spans: Vec<Vec<Span>> = vec![Vec::new(); n];
    let mut heap: BinaryHeap<Reverse<Event>> =
        BinaryHeap::with_capacity(2 * n + 4);
    let mut gen: Vec<u32> = vec![0; n];
    let mut flush_buf: Vec<u32> = Vec::new();

    drive_events::<true>(plan, costs, mem, &mut ranks, &mut tb, &mut heap,
                         &mut gen, &mut spans, &mut flush_buf)?;

    Ok(finish(n, &ranks, spans))
}

// ---------------------------------------------------------------------------
// Tier A: the zero-allocation scoring fast path
// ---------------------------------------------------------------------------

/// Caller-owned workspace for [`score_plan`]: rank states (with their
/// pending-p2 queues), the flattened completion-time tables, the event
/// heap, the staleness stamps, and the flush staging buffer.  All
/// buffers grow monotonically to the largest (ranks × microbatches)
/// shape ever scored and are reused verbatim afterwards, so a scratch
/// that has warmed up performs **zero heap allocations per evaluation**.
///
/// A scratch is plain mutable state — use one per worker thread (see
/// `experiments::sweep::run_grid_with`), never share one concurrently.
/// Results never depend on what was scored before: every call fully
/// re-initializes the slices it reads (enforced by the differential
/// proptest below, which reuses a single scratch across all cases).
#[derive(Default)]
pub struct Scratch {
    ranks: Vec<RankState>,
    fwd_done: Vec<f64>,
    grad_sent: Vec<f64>,
    heap: BinaryHeap<Reverse<Event>>,
    gen: Vec<u32>,
    flush_buf: Vec<u32>,
}

impl Scratch {
    pub fn new() -> Scratch {
        Scratch::default()
    }

    /// Re-initialize for `plan`: grow (never shrink) every buffer to the
    /// plan's shape and reset the portions the engine will read.
    fn reset(&mut self, plan: &Plan, mem: Option<&MemModel>) {
        let n = plan.n_ranks;
        let nm = n * plan.n_microbatches;
        if self.ranks.len() < n {
            self.ranks.resize_with(n, || RankState::new(0));
        }
        for (r, st) in self.ranks[..n].iter_mut().enumerate() {
            st.reset(mem.map(|mm| mm.static_bytes[r]).unwrap_or(0));
        }
        // clear-then-resize refills every slot with INFINITY without
        // reallocating once capacity has grown to the largest plan seen
        self.fwd_done.clear();
        self.fwd_done.resize(nm, f64::INFINITY);
        self.grad_sent.clear();
        self.grad_sent.resize(nm, f64::INFINITY);
        self.heap.clear();
        if self.gen.len() < n {
            self.gen.resize(n, 0);
        }
        for g in &mut self.gen[..n] {
            *g = 0;
        }
        self.flush_buf.clear();
    }
}

/// **Tier A** (scoring): evaluate `plan` through the event-driven
/// kernel without recording spans and without allocating — every
/// buffer lives in the caller's [`Scratch`] and is reused across
/// evaluations.  Returns only what a search ranks on; render the
/// winner with [`simulate`] when its timeline is actually needed.
///
/// Bit-identical to [`simulate`] on makespan, summed busy time, bubble
/// ratio, and peak bytes (a differential proptest in this file holds
/// the equality over fuzzed plans, cost/memory models, and a scratch
/// reused across every case).
///
/// The plan must be structurally valid (`schedule::validate`, or the
/// planner's incremental move revalidation): `score_plan` performs no
/// validation of its own — that is exactly the per-candidate cost the
/// two-tier split removes.  Feeding an *unvalidated* plan is a
/// contract violation: an out-of-range microbatch index is caught by
/// a debug assertion, but in release builds it can silently read or
/// write another rank's row of the flattened completion tables and
/// return wrong numbers.  A valid-but-deadlocked plan returns `Err`
/// like [`simulate`].
pub fn score_plan(
    plan: &Plan,
    costs: &CostModel,
    mem: Option<&MemModel>,
    budget: Option<u64>,
    scratch: &mut Scratch,
) -> Result<Score, SimError> {
    let n = plan.n_ranks;
    assert_eq!(costs.fwd.len(), n, "cost model rank count mismatch");
    let m = plan.n_microbatches;

    scratch.reset(plan, mem);
    let Scratch { ranks, fwd_done, grad_sent, heap, gen, flush_buf } = scratch;
    let mut tb = Tables {
        fwd_done: &mut fwd_done[..n * m],
        grad_sent: &mut grad_sent[..n * m],
        m,
    };
    drive_events::<false>(plan, costs, mem, &mut ranks[..n], &mut tb, heap,
                          &mut gen[..n], &mut [], flush_buf)?;

    // the same `reduce` call `finish` makes — bit-identical by sharing
    let ranks = &ranks[..n];
    let (makespan, total_busy, bubble_ratio) = reduce(n, ranks);
    let max_peak = ranks.iter().map(|s| s.peak).max().unwrap_or(0);
    let fits = budget.map(|b| max_peak <= b).unwrap_or(true);
    Ok(Score { makespan, total_busy, bubble_ratio, max_peak, fits })
}

// ---------------------------------------------------------------------------
// Reference engine
// ---------------------------------------------------------------------------

/// The original linear-scan simulation loop, kept verbatim in behavior:
/// rescan every rank's candidate after every dispatched action and pick
/// the global earliest (ties: real op over fill, then lowest rank).
/// O(total_ops × n_ranks) — the differential oracle for [`simulate`]
/// and the baseline for the `sweep_throughput` bench.
pub mod reference {
    use super::*;

    /// Simulate with the linear-scan loop.  Produces results
    /// bit-for-bit identical to [`simulate`] (enforced by the
    /// differential proptest in this file).
    pub fn simulate_naive(
        plan: &Plan,
        costs: &CostModel,
        mem: Option<&MemModel>,
    ) -> Result<SimResult, SimError> {
        let n = plan.n_ranks;
        assert_eq!(costs.fwd.len(), n, "cost model rank count mismatch");

        let inf = f64::INFINITY;
        let m = plan.n_microbatches;
        let mut fwd_done = vec![inf; n * m];
        let mut grad_sent = vec![inf; n * m];
        let mut tb = Tables { fwd_done: &mut fwd_done,
                              grad_sent: &mut grad_sent, m };
        let mut ranks = make_states(plan, mem);
        let mut spans: Vec<Vec<Span>> = vec![Vec::new(); n];
        let mut flush_buf: Vec<u32> = Vec::new();

        let total_ops = plan.total_ops();
        let mut done_ops = 0usize;

        while done_ops < total_ops {
            // collect candidate actions
            let mut best: Option<(f64, usize, Action)> = None;
            for r in 0..n {
                let cand = candidate(r, plan, costs, &ranks, &tb);
                if let Some((start, act)) = cand {
                    let better = match &best {
                        None => true,
                        Some((bs, _, ba)) => {
                            start < *bs
                                || (start == *bs
                                    && matches!(ba, Action::FillP2)
                                    && matches!(act, Action::Real))
                        }
                    };
                    if better {
                        best = Some((start, r, act));
                    }
                }
            }

            let (start, r, act) = best.ok_or_else(|| {
                deadlock_error(plan, &ranks, done_ops, total_ops)
            })?;

            match act {
                Action::FillP2 => {
                    let mb = ranks[r]
                        .pending_p2
                        .pop_front()
                        .expect("fill with empty pending queue");
                    run_p2::<true>(&mut ranks[r], &mut spans, r, &[mb], false,
                                   start, costs, mem);
                }
                Action::Real => {
                    let op = plan.ranks[r][ranks[r].next].clone();
                    let _ = exec_op::<true>(
                        &op, r, n, plan, costs, mem, start,
                        &mut ranks, &mut tb, &mut spans, &mut flush_buf,
                    );
                    ranks[r].next += 1;
                    done_ops += 1;
                }
            }
        }

        Ok(finish(n, &ranks, spans))
    }
}

// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::reference::simulate_naive;
    use super::*;
    use crate::schedule::{generate, validate::validate, ScheduleKind};

    fn bubble(kind: ScheduleKind, two_bp: bool, n: usize) -> f64 {
        // the paper's naive rows assume no micro-batching (M = 1)
        let m = if kind == ScheduleKind::Naive { 1 } else { 0 };
        let plan = generate(kind, two_bp, n, m, false);
        validate(&plan).unwrap();
        let res = simulate(&plan, &CostModel::unit(n), None).unwrap();
        res.bubble_ratio
    }

    fn assert_close(a: f64, b: f64, what: &str) {
        assert!((a - b).abs() < 1e-9, "{what}: got {a}, want {b}");
    }

    /// The paper's Table 1 closed forms, checked exactly for N = 2..10.
    #[test]
    fn table1_naive() {
        for n in 2..=10usize {
            let nf = n as f64;
            assert_close(bubble(ScheduleKind::Naive, false, n),
                         (nf - 1.0) / nf, &format!("naive N={n}"));
            assert_close(bubble(ScheduleKind::Naive, true, n),
                         2.0 * (nf - 1.0) / (2.0 * nf + 1.0),
                         &format!("naive+2bp N={n}"));
        }
    }

    #[test]
    fn table1_gpipe() {
        for n in 2..=10usize {
            let nf = n as f64;
            assert_close(bubble(ScheduleKind::GPipe, false, n),
                         (nf - 1.0) / (2.0 * nf - 1.0),
                         &format!("gpipe N={n}"));
            assert_close(bubble(ScheduleKind::GPipe, true, n),
                         2.0 * (nf - 1.0) / (2.0 * (nf - 1.0) + 3.0 * nf),
                         &format!("gpipe+2bp N={n}"));
        }
    }

    #[test]
    fn table1_1f1b1() {
        for n in 2..=10usize {
            let nf = n as f64;
            assert_close(bubble(ScheduleKind::OneF1B1, false, n),
                         (nf - 1.0) / (2.0 * nf - 1.0),
                         &format!("1f1b-1 N={n}"));
            assert_close(bubble(ScheduleKind::OneF1B1, true, n),
                         (nf - 1.0) / (nf - 1.0 + 3.0 * nf),
                         &format!("1f1b-1+2bp N={n}"));
        }
    }

    #[test]
    fn table1_1f1b2() {
        for n in 2..=10usize {
            let nf = n as f64;
            assert_close(bubble(ScheduleKind::OneF1B2, false, n),
                         (nf - 1.0) / (3.0 * nf - 1.0),
                         &format!("1f1b-2 N={n}"));
            assert_close(bubble(ScheduleKind::OneF1B2, true, n),
                         (nf - 1.0) / (nf - 1.0 + 6.0 * nf),
                         &format!("1f1b-2+2bp N={n}"));
        }
    }

    /// Throughput gain = (1-b)/(1-a) from Table 1's last column.
    #[test]
    fn table1_throughput_gains() {
        let n = 4usize;
        let nf = n as f64;
        let gain = |k: ScheduleKind| {
            let a = bubble(k, false, n);
            let b = bubble(k, true, n);
            (1.0 - b) / (1.0 - a)
        };
        assert_close(gain(ScheduleKind::Naive),
                     3.0 * nf / (2.0 * nf + 1.0), "naive gain");
        assert_close(gain(ScheduleKind::GPipe),
                     3.0 * (2.0 * nf - 1.0) / (2.0 * (nf - 1.0) + 3.0 * nf),
                     "gpipe gain");
        assert_close(gain(ScheduleKind::OneF1B1),
                     3.0 * (2.0 * nf - 1.0) / (nf - 1.0 + 3.0 * nf),
                     "1f1b-1 gain");
        assert_close(gain(ScheduleKind::OneF1B2),
                     3.0 * (3.0 * nf - 1.0) / (nf - 1.0 + 6.0 * nf),
                     "1f1b-2 gain");
    }

    #[test]
    fn two_bp_never_slower_at_unit_costs() {
        for kind in ScheduleKind::all() {
            for n in 2..=8 {
                let a = bubble(kind, false, n);
                let b = bubble(kind, true, n);
                assert!(
                    (1.0 - b) / (1.0 - a) >= 1.0 - 1e-12,
                    "{} N={n}: 2BP slowed throughput ({a} -> {b})",
                    kind.name()
                );
            }
        }
    }

    #[test]
    fn single_rank_has_no_bubble_without_comm() {
        for kind in ScheduleKind::all() {
            for two_bp in [false, true] {
                let plan = generate(kind, two_bp, 1, 4, false);
                let res = simulate(&plan, &CostModel::unit(1), None).unwrap();
                assert!(res.bubble_ratio.abs() < 1e-12,
                        "{} 2bp={two_bp}", kind.name());
            }
        }
    }

    #[test]
    fn comm_increases_makespan() {
        let plan = generate(ScheduleKind::OneF1B1, true, 4, 0, false);
        let base = simulate(&plan, &CostModel::unit(4), None).unwrap();
        let mut cm = CostModel::unit(4);
        cm.comm = 0.25;
        let with = simulate(&plan, &cm, None).unwrap();
        assert!(with.makespan > base.makespan);
    }

    #[test]
    fn inter_node_hop_penalty_applies() {
        let mut cm = CostModel::unit(8);
        cm.comm = 0.1;
        cm.comm_inter_node = 1.0;
        cm.ranks_per_node = 4;
        assert_close(cm.hop(3, 4), 1.1, "inter-node hop");
        assert_close(cm.hop(2, 3), 0.1, "intra-node hop");
    }

    #[test]
    fn memory_peaks_scale_with_schedule() {
        // GPipe stashes all M microbatches; 1F1B-1 rank N-1 stashes 1.
        let n = 4;
        let mm = MemModel {
            static_bytes: vec![0; n],
            res1: vec![10; n],
            res2: vec![100; n],
            inter: vec![50; n],
        };
        let gpipe = simulate(
            &generate(ScheduleKind::GPipe, false, n, 0, false),
            &CostModel::unit(n), Some(&mm)).unwrap();
        let f1b = simulate(
            &generate(ScheduleKind::OneF1B1, false, n, 0, false),
            &CostModel::unit(n), Some(&mm)).unwrap();
        // rank 0 peak: 4 x (res1+res2) stashed, +inter during the first
        // backward before res1 releases: 4*110 - 10 + 50 = 480
        assert_eq!(gpipe.peak_bytes[0], 480);
        // 1F1B rank N-1 holds at most ~1-2 microbatches
        assert!(f1b.peak_bytes[n - 1] < gpipe.peak_bytes[n - 1]);
    }

    #[test]
    fn two_bp_increases_peak_memory() {
        // the paper's Fig 4: 2BP trades memory for throughput
        let n = 4;
        let mm = MemModel {
            static_bytes: vec![0; n],
            res1: vec![10; n],
            res2: vec![100; n],
            inter: vec![50; n],
        };
        for kind in ScheduleKind::all() {
            let a = simulate(&generate(kind, false, n, 0, false),
                             &CostModel::unit(n), Some(&mm)).unwrap();
            let b = simulate(&generate(kind, true, n, 0, false),
                             &CostModel::unit(n), Some(&mm)).unwrap();
            assert!(
                b.max_peak() >= a.max_peak(),
                "{}: 2BP peak {} < non-2BP {}",
                kind.name(), b.max_peak(), a.max_peak()
            );
        }
    }

    #[test]
    fn eager_p2_variant_cuts_1f1b2_peak() {
        // Fig 5: mid-step flush caps the stash vs plain 1F1B-2 + 2BP
        let n = 4;
        let mm = MemModel {
            static_bytes: vec![0; n],
            res1: vec![10; n],
            res2: vec![100; n],
            inter: vec![50; n],
        };
        let plain = simulate(&generate(ScheduleKind::OneF1B2, true, n, 0, false),
                             &CostModel::unit(n), Some(&mm)).unwrap();
        let eager = simulate(
            &generate(ScheduleKind::OneF1B2EagerP2, true, n, 0, false),
            &CostModel::unit(n), Some(&mm)).unwrap();
        assert!(
            eager.max_peak() <= plain.max_peak(),
            "eager {} vs plain {}", eager.max_peak(), plain.max_peak()
        );
    }

    #[test]
    fn spans_cover_busy_time_exactly() {
        let plan = generate(ScheduleKind::OneF1B2, true, 4, 0, false);
        let res = simulate(&plan, &CostModel::ratios(4, 1.0, 1.2, 0.8), None)
            .unwrap();
        for (r, spans) in res.spans.iter().enumerate() {
            let total: f64 = spans.iter().map(|s| s.end - s.start).sum();
            assert!((total - res.busy[r]).abs() < 1e-9);
            // spans never overlap
            let mut sorted = spans.clone();
            sorted.sort_by(|a, b| a.start.partial_cmp(&b.start).unwrap());
            for w in sorted.windows(2) {
                assert!(w[1].start >= w[0].end - 1e-12);
            }
        }
    }

    #[test]
    fn prop_simulation_never_deadlocks() {
        use crate::util::proptest::{check, gen};
        check(
            "simulate() terminates for fuzzed plans/costs",
            150,
            |rng| {
                let kind = *gen::pick(rng, &ScheduleKind::all_variants());
                let two_bp = if kind == ScheduleKind::OneF1B2EagerP2 {
                    true
                } else {
                    gen::bool(rng)
                };
                let n = gen::usize_in(rng, 1, 8);
                let m = gen::usize_in(rng, 1, 16);
                let f = 0.5 + rng.next_f64();
                let p1 = 0.5 + rng.next_f64();
                let p2 = 0.5 + rng.next_f64();
                let comm = rng.next_f64() * 0.3;
                (kind, two_bp, n, m, f, p1, p2, comm)
            },
            |&(kind, two_bp, n, m, f, p1, p2, comm)| {
                let plan = generate(kind, two_bp, n, m, two_bp);
                let mut cm = CostModel::ratios(n, f, p1, p2);
                cm.comm = comm;
                let res = simulate(&plan, &cm, None)
                    .map_err(|e| e.to_string())?;
                if !(res.bubble_ratio >= -1e-9 && res.bubble_ratio < 1.0) {
                    return Err(format!("bubble {}", res.bubble_ratio));
                }
                // all compute accounted: busy == m*(f+p1+p2) (+opt=0)
                let want = m as f64 * (f + p1 + p2);
                for b in &res.busy {
                    if (b - want).abs() > 1e-6 {
                        return Err(format!("busy {b} != {want}"));
                    }
                }
                Ok(())
            },
        );
    }

    /// Every field of a [`SimResult`], compared bitwise.
    fn assert_identical(a: &SimResult, b: &SimResult, what: &str) {
        let f = |x: f64| x.to_bits();
        assert_eq!(f(a.makespan), f(b.makespan), "{what}: makespan");
        assert_eq!(f(a.bubble_ratio), f(b.bubble_ratio), "{what}: bubble");
        assert_eq!(a.busy.len(), b.busy.len(), "{what}: busy len");
        for (x, y) in a.busy.iter().zip(&b.busy) {
            assert_eq!(f(*x), f(*y), "{what}: busy");
        }
        assert_eq!(a.peak_bytes, b.peak_bytes, "{what}: peaks");
        assert_eq!(a.spans.len(), b.spans.len(), "{what}: span ranks");
        for (ra, rb) in a.spans.iter().zip(&b.spans) {
            assert_eq!(ra.len(), rb.len(), "{what}: span count");
            for (sa, sb) in ra.iter().zip(rb) {
                assert!(
                    f(sa.start) == f(sb.start)
                        && f(sa.end) == f(sb.end)
                        && sa.label == sb.label
                        && sa.mb == sb.mb,
                    "{what}: span {sa:?} != {sb:?}"
                );
            }
        }
    }

    /// The differential oracle: for fuzzed valid plans + cost/memory
    /// models, the event-driven engine and the linear-scan reference
    /// must agree bit-for-bit on makespan, busy times, bubble ratio,
    /// span sets, and peak bytes.
    #[test]
    fn prop_event_engine_matches_reference() {
        use crate::util::proptest::{check, gen};
        check(
            "event-driven simulate() == reference simulate_naive()",
            400,
            |rng| {
                let kind = *gen::pick(rng, &ScheduleKind::all_variants());
                let two_bp = if kind == ScheduleKind::OneF1B2EagerP2 {
                    true
                } else {
                    gen::bool(rng)
                };
                let n = gen::usize_in(rng, 1, 8);
                let m = gen::usize_in(rng, 1, 16);
                let concat = gen::bool(rng);
                let costs = (
                    0.25 + rng.next_f64(),
                    0.25 + rng.next_f64(),
                    0.25 + rng.next_f64(),
                    rng.next_f64() * 0.2,        // opt
                    rng.next_f64() * 0.3,        // loss
                    if gen::bool(rng) { rng.next_f64() * 0.4 } else { 0.0 },
                    0.8 + rng.next_f64() * 0.4,  // concat factor
                );
                let with_mem = gen::bool(rng);
                let mem_seed = rng.next_u64();
                (kind, two_bp, n, m, concat, costs, with_mem, mem_seed)
            },
            |&(kind, two_bp, n, m, concat, costs, with_mem, mem_seed)| {
                let (f, p1, p2, opt, loss, comm, cf) = costs;
                let plan = generate(kind, two_bp, n, m, concat);
                validate(&plan).map_err(|e| e.to_string())?;
                let mut cm = CostModel::ratios(n, f, p1, p2);
                cm.opt = vec![opt; n];
                cm.loss = loss;
                cm.comm = comm;
                cm.concat_factor = cf;
                if mem_seed & 1 == 1 {
                    cm.comm_inter_node = 0.5;
                    cm.ranks_per_node = 1 + (mem_seed >> 1) as usize % 4;
                }
                let mm = MemModel {
                    static_bytes: vec![mem_seed % 100; n],
                    res1: vec![(mem_seed >> 8) % 50; n],
                    res2: vec![(mem_seed >> 16) % 50; n],
                    inter: vec![(mem_seed >> 24) % 50; n],
                };
                let mem = with_mem.then_some(&mm);
                let a = simulate(&plan, &cm, mem)
                    .map_err(|e| format!("event: {e}"))?;
                let b = simulate_naive(&plan, &cm, mem)
                    .map_err(|e| format!("reference: {e}"))?;
                assert_identical(&a, &b, &plan.describe());
                Ok(())
            },
        );
    }

    /// The Tier A/B contract: `score_plan` (span-free, scratch-reusing)
    /// agrees with `simulate` bit-for-bit on makespan, total busy time,
    /// bubble ratio, and max peak bytes — across fuzzed generator plans
    /// *and* chains of validated planner mutations (which can deadlock:
    /// then both paths must reject).  One scratch is reused across every
    /// case, so the reuse/reset logic is itself under test.
    #[test]
    fn prop_score_plan_matches_simulate() {
        use crate::util::proptest::{check, gen};
        let mut scratch = Scratch::new();
        check(
            "score_plan() == simulate() on (makespan, busy, bubble, peak)",
            400,
            |rng| {
                let kind = *gen::pick(rng, &ScheduleKind::all_variants());
                let two_bp = if kind == ScheduleKind::OneF1B2EagerP2 {
                    true
                } else {
                    gen::bool(rng)
                };
                let n = gen::usize_in(rng, 1, 8);
                let m = gen::usize_in(rng, 1, 16);
                let n_moves = gen::usize_in(rng, 0, 6);
                let move_seed = rng.next_u64();
                let costs = (
                    0.25 + rng.next_f64(),
                    0.25 + rng.next_f64(),
                    0.25 + rng.next_f64(),
                    rng.next_f64() * 0.2,
                    rng.next_f64() * 0.3,
                    if gen::bool(rng) { rng.next_f64() * 0.4 } else { 0.0 },
                    0.8 + rng.next_f64() * 0.4,
                );
                let with_mem = gen::bool(rng);
                let with_budget = gen::bool(rng);
                let mem_seed = rng.next_u64();
                (kind, two_bp, n, m, n_moves, move_seed, costs, with_mem,
                 with_budget, mem_seed)
            },
            |&(kind, two_bp, n, m, n_moves, move_seed, costs, with_mem,
               with_budget, mem_seed)| {
                let (f, p1, p2, opt, loss, comm, cf) = costs;
                let mut plan = generate(kind, two_bp, n, m, false);
                // walk a few validated local moves so the corpus covers
                // planner-shaped plans, including live-locked ones
                let mut mrng =
                    crate::util::prng::SplitMix64::new(move_seed);
                for _ in 0..n_moves {
                    if let Some((next, _)) =
                        crate::planner::moves::mutate(&plan, &mut mrng)
                    {
                        plan = next;
                    }
                }
                validate(&plan).map_err(|e| e.to_string())?;
                let mut cm = CostModel::ratios(n, f, p1, p2);
                cm.opt = vec![opt; n];
                cm.loss = loss;
                cm.comm = comm;
                cm.concat_factor = cf;
                let mm = MemModel {
                    static_bytes: vec![mem_seed % 100; n],
                    res1: vec![(mem_seed >> 8) % 50; n],
                    res2: vec![(mem_seed >> 16) % 50; n],
                    inter: vec![(mem_seed >> 24) % 50; n],
                };
                let mem = with_mem.then_some(&mm);
                let budget =
                    with_budget.then_some((mem_seed >> 32) % 2000);
                let full = simulate(&plan, &cm, mem);
                let fast = score_plan(&plan, &cm, mem, budget, &mut scratch);
                match (full, fast) {
                    (Err(_), Err(_)) => Ok(()),
                    (Err(e), Ok(_)) => {
                        Err(format!("simulate rejected ({e}), score didn't"))
                    }
                    (Ok(_), Err(e)) => {
                        Err(format!("score rejected ({e}), simulate didn't"))
                    }
                    (Ok(a), Ok(s)) => {
                        let bits = |x: f64| x.to_bits();
                        if bits(a.makespan) != bits(s.makespan) {
                            return Err(format!(
                                "makespan {} != {}", a.makespan, s.makespan
                            ));
                        }
                        let total: f64 = a.busy.iter().sum();
                        if bits(total) != bits(s.total_busy) {
                            return Err(format!(
                                "busy {} != {}", total, s.total_busy
                            ));
                        }
                        if bits(a.bubble_ratio) != bits(s.bubble_ratio) {
                            return Err(format!(
                                "bubble {} != {}",
                                a.bubble_ratio, s.bubble_ratio
                            ));
                        }
                        if a.max_peak() != s.max_peak {
                            return Err(format!(
                                "peak {} != {}", a.max_peak(), s.max_peak
                            ));
                        }
                        let want_fits =
                            budget.map(|b| s.max_peak <= b).unwrap_or(true);
                        if s.fits != want_fits {
                            return Err(format!(
                                "fits {} != {}", s.fits, want_fits
                            ));
                        }
                        Ok(())
                    }
                }
            },
        );
    }

    /// Scratch reuse is shape-robust: scoring a large plan then a small
    /// one (and back) out of the same scratch never leaks state — a
    /// deterministic sequence hitting the grow/shrink boundary cases
    /// the fuzzer may miss.
    #[test]
    fn scratch_survives_shape_changes() {
        let mut scratch = Scratch::new();
        let cases = [
            (ScheduleKind::OneF1B2, 8usize, 32usize),
            (ScheduleKind::Naive, 1, 1),
            (ScheduleKind::GPipe, 4, 8),
            (ScheduleKind::OneF1B2, 8, 32),
            (ScheduleKind::OneF1B1, 2, 2),
        ];
        for &(kind, n, m) in &cases {
            let plan = generate(kind, true, n, m, false);
            let cm = CostModel::ratios(n, 1.0, 1.2, 0.8);
            let a = simulate(&plan, &cm, None).unwrap();
            let s = score_plan(&plan, &cm, None, None, &mut scratch).unwrap();
            assert_eq!(a.makespan.to_bits(), s.makespan.to_bits(),
                       "{} n={n} m={m}", kind.name());
            assert_eq!(a.bubble_ratio.to_bits(), s.bubble_ratio.to_bits());
        }
    }

    /// The reference engine also reproduces the Table 1 closed forms
    /// (it is the oracle — it must not drift).
    #[test]
    fn reference_engine_reproduces_closed_forms() {
        for n in 2..=8usize {
            let nf = n as f64;
            let plan = generate(ScheduleKind::OneF1B1, true, n, 0, false);
            let res =
                simulate_naive(&plan, &CostModel::unit(n), None).unwrap();
            assert_close(res.bubble_ratio, (nf - 1.0) / (nf - 1.0 + 3.0 * nf),
                         &format!("reference 1f1b-1+2bp N={n}"));
        }
    }
}
