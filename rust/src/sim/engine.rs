//! The simulation kernels.
//!
//! Two engines share one op-semantics core ([`op_ready`], [`exec_op`],
//! [`run_p2`]):
//!
//! * [`simulate`] — the production **event-driven** kernel: a min-heap
//!   of per-rank ready events plus dependency wakeups, O(1) amortized
//!   examinations per op.  See the module docs in [`crate::sim`] for the
//!   event-queue invariants.
//! * [`reference::simulate_naive`] — the original linear-scan loop
//!   (rescan every rank after every dispatched action), kept as the
//!   differential oracle and as the baseline the `sweep_throughput`
//!   bench measures speedup against.
//!
//! Both realize the same semantics: global earliest-start scheduling
//! over per-rank op cursors, with the 2BP greedy-p2 fill rule (run
//! deferred weight-grad work whenever a rank would otherwise idle —
//! non-preemptive, exactly like the real executor's poll-then-fill
//! loop), and the non-2BP fused-pair send rule (the input gradient is
//! released only after the paired backward-p2).  The differential
//! proptest at the bottom of this file holds them bit-for-bit equal.

use std::cmp::{Ordering, Reverse};
use std::collections::{BinaryHeap, VecDeque};

use super::{CostModel, MemModel, SimResult};
use crate::schedule::{Op, Plan};
use crate::util::gantt::{Span, SpanKind};

#[derive(Debug)]
pub struct SimError(pub String);

impl std::fmt::Display for SimError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "simulation error: {}", self.0)
    }
}

impl std::error::Error for SimError {}

struct RankState {
    t: f64,
    next: usize,
    /// p1-done microbatches whose p2 hasn't run (FIFO by p1 completion).
    pending_p2: VecDeque<u32>,
    spans: Vec<Span>,
    busy: f64,
    // memory accounting
    live: u64,
    peak: u64,
}

/// What a rank does next.  The discriminant order encodes the dispatch
/// tie-break: at equal start times a real (plan-cursor) op beats a
/// greedy p2 fill, matching the reference engine's scan rule.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
enum Action {
    Real = 0,
    FillP2 = 1,
}

fn make_states(plan: &Plan, mem: Option<&MemModel>) -> Vec<RankState> {
    (0..plan.n_ranks)
        .map(|r| {
            let static_b = mem.map(|mm| mm.static_bytes[r]).unwrap_or(0);
            RankState {
                t: 0.0,
                next: 0,
                pending_p2: VecDeque::new(),
                spans: Vec::new(),
                busy: 0.0,
                live: static_b,
                peak: static_b,
            }
        })
        .collect()
}

fn finish(n: usize, ranks: Vec<RankState>) -> SimResult {
    let makespan = ranks.iter().map(|s| s.t).fold(0.0, f64::max);
    let busy: Vec<f64> = ranks.iter().map(|s| s.busy).collect();
    let total_busy: f64 = busy.iter().sum();
    let bubble_ratio = if makespan > 0.0 {
        1.0 - total_busy / (n as f64 * makespan)
    } else {
        0.0
    };
    SimResult {
        makespan,
        bubble_ratio,
        spans: ranks.iter().map(|s| s.spans.clone()).collect(),
        peak_bytes: ranks.iter().map(|s| s.peak).collect(),
        busy,
    }
}

fn deadlock_error(plan: &Plan, ranks: &[RankState], done: usize,
                  total: usize) -> SimError {
    SimError(format!(
        "deadlock: {done}/{total} ops done; next ops: {:?}",
        (0..plan.n_ranks)
            .map(|r| plan.ranks[r].get(ranks[r].next))
            .collect::<Vec<_>>()
    ))
}

/// The per-rank dispatch decision (shared by both engines): when can
/// rank `r` act next, and is that action its next plan op or a greedy
/// p2 fill?  `None` = blocked with nothing to fill.
fn candidate(
    r: usize,
    plan: &Plan,
    costs: &CostModel,
    ranks: &[RankState],
    fwd_done: &[Vec<f64>],
    grad_sent: &[Vec<f64>],
) -> Option<(f64, Action)> {
    let st = &ranks[r];
    if st.next >= plan.ranks[r].len() {
        return None;
    }
    let op = &plan.ranks[r][st.next];
    let ready = op_ready(op, r, plan.n_ranks, costs, fwd_done, grad_sent);
    // Greedy 2BP fill rule: if the next op's input either doesn't exist
    // yet or arrives only after this rank's current time, the real
    // executor's poll fails and it starts a pending p2 instead
    // (non-preemptive — it may overshoot the arrival, which is the
    // paper's non-uniform-graph caveat in §3.2).
    let can_fill = plan.greedy_p2 && !st.pending_p2.is_empty();
    match ready {
        Some(dep_t) if dep_t <= st.t => Some((st.t, Action::Real)),
        Some(dep_t) => {
            if can_fill {
                Some((st.t, Action::FillP2))
            } else {
                Some((dep_t, Action::Real))
            }
        }
        None => can_fill.then_some((st.t, Action::FillP2)),
    }
}

/// Dependency-readiness of `op` on rank `r`: Some(t) when its external
/// input is available at time t, None when the input doesn't exist yet.
/// Local ordering is implied by the per-rank cursor.
fn op_ready(
    op: &Op,
    r: usize,
    n: usize,
    costs: &CostModel,
    fwd_done: &[Vec<f64>],
    grad_sent: &[Vec<f64>],
) -> Option<f64> {
    match op {
        Op::Fwd { mb } => {
            if r == 0 {
                Some(0.0)
            } else {
                let t = fwd_done[r - 1][*mb as usize];
                t.is_finite().then(|| t + costs.hop(r - 1, r))
            }
        }
        Op::BwdP1 { mb } => {
            if r == n - 1 {
                let t = fwd_done[r][*mb as usize];
                // loss runs on the last rank right before its first p1 use
                t.is_finite().then(|| t + costs.loss)
            } else {
                let t = grad_sent[r + 1][*mb as usize];
                t.is_finite().then(|| t + costs.hop(r, r + 1))
            }
        }
        // local-only ops: plan order + validator guarantee inputs exist
        Op::BwdP2 { .. } | Op::Flush { .. } | Op::OptStep => Some(0.0),
    }
    .filter(|t| t.is_finite())
}

/// Execute one plan op on rank `r` at `start`, updating its timeline,
/// memory accounting, and the completion tables.  Returns the neighbor
/// rank (if any) whose next op may have just become ready — the wakeup
/// edge the event-driven engine subscribes to.
#[allow(clippy::too_many_arguments)]
fn exec_op(
    op: &Op,
    r: usize,
    n: usize,
    plan: &Plan,
    costs: &CostModel,
    mem: Option<&MemModel>,
    start: f64,
    ranks: &mut [RankState],
    fwd_done: &mut [Vec<f64>],
    grad_sent: &mut [Vec<f64>],
) -> Option<usize> {
    let mut wake = None;
    match op {
        Op::Fwd { mb } => {
            let st = &mut ranks[r];
            let end = start + costs.fwd[r];
            st.spans.push(Span { start, end, label: SpanKind::Fwd, mb: *mb });
            st.busy += end - start;
            st.t = end;
            fwd_done[r][*mb as usize] = end;
            if let Some(mm) = mem {
                st.live += mm.res1[r] + mm.res2[r];
                st.peak = st.peak.max(st.live);
            }
            if r + 1 < n {
                wake = Some(r + 1);
            }
        }
        Op::BwdP1 { mb } => {
            let end = start + costs.p1[r];
            let st = &mut ranks[r];
            st.spans.push(Span { start, end, label: SpanKind::BwdP1, mb: *mb });
            st.busy += end - start;
            st.t = end;
            st.pending_p2.push_back(*mb);
            if let Some(mm) = mem {
                st.live = st.live - mm.res1[r] + mm.inter[r];
                st.peak = st.peak.max(st.live);
            }
            // 2BP: grad leaves right after p1.  Fused (non-2BP): the
            // following BwdP2 op updates grad_sent instead.
            if plan.two_bp && r > 0 {
                grad_sent[r][*mb as usize] = end;
                wake = Some(r - 1);
            }
            if !plan.two_bp {
                // fused pair: mark sent tentatively; BwdP2 will overwrite
                grad_sent[r][*mb as usize] = f64::INFINITY;
            }
        }
        Op::BwdP2 { mbs, concat } => {
            run_p2(&mut ranks[r], r, mbs, *concat, start, costs, mem);
            if !plan.two_bp {
                // fused semantics: the grad for this mb is released only now
                for mb in mbs {
                    grad_sent[r][*mb as usize] = ranks[r].t;
                }
                if r > 0 {
                    wake = Some(r - 1);
                }
            }
            let st = &mut ranks[r];
            st.pending_p2.retain(|mb| !mbs.contains(mb));
        }
        Op::Flush { upto, concat } => {
            let st = &mut ranks[r];
            let mut mbs: Vec<u32> = st
                .pending_p2
                .iter()
                .copied()
                .filter(|mb| upto.map(|u| *mb <= u).unwrap_or(true))
                .collect();
            mbs.sort_unstable();
            st.pending_p2.retain(|mb| !mbs.contains(mb));
            if !mbs.is_empty() {
                run_p2(st, r, &mbs, *concat, start, costs, mem);
            }
        }
        Op::OptStep => {
            let st = &mut ranks[r];
            let end = start + costs.opt[r];
            st.spans.push(Span { start, end, label: SpanKind::Opt, mb: 0 });
            st.busy += end - start;
            st.t = end;
        }
    }
    wake
}

fn run_p2(
    st: &mut RankState,
    r: usize,
    mbs: &[u32],
    concat: bool,
    start: f64,
    costs: &CostModel,
    mem: Option<&MemModel>,
) {
    let k = mbs.len() as f64;
    let dur = if concat && mbs.len() > 1 {
        k * costs.p2[r] * costs.concat_factor
    } else {
        k * costs.p2[r]
    };
    let end = start + dur;
    st.spans.push(Span {
        start,
        end,
        label: SpanKind::BwdP2,
        mb: mbs[0],
    });
    st.busy += dur;
    st.t = end;
    if let Some(mm) = mem {
        st.live -= (mm.res2[r] + mm.inter[r]) * mbs.len() as u64;
        st.peak = st.peak.max(st.live);
    }
}

// ---------------------------------------------------------------------------
// Event-driven engine
// ---------------------------------------------------------------------------

/// One queued dispatch opportunity.  Ordered ascending by
/// (start, action, rank) — exactly the reference engine's pick rule:
/// earliest start wins, a real op beats a fill at equal time, lowest
/// rank breaks remaining ties.  `gen` is a per-rank staleness stamp and
/// takes no part in the ordering.
#[derive(Clone, Copy)]
struct Event {
    start: f64,
    act: Action,
    rank: u32,
    gen: u32,
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}

impl Eq for Event {}

impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Event {
    fn cmp(&self, other: &Self) -> Ordering {
        // starts are finite by construction (op_ready filters infinities)
        self.start
            .partial_cmp(&other.start)
            .unwrap_or(Ordering::Equal)
            .then_with(|| self.act.cmp(&other.act))
            .then_with(|| self.rank.cmp(&other.rank))
    }
}

/// Simulate one training step of `plan` under `costs` (+ optional memory
/// model) with the event-driven kernel.
///
/// Fused (non-2BP) backward pairs are handled by the send rule: the
/// upstream rank's p1 readiness waits for the *pair* end on this rank,
/// because in plan order BwdP2 immediately follows BwdP1 and the
/// grad-send timestamp is taken after the following BwdP2 when the plan
/// is non-2BP.
pub fn simulate(
    plan: &Plan,
    costs: &CostModel,
    mem: Option<&MemModel>,
) -> Result<SimResult, SimError> {
    let n = plan.n_ranks;
    assert_eq!(costs.fwd.len(), n, "cost model rank count mismatch");

    // completion times (f64::INFINITY = not yet happened)
    let inf = f64::INFINITY;
    let m = plan.n_microbatches;
    let mut fwd_done = vec![vec![inf; m]; n];
    // time the input-grad for mb becomes available to rank r-1
    let mut grad_sent = vec![vec![inf; m]; n];
    let mut ranks = make_states(plan, mem);

    let total_ops = plan.total_ops();
    let mut done_ops = 0usize;

    let mut gen: Vec<u32> = vec![0; n];
    let mut heap: BinaryHeap<Reverse<Event>> =
        BinaryHeap::with_capacity(2 * n + 4);

    let push = |heap: &mut BinaryHeap<Reverse<Event>>,
                ranks: &[RankState],
                fwd_done: &[Vec<f64>],
                grad_sent: &[Vec<f64>],
                r: usize,
                gen: u32|
     -> bool {
        if let Some((start, act)) = candidate(r, plan, costs, ranks,
                                              fwd_done, grad_sent) {
            heap.push(Reverse(Event { start, act, rank: r as u32, gen }));
            true
        } else {
            false
        }
    };

    for r in 0..n {
        push(&mut heap, &ranks, &fwd_done, &grad_sent, r, gen[r]);
    }

    while done_ops < total_ops {
        // pop the earliest still-valid event (stale stamps are skipped)
        let ev = loop {
            match heap.pop() {
                Some(Reverse(e)) if e.gen == gen[e.rank as usize] => {
                    break Some(e)
                }
                Some(_) => continue,
                None => break None,
            }
        };
        let ev = match ev {
            Some(e) => e,
            None => {
                // Defensive full rescan.  With complete wakeup edges an
                // empty heap means no rank has a candidate (deadlock);
                // rebuilding from scratch keeps release builds exact
                // even if an edge were ever missed.
                let mut found = false;
                for r in 0..n {
                    gen[r] = gen[r].wrapping_add(1);
                    if push(&mut heap, &ranks, &fwd_done, &grad_sent, r,
                            gen[r]) {
                        found = true;
                    }
                }
                debug_assert!(
                    !found,
                    "event heap starved while candidates were runnable"
                );
                if found {
                    continue;
                }
                return Err(deadlock_error(plan, &ranks, done_ops, total_ops));
            }
        };

        let r = ev.rank as usize;
        let wake = match ev.act {
            Action::FillP2 => {
                let mb = ranks[r]
                    .pending_p2
                    .pop_front()
                    .expect("fill event with empty pending queue");
                run_p2(&mut ranks[r], r, &[mb], false, ev.start, costs, mem);
                None
            }
            Action::Real => {
                // `op` borrows `plan`, not the mutable sim state, so no
                // per-dispatch clone on the sweep hot path
                let op = &plan.ranks[r][ranks[r].next];
                let wake = exec_op(
                    op, r, n, plan, costs, mem, ev.start,
                    &mut ranks, &mut fwd_done, &mut grad_sent,
                );
                ranks[r].next += 1;
                done_ops += 1;
                wake
            }
        };

        // the executed rank always needs a fresh candidate; a woken
        // neighbor re-evaluates because a dependency it may be blocked
        // on (fwd activation from r-1, input-grad from r+1) just landed
        gen[r] = gen[r].wrapping_add(1);
        push(&mut heap, &ranks, &fwd_done, &grad_sent, r, gen[r]);
        if let Some(w) = wake {
            gen[w] = gen[w].wrapping_add(1);
            push(&mut heap, &ranks, &fwd_done, &grad_sent, w, gen[w]);
        }
    }

    Ok(finish(n, ranks))
}

// ---------------------------------------------------------------------------
// Reference engine
// ---------------------------------------------------------------------------

/// The original linear-scan simulation loop, kept verbatim in behavior:
/// rescan every rank's candidate after every dispatched action and pick
/// the global earliest (ties: real op over fill, then lowest rank).
/// O(total_ops × n_ranks) — the differential oracle for [`simulate`]
/// and the baseline for the `sweep_throughput` bench.
pub mod reference {
    use super::*;

    /// Simulate with the linear-scan loop.  Produces results
    /// bit-for-bit identical to [`simulate`] (enforced by the
    /// differential proptest in this file).
    pub fn simulate_naive(
        plan: &Plan,
        costs: &CostModel,
        mem: Option<&MemModel>,
    ) -> Result<SimResult, SimError> {
        let n = plan.n_ranks;
        assert_eq!(costs.fwd.len(), n, "cost model rank count mismatch");

        let inf = f64::INFINITY;
        let m = plan.n_microbatches;
        let mut fwd_done = vec![vec![inf; m]; n];
        let mut grad_sent = vec![vec![inf; m]; n];
        let mut ranks = make_states(plan, mem);

        let total_ops = plan.total_ops();
        let mut done_ops = 0usize;

        while done_ops < total_ops {
            // collect candidate actions
            let mut best: Option<(f64, usize, Action)> = None;
            for r in 0..n {
                let cand =
                    candidate(r, plan, costs, &ranks, &fwd_done, &grad_sent);
                if let Some((start, act)) = cand {
                    let better = match &best {
                        None => true,
                        Some((bs, _, ba)) => {
                            start < *bs
                                || (start == *bs
                                    && matches!(ba, Action::FillP2)
                                    && matches!(act, Action::Real))
                        }
                    };
                    if better {
                        best = Some((start, r, act));
                    }
                }
            }

            let (start, r, act) = best.ok_or_else(|| {
                deadlock_error(plan, &ranks, done_ops, total_ops)
            })?;

            match act {
                Action::FillP2 => {
                    let mb = ranks[r]
                        .pending_p2
                        .pop_front()
                        .expect("fill with empty pending queue");
                    run_p2(&mut ranks[r], r, &[mb], false, start, costs, mem);
                }
                Action::Real => {
                    let op = plan.ranks[r][ranks[r].next].clone();
                    let _ = exec_op(
                        &op, r, n, plan, costs, mem, start,
                        &mut ranks, &mut fwd_done, &mut grad_sent,
                    );
                    ranks[r].next += 1;
                    done_ops += 1;
                }
            }
        }

        Ok(finish(n, ranks))
    }
}

// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::reference::simulate_naive;
    use super::*;
    use crate::schedule::{generate, validate::validate, ScheduleKind};

    fn bubble(kind: ScheduleKind, two_bp: bool, n: usize) -> f64 {
        // the paper's naive rows assume no micro-batching (M = 1)
        let m = if kind == ScheduleKind::Naive { 1 } else { 0 };
        let plan = generate(kind, two_bp, n, m, false);
        validate(&plan).unwrap();
        let res = simulate(&plan, &CostModel::unit(n), None).unwrap();
        res.bubble_ratio
    }

    fn assert_close(a: f64, b: f64, what: &str) {
        assert!((a - b).abs() < 1e-9, "{what}: got {a}, want {b}");
    }

    /// The paper's Table 1 closed forms, checked exactly for N = 2..10.
    #[test]
    fn table1_naive() {
        for n in 2..=10usize {
            let nf = n as f64;
            assert_close(bubble(ScheduleKind::Naive, false, n),
                         (nf - 1.0) / nf, &format!("naive N={n}"));
            assert_close(bubble(ScheduleKind::Naive, true, n),
                         2.0 * (nf - 1.0) / (2.0 * nf + 1.0),
                         &format!("naive+2bp N={n}"));
        }
    }

    #[test]
    fn table1_gpipe() {
        for n in 2..=10usize {
            let nf = n as f64;
            assert_close(bubble(ScheduleKind::GPipe, false, n),
                         (nf - 1.0) / (2.0 * nf - 1.0),
                         &format!("gpipe N={n}"));
            assert_close(bubble(ScheduleKind::GPipe, true, n),
                         2.0 * (nf - 1.0) / (2.0 * (nf - 1.0) + 3.0 * nf),
                         &format!("gpipe+2bp N={n}"));
        }
    }

    #[test]
    fn table1_1f1b1() {
        for n in 2..=10usize {
            let nf = n as f64;
            assert_close(bubble(ScheduleKind::OneF1B1, false, n),
                         (nf - 1.0) / (2.0 * nf - 1.0),
                         &format!("1f1b-1 N={n}"));
            assert_close(bubble(ScheduleKind::OneF1B1, true, n),
                         (nf - 1.0) / (nf - 1.0 + 3.0 * nf),
                         &format!("1f1b-1+2bp N={n}"));
        }
    }

    #[test]
    fn table1_1f1b2() {
        for n in 2..=10usize {
            let nf = n as f64;
            assert_close(bubble(ScheduleKind::OneF1B2, false, n),
                         (nf - 1.0) / (3.0 * nf - 1.0),
                         &format!("1f1b-2 N={n}"));
            assert_close(bubble(ScheduleKind::OneF1B2, true, n),
                         (nf - 1.0) / (nf - 1.0 + 6.0 * nf),
                         &format!("1f1b-2+2bp N={n}"));
        }
    }

    /// Throughput gain = (1-b)/(1-a) from Table 1's last column.
    #[test]
    fn table1_throughput_gains() {
        let n = 4usize;
        let nf = n as f64;
        let gain = |k: ScheduleKind| {
            let a = bubble(k, false, n);
            let b = bubble(k, true, n);
            (1.0 - b) / (1.0 - a)
        };
        assert_close(gain(ScheduleKind::Naive),
                     3.0 * nf / (2.0 * nf + 1.0), "naive gain");
        assert_close(gain(ScheduleKind::GPipe),
                     3.0 * (2.0 * nf - 1.0) / (2.0 * (nf - 1.0) + 3.0 * nf),
                     "gpipe gain");
        assert_close(gain(ScheduleKind::OneF1B1),
                     3.0 * (2.0 * nf - 1.0) / (nf - 1.0 + 3.0 * nf),
                     "1f1b-1 gain");
        assert_close(gain(ScheduleKind::OneF1B2),
                     3.0 * (3.0 * nf - 1.0) / (nf - 1.0 + 6.0 * nf),
                     "1f1b-2 gain");
    }

    #[test]
    fn two_bp_never_slower_at_unit_costs() {
        for kind in ScheduleKind::all() {
            for n in 2..=8 {
                let a = bubble(kind, false, n);
                let b = bubble(kind, true, n);
                assert!(
                    (1.0 - b) / (1.0 - a) >= 1.0 - 1e-12,
                    "{} N={n}: 2BP slowed throughput ({a} -> {b})",
                    kind.name()
                );
            }
        }
    }

    #[test]
    fn single_rank_has_no_bubble_without_comm() {
        for kind in ScheduleKind::all() {
            for two_bp in [false, true] {
                let plan = generate(kind, two_bp, 1, 4, false);
                let res = simulate(&plan, &CostModel::unit(1), None).unwrap();
                assert!(res.bubble_ratio.abs() < 1e-12,
                        "{} 2bp={two_bp}", kind.name());
            }
        }
    }

    #[test]
    fn comm_increases_makespan() {
        let plan = generate(ScheduleKind::OneF1B1, true, 4, 0, false);
        let base = simulate(&plan, &CostModel::unit(4), None).unwrap();
        let mut cm = CostModel::unit(4);
        cm.comm = 0.25;
        let with = simulate(&plan, &cm, None).unwrap();
        assert!(with.makespan > base.makespan);
    }

    #[test]
    fn inter_node_hop_penalty_applies() {
        let mut cm = CostModel::unit(8);
        cm.comm = 0.1;
        cm.comm_inter_node = 1.0;
        cm.ranks_per_node = 4;
        assert_close(cm.hop(3, 4), 1.1, "inter-node hop");
        assert_close(cm.hop(2, 3), 0.1, "intra-node hop");
    }

    #[test]
    fn memory_peaks_scale_with_schedule() {
        // GPipe stashes all M microbatches; 1F1B-1 rank N-1 stashes 1.
        let n = 4;
        let mm = MemModel {
            static_bytes: vec![0; n],
            res1: vec![10; n],
            res2: vec![100; n],
            inter: vec![50; n],
        };
        let gpipe = simulate(
            &generate(ScheduleKind::GPipe, false, n, 0, false),
            &CostModel::unit(n), Some(&mm)).unwrap();
        let f1b = simulate(
            &generate(ScheduleKind::OneF1B1, false, n, 0, false),
            &CostModel::unit(n), Some(&mm)).unwrap();
        // rank 0 peak: 4 x (res1+res2) stashed, +inter during the first
        // backward before res1 releases: 4*110 - 10 + 50 = 480
        assert_eq!(gpipe.peak_bytes[0], 480);
        // 1F1B rank N-1 holds at most ~1-2 microbatches
        assert!(f1b.peak_bytes[n - 1] < gpipe.peak_bytes[n - 1]);
    }

    #[test]
    fn two_bp_increases_peak_memory() {
        // the paper's Fig 4: 2BP trades memory for throughput
        let n = 4;
        let mm = MemModel {
            static_bytes: vec![0; n],
            res1: vec![10; n],
            res2: vec![100; n],
            inter: vec![50; n],
        };
        for kind in ScheduleKind::all() {
            let a = simulate(&generate(kind, false, n, 0, false),
                             &CostModel::unit(n), Some(&mm)).unwrap();
            let b = simulate(&generate(kind, true, n, 0, false),
                             &CostModel::unit(n), Some(&mm)).unwrap();
            assert!(
                b.max_peak() >= a.max_peak(),
                "{}: 2BP peak {} < non-2BP {}",
                kind.name(), b.max_peak(), a.max_peak()
            );
        }
    }

    #[test]
    fn eager_p2_variant_cuts_1f1b2_peak() {
        // Fig 5: mid-step flush caps the stash vs plain 1F1B-2 + 2BP
        let n = 4;
        let mm = MemModel {
            static_bytes: vec![0; n],
            res1: vec![10; n],
            res2: vec![100; n],
            inter: vec![50; n],
        };
        let plain = simulate(&generate(ScheduleKind::OneF1B2, true, n, 0, false),
                             &CostModel::unit(n), Some(&mm)).unwrap();
        let eager = simulate(
            &generate(ScheduleKind::OneF1B2EagerP2, true, n, 0, false),
            &CostModel::unit(n), Some(&mm)).unwrap();
        assert!(
            eager.max_peak() <= plain.max_peak(),
            "eager {} vs plain {}", eager.max_peak(), plain.max_peak()
        );
    }

    #[test]
    fn spans_cover_busy_time_exactly() {
        let plan = generate(ScheduleKind::OneF1B2, true, 4, 0, false);
        let res = simulate(&plan, &CostModel::ratios(4, 1.0, 1.2, 0.8), None)
            .unwrap();
        for (r, spans) in res.spans.iter().enumerate() {
            let total: f64 = spans.iter().map(|s| s.end - s.start).sum();
            assert!((total - res.busy[r]).abs() < 1e-9);
            // spans never overlap
            let mut sorted = spans.clone();
            sorted.sort_by(|a, b| a.start.partial_cmp(&b.start).unwrap());
            for w in sorted.windows(2) {
                assert!(w[1].start >= w[0].end - 1e-12);
            }
        }
    }

    #[test]
    fn prop_simulation_never_deadlocks() {
        use crate::util::proptest::{check, gen};
        check(
            "simulate() terminates for fuzzed plans/costs",
            150,
            |rng| {
                let kind = *gen::pick(rng, &ScheduleKind::all_variants());
                let two_bp = if kind == ScheduleKind::OneF1B2EagerP2 {
                    true
                } else {
                    gen::bool(rng)
                };
                let n = gen::usize_in(rng, 1, 8);
                let m = gen::usize_in(rng, 1, 16);
                let f = 0.5 + rng.next_f64();
                let p1 = 0.5 + rng.next_f64();
                let p2 = 0.5 + rng.next_f64();
                let comm = rng.next_f64() * 0.3;
                (kind, two_bp, n, m, f, p1, p2, comm)
            },
            |&(kind, two_bp, n, m, f, p1, p2, comm)| {
                let plan = generate(kind, two_bp, n, m, two_bp);
                let mut cm = CostModel::ratios(n, f, p1, p2);
                cm.comm = comm;
                let res = simulate(&plan, &cm, None)
                    .map_err(|e| e.to_string())?;
                if !(res.bubble_ratio >= -1e-9 && res.bubble_ratio < 1.0) {
                    return Err(format!("bubble {}", res.bubble_ratio));
                }
                // all compute accounted: busy == m*(f+p1+p2) (+opt=0)
                let want = m as f64 * (f + p1 + p2);
                for b in &res.busy {
                    if (b - want).abs() > 1e-6 {
                        return Err(format!("busy {b} != {want}"));
                    }
                }
                Ok(())
            },
        );
    }

    /// Every field of a [`SimResult`], compared bitwise.
    fn assert_identical(a: &SimResult, b: &SimResult, what: &str) {
        let f = |x: f64| x.to_bits();
        assert_eq!(f(a.makespan), f(b.makespan), "{what}: makespan");
        assert_eq!(f(a.bubble_ratio), f(b.bubble_ratio), "{what}: bubble");
        assert_eq!(a.busy.len(), b.busy.len(), "{what}: busy len");
        for (x, y) in a.busy.iter().zip(&b.busy) {
            assert_eq!(f(*x), f(*y), "{what}: busy");
        }
        assert_eq!(a.peak_bytes, b.peak_bytes, "{what}: peaks");
        assert_eq!(a.spans.len(), b.spans.len(), "{what}: span ranks");
        for (ra, rb) in a.spans.iter().zip(&b.spans) {
            assert_eq!(ra.len(), rb.len(), "{what}: span count");
            for (sa, sb) in ra.iter().zip(rb) {
                assert!(
                    f(sa.start) == f(sb.start)
                        && f(sa.end) == f(sb.end)
                        && sa.label == sb.label
                        && sa.mb == sb.mb,
                    "{what}: span {sa:?} != {sb:?}"
                );
            }
        }
    }

    /// The differential oracle: for fuzzed valid plans + cost/memory
    /// models, the event-driven engine and the linear-scan reference
    /// must agree bit-for-bit on makespan, busy times, bubble ratio,
    /// span sets, and peak bytes.
    #[test]
    fn prop_event_engine_matches_reference() {
        use crate::util::proptest::{check, gen};
        check(
            "event-driven simulate() == reference simulate_naive()",
            400,
            |rng| {
                let kind = *gen::pick(rng, &ScheduleKind::all_variants());
                let two_bp = if kind == ScheduleKind::OneF1B2EagerP2 {
                    true
                } else {
                    gen::bool(rng)
                };
                let n = gen::usize_in(rng, 1, 8);
                let m = gen::usize_in(rng, 1, 16);
                let concat = gen::bool(rng);
                let costs = (
                    0.25 + rng.next_f64(),
                    0.25 + rng.next_f64(),
                    0.25 + rng.next_f64(),
                    rng.next_f64() * 0.2,        // opt
                    rng.next_f64() * 0.3,        // loss
                    if gen::bool(rng) { rng.next_f64() * 0.4 } else { 0.0 },
                    0.8 + rng.next_f64() * 0.4,  // concat factor
                );
                let with_mem = gen::bool(rng);
                let mem_seed = rng.next_u64();
                (kind, two_bp, n, m, concat, costs, with_mem, mem_seed)
            },
            |&(kind, two_bp, n, m, concat, costs, with_mem, mem_seed)| {
                let (f, p1, p2, opt, loss, comm, cf) = costs;
                let plan = generate(kind, two_bp, n, m, concat);
                validate(&plan).map_err(|e| e.to_string())?;
                let mut cm = CostModel::ratios(n, f, p1, p2);
                cm.opt = vec![opt; n];
                cm.loss = loss;
                cm.comm = comm;
                cm.concat_factor = cf;
                if mem_seed & 1 == 1 {
                    cm.comm_inter_node = 0.5;
                    cm.ranks_per_node = 1 + (mem_seed >> 1) as usize % 4;
                }
                let mm = MemModel {
                    static_bytes: vec![mem_seed % 100; n],
                    res1: vec![(mem_seed >> 8) % 50; n],
                    res2: vec![(mem_seed >> 16) % 50; n],
                    inter: vec![(mem_seed >> 24) % 50; n],
                };
                let mem = with_mem.then_some(&mm);
                let a = simulate(&plan, &cm, mem)
                    .map_err(|e| format!("event: {e}"))?;
                let b = simulate_naive(&plan, &cm, mem)
                    .map_err(|e| format!("reference: {e}"))?;
                assert_identical(&a, &b, &plan.describe());
                Ok(())
            },
        );
    }

    /// The reference engine also reproduces the Table 1 closed forms
    /// (it is the oracle — it must not drift).
    #[test]
    fn reference_engine_reproduces_closed_forms() {
        for n in 2..=8usize {
            let nf = n as f64;
            let plan = generate(ScheduleKind::OneF1B1, true, n, 0, false);
            let res =
                simulate_naive(&plan, &CostModel::unit(n), None).unwrap();
            assert_close(res.bubble_ratio, (nf - 1.0) / (nf - 1.0 + 3.0 * nf),
                         &format!("reference 1f1b-1+2bp N={n}"));
        }
    }
}
