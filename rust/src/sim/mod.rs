//! Discrete-event pipeline simulator.
//!
//! Replays a [`Plan`](crate::schedule::Plan) against a [`CostModel`]
//! (per-rank op durations + communication) and an optional [`MemModel`]
//! (per-microbatch byte classes from the artifact manifest), producing
//! per-rank timelines, bubble ratios, throughput and peak-memory
//! figures.
//!
//! Two roles:
//!
//! 1. **Theory checks** — with unit costs it must reproduce the paper's
//!    Table 1 closed forms exactly (tested in `engine.rs`).
//! 2. **Calibrated replay** — with op costs *measured* from the real
//!    PJRT runtime it predicts throughput for rank counts this host
//!    cannot run in parallel (Figs 3/6/7; the host has one core, see
//!    DESIGN.md §3).
//!
//! # The event-driven kernel and its invariants
//!
//! [`simulate`] is an event-driven discrete-event kernel: a min-heap of
//! per-rank *dispatch events* `(start, action, rank)` plus dependency
//! wakeups, instead of rescanning every rank after every dispatched op
//! (the original loop, retained as [`simulate_naive`] — the
//! differential oracle and the sweep bench baseline).  Each op is
//! examined O(1) amortized times, so schedule-space sweeps over
//! thousands of (schedule × ranks × microbatches × cost-ratio) cells
//! become interactive (`experiments::sweep`).
//!
//! The kernel preserves the reference semantics **bit-for-bit** (a
//! differential proptest over fuzzed plans enforces equality of
//! makespan, busy times, bubble ratio, span sets, and peak bytes).  The
//! invariants that make that hold:
//!
//! 1. **Earliest-event processing.**  The heap always pops the globally
//!    earliest runnable action (ties: real plan op before greedy fill,
//!    then lowest rank — the reference scan order).  Because every
//!    unexecuted action starts no earlier than the popped one, any
//!    question of the form "has dependency X arrived by time t?" is
//!    already decided when asked — which is exactly what keeps the
//!    **greedy-p2 fill rule non-preemptive and exact**: a rank that
//!    goes idle at time t fills with a deferred p2 only if its next
//!    op's input is not available at t, and no later-processed event
//!    can retroactively make that input available at ≤ t.
//! 2. **Complete wakeup edges.**  A blocked rank's decision can change
//!    only when one of its next op's external inputs lands.  Those
//!    writes are: `fwd_done[r]` by `Fwd` on rank r (wakes r+1),
//!    `grad_sent[r]` by `BwdP1` on rank r under 2BP (wakes r-1), and
//!    `grad_sent[r]` by `BwdP2` on rank r under fused (non-2BP)
//!    autograd (wakes r-1).  The last edge is how the **fused-pair
//!    grad-send timestamp is preserved**: without 2BP the input-grad
//!    is released only when the paired backward-p2 finishes, so the
//!    upstream wakeup fires at the pair end — never at p1 end.
//! 3. **Staleness stamps.**  Each rank carries a generation counter;
//!    (re)computing its candidate bumps the stamp and pushes a fresh
//!    event.  Popped events with stale stamps are discarded, so the
//!    heap never dispatches from outdated state.
//!
//! Everything downstream of the dispatch decision (op execution, span
//! recording, byte accounting) is one shared code path between the two
//! engines, so the oracle comparison isolates exactly the scheduling
//! logic.
//!
//! # The two-tier evaluation contract
//!
//! Search workloads (the planner's beam, schedule-space sweeps) evaluate
//! thousands of plans and read only a handful of scalars per plan;
//! rendering workloads (gantt, winner reports, the span-shape tests)
//! evaluate one plan and read its full timeline.  The simulator exposes
//! one entry point per tier:
//!
//! * **Tier A — scoring:** [`score_plan`] runs the event-driven kernel
//!   with span recording compiled out and every buffer (rank states,
//!   completion tables, event heap, pending-p2 queues) borrowed from a
//!   caller-owned [`Scratch`], so a warmed-up scratch evaluates a
//!   candidate with **zero heap allocations**.  It returns a [`Score`]
//!   — makespan, total busy, bubble ratio, max peak bytes, and a
//!   budget-fit flag — and nothing else.  `score_plan` does **not**
//!   validate: callers pass plans that are already known valid (the
//!   planner validates seeds once and incrementally revalidates local
//!   moves; `twobp sweep --plans` validates each file after parsing).
//! * **Tier B — rendering:** [`simulate`] records per-op [`Span`]s and
//!   returns the full [`SimResult`]; [`eval_plan`] wraps it with a full
//!   `schedule::validate` pass and the budget check — the one-stop path
//!   for winners, `gantt --plan`, and anything user-facing.
//!
//! The contract between the tiers: on any valid plan, `score_plan` is
//! **bit-identical** to `simulate` on makespan, summed busy time,
//! bubble ratio, and per-step max peak bytes, and the two agree on
//! rejection (deadlock) — enforced by a differential proptest in
//! `engine.rs` that reuses one scratch across every fuzzed case.
//! Spans exist only on Tier B: a `Score` carries none, by design —
//! render the winner with `simulate` when its timeline is needed.

pub mod engine;
pub mod perturb;

pub use engine::reference::simulate_naive;
pub use engine::{score_plan, simulate, Scratch, SimError};
pub use perturb::{score_plan_robust, Perturbation, RobustScore, RobustScratch};

use crate::util::gantt::Span;

/// Per-rank op durations (seconds, or abstract units).
#[derive(Debug, Clone)]
pub struct CostModel {
    pub fwd: Vec<f64>,
    pub p1: Vec<f64>,
    /// Cost of one microbatch's backward-p2.
    pub p2: Vec<f64>,
    pub opt: Vec<f64>,
    /// Loss + initial-gradient cost on the last rank.
    pub loss: f64,
    /// Activation/gradient hop latency between adjacent ranks.
    pub comm: f64,
    /// Extra latency when a hop crosses a node boundary (Figs 6/7: the
    /// paper's 4-GPU nodes mean hops at rank%4==3 are inter-node).
    pub comm_inter_node: f64,
    pub ranks_per_node: usize,
    /// Cost multiplier for a concatenated p2 covering k microbatches,
    /// relative to k separate calls (Table 3 found ≈ 1.0: concat saves
    /// dispatch but pays the copy).
    pub concat_factor: f64,
}

impl Default for CostModel {
    /// Empty (0-rank) model — the pre-warmup state of a
    /// [`perturb::RobustScratch`] working copy.
    fn default() -> Self {
        CostModel::unit(0)
    }
}

impl CostModel {
    /// Uniform unit-cost model (the Table 1 idealization: fwd = p1 = p2).
    pub fn unit(n_ranks: usize) -> Self {
        CostModel {
            fwd: vec![1.0; n_ranks],
            p1: vec![1.0; n_ranks],
            p2: vec![1.0; n_ranks],
            opt: vec![0.0; n_ranks],
            loss: 0.0,
            comm: 0.0,
            comm_inter_node: 0.0,
            ranks_per_node: usize::MAX,
            concat_factor: 1.0,
        }
    }

    /// Uniform costs with explicit f/p1/p2 ratios.
    pub fn ratios(n_ranks: usize, f: f64, p1: f64, p2: f64) -> Self {
        CostModel {
            fwd: vec![f; n_ranks],
            p1: vec![p1; n_ranks],
            p2: vec![p2; n_ranks],
            ..CostModel::unit(n_ranks)
        }
    }

    /// Hop latency from rank r to r±1.
    pub fn hop(&self, from: usize, to: usize) -> f64 {
        let a = from.min(to);
        let cross = self.ranks_per_node != usize::MAX
            && (a + 1) % self.ranks_per_node == 0;
        self.comm + if cross { self.comm_inter_node } else { 0.0 }
    }
}

/// Ring-allreduce wall time for one step of a `dp`-way replicated
/// stage holding `bytes` of gradients, at `per_byte` seconds/byte of
/// link bandwidth: each replica sends and receives `2·(dp-1)/dp` of
/// the buffer (reduce-scatter + all-gather).  `dp <= 1` costs nothing.
///
/// This is the DP term the partition co-search adds to a plan's
/// makespan — deliberately **outside** the event kernel, so the
/// two-tier contract above is untouched by the partition refactor
/// (the kernel still never sees anything but per-stage costs).
pub fn allreduce_time(dp: u32, bytes: u64, per_byte: f64) -> f64 {
    if dp <= 1 {
        0.0
    } else {
        2.0 * (dp as f64 - 1.0) / dp as f64 * bytes as f64 * per_byte
    }
}

/// Per-rank, per-microbatch byte classes (from the manifest) driving the
/// memory timeline (Fig 4/5 cross-check, Fig 7 OOM prediction).
#[derive(Debug, Clone)]
pub struct MemModel {
    /// Static residency: params + grads + optimizer state (+ anything
    /// held for the whole step), per rank.
    pub static_bytes: Vec<u64>,
    /// res1 (released at p1), res2 (held to p2), inter (p1 -> p2) per
    /// microbatch per rank.
    pub res1: Vec<u64>,
    pub res2: Vec<u64>,
    pub inter: Vec<u64>,
}

/// Result of simulating one training step.
#[derive(Debug, Clone)]
pub struct SimResult {
    pub makespan: f64,
    pub busy: Vec<f64>,
    /// idle / (N * makespan) — the paper's bubble ratio.
    pub bubble_ratio: f64,
    pub spans: Vec<Vec<Span>>,
    /// Peak live bytes per rank (only if a MemModel was supplied).
    pub peak_bytes: Vec<u64>,
}

impl SimResult {
    /// Samples/second given samples per microbatch and total microbatches.
    pub fn throughput(&self, samples_per_mb: usize, n_mb: usize) -> f64 {
        (samples_per_mb * n_mb) as f64 / self.makespan
    }

    /// Max of `peak_bytes` — the paper's Fig 4 "peak memory" metric
    /// (max over GPUs of per-GPU peak reserved memory).
    pub fn max_peak(&self) -> u64 {
        self.peak_bytes.iter().copied().max().unwrap_or(0)
    }
}

/// Tier A scoring result — everything a search ranks on, nothing it
/// doesn't (no spans, no per-rank vectors; see the two-tier evaluation
/// contract in the module docs).  Bit-identical to the corresponding
/// [`SimResult`] reductions, enforced by a differential proptest.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Score {
    pub makespan: f64,
    /// Sum of per-rank busy time (identical to summing
    /// [`SimResult::busy`] in rank order).
    pub total_busy: f64,
    /// idle / (N * makespan) — the paper's bubble ratio.
    pub bubble_ratio: f64,
    /// Max over ranks of peak live bytes (0 without a [`MemModel`]).
    pub max_peak: u64,
    /// `max_peak <= budget` (vacuously true without a budget).
    pub fits: bool,
}

impl Score {
    /// Samples/second given samples per microbatch and total microbatches
    /// (same formula as [`SimResult::throughput`]).
    pub fn throughput(&self, samples_per_mb: usize, n_mb: usize) -> f64 {
        (samples_per_mb * n_mb) as f64 / self.makespan
    }
}

/// Evaluation of one concrete plan against a cost/memory model and an
/// optional per-rank byte budget — the planner's unit of work, also
/// behind `twobp gantt --plan`.
#[derive(Debug, Clone)]
pub struct PlanEval {
    pub result: SimResult,
    /// `result.max_peak()`, cached (0 when no `MemModel` was given).
    pub max_peak: u64,
    /// Every rank's peak fits the budget (vacuously true without a
    /// budget or without a `MemModel`).
    pub fits: bool,
}

/// One-stop "how good is this plan" entry point (Tier B): statically
/// validate, simulate with spans, and score the peak against an
/// optional per-rank budget.  For bulk candidate evaluation use
/// [`score_plan`] instead — it skips validation and span recording and
/// reuses a caller-owned [`Scratch`] (the two-tier contract in the
/// module docs).
///
/// Validation failures and simulator deadlocks (possible for custom /
/// mutated plans whose cross-rank interleave is inconsistent even
/// though each rank is locally coherent) both surface as [`SimError`],
/// so callers have exactly one rejection path.
pub fn eval_plan(
    plan: &crate::schedule::Plan,
    costs: &CostModel,
    mem: Option<&MemModel>,
    budget: Option<u64>,
) -> Result<PlanEval, SimError> {
    crate::schedule::validate::validate(plan)
        .map_err(|e| SimError(e.to_string()))?;
    let result = simulate(plan, costs, mem)?;
    let max_peak = result.max_peak();
    let fits = budget.map(|b| max_peak <= b).unwrap_or(true);
    Ok(PlanEval { result, max_peak, fits })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allreduce_time_follows_the_ring_formula() {
        assert_eq!(allreduce_time(1, 1 << 30, 1e-9), 0.0);
        assert!((allreduce_time(2, 1000, 1e-3) - 1.0).abs() < 1e-12);
        assert!((allreduce_time(4, 1000, 1e-3) - 1.5).abs() < 1e-12);
        // traffic grows toward 2·bytes as dp → ∞
        assert!(allreduce_time(8, 1000, 1e-3)
            > allreduce_time(4, 1000, 1e-3));
    }
}
