//! Stochastic cost perturbation and tail-makespan (robust) scoring.
//!
//! The simulator's clean world scores every plan at its *expected*
//! makespan, but tightly packed schedules — exactly the ones 2BP's
//! deferral produces — are the ones a single straggler rank or a comm
//! spike unravels.  This module adds a seeded [`Perturbation`] model
//! and [`score_plan_robust`]: K Monte-Carlo draws of a perturbed
//! [`CostModel`] scored through the Tier A fast path
//! ([`score_plan`]), reusing one workspace ([`RobustScratch`]) so the
//! zero-allocation discipline of the scoring tier carries over —
//! a warmed-up scratch evaluates all K draws without heap allocation.
//!
//! # The perturbation model
//!
//! Draw `d` derives its own PRNG from `(seed, d)` — a pure function,
//! so results are independent of evaluation order and thread count,
//! and every candidate plan sees the *same* K perturbed worlds
//! (common random numbers: candidate comparisons are paired, which
//! cuts the variance of "A beats B" decisions).  Within a draw,
//! factors apply in a fixed order:
//!
//! 1. **Per-op jitter** — every per-rank cost entry (fwd, p1, p2, opt;
//!    then loss, then comm) is multiplied by `exp(jitter * z)`,
//!    `z ~ N(0,1)`: lognormal noise, always positive, median 1.
//! 2. **Stragglers** — deterministic per-rank multipliers applied to
//!    every draw (the "rank 2 is on a slow host" scenario).
//! 3. **Comm spike** — one Bernoulli per draw; on success all hop
//!    latencies multiply by `comm_spike_mult` (a congested fabric).
//!
//! # The identity contract
//!
//! With `jitter = 0`, straggler multipliers of `1.0`, and
//! `comm_spike_prob = 0`, every factor is *exactly* `1.0`, and
//! multiplying a finite positive f64 by `1.0` is bit-exact — so each
//! draw's [`Score`] is bit-identical to [`score_plan`]'s, with **no
//! special-casing** on the identity path (the normal draws are still
//! consumed, keeping the PRNG stream position independent of the knob
//! values).  The only subtlety is the mean: summing K copies of x and
//! dividing by K can round when K is not a power of two, so the
//! all-identical case short-circuits to the common value.  A
//! differential proptest below holds every [`RobustScore`] field
//! bit-equal to the corresponding [`score_plan`] field under the
//! identity perturbation.

use super::{score_plan, CostModel, MemModel, Scratch, SimError};
use crate::schedule::Plan;
use crate::util::prng::SplitMix64;

/// Seeded stochastic perturbation of a [`CostModel`] (see the module
/// docs for the model and the identity contract).
#[derive(Debug, Clone)]
pub struct Perturbation {
    /// Lognormal sigma of the per-op multiplicative jitter: each cost
    /// entry multiplies by `exp(jitter * z)`, `z ~ N(0,1)`.  0 = none.
    pub jitter: f64,
    /// Deterministic `(rank, multiplier)` straggler factors applied to
    /// that rank's fwd/p1/p2/opt in every draw.  `1.0` is a no-op;
    /// out-of-range ranks are ignored (the CLI validates them).
    pub stragglers: Vec<(usize, f64)>,
    /// Per-draw probability that all hop latencies spike.
    pub comm_spike_prob: f64,
    /// Comm multiplier when a spike fires.
    pub comm_spike_mult: f64,
    /// Base seed; draw `d` uses a pure function of `(seed, d)`.
    pub seed: u64,
}

impl Default for Perturbation {
    fn default() -> Self {
        Perturbation {
            jitter: 0.0,
            stragglers: Vec::new(),
            comm_spike_prob: 0.0,
            comm_spike_mult: 4.0,
            seed: 0x2B9_7E57,
        }
    }
}

impl Perturbation {
    /// True when every factor this model can produce is exactly 1.0
    /// (the bit-identity regime of the module docs).
    pub fn is_identity(&self) -> bool {
        self.jitter == 0.0
            && self.comm_spike_prob <= 0.0
            && self.stragglers.iter().all(|&(_, m)| m == 1.0)
    }

    /// The PRNG for draw `d` — a pure function of `(seed, d)`, so draws
    /// are identical regardless of evaluation order or thread count.
    fn draw_rng(&self, d: usize) -> SplitMix64 {
        SplitMix64::new(
            self.seed
                ^ (d as u64 + 1).wrapping_mul(0xA24B_AED4_963E_E407),
        )
    }

    /// Apply draw `d` in place to `dst` (already a copy of the base
    /// costs).  Factor order is fixed — see the module docs.
    fn apply(&self, d: usize, dst: &mut CostModel) {
        let mut rng = self.draw_rng(d);
        let n = dst.fwd.len();
        for r in 0..n {
            dst.fwd[r] *= jitter_factor(self.jitter, &mut rng);
            dst.p1[r] *= jitter_factor(self.jitter, &mut rng);
            dst.p2[r] *= jitter_factor(self.jitter, &mut rng);
            dst.opt[r] *= jitter_factor(self.jitter, &mut rng);
        }
        dst.loss *= jitter_factor(self.jitter, &mut rng);
        dst.comm *= jitter_factor(self.jitter, &mut rng);
        for &(rank, mult) in &self.stragglers {
            if rank < n {
                dst.fwd[rank] *= mult;
                dst.p1[rank] *= mult;
                dst.p2[rank] *= mult;
                dst.opt[rank] *= mult;
            }
        }
        // the Bernoulli draw is consumed unconditionally so the stream
        // position never depends on the probability knob
        let spike = rng.next_f64() < self.comm_spike_prob;
        if spike {
            dst.comm *= self.comm_spike_mult;
            dst.comm_inter_node *= self.comm_spike_mult;
        }
    }
}

/// One lognormal factor.  The normal draw is consumed even at
/// `sigma = 0` (stream position must not depend on the knob), where
/// `0.0 * z = ±0.0` and `exp(±0.0) = 1.0` exactly — the identity
/// contract needs no branch here.
fn jitter_factor(sigma: f64, rng: &mut SplitMix64) -> f64 {
    (sigma * rng.normal()).exp()
}

/// Tail statistics over K perturbed draws of one plan.  Percentiles
/// use the deterministic nearest-rank rule on the sorted makespans.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RobustScore {
    /// Median makespan over the draws.
    pub p50: f64,
    /// 95th-percentile makespan — the tail objective robust tuning
    /// ranks on.
    pub p95: f64,
    /// Worst-case (max) makespan over the draws.
    pub worst: f64,
    /// Mean makespan (exact when every draw agrees — see module docs).
    pub mean: f64,
    /// Fraction of draws whose peak bytes fit the budget (1.0 when all
    /// fit, or when no budget was given).
    pub fit_fraction: f64,
    /// Max over draws of the per-draw max peak bytes.
    pub max_peak: u64,
}

impl RobustScore {
    /// Tail throughput: samples/sec at the p95 makespan (the robust
    /// analogue of [`super::Score::throughput`]).
    pub fn throughput_p95(&self, samples_per_mb: usize, n_mb: usize) -> f64 {
        (samples_per_mb * n_mb) as f64 / self.p95
    }
}

/// Caller-owned workspace for [`score_plan_robust`]: the inner Tier A
/// [`Scratch`], a reusable perturbed-cost working copy, and the
/// makespan sample buffer.  Like `Scratch`, buffers grow monotonically
/// and are reused verbatim — one per worker thread, never shared.
#[derive(Default)]
pub struct RobustScratch {
    sim: Scratch,
    costs: CostModel,
    makespans: Vec<f64>,
}

impl RobustScratch {
    pub fn new() -> RobustScratch {
        RobustScratch::default()
    }

    /// The inner Tier A scratch, for callers that interleave plain
    /// [`score_plan`] calls with robust ones (the planner's evaluate
    /// loop) without carrying two workspaces.
    pub fn sim_mut(&mut self) -> &mut Scratch {
        &mut self.sim
    }
}

/// Overwrite `dst` with `src` reusing `dst`'s allocations (derived
/// `clone_from` would reallocate the vectors).
fn copy_costs(dst: &mut CostModel, src: &CostModel) {
    dst.fwd.clear();
    dst.fwd.extend_from_slice(&src.fwd);
    dst.p1.clear();
    dst.p1.extend_from_slice(&src.p1);
    dst.p2.clear();
    dst.p2.extend_from_slice(&src.p2);
    dst.opt.clear();
    dst.opt.extend_from_slice(&src.opt);
    dst.loss = src.loss;
    dst.comm = src.comm;
    dst.comm_inter_node = src.comm_inter_node;
    dst.ranks_per_node = src.ranks_per_node;
    dst.concat_factor = src.concat_factor;
}

/// Score `plan` under `trials` Monte-Carlo draws of `pert` applied to
/// `costs`, reusing `scratch` across draws (and across calls) — the
/// robust counterpart of [`score_plan`], same caller contract: the
/// plan must already be valid, and a deadlocked plan returns `Err`
/// (cost scaling never changes *whether* a plan deadlocks, only when
/// ops run, so any draw failing means the base plan fails).
///
/// `trials` is clamped to at least 1.  Under the identity perturbation
/// every field is bit-identical to the corresponding [`score_plan`]
/// field (differential proptest below).
pub fn score_plan_robust(
    plan: &Plan,
    costs: &CostModel,
    mem: Option<&MemModel>,
    budget: Option<u64>,
    pert: &Perturbation,
    trials: usize,
    scratch: &mut RobustScratch,
) -> Result<RobustScore, SimError> {
    let k = trials.max(1);
    let RobustScratch { sim, costs: work, makespans } = scratch;
    makespans.clear();
    let mut fit_count = 0usize;
    let mut max_peak = 0u64;
    for d in 0..k {
        copy_costs(work, costs);
        pert.apply(d, work);
        let s = score_plan(plan, work, mem, budget, sim)?;
        makespans.push(s.makespan);
        if s.fits {
            fit_count += 1;
        }
        max_peak = max_peak.max(s.max_peak);
    }
    makespans.sort_unstable_by(f64::total_cmp);
    // nearest-rank percentile: index ceil(q*K) - 1 (1-based rank)
    let pct = |q: f64| makespans[((q * k as f64).ceil() as usize).clamp(1, k) - 1];
    let p50 = pct(0.50);
    let p95 = pct(0.95);
    let worst = makespans[k - 1];
    // sum/K of K identical values can round when K is not a power of
    // two; the all-identical case (incl. the identity perturbation)
    // short-circuits to the exact common value
    let mean = if makespans[0].to_bits() == makespans[k - 1].to_bits() {
        makespans[0]
    } else {
        makespans.iter().sum::<f64>() / k as f64
    };
    Ok(RobustScore {
        p50,
        p95,
        worst,
        mean,
        fit_fraction: fit_count as f64 / k as f64,
        max_peak,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::{generate, validate::validate, ScheduleKind};

    fn pert(jitter: f64, stragglers: &[(usize, f64)]) -> Perturbation {
        Perturbation {
            jitter,
            stragglers: stragglers.to_vec(),
            ..Perturbation::default()
        }
    }

    /// The identity contract: jitter = 0, straggler = 1.0, spike
    /// prob = 0 must reproduce `score_plan` bit-for-bit on every
    /// field, across fuzzed plans / cost models / budgets / trial
    /// counts (odd K exercises the exact-mean short circuit), with
    /// one scratch reused across all cases.
    #[test]
    fn prop_identity_perturbation_matches_score_plan() {
        use crate::util::proptest::{check, gen};
        let mut rs = RobustScratch::new();
        let mut plain = Scratch::new();
        check(
            "score_plan_robust(identity) == score_plan, bit for bit",
            200,
            |rng| {
                let kind = *gen::pick(rng, &ScheduleKind::all_variants());
                let two_bp = if kind == ScheduleKind::OneF1B2EagerP2 {
                    true
                } else {
                    gen::bool(rng)
                };
                let n = gen::usize_in(rng, 1, 6);
                let m = gen::usize_in(rng, 1, 12);
                let trials = gen::usize_in(rng, 1, 9);
                let costs = (
                    0.25 + rng.next_f64(),
                    0.25 + rng.next_f64(),
                    0.25 + rng.next_f64(),
                    rng.next_f64() * 0.2,
                    rng.next_f64() * 0.3,
                    rng.next_f64() * 0.3,
                );
                let with_budget = gen::bool(rng);
                let mem_seed = rng.next_u64();
                let pert_seed = rng.next_u64();
                (kind, two_bp, n, m, trials, costs, with_budget, mem_seed,
                 pert_seed)
            },
            |&(kind, two_bp, n, m, trials, costs, with_budget, mem_seed,
               pert_seed)| {
                let (f, p1, p2, opt, loss, comm) = costs;
                let plan = generate(kind, two_bp, n, m, false);
                validate(&plan).map_err(|e| e.to_string())?;
                let mut cm = CostModel::ratios(n, f, p1, p2);
                cm.opt = vec![opt; n];
                cm.loss = loss;
                cm.comm = comm;
                let mm = MemModel {
                    static_bytes: vec![mem_seed % 100; n],
                    res1: vec![(mem_seed >> 8) % 50; n],
                    res2: vec![(mem_seed >> 16) % 50; n],
                    inter: vec![(mem_seed >> 24) % 50; n],
                };
                let budget = with_budget.then_some((mem_seed >> 32) % 2000);
                let ident = Perturbation {
                    jitter: 0.0,
                    stragglers: vec![(0, 1.0), (n - 1, 1.0), (n + 7, 1.0)],
                    comm_spike_prob: 0.0,
                    comm_spike_mult: 10.0,
                    seed: pert_seed,
                };
                assert!(ident.is_identity());
                let base = score_plan(&plan, &cm, Some(&mm), budget,
                                      &mut plain)
                    .map_err(|e| e.to_string())?;
                let rob = score_plan_robust(&plan, &cm, Some(&mm), budget,
                                            &ident, trials, &mut rs)
                    .map_err(|e| e.to_string())?;
                let bits = |x: f64| x.to_bits();
                for (name, got) in [
                    ("p50", rob.p50),
                    ("p95", rob.p95),
                    ("worst", rob.worst),
                    ("mean", rob.mean),
                ] {
                    if bits(got) != bits(base.makespan) {
                        return Err(format!(
                            "{name} {} != makespan {}", got, base.makespan
                        ));
                    }
                }
                let want_fit = if base.fits { 1.0 } else { 0.0 };
                if bits(rob.fit_fraction) != bits(want_fit) {
                    return Err(format!(
                        "fit_fraction {} != {}", rob.fit_fraction, want_fit
                    ));
                }
                if rob.max_peak != base.max_peak {
                    return Err(format!(
                        "max_peak {} != {}", rob.max_peak, base.max_peak
                    ));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn jitter_orders_the_tail_statistics() {
        let plan = generate(ScheduleKind::OneF1B1, true, 4, 8, false);
        let cm = CostModel::ratios(4, 1.0, 1.05, 0.95);
        let mut rs = RobustScratch::new();
        let rob = score_plan_robust(
            &plan, &cm, None, None, &pert(0.1, &[]), 64, &mut rs,
        )
        .unwrap();
        assert!(rob.p50 > 0.0);
        assert!(rob.p95 >= rob.p50, "p95 {} < p50 {}", rob.p95, rob.p50);
        assert!(rob.worst >= rob.p95);
        assert!(rob.worst > rob.p50, "64 jittered draws never spread");
        assert!((rob.fit_fraction - 1.0).abs() < 1e-12, "no budget given");
    }

    #[test]
    fn straggler_and_spike_slow_the_median() {
        let plan = generate(ScheduleKind::OneF1B1, true, 4, 8, false);
        let mut cm = CostModel::ratios(4, 1.0, 1.05, 0.95);
        cm.comm = 0.05;
        let mut rs = RobustScratch::new();
        let mut plain = Scratch::new();
        let base = score_plan(&plan, &cm, None, None, &mut plain).unwrap();
        let straggled = score_plan_robust(
            &plan, &cm, None, None, &pert(0.0, &[(1, 2.0)]), 8, &mut rs,
        )
        .unwrap();
        assert!(
            straggled.p50 > base.makespan,
            "2x straggler on rank 1 did not slow the pipeline \
             ({} <= {})",
            straggled.p50,
            base.makespan
        );
        let spiked = score_plan_robust(
            &plan, &cm, None, None,
            &Perturbation {
                comm_spike_prob: 1.0,
                comm_spike_mult: 20.0,
                ..Perturbation::default()
            },
            4, &mut rs,
        )
        .unwrap();
        assert!(
            spiked.p50 > base.makespan,
            "a certain 20x comm spike did not slow the pipeline"
        );
    }

    #[test]
    fn draws_are_seed_deterministic_and_trials_clamp() {
        let plan = generate(ScheduleKind::GPipe, true, 2, 4, false);
        let cm = CostModel::unit(2);
        let p = pert(0.2, &[]);
        let mut a_s = RobustScratch::new();
        let mut b_s = RobustScratch::new();
        let a = score_plan_robust(&plan, &cm, None, None, &p, 16, &mut a_s)
            .unwrap();
        let b = score_plan_robust(&plan, &cm, None, None, &p, 16, &mut b_s)
            .unwrap();
        assert_eq!(a, b, "same seed, same draws, same score");
        let other = Perturbation { seed: 999, ..p.clone() };
        let c = score_plan_robust(&plan, &cm, None, None, &other, 16,
                                  &mut a_s)
            .unwrap();
        assert_ne!(a.mean.to_bits(), c.mean.to_bits(),
                   "different seed should perturb differently");
        // trials = 0 clamps to one draw
        let one = score_plan_robust(&plan, &cm, None, None, &p, 0, &mut a_s)
            .unwrap();
        assert_eq!(one.p50.to_bits(), one.worst.to_bits());
    }
}
