//! PJRT runtime: load AOT HLO-text artifacts and execute them.
//!
//! Each pipeline stage worker owns one [`Device`] (its own
//! `PjRtClient`, mirroring one device context per accelerator — the
//! `xla` crate's client is `Rc`-based and single-threaded by design).
//! Tensors cross thread boundaries only as [`HostTensor`] byte buffers
//! (the NCCL-p2p stand-in; see DESIGN.md §3).
//!
//! The `xla` dependency is the vendored deterministic stub backend
//! (`vendor/xla-stub`): executables parse stub-HLO signature files and
//! produce reproducible seeded outputs of the right shape/dtype, which
//! is what lets this whole layer build, test, and smoke offline.  The
//! stub mirrors the real crate's API surface exactly — swap the path
//! dependency in `Cargo.toml` for the real PJRT bindings to run actual
//! compute; nothing in this module changes.

use std::path::Path;

use anyhow::{anyhow, bail, Result};

use crate::models::DType;

/// One accelerator stand-in: a PJRT CPU client.
pub struct Device {
    client: xla::PjRtClient,
}

impl Device {
    pub fn cpu() -> Result<Device> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("creating PJRT CPU client: {e:?}"))?;
        Ok(Device { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Upload a host literal to this device.
    pub fn upload(&self, lit: &xla::Literal) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_literal(None, lit)
            .map_err(|e| anyhow!("uploading literal: {e:?}"))
    }

    /// Load an HLO-text artifact and compile it for this device.
    pub fn load(&self, path: &Path) -> Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {}: {e:?}", path.display()))?;
        Ok(Executable { exe, name: path.display().to_string() })
    }
}

/// A compiled stage function.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    name: String,
}

impl Executable {
    /// Execute with literal inputs; returns the flat list of outputs
    /// (the AOT path lowers with `return_tuple=True`, so PJRT hands back
    /// one tuple literal which we decompose).  Accepts owned literals or
    /// references (`&[Literal]` / `&[&Literal]`).
    ///
    /// Implementation note: this goes through `execute_b` with buffers
    /// *we* own.  Against the real `xla` crate, its literal-taking
    /// `execute` leaks every input buffer it uploads
    /// (`buffer.release()` with no matching free), which shows up as
    /// ~10 MB/s of growth in a tiny training loop — owning the uploads
    /// means they drop (and free) here.  The vendored stub has no
    /// `execute` at all, so `execute_b` is also the only path it
    /// offers; keep this shape when swapping the real crate back in.
    /// The borrowed literals outlive the synchronous execution, so the
    /// host-to-device transfer always completes in time.
    pub fn run<L: std::borrow::Borrow<xla::Literal>>(
        &self,
        args: &[L],
    ) -> Result<Vec<xla::Literal>> {
        let client = self.exe.client();
        let uploaded: Vec<xla::PjRtBuffer> = args
            .iter()
            .map(|l| {
                client
                    .buffer_from_host_literal(None, l.borrow())
                    .map_err(|e| anyhow!("{}: uploading input: {e:?}", self.name))
            })
            .collect::<Result<_>>()?;
        let out = self.run_buffers(&uploaded)?;
        self.download(out)
    }

    /// Execute with device-resident inputs, keeping outputs on device.
    /// The fast path for state that survives across ops (parameters,
    /// optimizer slots, stashed residuals).
    pub fn run_buffers<B: std::borrow::Borrow<xla::PjRtBuffer>>(
        &self,
        args: &[B],
    ) -> Result<Vec<xla::PjRtBuffer>> {
        let mut replicas = self
            .exe
            .execute_b::<B>(args)
            .map_err(|e| anyhow!("executing {}: {e:?}", self.name))?;
        let replica = replicas
            .drain(..)
            .next()
            .ok_or_else(|| anyhow!("{}: no output replica", self.name))?;
        Ok(replica)
    }

    /// Fetch device outputs to host literals, decomposing the
    /// `return_tuple=True` wrapper if present.
    pub fn download(&self, bufs: Vec<xla::PjRtBuffer>) -> Result<Vec<xla::Literal>> {
        let first = bufs
            .first()
            .ok_or_else(|| anyhow!("{}: no output buffer", self.name))?;
        let mut result = first
            .to_literal_sync()
            .map_err(|e| anyhow!("{}: fetching output: {e:?}", self.name))?;
        let shape = result
            .shape()
            .map_err(|e| anyhow!("{}: output shape: {e:?}", self.name))?;
        match shape {
            xla::Shape::Tuple(_) => result
                .decompose_tuple()
                .map_err(|e| anyhow!("{}: decomposing tuple: {e:?}", self.name)),
            _ => {
                drop(result);
                bufs.iter()
                    .map(|b| {
                        b.to_literal_sync()
                            .map_err(|e| anyhow!("{}: fetching: {e:?}", self.name))
                    })
                    .collect()
            }
        }
    }
}

/// Host-side tensor: the inter-stage wire format and stash storage.
#[derive(Debug, Clone)]
pub struct HostTensor {
    pub shape: Vec<usize>,
    pub dtype: DType,
    pub data: Vec<u8>,
}

impl HostTensor {
    pub fn bytes(&self) -> u64 {
        self.data.len() as u64
    }

    pub fn zeros(shape: &[usize], dtype: DType) -> HostTensor {
        let n: usize = shape.iter().product();
        HostTensor {
            shape: shape.to_vec(),
            dtype,
            data: vec![0u8; n * dtype.itemsize()],
        }
    }

    pub fn from_f32(shape: &[usize], vals: &[f32]) -> HostTensor {
        assert_eq!(shape.iter().product::<usize>(), vals.len());
        // bulk reinterpret (little-endian host): one memcpy, not a
        // per-element loop (§Perf)
        let bytes = unsafe {
            std::slice::from_raw_parts(
                vals.as_ptr() as *const u8,
                std::mem::size_of_val(vals),
            )
        };
        HostTensor {
            shape: shape.to_vec(),
            dtype: DType::F32,
            data: bytes.to_vec(),
        }
    }

    pub fn from_i32(shape: &[usize], vals: &[i32]) -> HostTensor {
        assert_eq!(shape.iter().product::<usize>(), vals.len());
        let bytes = unsafe {
            std::slice::from_raw_parts(
                vals.as_ptr() as *const u8,
                std::mem::size_of_val(vals),
            )
        };
        HostTensor {
            shape: shape.to_vec(),
            dtype: DType::I32,
            data: bytes.to_vec(),
        }
    }

    pub fn to_f32(&self) -> Vec<f32> {
        assert_eq!(self.dtype, DType::F32);
        self.data
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect()
    }

    /// Upload to a device literal.  Single memcpy via the untyped-data
    /// constructor (§Perf: the old path staged through a typed Vec,
    /// costing a second full copy on every wire transfer).
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let ty = match self.dtype {
            DType::F32 => xla::ElementType::F32,
            DType::I32 => xla::ElementType::S32,
        };
        xla::Literal::create_from_shape_and_untyped_data(
            ty, &self.shape, &self.data,
        )
        .map_err(|e| anyhow!("literal upload: {e:?}"))
    }

    /// Download from a device literal.
    pub fn from_literal(lit: &xla::Literal) -> Result<HostTensor> {
        let ashape = lit
            .array_shape()
            .map_err(|e| anyhow!("literal shape: {e:?}"))?;
        let dims: Vec<usize> = ashape.dims().iter().map(|&d| d as usize).collect();
        match ashape.ty() {
            xla::ElementType::F32 => {
                let v = lit
                    .to_vec::<f32>()
                    .map_err(|e| anyhow!("literal download: {e:?}"))?;
                Ok(HostTensor::from_f32(&dims, &v))
            }
            xla::ElementType::S32 => {
                let v = lit
                    .to_vec::<i32>()
                    .map_err(|e| anyhow!("literal download: {e:?}"))?;
                Ok(HostTensor::from_i32(&dims, &v))
            }
            other => bail!("unsupported literal element type {other:?}"),
        }
    }
}

/// Zero-filled literal without host staging (XLA's CreateFromShape
/// zero-initializes; §Perf: replaces zeros-Vec + upload-copy per
/// optimizer step).
pub fn zero_literal(shape: &[usize], dtype: DType) -> xla::Literal {
    let ty = match dtype {
        DType::F32 => xla::PrimitiveType::F32,
        DType::I32 => xla::PrimitiveType::S32,
    };
    xla::Literal::create_from_shape(ty, shape)
}

/// Shared zero literals, one per (shape, dtype).
///
/// Zeros only ever feed ops as *inputs* (fresh gradient accumulators,
/// fresh Adam slots) — outputs are always new literals — so a single
/// immutable zero literal per shape can be handed out any number of
/// times.  This removes the per-OptStep/per-reset allocation churn the
/// `hotpath_micro` bench flags as "zero-literal alloc 1 MiB": the
/// worker allocates each distinct zero exactly once for its lifetime.
///
/// Safety assumption: callers go through [`Executable::run`], which
/// uploads every host literal to a fresh device buffer per call.  If a
/// future execute path aliases or donates *input* buffers (e.g.
/// buffer donation on the opt step), shared zeros must not be passed
/// twice to one call — revisit this cache before enabling donation.
pub struct ZeroCache {
    map: std::collections::HashMap<(Vec<usize>, DType), std::rc::Rc<xla::Literal>>,
}

impl Default for ZeroCache {
    fn default() -> Self {
        Self::new()
    }
}

impl ZeroCache {
    pub fn new() -> ZeroCache {
        ZeroCache { map: std::collections::HashMap::new() }
    }

    /// The shared zero literal for (shape, dtype), allocating on first
    /// use only.
    pub fn get(&mut self, shape: &[usize], dtype: DType) -> std::rc::Rc<xla::Literal> {
        if let Some(l) = self.map.get(&(shape.to_vec(), dtype)) {
            return l.clone();
        }
        let l = std::rc::Rc::new(zero_literal(shape, dtype));
        self.map.insert((shape.to_vec(), dtype), l.clone());
        l
    }

    /// Shared zeros matching each spec (deduplicated across equal
    /// shapes — a transformer stage's many identical block params share
    /// one literal).
    pub fn zeros_like(
        &mut self,
        specs: &[crate::models::TensorSpec],
    ) -> Vec<std::rc::Rc<xla::Literal>> {
        specs.iter().map(|s| self.get(&s.shape, s.dtype)).collect()
    }

    /// Distinct literals currently cached (for tests/benches).
    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }
}

/// Scalar literal helpers used by the executor.
pub fn scalar_i32(v: i32) -> xla::Literal {
    xla::Literal::scalar(v)
}

pub fn scalar_f32(v: f32) -> xla::Literal {
    xla::Literal::scalar(v)
}

/// Extract an f32 scalar (e.g. the loss) from a literal.
pub fn literal_to_f32_scalar(lit: &xla::Literal) -> Result<f32> {
    lit.get_first_element::<f32>()
        .map_err(|e| anyhow!("reading scalar: {e:?}"))
}

/// Logical byte size of a literal.
pub fn literal_bytes(lit: &xla::Literal) -> u64 {
    lit.size_bytes() as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_tensor_f32_roundtrip() {
        let t = HostTensor::from_f32(&[2, 3], &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(t.bytes(), 24);
        assert_eq!(t.to_f32(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
    }

    #[test]
    fn zeros_sized_correctly() {
        let t = HostTensor::zeros(&[4, 4], DType::I32);
        assert_eq!(t.data.len(), 64);
        assert!(t.data.iter().all(|&b| b == 0));
    }

    // Device/literal tests live in rust/tests/ (they need the PJRT
    // runtime and, for end-to-end paths, built artifacts).
}
