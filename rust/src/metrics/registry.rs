//! Deterministic, insertion-ordered metrics registry.
//!
//! The observability substrate for search/calibration/drift telemetry
//! (`--metrics-out`): named **counters**, **gauges**, **histograms**,
//! and free-form **events**, dumped as a JSONL run log.  Two rules make
//! the log diffable in CI:
//!
//! 1. **Insertion order is serialization order.**  Events stream first,
//!    in the order they were recorded; aggregates (counters, gauges,
//!    histogram summaries) follow in first-touch order.  No HashMap
//!    iteration anywhere.
//! 2. **Wall-clock values are quarantined.**  Any number derived from
//!    real elapsed time (measured seconds, ratios of them, scores
//!    against a measured profile) lives under a nested `"wall"` object
//!    — the *only* key a determinism check needs to strip.  Everything
//!    outside `"wall"` is a pure function of the run's inputs and seed,
//!    so two identical-seed runs must agree byte-for-byte on it
//!    (CI-gated; see ci/check_obs.py and docs/OBSERVABILITY.md).
//!
//! The registry is plain bookkeeping — no I/O until
//! [`MetricsRegistry::write`], no clocks, no threads — so it can thread
//! through the beam search and executor loops without touching the
//! Tier-A scoring path (which stays telemetry-free by contract).

use std::io;
use std::path::Path;

use crate::util::json::{obj, Json};

/// A deterministic field value on an [`MetricsRegistry::event`] line.
#[derive(Debug, Clone)]
pub enum Value {
    Int(i64),
    Float(f64),
    Str(String),
    Bool(bool),
}

impl Value {
    fn to_json(&self) -> Json {
        match self {
            Value::Int(v) => Json::Num(*v as f64),
            Value::Float(v) => Json::Num(*v),
            Value::Str(s) => Json::Str(s.clone()),
            Value::Bool(b) => Json::Bool(*b),
        }
    }
}

impl From<i64> for Value {
    fn from(v: i64) -> Value {
        Value::Int(v)
    }
}
impl From<usize> for Value {
    fn from(v: usize) -> Value {
        Value::Int(v as i64)
    }
}
impl From<u64> for Value {
    fn from(v: u64) -> Value {
        Value::Int(v as i64)
    }
}
impl From<f64> for Value {
    fn from(v: f64) -> Value {
        Value::Float(v)
    }
}
impl From<&str> for Value {
    fn from(v: &str) -> Value {
        Value::Str(v.into())
    }
}
impl From<String> for Value {
    fn from(v: String) -> Value {
        Value::Str(v)
    }
}
impl From<bool> for Value {
    fn from(v: bool) -> Value {
        Value::Bool(v)
    }
}

#[derive(Debug, Clone)]
struct Hist {
    name: String,
    samples: Vec<f64>,
    wall: bool,
}

/// Insertion-ordered counters/gauges/histograms + an event stream; see
/// the module docs for the determinism contract.
#[derive(Debug, Default)]
pub struct MetricsRegistry {
    events: Vec<Json>,
    counters: Vec<(String, u64)>,
    gauges: Vec<(String, f64, bool)>,
    hists: Vec<Hist>,
}

impl MetricsRegistry {
    pub fn new() -> MetricsRegistry {
        MetricsRegistry::default()
    }

    /// Add `delta` to a (first-touch-ordered) named counter.
    pub fn counter_add(&mut self, name: &str, delta: u64) {
        match self.counters.iter_mut().find(|(n, _)| n == name) {
            Some((_, v)) => *v += delta,
            None => self.counters.push((name.into(), delta)),
        }
    }

    /// Current value of a counter (0 if never touched).
    pub fn counter(&self, name: &str) -> u64 {
        self.counters
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, v)| *v)
            .unwrap_or(0)
    }

    /// Set a deterministic gauge (last write wins).
    pub fn gauge_set(&mut self, name: &str, value: f64) {
        self.gauge_impl(name, value, false);
    }

    /// Set a gauge whose value derives from wall-clock measurement —
    /// serialized under `"wall"` so determinism checks strip it.
    pub fn gauge_set_wall(&mut self, name: &str, value: f64) {
        self.gauge_impl(name, value, true);
    }

    fn gauge_impl(&mut self, name: &str, value: f64, wall: bool) {
        match self.gauges.iter_mut().find(|(n, _, _)| n == name) {
            Some((_, v, w)) => {
                *v = value;
                *w = wall;
            }
            None => self.gauges.push((name.into(), value, wall)),
        }
    }

    /// Record one sample into a deterministic histogram.
    pub fn hist_record(&mut self, name: &str, value: f64) {
        self.hist_impl(name, value, false);
    }

    /// Record one wall-clock-derived sample (summary goes under
    /// `"wall"`; the sample *count* stays outside — it is deterministic
    /// even when the values are not).
    pub fn hist_record_wall(&mut self, name: &str, value: f64) {
        self.hist_impl(name, value, true);
    }

    fn hist_impl(&mut self, name: &str, value: f64, wall: bool) {
        match self.hists.iter_mut().find(|h| h.name == name) {
            Some(h) => h.samples.push(value),
            None => self.hists.push(Hist {
                name: name.into(),
                samples: vec![value],
                wall,
            }),
        }
    }

    /// Record a free-form event with deterministic fields only.
    pub fn event(&mut self, name: &str, fields: Vec<(&str, Value)>) {
        self.event_mixed(name, fields, Vec::new());
    }

    /// Record an event with both deterministic fields and wall-clock
    /// fields (the latter nested under `"wall"`).
    pub fn event_mixed(
        &mut self,
        name: &str,
        fields: Vec<(&str, Value)>,
        wall_fields: Vec<(&str, f64)>,
    ) {
        let seq = self.events.len();
        let mut pairs = vec![
            ("kind", Json::Str("event".into())),
            ("name", Json::Str(name.into())),
            ("seq", Json::Num(seq as f64)),
        ];
        for (k, v) in &fields {
            pairs.push((*k, v.to_json()));
        }
        if !wall_fields.is_empty() {
            pairs.push((
                "wall",
                obj(wall_fields
                    .iter()
                    .map(|(k, v)| (*k, Json::Num(*v)))
                    .collect()),
            ));
        }
        self.events.push(obj(pairs));
    }

    /// Events recorded so far.
    pub fn n_events(&self) -> usize {
        self.events.len()
    }

    /// The JSONL run log: one JSON object per line — events first (in
    /// record order), then counters, gauges, and histogram summaries
    /// (each in first-touch order).
    pub fn to_jsonl(&self) -> String {
        let mut out = String::new();
        for e in &self.events {
            out.push_str(&e.to_string());
            out.push('\n');
        }
        for (name, v) in &self.counters {
            out.push_str(
                &obj(vec![
                    ("kind", Json::Str("counter".into())),
                    ("name", Json::Str(name.clone())),
                    ("value", Json::Num(*v as f64)),
                ])
                .to_string(),
            );
            out.push('\n');
        }
        for (name, v, wall) in &self.gauges {
            let mut pairs = vec![
                ("kind", Json::Str("gauge".into())),
                ("name", Json::Str(name.clone())),
            ];
            if *wall {
                pairs.push(("wall", obj(vec![("value", Json::Num(*v))])));
            } else {
                pairs.push(("value", Json::Num(*v)));
            }
            out.push_str(&obj(pairs).to_string());
            out.push('\n');
        }
        for h in &self.hists {
            let stats = summarize(&h.samples);
            let mut pairs = vec![
                ("kind", Json::Str("histogram".into())),
                ("name", Json::Str(h.name.clone())),
                ("count", Json::Num(h.samples.len() as f64)),
            ];
            if h.wall {
                pairs.push(("wall", stats));
            } else {
                pairs.push(("stats", stats));
            }
            out.push_str(&obj(pairs).to_string());
            out.push('\n');
        }
        out
    }

    /// Write the JSONL log to `path` (overwrites).
    pub fn write(&self, path: &Path) -> io::Result<()> {
        std::fs::write(path, self.to_jsonl())
    }
}

/// min/max/mean/p50/p95 of a sample set (nearest-rank percentiles on a
/// sorted copy — deterministic, no interpolation).
fn summarize(samples: &[f64]) -> Json {
    let mut sorted = samples.to_vec();
    sorted.sort_by(|a, b| a.total_cmp(b));
    let pct = |p: f64| -> f64 {
        let i = (p * (sorted.len() - 1) as f64).round() as usize;
        sorted[i]
    };
    let mean = sorted.iter().sum::<f64>() / sorted.len() as f64;
    obj(vec![
        ("min", Json::Num(sorted[0])),
        ("max", Json::Num(sorted[sorted.len() - 1])),
        ("mean", Json::Num(mean)),
        ("p50", Json::Num(pct(0.50))),
        ("p95", Json::Num(pct(0.95))),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate_in_first_touch_order() {
        let mut m = MetricsRegistry::new();
        m.counter_add("b", 2);
        m.counter_add("a", 1);
        m.counter_add("b", 3);
        assert_eq!(m.counter("b"), 5);
        assert_eq!(m.counter("a"), 1);
        assert_eq!(m.counter("missing"), 0);
        let lines: Vec<&str> = m.to_jsonl().lines().collect();
        // "b" was touched first, so it serializes first despite "a" < "b"
        assert!(lines[0].contains("\"name\":\"b\""), "{}", lines[0]);
        assert!(lines[1].contains("\"name\":\"a\""), "{}", lines[1]);
    }

    #[test]
    fn wall_values_nest_under_wall_key() {
        let mut m = MetricsRegistry::new();
        m.gauge_set("det", 4.0);
        m.gauge_set_wall("measured", 0.123);
        m.event_mixed(
            "drift.step",
            vec![("step", Value::from(3usize)), ("verdict", "Ok".into())],
            vec![("measured_s", 0.5), ("ratio", 1.01)],
        );
        let log = m.to_jsonl();
        let lines: Vec<Json> =
            log.lines().map(|l| Json::parse(l).unwrap()).collect();
        let ev = &lines[0];
        assert_eq!(ev.get("name").and_then(Json::as_str), Some("drift.step"));
        assert_eq!(ev.get("seq").and_then(Json::as_u64), Some(0));
        assert_eq!(ev.get("step").and_then(Json::as_u64), Some(3));
        assert_eq!(
            ev.get("wall")
                .and_then(|w| w.get("ratio"))
                .and_then(Json::as_f64),
            Some(1.01)
        );
        // deterministic gauge keeps its value at top level...
        let det = lines
            .iter()
            .find(|l| l.get("name").and_then(Json::as_str) == Some("det"))
            .unwrap();
        assert_eq!(det.get("value").and_then(Json::as_f64), Some(4.0));
        assert!(det.get("wall").is_none());
        // ...the measured one hides it under "wall"
        let wall = lines
            .iter()
            .find(|l| {
                l.get("name").and_then(Json::as_str) == Some("measured")
            })
            .unwrap();
        assert!(wall.get("value").is_none());
        assert_eq!(
            wall.get("wall")
                .and_then(|w| w.get("value"))
                .and_then(Json::as_f64),
            Some(0.123)
        );
    }

    #[test]
    fn histogram_summary_is_deterministic() {
        let mut m = MetricsRegistry::new();
        for v in [3.0, 1.0, 2.0, 4.0] {
            m.hist_record("h", v);
        }
        m.hist_record_wall("w", 9.0);
        let lines: Vec<Json> = m
            .to_jsonl()
            .lines()
            .map(|l| Json::parse(l).unwrap())
            .collect();
        let h = lines
            .iter()
            .find(|l| l.get("name").and_then(Json::as_str) == Some("h"))
            .unwrap();
        assert_eq!(h.get("count").and_then(Json::as_u64), Some(4));
        let stats = h.get("stats").unwrap();
        assert_eq!(stats.get("min").and_then(Json::as_f64), Some(1.0));
        assert_eq!(stats.get("max").and_then(Json::as_f64), Some(4.0));
        assert_eq!(stats.get("mean").and_then(Json::as_f64), Some(2.5));
        assert_eq!(stats.get("p50").and_then(Json::as_f64), Some(3.0));
        assert_eq!(stats.get("p95").and_then(Json::as_f64), Some(4.0));
        let w = lines
            .iter()
            .find(|l| l.get("name").and_then(Json::as_str) == Some("w"))
            .unwrap();
        assert_eq!(w.get("count").and_then(Json::as_u64), Some(1));
        assert!(w.get("stats").is_none());
        assert!(w.get("wall").is_some());
    }

    #[test]
    fn identical_recordings_serialize_identically() {
        let build = || {
            let mut m = MetricsRegistry::new();
            m.event("beam.generation", vec![("gen", 1usize.into())]);
            m.counter_add("beam.evaluated", 7);
            m.gauge_set("best", 2.0);
            m.to_jsonl()
        };
        assert_eq!(build(), build());
    }
}
