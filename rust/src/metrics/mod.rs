//! Reporting: turn run reports / sim results into the paper's tables,
//! plus the deterministic metrics [`registry`] behind `--metrics-out`
//! and the [`observer`] sink trait the tune API records through.

pub mod observer;
pub mod registry;

#[cfg(feature = "pjrt")]
use crate::pipeline::RunReport;
use crate::util::stats::fmt_bytes;
#[cfg(feature = "pjrt")]
use crate::util::stats::fmt_duration;
use crate::util::table::Table;

/// One row of a throughput comparison (Fig 3-style).
#[derive(Debug, Clone)]
pub struct ThroughputRow {
    pub model: String,
    pub schedule: String,
    pub without_2bp: f64,
    pub with_2bp: f64,
}

impl ThroughputRow {
    pub fn gain(&self) -> f64 {
        self.with_2bp / self.without_2bp
    }
}

pub fn throughput_table(rows: &[ThroughputRow], title: &str) -> Table {
    let mut t = Table::new(
        &["model", "schedule", "samples/s", "samples/s +2BP", "gain"],
    )
    .with_title(title);
    for r in rows {
        t.row(vec![
            r.model.clone(),
            r.schedule.clone(),
            format!("{:.2}", r.without_2bp),
            format!("{:.2}", r.with_2bp),
            format!("{:.2}x", r.gain()),
        ]);
    }
    t
}

/// One row of a peak-memory comparison (Fig 4-style).
#[derive(Debug, Clone)]
pub struct MemoryRow {
    pub model: String,
    pub schedule: String,
    pub without_2bp: u64,
    pub with_2bp: u64,
}

impl MemoryRow {
    pub fn increase(&self) -> f64 {
        self.with_2bp as f64 / self.without_2bp.max(1) as f64
    }
}

pub fn memory_table(rows: &[MemoryRow], title: &str) -> Table {
    let mut t = Table::new(
        &["model", "schedule", "peak mem", "peak mem +2BP", "increase"],
    )
    .with_title(title);
    for r in rows {
        t.row(vec![
            r.model.clone(),
            r.schedule.clone(),
            fmt_bytes(r.without_2bp),
            fmt_bytes(r.with_2bp),
            format!("{:.2}x", r.increase()),
        ]);
    }
    t
}

/// Deterministic evenly-spaced index sampler: which indices of a
/// `len`-long series to show when at most `max_shown` fit.  Always
/// includes the first and last index, spacing the rest uniformly
/// (`round(k·(len-1)/(max_shown-1))`), and returns strictly increasing
/// indices — unlike the old `i % (len/6)` filter, which could bunch
/// duplicated gaps around the ends.
pub fn sample_indices(len: usize, max_shown: usize) -> Vec<usize> {
    if len == 0 || max_shown == 0 {
        return Vec::new();
    }
    if len <= max_shown {
        return (0..len).collect();
    }
    if max_shown == 1 {
        // the spacing formula divides by max_shown - 1
        return vec![0];
    }
    let mut out = Vec::with_capacity(max_shown);
    for k in 0..max_shown {
        out.push((k * (len - 1) + (max_shown - 1) / 2) / (max_shown - 1));
    }
    out.dedup();
    out
}

/// Per-run summary printed after `twobp train`.
#[cfg(feature = "pjrt")]
pub fn run_summary(report: &RunReport) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "run: {} | {}\n",
        report.preset,
        report.plan.describe()
    ));
    out.push_str(&format!(
        "steps: {} | mean step (serialized): {}\n",
        report.step_times.len(),
        fmt_duration(report.mean_step_time()),
    ));
    if let Ok(tput) = report.simulated_throughput() {
        out.push_str(&format!(
            "pipeline throughput (calibrated sim): {:.2} samples/s\n",
            tput
        ));
    }
    let peaks = report.peak_bytes();
    out.push_str("peak memory per rank: ");
    out.push_str(
        &peaks
            .iter()
            .map(|p| fmt_bytes(*p))
            .collect::<Vec<_>>()
            .join(" | "),
    );
    out.push('\n');
    if !report.losses.is_empty() {
        out.push_str("loss: ");
        let shown = sample_indices(report.losses.len(), 12);
        let show: Vec<String> = shown
            .iter()
            .map(|&i| format!("[{i}] {:.4}", report.losses[i]))
            .collect();
        out.push_str(&show.join("  "));
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gain_and_increase() {
        let t = ThroughputRow {
            model: "x".into(), schedule: "gpipe".into(),
            without_2bp: 100.0, with_2bp: 150.0,
        };
        assert!((t.gain() - 1.5).abs() < 1e-12);
        let m = MemoryRow {
            model: "x".into(), schedule: "gpipe".into(),
            without_2bp: 100, with_2bp: 267,
        };
        assert!((m.increase() - 2.67).abs() < 1e-12);
    }

    #[test]
    fn sample_indices_are_even_and_unique() {
        // short series show every index
        assert_eq!(sample_indices(1, 12), vec![0]);
        assert_eq!(
            sample_indices(12, 12),
            (0..12).collect::<Vec<usize>>()
        );
        // just past the cap: 12 distinct, strictly increasing, 0..=12
        let s13 = sample_indices(13, 12);
        assert_eq!(s13.len(), 12);
        assert_eq!(*s13.first().unwrap(), 0);
        assert_eq!(*s13.last().unwrap(), 12);
        assert!(s13.windows(2).all(|w| w[0] < w[1]), "{s13:?}");
        // long series: exact uniform spacing (99/11 = 9)
        assert_eq!(
            sample_indices(100, 12),
            vec![0, 9, 18, 27, 36, 45, 54, 63, 72, 81, 90, 99]
        );
        // degenerate requests
        assert!(sample_indices(0, 12).is_empty());
        assert!(sample_indices(5, 0).is_empty());
        assert_eq!(sample_indices(5, 1), vec![0]);
    }

    #[test]
    fn tables_render() {
        let t = throughput_table(
            &[ThroughputRow {
                model: "transformer".into(), schedule: "1f1b-1".into(),
                without_2bp: 10.0, with_2bp: 17.0,
            }],
            "Fig 3",
        );
        let s = t.render();
        assert!(s.contains("1.70x"));
    }
}
