//! The telemetry sink abstraction behind the redesigned tune API.
//!
//! PR 9 collapses the `Option<&mut MetricsRegistry>` parameter sprawl
//! that had crept through `planner::tune_with` and the `experiments`
//! tune paths into one trait: an [`Observer`] is anything that can
//! absorb the registry's recording surface (counters, gauges,
//! histograms, events).  Producers take `&mut dyn Observer`
//! unconditionally; callers that want telemetry pass a
//! [`MetricsRegistry`], callers that don't pass a [`NullObserver`] —
//! no `Option`, no `as_deref_mut()` chains, no divergent signatures.
//!
//! Contract (inherited from the registry, see `metrics::registry`):
//!
//! * Observer calls must never perturb the observed computation — in
//!   particular the beam search consumes its PRNG only in the mutation
//!   loop, never inside an observer hook (pinned by
//!   `telemetry_observes_without_perturbing`).
//! * Wall-clock-derived values go through the `*_wall` methods and
//!   nowhere else, preserving the `"wall"` quarantine.
//! * [`Observer::enabled`] lets producers skip *building* expensive
//!   field vectors when nobody is listening; a recording observer
//!   must return `true` or those events are silently dropped at the
//!   call site.  Cheap static-name counter bumps may be issued
//!   unconditionally (the null sink discards them for free).

use super::registry::{MetricsRegistry, Value};

/// A sink for deterministic run telemetry.  Every method defaults to a
/// no-op, so `impl Observer for MySink {}` is a valid null sink and
/// partial observers override only what they store.
pub trait Observer {
    /// `true` if this sink actually records — producers gate the
    /// construction of non-trivial event payloads on it.
    fn enabled(&self) -> bool {
        false
    }

    /// Add `delta` to a named counter.
    fn counter_add(&mut self, name: &str, delta: u64) {
        let _ = (name, delta);
    }

    /// Set a deterministic gauge (last write wins).
    fn gauge_set(&mut self, name: &str, value: f64) {
        let _ = (name, value);
    }

    /// Set a wall-clock-derived gauge (quarantined under `"wall"`).
    fn gauge_set_wall(&mut self, name: &str, value: f64) {
        let _ = (name, value);
    }

    /// Record one sample into a deterministic histogram.
    fn hist_record(&mut self, name: &str, value: f64) {
        let _ = (name, value);
    }

    /// Record one wall-clock-derived histogram sample.
    fn hist_record_wall(&mut self, name: &str, value: f64) {
        let _ = (name, value);
    }

    /// Record a free-form event with deterministic fields only.
    fn event(&mut self, name: &str, fields: Vec<(&str, Value)>) {
        let _ = (name, fields);
    }

    /// Record an event with both deterministic fields and wall-clock
    /// fields (the latter nested under `"wall"`).
    fn event_mixed(
        &mut self,
        name: &str,
        fields: Vec<(&str, Value)>,
        wall_fields: Vec<(&str, f64)>,
    ) {
        let _ = (name, fields, wall_fields);
    }
}

/// The "nobody is listening" sink: every hook is the default no-op and
/// [`Observer::enabled`] stays `false`, so producers skip building
/// event payloads entirely.
#[derive(Debug, Default, Clone, Copy)]
pub struct NullObserver;

impl Observer for NullObserver {}

impl Observer for MetricsRegistry {
    fn enabled(&self) -> bool {
        true
    }

    fn counter_add(&mut self, name: &str, delta: u64) {
        MetricsRegistry::counter_add(self, name, delta);
    }

    fn gauge_set(&mut self, name: &str, value: f64) {
        MetricsRegistry::gauge_set(self, name, value);
    }

    fn gauge_set_wall(&mut self, name: &str, value: f64) {
        MetricsRegistry::gauge_set_wall(self, name, value);
    }

    fn hist_record(&mut self, name: &str, value: f64) {
        MetricsRegistry::hist_record(self, name, value);
    }

    fn hist_record_wall(&mut self, name: &str, value: f64) {
        MetricsRegistry::hist_record_wall(self, name, value);
    }

    fn event(&mut self, name: &str, fields: Vec<(&str, Value)>) {
        MetricsRegistry::event(self, name, fields);
    }

    fn event_mixed(
        &mut self,
        name: &str,
        fields: Vec<(&str, Value)>,
        wall_fields: Vec<(&str, f64)>,
    ) {
        MetricsRegistry::event_mixed(self, name, fields, wall_fields);
    }
}

/// Borrow an optional registry as an observer: the transition shim for
/// call sites that still hold `Option<&mut MetricsRegistry>` (e.g. CLI
/// code that only allocates a registry when `--metrics-out` was given).
/// Returns the registry when present, `fallback` otherwise.
pub fn observer_or<'a>(
    obs: Option<&'a mut MetricsRegistry>,
    fallback: &'a mut NullObserver,
) -> &'a mut dyn Observer {
    match obs {
        Some(m) => m,
        None => fallback,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn null_observer_is_disabled_and_inert() {
        let mut null = NullObserver;
        assert!(!null.enabled());
        null.counter_add("x", 3);
        null.gauge_set("g", 1.0);
        null.event("e", vec![("k", 1usize.into())]);
        // nothing to assert beyond "it compiled and didn't panic":
        // the sink has no state by construction
    }

    #[test]
    fn registry_observer_delegates_to_inherent_methods() {
        let mut reg = MetricsRegistry::new();
        {
            let obs: &mut dyn Observer = &mut reg;
            assert!(obs.enabled());
            obs.counter_add("c", 2);
            obs.counter_add("c", 3);
            obs.gauge_set("g", 4.0);
            obs.gauge_set_wall("gw", 0.5);
            obs.hist_record("h", 1.0);
            obs.hist_record_wall("hw", 2.0);
            obs.event("e", vec![("k", Value::from(7usize))]);
            obs.event_mixed("m", vec![("d", 1i64.into())],
                            vec![("w", 0.25)]);
        }
        assert_eq!(reg.counter("c"), 5);
        assert_eq!(reg.n_events(), 2);
        let log = reg.to_jsonl();
        assert!(log.contains("\"name\":\"g\",\"value\":4"), "{log}");
        assert!(log.contains("\"name\":\"gw\",\"wall\":{\"value\":0.5}"),
                "{log}");
        assert!(log.contains("\"name\":\"m\""), "{log}");
        assert!(log.contains("\"wall\":{\"w\":0.25}"), "{log}");
    }

    #[test]
    fn observer_or_picks_registry_or_fallback() {
        let mut null = NullObserver;
        let mut reg = MetricsRegistry::new();
        observer_or(Some(&mut reg), &mut null).counter_add("c", 1);
        assert_eq!(reg.counter("c"), 1);
        let mut null2 = NullObserver;
        let obs = observer_or(None, &mut null2);
        assert!(!obs.enabled());
    }
}
