//! # twobp — 2-Stage Backpropagation
//!
//! Reproduction of *"2BP: 2-Stage Backpropagation"* (Rae, Lee, Richings,
//! EPCC 2024) as a three-layer Rust + JAX + Pallas stack.
//!
//! This crate is **Layer 3**: the pipeline-parallel training coordinator.
//! It owns the process topology (one worker per pipeline stage), the
//! schedule (Naive / GPipe / 1F1B-1 / 1F1B-2, each with or without the
//! paper's 2BP backward split), inter-stage communication, activation /
//! intermediate-derivative stash management with byte-exact memory
//! accounting, the optimizer driver, and all measurement.
//!
//! Compute is **never** done in Rust: every stage function (`fwd`,
//! `bwd_p1`, `bwd_p2`, `bwd_p2_concat`, `opt`, `init`, `loss`) is an
//! AOT-compiled XLA executable produced once by `python/compile/aot.py`
//! (JAX model + Pallas kernels, lowered to HLO text) and executed through
//! the PJRT CPU client (`runtime`).
//!
//! The real-runtime path (`runtime`, `pipeline`, and the measured
//! experiments) sits behind the `pjrt` cargo feature, which builds
//! offline against the vendored deterministic stub backend in
//! `vendor/xla-stub`: executables parse stub-HLO signature files and
//! produce reproducible seeded outputs of the right shape/dtype, so the
//! whole executor builds, tests, and smokes end to end (`twobp train
//! --synthetic`, generating a manifest in-process via
//! `models::synthetic`) with no Python artifacts and no network.  The
//! stub's `cost` busy-delay directive even makes *measured-cost
//! calibration* physically meaningful offline: `twobp tune --synthetic`
//! measures real per-stage op costs on the executor, tunes the planner
//! against them, and executes the winning schedule back
//! (executor→planner→executor, predicted-vs-executed makespan).  To
//! run on real hardware, vendor the actual `xla` PJRT crate in the
//! stub's place — it mirrors that API surface, so no source changes are
//! needed.  Without the feature the simulator / schedule / planner core
//! still builds, tests, and benches with no artifacts present.
//!
//! Module map (see DESIGN.md for the full system inventory):
//!
//! * [`schedule`] — pipeline schedule plans + validator + plan DSL
//!   (paper §3, Fig 1/5; `docs/PLAN_FORMAT.md`)
//! * [`planner`]  — memory-constrained schedule auto-tuner (beam search
//!   over validated plans, PipeDream/BaPipe-style)
//! * [`sim`]      — discrete-event simulator (Table 1, Figs 1/6/7)
//! * [`runtime`]  — PJRT client wrapper: load + execute HLO artifacts
//! * [`models`]   — artifact manifest parsing (shapes, byte classes, flops)
//! * [`pipeline`] — the real distributed executor + memory accountant
//! * [`config`]   — run configuration and Table-2 presets
//! * [`metrics`]  — throughput/bubble/memory reporting, the
//!   deterministic metrics registry behind `--metrics-out`
//!   ([`metrics::registry`]; `docs/OBSERVABILITY.md`), and the
//!   [`metrics::observer`] sink the tune API records through
//! * [`serve`]    — the persistent tuning service behind `twobp serve`:
//!   line-delimited JSON jobs over stdin/a Unix socket, a deadline- and
//!   dependency-aware priority queue, a fingerprint-keyed result cache
//!   over resident profiles/scratch, and a replayable job log
//!   (`docs/SERVE.md`)
//! * [`util`]     — substrates: mini-JSON, PRNG, stats, tables, CLI
//!   args, Chrome-trace export ([`util::trace`], behind `--trace-out`
//!   and `twobp trace`)

pub mod config;
pub mod experiments;
pub mod metrics;
pub mod models;
pub mod planner;
#[cfg(feature = "pjrt")]
pub mod pipeline;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod schedule;
pub mod serve;
pub mod sim;
pub mod util;

pub use schedule::{Plan, ScheduleKind};
