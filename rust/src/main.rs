//! `twobp` — the 2BP pipeline-training launcher.
//!
//! ```text
//! twobp train    --preset transformer-tiny --schedule 1f1b-1 [--no-2bp]
//!                [--steps N] [--microbatches M] [--concat-p2] [--verbose]
//! twobp gantt    [--ranks N] [--cols W] [--schedule K] [--real --preset P]
//! twobp simulate --schedule 1f1b-1 --ranks 8 [--no-2bp] [--comm C]
//! twobp sweep    [--ranks 2,4,8,16,32] [--mults 1,2] [--threads K]
//! twobp bench    <table1|fig1|fig3|fig4|fig5|table3|fig6|fig7|ckpt|sweep>
//!                [--steps N]
//! twobp config   --list
//! ```
//!
//! `train`, `gantt --real`, and the measured bench experiments need the
//! `pjrt` feature (real runtime); everything else is pure simulator.

use anyhow::{anyhow, Result};

use twobp::config::table2;
use twobp::schedule::{generate, validate::validate, ScheduleKind};
use twobp::sim::{simulate, CostModel};
use twobp::util::args::Args;
use twobp::util::gantt;

const FLAGS: &[&str] = &["no-2bp", "concat-p2", "verbose", "list", "real",
                         "csv"];

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv, FLAGS);
    let cmd = args.positional.first().cloned().unwrap_or_default();
    let result = match cmd.as_str() {
        "train" => cmd_train(&args),
        "gantt" => cmd_gantt(&args),
        "simulate" => cmd_simulate(&args),
        "sweep" => cmd_sweep(&args),
        "bench" => cmd_bench(&args),
        "config" => {
            println!("{}", table2().render());
            Ok(())
        }
        _ => {
            eprintln!(
                "usage: twobp <train|gantt|simulate|sweep|bench|config> \
                 [options]\n\
                 see `cargo doc` or README.md for details"
            );
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

#[cfg(feature = "pjrt")]
fn cmd_train(args: &Args) -> Result<()> {
    let cfg = twobp::config::RunConfig::from_args(args)?;
    let report = twobp::pipeline::train(&cfg)?;
    print!("{}", twobp::metrics::run_summary(&report));
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_train(_args: &Args) -> Result<()> {
    Err(anyhow!(
        "`twobp train` needs the real runtime; rebuild with \
         `--features pjrt` (vendored xla crate required)"
    ))
}

#[cfg(feature = "pjrt")]
fn cmd_gantt_real(args: &Args, cols: usize) -> Result<()> {
    // render a measured timeline from a real (serialized) run
    let cfg = twobp::config::RunConfig::from_args(args)?;
    let report = twobp::pipeline::train(&cfg)?;
    let spans = report.spans();
    if args.has("csv") {
        print!("{}", gantt::to_csv(&spans));
    } else {
        print!("{}", gantt::render(&spans, cols));
    }
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_gantt_real(_args: &Args, _cols: usize) -> Result<()> {
    Err(anyhow!(
        "`twobp gantt --real` needs the real runtime; rebuild with \
         `--features pjrt` (vendored xla crate required)"
    ))
}

fn cmd_gantt(args: &Args) -> Result<()> {
    let cols = args.get_usize("cols", 96);
    if args.has("real") {
        return cmd_gantt_real(args, cols);
    }
    let n = args.get_usize("ranks", 4);
    match args.get("schedule") {
        Some(s) => {
            let kind = ScheduleKind::parse(s)
                .ok_or_else(|| anyhow!("unknown schedule '{s}'"))?;
            for two_bp in [false, true] {
                let m = args.get_usize("microbatches", 0);
                let plan = generate(kind, two_bp, n, m, false);
                let res = simulate(&plan, &CostModel::unit(n), None)
                    .map_err(|e| anyhow!("{e}"))?;
                println!("--- {} ---  bubble ratio {:.3}",
                         plan.describe(), res.bubble_ratio);
                print!("{}", gantt::render(&res.spans, cols));
            }
            Ok(())
        }
        None => {
            print!("{}", twobp::experiments::fig1(n, cols));
            Ok(())
        }
    }
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let n = args.get_usize("ranks", 4);
    let kind = ScheduleKind::parse(args.get_or("schedule", "1f1b-1"))
        .ok_or_else(|| anyhow!("unknown schedule"))?;
    let two_bp = !args.has("no-2bp");
    let m = args.get_usize("microbatches", 0);
    let mut cm = CostModel::ratios(
        n,
        args.get_f64("fwd", 1.0),
        args.get_f64("p1", 1.0),
        args.get_f64("p2", 1.0),
    );
    cm.comm = args.get_f64("comm", 0.0);
    let plan = generate(kind, two_bp, n, m, false);
    validate(&plan).map_err(|e| anyhow!("{e}"))?;
    let res = simulate(&plan, &cm, None).map_err(|e| anyhow!("{e}"))?;
    println!("{}", plan.describe());
    println!("makespan       : {:.4}", res.makespan);
    println!("bubble ratio   : {:.4}", res.bubble_ratio);
    println!("throughput gain vs no-2BP:");
    let base = generate(kind, false, n, m, false);
    let bres = simulate(&base, &cm, None).map_err(|e| anyhow!("{e}"))?;
    println!("  {:.3}x (makespan {:.4} -> {:.4})",
             bres.makespan / res.makespan, bres.makespan, res.makespan);
    Ok(())
}

/// Parallel schedule-space sweep (pure simulator; see
/// `experiments::schedule_space`).
fn cmd_sweep(args: &Args) -> Result<()> {
    let ranks = args
        .get_usize_list("ranks", &[2, 4, 8, 16, 32])
        .map_err(|e| anyhow!(e))?;
    let mults = args.get_usize_list("mults", &[1, 2]).map_err(|e| anyhow!(e))?;
    let threads = args.get_usize("threads", 0);
    if ranks.is_empty() || mults.is_empty() {
        return Err(anyhow!("--ranks and --mults need at least one value"));
    }
    print!("{}", twobp::experiments::schedule_space(&ranks, &mults, threads));
    Ok(())
}

fn cmd_bench(args: &Args) -> Result<()> {
    let exp = args
        .positional
        .get(1)
        .ok_or_else(|| anyhow!("bench needs an experiment name"))?;
    let steps = args.get_usize("steps", 3);
    let out = twobp::experiments::run_experiment(exp, steps)?;
    print!("{out}");
    Ok(())
}
