//! `twobp` — the 2BP pipeline-training launcher.
//!
//! ```text
//! twobp train    --preset transformer-tiny --schedule 1f1b-1 [--no-2bp]
//!                [--steps N] [--microbatches M] [--concat-p2] [--verbose]
//!                [--trace-out FILE.json]
//!                [--synthetic]  (in-process stub-backend manifest, no
//!                                artifacts needed; verified against sim)
//!                [--checkpoint-every N --checkpoint-dir DIR] [--resume DIR]
//!                 (bit-identical checkpoint/resume; docs/ROBUSTNESS.md §6)
//!                [--fault R:fail@C | R:stall-NS@C]  (with --synthetic:
//!                 inject a deterministic failure/stall into rank R's
//!                 forward at 0-based call C via the stub's `fault`
//!                 directive — the run fails fast with a typed error)
//!                [--comm-timeout-ms T] [--comm-backoff-ms B]
//!                [--comm-drop-prob P --comm-delay-ns NS
//!                 --comm-fault-seed S]  (seeded p2p chaos: reproducible
//!                 message drops/delays; drops trip the comm deadline)
//! twobp gantt    [--ranks N] [--cols W] [--schedule K] [--plan FILE]
//!                [--real --preset P]
//! twobp trace    --plan FILE [--out FILE.json]
//!                [--fwd F --p1 X --p2 Y --comm C]  (Chrome Trace Event
//!                 export of the plan's predicted timeline — load in
//!                 chrome://tracing or https://ui.perfetto.dev; see
//!                 docs/OBSERVABILITY.md)
//! twobp simulate --schedule 1f1b-1 --ranks 8 [--no-2bp] [--comm C]
//!                [--trace-out FILE.json]
//! twobp sweep    [--ranks 2,4,8,16,32] [--mults 1,2] [--threads K]
//!                [--plans DIR [--fwd F --p1 X --p2 Y --comm C]]
//! twobp tune     [--ranks N] [--budget 4.5G] [--beam K] [--gens G]
//!                [--seed S] [--fwd F --p1 X --p2 Y --comm C]
//!                [--out FILE.plan] [--gantt] [--threads K]
//!                [--trace-out FILE.json] [--metrics-out FILE.jsonl]
//!                 (observability: Chrome trace of the winner —
//!                 predicted timeline, plus the executed one in the
//!                 calibrated modes — and a deterministic JSONL run log
//!                 of search/calibration/drift metrics)
//!                [--robust [--jitter J] [--straggler R:MULT[,R:MULT]]
//!                 [--spike-prob P] [--spike-mult X] [--trials K]
//!                 [--pert-seed S]]  (tail objective: rank candidates
//!                 by p95 makespan over K seeded perturbation draws
//!                 instead of the clean makespan)
//!                [--synthetic | --manifest DIR]  (measured-cost
//!                 calibration loop: calibrate on the executor, tune
//!                 against measured costs, execute the winner back and
//!                 report predicted-vs-executed makespan; pjrt feature.
//!                 [--calib-steps N] [--steps N] apply there)
//!                [--replan [--drift-threshold T] [--drift-window W]
//!                 [--max-replans R] [--drift-cooldown C]]  (with
//!                 --synthetic: self-healing loop on a preset whose
//!                 stub costs drift mid-run — detect measured-vs-
//!                 predicted drift, re-calibrate + re-tune once;
//!                 beam/out flags use tuned defaults there)
//!                [--co-search [--devices D] [--layers L]
//!                 [--allreduce-per-byte S] [--migrations K]]  (joint
//!                 partition × schedule search: split D devices over
//!                 every dp×pp divisor cell, beam-search a schedule
//!                 per cell on the rolled-up per-layer profile,
//!                 hill-climb the layer boundaries, and rank cells by
//!                 effective throughput — makespan plus the DP
//!                 gradient-allreduce term; docs/PLAN_FORMAT.md §part.
//!                 With --synthetic/--manifest the *measured* stage
//!                 costs are repartitioned as layers instead)
//! twobp bench    <table1|fig1|synthetic|tune-calibrated|replan|faults
//!                 |robustness|fig3|fig4|fig5|table3|fig6|fig7|ckpt
//!                 |sweep|planner|partition> [--steps N]
//!                [--metrics-out FILE.jsonl]  (faults only: the
//!                 fault-recovery sweep's deterministic `fault.*` log)
//! twobp serve    [--socket PATH] [--log FILE] [--threads K]
//!                [--metrics-out FILE.jsonl]
//!                 (persistent tuning service: line-delimited JSON jobs
//!                 — calibrate/tune/score/gantt/shutdown — read from
//!                 stdin or a Unix socket, scheduled by deadline +
//!                 priority with calibration-gated dependencies,
//!                 answered one sorted-key JSON line per job; results
//!                 cached on request × profile fingerprints and
//!                 profiles/scratch kept resident across jobs; see
//!                 docs/SERVE.md)
//! twobp serve    --replay LOG  (re-execute an accepted-job log;
//!                 responses are byte-identical modulo "wall")
//! twobp config   --list
//! ```
//!
//! `train`, `gantt --real`, and the measured bench experiments need the
//! `pjrt` feature (real runtime); everything else is pure simulator.

use anyhow::{anyhow, Result};

use twobp::config::{table2, CoSearchFlags, RobustConfig};
use twobp::metrics::observer::{observer_or, NullObserver};
use twobp::metrics::registry::MetricsRegistry;
use twobp::planner::{
    co_search, BeamConfig, CoSearchConfig, CoSearchReport, ModelProfile,
    TuneProfile, TuneReport, TuneRequest,
};
use twobp::schedule::{generate, plan_io, validate::validate, ScheduleKind};
use twobp::sim::{simulate, CostModel};
use twobp::util::args::Args;
use twobp::util::gantt;
use twobp::util::stats::{fmt_bytes, parse_bytes};
use twobp::util::trace;

const FLAGS: &[&str] = &["no-2bp", "concat-p2", "verbose", "list", "real",
                         "csv", "gantt", "synthetic", "robust", "replan",
                         "co-search"];

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv, FLAGS);
    let cmd = args.positional.first().cloned().unwrap_or_default();
    let result = match cmd.as_str() {
        "train" => cmd_train(&args),
        "gantt" => cmd_gantt(&args),
        "simulate" => cmd_simulate(&args),
        "sweep" => cmd_sweep(&args),
        "tune" => cmd_tune(&args),
        "trace" => cmd_trace(&args),
        "bench" => cmd_bench(&args),
        "serve" => twobp::serve::run_cli(&args),
        "config" => {
            println!("{}", table2().render());
            Ok(())
        }
        _ => {
            eprintln!(
                "usage: twobp <train|gantt|simulate|sweep|tune|trace|bench\
                 |serve|config> [options]\n\
                 see `cargo doc` or README.md for details"
            );
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// `--trace-out` tail of `twobp train`: the executed timeline (per-rank
/// worker spans plus the comm lane) stacked against a predicted one —
/// the plan re-simulated under the run's own measured per-op costs.
/// The prediction covers one step; diff it against the first executed
/// step in Perfetto.
#[cfg(feature = "pjrt")]
fn train_trace_out(
    args: &Args,
    report: &twobp::pipeline::RunReport,
) -> Result<()> {
    let Some(path) = args.get("trace-out") else {
        return Ok(());
    };
    let costs = report.measured_costs()?;
    let sim =
        simulate(&report.plan, &costs, None).map_err(|e| anyhow!("{e}"))?;
    let mut tb = trace::TraceBuilder::new();
    tb.add_timeline("predicted", trace::PREDICTED_PID_BASE, &sim.spans);
    tb.add_timeline(
        "executed",
        trace::EXECUTED_PID_BASE,
        &report.trace_spans(),
    );
    write_trace(&tb, path)
}

#[cfg(feature = "pjrt")]
fn cmd_train(args: &Args) -> Result<()> {
    let mut cfg = twobp::config::RunConfig::from_args(args)?;
    if !cfg.synthetic {
        let report = twobp::pipeline::train(&cfg)?;
        print!("{}", twobp::metrics::run_summary(&report));
        return train_trace_out(args, &report);
    }
    // --synthetic: generate a stub-backend manifest in-process, train on
    // it, and cross-check the run against the simulator (op order +
    // byte-exact memory accounting) before reporting.
    if args.get("preset").is_some() || args.get("artifacts").is_some() {
        return Err(anyhow!(
            "--synthetic generates its own tiny in-process preset; \
             drop --preset/--artifacts (or drop --synthetic to train \
             on real artifacts)"
        ));
    }
    let spec = match &cfg.fault {
        // `--fault R:<kind>@C`: the tiny preset with the stub `fault`
        // directive baked into rank R's forward stage
        Some(f) => twobp::models::synthetic::SyntheticSpec::tiny_faulty(
            twobp::models::synthetic::StubFaultSpec::parse(f)?,
        ),
        None => twobp::models::synthetic::SyntheticSpec::tiny(),
    };
    let report = twobp::models::synthetic::with_temp_artifacts(
        "synth",
        &spec,
        |root, manifest| {
            cfg.artifacts = root.to_path_buf();
            cfg.preset = spec.preset.clone();
            let report = twobp::pipeline::train(&cfg)?;
            twobp::pipeline::verify_report_against_sim(
                &report, manifest, cfg.steps,
            )?;
            Ok(report)
        },
    )?;
    print!("{}", twobp::metrics::run_summary(&report));
    println!(
        "synthetic stub run verified against the simulator \
         (op order + byte-exact memory accounting)"
    );
    train_trace_out(args, &report)
}

#[cfg(not(feature = "pjrt"))]
fn cmd_train(_args: &Args) -> Result<()> {
    Err(anyhow!(
        "`twobp train` needs the real runtime; rebuild with \
         `--features pjrt` (built offline against the vendored stub \
         backend in vendor/xla-stub)"
    ))
}

#[cfg(feature = "pjrt")]
fn cmd_gantt_real(args: &Args, cols: usize) -> Result<()> {
    // render a measured timeline from a real (serialized) run
    let cfg = twobp::config::RunConfig::from_args(args)?;
    let report = twobp::pipeline::train(&cfg)?;
    let spans = report.spans();
    if args.has("csv") {
        print!("{}", gantt::to_csv(&spans));
    } else {
        print!("{}", gantt::render(&spans, cols));
    }
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_gantt_real(_args: &Args, _cols: usize) -> Result<()> {
    Err(anyhow!(
        "`twobp gantt --real` needs the real runtime; rebuild with \
         `--features pjrt` (built offline against the vendored stub \
         backend in vendor/xla-stub)"
    ))
}

/// Cost model from the shared `--fwd/--p1/--p2/--comm` ratio flags
/// (defaults to unit costs — the Fig 1 idealization).
fn cost_model_from_args(args: &Args, n: usize) -> CostModel {
    let mut cm = CostModel::ratios(
        n,
        args.get_f64("fwd", 1.0),
        args.get_f64("p1", 1.0),
        args.get_f64("p2", 1.0),
    );
    cm.comm = args.get_f64("comm", 0.0);
    cm
}

fn cmd_gantt(args: &Args) -> Result<()> {
    let cols = args.get_usize("cols", 96);
    if args.has("real") {
        return cmd_gantt_real(args, cols);
    }
    if let Some(path) = args.get("plan") {
        // render an arbitrary `.plan` file (hand-written or a
        // `twobp tune --out` winner) — see docs/PLAN_FORMAT.md
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("reading {path}: {e}"))?;
        let plan = plan_io::parse(&text).map_err(|e| anyhow!("{path}: {e}"))?;
        let cm = cost_model_from_args(args, plan.n_ranks);
        // eval_plan = validate + simulate: the one rejection path shared
        // with the planner
        let res = twobp::sim::eval_plan(&plan, &cm, None, None)
            .map_err(|e| anyhow!("{path}: {e}"))?
            .result;
        if args.has("csv") {
            print!("{}", gantt::to_csv(&res.spans));
        } else {
            println!("--- {} ({path}) ---  bubble ratio {:.3}",
                     plan.describe(), res.bubble_ratio);
            // v2 plans carry a layer partition: prefix the per-rank
            // `layers a-b  dp=k` headers (byte-identical for v1 plans)
            print!("{}", gantt::render_with_partition(
                &res.spans, cols, plan.partition.as_ref()));
        }
        return Ok(());
    }
    let n = args.get_usize("ranks", 4);
    match args.get_parsed::<ScheduleKind>("schedule").map_err(|e| anyhow!(e))? {
        Some(kind) => {
            for two_bp in [false, true] {
                let m = args.get_usize("microbatches", 0);
                let plan = generate(kind, two_bp, n, m, false);
                let res = simulate(&plan, &CostModel::unit(n), None)
                    .map_err(|e| anyhow!("{e}"))?;
                println!("--- {} ---  bubble ratio {:.3}",
                         plan.describe(), res.bubble_ratio);
                print!("{}", gantt::render(&res.spans, cols));
            }
            Ok(())
        }
        None => {
            print!("{}", twobp::experiments::fig1(n, cols));
            Ok(())
        }
    }
}

/// Write a finished Chrome trace to `path` with a pointer line (the
/// shared `--trace-out` tail; format in docs/OBSERVABILITY.md).
fn write_trace(tb: &trace::TraceBuilder, path: &str) -> Result<()> {
    tb.write(std::path::Path::new(path))
        .map_err(|e| anyhow!("writing {path}: {e}"))?;
    println!(
        "wrote Chrome trace to {path} ({} events; load in chrome://tracing \
         or https://ui.perfetto.dev)",
        tb.len(),
    );
    Ok(())
}

/// Write a metrics-registry run log to `path` with a pointer line (the
/// shared `--metrics-out` tail; schema in docs/OBSERVABILITY.md).
fn write_metrics(m: &MetricsRegistry, path: &str) -> Result<()> {
    m.write(std::path::Path::new(path))
        .map_err(|e| anyhow!("writing {path}: {e}"))?;
    println!(
        "wrote metrics log to {path} ({} events + aggregates, JSONL)",
        m.n_events(),
    );
    Ok(())
}

/// `twobp trace`: export a `.plan` file's **predicted** timeline (Tier B
/// sim under the `--fwd/--p1/--p2/--comm` cost shape) as a Chrome Trace
/// Event file.  The executed counterpart comes from `--trace-out` on
/// `train`/`tune --synthetic`, which stack the real run's spans next to
/// the prediction under a separate process group.
fn cmd_trace(args: &Args) -> Result<()> {
    let path = args.get("plan").ok_or_else(|| {
        anyhow!(
            "trace needs --plan FILE (write one with `twobp tune --out`, \
             grammar in docs/PLAN_FORMAT.md)"
        )
    })?;
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow!("reading {path}: {e}"))?;
    let plan = plan_io::parse(&text).map_err(|e| anyhow!("{path}: {e}"))?;
    let cm = cost_model_from_args(args, plan.n_ranks);
    let res = twobp::sim::eval_plan(&plan, &cm, None, None)
        .map_err(|e| anyhow!("{path}: {e}"))?
        .result;
    let mut tb = trace::TraceBuilder::new();
    tb.add_timeline("predicted", trace::PREDICTED_PID_BASE, &res.spans);
    match args.get("out") {
        Some(out) => write_trace(&tb, out),
        None => {
            println!("{}", tb.render());
            Ok(())
        }
    }
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let n = args.get_usize("ranks", 4);
    let kind = args
        .get_parsed::<ScheduleKind>("schedule")
        .map_err(|e| anyhow!(e))?
        .unwrap_or(ScheduleKind::OneF1B1);
    let two_bp = !args.has("no-2bp");
    let m = args.get_usize("microbatches", 0);
    let cm = cost_model_from_args(args, n);
    let plan = generate(kind, two_bp, n, m, false);
    validate(&plan).map_err(|e| anyhow!("{e}"))?;
    let res = simulate(&plan, &cm, None).map_err(|e| anyhow!("{e}"))?;
    println!("{}", plan.describe());
    println!("makespan       : {:.4}", res.makespan);
    println!("bubble ratio   : {:.4}", res.bubble_ratio);
    if let Some(path) = args.get("trace-out") {
        let mut tb = trace::TraceBuilder::new();
        tb.add_timeline("predicted", trace::PREDICTED_PID_BASE, &res.spans);
        write_trace(&tb, path)?;
    }
    println!("throughput gain vs no-2BP:");
    let base = generate(kind, false, n, m, false);
    let bres = simulate(&base, &cm, None).map_err(|e| anyhow!("{e}"))?;
    println!("  {:.3}x (makespan {:.4} -> {:.4})",
             bres.makespan / res.makespan, bres.makespan, res.makespan);
    Ok(())
}

/// Parallel schedule-space sweep (pure simulator; see
/// `experiments::schedule_space`).  With `--plans DIR`, sweeps a
/// directory of `.plan` files instead of the generator grid — every
/// file evaluated through the scoring fast path under the shared
/// `--fwd/--p1/--p2/--comm` cost shape (`experiments::plan_space`).
fn cmd_sweep(args: &Args) -> Result<()> {
    let threads = args.get_usize("threads", 0);
    if let Some(dir) = args.get("plans") {
        if args.get("ranks").is_some() || args.get("mults").is_some() {
            return Err(anyhow!(
                "--plans sweeps a directory of .plan files; --ranks/--mults \
                 apply only to the generator grid (drop them, or drop \
                 --plans)"
            ));
        }
        let ratios = (
            args.get_f64("fwd", 1.0),
            args.get_f64("p1", 1.0),
            args.get_f64("p2", 1.0),
        );
        let comm = args.get_f64("comm", 0.0);
        print!(
            "{}",
            twobp::experiments::plan_space(
                std::path::Path::new(dir),
                ratios,
                comm,
                threads,
            )?
        );
        return Ok(());
    }
    let ranks = args
        .get_usize_list("ranks", &[2, 4, 8, 16, 32])
        .map_err(|e| anyhow!(e))?;
    let mults = args.get_usize_list("mults", &[1, 2]).map_err(|e| anyhow!(e))?;
    if ranks.is_empty() || mults.is_empty() {
        return Err(anyhow!("--ranks and --mults need at least one value"));
    }
    print!("{}", twobp::experiments::schedule_space(&ranks, &mults, threads));
    Ok(())
}

/// Beam-search hyper-parameters from the shared `twobp tune` flags
/// (used by both the ratio-profile and calibrated paths; the robust
/// knob cluster parses through [`RobustConfig`] in `config`).
fn beam_config_from_args(args: &Args) -> Result<BeamConfig> {
    let budget = match args.get("budget") {
        Some(s) => Some(parse_bytes(s).map_err(|e| anyhow!(e))?),
        None => None,
    };
    let defaults = BeamConfig::default();
    Ok(BeamConfig {
        beam_width: args.get_usize("beam", defaults.beam_width),
        generations: args.get_usize("gens", defaults.generations),
        mutations_per_parent: args
            .get_usize("mutations", defaults.mutations_per_parent),
        max_microbatches: args.get_usize("microbatches-max", 0),
        seed: args.get_usize("seed", defaults.seed as usize) as u64,
        threads: args.get_usize("threads", 0),
        budget_bytes: budget,
        patience: args.get_usize("patience", defaults.patience),
        robust: RobustConfig::from_args(args)?.objective,
    })
}

/// Shared `--out` / `--gantt` tail of both `twobp tune` paths: write
/// the winner's `.plan` text and/or render its timeline under `costs`.
fn winner_outputs(
    args: &Args,
    text: &str,
    plan: &twobp::Plan,
    costs: &CostModel,
) -> Result<()> {
    if let Some(path) = args.get("out") {
        std::fs::write(path, text)
            .map_err(|e| anyhow!("writing {path}: {e}"))?;
        println!("wrote winner to {path} (render: twobp gantt --plan {path})");
    }
    if args.has("gantt") {
        let res = simulate(plan, costs, None).map_err(|e| anyhow!("{e}"))?;
        print!("{}", gantt::render_with_partition(
            &res.spans, args.get_usize("cols", 96),
            plan.partition.as_ref()));
    }
    Ok(())
}

/// Print the search-effort / winner / named-best block shared by every
/// `twobp tune` profile source.
fn print_search_summary(report: &TuneReport, cfg: &BeamConfig) {
    if let Some(r) = &cfg.robust {
        println!(
            "robust objective: rank by p95 makespan over {} seeded draws \
             (jitter {:.3}, stragglers {}, comm spike p={:.2} x{:.1}, \
             pert seed {:#x})",
            r.trials,
            r.pert.jitter,
            if r.pert.stragglers.is_empty() {
                "none".to_string()
            } else {
                r.pert
                    .stragglers
                    .iter()
                    .map(|(rk, m)| format!("r{rk}:x{m}"))
                    .collect::<Vec<_>>()
                    .join(",")
            },
            r.pert.comm_spike_prob,
            r.pert.comm_spike_mult,
            r.pert.seed,
        );
    }
    println!(
        "  evaluated {} candidates over {} generations \
         ({} over budget, {} sim-rejected; beam {}, seed {})",
        report.evaluated, report.generations_run, report.rejected_budget,
        report.rejected_sim, cfg.beam_width, cfg.seed,
    );
    println!(
        "  best samples/s by generation: {}",
        report
            .history
            .iter()
            .map(|t| format!("{t:.4}"))
            .collect::<Vec<_>>()
            .join(" -> "),
    );
    let best = &report.best;
    println!(
        "winner: {} [{} from {}]\n  throughput {:.4} samples/s   \
         peak {}   makespan {:.3}",
        best.plan.describe(), best.origin, best.seed, best.throughput,
        fmt_bytes(best.max_peak), best.makespan,
    );
    match &report.named_best {
        Some(nb) => println!(
            "vs best named schedule that fits: {} at {:.4} samples/s, \
             peak {} -> {:.3}x",
            nb.plan.describe(),
            nb.throughput,
            fmt_bytes(nb.max_peak),
            best.throughput / nb.throughput,
        ),
        None => println!(
            "no unmodified named schedule fits this budget \
             (the winner is planner-built)"
        ),
    }
}

/// Print the ranked dp×pp cell table + winner block of a co-search
/// run (shared by the ratio-profile and calibrated paths).
fn print_cosearch_summary(report: &CoSearchReport, cfg: &CoSearchConfig) {
    println!(
        "co-search: model {}, {} devices, budget {}/device",
        report.model_name,
        report.devices,
        cfg.beam
            .budget_bytes
            .map(fmt_bytes)
            .unwrap_or_else(|| "unconstrained".into()),
    );
    println!(
        "  {:>2} × {:<2}  {:<26} {:>10} {:>11} {:>10} {:>5}",
        "dp", "pp", "partition", "step time", "samples/s", "peak", "migr",
    );
    for c in &report.cells {
        println!(
            "  {:>2} × {:<2}  {:<26} {:>10.4} {:>11.3} {:>10} {:>5}",
            c.dp,
            c.pp,
            c.partition.describe(),
            c.step_time,
            c.throughput,
            fmt_bytes(c.max_peak),
            c.migrations,
        );
    }
    for (dp, pp, e) in &report.infeasible {
        println!("  {dp:>2} × {pp:<2}  infeasible: {e}");
    }
    let b = report.best();
    println!(
        "winner: dp={} pp={}  {}  [{}]\n  throughput {:.4} samples/s   \
         step time {:.4} (makespan {:.4} + allreduce {:.4})   peak {}",
        b.dp,
        b.pp,
        b.partition.describe(),
        b.candidate.plan.describe(),
        b.throughput,
        b.step_time,
        b.makespan,
        b.allreduce_s,
        fmt_bytes(b.max_peak),
    );
}

/// `twobp tune --co-search` on the ratio profile: build a per-layer
/// [`ModelProfile`] (LLaMa-like, or `--fwd/--p1/--p2/--comm` ratios)
/// and run the joint partition × schedule search over the dp×pp grid.
fn cmd_tune_cosearch(args: &Args, flags: &CoSearchFlags) -> Result<()> {
    if args.get("ranks").is_some() {
        return Err(anyhow!(
            "--ranks fixes the stage count, but --co-search searches \
             the whole dp×pp grid (pipeline depth included); use \
             --devices and --layers instead"
        ));
    }
    let layers = flags.layer_count();
    let custom_costs = ["fwd", "p1", "p2", "comm"]
        .iter()
        .any(|k| args.get(k).is_some());
    let profile = if custom_costs {
        TuneProfile::from_ratios(
            layers,
            args.get_f64("fwd", 1.0),
            args.get_f64("p1", 1.05),
            args.get_f64("p2", 0.95),
            args.get_f64("comm", 0.05),
        )
    } else {
        TuneProfile::llama_like(layers)
    };
    let mut model = ModelProfile::from_profile(&profile);
    model.allreduce_per_byte = flags.allreduce_per_byte;
    let mut cfg = CoSearchConfig::new(flags.devices, beam_config_from_args(args)?);
    cfg.max_migrations = flags.migrations;
    let mut obs = args.get("metrics-out").map(|_| MetricsRegistry::new());
    let mut null = NullObserver;
    let report = co_search(&model, &cfg, observer_or(obs.as_mut(), &mut null))
        .map_err(|e| anyhow!(e))?;
    print_cosearch_summary(&report, &cfg);
    let best = report.best();
    // the winner's outputs price under its own rolled-up stage profile
    let rolled = model.roll_up(&best.partition).map_err(|e| anyhow!(e))?;
    winner_outputs(args, &best.candidate.text, &best.candidate.plan,
                   &rolled.costs)?;
    if let Some(path) = args.get("trace-out") {
        let res = simulate(&best.candidate.plan, &rolled.costs, None)
            .map_err(|e| anyhow!("{e}"))?;
        let mut tb = trace::TraceBuilder::new();
        tb.add_timeline("predicted", trace::PREDICTED_PID_BASE, &res.spans);
        write_trace(&tb, path)?;
    }
    if let (Some(path), Some(m)) = (args.get("metrics-out"), obs.as_ref()) {
        write_metrics(m, path)?;
    }
    Ok(())
}

/// Memory-constrained schedule auto-tuning (the `planner/` subsystem):
/// beam-search the legal-plan space for the best-throughput schedule
/// whose per-rank peak fits `--budget`.  Profile defaults to the
/// LLaMa-like one; `--fwd/--p1/--p2/--comm` override the cost shape;
/// `--synthetic` / `--manifest <preset-dir>` switch to the
/// measured-cost calibration loop instead (pjrt feature).
fn cmd_tune(args: &Args) -> Result<()> {
    let cosearch = CoSearchFlags::from_args(args)?;
    if args.has("synthetic") || args.get("manifest").is_some() {
        // measured-cost mode: rank count and cost shape come from the
        // manifest + calibration, so the ratio-profile flags would be
        // silently ignored — reject the conflict instead
        for k in ["ranks", "fwd", "p1", "p2", "comm"] {
            if args.get(k).is_some() {
                return Err(anyhow!(
                    "--{k} sets the hand-tuned ratio profile, but \
                     --synthetic/--manifest tune against *measured* \
                     costs (rank count and cost shape come from the \
                     manifest); drop --{k}"
                ));
            }
        }
        return cmd_tune_calibrated(args);
    }
    if cosearch.enabled {
        return cmd_tune_cosearch(args, &cosearch);
    }
    let n = args.get_usize("ranks", 4);
    let custom_costs = ["fwd", "p1", "p2", "comm"]
        .iter()
        .any(|k| args.get(k).is_some());
    let profile = if custom_costs {
        TuneProfile::from_ratios(
            n,
            args.get_f64("fwd", 1.0),
            args.get_f64("p1", 1.05),
            args.get_f64("p2", 0.95),
            args.get_f64("comm", 0.05),
        )
    } else {
        TuneProfile::llama_like(n)
    };
    let cfg = beam_config_from_args(args)?;
    let mut obs = args.get("metrics-out").map(|_| MetricsRegistry::new());
    let mut null = NullObserver;
    let report = TuneRequest::new(&profile, n, cfg.clone())
        .run(observer_or(obs.as_mut(), &mut null))
        .map_err(|e| anyhow!(e))?;

    println!(
        "planner: profile {}, {} ranks, budget {}/rank",
        report.profile_name,
        report.n_ranks,
        report
            .budget_bytes
            .map(fmt_bytes)
            .unwrap_or_else(|| "unconstrained".into()),
    );
    print_search_summary(&report, &cfg);
    winner_outputs(args, &report.best.text, &report.best.plan,
                   &profile.costs)?;
    if let Some(path) = args.get("trace-out") {
        // ratio-profile mode has no executor run: the trace carries the
        // winner's predicted timeline only
        let res = simulate(&report.best.plan, &profile.costs, None)
            .map_err(|e| anyhow!("{e}"))?;
        let mut tb = trace::TraceBuilder::new();
        tb.add_timeline("predicted", trace::PREDICTED_PID_BASE, &res.spans);
        write_trace(&tb, path)?;
    }
    if let (Some(path), Some(m)) = (args.get("metrics-out"), obs.as_ref()) {
        write_metrics(m, path)?;
    }
    Ok(())
}

/// The measured-cost calibration loop (`twobp tune --synthetic` /
/// `--manifest <preset-dir>`): run contention-free calibration steps on
/// the real executor, derive a measured [`TuneProfile`] from
/// `RunReport::measured_costs` + the manifest byte classes, beam-search
/// against it, then execute the winning plan back on the executor
/// (verified against the simulator) and report predicted-vs-executed
/// makespan.
#[cfg(feature = "pjrt")]
fn cmd_tune_calibrated(args: &Args) -> Result<()> {
    use twobp::config::{CalibConfig, RunConfig};
    use twobp::experiments::tune_and_execute;
    use twobp::models::Manifest;
    use twobp::pipeline::Cluster;
    use twobp::util::stats::fmt_duration;

    let calib = CalibConfig::from_args(args)?;
    let beam_cfg = beam_config_from_args(args)?;
    let cosearch = CoSearchFlags::from_args(args)?;
    if cosearch.enabled && calib.replan {
        return Err(anyhow!(
            "--replan re-tunes the fixed-stage schedule mid-run; \
             --co-search is a static planning mode — drop one"
        ));
    }
    if cosearch.enabled && cosearch.layers != 0 {
        return Err(anyhow!(
            "--layers sets the ratio-profile layer count, but with \
             --synthetic/--manifest the measured stages *are* the \
             layers (one per manifest stage); drop --layers"
        ));
    }
    let mut obs = args.get("metrics-out").map(|_| MetricsRegistry::new());

    if calib.replan {
        if args.get("trace-out").is_some() {
            return Err(anyhow!(
                "--trace-out only applies to single-run modes (the replan \
                 loop executes many one-step chunks); drop it, or drop \
                 --replan"
            ));
        }
        // self-healing loop: tune_replan owns its cluster, drifting
        // preset, and (deliberately fixed) beam settings — only the
        // drift knobs, the step count, and the metrics observer pass
        // through
        let drift = twobp::pipeline::DriftConfig {
            threshold: calib.drift.threshold,
            window: calib.drift.window,
            max_replans: calib.drift.max_replans,
            cooldown: calib.drift.cooldown,
        };
        let mut null = NullObserver;
        print!(
            "{}",
            twobp::experiments::tune_replan(
                calib.exec_steps,
                drift,
                observer_or(obs.as_mut(), &mut null),
            )?
        );
        if let (Some(path), Some(m)) = (args.get("metrics-out"), obs.as_ref())
        {
            write_metrics(m, path)?;
        }
        return Ok(());
    }

    let mut run_loop = |root: &std::path::Path,
                        preset: &str,
                        manifest: &Manifest|
     -> Result<()> {
        let base = RunConfig {
            preset: preset.to_string(),
            artifacts: root.to_path_buf(),
            steps: calib.calib_steps,
            n_microbatches: manifest.n_stages,
            seed: calib.seed,
            ..RunConfig::default()
        };
        let cluster = Cluster::new(&base)?;
        let (costs, _calib_report) = cluster.calibrate(&base)?;
        println!(
            "calibration ({} naive steps on {preset}): measured \
             per-stage costs",
            base.steps,
        );
        for r in 0..costs.fwd.len() {
            println!(
                "  stage {r}: fwd {:8.3}ms  p1 {:8.3}ms  p2 {:8.3}ms  \
                 opt {:8.3}ms",
                costs.fwd[r] * 1e3,
                costs.p1[r] * 1e3,
                costs.p2[r] * 1e3,
                costs.opt[r] * 1e3,
            );
        }
        println!("  loss (last rank): {:.3}ms", costs.loss * 1e3);
        // Schedule-aware comm (docs/ROBUSTNESS.md §5): probe measured
        // per-(schedule, m) send costs to replace the single naive-run
        // mean — send cost depends on how the schedule interleaves
        // compute with serialization.  The beam still prices
        // planner-built candidates with one scalar, so it gets the
        // probed-cell mean; unprobed shapes fall back to the floor.
        let comm_cells: Vec<(ScheduleKind, usize)> = [
            ScheduleKind::GPipe,
            ScheduleKind::OneF1B1,
            ScheduleKind::OneF1B2,
        ]
        .into_iter()
        .map(|k| (k, manifest.n_stages))
        .collect();
        let comm_cal =
            cluster.calibrate_comm(&base, costs.comm, &comm_cells)?;
        let mut costs = costs;
        for (kind, m, v) in comm_cal.cells() {
            println!("  comm[{} m={m}]: {:8.3}ms", kind.name(), v * 1e3);
        }
        if !comm_cal.cells().is_empty() {
            let mean = comm_cal.cells().iter().map(|(_, _, v)| *v)
                .sum::<f64>() / comm_cal.cells().len() as f64;
            println!(
                "  comm floor {:.3}ms -> per-cell mean {:.3}ms \
                 (planner scalar)",
                costs.comm * 1e3,
                mean * 1e3,
            );
            costs.comm = mean;
        }
        if let Some(m) = obs.as_mut() {
            twobp::experiments::record_calibration(m, &costs, base.steps);
        }
        let profile = TuneProfile::from_measured(
            format!("measured:{preset}"),
            costs,
            manifest.mem_model(),
            manifest.samples_per_microbatch,
        )
        .map_err(|e| anyhow!(e))?;
        if cosearch.enabled {
            // measured-cost co-search: the calibrated per-stage costs
            // become the per-layer model (stage s → layer s) and the
            // dp×pp grid is searched over them.  The winner is *not*
            // executed back — execute-back assumes the manifest's own
            // layer→stage mapping, which a repartition changes.
            let mut model = ModelProfile::from_profile(&profile);
            model.allreduce_per_byte = cosearch.allreduce_per_byte;
            let mut cs_cfg =
                CoSearchConfig::new(cosearch.devices, beam_cfg.clone());
            cs_cfg.max_migrations = cosearch.migrations;
            let mut null = NullObserver;
            let report = co_search(
                &model,
                &cs_cfg,
                observer_or(obs.as_mut(), &mut null),
            )
            .map_err(|e| anyhow!(e))?;
            print_cosearch_summary(&report, &cs_cfg);
            println!(
                "note: co-search repartitions the {} measured stages as \
                 layers; the winner is planned, not executed back \
                 (execute-back assumes the manifest's stage mapping)",
                manifest.n_stages,
            );
            let best = report.best();
            let rolled =
                model.roll_up(&best.partition).map_err(|e| anyhow!(e))?;
            winner_outputs(args, &best.candidate.text,
                           &best.candidate.plan, &rolled.costs)?;
            if let Some(path) = args.get("trace-out") {
                let res =
                    simulate(&best.candidate.plan, &rolled.costs, None)
                        .map_err(|e| anyhow!("{e}"))?;
                let mut tb = trace::TraceBuilder::new();
                tb.add_timeline(
                    "predicted",
                    trace::PREDICTED_PID_BASE,
                    &res.spans,
                );
                write_trace(&tb, path)?;
            }
            if let (Some(path), Some(m)) =
                (args.get("metrics-out"), obs.as_ref())
            {
                write_metrics(m, path)?;
            }
            return Ok(());
        }
        println!(
            "planner: profile {}, {} ranks, budget {}/rank",
            profile.name,
            manifest.n_stages,
            beam_cfg
                .budget_bytes
                .map(fmt_bytes)
                .unwrap_or_else(|| "unconstrained".into()),
        );
        // the winner executes under the same seed/data stream the
        // calibration measured; only the step count differs
        let exec_cfg = RunConfig { steps: calib.exec_steps, ..base.clone() };
        let mut null = NullObserver;
        let ct = tune_and_execute(&cluster, manifest, &profile, &beam_cfg,
                                  &exec_cfg,
                                  observer_or(obs.as_mut(), &mut null))?;
        print_search_summary(&ct.report, &beam_cfg);
        println!(
            "winner executed back on the runtime for {} steps, verified \
             against the simulator (op order + byte-exact memory)",
            calib.exec_steps,
        );
        println!(
            "  predicted step makespan {} | executed {} | \
             executed/predicted {:.2}",
            fmt_duration(ct.predicted_makespan),
            fmt_duration(ct.executed_makespan),
            ct.executed_makespan / ct.predicted_makespan.max(1e-12),
        );
        if let Some(m) = obs.as_mut() {
            // passive drift watch: judge the executed steps against the
            // planner's prediction with a default monitor, so the run
            // log carries drift verdicts even without --replan
            twobp::experiments::record_passive_drift(
                m,
                &ct.executed,
                ct.predicted_makespan,
                twobp::pipeline::DriftConfig::default(),
            );
        }
        winner_outputs(args, &ct.report.best.text, &ct.report.best.plan,
                       &profile.costs)?;
        if let Some(path) = args.get("trace-out") {
            // predicted: the winner under the measured (calibration)
            // cost model; executed: the verified winner run itself
            let res = simulate(&ct.report.best.plan, &profile.costs, None)
                .map_err(|e| anyhow!("{e}"))?;
            let mut tb = trace::TraceBuilder::new();
            tb.add_timeline(
                "predicted",
                trace::PREDICTED_PID_BASE,
                &res.spans,
            );
            tb.add_timeline(
                "executed",
                trace::EXECUTED_PID_BASE,
                &ct.executed.trace_spans(),
            );
            write_trace(&tb, path)?;
        }
        if let (Some(path), Some(m)) =
            (args.get("metrics-out"), obs.as_ref())
        {
            write_metrics(m, path)?;
        }
        Ok(())
    };

    if calib.synthetic {
        let spec = twobp::models::synthetic::SyntheticSpec::skewed();
        twobp::models::synthetic::with_temp_artifacts(
            "tune-synth",
            &spec,
            |root, manifest| run_loop(root, &spec.preset, manifest),
        )
    } else {
        let dir = calib
            .manifest_dir
            .clone()
            .expect("CalibConfig::from_args guarantees a source");
        let (root, preset) = CalibConfig::split_manifest(&dir)?;
        let manifest = Manifest::load(&root, &preset)?;
        run_loop(&root, &preset, &manifest)
    }
}

#[cfg(not(feature = "pjrt"))]
fn cmd_tune_calibrated(_args: &Args) -> Result<()> {
    Err(anyhow!(
        "`twobp tune --synthetic/--manifest` calibrates on the real \
         runtime; rebuild with `--features pjrt` (built offline against \
         the vendored stub backend in vendor/xla-stub)"
    ))
}

fn cmd_bench(args: &Args) -> Result<()> {
    let exp = args
        .positional
        .get(1)
        .ok_or_else(|| anyhow!("bench needs an experiment name"))?;
    let steps = args.get_usize("steps", 3);
    if args.get("metrics-out").is_some()
        && !matches!(exp.as_str(), "faults" | "fault")
    {
        return Err(anyhow!(
            "--metrics-out on bench applies to the 'faults' experiment \
             (search/drift run logs come from `twobp tune --metrics-out`)"
        ));
    }
    let mut obs = args.get("metrics-out").map(|_| MetricsRegistry::new());
    let mut null = NullObserver;
    let out = twobp::experiments::run_experiment_with(
        exp,
        steps,
        observer_or(obs.as_mut(), &mut null),
    )?;
    print!("{out}");
    if let (Some(path), Some(m)) = (args.get("metrics-out"), obs.as_ref()) {
        write_metrics(m, path)?;
    }
    Ok(())
}
