//! Layer→stage partitions with a data-parallel replication factor.
//!
//! The paper fixes the layer→stage split and schedules around it; a
//! [`Partition`] makes the split itself a first-class, searchable part
//! of a [`Plan`](crate::schedule::Plan) (BaPipe / DAPPLE, PAPERS.md).
//! A partition is a **contiguous** assignment of `n_layers` model
//! layers to `n_stages` pipeline stages — encoded as a strictly
//! increasing cut vector — plus a replication factor `dp`: the whole
//! pipeline is cloned `dp` times over the device grid (DAPPLE-style
//! hybrid DP×PP), paying a gradient allreduce per step in exchange.
//!
//! Plans without a partition behave exactly as before — the field is
//! optional everywhere (DSL v1 files, the fingerprint, the validator)
//! so every persisted artifact and fingerprint stays stable.

/// A contiguous layer→stage assignment plus a DP replication factor.
///
/// `cuts` has `n_stages + 1` entries: `cuts[0] == 0`,
/// `cuts[n_stages] == n_layers`, strictly increasing — stage `s` owns
/// layers `cuts[s] .. cuts[s+1]` (every stage at least one layer).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Partition {
    pub cuts: Vec<usize>,
    /// Data-parallel replication factor (>= 1; 1 = pure pipeline).
    pub dp: u32,
}

impl Partition {
    /// The balanced-by-count contiguous split: `n_layers` layers over
    /// `n_stages` stages, remainder spread over the *earliest* stages
    /// (deterministic; the co-search's starting point).
    ///
    /// Panics if `n_stages == 0` or `n_layers < n_stages` (a stage
    /// would own no layer).
    pub fn balanced(n_layers: usize, n_stages: usize, dp: u32) -> Partition {
        assert!(n_stages > 0, "partition needs at least one stage");
        assert!(
            n_layers >= n_stages,
            "{n_layers} layers cannot cover {n_stages} stages"
        );
        let base = n_layers / n_stages;
        let extra = n_layers % n_stages;
        let mut cuts = Vec::with_capacity(n_stages + 1);
        let mut at = 0usize;
        cuts.push(at);
        for s in 0..n_stages {
            at += base + usize::from(s < extra);
            cuts.push(at);
        }
        Partition { cuts, dp: dp.max(1) }
    }

    /// The identity split: one layer per stage (the pre-partition
    /// world, where stage s *is* layer s).  Rolling a per-layer model
    /// up through this partition is bit-identical to the old per-stage
    /// path — the differential property the refactor is held to.
    pub fn trivial(n_layers: usize) -> Partition {
        Partition::balanced(n_layers, n_layers, 1)
    }

    pub fn n_stages(&self) -> usize {
        self.cuts.len().saturating_sub(1)
    }

    pub fn n_layers(&self) -> usize {
        self.cuts.last().copied().unwrap_or(0)
    }

    /// Layers owned by stage `s`, as a half-open range.
    pub fn layers(&self, s: usize) -> std::ops::Range<usize> {
        self.cuts[s]..self.cuts[s + 1]
    }

    /// Structural validity: >= 2 cut points, `cuts[0] == 0`, strictly
    /// increasing (every stage non-empty), `dp >= 1`.
    pub fn check(&self) -> Result<(), String> {
        if self.cuts.len() < 2 {
            return Err(format!(
                "partition needs at least 2 cut points, got {}",
                self.cuts.len()
            ));
        }
        if self.cuts[0] != 0 {
            return Err(format!(
                "partition cuts must start at 0, got {}",
                self.cuts[0]
            ));
        }
        for w in self.cuts.windows(2) {
            if w[1] <= w[0] {
                return Err(format!(
                    "partition cuts must be strictly increasing \
                     (every stage owns >= 1 layer), got {} then {}",
                    w[0], w[1]
                ));
            }
        }
        if self.dp == 0 {
            return Err("partition dp factor must be >= 1".into());
        }
        Ok(())
    }

    /// Human-readable form, e.g. `dp=2 layers 0-2|3-3` (inclusive
    /// per-stage layer ranges — the same ranges the DSL `part` header
    /// and the gantt per-rank headers print).
    pub fn describe(&self) -> String {
        let stages: Vec<String> = (0..self.n_stages())
            .map(|s| {
                let r = self.layers(s);
                format!("{}-{}", r.start, r.end - 1)
            })
            .collect();
        format!("dp={} layers {}", self.dp, stages.join("|"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn balanced_spreads_the_remainder_over_early_stages() {
        let p = Partition::balanced(10, 4, 1);
        assert_eq!(p.cuts, vec![0, 3, 6, 8, 10]);
        assert_eq!(p.n_stages(), 4);
        assert_eq!(p.n_layers(), 10);
        assert_eq!(p.layers(0), 0..3);
        assert_eq!(p.layers(3), 8..10);
        p.check().unwrap();
        // exact division: uniform stages
        let q = Partition::balanced(8, 4, 2);
        assert_eq!(q.cuts, vec![0, 2, 4, 6, 8]);
        assert_eq!(q.dp, 2);
    }

    #[test]
    fn trivial_is_one_layer_per_stage() {
        let p = Partition::trivial(5);
        assert_eq!(p.cuts, vec![0, 1, 2, 3, 4, 5]);
        assert_eq!(p.dp, 1);
        for s in 0..5 {
            assert_eq!(p.layers(s), s..s + 1);
        }
    }

    #[test]
    fn check_rejects_malformed_partitions() {
        let ok = Partition { cuts: vec![0, 2, 4], dp: 1 };
        ok.check().unwrap();
        for (bad, needle) in [
            (Partition { cuts: vec![0], dp: 1 }, "at least 2"),
            (Partition { cuts: vec![1, 4], dp: 1 }, "start at 0"),
            (Partition { cuts: vec![0, 2, 2], dp: 1 },
             "strictly increasing"),
            (Partition { cuts: vec![0, 3, 2], dp: 1 },
             "strictly increasing"),
            (Partition { cuts: vec![0, 2, 4], dp: 0 }, ">= 1"),
        ] {
            let err = bad.check().unwrap_err();
            assert!(err.contains(needle), "{bad:?}: {err}");
        }
    }

    #[test]
    fn describe_prints_inclusive_ranges() {
        let p = Partition { cuts: vec![0, 3, 4, 7], dp: 2 };
        assert_eq!(p.describe(), "dp=2 layers 0-2|3-3|4-6");
    }
}
