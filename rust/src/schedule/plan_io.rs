//! The plan DSL: a line-oriented text format (`.plan` files) that
//! round-trips [`Plan`] exactly, so hand-built and planner-found
//! schedules are first-class inputs everywhere a generated one is
//! (sweeps, gantt, the simulator, the tuner).
//!
//! Canonical form (see `docs/PLAN_FORMAT.md` for the full grammar):
//!
//! ```text
//! plan v1
//! kind 1f1b-1
//! two_bp true
//! ranks 2
//! microbatches 2
//! greedy_p2 true
//! rank 0 | f0 f1 b0 b1 flush opt
//! rank 1 | f0 b0 f1 b1 flush opt
//! ```
//!
//! Op tokens: `f<mb>` forward, `b<mb>` backward-p1, `w(<mb>,...)`
//! explicit backward-p2 (`wc(...)` = concatenated call), `flush` /
//! `flushc` full flush, `flush@<k>` / `flushc@<k>` partial flush of
//! pending microbatches ≤ k, `opt` optimizer step.  `#` starts a
//! comment; blank lines are ignored.  Header keys may appear in any
//! order and anywhere in the file; a repeated key takes its last
//! value.  The one ordering rule: `ranks` must be declared before the
//! first `rank` line (it sizes the rank table) and may not change
//! afterwards.  The canonical form [`to_text`] emits lists all headers
//! first.
//!
//! **v2** adds one optional header carrying the layer→stage
//! [`Partition`](super::Partition) (docs/PLAN_FORMAT.md §v2):
//!
//! ```text
//! plan v2
//! ...
//! part dp 2 layers 0-2 3-3 4-6
//! ```
//!
//! `dp` is the data-parallel replication factor; each `a-b` is one
//! stage's **inclusive** layer range, one per rank, contiguous from
//! layer 0.  The parser accepts both magics; `part` is only legal
//! under `plan v2`.  A partition-less plan serializes as `plan v1`
//! byte-identically to before — v2 is emitted only when there is a
//! partition to carry — so every existing `.plan` artifact is stable.
//!
//! The parser is purely syntactic: it reconstructs a [`Plan`] and
//! leaves semantic checks (fwd-before-p1, p2 coverage, cross-rank
//! order consistency, ...) to [`super::validate::validate`], exactly as
//! for generator-built plans.  [`parse`] ∘ [`to_text`] is the identity
//! on every `Plan` (enforced by a proptest below).

use super::{Op, Partition, Plan, ScheduleKind};

/// A parse failure, pointing at the 1-based source line.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PlanIoError {
    pub line: usize,
    pub msg: String,
}

impl std::fmt::Display for PlanIoError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "plan parse error at line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for PlanIoError {}

// ---------------------------------------------------------------------------
// Serializer
// ---------------------------------------------------------------------------

fn op_token(op: &Op, out: &mut String) {
    match op {
        Op::Fwd { mb } => {
            out.push('f');
            out.push_str(&mb.to_string());
        }
        Op::BwdP1 { mb } => {
            out.push('b');
            out.push_str(&mb.to_string());
        }
        Op::BwdP2 { mbs, concat } => {
            out.push_str(if *concat { "wc(" } else { "w(" });
            for (i, mb) in mbs.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                out.push_str(&mb.to_string());
            }
            out.push(')');
        }
        Op::Flush { upto, concat } => {
            out.push_str(if *concat { "flushc" } else { "flush" });
            if let Some(u) = upto {
                out.push('@');
                out.push_str(&u.to_string());
            }
        }
        Op::OptStep => out.push_str("opt"),
    }
}

/// Serialize a plan to its canonical text form: `plan v1`
/// byte-identical to the pre-partition serializer when the plan has no
/// partition, `plan v2` with one `part` header when it does.
pub fn to_text(plan: &Plan) -> String {
    let mut out = String::with_capacity(64 + plan.total_ops() * 4);
    out.push_str("# twobp plan file — docs/PLAN_FORMAT.md\n");
    out.push_str(if plan.partition.is_some() {
        "plan v2\n"
    } else {
        "plan v1\n"
    });
    out.push_str(&format!("kind {}\n", plan.kind.name()));
    out.push_str(&format!("two_bp {}\n", plan.two_bp));
    out.push_str(&format!("ranks {}\n", plan.n_ranks));
    out.push_str(&format!("microbatches {}\n", plan.n_microbatches));
    out.push_str(&format!("greedy_p2 {}\n", plan.greedy_p2));
    if let Some(part) = &plan.partition {
        out.push_str(&format!("part dp {} layers", part.dp));
        for s in 0..part.n_stages() {
            let r = part.layers(s);
            out.push_str(&format!(" {}-{}", r.start, r.end - 1));
        }
        out.push('\n');
    }
    for (r, ops) in plan.ranks.iter().enumerate() {
        out.push_str(&format!("rank {r} |"));
        for op in ops {
            out.push(' ');
            op_token(op, &mut out);
        }
        out.push('\n');
    }
    out
}

// ---------------------------------------------------------------------------
// Parser
// ---------------------------------------------------------------------------

fn parse_u32(s: &str, line: usize, what: &str) -> Result<u32, PlanIoError> {
    s.parse::<u32>().map_err(|_| PlanIoError {
        line,
        msg: format!("{what}: '{s}' is not a non-negative integer"),
    })
}

fn parse_bool(s: &str, line: usize, key: &str) -> Result<bool, PlanIoError> {
    match s {
        "true" => Ok(true),
        "false" => Ok(false),
        _ => Err(PlanIoError {
            line,
            msg: format!("{key}: expected 'true' or 'false', got '{s}'"),
        }),
    }
}

fn parse_op(tok: &str, line: usize) -> Result<Op, PlanIoError> {
    let err = |msg: String| PlanIoError { line, msg };
    if tok == "opt" {
        return Ok(Op::OptStep);
    }
    if let Some(rest) = tok.strip_prefix("flushc") {
        return Ok(Op::Flush {
            upto: match rest.strip_prefix('@') {
                Some(k) => Some(parse_u32(k, line, "flushc@")?),
                None if rest.is_empty() => None,
                None => return Err(err(format!("bad op token '{tok}'"))),
            },
            concat: true,
        });
    }
    if let Some(rest) = tok.strip_prefix("flush") {
        return Ok(Op::Flush {
            upto: match rest.strip_prefix('@') {
                Some(k) => Some(parse_u32(k, line, "flush@")?),
                None if rest.is_empty() => None,
                None => return Err(err(format!("bad op token '{tok}'"))),
            },
            concat: false,
        });
    }
    if let Some(rest) = tok.strip_prefix('f') {
        return Ok(Op::Fwd { mb: parse_u32(rest, line, "f")? });
    }
    if let Some(rest) = tok.strip_prefix('b') {
        return Ok(Op::BwdP1 { mb: parse_u32(rest, line, "b")? });
    }
    for (prefix, concat) in [("wc(", true), ("w(", false)] {
        if let Some(rest) = tok.strip_prefix(prefix) {
            let inner = rest.strip_suffix(')').ok_or_else(|| {
                err(format!("'{tok}' is missing the closing ')'"))
            })?;
            if inner.is_empty() {
                return Err(err(format!(
                    "'{tok}': backward-p2 needs at least one microbatch"
                )));
            }
            let mbs = inner
                .split(',')
                .map(|m| parse_u32(m, line, "w()"))
                .collect::<Result<Vec<u32>, _>>()?;
            return Ok(Op::BwdP2 { mbs, concat });
        }
    }
    Err(err(format!(
        "unknown op token '{tok}' \
         (expected f<N>, b<N>, w(..), wc(..), flush[c][@N], or opt)"
    )))
}

/// Parse the v2 `part` header payload:
/// `dp <k> layers <a-b> <a-b> ...` with inclusive per-stage layer
/// ranges, contiguous from layer 0 (one range per rank — that count is
/// checked against `ranks` at end of file, not here).
fn parse_part(rest: &str, line: usize) -> Result<Partition, PlanIoError> {
    let err = |msg: String| PlanIoError { line, msg };
    let mut toks = rest.split_whitespace();
    if toks.next() != Some("dp") {
        return Err(err(
            "part header needs the form \
             'part dp <k> layers <a-b> ...'"
                .into(),
        ));
    }
    let dp = toks
        .next()
        .ok_or_else(|| err("part: missing dp value".into()))
        .and_then(|s| parse_u32(s, line, "part dp"))?;
    if dp == 0 {
        return Err(err("part: dp must be >= 1".into()));
    }
    if toks.next() != Some("layers") {
        return Err(err(
            "part: expected 'layers' after the dp value".into(),
        ));
    }
    let mut cuts = vec![0usize];
    for tok in toks {
        let (a, b) = tok.split_once('-').ok_or_else(|| {
            err(format!("part: bad layer range '{tok}' (expected a-b)"))
        })?;
        let a = parse_u32(a, line, "part layer range")? as usize;
        let b = parse_u32(b, line, "part layer range")? as usize;
        if b < a {
            return Err(err(format!(
                "part: layer range '{tok}' is backwards"
            )));
        }
        let prev = *cuts.last().expect("cuts starts non-empty");
        if a != prev {
            return Err(err(format!(
                "part: layer ranges must be contiguous from 0 \
                 (expected the next range to start at {prev}, got {a})"
            )));
        }
        cuts.push(b + 1);
    }
    if cuts.len() < 2 {
        return Err(err(
            "part: needs at least one layer range".into(),
        ));
    }
    Ok(Partition { cuts, dp })
}

/// Parse the text form back into a [`Plan`].  Inverse of [`to_text`];
/// also accepts extra whitespace, `#` comments, and header keys in any
/// order.  Semantic validity is *not* checked here — run the result
/// through [`super::validate::validate`].
pub fn parse(text: &str) -> Result<Plan, PlanIoError> {
    let mut kind: Option<ScheduleKind> = None;
    let mut two_bp: Option<bool> = None;
    let mut n_ranks: Option<usize> = None;
    let mut n_microbatches: Option<usize> = None;
    let mut greedy_p2: Option<bool> = None;
    let mut partition: Option<Partition> = None;
    let mut ranks: Vec<Option<Vec<Op>>> = Vec::new();
    let mut saw_magic = false;
    let mut v2 = false;

    for (i, raw) in text.lines().enumerate() {
        let lineno = i + 1;
        let err = |msg: String| PlanIoError { line: lineno, msg };
        let line = match raw.find('#') {
            Some(pos) => &raw[..pos],
            None => raw,
        }
        .trim();
        if line.is_empty() {
            continue;
        }
        if !saw_magic {
            match line {
                "plan v1" => {}
                "plan v2" => v2 = true,
                _ => {
                    return Err(err(format!(
                        "expected header 'plan v1' or 'plan v2', \
                         got '{line}'"
                    )))
                }
            }
            saw_magic = true;
            continue;
        }
        let (key, rest) = match line.split_once(char::is_whitespace) {
            Some((k, r)) => (k, r.trim()),
            None => (line, ""),
        };
        match key {
            "kind" => {
                kind = Some(
                    ScheduleKind::parse(rest)
                        .map_err(|e| err(e.to_string()))?,
                );
            }
            "two_bp" => two_bp = Some(parse_bool(rest, lineno, "two_bp")?),
            "greedy_p2" => {
                greedy_p2 = Some(parse_bool(rest, lineno, "greedy_p2")?)
            }
            "ranks" => {
                let n = parse_u32(rest, lineno, "ranks")? as usize;
                if n == 0 {
                    return Err(err("ranks must be >= 1".into()));
                }
                // the rank-line table is sized off the first value; a
                // conflicting re-declaration would desync them
                if !ranks.is_empty() && n != ranks.len() {
                    return Err(err(
                        "'ranks' re-declared after rank lines".into(),
                    ));
                }
                n_ranks = Some(n);
            }
            "microbatches" => {
                let m = parse_u32(rest, lineno, "microbatches")? as usize;
                if m == 0 {
                    return Err(err("microbatches must be >= 1".into()));
                }
                n_microbatches = Some(m);
            }
            "part" => {
                if !v2 {
                    return Err(err(
                        "'part' is a v2 header; declare 'plan v2'".into(),
                    ));
                }
                partition = Some(parse_part(rest, lineno)?);
            }
            "rank" => {
                let n = n_ranks.ok_or_else(|| {
                    err("'ranks' must be declared before rank lines".into())
                })?;
                if ranks.is_empty() {
                    ranks = vec![None; n];
                }
                let (r_str, ops_str) = rest.split_once('|').ok_or_else(|| {
                    err("rank line needs the form 'rank <r> | <ops>'".into())
                })?;
                let r = parse_u32(r_str.trim(), lineno, "rank")? as usize;
                if r >= n {
                    return Err(err(format!(
                        "rank {r} out of range (ranks = {n})"
                    )));
                }
                if ranks[r].is_some() {
                    return Err(err(format!("rank {r} listed twice")));
                }
                let ops = ops_str
                    .split_whitespace()
                    .map(|tok| parse_op(tok, lineno))
                    .collect::<Result<Vec<Op>, _>>()?;
                ranks[r] = Some(ops);
            }
            other => {
                return Err(err(format!("unknown header key '{other}'")));
            }
        }
    }

    let at_end = |msg: &str| PlanIoError {
        line: text.lines().count(),
        msg: msg.to_string(),
    };
    if !saw_magic {
        return Err(at_end("empty plan file (missing 'plan v1' header)"));
    }
    let kind = kind.ok_or_else(|| at_end("missing 'kind' header"))?;
    let two_bp = two_bp.ok_or_else(|| at_end("missing 'two_bp' header"))?;
    let n_ranks = n_ranks.ok_or_else(|| at_end("missing 'ranks' header"))?;
    let n_microbatches = n_microbatches
        .ok_or_else(|| at_end("missing 'microbatches' header"))?;
    let greedy_p2 =
        greedy_p2.ok_or_else(|| at_end("missing 'greedy_p2' header"))?;
    if ranks.is_empty() {
        ranks = vec![None; n_ranks];
    }
    let ranks = ranks
        .into_iter()
        .enumerate()
        .map(|(r, ops)| {
            ops.ok_or_else(|| at_end(&format!("missing 'rank {r}' line")))
        })
        .collect::<Result<Vec<Vec<Op>>, _>>()?;
    if let Some(part) = &partition {
        if part.n_stages() != n_ranks {
            return Err(at_end(&format!(
                "part header lists {} layer ranges but the plan has \
                 {} ranks (one range per rank)",
                part.n_stages(),
                n_ranks
            )));
        }
        part.check().map_err(|e| at_end(&format!("part: {e}")))?;
    }

    Ok(Plan {
        kind,
        two_bp,
        n_ranks,
        n_microbatches,
        ranks,
        greedy_p2,
        partition,
    })
}

#[cfg(test)]
mod tests {
    use super::super::{generate, validate::validate};
    use super::*;
    use crate::util::proptest::{check, gen};

    fn sample() -> Plan {
        generate(ScheduleKind::OneF1B1, true, 2, 2, false)
    }

    #[test]
    fn round_trips_a_generated_plan() {
        let plan = sample();
        let text = to_text(&plan);
        let back = parse(&text).unwrap();
        assert_eq!(plan, back);
    }

    #[test]
    fn parses_the_documented_example() {
        let text = "\
# hand-written
plan v1
kind 1f1b-1
two_bp true
ranks 2
microbatches 2
greedy_p2 true
rank 0 | f0 f1 b0 b1 flush opt
rank 1 | f0 b0 f1 b1 flush opt
";
        let plan = parse(text).unwrap();
        assert_eq!(plan.kind, ScheduleKind::OneF1B1);
        assert_eq!(plan.n_ranks, 2);
        assert_eq!(plan.ranks[1][0], Op::Fwd { mb: 0 });
        assert_eq!(plan.ranks[1][1], Op::BwdP1 { mb: 0 });
        validate(&plan).unwrap();
    }

    #[test]
    fn parses_every_op_token_form() {
        let text = "\
plan v1
kind gpipe
two_bp false
ranks 1
microbatches 4
greedy_p2 false
rank 0 | f0 f1 f2 f3 b3 w(3) b2 wc(2) b1 b0 flush@1 flushc opt
";
        let plan = parse(text).unwrap();
        let ops = &plan.ranks[0];
        assert_eq!(ops[5], Op::BwdP2 { mbs: vec![3], concat: false });
        assert_eq!(ops[7], Op::BwdP2 { mbs: vec![2], concat: true });
        assert_eq!(ops[10], Op::Flush { upto: Some(1), concat: false });
        assert_eq!(ops[11], Op::Flush { upto: None, concat: true });
        assert_eq!(ops[12], Op::OptStep);
        validate(&plan).unwrap();
        // and the canonical form round-trips
        assert_eq!(parse(&to_text(&plan)).unwrap(), plan);
    }

    #[test]
    fn tolerates_comments_blank_lines_and_header_order() {
        let text = "\

# leading comment
plan v1
microbatches 1   # trailing comment
ranks 1
greedy_p2 false
kind naive
two_bp false

rank 0 | f0 b0 w(0) opt
";
        let plan = parse(text).unwrap();
        validate(&plan).unwrap();
        assert_eq!(plan.n_microbatches, 1);
    }

    #[test]
    fn rejects_malformed_inputs() {
        let cases: &[(&str, &str)] = &[
            ("", "plan v1"),
            ("plan v9\n", "plan v1' or 'plan v2"),
            ("plan v1\nkind zigzag\n", "unknown schedule"),
            ("plan v1\nbogus 3\n", "unknown header key"),
            ("plan v1\nrank 0 | opt\n", "'ranks' must be declared"),
            (
                "plan v1\nkind naive\ntwo_bp false\nranks 1\n\
                 microbatches 1\ngreedy_p2 false\nrank 0 | zap\n",
                "unknown op token",
            ),
            (
                "plan v1\nkind naive\ntwo_bp false\nranks 1\n\
                 microbatches 1\ngreedy_p2 false\nrank 0 | w()\n",
                "at least one microbatch",
            ),
            (
                "plan v1\nkind naive\ntwo_bp false\nranks 1\n\
                 microbatches 1\ngreedy_p2 false\nrank 0 | w(1\n",
                "closing ')'",
            ),
            (
                "plan v1\nkind naive\ntwo_bp false\nranks 1\n\
                 microbatches 1\ngreedy_p2 false\n\
                 rank 0 | opt\nrank 0 | opt\n",
                "listed twice",
            ),
            (
                "plan v1\nkind naive\ntwo_bp false\nranks 2\n\
                 microbatches 1\ngreedy_p2 false\nrank 0 | f0 b0 w(0) opt\n",
                "missing 'rank 1'",
            ),
            (
                "plan v1\nkind naive\ntwo_bp false\nranks 1\n\
                 microbatches 1\ngreedy_p2 false\nrank 7 | opt\n",
                "out of range",
            ),
            (
                "plan v1\nkind naive\ntwo_bp false\nranks 1\n\
                 microbatches 1\ngreedy_p2 false\nrank 0 | f0 b0 w(0) opt\n\
                 ranks 3\nrank 2 | opt\n",
                "re-declared",
            ),
            (
                "plan v1\nkind naive\ntwo_bp false\nranks 1\n\
                 microbatches 1\n",
                "missing 'greedy_p2'",
            ),
            // -- v2 / part header -----------------------------------------
            (
                "plan v1\nkind naive\ntwo_bp false\nranks 1\n\
                 microbatches 1\ngreedy_p2 false\n\
                 part dp 1 layers 0-0\nrank 0 | f0 b0 w(0) opt\n",
                "'part' is a v2 header",
            ),
            (
                "plan v2\nkind naive\ntwo_bp false\nranks 1\n\
                 microbatches 1\ngreedy_p2 false\n\
                 part dp 1 layers 0-1 3-4\nrank 0 | f0 b0 w(0) opt\n",
                "contiguous from 0",
            ),
            (
                "plan v2\nkind naive\ntwo_bp false\nranks 1\n\
                 microbatches 1\ngreedy_p2 false\n\
                 part dp 1 layers 2-1\nrank 0 | f0 b0 w(0) opt\n",
                "is backwards",
            ),
            (
                "plan v2\nkind naive\ntwo_bp false\nranks 1\n\
                 microbatches 1\ngreedy_p2 false\n\
                 part dp 0 layers 0-0\nrank 0 | f0 b0 w(0) opt\n",
                "dp must be >= 1",
            ),
            (
                "plan v2\nkind naive\ntwo_bp false\nranks 1\n\
                 microbatches 1\ngreedy_p2 false\n\
                 part dp 1 layers\nrank 0 | f0 b0 w(0) opt\n",
                "at least one layer range",
            ),
            (
                "plan v2\nkind naive\ntwo_bp false\nranks 1\n\
                 microbatches 1\ngreedy_p2 false\n\
                 part layers 0-0\nrank 0 | f0 b0 w(0) opt\n",
                "part dp <k> layers",
            ),
            (
                "plan v2\nkind naive\ntwo_bp false\nranks 1\n\
                 microbatches 1\ngreedy_p2 false\n\
                 part dp 1 layers 0-0 1-1\nrank 0 | f0 b0 w(0) opt\n",
                "one range per rank",
            ),
        ];
        for (text, want) in cases {
            match parse(text) {
                Ok(_) => panic!("parse accepted: {text:?}"),
                Err(e) => assert!(
                    e.to_string().contains(want),
                    "error {e} does not mention '{want}' for {text:?}"
                ),
            }
        }
    }

    #[test]
    fn parses_the_documented_v2_example() {
        let text = "\
plan v2
kind 1f1b-1
two_bp true
ranks 2
microbatches 2
greedy_p2 true
part dp 2 layers 0-2 3-6
rank 0 | f0 f1 b0 b1 flush opt
rank 1 | f0 b0 f1 b1 flush opt
";
        let plan = parse(text).unwrap();
        let part = plan.partition.as_ref().expect("v2 part header kept");
        assert_eq!(part.dp, 2);
        assert_eq!(part.cuts, vec![0, 3, 7]);
        assert_eq!(part.layers(0), 0..3);
        assert_eq!(part.layers(1), 3..7);
        validate(&plan).unwrap();
        // canonical re-emission keeps the v2 magic and the part header
        let text2 = to_text(&plan);
        assert!(text2.contains("plan v2\n"), "{text2}");
        assert!(text2.contains("part dp 2 layers 0-2 3-6\n"), "{text2}");
        assert_eq!(parse(&text2).unwrap(), plan);
    }

    #[test]
    fn v2_without_part_canonicalizes_to_v1() {
        // v2 magic is legal without a part header; the plan it builds
        // has no partition, so it re-serializes as (byte-stable) v1.
        let mut text = to_text(&sample());
        text = text.replace("plan v1", "plan v2");
        let plan = parse(&text).unwrap();
        assert!(plan.partition.is_none());
        assert_eq!(plan, sample());
        assert!(to_text(&plan).contains("plan v1\n"));
    }

    #[test]
    fn partitioned_plan_round_trips() {
        let mut plan = sample();
        plan.partition = Some(Partition { cuts: vec![0, 3, 7], dp: 4 });
        let text = to_text(&plan);
        assert_eq!(parse(&text).unwrap(), plan);
    }

    #[test]
    fn error_reports_line_numbers() {
        let text = "plan v1\nkind naive\ntwo_bp maybe\n";
        let e = parse(text).unwrap_err();
        assert_eq!(e.line, 3);
    }

    /// Satellite: `Plan → text → Plan` is bit-identical for fuzzed
    /// generator plans, and the serialized text is accepted by both the
    /// parser and the validator.
    #[test]
    fn prop_dsl_round_trip_is_identity() {
        check(
            "plan DSL round-trips generator plans exactly",
            300,
            |rng| {
                let kind = *gen::pick(rng, &ScheduleKind::all_variants());
                let two_bp = if kind == ScheduleKind::OneF1B2EagerP2 {
                    true
                } else {
                    gen::bool(rng)
                };
                let n = gen::usize_in(rng, 1, 10);
                let m = gen::usize_in(rng, 1, 20);
                let concat = gen::bool(rng);
                // half the plans carry a v2 partition: n stages over a
                // random layer count >= n, random dp
                let part = if gen::bool(rng) {
                    let layers = gen::usize_in(rng, n, 3 * n);
                    let dp = gen::usize_in(rng, 1, 4) as u32;
                    Some((layers, dp))
                } else {
                    None
                };
                (kind, two_bp, n, m, concat, part)
            },
            |&(kind, two_bp, n, m, concat, part)| {
                let mut plan = generate(kind, two_bp, n, m, concat);
                plan.partition =
                    part.map(|(l, dp)| Partition::balanced(l, n, dp));
                let text = to_text(&plan);
                let back = parse(&text)
                    .map_err(|e| format!("parse failed: {e}\n{text}"))?;
                if back != plan {
                    return Err(format!("round-trip drifted:\n{text}"));
                }
                validate(&back).map_err(|e| {
                    format!("parsed plan failed validation: {e}")
                })?;
                Ok(())
            },
        );
    }
}
