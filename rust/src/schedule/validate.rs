//! Static plan validation — the safety net under every schedule the
//! generators (or a future custom schedule) produce.
//!
//! Checks, per rank:
//!   1. every microbatch is forwarded exactly once and p1'd exactly once;
//!   2. p1(mb) comes after fwd(mb);
//!   3. explicit p2 coverage: each mb's p2 runs at most once, always
//!      after its p1; with greedy/Flush plans, a trailing Flush covers
//!      the remainder (full-coverage check);
//!   3b. greedy-p2 plans carry no *explicit* `BwdP2` ops: the greedy
//!      fill may already have run any pending microbatch, so an
//!      explicit op could execute the same p2 twice (schedule p2 points
//!      in such plans with partial `Flush` instead);
//!   4. OptStep is last and appears exactly once;
//! and across ranks:
//!   5. all ranks agree on the microbatch set;
//!   6. forward order is identical on all ranks and backward order is
//!      identical on all ranks (FIFO-channel compatibility: with tagged
//!      receives this is not required for correctness, but plan-order
//!      consistency is what makes the schedules analyzable, so we insist).

use super::{Op, Plan};

#[derive(Debug, PartialEq, Eq)]
pub struct ValidationError {
    pub rank: usize,
    pub msg: String,
}

impl std::fmt::Display for ValidationError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "plan invalid at rank {}: {}", self.rank, self.msg)
    }
}

impl std::error::Error for ValidationError {}

/// Validate rank `r`'s op list in isolation — the per-rank invariants
/// 1–4 of the module docs (fwd/p1 exactly once and ordered, p2
/// coverage, no explicit `BwdP2` under greedy-p2, OptStep last).
/// Returns the rank's (forward order, backward order) for the
/// cross-rank checks in [`validate`].
///
/// This is also the planner's **incremental revalidation** primitive:
/// a local move that provably cannot change other ranks, the mb
/// multiset, or the per-kind cross-rank orders (see `planner::moves`
/// for the per-move argument) rechecks only the mutated rank through
/// this function instead of paying a full [`validate`] pass per
/// candidate.
pub fn validate_rank(
    plan: &Plan,
    r: usize,
) -> Result<(Vec<u32>, Vec<u32>), ValidationError> {
    let m = plan.n_microbatches as u32;
    let ops = &plan.ranks[r];
    let err = |msg: String| Err(ValidationError { rank: r, msg });
    let mut fwd_seen = vec![false; m as usize];
    let mut p1_seen = vec![false; m as usize];
    let mut p2_seen = vec![false; m as usize];
    let mut has_flush_all = false;
    let mut opt_seen = false;
    let mut fwd_order = Vec::new();
    let mut bwd_order = Vec::new();

    for (i, op) in ops.iter().enumerate() {
        if opt_seen {
            return err(format!("op after OptStep at index {i}"));
        }
        match op {
            Op::Fwd { mb } => {
                if *mb >= m {
                    return err(format!("Fwd mb {mb} out of range"));
                }
                if fwd_seen[*mb as usize] {
                    return err(format!("mb {mb} forwarded twice"));
                }
                fwd_seen[*mb as usize] = true;
                fwd_order.push(*mb);
            }
            Op::BwdP1 { mb } => {
                if *mb >= m || !fwd_seen[*mb as usize] {
                    return err(format!("BwdP1 mb {mb} before its Fwd"));
                }
                if p1_seen[*mb as usize] {
                    return err(format!("mb {mb} p1 twice"));
                }
                p1_seen[*mb as usize] = true;
                bwd_order.push(*mb);
            }
            Op::BwdP2 { mbs, .. } => {
                if plan.greedy_p2 {
                    return err(
                        "explicit BwdP2 in a greedy-p2 plan (the fill \
                         rule may already have run these microbatches; \
                         use a partial Flush instead)"
                            .into(),
                    );
                }
                for mb in mbs {
                    if *mb >= m || !p1_seen[*mb as usize] {
                        return err(format!("BwdP2 mb {mb} before its p1"));
                    }
                    if p2_seen[*mb as usize] {
                        return err(format!("mb {mb} p2 twice"));
                    }
                    p2_seen[*mb as usize] = true;
                }
            }
            Op::Flush { upto, .. } => {
                // flush covers pending (p1-done, p2-not-done) mbs
                for mb in 0..m {
                    let within =
                        upto.map(|u| mb <= u).unwrap_or(true);
                    if within && p1_seen[mb as usize]
                        && !p2_seen[mb as usize]
                    {
                        p2_seen[mb as usize] = true;
                    }
                }
                if upto.is_none() {
                    has_flush_all = true;
                }
            }
            Op::OptStep => {
                opt_seen = true;
            }
        }
    }

    if !opt_seen {
        return err("missing OptStep".into());
    }
    for mb in 0..m as usize {
        if !fwd_seen[mb] {
            return err(format!("mb {mb} never forwarded"));
        }
        if !p1_seen[mb] {
            return err(format!("mb {mb} never p1'd"));
        }
        if !p2_seen[mb] {
            return err(format!(
                "mb {mb} p2 never runs (and no covering Flush)"));
        }
    }
    if plan.greedy_p2 && !has_flush_all {
        return err("greedy_p2 plan lacks a full Flush".into());
    }
    Ok((fwd_order, bwd_order))
}

pub fn validate(plan: &Plan) -> Result<(), ValidationError> {
    if plan.ranks.len() != plan.n_ranks {
        return Err(ValidationError {
            rank: 0,
            msg: format!("{} rank lists for {} ranks",
                         plan.ranks.len(), plan.n_ranks),
        });
    }

    if let Some(part) = &plan.partition {
        if let Err(e) = part.check() {
            return Err(ValidationError {
                rank: 0,
                msg: format!("partition: {e}"),
            });
        }
        if part.n_stages() != plan.n_ranks {
            return Err(ValidationError {
                rank: 0,
                msg: format!(
                    "partition has {} stages for {} ranks",
                    part.n_stages(),
                    plan.n_ranks
                ),
            });
        }
    }

    let mut fwd_orders: Vec<Vec<u32>> = Vec::new();
    let mut bwd_orders: Vec<Vec<u32>> = Vec::new();

    for r in 0..plan.ranks.len() {
        let (fwd_order, bwd_order) = validate_rank(plan, r)?;
        fwd_orders.push(fwd_order);
        bwd_orders.push(bwd_order);
    }

    for r in 1..plan.n_ranks {
        if fwd_orders[r] != fwd_orders[0] {
            return Err(ValidationError {
                rank: r,
                msg: "forward order differs from rank 0".into(),
            });
        }
        if bwd_orders[r] != bwd_orders[0] {
            return Err(ValidationError {
                rank: r,
                msg: "backward order differs from rank 0".into(),
            });
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::super::{generate, ScheduleKind};
    use super::*;
    use crate::util::proptest::{check, gen};

    #[test]
    fn all_generated_plans_validate() {
        for kind in ScheduleKind::all() {
            for two_bp in [false, true] {
                for n in [1, 2, 3, 4, 8] {
                    for m_mult in [1, 2] {
                        let m = kind.default_microbatches(n) * m_mult;
                        let plan = generate(kind, two_bp, n, m, two_bp);
                        validate(&plan).unwrap_or_else(|e| {
                            panic!("{} 2bp={two_bp} n={n} m={m}: {e}",
                                   kind.name())
                        });
                    }
                }
            }
        }
    }

    #[test]
    fn eager_variant_validates() {
        let plan = generate(ScheduleKind::OneF1B2EagerP2, true, 4, 0, false);
        validate(&plan).unwrap();
    }

    #[test]
    fn rejects_missing_p2_coverage() {
        let mut plan = generate(ScheduleKind::GPipe, true, 2, 2, false);
        // drop the Flush on rank 1
        plan.ranks[1].retain(|op| !matches!(op, Op::Flush { .. }));
        assert!(validate(&plan).is_err());
    }

    #[test]
    fn rejects_p1_before_fwd() {
        let mut plan = generate(ScheduleKind::GPipe, false, 2, 2, false);
        plan.ranks[0].swap(0, 2); // move a BwdP1 before its Fwd
        assert!(validate(&plan).is_err());
    }

    #[test]
    fn rejects_double_p2() {
        let mut plan = generate(ScheduleKind::GPipe, false, 2, 2, false);
        plan.ranks[0].insert(4, Op::BwdP2 { mbs: vec![1], concat: false });
        assert!(validate(&plan).is_err());
    }

    #[test]
    fn rejects_explicit_p2_in_greedy_plan() {
        let mut plan = generate(ScheduleKind::GPipe, true, 2, 2, false);
        // a hand-built (DSL) plan could try to pair an explicit p2 with
        // the greedy fill — ambiguous, so the validator forbids it
        let pos = plan.ranks[0]
            .iter()
            .position(|op| matches!(op, Op::Flush { .. }))
            .unwrap();
        plan.ranks[0].insert(pos, Op::BwdP2 { mbs: vec![0], concat: false });
        let err = validate(&plan).unwrap_err();
        assert!(err.msg.contains("greedy-p2"), "{err}");
    }

    #[test]
    fn rejects_partition_stage_count_mismatch() {
        use super::super::Partition;
        let mut plan = generate(ScheduleKind::GPipe, true, 2, 2, false);
        plan.partition = Some(Partition::balanced(8, 2, 1));
        validate(&plan).unwrap();
        plan.partition = Some(Partition::balanced(8, 4, 1));
        let err = validate(&plan).unwrap_err();
        assert!(err.msg.contains("4 stages for 2 ranks"), "{err}");
        plan.partition = Some(Partition { cuts: vec![0, 2, 2], dp: 1 });
        let err = validate(&plan).unwrap_err();
        assert!(err.msg.contains("partition:"), "{err}");
    }

    #[test]
    fn rejects_op_after_optstep() {
        let mut plan = generate(ScheduleKind::Naive, false, 2, 1, false);
        plan.ranks[0].push(Op::Fwd { mb: 0 });
        assert!(validate(&plan).is_err());
    }

    #[test]
    fn prop_random_schedule_params_always_validate() {
        check(
            "generated plans validate for fuzzed (kind, 2bp, n, m)",
            200,
            |rng| {
                let kind = *gen::pick(rng, &ScheduleKind::all_variants());
                let two_bp = if kind == ScheduleKind::OneF1B2EagerP2 {
                    true
                } else {
                    gen::bool(rng)
                };
                let n = gen::usize_in(rng, 1, 12);
                let m = gen::usize_in(rng, 1, 24);
                (kind, two_bp, n, m)
            },
            |&(kind, two_bp, n, m)| {
                let plan = generate(kind, two_bp, n, m, two_bp);
                validate(&plan).map_err(|e| e.to_string())?;
                if plan.ranks.iter().any(|ops| ops.is_empty()) {
                    return Err("empty rank".into());
                }
                Ok(())
            },
        );
    }
}
