//! Plan generators for the paper's four schedules, ±2BP (Fig 1) and the
//! Fig 5 eager-p2 variant.
//!
//! Non-2BP semantics (classical autograd): backward is *fused* — the
//! input gradient is sent upstream only after both p1 and p2 complete.
//! This is the bottleneck the paper identifies: "current implementations
//! of pipeline parallelism are being unintentionally bottlenecked by the
//! automatic differentiation tools".  In plans this is encoded as
//! `BwdP1(mb)` immediately followed by `BwdP2([mb])`, and the executor /
//! simulator treat the pair as atomic (send-after-p2).
//!
//! 2BP semantics: `BwdP1` sends the input gradient immediately; p2 ops
//! are deferred (greedy fill + trailing `Flush`).

use super::{Op, Plan, ScheduleKind};

/// Generate a plan.  `n_microbatches` defaults (when 0) to the paper's
/// convention: M = N for Naive/GPipe/1F1B-1, M = 2N for 1F1B-2.
pub fn generate(
    kind: ScheduleKind,
    two_bp: bool,
    n_ranks: usize,
    n_microbatches: usize,
    concat_p2: bool,
) -> Plan {
    assert!(n_ranks >= 1, "need at least one pipeline rank");
    let m = if n_microbatches == 0 {
        kind.default_microbatches(n_ranks)
    } else {
        n_microbatches
    };
    let ranks = (0..n_ranks)
        .map(|r| rank_ops(kind, two_bp, n_ranks, m, r, concat_p2))
        .collect();
    Plan {
        kind,
        two_bp,
        n_ranks,
        n_microbatches: m,
        ranks,
        greedy_p2: two_bp,
        partition: None,
    }
}

fn fused_bwd(ops: &mut Vec<Op>, mb: u32, concat: bool) {
    ops.push(Op::BwdP1 { mb });
    ops.push(Op::BwdP2 { mbs: vec![mb], concat });
}

fn rank_ops(
    kind: ScheduleKind,
    two_bp: bool,
    n: usize,
    m: usize,
    _rank: usize,
    concat_p2: bool,
) -> Vec<Op> {
    let rank = _rank;
    // exact op count: m fwds + m p1s (+ m fused p2s), plus at most two
    // flushes and the opt step — pre-sized so the sweep hot path never
    // reallocates mid-generation
    let cap = m * if two_bp { 2 } else { 3 } + 3;
    let mut ops = Vec::with_capacity(cap);
    match kind {
        // -- naive: strictly sequential microbatches (gradient accumulation,
        //    as in the paper's ResNet naive runs) --------------------------
        ScheduleKind::Naive => {
            for mb in 0..m as u32 {
                ops.push(Op::Fwd { mb });
                if two_bp {
                    ops.push(Op::BwdP1 { mb });
                } else {
                    fused_bwd(&mut ops, mb, false);
                }
            }
        }

        // -- GPipe: all forwards, then all backwards (reverse mb order) ----
        ScheduleKind::GPipe => {
            for mb in 0..m as u32 {
                ops.push(Op::Fwd { mb });
            }
            for mb in (0..m as u32).rev() {
                if two_bp {
                    ops.push(Op::BwdP1 { mb });
                } else {
                    fused_bwd(&mut ops, mb, false);
                }
            }
        }

        // -- 1F1B (PipeDream-flush / Megatron): warmup, steady, cooldown ---
        ScheduleKind::OneF1B1 | ScheduleKind::OneF1B2
        | ScheduleKind::OneF1B2EagerP2 => {
            let warmup = (n - 1 - rank).min(m);
            let mut f: u32 = 0;
            let mut b: u32 = 0;
            for _ in 0..warmup {
                ops.push(Op::Fwd { mb: f });
                f += 1;
            }
            for _ in 0..(m - warmup) {
                ops.push(Op::Fwd { mb: f });
                f += 1;
                if two_bp {
                    ops.push(Op::BwdP1 { mb: b });
                } else {
                    fused_bwd(&mut ops, b, false);
                }
                b += 1;
            }
            for _ in 0..warmup {
                if two_bp {
                    ops.push(Op::BwdP1 { mb: b });
                } else {
                    fused_bwd(&mut ops, b, false);
                }
                b += 1;
            }
        }
    }

    // -- 2BP epilogue: flush deferred p2 work, then step ---------------------
    if two_bp {
        if kind == ScheduleKind::OneF1B2EagerP2 {
            // Fig 5: partial flush halfway through — cap the stash at ~M/2
            // microbatches of res2+inter.
            let half = (m / 2).max(1) as u32 - 1;
            insert_partial_flush(&mut ops, half, concat_p2);
        }
        ops.push(Op::Flush { upto: None, concat: concat_p2 });
    }
    ops.push(Op::OptStep);
    ops
}

/// Insert `Flush{upto}` right after `BwdP1(upto)` (Fig 5's mid-step p2
/// drain).  Returns whether it inserted — false when that p1 is not in
/// the list (e.g. m == 1, or an out-of-range flush point).  Shared with
/// the planner's seeding/mutation moves (re-exported from the parent
/// module) so generator and planner flush placement can never drift.
pub(crate) fn insert_partial_flush(
    ops: &mut Vec<Op>,
    upto: u32,
    concat: bool,
) -> bool {
    match ops
        .iter()
        .position(|op| matches!(op, Op::BwdP1 { mb } if *mb == upto))
    {
        Some(pos) => {
            ops.insert(pos + 1, Op::Flush { upto: Some(upto), concat });
            true
        }
        None => false,
    }
}

/// The microbatch indices at which the eager-p2 variant flushes (used by
/// benches to label Fig 5 output).
pub fn eager_p2_flush_points(m: usize) -> Vec<u32> {
    vec![(m / 2).max(1) as u32 - 1, m as u32 - 1]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn count_ops(ops: &[Op]) -> (usize, usize, usize) {
        let f = ops.iter().filter(|o| matches!(o, Op::Fwd { .. })).count();
        let p1 = ops.iter().filter(|o| matches!(o, Op::BwdP1 { .. })).count();
        let p2 = ops
            .iter()
            .map(|o| match o {
                Op::BwdP2 { mbs, .. } => mbs.len(),
                _ => 0,
            })
            .sum();
        (f, p1, p2)
    }

    #[test]
    fn default_microbatch_counts_follow_paper() {
        assert_eq!(generate(ScheduleKind::OneF1B1, false, 4, 0, false)
                       .n_microbatches, 4);
        assert_eq!(generate(ScheduleKind::OneF1B2, false, 4, 0, false)
                       .n_microbatches, 8);
    }

    #[test]
    fn non_2bp_pairs_p1_with_p2() {
        for kind in ScheduleKind::all() {
            let plan = generate(kind, false, 4, 0, false);
            for ops in &plan.ranks {
                let (f, p1, p2) = count_ops(ops);
                assert_eq!(f, plan.n_microbatches);
                assert_eq!(p1, plan.n_microbatches);
                assert_eq!(p2, plan.n_microbatches);
                // every BwdP1 immediately followed by its BwdP2
                for (i, op) in ops.iter().enumerate() {
                    if let Op::BwdP1 { mb } = op {
                        assert_eq!(ops[i + 1],
                                   Op::BwdP2 { mbs: vec![*mb], concat: false });
                    }
                }
                assert!(!plan.greedy_p2);
            }
        }
    }

    #[test]
    fn two_bp_defers_all_p2_to_flush() {
        for kind in ScheduleKind::all() {
            let plan = generate(kind, true, 4, 0, true);
            assert!(plan.greedy_p2);
            for ops in &plan.ranks {
                let (f, p1, p2) = count_ops(ops);
                assert_eq!(f, plan.n_microbatches);
                assert_eq!(p1, plan.n_microbatches);
                assert_eq!(p2, 0, "2BP plans carry no explicit BwdP2");
                assert!(matches!(ops[ops.len() - 2],
                                 Op::Flush { upto: None, .. }));
                assert!(matches!(ops[ops.len() - 1], Op::OptStep));
            }
        }
    }

    #[test]
    fn one_f1b_warmup_depth_decreases_with_rank() {
        let plan = generate(ScheduleKind::OneF1B1, true, 4, 0, false);
        // leading consecutive Fwds per rank = min(N-1-rank, M)
        for (r, ops) in plan.ranks.iter().enumerate() {
            let lead = ops.iter().take_while(|o| matches!(o, Op::Fwd { .. }))
                .count();
            // warmup fwds plus the first steady-state fwd
            let warmup = (4 - 1 - r).min(4);
            let expect = warmup + usize::from(warmup < 4);
            assert_eq!(lead, expect, "rank {r} lead {lead}");
        }
    }

    #[test]
    fn last_rank_alternates_1f1b() {
        let plan = generate(ScheduleKind::OneF1B1, false, 4, 0, false);
        let ops = &plan.ranks[3];
        assert!(matches!(ops[0], Op::Fwd { mb: 0 }));
        assert!(matches!(ops[1], Op::BwdP1 { mb: 0 }));
    }

    #[test]
    fn eager_variant_has_partial_flush() {
        let plan = generate(ScheduleKind::OneF1B2EagerP2, true, 4, 0, false);
        for ops in &plan.ranks {
            let partials = ops.iter().filter(
                |o| matches!(o, Op::Flush { upto: Some(_), .. })).count();
            assert_eq!(partials, 1);
        }
    }

    #[test]
    fn naive_is_strictly_sequential_per_rank() {
        let plan = generate(ScheduleKind::Naive, false, 3, 4, false);
        let ops = &plan.ranks[0];
        // F0 B0 F1 B1 ... (B = p1+p2 pair)
        assert!(matches!(ops[0], Op::Fwd { mb: 0 }));
        assert!(matches!(ops[1], Op::BwdP1 { mb: 0 }));
        assert!(matches!(ops[3], Op::Fwd { mb: 1 }));
    }
}
