//! Pipeline schedules — the paper's §3 contribution.
//!
//! A [`Plan`] is, per pipeline rank, an ordered op list.  The paper's
//! four schedules (Naive, GPipe, 1F1B-1, 1F1B-2) are generated with or
//! without the 2BP split:
//!
//! * **without 2BP** each `BwdP1(mb)` is immediately followed by
//!   `BwdP2([mb])` — the fused behaviour of a classical autograd engine;
//! * **with 2BP** the `BwdP2` ops are *deferred*: the plan enables
//!   greedy fill (`greedy_p2`) so the executor/simulator runs pending p2
//!   work whenever the rank would otherwise idle, and a trailing
//!   [`Op::Flush`] covers the remainder (optionally as one concatenated
//!   call — Fig 2).
//!
//! The Fig 5 *eager-p2* 1F1B-2 variant inserts a mid-step partial flush
//! to cap stash growth.

mod generators;
pub mod validate;

pub use generators::{eager_p2_flush_points, generate};

/// One operation in a rank's schedule.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Op {
    /// Forward a microbatch (implicitly: recv activation from rank-1,
    /// send result to rank+1; the last rank then computes the loss).
    Fwd { mb: u32 },
    /// Backward-p1 (input gradient) for a microbatch (implicitly: recv
    /// output-grad from rank+1, send input-grad to rank-1).
    BwdP1 { mb: u32 },
    /// Backward-p2 (weight gradient) for explicit microbatches.
    /// `concat`: single concatenated call vs per-mb loop (Fig 2/Table 3).
    BwdP2 { mbs: Vec<u32>, concat: bool },
    /// Run backward-p2 for every microbatch whose p1 is done but whose
    /// p2 hasn't run yet, restricted to `upto` lowest-numbered pending
    /// ones when given (Fig 5 partial flush).
    Flush { upto: Option<u32>, concat: bool },
    /// Optimizer step (after all p2 work of the training step).
    OptStep,
}

/// Which of the paper's schedules to generate.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScheduleKind {
    /// No micro-batch overlap at all: each microbatch traverses the whole
    /// pipeline before the next starts (the paper's "naive" baseline,
    /// realized as gradient accumulation as in its ResNet runs).
    Naive,
    /// GPipe: all forwards, then all backwards.
    GPipe,
    /// 1F1B with M = N microbatches (paper "1F1B-1").
    OneF1B1,
    /// 1F1B with M = 2N microbatches (paper "1F1B-2").
    OneF1B2,
    /// Fig 5: 1F1B-2 + 2BP with mid-step partial p2 flushes to cap the
    /// stash (only meaningful with `two_bp = true`).
    OneF1B2EagerP2,
}

impl ScheduleKind {
    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "naive" => ScheduleKind::Naive,
            "gpipe" => ScheduleKind::GPipe,
            "1f1b-1" | "1f1b1" => ScheduleKind::OneF1B1,
            "1f1b-2" | "1f1b2" => ScheduleKind::OneF1B2,
            "1f1b-2-eager" | "eager" => ScheduleKind::OneF1B2EagerP2,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            ScheduleKind::Naive => "naive",
            ScheduleKind::GPipe => "gpipe",
            ScheduleKind::OneF1B1 => "1f1b-1",
            ScheduleKind::OneF1B2 => "1f1b-2",
            ScheduleKind::OneF1B2EagerP2 => "1f1b-2-eager",
        }
    }

    /// The paper's default microbatch count for N pipeline ranks.
    pub fn default_microbatches(&self, n_ranks: usize) -> usize {
        match self {
            ScheduleKind::Naive | ScheduleKind::GPipe
            | ScheduleKind::OneF1B1 => n_ranks,
            ScheduleKind::OneF1B2 | ScheduleKind::OneF1B2EagerP2 => 2 * n_ranks,
        }
    }

    pub fn all() -> [ScheduleKind; 4] {
        [ScheduleKind::Naive, ScheduleKind::GPipe,
         ScheduleKind::OneF1B1, ScheduleKind::OneF1B2]
    }

    /// Every generator variant, including the Fig 5 eager-p2 one (which
    /// is only meaningful with `two_bp = true`).  The sweep grid and the
    /// fuzzers iterate this.
    pub fn all_variants() -> [ScheduleKind; 5] {
        [ScheduleKind::Naive, ScheduleKind::GPipe, ScheduleKind::OneF1B1,
         ScheduleKind::OneF1B2, ScheduleKind::OneF1B2EagerP2]
    }
}

/// A complete schedule for one training step.
#[derive(Debug, Clone)]
pub struct Plan {
    pub kind: ScheduleKind,
    pub two_bp: bool,
    pub n_ranks: usize,
    pub n_microbatches: usize,
    /// `ranks[r]` is the ordered op list for pipeline rank r.
    pub ranks: Vec<Vec<Op>>,
    /// With 2BP: the executor/simulator may run pending p2 work when the
    /// next op's inputs are not yet available (the paper's "fill idle
    /// time between backward-p1 calls with backward-p2 calls").
    pub greedy_p2: bool,
}

impl Plan {
    /// Total op count across all ranks (the event count a simulation
    /// dispatches; sweep throughput is often quoted per op).
    pub fn total_ops(&self) -> usize {
        self.ranks.iter().map(|ops| ops.len()).sum()
    }

    /// Human-readable one-line description, e.g. "1f1b-1+2bp (4 ranks × 4 mb)".
    pub fn describe(&self) -> String {
        format!(
            "{}{} ({} ranks × {} mb)",
            self.kind.name(),
            if self.two_bp { "+2bp" } else { "" },
            self.n_ranks,
            self.n_microbatches
        )
    }
}
