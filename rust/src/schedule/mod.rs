//! Pipeline schedules — the paper's §3 contribution.
//!
//! A [`Plan`] is, per pipeline rank, an ordered op list.  The paper's
//! four schedules (Naive, GPipe, 1F1B-1, 1F1B-2) are generated with or
//! without the 2BP split:
//!
//! * **without 2BP** each `BwdP1(mb)` is immediately followed by
//!   `BwdP2([mb])` — the fused behaviour of a classical autograd engine;
//! * **with 2BP** the `BwdP2` ops are *deferred*: the plan enables
//!   greedy fill (`greedy_p2`) so the executor/simulator runs pending p2
//!   work whenever the rank would otherwise idle, and a trailing
//!   [`Op::Flush`] covers the remainder (optionally as one concatenated
//!   call — Fig 2).
//!
//! The Fig 5 *eager-p2* 1F1B-2 variant inserts a mid-step partial flush
//! to cap stash growth.

mod generators;
pub mod partition;
pub mod plan_io;
pub mod validate;

pub use generators::{eager_p2_flush_points, generate};
pub(crate) use generators::insert_partial_flush;
pub use partition::Partition;

/// One operation in a rank's schedule.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Op {
    /// Forward a microbatch (implicitly: recv activation from rank-1,
    /// send result to rank+1; the last rank then computes the loss).
    Fwd { mb: u32 },
    /// Backward-p1 (input gradient) for a microbatch (implicitly: recv
    /// output-grad from rank+1, send input-grad to rank-1).
    BwdP1 { mb: u32 },
    /// Backward-p2 (weight gradient) for explicit microbatches.
    /// `concat`: single concatenated call vs per-mb loop (Fig 2/Table 3).
    BwdP2 { mbs: Vec<u32>, concat: bool },
    /// Run backward-p2 for every microbatch whose p1 is done but whose
    /// p2 hasn't run yet, restricted to `upto` lowest-numbered pending
    /// ones when given (Fig 5 partial flush).
    Flush { upto: Option<u32>, concat: bool },
    /// Optimizer step (after all p2 work of the training step).
    OptStep,
}

/// Which of the paper's schedules to generate.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScheduleKind {
    /// No micro-batch overlap at all: each microbatch traverses the whole
    /// pipeline before the next starts (the paper's "naive" baseline,
    /// realized as gradient accumulation as in its ResNet runs).
    Naive,
    /// GPipe: all forwards, then all backwards.
    GPipe,
    /// 1F1B with M = N microbatches (paper "1F1B-1").
    OneF1B1,
    /// 1F1B with M = 2N microbatches (paper "1F1B-2").
    OneF1B2,
    /// Fig 5: 1F1B-2 + 2BP with mid-step partial p2 flushes to cap the
    /// stash (only meaningful with `two_bp = true`).
    OneF1B2EagerP2,
}

/// `ScheduleKind::parse` failure: carries the rejected input and lists
/// every accepted name, so CLI/DSL errors are self-explanatory.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParseScheduleKindError {
    pub input: String,
}

impl std::fmt::Display for ParseScheduleKindError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "unknown schedule '{}' (valid: {})",
            self.input,
            ScheduleKind::VALID_NAMES.join(", ")
        )
    }
}

impl std::error::Error for ParseScheduleKindError {}

impl std::str::FromStr for ScheduleKind {
    type Err = ParseScheduleKindError;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        ScheduleKind::parse(s)
    }
}

impl ScheduleKind {
    /// Every name [`ScheduleKind::parse`] accepts (canonical name first
    /// per kind; the error message and docs quote this list).
    pub const VALID_NAMES: [&'static str; 8] = [
        "naive", "gpipe", "1f1b-1", "1f1b1", "1f1b-2", "1f1b2",
        "1f1b-2-eager", "eager",
    ];

    pub fn parse(s: &str) -> Result<Self, ParseScheduleKindError> {
        Ok(match s {
            "naive" => ScheduleKind::Naive,
            "gpipe" => ScheduleKind::GPipe,
            "1f1b-1" | "1f1b1" => ScheduleKind::OneF1B1,
            "1f1b-2" | "1f1b2" => ScheduleKind::OneF1B2,
            "1f1b-2-eager" | "eager" => ScheduleKind::OneF1B2EagerP2,
            _ => return Err(ParseScheduleKindError { input: s.to_string() }),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            ScheduleKind::Naive => "naive",
            ScheduleKind::GPipe => "gpipe",
            ScheduleKind::OneF1B1 => "1f1b-1",
            ScheduleKind::OneF1B2 => "1f1b-2",
            ScheduleKind::OneF1B2EagerP2 => "1f1b-2-eager",
        }
    }

    /// The paper's default microbatch count for N pipeline ranks.
    pub fn default_microbatches(&self, n_ranks: usize) -> usize {
        match self {
            ScheduleKind::Naive | ScheduleKind::GPipe
            | ScheduleKind::OneF1B1 => n_ranks,
            ScheduleKind::OneF1B2 | ScheduleKind::OneF1B2EagerP2 => 2 * n_ranks,
        }
    }

    pub fn all() -> [ScheduleKind; 4] {
        [ScheduleKind::Naive, ScheduleKind::GPipe,
         ScheduleKind::OneF1B1, ScheduleKind::OneF1B2]
    }

    /// Every generator variant, including the Fig 5 eager-p2 one (which
    /// is only meaningful with `two_bp = true`).  The sweep grid and the
    /// fuzzers iterate this.
    pub fn all_variants() -> [ScheduleKind; 5] {
        [ScheduleKind::Naive, ScheduleKind::GPipe, ScheduleKind::OneF1B1,
         ScheduleKind::OneF1B2, ScheduleKind::OneF1B2EagerP2]
    }
}

/// A complete schedule for one training step.
///
/// `PartialEq` compares every field (the DSL round-trip property in
/// [`plan_io`] relies on exact equality).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Plan {
    pub kind: ScheduleKind,
    pub two_bp: bool,
    pub n_ranks: usize,
    pub n_microbatches: usize,
    /// `ranks[r]` is the ordered op list for pipeline rank r.
    pub ranks: Vec<Vec<Op>>,
    /// With 2BP: the executor/simulator may run pending p2 work when the
    /// next op's inputs are not yet available (the paper's "fill idle
    /// time between backward-p1 calls with backward-p2 calls").
    pub greedy_p2: bool,
    /// Which model layers each stage owns, plus the DP replication
    /// factor (`None` = the classic "stage s is layer s" world; every
    /// DSL v1 plan and pre-partition fingerprint is unchanged).
    pub partition: Option<Partition>,
}

impl Plan {
    /// Total op count across all ranks (the event count a simulation
    /// dispatches; sweep throughput is often quoted per op).
    pub fn total_ops(&self) -> usize {
        self.ranks.iter().map(|ops| ops.len()).sum()
    }

    /// Stable 64-bit structural fingerprint: FNV-1a over an injective
    /// encoding of every field (kind, flags, shape, and each rank's op
    /// list).  Two plans have equal fingerprints iff they are equal —
    /// up to 64-bit hash collisions, which at planner pool sizes
    /// (thousands of candidates, birthday bound ≈ k²/2⁶⁵) are
    /// negligible.  The value is independent of process, platform, and
    /// Rust version, so it can be persisted or compared across runs.
    ///
    /// This is the planner's dedup / pool key: hashing a plan costs one
    /// pass over its ops, where the previous text key paid a full DSL
    /// serialization plus a heap-allocated `String` per candidate.
    pub fn fingerprint(&self) -> u64 {
        const PRIME: u64 = 0x0000_0100_0000_01b3;
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        let mut mix = |x: u64| {
            for b in x.to_le_bytes() {
                h = (h ^ b as u64).wrapping_mul(PRIME);
            }
        };
        mix(match self.kind {
            ScheduleKind::Naive => 0,
            ScheduleKind::GPipe => 1,
            ScheduleKind::OneF1B1 => 2,
            ScheduleKind::OneF1B2 => 3,
            ScheduleKind::OneF1B2EagerP2 => 4,
        });
        mix(self.two_bp as u64 | (self.greedy_p2 as u64) << 1);
        mix(self.n_ranks as u64);
        mix(self.n_microbatches as u64);
        for ops in &self.ranks {
            // length prefixes keep the encoding injective across rank
            // and mbs-list boundaries
            mix(ops.len() as u64);
            for op in ops {
                match op {
                    Op::Fwd { mb } => {
                        mix(1);
                        mix(*mb as u64);
                    }
                    Op::BwdP1 { mb } => {
                        mix(2);
                        mix(*mb as u64);
                    }
                    Op::BwdP2 { mbs, concat } => {
                        mix(3 | (*concat as u64) << 8);
                        mix(mbs.len() as u64);
                        for mb in mbs {
                            mix(*mb as u64);
                        }
                    }
                    Op::Flush { upto, concat } => {
                        mix(4 | (*concat as u64) << 8);
                        mix(upto.map(|u| u as u64 + 1).unwrap_or(0));
                    }
                    Op::OptStep => mix(5),
                }
            }
        }
        // a partition-less plan mixes NOTHING here, so every fingerprint
        // persisted before partitions existed is unchanged; a tagged,
        // length-prefixed suffix keeps Some-vs-None and every (dp, cuts)
        // shape injective (domain separation tested below)
        if let Some(p) = &self.partition {
            mix(6);
            mix(p.dp as u64);
            mix(p.cuts.len() as u64);
            for &c in &p.cuts {
                mix(c as u64);
            }
        }
        h
    }

    /// Human-readable one-line description, e.g. "1f1b-1+2bp (4 ranks × 4 mb)".
    pub fn describe(&self) -> String {
        format!(
            "{}{} ({} ranks × {} mb)",
            self.kind.name(),
            if self.two_bp { "+2bp" } else { "" },
            self.n_ranks,
            self.n_microbatches
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrips_canonical_names() {
        for kind in ScheduleKind::all_variants() {
            assert_eq!(ScheduleKind::parse(kind.name()), Ok(kind));
        }
    }

    /// Fingerprint ↔ plan-identity: across the whole generator space
    /// (plus concat and flag variations), distinct plans get distinct
    /// fingerprints and equal plans hash equal — the property the
    /// planner's hash-keyed dedup rests on.
    #[test]
    fn fingerprint_separates_generator_space() {
        use std::collections::BTreeMap;
        let mut by_fp: BTreeMap<u64, Plan> = BTreeMap::new();
        let mut count = 0usize;
        for kind in ScheduleKind::all_variants() {
            for two_bp in [false, true] {
                for n in [1usize, 2, 3, 4] {
                    for m in [1usize, 2, 4, 7] {
                        for concat in [false, true] {
                            let p = generate(kind, two_bp, n, m, concat);
                            assert_eq!(p.fingerprint(), p.fingerprint());
                            assert_eq!(p.clone().fingerprint(),
                                       p.fingerprint());
                            match by_fp.get(&p.fingerprint()) {
                                Some(q) => assert_eq!(
                                    *q, p,
                                    "fingerprint collision between \
                                     distinct plans"
                                ),
                                None => {
                                    by_fp.insert(p.fingerprint(), p);
                                }
                            }
                            count += 1;
                        }
                    }
                }
            }
        }
        // sanity: the space is non-trivial and mostly distinct plans
        assert!(count >= 100 && by_fp.len() > count / 2);
    }

    /// The fingerprint covers every field the plan DSL serializes: any
    /// single-field change moves the hash.
    #[test]
    fn fingerprint_tracks_every_field() {
        let base = generate(ScheduleKind::OneF1B1, true, 2, 4, false);
        let fp = base.fingerprint();
        let mut kind = base.clone();
        kind.kind = ScheduleKind::GPipe;
        assert_ne!(kind.fingerprint(), fp, "kind label ignored");
        let mut flag = base.clone();
        flag.greedy_p2 = false;
        assert_ne!(flag.fingerprint(), fp, "greedy_p2 ignored");
        let mut ops = base.clone();
        if let Some(Op::Flush { concat, .. }) = ops.ranks[0]
            .iter_mut()
            .find(|op| matches!(op, Op::Flush { .. }))
        {
            *concat = true;
        }
        assert_ne!(ops.fingerprint(), fp, "flush concat ignored");
        let mut swapped = base.clone();
        swapped.ranks[0].swap(0, 1);
        assert_ne!(swapped.fingerprint(), fp, "op order ignored");
    }

    /// Domain separation for the partition suffix: plans differing
    /// only in partition presence, cut placement, or DP factor never
    /// collide — and attaching no partition reproduces the
    /// pre-partition fingerprint bit-for-bit.
    #[test]
    fn fingerprint_separates_partitions() {
        use std::collections::BTreeSet;
        let base = generate(ScheduleKind::OneF1B1, true, 4, 4, false);
        assert_eq!(base.partition, None);
        let fp_none = base.fingerprint();
        let mut seen: BTreeSet<u64> = BTreeSet::new();
        seen.insert(fp_none);
        let parts = [
            Partition::trivial(4),
            Partition::balanced(8, 4, 1),
            Partition::balanced(8, 4, 2),
            Partition::balanced(8, 4, 4),
            Partition { cuts: vec![0, 1, 2, 3, 8], dp: 1 },
            Partition { cuts: vec![0, 5, 6, 7, 8], dp: 1 },
            Partition { cuts: vec![0, 1, 2, 3, 8], dp: 2 },
        ];
        for part in parts {
            let mut p = base.clone();
            p.partition = Some(part.clone());
            let fp = p.fingerprint();
            assert!(
                seen.insert(fp),
                "fingerprint collision at partition {}",
                part.describe()
            );
            // equal plans still hash equal
            assert_eq!(p.clone().fingerprint(), fp);
        }
    }

    #[test]
    fn parse_error_lists_valid_names() {
        let err = ScheduleKind::parse("zigzag").unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("zigzag"), "{msg}");
        for name in ScheduleKind::VALID_NAMES {
            assert!(msg.contains(name), "missing {name} in: {msg}");
        }
        // and through FromStr (the CLI arg path)
        assert!("bogus".parse::<ScheduleKind>().is_err());
        assert_eq!("gpipe".parse::<ScheduleKind>(), Ok(ScheduleKind::GPipe));
    }
}
