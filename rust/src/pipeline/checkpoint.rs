//! Bit-exact per-rank checkpointing for the stub-backed executor.
//!
//! PipeDream's weight-stashing discipline (PAPERS.md) pins down exactly
//! what per-rank state a correct pipeline checkpoint must capture: the
//! parameters, both Adam slots, and the step counters that seed the
//! optimizer schedule and the data stream.  Everything else in a
//! [`StageWorker`](crate::pipeline::stage) is either empty at a step
//! boundary (activation stash, pending-p2 queue, gradient accumulators)
//! or a pure function of `(seed, step)` (the `DataGen` stream), so a
//! checkpoint taken *between* steps plus the original `RunConfig`
//! reconstructs the worker bit-for-bit.
//!
//! The on-disk format is deliberately dumb and deterministic: one
//! little-endian binary file per rank (`rank{r}.ckpt`) under a
//! `step-{NNNNNN}` directory, no compression, no timestamps, no
//! platform-dependent encoding — two checkpoints of the same state are
//! byte-identical, which is what lets the resume test assert
//! `2N straight steps == N + restore + N` at the digest level.
//!
//! Layout of one rank file:
//!
//! ```text
//! magic     8  b"2BPCKv1\n"
//! rank      8  u64 le
//! step      8  u64 le
//! step_t    4  f32 le   (optimizer timestep; step+1 as f32)
//! opt_fresh 1  u8       (1: Adam slots unallocated, sections empty)
//! params / m_state / v_state sections, each:
//!   count   8  u64 le
//!   per tensor:
//!     dtype 1  u8       (0 = f32, 1 = i32)
//!     ndim  1  u8
//!     dims  8*ndim u64 le
//!     len   8  u64 le   (payload bytes; must equal prod(dims)*itemsize)
//!     data  len         (raw little-endian element bytes)
//! ```

use std::fs;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::models::DType;
use crate::runtime::HostTensor;

/// File magic; the trailing newline makes `head -c8` output readable.
pub const MAGIC: &[u8; 8] = b"2BPCKv1\n";

/// Everything a stage worker needs to resume at a step boundary.
#[derive(Debug, Clone)]
pub struct RankCheckpoint {
    pub rank: usize,
    /// Completed steps (the worker resumes *into* step `step`).
    pub step: usize,
    /// Adam timestep fed to the opt executable (`step + 1` as f32, but
    /// stored rather than derived so the restore is a pure copy).
    pub step_t: f32,
    /// True while the Adam slots are still the shared zeros; `m_state`
    /// and `v_state` are empty exactly when this is set.
    pub opt_fresh: bool,
    pub params: Vec<HostTensor>,
    pub m_state: Vec<HostTensor>,
    pub v_state: Vec<HostTensor>,
}

fn dtype_tag(d: DType) -> u8 {
    match d {
        DType::F32 => 0,
        DType::I32 => 1,
    }
}

fn tag_dtype(t: u8) -> Result<DType> {
    match t {
        0 => Ok(DType::F32),
        1 => Ok(DType::I32),
        other => bail!("bad dtype tag {other}"),
    }
}

fn push_tensors(buf: &mut Vec<u8>, tensors: &[HostTensor]) {
    buf.extend_from_slice(&(tensors.len() as u64).to_le_bytes());
    for t in tensors {
        buf.push(dtype_tag(t.dtype));
        buf.push(t.shape.len() as u8);
        for &d in &t.shape {
            buf.extend_from_slice(&(d as u64).to_le_bytes());
        }
        buf.extend_from_slice(&(t.data.len() as u64).to_le_bytes());
        buf.extend_from_slice(&t.data);
    }
}

/// Cursor-style reader over the encoded byte stream.
struct Cursor<'a> {
    buf: &'a [u8],
    at: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self
            .at
            .checked_add(n)
            .filter(|e| *e <= self.buf.len())
            .ok_or_else(|| anyhow!("truncated checkpoint (need {n} more bytes at offset {})", self.at))?;
        let s = &self.buf[self.at..end];
        self.at = end;
        Ok(s)
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn tensors(&mut self) -> Result<Vec<HostTensor>> {
        let count = self.u64()? as usize;
        // count is bounded by the remaining bytes (each tensor costs at
        // least 10 bytes of header) — reject garbage before allocating
        if count > self.buf.len() - self.at {
            bail!("tensor count {count} exceeds remaining bytes");
        }
        let mut out = Vec::with_capacity(count);
        for _ in 0..count {
            let dtype = tag_dtype(self.u8()?)?;
            let ndim = self.u8()? as usize;
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                shape.push(self.u64()? as usize);
            }
            let len = self.u64()? as usize;
            let expect =
                shape.iter().product::<usize>() * dtype.itemsize();
            if len != expect {
                bail!(
                    "tensor payload {len} bytes != shape {shape:?} \
                     x {dtype:?} ({expect} bytes)"
                );
            }
            let data = self.take(len)?.to_vec();
            out.push(HostTensor { shape, dtype, data });
        }
        Ok(out)
    }
}

impl RankCheckpoint {
    /// Deterministic binary encoding (see the module docs for layout).
    pub fn encode(&self) -> Vec<u8> {
        let mut buf = Vec::new();
        buf.extend_from_slice(MAGIC);
        buf.extend_from_slice(&(self.rank as u64).to_le_bytes());
        buf.extend_from_slice(&(self.step as u64).to_le_bytes());
        buf.extend_from_slice(&self.step_t.to_le_bytes());
        buf.push(self.opt_fresh as u8);
        push_tensors(&mut buf, &self.params);
        push_tensors(&mut buf, &self.m_state);
        push_tensors(&mut buf, &self.v_state);
        buf
    }

    pub fn decode(bytes: &[u8]) -> Result<RankCheckpoint> {
        let mut c = Cursor { buf: bytes, at: 0 };
        let magic = c.take(MAGIC.len())?;
        if magic != MAGIC {
            bail!(
                "bad checkpoint magic {:?} (want {:?})",
                String::from_utf8_lossy(magic),
                String::from_utf8_lossy(MAGIC)
            );
        }
        let rank = c.u64()? as usize;
        let step = c.u64()? as usize;
        let step_t = c.f32()?;
        let opt_fresh = match c.u8()? {
            0 => false,
            1 => true,
            other => bail!("bad opt_fresh byte {other}"),
        };
        let params = c.tensors()?;
        let m_state = c.tensors()?;
        let v_state = c.tensors()?;
        if c.at != bytes.len() {
            bail!("{} trailing bytes after checkpoint", bytes.len() - c.at);
        }
        if opt_fresh && (!m_state.is_empty() || !v_state.is_empty()) {
            bail!("opt_fresh checkpoint carries Adam slots");
        }
        if !opt_fresh
            && (m_state.len() != params.len()
                || v_state.len() != params.len())
        {
            bail!(
                "Adam slot arity (m={}, v={}) != params ({})",
                m_state.len(),
                v_state.len(),
                params.len()
            );
        }
        Ok(RankCheckpoint {
            rank,
            step,
            step_t,
            opt_fresh,
            params,
            m_state,
            v_state,
        })
    }
}

/// `rank{r}.ckpt` inside a step directory.
pub fn rank_file(dir: &Path, rank: usize) -> PathBuf {
    dir.join(format!("rank{rank}.ckpt"))
}

/// `step-{NNNNNN}` under the checkpoint base directory.
pub fn step_dir(base: &Path, step: usize) -> PathBuf {
    base.join(format!("step-{step:06}"))
}

/// Write one file per rank into `dir` (created if missing).  Each file
/// is written to a `.tmp` sibling and renamed into place, so a crash
/// mid-save never leaves a truncated `rank{r}.ckpt` that a later
/// resume would trip over.
pub fn save(dir: &Path, ckpts: &[RankCheckpoint]) -> Result<()> {
    fs::create_dir_all(dir)
        .with_context(|| format!("creating {}", dir.display()))?;
    for c in ckpts {
        let path = rank_file(dir, c.rank);
        let tmp = path.with_extension("ckpt.tmp");
        fs::write(&tmp, c.encode())
            .with_context(|| format!("writing {}", tmp.display()))?;
        fs::rename(&tmp, &path)
            .with_context(|| format!("renaming to {}", path.display()))?;
    }
    Ok(())
}

/// Load all `n_ranks` rank files from `dir` and cross-validate: every
/// rank present, each file's recorded rank matching its name, and all
/// ranks agreeing on the step (a torn save must not half-resume).
pub fn load(dir: &Path, n_ranks: usize) -> Result<Vec<RankCheckpoint>> {
    let mut out = Vec::with_capacity(n_ranks);
    for rank in 0..n_ranks {
        let path = rank_file(dir, rank);
        let bytes = fs::read(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let c = RankCheckpoint::decode(&bytes)
            .with_context(|| format!("decoding {}", path.display()))?;
        if c.rank != rank {
            bail!(
                "{} says rank {} (file name says {rank})",
                path.display(),
                c.rank
            );
        }
        out.push(c);
    }
    if let Some(first) = out.first() {
        for c in &out[1..] {
            if c.step != first.step {
                bail!(
                    "checkpoint step mismatch: rank 0 at step {}, \
                     rank {} at step {} — torn save?",
                    first.step,
                    c.rank,
                    c.step
                );
            }
        }
    }
    Ok(out)
}

/// Resolve a `--resume` directory: if it directly holds `rank0.ckpt`
/// it IS a step dir; otherwise pick the highest `step-*` child written
/// by `--checkpoint-every`, so `--resume` can point at the same path
/// that `--checkpoint-dir` wrote to.
pub fn resolve_resume_dir(dir: &Path) -> Result<PathBuf> {
    if rank_file(dir, 0).is_file() {
        return Ok(dir.to_path_buf());
    }
    let mut best: Option<(usize, PathBuf)> = None;
    let entries = fs::read_dir(dir)
        .with_context(|| format!("reading {}", dir.display()))?;
    for entry in entries {
        let entry = entry?;
        let name = entry.file_name();
        let name = name.to_string_lossy();
        if let Some(step) = name
            .strip_prefix("step-")
            .and_then(|s| s.parse::<usize>().ok())
        {
            if best.as_ref().map(|(b, _)| step > *b).unwrap_or(true) {
                best = Some((step, entry.path()));
            }
        }
    }
    best.map(|(_, p)| p).ok_or_else(|| {
        anyhow!(
            "{}: no rank0.ckpt and no step-* subdirectories",
            dir.display()
        )
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tensor(vals: &[f32]) -> HostTensor {
        HostTensor::from_f32(&[vals.len()], vals)
    }

    fn sample(rank: usize, step: usize) -> RankCheckpoint {
        RankCheckpoint {
            rank,
            step,
            step_t: (step + 1) as f32,
            opt_fresh: false,
            params: vec![tensor(&[1.0, -2.0, 3.5]), tensor(&[0.25])],
            m_state: vec![tensor(&[0.1, 0.2, 0.3]), tensor(&[0.4])],
            v_state: vec![tensor(&[0.0, 1.0, 2.0]), tensor(&[3.0])],
        }
    }

    fn temp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("twobp-ckpt-{tag}-{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn encode_decode_round_trips_bit_exactly() {
        let c = sample(1, 7);
        let bytes = c.encode();
        let d = RankCheckpoint::decode(&bytes).unwrap();
        // HostTensor has no PartialEq; the deterministic encoding IS
        // the equality probe
        assert_eq!(d.encode(), bytes);
        assert_eq!(d.rank, 1);
        assert_eq!(d.step, 7);
        assert_eq!(d.step_t, 8.0);
        assert!(!d.opt_fresh);
        assert_eq!(d.params[0].to_f32(), vec![1.0, -2.0, 3.5]);
    }

    #[test]
    fn opt_fresh_checkpoint_has_empty_slots() {
        let c = RankCheckpoint {
            opt_fresh: true,
            m_state: Vec::new(),
            v_state: Vec::new(),
            ..sample(0, 0)
        };
        let d = RankCheckpoint::decode(&c.encode()).unwrap();
        assert!(d.opt_fresh);
        assert!(d.m_state.is_empty() && d.v_state.is_empty());
    }

    #[test]
    fn rejects_bad_magic_truncation_and_trailing_garbage() {
        let good = sample(0, 1).encode();

        let mut bad = good.clone();
        bad[0] = b'X';
        assert!(RankCheckpoint::decode(&bad)
            .unwrap_err()
            .to_string()
            .contains("magic"));

        assert!(RankCheckpoint::decode(&good[..good.len() - 1]).is_err());

        let mut long = good.clone();
        long.push(0);
        assert!(RankCheckpoint::decode(&long)
            .unwrap_err()
            .to_string()
            .contains("trailing"));
    }

    #[test]
    fn rejects_payload_shape_mismatch() {
        let mut c = sample(0, 1);
        // lie about the shape: 3 elements claimed, 4 stored
        c.params[0].shape = vec![4];
        assert!(RankCheckpoint::decode(&c.encode()).is_err());
    }

    #[test]
    fn save_load_round_trip_and_step_mismatch_detection() {
        let dir = temp_dir("roundtrip");
        let ckpts = vec![sample(0, 5), sample(1, 5)];
        save(&dir, &ckpts).unwrap();
        let loaded = load(&dir, 2).unwrap();
        assert_eq!(loaded.len(), 2);
        for (a, b) in ckpts.iter().zip(&loaded) {
            assert_eq!(a.encode(), b.encode());
        }
        // missing rank file is an error, not a short vec
        assert!(load(&dir, 3).is_err());
        // torn save: rank 1 one step behind
        save(&dir, &[sample(1, 4)]).unwrap();
        let err = load(&dir, 2).unwrap_err().to_string();
        assert!(err.contains("mismatch"), "{err}");
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn resolve_resume_prefers_latest_step_dir() {
        let base = temp_dir("resolve");
        save(&step_dir(&base, 3), &[sample(0, 3)]).unwrap();
        save(&step_dir(&base, 12), &[sample(0, 12)]).unwrap();
        let picked = resolve_resume_dir(&base).unwrap();
        assert_eq!(picked, step_dir(&base, 12));
        // pointing straight at a step dir also works
        assert_eq!(resolve_resume_dir(&picked).unwrap(), picked);
        // an empty dir is a clear error
        let empty = base.join("empty");
        fs::create_dir_all(&empty).unwrap();
        assert!(resolve_resume_dir(&empty).is_err());
        fs::remove_dir_all(&base).unwrap();
    }

    #[test]
    fn deterministic_encoding_is_stable_across_calls() {
        let c = sample(2, 9);
        assert_eq!(c.encode(), c.encode());
        assert_eq!(c.encode(), c.clone().encode());
    }
}
