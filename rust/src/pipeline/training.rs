//! Training orchestration.
//!
//! A [`Cluster`] spawns one worker thread per pipeline rank.  Workers
//! compile their stage executables **once** and then serve any number of
//! runs (different schedules, ±2BP, loop/concat p2) — compilation
//! dominates end-to-end time on this host, so the Fig 3/4 benchmarks
//! (32 cells) would be infeasible without executable reuse.  Between
//! runs each worker re-inits parameters from the seed, so every cell
//! sees an identical model + data stream (what makes the cross-schedule
//! equivalence checks meaningful).

use std::collections::BTreeMap;
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::config::{P2Mode, RunConfig};
use crate::models::{Manifest, StageInfo};
use crate::pipeline::checkpoint::{self, RankCheckpoint};
use crate::pipeline::comm::pipeline_links_with;
use crate::pipeline::fault::{
    CommFaultCfg, Failure, FailureKind, FaultCell, RunError,
};
use crate::pipeline::stage::{StageWorker, WorkerReport};
use crate::schedule::{generate, validate::validate, Op, Plan, ScheduleKind};
use crate::sim::CostModel;
use crate::util::gantt::{Span, SpanKind};

/// How often the leader re-checks the shared fault cell while waiting
/// on worker channels — the leader-side detection latency bound.
const SUPERVISE_TICK: Duration = Duration::from_millis(50);

/// Block on `rx` in bounded ticks, surfacing a tripped fault cell as
/// the typed [`RunError`] instead of waiting on channels whose workers
/// are unwinding.  This is what makes every leader-side wait in
/// [`Cluster::run_plan`] hang-free: workers detect stalls via their
/// own receive deadlines and trip the cell; the leader notices within
/// one tick.
fn recv_supervised<T>(
    rx: &Receiver<T>,
    fault: &FaultCell,
    waiting_for: &str,
) -> Result<T> {
    loop {
        match rx.recv_timeout(SUPERVISE_TICK) {
            Ok(v) => return Ok(v),
            Err(RecvTimeoutError::Timeout) => {
                if let Some(f) = fault.get() {
                    return Err(anyhow::Error::new(RunError::from(f)));
                }
            }
            Err(RecvTimeoutError::Disconnected) => {
                return Err(match fault.get() {
                    Some(f) => anyhow::Error::new(RunError::from(f)),
                    None => anyhow!("workers died {waiting_for}"),
                });
            }
        }
    }
}

/// Everything measured during a run.
#[derive(Debug)]
pub struct RunReport {
    pub plan: Plan,
    pub preset: String,
    /// Mean loss per step (averaged over microbatches; last rank).
    pub losses: Vec<f32>,
    /// Wall seconds per step (serialized on this 1-core host — see
    /// DESIGN.md §3; use `measured_costs` + the simulator for pipeline
    /// wall-clock).
    pub step_times: Vec<f64>,
    pub reports: Vec<WorkerReport>,
    pub samples_per_step: usize,
}

impl RunReport {
    /// Per-rank measured mean op costs, as a simulator CostModel.
    ///
    /// `loss` comes from the last rank's separately-timed
    /// [`SpanKind::Loss`] spans (see [`WorkerReport::mean_loss`]) — it
    /// is **not** folded into p1, because the simulator already
    /// schedules a loss op on the last rank and would double-count it.
    /// Errors instead of panicking when a rank report is missing,
    /// duplicated, or out of range (a worker died mid-run, or a
    /// hand-built report is malformed) — silently mis-attributing
    /// per-rank costs would skew every model derived from the run.
    pub fn measured_costs(&self) -> Result<CostModel> {
        let n = self.reports.len();
        let mut by_rank: Vec<Option<&WorkerReport>> = vec![None; n];
        for w in &self.reports {
            let slot = by_rank.get_mut(w.rank).ok_or_else(|| {
                anyhow!(
                    "measured_costs: rank {} out of range ({n} rank reports)",
                    w.rank
                )
            })?;
            if slot.replace(w).is_some() {
                bail!("measured_costs: duplicate report for rank {}", w.rank);
            }
        }
        let ranked: Vec<&WorkerReport> = by_rank
            .into_iter()
            .enumerate()
            .map(|(r, w)| {
                w.ok_or_else(|| {
                    anyhow!("measured_costs: missing report for rank {r}")
                })
            })
            .collect::<Result<_>>()?;
        let pick = |f: fn(&WorkerReport) -> f64| -> Vec<f64> {
            ranked.iter().map(|&w| f(w)).collect()
        };
        // hop latency: mean of per-rank measured send costs over the
        // ranks that actually sent (a single-rank pipeline sends
        // nothing and keeps comm = 0).  In-process channels make this
        // a µs-scale floor rather than a network figure, but a floor
        // beats the old hard-coded 0.0: plans that differ only in hop
        // count stop looking timing-identical to the planner.
        let senders: Vec<f64> = ranked
            .iter()
            .filter(|w| w.mean_comm > 0.0)
            .map(|w| w.mean_comm)
            .collect();
        let comm = if senders.is_empty() {
            0.0
        } else {
            senders.iter().sum::<f64>() / senders.len() as f64
        };
        Ok(CostModel {
            fwd: pick(|w| w.mean_costs.0),
            p1: pick(|w| w.mean_costs.1),
            p2: pick(|w| w.mean_costs.2),
            opt: pick(|w| w.mean_costs.3),
            loss: ranked.last().map(|w| w.mean_loss).unwrap_or(0.0),
            comm,
            comm_inter_node: 0.0,
            ranks_per_node: usize::MAX,
            concat_factor: 1.0,
        })
    }

    /// Peak bytes per rank (the Fig 4 metric).
    pub fn peak_bytes(&self) -> Vec<u64> {
        let mut v = vec![0u64; self.reports.len()];
        for w in &self.reports {
            v[w.rank] = w.peak_bytes;
        }
        v
    }

    /// Peak of the simulator-modeled classes per rank (everything but
    /// the in-flight `Wire` buffers) — directly comparable to
    /// `SimResult::peak_bytes` from the same plan and
    /// `Manifest::mem_model` (see [`verify_report_against_sim`]).
    pub fn peak_model_bytes(&self) -> Vec<u64> {
        let mut v = vec![0u64; self.reports.len()];
        for w in &self.reports {
            v[w.rank] = w.peak_model;
        }
        v
    }

    pub fn max_peak(&self) -> u64 {
        self.peak_bytes().into_iter().max().unwrap_or(0)
    }

    /// Throughput from measured per-op costs replayed through the
    /// simulator (the calibrated pipeline wall-clock; samples/sec).
    pub fn simulated_throughput(&self) -> Result<f64> {
        let costs = self.measured_costs()?;
        let res = crate::sim::simulate(&self.plan, &costs, None)
            .map_err(|e| anyhow!("{e}"))?;
        Ok(self.samples_per_step as f64 / res.makespan)
    }

    /// Real spans of the measured steps (for gantt rendering).
    pub fn spans(&self) -> Vec<Vec<Span>> {
        let mut out = vec![Vec::new(); self.reports.len()];
        for w in &self.reports {
            out[w.rank] = w
                .timings
                .iter()
                .map(|t| Span {
                    start: t.start,
                    end: t.end,
                    label: t.kind,
                    mb: t.mb,
                })
                .collect();
        }
        out
    }

    /// The comm lane: each rank's timed p2p sends as
    /// [`SpanKind::Comm`] spans (same epoch as [`Self::spans`]).  Kept
    /// out of `spans()` because the span-shape verifier compares that
    /// timeline 1:1 against simulator spans, which carry no comm ops;
    /// the trace export merges both lanes.
    pub fn comm_spans(&self) -> Vec<Vec<Span>> {
        let mut out = vec![Vec::new(); self.reports.len()];
        for w in &self.reports {
            out[w.rank] = w
                .comm_timings
                .iter()
                .map(|t| Span {
                    start: t.start,
                    end: t.end,
                    label: t.kind,
                    mb: t.mb,
                })
                .collect();
        }
        out
    }

    /// Compute + comm spans per rank, merged — the executed timeline as
    /// the trace export renders it.
    pub fn trace_spans(&self) -> Vec<Vec<Span>> {
        let mut out = self.spans();
        for (rank, comm) in self.comm_spans().into_iter().enumerate() {
            out[rank].extend(comm);
        }
        out
    }

    /// Measured makespan of each executed step: per rank the timeline
    /// splits into steps at each [`SpanKind::Opt`] span (the same
    /// segmentation [`verify_report_against_sim`] uses), and step `s`
    /// spans from the earliest op start to the latest op end across
    /// ranks.  This is the per-step drift signal for a *finished* run —
    /// the replan loop computes the same quantity step by step.
    pub fn step_makespans(&self) -> Vec<f64> {
        // (earliest start, latest end) across ranks, per step
        let mut bounds: Vec<(f64, f64)> = Vec::new();
        for w in &self.reports {
            let mut step = 0usize;
            let mut seg_start: Option<f64> = None;
            for t in &w.timings {
                let first = *seg_start.get_or_insert(t.start);
                if t.kind == SpanKind::Opt {
                    if bounds.len() <= step {
                        bounds.resize(
                            step + 1,
                            (f64::INFINITY, f64::NEG_INFINITY),
                        );
                    }
                    bounds[step].0 = bounds[step].0.min(first);
                    bounds[step].1 = bounds[step].1.max(t.end);
                    seg_start = None;
                    step += 1;
                }
            }
        }
        bounds.into_iter().map(|(a, b)| (b - a).max(0.0)).collect()
    }

    /// Sum of per-rank parameter checksums (equivalence testing).
    pub fn param_checksum(&self) -> f64 {
        self.reports.iter().map(|w| w.param_checksum).sum()
    }

    /// Per-rank raw-byte parameter digests (rank order) — bit-exact
    /// equivalence: two runs have equal digests iff every parameter
    /// byte matches (up to 64-bit FNV collisions), unlike the
    /// sign-blind [`Self::param_checksum`].
    pub fn param_digests(&self) -> Vec<u64> {
        let mut v = vec![0u64; self.reports.len()];
        for w in &self.reports {
            v[w.rank] = w.param_digest;
        }
        v
    }

    pub fn mean_step_time(&self) -> f64 {
        if self.step_times.is_empty() {
            0.0
        } else {
            self.step_times.iter().sum::<f64>() / self.step_times.len() as f64
        }
    }
}

/// Per-(schedule, microbatch-count) measured comm means — the PR 6
/// follow-on replacing the single-mean comm floor for schedule-aware
/// tuning.  Send cost depends on how the schedule interleaves compute
/// with serialization (a GPipe burst contends differently than 1F1B's
/// steady state), so one global mean mis-prices candidates; cells are
/// measured per (kind, m) and anything unprobed falls back to the
/// floor (the old behavior, never worse).
#[derive(Debug, Clone, Default)]
pub struct CommCalibration {
    cells: Vec<(ScheduleKind, usize, f64)>,
    floor: f64,
}

impl CommCalibration {
    /// Start from the single-mean floor (`measured_costs().comm`).
    pub fn with_floor(floor: f64) -> CommCalibration {
        CommCalibration { cells: Vec::new(), floor }
    }

    pub fn floor(&self) -> f64 {
        self.floor
    }

    /// Record a cell's measured sender-mean (last write wins).
    pub fn record(&mut self, kind: ScheduleKind, m: usize, comm: f64) {
        match self
            .cells
            .iter_mut()
            .find(|(k, mm, _)| *k == kind && *mm == m)
        {
            Some((_, _, v)) => *v = comm,
            None => self.cells.push((kind, m, comm)),
        }
    }

    /// The comm cost to price a `(kind, m)` candidate with: its own
    /// measured cell if probed, the floor otherwise.
    pub fn comm_for(&self, kind: ScheduleKind, m: usize) -> f64 {
        self.cells
            .iter()
            .find(|(k, mm, _)| *k == kind && *mm == m)
            .map(|(_, _, v)| *v)
            .unwrap_or(self.floor)
    }

    /// Probed cells in record order.
    pub fn cells(&self) -> &[(ScheduleKind, usize, f64)] {
        &self.cells
    }

    /// `base` with its comm term replaced by this candidate's cell.
    pub fn specialize(
        &self,
        kind: ScheduleKind,
        m: usize,
        base: &CostModel,
    ) -> CostModel {
        CostModel { comm: self.comm_for(kind, m), ..base.clone() }
    }
}

enum Cmd {
    Run {
        ops: Vec<Op>,
        steps: usize,
        greedy: bool,
        two_bp: bool,
        p2_mode: P2Mode,
        seed: u64,
        data_cycle: usize,
        /// Snapshot after every N steps (0 = never).
        ckpt_every: usize,
        /// Restore this rank's state right after the reset.
        resume: Option<Box<RankCheckpoint>>,
    },
    Shutdown,
}

/// A persistent set of stage workers for one preset.  Compiles all
/// artifacts once; serves many runs.
pub struct Cluster {
    manifest: Manifest,
    cmd_txs: Vec<Sender<Cmd>>,
    rep_rx: Receiver<(usize, WorkerReport)>,
    done_rx: Receiver<(usize, usize)>,
    ckpt_rx: Receiver<(usize, RankCheckpoint)>,
    fault: FaultCell,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl Cluster {
    /// Spawn workers and compile every stage's executables.
    pub fn new(cfg: &RunConfig) -> Result<Cluster> {
        let manifest = Manifest::load(&cfg.artifacts, &cfg.preset)
            .with_context(|| format!("loading preset {}", cfg.preset))?;
        let n = manifest.n_stages;
        let comm_fault = CommFaultCfg {
            seed: cfg.comm_fault_seed,
            drop_prob: cfg.comm_drop_prob,
            delay_ns: cfg.comm_delay_ns,
        };
        let links = pipeline_links_with(n, Some(&comm_fault));
        let epoch = Instant::now();
        let fault = FaultCell::new();
        let comm_timeout = Duration::from_millis(cfg.comm_timeout_ms.max(1));
        let comm_backoff = Duration::from_millis(cfg.comm_backoff_ms.max(1));
        let (rep_tx, rep_rx) = channel::<(usize, WorkerReport)>();
        let (done_tx, done_rx) = channel::<(usize, usize)>();
        let (ckpt_tx, ckpt_rx) = channel::<(usize, RankCheckpoint)>();
        let (ready_tx, ready_rx) =
            channel::<core::result::Result<(), String>>();

        // workers start with a neutral plan; real mode comes per-command
        let init_plan = generate(ScheduleKind::GPipe, true, n, n, false);
        let mut cmd_txs = Vec::new();
        let mut handles = Vec::new();
        for (rank, rank_links) in links.into_iter().enumerate() {
            let manifest_cl = manifest.clone();
            let plan_cl = init_plan.clone();
            let (cmd_tx, cmd_rx) = channel::<Cmd>();
            cmd_txs.push(cmd_tx);
            let rep_tx = rep_tx.clone();
            let done_tx = done_tx.clone();
            let ckpt_tx = ckpt_tx.clone();
            let ready_tx = ready_tx.clone();
            let cell = fault.clone();
            let seed = cfg.seed;
            handles.push(
                std::thread::Builder::new()
                    .name(format!("stage-{rank}"))
                    .spawn(move || {
                        let mut w = match StageWorker::new(
                            rank, &manifest_cl, &plan_cl, P2Mode::Loop,
                            rank_links, seed, 0, epoch,
                        ) {
                            Ok(w) => {
                                let _ = ready_tx.send(Ok(()));
                                w
                            }
                            Err(e) => {
                                let _ = ready_tx
                                    .send(Err(format!("stage {rank}: {e:#}")));
                                return;
                            }
                        };
                        w.set_supervision(
                            cell.clone(),
                            comm_timeout,
                            comm_backoff,
                        );
                        // fail-fast: on any error, trip the shared cell
                        // (first failure wins — a CommTimeout the worker
                        // tripped deeper down is preserved) and exit the
                        // thread.  Dropping our links unblocks peers via
                        // channel hangup; peers still waiting observe
                        // the cell within one backoff tick.
                        let trip = |w: &StageWorker, stage: &str, e: anyhow::Error| {
                            cell.trip(Failure {
                                kind: FailureKind::RankFailed,
                                rank,
                                step: w.step(),
                                cause: if stage.is_empty() {
                                    format!("{e:#}")
                                } else {
                                    format!("{stage}: {e:#}")
                                },
                            });
                        };
                        'serve: while let Ok(cmd) = cmd_rx.recv() {
                            match cmd {
                                Cmd::Shutdown => break,
                                Cmd::Run {
                                    ops, steps, greedy, two_bp, p2_mode,
                                    seed, data_cycle, ckpt_every, resume,
                                } => {
                                    if let Err(e) = w.reset(
                                        seed, greedy, two_bp, p2_mode,
                                        data_cycle,
                                    ) {
                                        trip(&w, "reset", e);
                                        break 'serve;
                                    }
                                    if let Some(c) = &resume {
                                        if let Err(e) = w.restore(c) {
                                            trip(&w, "restore", e);
                                            break 'serve;
                                        }
                                    }
                                    for s in 0..steps {
                                        if let Err(e) = w.run_step(&ops) {
                                            trip(&w, "", e);
                                            break 'serve;
                                        }
                                        let _ = done_tx.send((rank, s));
                                        if ckpt_every > 0
                                            && (s + 1) % ckpt_every == 0
                                        {
                                            match w.snapshot() {
                                                Ok(c) => {
                                                    let _ = ckpt_tx
                                                        .send((rank, c));
                                                }
                                                Err(e) => {
                                                    trip(&w, "snapshot", e);
                                                    break 'serve;
                                                }
                                            }
                                        }
                                    }
                                    match w.report() {
                                        Ok(r) => {
                                            let _ = rep_tx.send((rank, r));
                                        }
                                        Err(e) => {
                                            trip(&w, "report", e);
                                            break 'serve;
                                        }
                                    }
                                }
                            }
                        }
                    })
                    .context("spawning stage thread")?,
            );
        }
        for _ in 0..n {
            ready_rx
                .recv()
                .map_err(|_| anyhow!("worker died during startup"))?
                .map_err(|e| anyhow!(e))?;
        }
        Ok(Cluster {
            manifest,
            cmd_txs,
            rep_rx,
            done_rx,
            ckpt_rx,
            fault,
            handles,
        })
    }

    /// The first failure any rank has reported this cluster's lifetime
    /// (a tripped cluster stays poisoned: dead worker threads are not
    /// respawned — recover by rebuilding the cluster and resuming from
    /// the last checkpoint, as `experiments::fault_sweep` does).
    pub fn first_failure(&self) -> Option<Failure> {
        self.fault.get()
    }

    pub fn n_stages(&self) -> usize {
        self.manifest.n_stages
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Execute one run (a full schedule for `cfg.steps` training steps).
    pub fn run(&self, cfg: &RunConfig) -> Result<RunReport> {
        let n = self.manifest.n_stages;
        let m = cfg.microbatches(n);
        let plan = generate(cfg.schedule, cfg.two_bp, n, m,
                            cfg.p2_mode == P2Mode::Concat);
        self.run_plan(&plan, cfg)
    }

    /// Measured-cost calibration — the first half of the
    /// executor→planner→executor loop (`twobp tune --synthetic`): run
    /// `cfg.steps` (at least 2) training steps under the **naive**
    /// schedule, whose ops never overlap across ranks, so per-op
    /// timings are contention-free on a shared-core host (the
    /// DESIGN.md §3 calibration methodology), and return the measured
    /// per-stage [`CostModel`] together with the calibration report.
    pub fn calibrate(&self, cfg: &RunConfig) -> Result<(CostModel, RunReport)> {
        let calib_cfg = RunConfig {
            schedule: ScheduleKind::Naive,
            two_bp: false,
            p2_mode: P2Mode::Loop,
            steps: cfg.steps.max(2),
            ..cfg.clone()
        };
        let report = self.run(&calib_cfg)?;
        let costs = report.measured_costs()?;
        Ok((costs, report))
    }

    /// Probe measured comm per `(schedule, m)` cell: one short run
    /// each, recording that run's sender-mean send cost.  `floor` is
    /// the single-mean fallback from [`Cluster::calibrate`] — unprobed
    /// cells price at the floor, so this strictly refines the PR 6
    /// model (see docs/ROBUSTNESS.md §5).
    pub fn calibrate_comm(
        &self,
        cfg: &RunConfig,
        floor: f64,
        cells: &[(ScheduleKind, usize)],
    ) -> Result<CommCalibration> {
        let mut out = CommCalibration::with_floor(floor);
        for &(kind, m) in cells {
            let cell_cfg = RunConfig {
                schedule: kind,
                n_microbatches: m,
                p2_mode: P2Mode::Loop,
                steps: cfg.steps.clamp(1, 2),
                ..cfg.clone()
            };
            let report = self.run(&cell_cfg)?;
            let comm = report.measured_costs()?.comm;
            if comm > 0.0 {
                out.record(kind, m, comm);
            }
        }
        Ok(out)
    }

    /// Execute an **arbitrary validated plan** — generator-made, a DSL
    /// `.plan` file, or a planner winner — for `cfg.steps` steps.  This
    /// is the replay half of the calibration loop: `twobp tune
    /// --synthetic` executes its tuned winner back through here and
    /// reports predicted-vs-executed makespan.  The plan *is* the
    /// schedule: `cfg.schedule` / `two_bp` / `n_microbatches` are
    /// ignored.  Concat-p2 execution must be expressed *in the plan*
    /// (`wc(...)` / `flushc` ops): `cfg.p2_mode == Concat` with a plan
    /// carrying no concat ops is rejected, because the executor would
    /// then concat flushes the plan (and hence the simulator and
    /// [`verify_report_against_sim`]) models as loop-mode.
    pub fn run_plan(&self, plan: &Plan, cfg: &RunConfig) -> Result<RunReport> {
        let n = self.manifest.n_stages;
        if plan.n_ranks != n {
            bail!(
                "plan is shaped for {} ranks, cluster has {n} stages",
                plan.n_ranks
            );
        }
        // concat execution must be expressed per-op in the plan: under
        // `p2_mode == Concat` the worker would also concat-execute
        // loop-marked flushes (stage.rs `op_flush`), which the
        // simulator — and verify_report_against_sim — model as
        // loop-mode.  Generated concat plans mark every p2/flush op,
        // so `Cluster::run` never trips this.
        if cfg.p2_mode == P2Mode::Concat {
            let loop_p2 = plan.ranks.iter().flatten().any(|op| {
                matches!(
                    op,
                    Op::Flush { concat: false, .. }
                        | Op::BwdP2 { concat: false, .. }
                )
            });
            if loop_p2 {
                bail!(
                    "--concat-p2 would concat-execute p2 work this plan \
                     marks as loop-mode (and the simulator models as \
                     loop-mode): express concat in the plan itself \
                     (wc(...)/flushc, see docs/PLAN_FORMAT.md) or drop \
                     --concat-p2"
                );
            }
        }
        let m = plan.n_microbatches;
        validate(plan).map_err(|e| anyhow!("invalid plan: {e}"))?;

        // a cluster that already failed stays failed: its worker
        // threads exited, so a new run would hang on dead channels
        if let Some(f) = self.fault.get() {
            return Err(anyhow::Error::new(RunError::from(f)));
        }
        if cfg.checkpoint_every > 0 && cfg.checkpoint_dir.is_none() {
            bail!("--checkpoint-every requires --checkpoint-dir");
        }
        let resume: Option<Vec<RankCheckpoint>> = match &cfg.resume {
            Some(dir) => {
                let dir = checkpoint::resolve_resume_dir(dir)?;
                let cks = checkpoint::load(&dir, n).with_context(|| {
                    format!("resuming from {}", dir.display())
                })?;
                Some(cks)
            }
            None => None,
        };
        let mut resume_by_rank: Vec<Option<Box<RankCheckpoint>>> =
            match resume {
                Some(cks) => cks.into_iter().map(|c| Some(Box::new(c))).collect(),
                None => (0..n).map(|_| None).collect(),
            };

        for (rank, tx) in self.cmd_txs.iter().enumerate() {
            tx.send(Cmd::Run {
                ops: plan.ranks[rank].clone(),
                steps: cfg.steps,
                greedy: plan.greedy_p2,
                two_bp: plan.two_bp,
                p2_mode: cfg.p2_mode,
                seed: cfg.seed,
                data_cycle: cfg.data_cycle,
                ckpt_every: cfg.checkpoint_every,
                resume: resume_by_rank[rank].take(),
            })
            .map_err(|_| anyhow!("stage {rank} is gone"))?;
        }

        // step s completes when all n ranks reported it; every wait is
        // supervised, so a rank failure surfaces as a typed RunError
        // within one tick instead of hanging this loop forever
        let mut step_times = Vec::with_capacity(cfg.steps);
        let mut completed = vec![0usize; cfg.steps];
        let mut t0 = Instant::now();
        let mut next_step = 0usize;
        while next_step < cfg.steps {
            let (_rank, s) = match recv_supervised(
                &self.done_rx,
                &self.fault,
                "mid-run",
            ) {
                Ok(v) => v,
                Err(e) => {
                    // the run is lost, but snapshots of the steps every
                    // rank *did* finish are already in flight — persist
                    // them so recovery resumes from the last good step
                    self.salvage_checkpoints(cfg);
                    return Err(e);
                }
            };
            completed[s] += 1;
            while next_step < cfg.steps && completed[next_step] == n {
                let dt = t0.elapsed().as_secs_f64();
                step_times.push(dt);
                if cfg.verbose {
                    eprintln!("step {next_step}: {:.3}s", dt);
                }
                t0 = Instant::now();
                next_step += 1;
            }
        }

        // drain the expected snapshots (workers send each right after
        // its step's done message, so these are already in flight) and
        // persist them grouped by step under the checkpoint dir
        if cfg.checkpoint_every > 0 {
            let dir = cfg.checkpoint_dir.as_ref().unwrap();
            let expected = (cfg.steps / cfg.checkpoint_every) * n;
            let mut by_step: BTreeMap<usize, Vec<RankCheckpoint>> =
                BTreeMap::new();
            for _ in 0..expected {
                let (_, c) = recv_supervised(
                    &self.ckpt_rx,
                    &self.fault,
                    "before checkpointing",
                )?;
                by_step.entry(c.step).or_default().push(c);
            }
            for (step, mut cks) in by_step {
                if cks.len() != n {
                    bail!(
                        "checkpoint at step {step}: {}/{n} rank snapshots",
                        cks.len()
                    );
                }
                cks.sort_by_key(|c| c.rank);
                checkpoint::save(&checkpoint::step_dir(dir, step), &cks)?;
            }
        }

        let mut reports: Vec<WorkerReport> = Vec::with_capacity(n);
        for _ in 0..n {
            let (_, r) = recv_supervised(
                &self.rep_rx,
                &self.fault,
                "before reporting",
            )?;
            reports.push(r);
        }
        reports.sort_by_key(|w| w.rank);

        let last = reports
            .iter()
            .find(|w| w.rank == n - 1)
            .ok_or_else(|| anyhow!("missing last-rank report"))?;
        let losses: Vec<f32> = last
            .losses
            .chunks(m)
            .map(|c| c.iter().sum::<f32>() / c.len() as f32)
            .collect();

        Ok(RunReport {
            plan: plan.clone(),
            preset: cfg.preset.clone(),
            losses,
            step_times,
            reports,
            samples_per_step: self.manifest.samples_per_microbatch * m,
        })
    }
}

impl Cluster {
    /// After a failed run: drain whatever per-step snapshots the ranks
    /// already sent and persist every **complete** step set (all n
    /// ranks), so recovery can resume from the last good step instead
    /// of step 0.  Incomplete sets are discarded — a torn checkpoint is
    /// worse than none.  Best-effort by design: the run's own error is
    /// the primary outcome, so save failures only go to stderr.
    fn salvage_checkpoints(&self, cfg: &RunConfig) {
        if cfg.checkpoint_every == 0 {
            return;
        }
        let Some(dir) = cfg.checkpoint_dir.as_ref() else { return };
        let n = self.manifest.n_stages;
        // a healthy rank that finished a step is at most a few ticks
        // behind the failure notice; quiet for this long means nothing
        // more is coming
        let grace = SUPERVISE_TICK * 4;
        let mut by_step: BTreeMap<usize, Vec<RankCheckpoint>> =
            BTreeMap::new();
        while let Ok((_, c)) = self.ckpt_rx.recv_timeout(grace) {
            by_step.entry(c.step).or_default().push(c);
        }
        for (step, mut cks) in by_step {
            if cks.len() != n {
                continue;
            }
            cks.sort_by_key(|c| c.rank);
            if let Err(e) =
                checkpoint::save(&checkpoint::step_dir(dir, step), &cks)
            {
                eprintln!("checkpoint salvage at step {step}: {e:#}");
            }
        }
    }

    /// Send Shutdown and join every worker, collecting the ranks whose
    /// threads *panicked* (distinct from fail-fast exits, which return
    /// normally after tripping the fault cell).
    fn teardown(&mut self) -> Vec<usize> {
        for tx in &self.cmd_txs {
            let _ = tx.send(Cmd::Shutdown);
        }
        let mut panicked = Vec::new();
        for (rank, h) in self.handles.drain(..).enumerate() {
            if h.join().is_err() {
                panicked.push(rank);
            }
        }
        panicked
    }

    /// Graceful teardown that *propagates* worker join results — the
    /// fix for the old `let _ = h.join()`, which silently swallowed
    /// panicked workers.  Prefer this over relying on `Drop` wherever
    /// an error can still be surfaced to the caller.
    pub fn shutdown(mut self) -> Result<()> {
        let panicked = self.teardown();
        if panicked.is_empty() {
            Ok(())
        } else {
            bail!(
                "stage worker thread(s) panicked during the run: rank {}",
                panicked
                    .iter()
                    .map(|r| r.to_string())
                    .collect::<Vec<_>>()
                    .join(", rank ")
            )
        }
    }
}

impl Drop for Cluster {
    fn drop(&mut self) {
        // Drop can't return an error, but it must not swallow one
        // either: a panicked worker is at least named on stderr.
        let panicked = self.teardown();
        if !panicked.is_empty() {
            eprintln!(
                "cluster teardown: stage worker thread(s) panicked: {}",
                panicked
                    .iter()
                    .map(|r| format!("rank {r}"))
                    .collect::<Vec<_>>()
                    .join(", ")
            );
        }
    }
}

/// One-shot convenience: build a cluster, run once, tear down loudly
/// (a panicked worker fails the call even if the run itself reported).
pub fn train(cfg: &RunConfig) -> Result<RunReport> {
    let cluster = Cluster::new(cfg)?;
    let report = cluster.run(cfg);
    let teardown = cluster.shutdown();
    let report = report?;
    teardown?;
    Ok(report)
}

/// Cross-check a finished run against the simulator and the manifest
/// byte classes — the `twobp train --synthetic` smoke contract:
///
/// 1. **Op order.**  For deterministic (non-greedy) plans whose p2 ops
///    are all singletons (every generated fused plan), every rank's
///    executed `(kind, microbatch)` sequence must equal the simulated
///    timeline in every step.  Otherwise — greedy-p2 plans, where real
///    arrival timing may legally fill deferred p2 work at different
///    instants than the modeled timeline, and DSL plans with
///    multi-microbatch p2 batches, where sim spans and executed spans
///    differ in granularity — the check weakens to: the Fwd/BwdP1
///    backbone matches the sim order, and (greedy only) every
///    microbatch's p2 ran within the step, never before its own p1.
/// 2. **Memory.**  Replaying the rank's *own* executed op order through
///    the manifest byte classes must reproduce the byte-exact
///    accountant's model peak ([`crate::pipeline::memory::MemAccountant::peak_model`]);
///    for non-greedy plans that peak must also equal the simulator's
///    `peak_bytes` under `Manifest::mem_model`.
///
/// Concat-mode p2 (`Op::{Flush, BwdP2} { concat: true }`) collapses
/// several microbatches into one recorded span, so the per-span replay
/// and p2-coverage checks are skipped for such plans — their gradient
/// equivalence is covered separately by the concat-vs-loop tests.
pub fn verify_report_against_sim(
    report: &RunReport,
    manifest: &Manifest,
    steps: usize,
) -> Result<()> {
    let plan = &report.plan;
    let costs = manifest.cost_model_from_flops(0.0);
    let mm = manifest.mem_model();
    let sim = crate::sim::simulate(plan, &costs, Some(&mm))
        .map_err(|e| anyhow!("simulating {}: {e}", plan.describe()))?;
    let concat = plan.ranks.iter().flatten().any(|op| {
        matches!(
            op,
            Op::Flush { concat: true, .. } | Op::BwdP2 { concat: true, .. }
        )
    });
    // The strict span-for-span comparison assumes every executed p2
    // span covers exactly one microbatch.  Generated fused plans pair
    // each BwdP1 with a singleton BwdP2 so that holds; DSL plans can
    // carry multi-microbatch BwdP2 or Flush ops on non-greedy ranks,
    // where the sim records one span per batch but the executor one
    // per microbatch — fall back to the backbone checks for those.
    let strict = !plan.greedy_p2
        && plan.ranks.iter().flatten().all(|op| match op {
            Op::BwdP2 { mbs, .. } => mbs.len() == 1,
            Op::Flush { .. } => false,
            _ => true,
        });
    let model_peaks = report.peak_model_bytes();

    for w in &report.reports {
        let r = w.rank;
        let sim_seq: Vec<(SpanKind, u32)> =
            sim.spans[r].iter().map(|s| (s.label, s.mb)).collect();

        // split the rank's timeline into steps at each OptStep
        let mut segs: Vec<&[crate::pipeline::stage::OpTiming]> = Vec::new();
        let mut seg_start = 0usize;
        for (i, t) in w.timings.iter().enumerate() {
            if t.kind == SpanKind::Opt {
                segs.push(&w.timings[seg_start..=i]);
                seg_start = i + 1;
            }
        }
        if seg_start != w.timings.len() {
            bail!(
                "rank {r}: {} trailing ops after the last OptStep",
                w.timings.len() - seg_start
            );
        }
        if segs.len() != steps {
            bail!("rank {r}: {} executed steps, expected {steps}",
                  segs.len());
        }

        for (si, seg) in segs.iter().enumerate() {
            // Loss spans exist only on the executor side (the sim models
            // loss as a latency on the last rank's p1 readiness, not as
            // a span): check their count, then compare without them.
            let n_loss =
                seg.iter().filter(|t| t.kind == SpanKind::Loss).count();
            let want_loss = if r == plan.n_ranks - 1 {
                plan.n_microbatches
            } else {
                0
            };
            if n_loss != want_loss {
                bail!(
                    "rank {r} step {si}: {n_loss} loss spans, expected \
                     {want_loss}"
                );
            }
            let seq: Vec<(SpanKind, u32)> = seg
                .iter()
                .filter(|t| t.kind != SpanKind::Loss)
                .map(|t| (t.kind, t.mb))
                .collect();
            if strict {
                if seq != sim_seq {
                    bail!(
                        "rank {r} step {si}: executed op order diverges \
                         from the sim timeline\n  executed: {seq:?}\n  \
                         sim:      {sim_seq:?}"
                    );
                }
                continue;
            }
            let pick = |xs: &[(SpanKind, u32)], k: SpanKind| -> Vec<u32> {
                xs.iter()
                    .filter(|(kk, _)| *kk == k)
                    .map(|(_, mb)| *mb)
                    .collect()
            };
            for kind in [SpanKind::Fwd, SpanKind::BwdP1] {
                if pick(&seq, kind) != pick(&sim_seq, kind) {
                    bail!(
                        "rank {r} step {si}: {kind:?} order diverges from \
                         the sim timeline"
                    );
                }
            }
            if !concat && plan.greedy_p2 {
                let mut p2 = pick(&seq, SpanKind::BwdP2);
                p2.sort_unstable();
                let want: Vec<u32> =
                    (0..plan.n_microbatches as u32).collect();
                if p2 != want {
                    bail!(
                        "rank {r} step {si}: p2 coverage {p2:?} != every \
                         microbatch 0..{}",
                        plan.n_microbatches
                    );
                }
                for t in seg.iter().filter(|t| t.kind == SpanKind::BwdP2) {
                    let p1_end = seg
                        .iter()
                        .find(|u| u.kind == SpanKind::BwdP1 && u.mb == t.mb)
                        .map(|u| u.end);
                    match p1_end {
                        Some(e) if e <= t.start + 1e-9 => {}
                        Some(_) => bail!(
                            "rank {r} step {si}: p2 of mb {} started \
                             before its p1 finished",
                            t.mb
                        ),
                        None => bail!(
                            "rank {r} step {si}: p2 of mb {} has no p1",
                            t.mb
                        ),
                    }
                }
            }
        }

        // memory: replay the executed order through the byte classes
        let st = &manifest.stages[r];
        if !concat {
            let (peak, live_end) = replay_model_bytes(&w.timings, st);
            if peak != model_peaks[r] {
                bail!(
                    "rank {r}: accountant model peak {} != {peak} from \
                     replaying the executed op order through the manifest \
                     byte classes",
                    model_peaks[r]
                );
            }
            let static_b = st.bytes.params * 3 + st.bytes.grads;
            if live_end != static_b {
                bail!(
                    "rank {r}: {live_end} model bytes live after the run, \
                     expected the static {static_b}"
                );
            }
        }
        if strict && !concat && model_peaks[r] != sim.peak_bytes[r] {
            bail!(
                "rank {r}: accountant model peak {} != simulator peak {} \
                 (Manifest::mem_model)",
                model_peaks[r],
                sim.peak_bytes[r]
            );
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn wr(rank: usize) -> WorkerReport {
        WorkerReport {
            rank,
            timings: Vec::new(),
            comm_timings: Vec::new(),
            peak_bytes: 0,
            peak_model: 0,
            peak_static: 0,
            peak_res1: 0,
            peak_res2: 0,
            peak_inter: 0,
            mean_costs: (1.0 + rank as f64, 2.0, 3.0, 0.5),
            mean_loss: if rank == 1 { 0.25 } else { 0.0 },
            // last rank sends nothing in a 2-rank pipeline's fwd path
            mean_comm: if rank == 0 { 0.002 } else { 0.0 },
            losses: Vec::new(),
            param_checksum: 0.0,
            param_digest: 0,
        }
    }

    fn report_with(reports: Vec<WorkerReport>) -> RunReport {
        RunReport {
            plan: generate(ScheduleKind::GPipe, true, 2, 2, false),
            preset: "t".into(),
            losses: Vec::new(),
            step_times: Vec::new(),
            reports,
            samples_per_step: 2,
        }
    }

    #[test]
    fn measured_costs_orders_by_rank_and_attributes_loss() {
        // reports arrive out of rank order; costs must come back ranked
        let r = report_with(vec![wr(1), wr(0)]);
        let c = r.measured_costs().unwrap();
        assert_eq!(c.fwd, vec![1.0, 2.0]);
        // loss is the last rank's separately-timed mean, NOT folded
        // into (or zeroing out of) the p1 column
        assert_eq!(c.loss, 0.25);
        assert_eq!(c.p1, vec![2.0, 2.0]);
    }

    #[test]
    fn measured_costs_averages_comm_over_sending_ranks_only() {
        // rank 0 sent (mean 2 ms), rank 1 sent nothing: the comm floor
        // is the senders' mean, not dragged down by non-senders
        let r = report_with(vec![wr(0), wr(1)]);
        let c = r.measured_costs().unwrap();
        assert_eq!(c.comm, 0.002);
        // both ranks sent: plain mean
        let mut a = wr(0);
        a.mean_comm = 0.002;
        let mut b = wr(1);
        b.mean_comm = 0.004;
        let c = report_with(vec![a, b]).measured_costs().unwrap();
        assert!((c.comm - 0.003).abs() < 1e-12, "{}", c.comm);
        // nobody sent (single rank): comm stays 0
        let mut solo = wr(0);
        solo.mean_comm = 0.0;
        let c = report_with(vec![solo]).measured_costs();
        // 1-rank report against the 2-rank plan is fine for costs
        assert_eq!(c.unwrap().comm, 0.0);
    }

    #[test]
    fn step_makespans_segment_at_opt_across_ranks() {
        use crate::pipeline::stage::OpTiming;
        let t = |kind, mb, start: f64, end: f64| OpTiming {
            kind, mb, start, end,
        };
        let mut a = wr(0);
        a.timings = vec![
            t(SpanKind::Fwd, 0, 0.0, 1.0),
            t(SpanKind::Opt, 0, 1.0, 1.5),
            t(SpanKind::Fwd, 0, 2.0, 3.0),
            t(SpanKind::Opt, 0, 3.0, 3.25),
        ];
        a.comm_timings = vec![t(SpanKind::Comm, 0, 1.0, 1.1)];
        let mut b = wr(1);
        b.timings = vec![
            t(SpanKind::Fwd, 0, 0.5, 1.75),
            t(SpanKind::Opt, 0, 1.75, 2.0),
            t(SpanKind::Fwd, 0, 2.5, 3.5),
            t(SpanKind::Opt, 0, 3.5, 4.0),
        ];
        let r = report_with(vec![a, b]);
        let ms = r.step_makespans();
        // step 0: rank 0 starts at 0.0, rank 1's opt ends at 2.0
        // step 1: earliest start 2.0, latest end 4.0
        assert_eq!(ms.len(), 2);
        assert!((ms[0] - 2.0).abs() < 1e-12, "{ms:?}");
        assert!((ms[1] - 2.0).abs() < 1e-12, "{ms:?}");
        // the comm lane surfaces through comm_spans / trace_spans
        assert_eq!(r.comm_spans()[0].len(), 1);
        assert_eq!(r.comm_spans()[1].len(), 0);
        assert_eq!(r.trace_spans()[0].len(), 5);
        assert_eq!(
            r.trace_spans()[0].last().unwrap().label,
            SpanKind::Comm
        );
    }

    #[test]
    fn measured_costs_errors_on_missing_rank() {
        // one report whose rank is out of range == rank 0 missing
        let r = report_with(vec![wr(1)]);
        let err = r.measured_costs().unwrap_err().to_string();
        assert!(err.contains("out of range"), "{err}");
    }

    #[test]
    fn measured_costs_errors_on_duplicate_rank() {
        let r = report_with(vec![wr(0), wr(0)]);
        let err = r.measured_costs().unwrap_err().to_string();
        assert!(err.contains("duplicate"), "{err}");
    }

    #[test]
    fn comm_calibration_cells_override_the_floor() {
        let mut c = CommCalibration::with_floor(0.001);
        // unprobed cell: the floor (the old single-mean behavior)
        assert_eq!(c.comm_for(ScheduleKind::GPipe, 4), 0.001);
        c.record(ScheduleKind::GPipe, 4, 0.003);
        c.record(ScheduleKind::OneF1B1, 4, 0.002);
        c.record(ScheduleKind::GPipe, 4, 0.004); // last write wins
        assert_eq!(c.comm_for(ScheduleKind::GPipe, 4), 0.004);
        assert_eq!(c.comm_for(ScheduleKind::OneF1B1, 4), 0.002);
        assert_eq!(c.comm_for(ScheduleKind::OneF1B1, 8), 0.001);
        assert_eq!(c.cells().len(), 2);
        let base =
            report_with(vec![wr(0), wr(1)]).measured_costs().unwrap();
        let s = c.specialize(ScheduleKind::GPipe, 4, &base);
        assert_eq!(s.comm, 0.004);
        assert_eq!(s.fwd, base.fwd);
        assert_eq!(s.loss, base.loss);
    }

    #[test]
    fn recv_supervised_surfaces_the_tripped_cell_as_run_error() {
        let cell = FaultCell::new();
        cell.trip(Failure {
            kind: FailureKind::RankFailed,
            rank: 2,
            step: 5,
            cause: "dead executable".into(),
        });
        // channel alive but silent: the timeout tick notices the cell
        let (tx, rx) = channel::<usize>();
        let err = recv_supervised(&rx, &cell, "in test").unwrap_err();
        let run = err.downcast_ref::<RunError>().expect("typed RunError");
        assert_eq!(run.rank(), 2);
        assert_eq!(run.step(), 5);
        drop(tx);
        // disconnected with NO fault recorded: a plain untyped error
        let (tx2, rx2) = channel::<usize>();
        drop(tx2);
        let quiet = FaultCell::new();
        let err = recv_supervised(&rx2, &quiet, "in test").unwrap_err();
        assert!(err.downcast_ref::<RunError>().is_none());
        assert!(err.to_string().contains("in test"), "{err}");
    }
}

/// Replay a rank's executed (loop-mode) op sequence through the
/// manifest byte classes, mirroring exactly what `StageWorker` tells
/// its accountant per op.  Returns (peak, final live) of the modeled
/// classes.
fn replay_model_bytes(
    timings: &[crate::pipeline::stage::OpTiming],
    st: &StageInfo,
) -> (u64, u64) {
    let static_b = st.bytes.params * 3 + st.bytes.grads;
    let mut live = static_b;
    let mut peak = static_b;
    for t in timings {
        match t.kind {
            SpanKind::Fwd => live += st.bytes.res1 + st.bytes.res2,
            SpanKind::BwdP1 => {
                live = live - st.bytes.res1 + st.bytes.inter;
            }
            SpanKind::BwdP2 => live -= st.bytes.res2 + st.bytes.inter,
            // loss touches only Wire bytes (logits), not modeled classes
            SpanKind::Opt | SpanKind::Comm | SpanKind::Loss => {}
        }
        peak = peak.max(live);
    }
    (peak, live)
}
