//! Training orchestration.
//!
//! A [`Cluster`] spawns one worker thread per pipeline rank.  Workers
//! compile their stage executables **once** and then serve any number of
//! runs (different schedules, ±2BP, loop/concat p2) — compilation
//! dominates end-to-end time on this host, so the Fig 3/4 benchmarks
//! (32 cells) would be infeasible without executable reuse.  Between
//! runs each worker re-inits parameters from the seed, so every cell
//! sees an identical model + data stream (what makes the cross-schedule
//! equivalence checks meaningful).

use std::sync::mpsc::{channel, Receiver, Sender};
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use crate::config::{P2Mode, RunConfig};
use crate::models::{Manifest, StageInfo};
use crate::pipeline::comm::pipeline_links;
use crate::pipeline::stage::{StageWorker, WorkerReport};
use crate::schedule::{generate, validate::validate, Op, Plan, ScheduleKind};
use crate::sim::CostModel;
use crate::util::gantt::{Span, SpanKind};

/// Everything measured during a run.
#[derive(Debug)]
pub struct RunReport {
    pub plan: Plan,
    pub preset: String,
    /// Mean loss per step (averaged over microbatches; last rank).
    pub losses: Vec<f32>,
    /// Wall seconds per step (serialized on this 1-core host — see
    /// DESIGN.md §3; use `measured_costs` + the simulator for pipeline
    /// wall-clock).
    pub step_times: Vec<f64>,
    pub reports: Vec<WorkerReport>,
    pub samples_per_step: usize,
}

impl RunReport {
    /// Per-rank measured mean op costs, as a simulator CostModel.
    pub fn measured_costs(&self) -> CostModel {
        let n = self.reports.len();
        let pick = |f: fn(&WorkerReport) -> f64| -> Vec<f64> {
            (0..n)
                .map(|r| {
                    f(self
                        .reports
                        .iter()
                        .find(|w| w.rank == r)
                        .expect("missing rank report"))
                })
                .collect()
        };
        CostModel {
            fwd: pick(|w| w.mean_costs.0),
            p1: pick(|w| w.mean_costs.1),
            p2: pick(|w| w.mean_costs.2),
            opt: pick(|w| w.mean_costs.3),
            loss: 0.0, // folded into the last rank's p1 timing
            comm: 0.0,
            comm_inter_node: 0.0,
            ranks_per_node: usize::MAX,
            concat_factor: 1.0,
        }
    }

    /// Peak bytes per rank (the Fig 4 metric).
    pub fn peak_bytes(&self) -> Vec<u64> {
        let mut v = vec![0u64; self.reports.len()];
        for w in &self.reports {
            v[w.rank] = w.peak_bytes;
        }
        v
    }

    /// Peak of the simulator-modeled classes per rank (everything but
    /// the in-flight `Wire` buffers) — directly comparable to
    /// `SimResult::peak_bytes` from the same plan and
    /// `Manifest::mem_model` (see [`verify_report_against_sim`]).
    pub fn peak_model_bytes(&self) -> Vec<u64> {
        let mut v = vec![0u64; self.reports.len()];
        for w in &self.reports {
            v[w.rank] = w.peak_model;
        }
        v
    }

    pub fn max_peak(&self) -> u64 {
        self.peak_bytes().into_iter().max().unwrap_or(0)
    }

    /// Throughput from measured per-op costs replayed through the
    /// simulator (the calibrated pipeline wall-clock; samples/sec).
    pub fn simulated_throughput(&self) -> Result<f64> {
        let costs = self.measured_costs();
        let res = crate::sim::simulate(&self.plan, &costs, None)
            .map_err(|e| anyhow!("{e}"))?;
        Ok(self.samples_per_step as f64 / res.makespan)
    }

    /// Real spans of the measured steps (for gantt rendering).
    pub fn spans(&self) -> Vec<Vec<Span>> {
        let mut out = vec![Vec::new(); self.reports.len()];
        for w in &self.reports {
            out[w.rank] = w
                .timings
                .iter()
                .map(|t| Span {
                    start: t.start,
                    end: t.end,
                    label: t.kind,
                    mb: t.mb,
                })
                .collect();
        }
        out
    }

    /// Sum of per-rank parameter checksums (equivalence testing).
    pub fn param_checksum(&self) -> f64 {
        self.reports.iter().map(|w| w.param_checksum).sum()
    }

    /// Per-rank raw-byte parameter digests (rank order) — bit-exact
    /// equivalence: two runs have equal digests iff every parameter
    /// byte matches (up to 64-bit FNV collisions), unlike the
    /// sign-blind [`Self::param_checksum`].
    pub fn param_digests(&self) -> Vec<u64> {
        let mut v = vec![0u64; self.reports.len()];
        for w in &self.reports {
            v[w.rank] = w.param_digest;
        }
        v
    }

    pub fn mean_step_time(&self) -> f64 {
        if self.step_times.is_empty() {
            0.0
        } else {
            self.step_times.iter().sum::<f64>() / self.step_times.len() as f64
        }
    }
}

enum Cmd {
    Run {
        ops: Vec<Op>,
        steps: usize,
        greedy: bool,
        two_bp: bool,
        p2_mode: P2Mode,
        seed: u64,
        data_cycle: usize,
    },
    Shutdown,
}

/// A persistent set of stage workers for one preset.  Compiles all
/// artifacts once; serves many runs.
pub struct Cluster {
    manifest: Manifest,
    cmd_txs: Vec<Sender<Cmd>>,
    rep_rx: Receiver<(usize, WorkerReport)>,
    done_rx: Receiver<(usize, usize)>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl Cluster {
    /// Spawn workers and compile every stage's executables.
    pub fn new(cfg: &RunConfig) -> Result<Cluster> {
        let manifest = Manifest::load(&cfg.artifacts, &cfg.preset)
            .with_context(|| format!("loading preset {}", cfg.preset))?;
        let n = manifest.n_stages;
        let links = pipeline_links(n);
        let epoch = Instant::now();
        let (rep_tx, rep_rx) = channel::<(usize, WorkerReport)>();
        let (done_tx, done_rx) = channel::<(usize, usize)>();
        let (ready_tx, ready_rx) =
            channel::<core::result::Result<(), String>>();

        // workers start with a neutral plan; real mode comes per-command
        let init_plan = generate(ScheduleKind::GPipe, true, n, n, false);
        let mut cmd_txs = Vec::new();
        let mut handles = Vec::new();
        for (rank, rank_links) in links.into_iter().enumerate() {
            let manifest_cl = manifest.clone();
            let plan_cl = init_plan.clone();
            let (cmd_tx, cmd_rx) = channel::<Cmd>();
            cmd_txs.push(cmd_tx);
            let rep_tx = rep_tx.clone();
            let done_tx = done_tx.clone();
            let ready_tx = ready_tx.clone();
            let seed = cfg.seed;
            handles.push(
                std::thread::Builder::new()
                    .name(format!("stage-{rank}"))
                    .spawn(move || {
                        let mut w = match StageWorker::new(
                            rank, &manifest_cl, &plan_cl, P2Mode::Loop,
                            rank_links, seed, 0, epoch,
                        ) {
                            Ok(w) => {
                                let _ = ready_tx.send(Ok(()));
                                w
                            }
                            Err(e) => {
                                let _ = ready_tx
                                    .send(Err(format!("stage {rank}: {e:#}")));
                                return;
                            }
                        };
                        while let Ok(cmd) = cmd_rx.recv() {
                            match cmd {
                                Cmd::Shutdown => break,
                                Cmd::Run {
                                    ops, steps, greedy, two_bp, p2_mode,
                                    seed, data_cycle,
                                } => {
                                    // errors poison the pipeline loudly:
                                    // the dying thread drops its links, so
                                    // peers unblock via channel hangup
                                    if let Err(e) = w.reset(
                                        seed, greedy, two_bp, p2_mode,
                                        data_cycle,
                                    ) {
                                        panic!("stage {rank} reset: {e:#}");
                                    }
                                    for s in 0..steps {
                                        if let Err(e) = w.run_step(&ops) {
                                            panic!("stage {rank}: {e:#}");
                                        }
                                        let _ = done_tx.send((rank, s));
                                    }
                                    match w.report() {
                                        Ok(r) => {
                                            let _ = rep_tx.send((rank, r));
                                        }
                                        Err(e) => panic!(
                                            "stage {rank} report: {e:#}"
                                        ),
                                    }
                                }
                            }
                        }
                    })
                    .context("spawning stage thread")?,
            );
        }
        for _ in 0..n {
            ready_rx
                .recv()
                .map_err(|_| anyhow!("worker died during startup"))?
                .map_err(|e| anyhow!(e))?;
        }
        Ok(Cluster { manifest, cmd_txs, rep_rx, done_rx, handles })
    }

    pub fn n_stages(&self) -> usize {
        self.manifest.n_stages
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Execute one run (a full schedule for `cfg.steps` training steps).
    pub fn run(&self, cfg: &RunConfig) -> Result<RunReport> {
        let n = self.manifest.n_stages;
        let m = cfg.microbatches(n);
        let plan = generate(cfg.schedule, cfg.two_bp, n, m,
                            cfg.p2_mode == P2Mode::Concat);
        validate(&plan).map_err(|e| anyhow!("invalid plan: {e}"))?;

        for (rank, tx) in self.cmd_txs.iter().enumerate() {
            tx.send(Cmd::Run {
                ops: plan.ranks[rank].clone(),
                steps: cfg.steps,
                greedy: plan.greedy_p2,
                two_bp: plan.two_bp,
                p2_mode: cfg.p2_mode,
                seed: cfg.seed,
                data_cycle: cfg.data_cycle,
            })
            .map_err(|_| anyhow!("stage {rank} is gone"))?;
        }

        // step s completes when all n ranks reported it
        let mut step_times = Vec::with_capacity(cfg.steps);
        let mut completed = vec![0usize; cfg.steps];
        let mut t0 = Instant::now();
        let mut next_step = 0usize;
        while next_step < cfg.steps {
            let (_rank, s) = self
                .done_rx
                .recv()
                .map_err(|_| anyhow!("workers died mid-run"))?;
            completed[s] += 1;
            while next_step < cfg.steps && completed[next_step] == n {
                let dt = t0.elapsed().as_secs_f64();
                step_times.push(dt);
                if cfg.verbose {
                    eprintln!("step {next_step}: {:.3}s", dt);
                }
                t0 = Instant::now();
                next_step += 1;
            }
        }

        let mut reports: Vec<WorkerReport> = Vec::with_capacity(n);
        for _ in 0..n {
            let (_, r) = self
                .rep_rx
                .recv()
                .map_err(|_| anyhow!("workers died before reporting"))?;
            reports.push(r);
        }
        reports.sort_by_key(|w| w.rank);

        let last = reports
            .iter()
            .find(|w| w.rank == n - 1)
            .ok_or_else(|| anyhow!("missing last-rank report"))?;
        let losses: Vec<f32> = last
            .losses
            .chunks(m)
            .map(|c| c.iter().sum::<f32>() / c.len() as f32)
            .collect();

        Ok(RunReport {
            plan,
            preset: cfg.preset.clone(),
            losses,
            step_times,
            reports,
            samples_per_step: self.manifest.samples_per_microbatch * m,
        })
    }
}

impl Drop for Cluster {
    fn drop(&mut self) {
        for tx in &self.cmd_txs {
            let _ = tx.send(Cmd::Shutdown);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// One-shot convenience: build a cluster, run once.
pub fn train(cfg: &RunConfig) -> Result<RunReport> {
    let cluster = Cluster::new(cfg)?;
    cluster.run(cfg)
}

/// Cross-check a finished run against the simulator and the manifest
/// byte classes — the `twobp train --synthetic` smoke contract:
///
/// 1. **Op order.**  For deterministic (non-greedy) plans whose p2 ops
///    are all singletons (every generated fused plan), every rank's
///    executed `(kind, microbatch)` sequence must equal the simulated
///    timeline in every step.  Otherwise — greedy-p2 plans, where real
///    arrival timing may legally fill deferred p2 work at different
///    instants than the modeled timeline, and DSL plans with
///    multi-microbatch p2 batches, where sim spans and executed spans
///    differ in granularity — the check weakens to: the Fwd/BwdP1
///    backbone matches the sim order, and (greedy only) every
///    microbatch's p2 ran within the step, never before its own p1.
/// 2. **Memory.**  Replaying the rank's *own* executed op order through
///    the manifest byte classes must reproduce the byte-exact
///    accountant's model peak ([`crate::pipeline::memory::MemAccountant::peak_model`]);
///    for non-greedy plans that peak must also equal the simulator's
///    `peak_bytes` under `Manifest::mem_model`.
///
/// Concat-mode p2 (`Op::{Flush, BwdP2} { concat: true }`) collapses
/// several microbatches into one recorded span, so the per-span replay
/// and p2-coverage checks are skipped for such plans — their gradient
/// equivalence is covered separately by the concat-vs-loop tests.
pub fn verify_report_against_sim(
    report: &RunReport,
    manifest: &Manifest,
    steps: usize,
) -> Result<()> {
    let plan = &report.plan;
    let costs = manifest.cost_model_from_flops(0.0);
    let mm = manifest.mem_model();
    let sim = crate::sim::simulate(plan, &costs, Some(&mm))
        .map_err(|e| anyhow!("simulating {}: {e}", plan.describe()))?;
    let concat = plan.ranks.iter().flatten().any(|op| {
        matches!(
            op,
            Op::Flush { concat: true, .. } | Op::BwdP2 { concat: true, .. }
        )
    });
    // The strict span-for-span comparison assumes every executed p2
    // span covers exactly one microbatch.  Generated fused plans pair
    // each BwdP1 with a singleton BwdP2 so that holds; DSL plans can
    // carry multi-microbatch BwdP2 or Flush ops on non-greedy ranks,
    // where the sim records one span per batch but the executor one
    // per microbatch — fall back to the backbone checks for those.
    let strict = !plan.greedy_p2
        && plan.ranks.iter().flatten().all(|op| match op {
            Op::BwdP2 { mbs, .. } => mbs.len() == 1,
            Op::Flush { .. } => false,
            _ => true,
        });
    let model_peaks = report.peak_model_bytes();

    for w in &report.reports {
        let r = w.rank;
        let sim_seq: Vec<(SpanKind, u32)> =
            sim.spans[r].iter().map(|s| (s.label, s.mb)).collect();

        // split the rank's timeline into steps at each OptStep
        let mut segs: Vec<&[crate::pipeline::stage::OpTiming]> = Vec::new();
        let mut seg_start = 0usize;
        for (i, t) in w.timings.iter().enumerate() {
            if t.kind == SpanKind::Opt {
                segs.push(&w.timings[seg_start..=i]);
                seg_start = i + 1;
            }
        }
        if seg_start != w.timings.len() {
            bail!(
                "rank {r}: {} trailing ops after the last OptStep",
                w.timings.len() - seg_start
            );
        }
        if segs.len() != steps {
            bail!("rank {r}: {} executed steps, expected {steps}",
                  segs.len());
        }

        for (si, seg) in segs.iter().enumerate() {
            let seq: Vec<(SpanKind, u32)> =
                seg.iter().map(|t| (t.kind, t.mb)).collect();
            if strict {
                if seq != sim_seq {
                    bail!(
                        "rank {r} step {si}: executed op order diverges \
                         from the sim timeline\n  executed: {seq:?}\n  \
                         sim:      {sim_seq:?}"
                    );
                }
                continue;
            }
            let pick = |xs: &[(SpanKind, u32)], k: SpanKind| -> Vec<u32> {
                xs.iter()
                    .filter(|(kk, _)| *kk == k)
                    .map(|(_, mb)| *mb)
                    .collect()
            };
            for kind in [SpanKind::Fwd, SpanKind::BwdP1] {
                if pick(&seq, kind) != pick(&sim_seq, kind) {
                    bail!(
                        "rank {r} step {si}: {kind:?} order diverges from \
                         the sim timeline"
                    );
                }
            }
            if !concat && plan.greedy_p2 {
                let mut p2 = pick(&seq, SpanKind::BwdP2);
                p2.sort_unstable();
                let want: Vec<u32> =
                    (0..plan.n_microbatches as u32).collect();
                if p2 != want {
                    bail!(
                        "rank {r} step {si}: p2 coverage {p2:?} != every \
                         microbatch 0..{}",
                        plan.n_microbatches
                    );
                }
                for t in seg.iter().filter(|t| t.kind == SpanKind::BwdP2) {
                    let p1_end = seg
                        .iter()
                        .find(|u| u.kind == SpanKind::BwdP1 && u.mb == t.mb)
                        .map(|u| u.end);
                    match p1_end {
                        Some(e) if e <= t.start + 1e-9 => {}
                        Some(_) => bail!(
                            "rank {r} step {si}: p2 of mb {} started \
                             before its p1 finished",
                            t.mb
                        ),
                        None => bail!(
                            "rank {r} step {si}: p2 of mb {} has no p1",
                            t.mb
                        ),
                    }
                }
            }
        }

        // memory: replay the executed order through the byte classes
        let st = &manifest.stages[r];
        if !concat {
            let (peak, live_end) = replay_model_bytes(&w.timings, st);
            if peak != model_peaks[r] {
                bail!(
                    "rank {r}: accountant model peak {} != {peak} from \
                     replaying the executed op order through the manifest \
                     byte classes",
                    model_peaks[r]
                );
            }
            let static_b = st.bytes.params * 3 + st.bytes.grads;
            if live_end != static_b {
                bail!(
                    "rank {r}: {live_end} model bytes live after the run, \
                     expected the static {static_b}"
                );
            }
        }
        if strict && !concat && model_peaks[r] != sim.peak_bytes[r] {
            bail!(
                "rank {r}: accountant model peak {} != simulator peak {} \
                 (Manifest::mem_model)",
                model_peaks[r],
                sim.peak_bytes[r]
            );
        }
    }
    Ok(())
}

/// Replay a rank's executed (loop-mode) op sequence through the
/// manifest byte classes, mirroring exactly what `StageWorker` tells
/// its accountant per op.  Returns (peak, final live) of the modeled
/// classes.
fn replay_model_bytes(
    timings: &[crate::pipeline::stage::OpTiming],
    st: &StageInfo,
) -> (u64, u64) {
    let static_b = st.bytes.params * 3 + st.bytes.grads;
    let mut live = static_b;
    let mut peak = static_b;
    for t in timings {
        match t.kind {
            SpanKind::Fwd => live += st.bytes.res1 + st.bytes.res2,
            SpanKind::BwdP1 => {
                live = live - st.bytes.res1 + st.bytes.inter;
            }
            SpanKind::BwdP2 => live -= st.bytes.res2 + st.bytes.inter,
            SpanKind::Opt | SpanKind::Comm => {}
        }
        peak = peak.max(live);
    }
    (peak, live)
}
