//! Byte-exact memory accountant — the instrument behind Fig 4 / Fig 5.
//!
//! Tracks the live bytes a rank holds in each residency class from the
//! paper's §4.2 taxonomy:
//!
//! * `Static`  — params + grad accumulators + optimizer state
//! * `Res1`    — activations needed only by backward-p1 (released at p1)
//! * `Res2`    — activations held across the p1→p2 gap
//! * `Inter`   — the intermediate derivatives ∂L/∂z produced by p1
//! * `Wire`    — in-flight activation/gradient buffers (recv'd, logits)
//!
//! The invariant (tested): at the end of every training step, all
//! dynamic classes return to zero — a stash leak means a schedule bug.

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Class {
    Static,
    Res1,
    Res2,
    Inter,
    Wire,
}

const NCLASS: usize = 5;

fn idx(c: Class) -> usize {
    match c {
        Class::Static => 0,
        Class::Res1 => 1,
        Class::Res2 => 2,
        Class::Inter => 3,
        Class::Wire => 4,
    }
}

/// Per-rank memory accountant.
#[derive(Debug, Default, Clone)]
pub struct MemAccountant {
    live: [u64; NCLASS],
    peak_total: u64,
    peak_by_class: [u64; NCLASS],
    peak_model: u64,
}

impl MemAccountant {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn alloc(&mut self, class: Class, bytes: u64) {
        self.live[idx(class)] += bytes;
        let total = self.total();
        if total > self.peak_total {
            self.peak_total = total;
        }
        if class != Class::Wire {
            let model = total - self.live[idx(Class::Wire)];
            if model > self.peak_model {
                self.peak_model = model;
            }
        }
        let i = idx(class);
        if self.live[i] > self.peak_by_class[i] {
            self.peak_by_class[i] = self.live[i];
        }
    }

    pub fn free(&mut self, class: Class, bytes: u64) {
        let i = idx(class);
        assert!(
            self.live[i] >= bytes,
            "memory accountant underflow: freeing {bytes} from {:?} (live {})",
            class,
            self.live[i]
        );
        self.live[i] -= bytes;
    }

    pub fn total(&self) -> u64 {
        self.live.iter().sum()
    }

    pub fn live(&self, class: Class) -> u64 {
        self.live[idx(class)]
    }

    /// Peak of the summed classes — the paper's per-GPU "peak reserved".
    pub fn peak(&self) -> u64 {
        self.peak_total
    }

    pub fn peak_of(&self, class: Class) -> u64 {
        self.peak_by_class[idx(class)]
    }

    /// Peak of the *simulator-modeled* classes — everything except
    /// `Wire` (the simulator's `MemModel` treats communication as
    /// latency, not resident bytes).  Directly comparable to the
    /// per-rank `SimResult::peak_bytes` of the same plan replayed
    /// through `Manifest::mem_model` (asserted byte-exactly by
    /// `pipeline::verify_report_against_sim`).
    pub fn peak_model(&self) -> u64 {
        self.peak_model
    }

    /// All dynamic classes must be zero at a step boundary.
    pub fn assert_step_balanced(&self) {
        for c in [Class::Res1, Class::Res2, Class::Inter, Class::Wire] {
            assert_eq!(
                self.live(c),
                0,
                "stash leak at step end in {c:?}: {} bytes",
                self.live(c)
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tracks_peak_across_classes() {
        let mut m = MemAccountant::new();
        m.alloc(Class::Static, 100);
        m.alloc(Class::Res2, 50);
        m.alloc(Class::Inter, 25);
        assert_eq!(m.peak(), 175);
        m.free(Class::Res2, 50);
        m.free(Class::Inter, 25);
        assert_eq!(m.peak(), 175);
        assert_eq!(m.total(), 100);
    }

    #[test]
    fn step_balance_check_passes_when_drained() {
        let mut m = MemAccountant::new();
        m.alloc(Class::Static, 10);
        m.alloc(Class::Res1, 5);
        m.free(Class::Res1, 5);
        m.assert_step_balanced();
    }

    #[test]
    #[should_panic(expected = "stash leak")]
    fn step_balance_check_catches_leak() {
        let mut m = MemAccountant::new();
        m.alloc(Class::Res2, 5);
        m.assert_step_balanced();
    }

    #[test]
    #[should_panic(expected = "underflow")]
    fn double_free_caught() {
        let mut m = MemAccountant::new();
        m.alloc(Class::Inter, 5);
        m.free(Class::Inter, 6);
    }

    #[test]
    fn per_class_peaks() {
        let mut m = MemAccountant::new();
        m.alloc(Class::Res1, 30);
        m.free(Class::Res1, 30);
        m.alloc(Class::Res1, 20);
        assert_eq!(m.peak_of(Class::Res1), 30);
    }

    #[test]
    fn model_peak_excludes_wire() {
        let mut m = MemAccountant::new();
        m.alloc(Class::Static, 100);
        m.alloc(Class::Wire, 1000);
        m.alloc(Class::Res2, 50);
        assert_eq!(m.peak(), 1150);
        assert_eq!(m.peak_model(), 150);
        m.free(Class::Wire, 1000);
        m.alloc(Class::Inter, 25);
        assert_eq!(m.peak_model(), 175);
    }

    /// The accountant against an independent shadow model: for any
    /// sequence of allocs and in-budget frees, live counts and every
    /// peak (total, per-class, model) match exact shadow bookkeeping,
    /// and no counter ever underflows (the accountant panics if one
    /// would go negative — surviving the sequence *is* the property).
    #[test]
    fn prop_accountant_matches_shadow_model() {
        use crate::util::prng::SplitMix64;
        use crate::util::proptest::{check, gen};

        const CLASSES: [Class; 5] = [Class::Static, Class::Res1,
                                     Class::Res2, Class::Inter, Class::Wire];
        check(
            "MemAccountant bookkeeping == shadow model",
            200,
            |rng| (gen::usize_in(rng, 1, 60), rng.next_u64()),
            |&(len, seed)| {
                let mut rng = SplitMix64::new(seed);
                let mut m = MemAccountant::new();
                let mut live = [0u64; 5];
                let mut peak_total = 0u64;
                let mut peak_class = [0u64; 5];
                let mut peak_model = 0u64;
                for _ in 0..len {
                    let ci = rng.below(5) as usize;
                    let class = CLASSES[ci];
                    let do_free = rng.below(2) == 1 && live[ci] > 0;
                    if do_free {
                        let bytes = rng.below(live[ci] + 1);
                        m.free(class, bytes);
                        live[ci] -= bytes;
                    } else {
                        let bytes = rng.below(1 << 20);
                        m.alloc(class, bytes);
                        live[ci] += bytes;
                        let total: u64 = live.iter().sum();
                        peak_total = peak_total.max(total);
                        peak_class[ci] = peak_class[ci].max(live[ci]);
                        if class != Class::Wire {
                            peak_model = peak_model.max(total - live[4]);
                        }
                    }
                    let total: u64 = live.iter().sum();
                    if m.total() != total {
                        return Err(format!("total {} != {total}", m.total()));
                    }
                    for (j, c) in CLASSES.iter().enumerate() {
                        if m.live(*c) != live[j] {
                            return Err(format!(
                                "live[{c:?}] {} != {}", m.live(*c), live[j]
                            ));
                        }
                    }
                }
                if m.peak() != peak_total {
                    return Err(format!("peak {} != {peak_total}", m.peak()));
                }
                if m.peak_model() != peak_model {
                    return Err(format!(
                        "model peak {} != {peak_model}", m.peak_model()
                    ));
                }
                for (j, c) in CLASSES.iter().enumerate() {
                    if m.peak_of(*c) != peak_class[j] {
                        return Err(format!(
                            "peak_of[{c:?}] {} != {}",
                            m.peak_of(*c), peak_class[j]
                        ));
                    }
                }
                Ok(())
            },
        );
    }
}
