//! Inter-stage communication: tagged point-to-point channels.
//!
//! Simulates NCCL p2p send/recv between adjacent pipeline ranks.  Each
//! message is tagged with its microbatch id; the receiver can ask for a
//! specific tag (out-of-order arrivals are parked), and can *poll*
//! non-blockingly — the primitive the 2BP greedy-p2 fill rule is built
//! on ("if the next activation/gradient hasn't arrived, do deferred
//! weight-gradient work instead of idling").
//!
//! Fault-tolerance hooks (see `pipeline/fault.rs`):
//!
//! - [`TaggedRx::recv_timeout`] is the deadline-based receive the
//!   supervised executor uses instead of the infinite [`TaggedRx::recv`]
//!   — a stalled peer becomes a [`RecvOutcome::TimedOut`] the worker
//!   can escalate to a `CommTimeout`, never a hang;
//! - [`pipeline_links_with`] arms every link's sender with a seeded
//!   [`CommFaultCfg`] injector: drops and delays are a pure function of
//!   (seed, link id, send index), so a failing scenario replays
//!   identically on every run.

use std::cell::Cell;
use std::collections::HashMap;
use std::sync::mpsc::{
    channel, Receiver, RecvTimeoutError, Sender, TryRecvError,
};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::pipeline::fault::CommFaultCfg;
use crate::runtime::HostTensor;
use crate::util::prng::SplitMix64;

/// A tagged tensor message (one activation or gradient for one mb).
pub struct Msg {
    pub mb: u32,
    pub tensor: HostTensor,
}

/// Seeded per-link fault state: which send indices drop is decided by
/// a PRNG keyed on (config seed, link id, send index) — no global
/// state, no wall clock, bit-identical across runs.
struct LinkFault {
    cfg: CommFaultCfg,
    link_id: u64,
    sends: Cell<u64>,
}

impl LinkFault {
    /// Advance the send counter and decide this send's fate.
    fn drops_this_send(&self) -> bool {
        let ix = self.sends.get();
        self.sends.set(ix + 1);
        if self.cfg.drop_prob <= 0.0 {
            return false;
        }
        let mut rng = SplitMix64::new(
            self.cfg.seed
                ^ self.link_id.wrapping_mul(0x9e37_79b9_7f4a_7c15)
                ^ ix.wrapping_mul(0xff51_afd7_ed55_8ccd),
        );
        rng.next_f64() < self.cfg.drop_prob
    }
}

pub struct TaggedTx {
    tx: Sender<Msg>,
    /// Present only on links armed by [`pipeline_links_with`] with an
    /// active [`CommFaultCfg`]; healthy links pay nothing.
    fault: Option<LinkFault>,
}

impl TaggedTx {
    pub fn send(&self, mb: u32, tensor: HostTensor) -> Result<()> {
        if let Some(f) = &self.fault {
            if f.drops_this_send() {
                // a dropped message is *silent*: the receiver's
                // deadline — not this sender — detects it
                return Ok(());
            }
            if f.cfg.delay_ns > 0 {
                std::thread::sleep(Duration::from_nanos(f.cfg.delay_ns));
            }
        }
        self.tx
            .send(Msg { mb, tensor })
            .map_err(|_| anyhow!("peer rank hung up"))
    }
}

/// What a deadline-based receive resolved to.
#[derive(Debug)]
pub enum RecvOutcome {
    Got(HostTensor),
    /// Nothing tagged `mb` arrived before the deadline.
    TimedOut,
    /// The sender is gone and the channel is drained of other tags.
    Disconnected,
}

pub struct TaggedRx {
    rx: Receiver<Msg>,
    parked: HashMap<u32, HostTensor>,
}

impl TaggedRx {
    /// Non-blocking: is the message for `mb` already here?
    pub fn poll(&mut self, mb: u32) -> bool {
        if self.parked.contains_key(&mb) {
            return true;
        }
        loop {
            match self.rx.try_recv() {
                Ok(m) => {
                    let hit = m.mb == mb;
                    self.parked.insert(m.mb, m.tensor);
                    if hit {
                        return true;
                    }
                }
                Err(TryRecvError::Empty) | Err(TryRecvError::Disconnected) => {
                    return false;
                }
            }
        }
    }

    /// Blocking receive of the message tagged `mb`.  Unsupervised — can
    /// wait forever on a stalled peer; the executor's workers use
    /// [`Self::recv_timeout`] instead.
    pub fn recv(&mut self, mb: u32) -> Result<HostTensor> {
        if let Some(t) = self.parked.remove(&mb) {
            return Ok(t);
        }
        loop {
            let m = self
                .rx
                .recv()
                .map_err(|_| anyhow!("peer rank hung up waiting for mb {mb}"))?;
            if m.mb == mb {
                return Ok(m.tensor);
            }
            self.parked.insert(m.mb, m.tensor);
        }
    }

    /// Deadline-based receive of the message tagged `mb`: park
    /// mismatched tags as they arrive, give up at `timeout`.  Parked
    /// messages are never lost on the timeout path — a later call (or
    /// `poll`/`take_parked`) still sees them.
    pub fn recv_timeout(&mut self, mb: u32, timeout: Duration) -> RecvOutcome {
        if let Some(t) = self.parked.remove(&mb) {
            return RecvOutcome::Got(t);
        }
        let deadline = Instant::now() + timeout;
        loop {
            let remaining = deadline.saturating_duration_since(Instant::now());
            if remaining.is_zero() {
                return RecvOutcome::TimedOut;
            }
            match self.rx.recv_timeout(remaining) {
                Ok(m) => {
                    if m.mb == mb {
                        return RecvOutcome::Got(m.tensor);
                    }
                    self.parked.insert(m.mb, m.tensor);
                }
                Err(RecvTimeoutError::Timeout) => return RecvOutcome::TimedOut,
                Err(RecvTimeoutError::Disconnected) => {
                    return RecvOutcome::Disconnected;
                }
            }
        }
    }

    /// Take an already-parked message without touching the channel.
    pub fn take_parked(&mut self, mb: u32) -> Option<HostTensor> {
        self.parked.remove(&mb)
    }
}

fn link_with(fault: Option<&CommFaultCfg>, link_id: u64) -> (TaggedTx, TaggedRx) {
    let (tx, rx) = channel();
    let fault = fault.filter(|c| c.active()).map(|cfg| LinkFault {
        cfg: *cfg,
        link_id,
        sends: Cell::new(0),
    });
    (TaggedTx { tx, fault }, TaggedRx { rx, parked: HashMap::new() })
}

/// Create a healthy tagged p2p link.
pub fn link() -> (TaggedTx, TaggedRx) {
    link_with(None, 0)
}

/// The channel endpoints owned by one rank.
#[derive(Default)]
pub struct RankLinks {
    /// Activations arriving from rank-1 (None on rank 0).
    pub act_in: Option<TaggedRx>,
    /// Activations leaving to rank+1 (None on the last rank).
    pub act_out: Option<TaggedTx>,
    /// Gradients arriving from rank+1 (None on the last rank).
    pub grad_in: Option<TaggedRx>,
    /// Gradients leaving to rank-1 (None on rank 0).
    pub grad_out: Option<TaggedTx>,
}

/// Wire up a linear pipeline of `n` healthy ranks.
pub fn pipeline_links(n: usize) -> Vec<RankLinks> {
    pipeline_links_with(n, None)
}

/// Wire up a linear pipeline of `n` ranks, arming every link with the
/// given fault injector (activation link `r -> r+1` gets id `2r`, the
/// paired gradient link id `2r + 1`, so each link draws an independent
/// deterministic drop/delay stream from the shared seed).
pub fn pipeline_links_with(
    n: usize,
    fault: Option<&CommFaultCfg>,
) -> Vec<RankLinks> {
    let mut links: Vec<RankLinks> =
        (0..n).map(|_| RankLinks::default()).collect();
    for r in 0..n.saturating_sub(1) {
        let (atx, arx) = link_with(fault, (r as u64) * 2);
        links[r].act_out = Some(atx);
        links[r + 1].act_in = Some(arx);
        let (gtx, grx) = link_with(fault, (r as u64) * 2 + 1);
        links[r + 1].grad_out = Some(gtx);
        links[r].grad_in = Some(grx);
    }
    links
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::DType;
    use crate::util::proptest::{check, gen};

    fn t(v: f32) -> HostTensor {
        HostTensor::from_f32(&[1], &[v])
    }

    #[test]
    fn tagged_out_of_order_delivery() {
        let (tx, mut rx) = link();
        tx.send(1, t(1.0)).unwrap();
        tx.send(0, t(0.0)).unwrap();
        assert_eq!(rx.recv(0).unwrap().to_f32(), vec![0.0]);
        assert_eq!(rx.recv(1).unwrap().to_f32(), vec![1.0]);
    }

    #[test]
    fn poll_parks_mismatches() {
        let (tx, mut rx) = link();
        assert!(!rx.poll(0));
        tx.send(2, t(2.0)).unwrap();
        assert!(!rx.poll(0));
        tx.send(0, t(0.0)).unwrap();
        assert!(rx.poll(0));
        assert!(rx.take_parked(2).is_some());
    }

    #[test]
    fn pipeline_links_shape() {
        let links = pipeline_links(3);
        assert!(links[0].act_in.is_none());
        assert!(links[0].act_out.is_some());
        assert!(links[0].grad_in.is_some());
        assert!(links[0].grad_out.is_none());
        assert!(links[2].act_in.is_some());
        assert!(links[2].act_out.is_none());
        assert!(links[2].grad_in.is_none());
        assert!(links[2].grad_out.is_some());
        let _ = DType::F32;
    }

    #[test]
    fn cross_thread_transfer() {
        let (tx, mut rx) = link();
        let h = std::thread::spawn(move || {
            for mb in (0..4u32).rev() {
                tx.send(mb, t(mb as f32)).unwrap();
            }
        });
        for mb in 0..4u32 {
            assert_eq!(rx.recv(mb).unwrap().to_f32(), vec![mb as f32]);
        }
        h.join().unwrap();
    }

    #[test]
    fn recv_timeout_happy_timeout_and_disconnect() {
        let (tx, mut rx) = link();
        tx.send(1, t(1.0)).unwrap();
        // parked-on-arrival path: ask for 1 directly
        match rx.recv_timeout(1, Duration::from_millis(100)) {
            RecvOutcome::Got(h) => assert_eq!(h.to_f32(), vec![1.0]),
            other => panic!("expected Got, saw {other:?}"),
        }
        // nothing tagged 0 in flight: fires TimedOut within the deadline
        let t0 = Instant::now();
        assert!(matches!(
            rx.recv_timeout(0, Duration::from_millis(20)),
            RecvOutcome::TimedOut
        ));
        assert!(t0.elapsed() < Duration::from_secs(2));
        // sender gone + channel drained: Disconnected, not a hang
        drop(tx);
        assert!(matches!(
            rx.recv_timeout(0, Duration::from_millis(20)),
            RecvOutcome::Disconnected
        ));
    }

    #[test]
    fn recv_timeout_parks_mismatches_without_losing_them() {
        let (tx, mut rx) = link();
        tx.send(7, t(7.0)).unwrap();
        assert!(matches!(
            rx.recv_timeout(0, Duration::from_millis(10)),
            RecvOutcome::TimedOut
        ));
        // the mismatched tag survived the timeout
        assert_eq!(rx.take_parked(7).unwrap().to_f32(), vec![7.0]);
        drop(tx);
    }

    #[test]
    fn drops_are_deterministic_per_seed_and_silent() {
        let cfg = CommFaultCfg { seed: 42, drop_prob: 0.5, delay_ns: 0 };
        let pattern = |cfg: &CommFaultCfg| -> Vec<bool> {
            let (tx, mut rx) = link_with(Some(cfg), 3);
            let mut got = Vec::new();
            for mb in 0..32u32 {
                tx.send(mb, t(mb as f32)).unwrap();
                got.push(rx.poll(mb));
            }
            got
        };
        let a = pattern(&cfg);
        let b = pattern(&cfg);
        assert_eq!(a, b, "same seed must reproduce the same drops");
        assert!(a.iter().any(|x| *x), "p=0.5 should deliver some");
        assert!(a.iter().any(|x| !*x), "p=0.5 should drop some");
        // a different seed draws a different pattern (32 sends at
        // p=0.5 colliding by chance is a 2^-32 event)
        let c = pattern(&CommFaultCfg { seed: 43, ..cfg });
        assert_ne!(a, c);
        // drop_prob 1.0 starves the receiver into TimedOut
        let (tx, mut rx) =
            link_with(Some(&CommFaultCfg { seed: 1, drop_prob: 1.0, delay_ns: 0 }), 0);
        tx.send(0, t(0.0)).unwrap();
        assert!(matches!(
            rx.recv_timeout(0, Duration::from_millis(10)),
            RecvOutcome::TimedOut
        ));
    }

    #[test]
    fn inactive_fault_cfg_arms_nothing() {
        let quiet = CommFaultCfg { seed: 9, drop_prob: 0.0, delay_ns: 0 };
        let links = pipeline_links_with(2, Some(&quiet));
        assert!(links[0].act_out.as_ref().unwrap().fault.is_none());
        // and an active one does arm the sender
        let noisy = CommFaultCfg { seed: 9, drop_prob: 0.1, delay_ns: 0 };
        let links = pipeline_links_with(2, Some(&noisy));
        assert!(links[0].act_out.as_ref().unwrap().fault.is_some());
    }

    /// Satellite: parked messages are never lost under arbitrary
    /// arrival orders — send a random permutation, receive in order.
    #[test]
    fn prop_out_of_order_delivery_loses_nothing() {
        check(
            "comm-permutation",
            64,
            |r| {
                let n = gen::usize_in(r, 1, 12);
                let mut perm: Vec<u32> = (0..n as u32).collect();
                // Fisher–Yates off the harness PRNG
                for i in (1..n).rev() {
                    let j = gen::usize_in(r, 0, i);
                    perm.swap(i, j);
                }
                perm
            },
            |perm| {
                let (tx, mut rx) = link();
                for &mb in perm {
                    tx.send(mb, t(mb as f32)).unwrap();
                }
                for mb in 0..perm.len() as u32 {
                    let got = rx
                        .recv_timeout(mb, Duration::from_secs(5));
                    match got {
                        RecvOutcome::Got(h) if h.to_f32() == vec![mb as f32] => {}
                        other => {
                            return Err(format!(
                                "mb {mb} of {perm:?}: {other:?}"
                            ))
                        }
                    }
                }
                Ok(())
            },
        );
    }

    /// Satellite: after the peer disconnects, `poll` still drains the
    /// already-parked tags and the missing tag resolves to
    /// Disconnected — never a hang, never a lost message.
    #[test]
    fn prop_disconnect_still_drains_parked() {
        check(
            "comm-disconnect",
            64,
            |r| {
                let sent = gen::usize_in(r, 1, 8) as u32;
                let ask_missing = gen::bool(r);
                (sent, ask_missing)
            },
            |&(sent, ask_missing)| {
                let (tx, mut rx) = link();
                for mb in 0..sent {
                    tx.send(mb, t(mb as f32)).unwrap();
                }
                drop(tx);
                if ask_missing {
                    // tag `sent` never went out: the parked tags get
                    // buffered on the way to Disconnected...
                    match rx.recv_timeout(sent, Duration::from_secs(5)) {
                        RecvOutcome::Disconnected => {}
                        other => return Err(format!("{other:?}")),
                    }
                }
                // ...and every sent tag is still retrievable
                for mb in 0..sent {
                    if !rx.poll(mb) {
                        return Err(format!("mb {mb} lost after hangup"));
                    }
                    if rx.take_parked(mb).is_none() {
                        return Err(format!("mb {mb} parked but gone"));
                    }
                }
                Ok(())
            },
        );
    }
}
