//! Inter-stage communication: tagged point-to-point channels.
//!
//! Simulates NCCL p2p send/recv between adjacent pipeline ranks.  Each
//! message is tagged with its microbatch id; the receiver can ask for a
//! specific tag (out-of-order arrivals are parked), and can *poll*
//! non-blockingly — the primitive the 2BP greedy-p2 fill rule is built
//! on ("if the next activation/gradient hasn't arrived, do deferred
//! weight-gradient work instead of idling").

use std::collections::HashMap;
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};

use anyhow::{anyhow, Result};

use crate::runtime::HostTensor;

/// A tagged tensor message (one activation or gradient for one mb).
pub struct Msg {
    pub mb: u32,
    pub tensor: HostTensor,
}

pub struct TaggedTx {
    tx: Sender<Msg>,
}

impl TaggedTx {
    pub fn send(&self, mb: u32, tensor: HostTensor) -> Result<()> {
        self.tx
            .send(Msg { mb, tensor })
            .map_err(|_| anyhow!("peer rank hung up"))
    }
}

pub struct TaggedRx {
    rx: Receiver<Msg>,
    parked: HashMap<u32, HostTensor>,
}

impl TaggedRx {
    /// Non-blocking: is the message for `mb` already here?
    pub fn poll(&mut self, mb: u32) -> bool {
        if self.parked.contains_key(&mb) {
            return true;
        }
        loop {
            match self.rx.try_recv() {
                Ok(m) => {
                    let hit = m.mb == mb;
                    self.parked.insert(m.mb, m.tensor);
                    if hit {
                        return true;
                    }
                }
                Err(TryRecvError::Empty) | Err(TryRecvError::Disconnected) => {
                    return false;
                }
            }
        }
    }

    /// Blocking receive of the message tagged `mb`.
    pub fn recv(&mut self, mb: u32) -> Result<HostTensor> {
        if let Some(t) = self.parked.remove(&mb) {
            return Ok(t);
        }
        loop {
            let m = self
                .rx
                .recv()
                .map_err(|_| anyhow!("peer rank hung up waiting for mb {mb}"))?;
            if m.mb == mb {
                return Ok(m.tensor);
            }
            self.parked.insert(m.mb, m.tensor);
        }
    }

    /// Take an already-parked message without touching the channel.
    pub fn take_parked(&mut self, mb: u32) -> Option<HostTensor> {
        self.parked.remove(&mb)
    }
}

/// Create a tagged p2p link.
pub fn link() -> (TaggedTx, TaggedRx) {
    let (tx, rx) = channel();
    (TaggedTx { tx }, TaggedRx { rx, parked: HashMap::new() })
}

/// The channel endpoints owned by one rank.
#[derive(Default)]
pub struct RankLinks {
    /// Activations arriving from rank-1 (None on rank 0).
    pub act_in: Option<TaggedRx>,
    /// Activations leaving to rank+1 (None on the last rank).
    pub act_out: Option<TaggedTx>,
    /// Gradients arriving from rank+1 (None on the last rank).
    pub grad_in: Option<TaggedRx>,
    /// Gradients leaving to rank-1 (None on rank 0).
    pub grad_out: Option<TaggedTx>,
}

/// Wire up a linear pipeline of `n` ranks.
pub fn pipeline_links(n: usize) -> Vec<RankLinks> {
    let mut links: Vec<RankLinks> = (0..n).map(|_| RankLinks::default()).collect();
    for r in 0..n.saturating_sub(1) {
        let (atx, arx) = link();
        links[r].act_out = Some(atx);
        links[r + 1].act_in = Some(arx);
        let (gtx, grx) = link();
        links[r + 1].grad_out = Some(gtx);
        links[r].grad_in = Some(grx);
    }
    links
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::DType;

    fn t(v: f32) -> HostTensor {
        HostTensor::from_f32(&[1], &[v])
    }

    #[test]
    fn tagged_out_of_order_delivery() {
        let (tx, mut rx) = link();
        tx.send(1, t(1.0)).unwrap();
        tx.send(0, t(0.0)).unwrap();
        assert_eq!(rx.recv(0).unwrap().to_f32(), vec![0.0]);
        assert_eq!(rx.recv(1).unwrap().to_f32(), vec![1.0]);
    }

    #[test]
    fn poll_parks_mismatches() {
        let (tx, mut rx) = link();
        assert!(!rx.poll(0));
        tx.send(2, t(2.0)).unwrap();
        assert!(!rx.poll(0));
        tx.send(0, t(0.0)).unwrap();
        assert!(rx.poll(0));
        assert!(rx.take_parked(2).is_some());
    }

    #[test]
    fn pipeline_links_shape() {
        let links = pipeline_links(3);
        assert!(links[0].act_in.is_none());
        assert!(links[0].act_out.is_some());
        assert!(links[0].grad_in.is_some());
        assert!(links[0].grad_out.is_none());
        assert!(links[2].act_in.is_some());
        assert!(links[2].act_out.is_none());
        assert!(links[2].grad_in.is_none());
        assert!(links[2].grad_out.is_some());
        let _ = DType::F32;
    }

    #[test]
    fn cross_thread_transfer() {
        let (tx, mut rx) = link();
        let h = std::thread::spawn(move || {
            for mb in (0..4u32).rev() {
                tx.send(mb, t(mb as f32)).unwrap();
            }
        });
        for mb in 0..4u32 {
            assert_eq!(rx.recv(mb).unwrap().to_f32(), vec![mb as f32]);
        }
        h.join().unwrap();
    }
}
