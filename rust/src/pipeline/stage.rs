//! Per-rank stage worker: owns one device context, the stage's compiled
//! executables, parameters/optimizer state, and the activation /
//! intermediate-derivative stashes.  Interprets plan ops, realizes the
//! 2BP greedy-fill rule via non-blocking channel polls, and accounts
//! every byte + times every op.

use std::collections::HashMap;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::config::P2Mode;
use crate::models::{Manifest, StageInfo};
use crate::pipeline::checkpoint::RankCheckpoint;
use crate::pipeline::comm::{RankLinks, RecvOutcome};
use crate::pipeline::data::DataGen;
use crate::pipeline::fault::{Failure, FailureKind, FaultCell};
use crate::pipeline::memory::{Class, MemAccountant};
use crate::runtime::{
    literal_bytes, literal_to_f32_scalar, scalar_f32, scalar_i32, Device,
    Executable, HostTensor, ZeroCache,
};
use crate::schedule::{Op, Plan};
use crate::util::gantt::SpanKind;

/// One timed op on this rank (seconds relative to the shared epoch).
#[derive(Debug, Clone, Copy)]
pub struct OpTiming {
    pub kind: SpanKind,
    pub mb: u32,
    pub start: f64,
    pub end: f64,
}

/// What a worker hands back to the leader after a run.
#[derive(Debug)]
pub struct WorkerReport {
    pub rank: usize,
    pub timings: Vec<OpTiming>,
    /// Timed p2p sends as [`SpanKind::Comm`] spans, kept in a lane of
    /// their own: `timings` must stay 1:1 with the simulator's per-op
    /// spans (the span-shape verifier compares them directly), but the
    /// trace export wants the comm activity on the timeline too.
    pub comm_timings: Vec<OpTiming>,
    pub peak_bytes: u64,
    /// Peak of the simulator-modeled classes (everything but `Wire`) —
    /// comparable to `SimResult::peak_bytes` (see
    /// [`MemAccountant::peak_model`]).
    pub peak_model: u64,
    pub peak_static: u64,
    pub peak_res1: u64,
    pub peak_res2: u64,
    pub peak_inter: u64,
    /// Mean measured seconds per op kind: (fwd, p1, p2, opt).
    pub mean_costs: (f64, f64, f64, f64),
    /// Mean measured seconds per p2p send (serialize + channel write;
    /// 0.0 if this rank sent nothing).  Sends are timed as part of no
    /// op span — the producing span ends *before* the send — so this
    /// is the executor's measured stand-in for [`CostModel::comm`],
    /// not a slice of fwd/p1 time.
    ///
    /// [`CostModel::comm`]: crate::sim::CostModel::comm
    pub mean_comm: f64,
    /// Mean measured seconds of the loss + initial-gradient computation
    /// (last rank only; 0.0 elsewhere).  Timed as its own
    /// [`SpanKind::Loss`] span so it never inflates the p1 mean — a
    /// measured model folds it into [`crate::sim::CostModel::loss`],
    /// which the simulator already schedules separately (folding it
    /// into p1 *and* modeling a loss op would double-count it).
    pub mean_loss: f64,
    /// Losses in microbatch order per step (last rank only).
    pub losses: Vec<f32>,
    /// Sum of |params| after the run (determinism / equivalence checks).
    pub param_checksum: f64,
    /// Order-sensitive FNV-1a over the raw bytes of every parameter —
    /// the *bit-exact* equivalence probe (`param_checksum` is
    /// magnitude-based and blind to sign flips).
    pub param_digest: u64,
}

struct MbStash {
    res1: Option<Vec<xla::Literal>>,
    res2: Option<Vec<xla::Literal>>,
    inter: Option<Vec<xla::Literal>>,
    logits: Option<xla::Literal>,
    /// Input-grad held until the fused-pair send point (non-2BP mode).
    gx: Option<HostTensor>,
}

impl MbStash {
    fn empty() -> Self {
        MbStash { res1: None, res2: None, inter: None, logits: None, gx: None }
    }
}

pub struct StageWorker {
    rank: usize,
    n_ranks: usize,
    info: StageInfo,
    vocab: i32,
    concat_m: usize,
    p2_mode: P2Mode,
    greedy: bool,
    two_bp: bool,

    exe_init: Executable,
    exe_fwd: Executable,
    exe_p1: Executable,
    exe_p2: Executable,
    exe_p2_concat: Executable,
    exe_opt: Executable,
    exe_loss: Option<Executable>,

    params: Vec<xla::Literal>,
    /// Adam slots; empty while `opt_fresh` (the shared zeros stand in).
    m_state: Vec<xla::Literal>,
    v_state: Vec<xla::Literal>,
    /// Gradient accumulators; empty while `grads_fresh` (the shared
    /// zeros stand in — see [`ZeroCache`]).
    grads: Vec<xla::Literal>,
    grads_fresh: bool,
    opt_fresh: bool,
    /// Shared zero literals: allocated once per distinct (shape, dtype)
    /// at worker construction, reused across steps and runs.
    zero_grads: Vec<std::rc::Rc<xla::Literal>>,
    zero_params: Vec<std::rc::Rc<xla::Literal>>,
    step_t: f32,

    stash: HashMap<u32, MbStash>,
    pending_p2: Vec<u32>,

    links: RankLinks,
    data: DataGen,
    labels_spec: crate::models::TensorSpec,
    step: usize,

    /// Shared first-failure latch (see `pipeline/fault.rs`): tripped by
    /// this worker on a receive deadline, observed every backoff tick
    /// so a peer's failure unwinds this rank too.
    fault: FaultCell,
    /// How long a receive may sit *idle* (no fill work, nothing
    /// arriving) before this rank declares the peer stalled.
    comm_timeout: Duration,
    /// Poll granularity while waiting: each tick re-checks the fault
    /// cell, so failure propagation latency is one backoff.
    comm_backoff: Duration,

    pub mem: MemAccountant,
    pub timings: Vec<OpTiming>,
    pub losses: Vec<f32>,
    /// Total seconds spent in p2p sends and how many there were —
    /// the measured-comm accumulator behind [`WorkerReport::mean_comm`]
    /// (accumulators, not timeline spans: the span-shape verifier
    /// compares executed timelines against simulator spans 1:1 and
    /// must not see op kinds the simulator doesn't emit per-plan-op).
    comm_secs: f64,
    comm_sends: usize,
    /// The same sends as [`SpanKind::Comm`] timeline spans (one per
    /// send) — the trace export's comm lane.  Kept separate from
    /// `timings` for the reason documented on `comm_secs`.
    comm_timings: Vec<OpTiming>,
    epoch: Instant,
}

impl StageWorker {
    /// Build a worker: create the device, compile this stage's artifacts,
    /// initialize parameters + optimizer state on-device.
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        rank: usize,
        manifest: &Manifest,
        plan: &Plan,
        p2_mode: P2Mode,
        links: RankLinks,
        seed: u64,
        data_cycle: usize,
        epoch: Instant,
    ) -> Result<StageWorker> {
        let info = manifest.stages[rank].clone();
        let device = Device::cpu().context("creating device")?;
        let exe_init = device.load(&info.init.file)?;
        let exe_fwd = device.load(&info.fwd.file)?;
        let exe_p1 = device.load(&info.bwd_p1.file)?;
        let exe_p2 = device.load(&info.bwd_p2.file)?;
        let exe_p2_concat = device.load(&info.bwd_p2_concat.file)?;
        let exe_opt = device.load(&info.opt.file)?;
        let exe_loss = if rank == manifest.n_stages - 1 {
            Some(device.load(&manifest.loss.file)?)
        } else {
            None
        };

        let params = exe_init.run(&[scalar_i32(seed as i32)])?;
        if params.len() != info.params.len() {
            bail!(
                "stage {rank}: init produced {} params, manifest says {}",
                params.len(),
                info.params.len()
            );
        }
        // fresh grads/Adam slots are shared zero literals, not per-step
        // allocations (the hotpath_micro "zero-literal alloc" fix)
        let mut zeros = ZeroCache::new();
        let zero_params = zeros.zeros_like(&info.params);
        let zero_grads = zeros.zeros_like(&info.grads);

        let vocab = *manifest.logits.shape.last().unwrap_or(&2) as i32;

        Ok(StageWorker {
            rank,
            n_ranks: manifest.n_stages,
            info,
            vocab,
            concat_m: manifest.concat_m,
            p2_mode,
            greedy: plan.greedy_p2,
            two_bp: plan.two_bp,
            exe_init,
            exe_fwd,
            exe_p1,
            exe_p2,
            exe_p2_concat,
            exe_opt,
            exe_loss,
            params,
            m_state: Vec::new(),
            v_state: Vec::new(),
            grads: Vec::new(),
            grads_fresh: true,
            opt_fresh: true,
            zero_grads,
            zero_params,
            step_t: 1.0,
            stash: HashMap::new(),
            pending_p2: Vec::new(),
            links,
            data: DataGen::with_cycle(seed, data_cycle),
            labels_spec: manifest.labels.clone(),
            step: 0,
            fault: FaultCell::new(),
            comm_timeout: Duration::from_secs(5),
            comm_backoff: Duration::from_millis(10),
            mem: MemAccountant::new(),
            timings: Vec::new(),
            losses: Vec::new(),
            comm_secs: 0.0,
            comm_sends: 0,
            comm_timings: Vec::new(),
            epoch,
        })
        .map(|mut w| {
            w.mem.alloc(Class::Static,
                        w.info.bytes.params * 3 + w.info.bytes.grads);
            w
        })
    }

    /// Re-arm the worker for a fresh run: new params (same seed), zeroed
    /// optimizer/grad state, cleared stashes/measurements, and a new
    /// schedule mode.  Compiled executables are reused — this is what
    /// makes multi-cell benchmarks affordable (compilation dominates).
    pub fn reset(
        &mut self,
        seed: u64,
        greedy: bool,
        two_bp: bool,
        p2_mode: P2Mode,
        data_cycle: usize,
    ) -> Result<()> {
        self.params = self.exe_init.run(&[scalar_i32(seed as i32)])?;
        // fresh grads/Adam slots: drop the stale state and fall back to
        // the shared zeros (no reallocation between runs)
        self.m_state = Vec::new();
        self.v_state = Vec::new();
        self.grads = Vec::new();
        self.grads_fresh = true;
        self.opt_fresh = true;
        self.step_t = 1.0;
        self.stash.clear();
        self.pending_p2.clear();
        self.data = DataGen::with_cycle(seed, data_cycle);
        self.step = 0;
        self.greedy = greedy;
        self.two_bp = two_bp;
        self.p2_mode = p2_mode;
        self.mem = MemAccountant::new();
        self.mem.alloc(Class::Static,
                       self.info.bytes.params * 3 + self.info.bytes.grads);
        self.timings.clear();
        self.losses.clear();
        self.comm_secs = 0.0;
        self.comm_sends = 0;
        self.comm_timings.clear();
        Ok(())
    }

    /// Capture the rank's resumable state at a step boundary.  Only
    /// valid between steps — `run_step` guarantees the stash and
    /// pending-p2 queue are empty and the grad accumulators fresh
    /// there, so params + Adam slots + counters are the whole state
    /// (the data stream is a pure function of `(seed, step, mb)`).
    pub fn snapshot(&self) -> Result<RankCheckpoint> {
        if !self.stash.is_empty() || !self.pending_p2.is_empty() {
            bail!(
                "rank {}: snapshot mid-step (stash {}, pending p2 {})",
                self.rank,
                self.stash.len(),
                self.pending_p2.len()
            );
        }
        let to_host = |ls: &[xla::Literal]| -> Result<Vec<HostTensor>> {
            ls.iter().map(HostTensor::from_literal).collect()
        };
        Ok(RankCheckpoint {
            rank: self.rank,
            step: self.step,
            step_t: self.step_t,
            opt_fresh: self.opt_fresh,
            params: to_host(&self.params)?,
            m_state: to_host(&self.m_state)?,
            v_state: to_host(&self.v_state)?,
        })
    }

    /// Restore a step-boundary snapshot taken by [`Self::snapshot`].
    /// Call after `reset` with the original run's seed/data-cycle:
    /// params, Adam slots, and both step counters come from the
    /// checkpoint, and the seeded data stream picks up at `step`
    /// exactly where the checkpointed run left it.
    pub fn restore(&mut self, c: &RankCheckpoint) -> Result<()> {
        if c.rank != self.rank {
            bail!("rank {} fed rank {}'s checkpoint", self.rank, c.rank);
        }
        if c.params.len() != self.info.params.len() {
            bail!(
                "rank {}: checkpoint has {} params, stage wants {}",
                self.rank,
                c.params.len(),
                self.info.params.len()
            );
        }
        let to_dev = |ts: &[HostTensor]| -> Result<Vec<xla::Literal>> {
            ts.iter().map(|t| t.to_literal()).collect()
        };
        self.params = to_dev(&c.params)?;
        self.m_state = to_dev(&c.m_state)?;
        self.v_state = to_dev(&c.v_state)?;
        self.grads = Vec::new();
        self.grads_fresh = true;
        self.opt_fresh = c.opt_fresh;
        self.step_t = c.step_t;
        self.step = c.step;
        Ok(())
    }

    /// Arm the worker with the cluster's shared fault cell and receive
    /// deadlines (kept out of `new` — supervision is the cluster's
    /// concern, and standalone workers in tests stay unsupervised with
    /// a private cell and generous defaults).
    pub fn set_supervision(
        &mut self,
        fault: FaultCell,
        comm_timeout: Duration,
        comm_backoff: Duration,
    ) {
        self.fault = fault;
        self.comm_timeout = comm_timeout.max(Duration::from_millis(1));
        self.comm_backoff = comm_backoff
            .max(Duration::from_millis(1))
            .min(self.comm_timeout);
    }

    /// Completed training steps (monotone across resumes within a run).
    pub fn step(&self) -> usize {
        self.step
    }

    fn now(&self) -> f64 {
        self.epoch.elapsed().as_secs_f64()
    }

    /// Gradient-accumulator inputs for the next p2/opt call: the shared
    /// zero literals before any p2 ran this step, the accumulated
    /// literals afterwards.
    fn grad_inputs(&self) -> Vec<&xla::Literal> {
        if self.grads_fresh {
            self.zero_grads.iter().map(|l| l.as_ref()).collect()
        } else {
            self.grads.iter().collect()
        }
    }

    fn record(&mut self, kind: SpanKind, mb: u32, start: f64) {
        self.timings.push(OpTiming { kind, mb, start, end: self.now() });
    }

    /// Account one just-completed p2p send that began at `start`: feeds
    /// both the mean-comm accumulators and the comm span lane.
    fn record_comm(&mut self, mb: u32, start: f64) {
        let end = self.now();
        self.comm_secs += end - start;
        self.comm_sends += 1;
        self.comm_timings.push(OpTiming {
            kind: SpanKind::Comm,
            mb,
            start,
            end,
        });
    }

    // -- greedy-aware receive ------------------------------------------------

    /// Supervised receive with the paper's 2BP fill rule: while the
    /// wanted message hasn't arrived, run one pending backward-p2
    /// instead of idling; with no p2 left, wait in bounded
    /// [`TaggedRx::recv_timeout`] ticks, observing the shared fault
    /// cell each tick.  A peer that stays silent past `comm_timeout`
    /// of *idle* waiting (fill work resets the deadline — a busy rank
    /// is not a stalled peer) trips [`FailureKind::CommTimeout`] on the
    /// cell; a cell already tripped elsewhere unwinds this rank within
    /// one backoff tick.
    ///
    /// [`TaggedRx::recv_timeout`]: crate::pipeline::comm::TaggedRx::recv_timeout
    fn recv_or_fill(&mut self, grad_side: bool, mb: u32) -> Result<HostTensor> {
        let side = if grad_side { "grad" } else { "act" };
        let peer = if grad_side { self.rank + 1 } else { self.rank.wrapping_sub(1) };
        let mut deadline = Instant::now() + self.comm_timeout;
        loop {
            let ready = {
                let rx = if grad_side {
                    self.links.grad_in.as_mut()
                } else {
                    self.links.act_in.as_mut()
                }
                .ok_or_else(|| anyhow!("rank {} has no link", self.rank))?;
                rx.poll(mb)
            };
            if ready {
                let rx = if grad_side {
                    self.links.grad_in.as_mut()
                } else {
                    self.links.act_in.as_mut()
                }
                .unwrap();
                return rx.recv(mb);
            }
            if self.greedy && !self.pending_p2.is_empty() {
                let next = self.pending_p2[0];
                self.run_p2_loop(&[next])?;
                // time spent doing useful fill work was not idle waiting
                deadline = Instant::now() + self.comm_timeout;
                continue;
            }
            let backoff = self.comm_backoff;
            let rx = if grad_side {
                self.links.grad_in.as_mut()
            } else {
                self.links.act_in.as_mut()
            }
            .unwrap();
            match rx.recv_timeout(mb, backoff) {
                RecvOutcome::Got(t) => return Ok(t),
                RecvOutcome::TimedOut => {
                    if let Some(f) = self.fault.get() {
                        bail!(
                            "rank {} unwinding: cluster fault at rank {} \
                             ({})",
                            self.rank,
                            f.rank,
                            f.cause
                        );
                    }
                    if Instant::now() >= deadline {
                        let cause = format!(
                            "no {side} tensor for mb {mb} from rank \
                             {peer} within {:?}",
                            self.comm_timeout
                        );
                        self.fault.trip(Failure {
                            kind: FailureKind::CommTimeout,
                            rank: self.rank,
                            step: self.step,
                            cause: cause.clone(),
                        });
                        bail!("{cause}");
                    }
                }
                RecvOutcome::Disconnected => {
                    if let Some(f) = self.fault.get() {
                        bail!(
                            "rank {} unwinding: cluster fault at rank {} \
                             ({})",
                            self.rank,
                            f.rank,
                            f.cause
                        );
                    }
                    bail!(
                        "rank {peer} hung up before sending the {side} \
                         tensor for mb {mb}"
                    );
                }
            }
        }
    }

    // -- op execution ---------------------------------------------------------

    pub fn exec(&mut self, op: &Op) -> Result<()> {
        match op.clone() {
            Op::Fwd { mb } => self.op_fwd(mb),
            Op::BwdP1 { mb } => self.op_bwd_p1(mb),
            Op::BwdP2 { mbs, concat } => self.op_bwd_p2(&mbs, concat),
            Op::Flush { upto, concat } => self.op_flush(upto, concat),
            Op::OptStep => self.op_opt(),
        }
    }

    /// Run one full training step following `ops`.
    pub fn run_step(&mut self, ops: &[Op]) -> Result<()> {
        for op in ops {
            self.exec(op)
                .with_context(|| format!("rank {} step {} op {:?}",
                                         self.rank, self.step, op))?;
        }
        self.mem.assert_step_balanced();
        if !self.stash.is_empty() {
            bail!("rank {}: stash not empty at step end", self.rank);
        }
        if !self.pending_p2.is_empty() {
            bail!("rank {}: pending p2 at step end", self.rank);
        }
        self.step += 1;
        Ok(())
    }

    fn op_fwd(&mut self, mb: u32) -> Result<()> {
        // obtain input
        let x_host = if self.rank == 0 {
            self.data.input(&self.info.input, self.vocab, self.step, mb)
        } else {
            let t = self.recv_or_fill(false, mb)?;
            self.mem.alloc(Class::Wire, t.bytes());
            t
        };
        let start = self.now();
        let x = x_host.to_literal()?;
        let mut args: Vec<&xla::Literal> = self.params.iter().collect();
        args.push(&x);
        let outs = self.exe_fwd.run(&args)?;
        let n1 = self.info.res1.len();
        let n2 = self.info.res2.len();
        if outs.len() != 1 + n1 + n2 {
            bail!("fwd output arity {} != {}", outs.len(), 1 + n1 + n2);
        }
        let mut it = outs.into_iter();
        let y = it.next().unwrap();
        let res1: Vec<_> = (&mut it).take(n1).collect();
        let res2: Vec<_> = it.collect();

        self.mem.alloc(Class::Res1, self.info.bytes.res1);
        self.mem.alloc(Class::Res2, self.info.bytes.res2);
        if self.rank > 0 {
            self.mem.free(Class::Wire, x_host.bytes());
        }

        let entry = self.stash.entry(mb).or_insert_with(MbStash::empty);
        entry.res1 = Some(res1);
        entry.res2 = Some(res2);

        if self.rank + 1 < self.n_ranks {
            // the compute span ends here; serialize + send is timed as
            // comm (the measured CostModel::comm), not as fwd time
            let end = self.now();
            let y_host = HostTensor::from_literal(&y)?;
            self.links
                .act_out
                .as_ref()
                .ok_or_else(|| anyhow!("missing act_out"))?
                .send(mb, y_host)?;
            self.record_comm(mb, end);
            self.timings.push(OpTiming { kind: SpanKind::Fwd, mb, start, end });
        } else {
            self.mem.alloc(Class::Wire, literal_bytes(&y));
            entry.logits = Some(y);
            self.record(SpanKind::Fwd, mb, start);
        }
        Ok(())
    }

    fn op_bwd_p1(&mut self, mb: u32) -> Result<()> {
        // obtain the output-gradient
        let (gy, gy_wire_bytes, start) = if self.rank == self.n_ranks - 1 {
            let logits = self
                .stash
                .get_mut(&mb)
                .and_then(|s| s.logits.take())
                .ok_or_else(|| anyhow!("no logits stashed for mb {mb}"))?;
            // the loss + initial-gradient computation gets its own span:
            // folding it into the BwdP1 timing would skew any measured
            // cost model replayed through the simulator, which schedules
            // loss separately (CostModel::loss)
            let loss_start = self.now();
            let labels = self
                .data
                .labels(&self.labels_spec, self.vocab, self.step, mb)
                .to_literal()?;
            let outs = self
                .exe_loss
                .as_ref()
                .unwrap()
                .run(&[&logits, &labels])?;
            let loss = literal_to_f32_scalar(&outs[0])?;
            self.losses.push(loss);
            let lb = literal_bytes(&logits);
            self.mem.free(Class::Wire, lb);
            let gy = outs.into_iter().nth(1).unwrap();
            self.record(SpanKind::Loss, mb, loss_start);
            let start = self.now();
            (gy, 0u64, start)
        } else {
            let t = self.recv_or_fill(true, mb)?;
            let b = t.bytes();
            self.mem.alloc(Class::Wire, b);
            let start = self.now();
            (t.to_literal()?, b, start)
        };

        let (res1, res2) = {
            let entry = self
                .stash
                .get_mut(&mb)
                .ok_or_else(|| anyhow!("no stash for mb {mb}"))?;
            (
                entry.res1.take().ok_or_else(|| anyhow!("res1 missing"))?,
                entry.res2.take().ok_or_else(|| anyhow!("res2 missing"))?,
            )
        };
        let mut args: Vec<&xla::Literal> = self.params.iter().collect();
        args.extend(res1.iter());
        args.extend(res2.iter());
        args.push(&gy);
        let outs = self.exe_p1.run(&args)?;
        let ni = self.info.inter.len();
        if outs.len() != 1 + ni {
            bail!("bwd_p1 output arity {} != {}", outs.len(), 1 + ni);
        }
        let mut it = outs.into_iter();
        let gx = it.next().unwrap();
        let inter: Vec<_> = it.collect();

        drop(res1);
        self.mem.free(Class::Res1, self.info.bytes.res1);
        self.mem.alloc(Class::Inter, self.info.bytes.inter);
        if gy_wire_bytes > 0 {
            self.mem.free(Class::Wire, gy_wire_bytes);
        }

        let entry = self.stash.get_mut(&mb).unwrap();
        entry.res2 = Some(res2);
        entry.inter = Some(inter);
        self.pending_p2.push(mb);

        if self.rank > 0 {
            if self.two_bp {
                // 2BP: the input-grad leaves immediately after p1; the
                // p1 span ends before the timed serialize + send
                let end = self.now();
                let gx_host = HostTensor::from_literal(&gx)?;
                self.links.grad_out.as_ref().unwrap().send(mb, gx_host)?;
                self.record_comm(mb, end);
                self.timings.push(OpTiming {
                    kind: SpanKind::BwdP1,
                    mb,
                    start,
                    end,
                });
                return Ok(());
            }
            // fused autograd semantics: hold until the paired p2 ran
            let gx_host = HostTensor::from_literal(&gx)?;
            self.mem.alloc(Class::Wire, gx_host.bytes());
            entry.gx = Some(gx_host);
        }
        self.record(SpanKind::BwdP1, mb, start);
        Ok(())
    }

    /// Loop-mode p2 for the given microbatches (accumulating executable).
    fn run_p2_loop(&mut self, mbs: &[u32]) -> Result<()> {
        for &mb in mbs {
            let start = self.now();
            let (res2, inter) = {
                let entry = self
                    .stash
                    .get_mut(&mb)
                    .ok_or_else(|| anyhow!("no stash for p2 of mb {mb}"))?;
                (
                    entry.res2.take().ok_or_else(|| anyhow!("res2 gone"))?,
                    entry.inter.take().ok_or_else(|| anyhow!("inter gone"))?,
                )
            };
            let mut args: Vec<&xla::Literal> = Vec::new();
            args.extend(res2.iter());
            args.extend(inter.iter());
            args.extend(self.grad_inputs());
            let outs = self.exe_p2.run(&args)?;
            if outs.len() != self.info.grads.len() {
                bail!("bwd_p2 arity {} != {}", outs.len(),
                      self.info.grads.len());
            }
            self.grads = outs;
            self.grads_fresh = false;
            self.mem.free(Class::Res2, self.info.bytes.res2);
            self.mem.free(Class::Inter, self.info.bytes.inter);
            self.pending_p2.retain(|x| *x != mb);
            // span ends before finish_mb: the fused-mode grad send it
            // may perform is timed as comm, not p2
            let end = self.now();
            self.finish_mb(mb)?;
            self.timings.push(OpTiming {
                kind: SpanKind::BwdP2,
                mb,
                start,
                end,
            });
        }
        Ok(())
    }

    /// Concat-mode p2 over exactly `concat_m` microbatches (Fig 2).
    fn run_p2_concat(&mut self, mbs: &[u32]) -> Result<()> {
        let start = self.now();
        let mut groups: Vec<(Vec<xla::Literal>, Vec<xla::Literal>)> = Vec::new();
        for &mb in mbs {
            let entry = self
                .stash
                .get_mut(&mb)
                .ok_or_else(|| anyhow!("no stash for concat p2 of mb {mb}"))?;
            groups.push((
                entry.res2.take().ok_or_else(|| anyhow!("res2 gone"))?,
                entry.inter.take().ok_or_else(|| anyhow!("inter gone"))?,
            ));
        }
        let mut args: Vec<&xla::Literal> = Vec::new();
        for (res2, inter) in &groups {
            args.extend(res2.iter());
            args.extend(inter.iter());
        }
        let outs = self.exe_p2_concat.run(&args)?;
        if outs.len() != self.info.grads.len() {
            bail!("bwd_p2_concat arity {} != {}", outs.len(),
                  self.info.grads.len());
        }
        // concat covers the whole step's p2 — valid only on fresh grads
        self.grads = outs;
        self.grads_fresh = false;
        // span ends before the per-mb cleanup: any fused-mode grad
        // sends in finish_mb are timed as comm, not p2
        let end = self.now();
        for &mb in mbs {
            self.mem.free(Class::Res2, self.info.bytes.res2);
            self.mem.free(Class::Inter, self.info.bytes.inter);
            self.pending_p2.retain(|x| *x != mb);
            self.finish_mb(mb)?;
        }
        self.timings.push(OpTiming {
            kind: SpanKind::BwdP2,
            mb: mbs[0],
            start,
            end,
        });
        Ok(())
    }

    /// Per-mb cleanup after its p2: fused-mode grad send + stash removal.
    fn finish_mb(&mut self, mb: u32) -> Result<()> {
        let held_gx = self.stash.get_mut(&mb).unwrap().gx.take();
        if let Some(gx_host) = held_gx {
            self.mem.free(Class::Wire, gx_host.bytes());
            let t0 = self.now();
            self.links
                .grad_out
                .as_ref()
                .ok_or_else(|| anyhow!("missing grad_out"))?
                .send(mb, gx_host)?;
            self.record_comm(mb, t0);
        }
        let entry = self.stash.get_mut(&mb).unwrap();
        if entry.res1.is_none()
            && entry.res2.is_none()
            && entry.inter.is_none()
            && entry.logits.is_none()
        {
            self.stash.remove(&mb);
        }
        Ok(())
    }

    fn op_bwd_p2(&mut self, mbs: &[u32], concat: bool) -> Result<()> {
        if concat && mbs.len() == self.concat_m && self.grads_fresh {
            self.run_p2_concat(mbs)
        } else {
            self.run_p2_loop(mbs)
        }
    }

    fn op_flush(&mut self, upto: Option<u32>, concat: bool) -> Result<()> {
        let mut mbs: Vec<u32> = self
            .pending_p2
            .iter()
            .copied()
            .filter(|mb| upto.map(|u| *mb <= u).unwrap_or(true))
            .collect();
        mbs.sort_unstable();
        if mbs.is_empty() {
            return Ok(());
        }
        let use_concat = (concat || self.p2_mode == P2Mode::Concat)
            && mbs.len() == self.concat_m
            && self.grads_fresh;
        if use_concat {
            self.run_p2_concat(&mbs)
        } else {
            self.run_p2_loop(&mbs)
        }
    }

    fn op_opt(&mut self) -> Result<()> {
        let start = self.now();
        let t = scalar_f32(self.step_t);
        let mut args: Vec<&xla::Literal> = Vec::new();
        args.extend(self.params.iter());
        args.extend(self.grad_inputs());
        if self.opt_fresh {
            // first step: both Adam slots are the shared zeros
            args.extend(self.zero_params.iter().map(|l| l.as_ref()));
            args.extend(self.zero_params.iter().map(|l| l.as_ref()));
        } else {
            args.extend(self.m_state.iter());
            args.extend(self.v_state.iter());
        }
        args.push(&t);
        let outs = self.exe_opt.run(&args)?;
        let np = self.params.len();
        if outs.len() != 3 * np {
            bail!("opt arity {} != {}", outs.len(), 3 * np);
        }
        let mut it = outs.into_iter();
        self.params = (&mut it).take(np).collect();
        self.m_state = (&mut it).take(np).collect();
        self.v_state = it.collect();
        self.opt_fresh = false;
        // reset gradient accumulators to the shared zeros (no
        // per-OptStep allocation — see ZeroCache)
        self.grads = Vec::new();
        self.grads_fresh = true;
        self.step_t += 1.0;
        self.record(SpanKind::Opt, 0, start);
        Ok(())
    }

    /// Build the final report (consumes accumulated measurements).
    pub fn report(&mut self) -> Result<WorkerReport> {
        let timings = std::mem::take(&mut self.timings);
        let mean = {
            let timings = &timings;
            move |kind: SpanKind| -> f64 {
                let xs: Vec<f64> = timings
                    .iter()
                    .filter(|t| t.kind == kind)
                    .map(|t| t.end - t.start)
                    .collect();
                if xs.is_empty() {
                    0.0
                } else {
                    xs.iter().sum::<f64>() / xs.len() as f64
                }
            }
        };
        let mean_costs = (
            mean(SpanKind::Fwd),
            mean(SpanKind::BwdP1),
            mean(SpanKind::BwdP2),
            mean(SpanKind::Opt),
        );
        let mean_loss = mean(SpanKind::Loss);
        let mean_comm = if self.comm_sends == 0 {
            0.0
        } else {
            self.comm_secs / self.comm_sends as f64
        };
        // consumed like `timings`: a report drains the accumulators
        self.comm_secs = 0.0;
        self.comm_sends = 0;
        let comm_timings = std::mem::take(&mut self.comm_timings);
        let mut checksum = 0.0f64;
        let mut digest: u64 = 0xcbf2_9ce4_8422_2325;
        for p in &self.params {
            let h = HostTensor::from_literal(p)?;
            if h.dtype == crate::models::DType::F32 {
                checksum += h.to_f32().iter().map(|v| v.abs() as f64).sum::<f64>();
            }
            for &b in &h.data {
                digest = (digest ^ b as u64)
                    .wrapping_mul(0x0000_0100_0000_01b3);
            }
        }
        Ok(WorkerReport {
            rank: self.rank,
            timings,
            comm_timings,
            peak_bytes: self.mem.peak(),
            peak_model: self.mem.peak_model(),
            peak_static: self.mem.peak_of(Class::Static),
            peak_res1: self.mem.peak_of(Class::Res1),
            peak_res2: self.mem.peak_of(Class::Res2),
            peak_inter: self.mem.peak_of(Class::Inter),
            mean_costs,
            mean_loss,
            mean_comm,
            losses: std::mem::take(&mut self.losses),
            param_checksum: checksum,
            param_digest: digest,
        })
    }
}

