//! Fail-fast supervision primitives: the shared fault cell, the typed
//! run error, and the seeded comm-layer fault injector.
//!
//! A production pipeline fails in two ways the happy path never sees: a
//! rank *dies* (an executable errors, the process aborts) or a rank
//! *stalls* (the neighbor is alive but the tensor never arrives).
//! Before this module, the first was a `panic!` swallowed by
//! `let _ = h.join()` and the second was an infinite `mpsc::recv` —
//! either way the cluster hung or lied.  Now:
//!
//! - every worker shares one [`FaultCell`]; the **first** failure wins
//!   and every other rank observes it within one receive-backoff tick
//!   and unwinds cleanly;
//! - `Cluster::run_plan` surfaces the cell's contents as a typed
//!   [`RunError`] (`RankFailed` / `CommTimeout`, each naming the rank,
//!   step, and cause) that callers can downcast out of `anyhow`;
//! - [`CommFaultCfg`] injects seeded, reproducible message drops and
//!   delays into the p2p links, so the timeout path is testable offline
//!   without a flaky network (the stub's `fault` directive covers the
//!   compute-failure path the same way).
//!
//! Everything here is plain bookkeeping over `std::sync` — no executor
//! types — so the supervision logic stays unit-testable without a
//! cluster, like `pipeline/drift.rs`.

use std::fmt;
use std::sync::{Arc, Mutex};

/// How a rank failed (drives the [`RunError`] variant).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FailureKind {
    /// An op on the rank returned an error (dead executable, poisoned
    /// state, injected `fault fail@N`).
    RankFailed,
    /// The rank gave up waiting for a peer's tensor (deadline-based
    /// receive timeout; the peer is stalled, not gone).
    CommTimeout,
}

/// The first failure observed anywhere in the cluster.
#[derive(Debug, Clone)]
pub struct Failure {
    pub kind: FailureKind,
    /// The rank that *reported* the failure (for `CommTimeout` this is
    /// the waiting rank; the stalled peer is named in `cause`).
    pub rank: usize,
    /// The training step the rank was executing when it failed.
    pub step: usize,
    pub cause: String,
}

/// Shared first-failure-wins latch: one per cluster, cloned into every
/// worker.  Tripping it is how a dying rank tells everyone else to stop
/// waiting and unwind.
#[derive(Debug, Clone, Default)]
pub struct FaultCell {
    slot: Arc<Mutex<Option<Failure>>>,
}

impl FaultCell {
    pub fn new() -> FaultCell {
        FaultCell::default()
    }

    /// Record a failure; the first call wins.  Returns whether this
    /// call set the cell (false: an earlier failure was already in).
    pub fn trip(&self, failure: Failure) -> bool {
        let mut slot = self.slot.lock().unwrap_or_else(|e| e.into_inner());
        if slot.is_none() {
            *slot = Some(failure);
            true
        } else {
            false
        }
    }

    /// The recorded failure, if any rank has tripped the cell.
    pub fn get(&self) -> Option<Failure> {
        self.slot
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .clone()
    }

    pub fn is_tripped(&self) -> bool {
        self.slot
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .is_some()
    }
}

/// Typed outcome of a failed `Cluster::run_plan`, carried inside the
/// returned `anyhow::Error` — downcast with
/// `err.downcast_ref::<RunError>()` to branch on the variant.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum RunError {
    /// A stage worker's op errored at (rank, step).
    RankFailed {
        rank: usize,
        step: usize,
        cause: String,
    },
    /// A rank timed out waiting for a peer tensor at (rank, step).
    CommTimeout {
        rank: usize,
        step: usize,
        cause: String,
    },
}

impl From<Failure> for RunError {
    fn from(f: Failure) -> RunError {
        match f.kind {
            FailureKind::RankFailed => RunError::RankFailed {
                rank: f.rank,
                step: f.step,
                cause: f.cause,
            },
            FailureKind::CommTimeout => RunError::CommTimeout {
                rank: f.rank,
                step: f.step,
                cause: f.cause,
            },
        }
    }
}

impl fmt::Display for RunError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            RunError::RankFailed { rank, step, cause } => write!(
                f,
                "rank {rank} failed at step {step}: {cause}"
            ),
            RunError::CommTimeout { rank, step, cause } => write!(
                f,
                "rank {rank} timed out at step {step}: {cause}"
            ),
        }
    }
}

impl std::error::Error for RunError {}

impl RunError {
    /// The failing (or waiting) rank.
    pub fn rank(&self) -> usize {
        match self {
            RunError::RankFailed { rank, .. }
            | RunError::CommTimeout { rank, .. } => *rank,
        }
    }

    /// The step the failure was observed at.
    pub fn step(&self) -> usize {
        match self {
            RunError::RankFailed { step, .. }
            | RunError::CommTimeout { step, .. } => *step,
        }
    }
}

/// Seeded comm-layer fault injection: every p2p send consults a PRNG
/// that is a pure function of (seed, link id, send index), so a given
/// config reproduces the exact same drops and delays on every run —
/// deterministic chaos, per the stub backend's design rule.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CommFaultCfg {
    pub seed: u64,
    /// Probability in [0, 1] that a send is silently dropped (the
    /// receiver then hits its deadline and trips `CommTimeout`).
    pub drop_prob: f64,
    /// Fixed extra latency added to every (non-dropped) send.
    pub delay_ns: u64,
}

impl CommFaultCfg {
    /// None when the config injects nothing (the common case).
    pub fn active(&self) -> bool {
        self.drop_prob > 0.0 || self.delay_ns > 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn failure(kind: FailureKind, rank: usize) -> Failure {
        Failure {
            kind,
            rank,
            step: 3,
            cause: "boom".into(),
        }
    }

    #[test]
    fn first_failure_wins() {
        let cell = FaultCell::new();
        assert!(!cell.is_tripped());
        assert!(cell.trip(failure(FailureKind::RankFailed, 1)));
        assert!(!cell.trip(failure(FailureKind::CommTimeout, 2)));
        let f = cell.get().unwrap();
        assert_eq!(f.rank, 1);
        assert_eq!(f.kind, FailureKind::RankFailed);
    }

    #[test]
    fn clones_share_the_slot() {
        let cell = FaultCell::new();
        let peer = cell.clone();
        cell.trip(failure(FailureKind::CommTimeout, 0));
        assert!(peer.is_tripped());
        assert_eq!(peer.get().unwrap().rank, 0);
    }

    #[test]
    fn run_error_names_rank_and_step() {
        let e = RunError::from(failure(FailureKind::RankFailed, 2));
        assert_eq!(e.rank(), 2);
        assert_eq!(e.step(), 3);
        let msg = e.to_string();
        assert!(msg.contains("rank 2"), "{msg}");
        assert!(msg.contains("step 3"), "{msg}");
        let t = RunError::from(failure(FailureKind::CommTimeout, 1));
        assert!(t.to_string().contains("timed out"), "{t}");
    }

    #[test]
    fn comm_fault_cfg_activity() {
        let quiet = CommFaultCfg { seed: 1, drop_prob: 0.0, delay_ns: 0 };
        assert!(!quiet.active());
        assert!(CommFaultCfg { drop_prob: 0.5, ..quiet }.active());
        assert!(CommFaultCfg { delay_ns: 10, ..quiet }.active());
    }
}
