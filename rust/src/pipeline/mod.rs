//! The real pipeline-parallel executor.
//!
//! One OS thread per pipeline rank, each owning its own PJRT device
//! context and compiled stage executables; activations and gradients
//! travel between adjacent ranks as [`HostTensor`](crate::runtime::HostTensor)
//! messages over tagged channels (the NCCL-p2p stand-in).  The executor
//! interprets [`Plan`](crate::schedule::Plan) ops, realizes the 2BP
//! greedy-fill rule with non-blocking channel polls, accounts every
//! stash byte (Fig 4/5), and times every op (calibrating the simulator).
//!
//! Failure is a first-class outcome, not a hang: workers share a
//! [`FaultCell`], receives carry deadlines, `Cluster::run_plan` returns
//! a typed [`RunError`] naming the failing rank and step, and
//! [`checkpoint`] serializes per-rank state for bit-identical resume
//! (`--checkpoint-every` / `--resume`; see docs/ROBUSTNESS.md §6).

pub mod checkpoint;
pub mod comm;
pub mod data;
pub mod drift;
pub mod fault;
pub mod memory;
pub mod stage;
pub mod training;

pub use checkpoint::RankCheckpoint;
pub use drift::{DriftConfig, DriftMonitor, Verdict};
pub use fault::{CommFaultCfg, Failure, FailureKind, FaultCell, RunError};
pub use training::{
    train, verify_report_against_sim, Cluster, CommCalibration, RunReport,
};
