//! Mid-run drift detection: is the plan the executor is running still
//! the plan the planner scored?
//!
//! A tuned plan embeds a calibrated cost model; when the cluster's real
//! per-op costs wander (thermal throttling, a slow neighbor, a changed
//! kernel — or, offline, the stub's `drift` directive), measured step
//! makespans pull away from the prediction and the "optimal" plan can
//! silently stop being one.  [`DriftMonitor`] watches the
//! measured-vs-predicted ratio with **hysteresis** (one slow step is
//! noise; N consecutive slow steps are drift) and a **bounded replan
//! budget with cooldown** (a flapping cluster triggers at most
//! `max_replans` re-tunes, never a thrash loop).
//!
//! The monitor is pure bookkeeping — no executor types — so the
//! replan loop in `experiments` stays testable without a cluster:
//! feed it makespans, read back [`Verdict`]s.
//!
//! ```text
//!            measured ≤ predicted·(1+threshold)          streak < window
//!          ┌──────────────── Ok ◄───────────────┐      ┌── Drifting ──┐
//!          ▼                                    │      ▼              │
//!   (streak = 0) ──— slow step ——► (streak += 1)┴──────┴─ streak ≥ window
//!                                                            │
//!              replans < max_replans? ── no ──► Exhausted    │
//!                        │ yes                               │
//!                        ▼                                   │
//!                     Replan ──► caller re-tunes, calls rearm(new
//!                                prediction): streak = 0, cooldown
//!                                masks the steps run mid-transition
//! ```

/// Tuning knobs for [`DriftMonitor`].
#[derive(Debug, Clone)]
pub struct DriftConfig {
    /// Relative slowdown that counts as a slow step: measured >
    /// predicted × (1 + threshold).
    pub threshold: f64,
    /// Consecutive slow steps before a replan triggers (hysteresis
    /// window; ≥ 1).
    pub window: usize,
    /// Replans allowed over the monitor's lifetime.
    pub max_replans: usize,
    /// Steps ignored right after a [`DriftMonitor::rearm`] — measured
    /// makespans straddling the plan swap mix old- and new-plan ops.
    pub cooldown: usize,
}

impl Default for DriftConfig {
    fn default() -> Self {
        DriftConfig {
            threshold: 0.3,
            window: 2,
            max_replans: 1,
            cooldown: 1,
        }
    }
}

/// What one observed step means for the run (see the module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Verdict {
    /// Measured makespan within tolerance of the prediction.
    Ok,
    /// Slow step inside the hysteresis window — keep running.
    Drifting,
    /// Drift confirmed: re-calibrate, re-tune, then [`DriftMonitor::rearm`].
    Replan,
    /// Drift confirmed but the replan budget is spent — keep the
    /// current plan (the backoff that stops a flapping cluster from
    /// thrashing the tuner).
    Exhausted,
}

/// Hysteresis comparator between measured and predicted step makespan.
#[derive(Debug, Clone)]
pub struct DriftMonitor {
    cfg: DriftConfig,
    predicted: f64,
    streak: usize,
    cooldown_left: usize,
    replans: usize,
}

impl DriftMonitor {
    /// Monitor a run whose tuned plan predicts `predicted` seconds per
    /// step.
    pub fn new(cfg: DriftConfig, predicted: f64) -> DriftMonitor {
        assert!(cfg.window >= 1, "hysteresis window must be >= 1");
        DriftMonitor {
            cfg,
            predicted,
            streak: 0,
            cooldown_left: 0,
            replans: 0,
        }
    }

    /// Feed one measured step makespan; returns what to do about it.
    pub fn observe(&mut self, measured: f64) -> Verdict {
        if self.cooldown_left > 0 {
            self.cooldown_left -= 1;
            return Verdict::Ok;
        }
        let slow = measured > self.predicted * (1.0 + self.cfg.threshold);
        if !slow {
            self.streak = 0;
            return Verdict::Ok;
        }
        self.streak += 1;
        if self.streak < self.cfg.window {
            return Verdict::Drifting;
        }
        if self.replans >= self.cfg.max_replans {
            // stay triggered but don't re-announce every step: a fresh
            // window must build up before the next Exhausted verdict
            self.streak = 0;
            return Verdict::Exhausted;
        }
        Verdict::Replan
    }

    /// The caller replanned: adopt the new prediction, reset the
    /// hysteresis, start the cooldown, and burn one replan credit.
    pub fn rearm(&mut self, new_predicted: f64) {
        self.predicted = new_predicted;
        self.streak = 0;
        self.cooldown_left = self.cfg.cooldown;
        self.replans += 1;
    }

    /// Replans performed so far (i.e. [`DriftMonitor::rearm`] calls).
    pub fn replans(&self) -> usize {
        self.replans
    }

    /// The prediction currently being compared against.
    pub fn predicted(&self) -> f64 {
        self.predicted
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn monitor(window: usize, max_replans: usize) -> DriftMonitor {
        DriftMonitor::new(
            DriftConfig {
                threshold: 0.5,
                window,
                max_replans,
                cooldown: 1,
            },
            1.0,
        )
    }

    #[test]
    fn within_tolerance_stays_ok() {
        let mut m = monitor(2, 1);
        for x in [0.9, 1.0, 1.4, 1.5] {
            assert_eq!(m.observe(x), Verdict::Ok, "{x}");
        }
        assert_eq!(m.replans(), 0);
    }

    #[test]
    fn one_slow_step_is_noise_two_are_drift() {
        let mut m = monitor(2, 1);
        assert_eq!(m.observe(2.0), Verdict::Drifting);
        // a good step resets the hysteresis
        assert_eq!(m.observe(1.0), Verdict::Ok);
        assert_eq!(m.observe(2.0), Verdict::Drifting);
        assert_eq!(m.observe(2.0), Verdict::Replan);
    }

    #[test]
    fn rearm_adopts_prediction_and_cools_down() {
        let mut m = monitor(1, 2);
        assert_eq!(m.observe(2.0), Verdict::Replan);
        m.rearm(2.0);
        assert_eq!(m.replans(), 1);
        assert_eq!(m.predicted(), 2.0);
        // first post-swap step is masked even though it's slow...
        assert_eq!(m.observe(9.0), Verdict::Ok);
        // ...then the new prediction is what's compared against
        assert_eq!(m.observe(2.5), Verdict::Ok);
        assert_eq!(m.observe(4.0), Verdict::Replan);
    }

    #[test]
    fn replan_budget_bounds_thrash() {
        let mut m = monitor(1, 1);
        assert_eq!(m.observe(2.0), Verdict::Replan);
        m.rearm(1.0); // replan didn't help: cluster still slow
        assert_eq!(m.observe(2.0), Verdict::Ok); // cooldown
        assert_eq!(m.observe(2.0), Verdict::Exhausted);
        // exhausted re-announces only after a full fresh window
        let mut m = monitor(2, 0);
        assert_eq!(m.observe(2.0), Verdict::Drifting);
        assert_eq!(m.observe(2.0), Verdict::Exhausted);
        assert_eq!(m.observe(2.0), Verdict::Drifting);
        assert_eq!(m.observe(2.0), Verdict::Exhausted);
        assert_eq!(m.replans(), 0);
    }
}
