//! Synthetic training data (paper §3.2: "The data collected came from
//! training on randomly generated data ... since dataloading can be a
//! significant bottleneck and optimising dataloading is beyond the scope
//! of this paper").

use crate::models::{DType, TensorSpec};
use crate::runtime::HostTensor;
use crate::util::prng::SplitMix64;

const INPUT_SALT: u64 = 0x1B7D4_C0FFEE;
const LABEL_SALT: u64 = 0x1ABE1_5EED;

/// Deterministic sample generator: the tensor for (step, microbatch) is
/// a pure function of (seed, step, mb), so reruns and cross-schedule
/// comparisons see identical data.
pub struct DataGen {
    seed: u64,
    /// Steps cycle over this many distinct batches (0 = fresh data every
    /// step, the paper's pure-throughput setting; a small cycle makes the
    /// loss curve meaningful for the training examples).
    cycle: usize,
}

impl DataGen {
    pub fn new(seed: u64) -> Self {
        DataGen { seed, cycle: 0 }
    }

    pub fn with_cycle(seed: u64, cycle: usize) -> Self {
        DataGen { seed, cycle }
    }

    fn rng(&self, step: usize, mb: u32, salt: u64) -> SplitMix64 {
        let step = if self.cycle > 0 { step % self.cycle } else { step };
        SplitMix64::new(
            self.seed
                ^ (step as u64).wrapping_mul(0x9E3779B97F4A7C15)
                ^ (mb as u64 + 1).wrapping_mul(0xD1B54A32D192ED03)
                ^ salt,
        )
    }

    /// Model input for (step, mb): token ids for int32 specs, standard
    /// normal floats otherwise.
    pub fn input(
        &self,
        spec: &TensorSpec,
        vocab: i32,
        step: usize,
        mb: u32,
    ) -> HostTensor {
        let n: usize = spec.shape.iter().product();
        let mut rng = self.rng(step, mb, INPUT_SALT);
        match spec.dtype {
            DType::I32 => {
                let mut buf = vec![0i32; n];
                rng.fill_tokens(&mut buf, vocab.max(2));
                HostTensor::from_i32(&spec.shape, &buf)
            }
            DType::F32 => {
                let mut buf = vec![0f32; n];
                rng.fill_normal(&mut buf);
                HostTensor::from_f32(&spec.shape, &buf)
            }
        }
    }

    /// Labels for (step, mb): class/token ids in [0, n_classes).
    pub fn labels(
        &self,
        spec: &TensorSpec,
        n_classes: i32,
        step: usize,
        mb: u32,
    ) -> HostTensor {
        let n: usize = spec.shape.iter().product();
        let mut rng = self.rng(step, mb, LABEL_SALT);
        let mut buf = vec![0i32; n];
        rng.fill_tokens(&mut buf, n_classes.max(2));
        HostTensor::from_i32(&spec.shape, &buf)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(shape: &[usize], dtype: DType) -> TensorSpec {
        TensorSpec {
            shape: shape.to_vec(),
            dtype,
            bytes: (shape.iter().product::<usize>() * 4) as u64,
            name: None,
        }
    }

    #[test]
    fn deterministic_per_key() {
        let g = DataGen::new(7);
        let s = spec(&[2, 8], DType::I32);
        let a = g.input(&s, 100, 3, 1);
        let b = g.input(&s, 100, 3, 1);
        assert_eq!(a.data, b.data);
        let c = g.input(&s, 100, 3, 2);
        assert_ne!(a.data, c.data);
    }

    #[test]
    fn tokens_in_range() {
        let g = DataGen::new(0);
        let s = spec(&[4, 16], DType::I32);
        let t = g.input(&s, 50, 0, 0);
        let ids: Vec<i32> = t
            .data
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        assert!(ids.iter().all(|&i| (0..50).contains(&i)));
    }

    #[test]
    fn labels_differ_from_inputs() {
        let g = DataGen::new(0);
        let s = spec(&[2, 8], DType::I32);
        let a = g.input(&s, 100, 0, 0);
        let b = g.labels(&s, 100, 0, 0);
        assert_ne!(a.data, b.data);
    }

    #[test]
    fn float_inputs_normalish() {
        let g = DataGen::new(1);
        let s = spec(&[8, 3, 8, 8], DType::F32);
        let t = g.input(&s, 0, 0, 0);
        let v = t.to_f32();
        let mean: f32 = v.iter().sum::<f32>() / v.len() as f32;
        assert!(mean.abs() < 0.2);
    }
}
