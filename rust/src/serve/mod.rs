//! `twobp serve` — the persistent tuning service (`docs/SERVE.md`).
//!
//! The daemon and the one-shot CLI are two thin callers of the same
//! core: every job here bottoms out in the exact entry points the CLI
//! uses ([`crate::planner::TuneRequest`], [`crate::sim::score_plan`],
//! [`crate::util::gantt::render`]), so a served answer and a CLI
//! answer are the same bytes.  What the service adds is *residency* —
//! calibrated profiles, warm scratch pools, and a fingerprint-keyed
//! result cache that outlive any single job — plus scheduling:
//!
//! * jobs arrive as line-delimited JSON on stdin or a Unix socket
//!   ([`protocol`]),
//! * a deadline- and priority-aware heap orders ready work and
//!   dependency gating parks jobs until the jobs they name complete
//!   ([`queue`], [`run_batch`]) — calibration jobs therefore always
//!   run before the tunes that depend on them,
//! * every accepted job is appended to a deterministic job log that
//!   `twobp serve --replay <log>` re-executes to byte-identical
//!   responses modulo the `"wall"` quarantine key ([`joblog`]),
//! * a `shutdown` job drains the queue gracefully: everything already
//!   accepted still runs, then the service stops accepting.
//!
//! Batch model: each drain reads its input to EOF (stdin: the whole
//! stream; socket: one connection whose client half-closes after
//! writing), schedules everything, and answers in completion order.
//! Responses are deterministic because ordering is (deadline,
//! priority, submission seq) and every op is seeded.

pub mod engine;
pub mod joblog;
pub mod protocol;
pub mod queue;

pub use engine::Engine;
pub use joblog::JobLog;
pub use protocol::{strip_wall, Op, Request};
pub use queue::JobQueue;

use std::collections::{BTreeMap, BTreeSet};
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::args::Args;

/// Entry point behind `twobp serve` (see the usage text in `main.rs`).
///
/// Modes: `--replay <log>` re-executes a job log to stdout; `--socket
/// <path>` serves batches per connection until a `shutdown` job;
/// otherwise one batch is read from stdin.  `--log <file>` appends
/// accepted jobs for later replay; `--metrics-out <file>` writes the
/// deterministic registry (with `serve.*` counters) on exit;
/// `--threads <k>` sizes the planner's worker pool.
pub fn run_cli(args: &Args) -> Result<()> {
    let threads = args.get_usize("threads", 0);
    let mut engine = Engine::new(threads);

    if let Some(replay) = args.get("replay") {
        if args.get("socket").is_some() || args.get("log").is_some() {
            bail!(
                "--replay re-executes an existing job log; \
                 drop --socket/--log"
            );
        }
        let text = std::fs::read_to_string(replay)
            .with_context(|| format!("reading job log {replay}"))?;
        let (responses, _) = run_batch(&mut engine, &text, &mut None)?;
        let mut out = std::io::stdout().lock();
        for r in &responses {
            writeln!(out, "{r}")?;
        }
    } else if let Some(sock) = args.get("socket") {
        serve_socket(&mut engine, Path::new(sock), args.get("log"))?;
    } else {
        let mut input = String::new();
        std::io::stdin().read_to_string(&mut input)?;
        let mut log = open_log(args.get("log"))?;
        let (responses, _) = run_batch(&mut engine, &input, &mut log)?;
        let mut out = std::io::stdout().lock();
        for r in &responses {
            writeln!(out, "{r}")?;
        }
    }

    if let Some(path) = args.get("metrics-out") {
        engine.metrics.write(Path::new(path))?;
        eprintln!("metrics: wrote {path}");
    }
    Ok(())
}

fn open_log(path: Option<&str>) -> Result<Option<JobLog>> {
    match path {
        None => Ok(None),
        Some(p) => Ok(Some(
            JobLog::open(Path::new(p))
                .with_context(|| format!("opening job log {p}"))?,
        )),
    }
}

/// Serve batches over a Unix socket: each connection is one batch (the
/// client writes jobs, half-closes, and reads responses back).  A
/// successful `shutdown` job finishes its batch — graceful drain —
/// then stops accepting connections.
#[cfg(unix)]
fn serve_socket(
    engine: &mut Engine,
    path: &Path,
    log_path: Option<&str>,
) -> Result<()> {
    use std::os::unix::net::UnixListener;
    let _ = std::fs::remove_file(path);
    let listener = UnixListener::bind(path)
        .with_context(|| format!("binding {}", path.display()))?;
    eprintln!("serve: listening on {}", path.display());
    let mut log = open_log(log_path)?;
    for stream in listener.incoming() {
        let mut stream = stream?;
        let mut input = String::new();
        stream.read_to_string(&mut input)?;
        let (responses, shutdown) = run_batch(engine, &input, &mut log)?;
        for r in &responses {
            writeln!(stream, "{r}")?;
        }
        if shutdown {
            break;
        }
    }
    let _ = std::fs::remove_file(path);
    eprintln!("serve: drained, shutting down");
    Ok(())
}

#[cfg(not(unix))]
fn serve_socket(
    _engine: &mut Engine,
    _path: &Path,
    _log_path: Option<&str>,
) -> Result<()> {
    bail!("--socket requires a Unix platform; use the stdin leg instead")
}

/// Drain one batch of job lines through `engine`.
///
/// Submission pass: parse every line; malformed lines and duplicate ids
/// are answered immediately (and never logged), accepted jobs are
/// appended to the job log in submission order.  Scheduling pass: jobs
/// whose dependencies are all satisfied enter the deadline/priority
/// heap; completing a job releases its dependents, a failing job fails
/// them (`dependency '<id>' failed`), and jobs left parked when the
/// heap drains — dependency cycles — are answered last, in submission
/// order.  Returns the response lines in completion order plus whether
/// a `shutdown` job was executed.
pub fn run_batch(
    engine: &mut Engine,
    input: &str,
    log: &mut Option<JobLog>,
) -> Result<(Vec<String>, bool)> {
    let mut responses = Vec::new();
    let mut shutdown = false;
    let mut accepted: Vec<Request> = Vec::new();
    let mut batch_ids: BTreeSet<String> = BTreeSet::new();

    for line in input.lines() {
        if line.trim().is_empty() {
            continue;
        }
        let default_id = format!("job-{}", engine.bump_seq());
        match Request::parse(line, &default_id) {
            Err(e) => {
                engine.metrics.counter_add("serve.rejected", 1);
                responses.push(protocol::error_line(None, &e));
            }
            Ok(r) => {
                if batch_ids.contains(&r.id)
                    || engine.done_status(&r.id).is_some()
                {
                    engine.metrics.counter_add("serve.rejected", 1);
                    responses.push(protocol::error_line(
                        Some(&r.id),
                        &format!("duplicate job id '{}'", r.id),
                    ));
                } else {
                    if let Some(l) = log.as_mut() {
                        l.append(&r.raw).context("appending to job log")?;
                    }
                    engine.metrics.counter_add("serve.accepted", 1);
                    batch_ids.insert(r.id.clone());
                    accepted.push(r);
                }
            }
        }
    }

    let n = accepted.len();
    let id_to_idx: BTreeMap<&str, usize> = accepted
        .iter()
        .enumerate()
        .map(|(i, r)| (r.id.as_str(), i))
        .collect();
    let mut unmet = vec![0usize; n];
    let mut dependents: Vec<Vec<usize>> = vec![Vec::new(); n];
    let mut failed: Vec<Option<String>> = vec![None; n];

    for (i, r) in accepted.iter().enumerate() {
        for dep in &r.deps {
            if let Some(&j) = id_to_idx.get(dep.as_str()) {
                if j == i {
                    failed[i] =
                        Some(format!("job '{}' depends on itself", r.id));
                } else {
                    unmet[i] += 1;
                    dependents[j].push(i);
                }
            } else {
                match engine.done_status(dep) {
                    Some(true) => {}
                    Some(false) => {
                        failed[i] =
                            Some(format!("dependency '{dep}' failed"));
                    }
                    None => {
                        failed[i] =
                            Some(format!("unknown dependency '{dep}'"));
                    }
                }
            }
        }
    }

    let mut queue = JobQueue::new();
    for (i, r) in accepted.iter().enumerate() {
        if unmet[i] == 0 {
            queue.push(r.deadline, r.priority, i);
        }
    }

    while let Some(i) = queue.pop() {
        let (line, ok) = match &failed[i] {
            Some(e) => {
                engine.metrics.counter_add("serve.dep_failures", 1);
                (protocol::error_line(Some(&accepted[i].id), e), false)
            }
            None => engine.execute(&accepted[i]),
        };
        if accepted[i].op == Op::Shutdown && ok {
            shutdown = true;
        }
        engine.mark_done(&accepted[i].id, ok);
        responses.push(line);
        for &d in &dependents[i] {
            if !ok && failed[d].is_none() {
                failed[d] =
                    Some(format!("dependency '{}' failed", accepted[i].id));
            }
            unmet[d] -= 1;
            if unmet[d] == 0 {
                queue.push(accepted[d].deadline, accepted[d].priority, d);
            }
        }
    }

    for (i, r) in accepted.iter().enumerate() {
        if unmet[i] > 0 && engine.done_status(&r.id).is_none() {
            engine.metrics.counter_add("serve.dep_failures", 1);
            responses.push(protocol::error_line(
                Some(&r.id),
                "unresolved dependency cycle",
            ));
            engine.mark_done(&r.id, false);
        }
    }

    Ok((responses, shutdown))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::observer::NullObserver;
    use crate::planner::{BeamConfig, TuneProfile, TuneRequest};
    use crate::schedule::{generate, plan_io, ScheduleKind};

    fn plan_json_text() -> String {
        plan_io::to_text(&generate(ScheduleKind::GPipe, true, 2, 4, false))
            .replace('\\', "\\\\")
            .replace('"', "\\\"")
            .replace('\n', "\\n")
    }

    #[test]
    fn calibration_gates_the_tune_that_depends_on_it() {
        let mut e = Engine::new(0);
        // The tune is submitted FIRST and has the EARLIEST deadline; it
        // must still run after the calibrate it depends on.
        let input = concat!(
            r#"{"op":"tune","id":"t","profile":"p","deps":["c"],"#,
            r#""deadline":1,"beam":2,"gens":1,"mutations":1}"#,
            "\n",
            r#"{"op":"calibrate","id":"c","name":"p","ranks":2,"#,
            r#""deadline":99}"#,
            "\n",
        );
        let (resp, shutdown) = run_batch(&mut e, input, &mut None).unwrap();
        assert!(!shutdown);
        assert_eq!(resp.len(), 2, "{resp:?}");
        assert!(resp[0].contains("\"id\":\"c\""), "{resp:?}");
        assert!(resp[1].contains("\"id\":\"t\""), "{resp:?}");
        assert!(resp[1].contains("\"ok\":true"), "{resp:?}");
    }

    #[test]
    fn dependency_failures_cascade_and_stragglers_are_reported() {
        let mut e = Engine::new(0);
        let input = concat!(
            r#"{"op":"tune","id":"bad","profile":"missing"}"#,
            "\n",
            r#"{"op":"gantt","id":"child","deps":["bad"],"plan":"x"}"#,
            "\n",
            r#"{"op":"shutdown","id":"orphan","deps":["ghost"]}"#,
            "\n",
            r#"{"op":"shutdown","id":"a","deps":["b"]}"#,
            "\n",
            r#"{"op":"shutdown","id":"b","deps":["a"]}"#,
            "\n",
            r#"{"op":"shutdown","id":"a"}"#,
            "\n",
        );
        let (resp, shutdown) = run_batch(&mut e, input, &mut None).unwrap();
        // None of the shutdown jobs executed ok.
        assert!(!shutdown);
        assert_eq!(resp.len(), 6, "{resp:?}");
        let find = |id: &str| {
            resp.iter()
                .find(|r| r.contains(&format!("\"id\":\"{id}\"")))
                .unwrap_or_else(|| panic!("no response for {id}: {resp:?}"))
        };
        assert!(find("bad").contains("unknown profile"), "{resp:?}");
        assert!(
            find("child").contains("dependency 'bad' failed"),
            "{resp:?}"
        );
        assert!(
            find("orphan").contains("unknown dependency 'ghost'"),
            "{resp:?}"
        );
        assert!(find("a").contains("cycle"), "{resp:?}");
        assert!(find("b").contains("cycle"), "{resp:?}");
        // The duplicate "a" was rejected at submission.
        assert!(
            resp.iter().any(|r| r.contains("duplicate job id 'a'")),
            "{resp:?}"
        );
        assert_eq!(e.metrics.counter("serve.rejected"), 1);
    }

    #[test]
    fn scripted_batch_matches_one_shot_tunes_and_hits_the_cache() {
        let mut e = Engine::new(0);
        // The acceptance batch: calibrate -> three dependent tunes ->
        // one repeated tune (same knobs as t1, so a cache hit).
        let input = concat!(
            r#"{"op":"calibrate","id":"c","name":"m","ranks":2,"p1":1.2}"#,
            "\n",
            r#"{"op":"tune","id":"t1","profile":"m","deps":["c"],"#,
            r#""beam":2,"gens":1,"mutations":1}"#,
            "\n",
            r#"{"op":"tune","id":"t2","profile":"m","deps":["c"],"#,
            r#""beam":2,"gens":1,"mutations":1,"seed":7}"#,
            "\n",
            r#"{"op":"tune","id":"t3","profile":"m","deps":["c"],"#,
            r#""beam":2,"gens":2,"mutations":1}"#,
            "\n",
            r#"{"op":"tune","id":"t4","profile":"m","#,
            r#""beam":2,"gens":1,"mutations":1}"#,
            "\n",
        );
        let (resp, _) = run_batch(&mut e, input, &mut None).unwrap();
        assert_eq!(resp.len(), 5, "{resp:?}");
        assert!(resp.iter().all(|r| r.contains("\"ok\":true")), "{resp:?}");
        assert_eq!(e.metrics.counter("serve.cache_hits"), 1);
        assert_eq!(e.metrics.counter("serve.cache_misses"), 3);
        let t4 = resp.iter().find(|r| r.contains("\"id\":\"t4\"")).unwrap();
        assert!(t4.contains("\"cache\":\"hit\""), "{t4}");

        // The service's winner is the one-shot API's winner.
        let mut profile = TuneProfile::from_ratios(2, 1.0, 1.2, 0.95, 0.05);
        profile.name = "m".to_string();
        let cfg = BeamConfig {
            beam_width: 2,
            generations: 1,
            mutations_per_parent: 1,
            ..BeamConfig::default()
        };
        let report = TuneRequest::new(&profile, 2, cfg)
            .run(&mut NullObserver)
            .unwrap();
        let t1 = resp.iter().find(|r| r.contains("\"id\":\"t1\"")).unwrap();
        let winner = format!("\"winner\":\"{}\"", report.best.plan.describe());
        assert!(t1.contains(&winner), "{t1} vs {winner}");
    }

    #[test]
    fn replay_reproduces_responses_byte_identically_modulo_wall() {
        let dir = std::env::temp_dir().join("twobp-serve-replay-test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("jobs.jsonl");
        let _ = std::fs::remove_file(&path);

        let mut e = Engine::new(0);
        let mut log = Some(JobLog::open(&path).unwrap());
        // Line 1 is rejected (consumes seq 0, never logged); the rest
        // rely on defaulted ids, which the log must materialize.
        let input = concat!(
            "not json\n",
            r#"{"op":"calibrate","name":"p","ranks":2}"#,
            "\n",
            r#"{"op":"tune","profile":"p","deps":["job-1"],"beam":2,"#,
            r#""gens":1,"mutations":1}"#,
            "\n",
            r#"{"op":"tune","profile":"p","beam":2,"gens":1,"mutations":1}"#,
            "\n",
            r#"{"op":"shutdown"}"#,
            "\n",
        );
        let (orig, shutdown) = run_batch(&mut e, input, &mut log).unwrap();
        assert!(shutdown);
        drop(log);

        let logged = std::fs::read_to_string(&path).unwrap();
        assert_eq!(logged.lines().count(), 4, "{logged}");
        assert!(logged.contains("\"id\":\"job-1\""), "{logged}");

        let mut e2 = Engine::new(0);
        let (replayed, shutdown) =
            run_batch(&mut e2, &logged, &mut None).unwrap();
        assert!(shutdown);
        let orig_accepted: Vec<&String> = orig
            .iter()
            .filter(|r| !r.contains("bad job json"))
            .collect();
        assert_eq!(orig_accepted.len(), replayed.len());
        for (a, b) in orig_accepted.iter().zip(&replayed) {
            assert_eq!(strip_wall(a), strip_wall(b));
        }
        // The repeated tune stayed a cache hit on replay.
        assert_eq!(e2.metrics.counter("serve.cache_hits"), 1);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn shuffled_submission_orders_drain_identically() {
        let t = plan_json_text();
        let jobs: Vec<String> = vec![
            r#"{"op":"calibrate","id":"c","name":"p","ranks":2,"deadline":1}"#
                .to_string(),
            format!(
                r#"{{"op":"score","id":"s1","plan":"{t}","profile":"p","deadline":2,"deps":["c"]}}"#
            ),
            format!(
                r#"{{"op":"gantt","id":"g1","plan":"{t}","cols":32,"deadline":3}}"#
            ),
            format!(r#"{{"op":"score","id":"s2","plan":"{t}","deadline":4}}"#),
            r#"{"op":"shutdown","id":"z","deadline":5}"#.to_string(),
        ];
        let run = |order: &[usize]| -> Vec<String> {
            let input = order
                .iter()
                .map(|&i| jobs[i].as_str())
                .collect::<Vec<_>>()
                .join("\n");
            let mut e = Engine::new(0);
            let (resp, shutdown) =
                run_batch(&mut e, &input, &mut None).unwrap();
            assert!(shutdown);
            resp.iter().map(|r| strip_wall(r)).collect()
        };
        let reference = run(&[0, 1, 2, 3, 4]);
        assert_eq!(reference.len(), jobs.len());

        crate::util::proptest::check(
            "serve-shuffled-submissions",
            16,
            |rng| {
                // Fisher-Yates permutation of the job indices.
                let mut order: Vec<usize> = (0..jobs.len()).collect();
                for i in (1..order.len()).rev() {
                    let j = rng.below((i + 1) as u64) as usize;
                    order.swap(i, j);
                }
                order
            },
            |order| {
                let got = run(order);
                if got == reference {
                    Ok(())
                } else {
                    Err(format!("responses diverged: {got:?}"))
                }
            },
        );
    }

    #[cfg(unix)]
    #[test]
    fn socket_leg_serves_a_batch_per_connection() {
        use std::io::{Read, Write};
        use std::net::Shutdown;
        use std::os::unix::net::UnixStream;

        let dir = std::env::temp_dir().join("twobp-serve-sock-test");
        std::fs::create_dir_all(&dir).unwrap();
        let sock = dir.join("serve.sock");
        let _ = std::fs::remove_file(&sock);

        let server = {
            let sock = sock.clone();
            std::thread::spawn(move || {
                let mut e = Engine::new(0);
                serve_socket(&mut e, &sock, None).unwrap();
                e.metrics.counter("serve.jobs")
            })
        };
        // Wait for the listener to appear.
        for _ in 0..200 {
            if sock.exists() {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(5));
        }

        let mut c = UnixStream::connect(&sock).unwrap();
        c.write_all(
            concat!(
                r#"{"op":"calibrate","id":"c","name":"p","ranks":2}"#,
                "\n",
                r#"{"op":"shutdown","id":"z"}"#,
                "\n",
            )
            .as_bytes(),
        )
        .unwrap();
        c.shutdown(Shutdown::Write).unwrap();
        let mut out = String::new();
        c.read_to_string(&mut out).unwrap();
        let lines: Vec<&str> = out.lines().collect();
        assert_eq!(lines.len(), 2, "{out}");
        assert!(lines[0].contains("\"id\":\"c\""), "{out}");
        assert!(lines[1].contains("\"id\":\"z\""), "{out}");

        assert_eq!(server.join().unwrap(), 2);
        assert!(!sock.exists());
    }
}
