//! The serve execution engine: resident state + op implementations.
//!
//! One [`Engine`] lives for the whole service session.  Across jobs it
//! keeps
//!
//! * calibrated [`TuneProfile`]s, registered by `calibrate` jobs and
//!   referenced by name from later `tune`/`score`/`gantt` jobs,
//! * a worker [`RobustScratch`] pool handed to
//!   [`TuneRequest::run_with_pool`], so repeated searches reuse warm
//!   simulation buffers instead of reallocating per job,
//! * a result cache keyed on [`TuneRequest::fingerprint`] ×
//!   [`TuneProfile::fingerprint`] — a repeated tune query returns the
//!   stored payload without re-running the search (`"cache": "hit"`),
//! * the deterministic [`MetricsRegistry`] behind `--metrics-out`
//!   (`serve.*` counters; beam search records its own `beam.*` series
//!   through the same [`crate::metrics::observer::Observer`] sink).
//!
//! Every op is deterministic given the job stream: profiles come from
//! ratios, the planner is seeded, and responses carry wall-clock only
//! under the `"wall"` quarantine key.

use std::collections::BTreeMap;
use std::time::Instant;

use crate::metrics::registry::MetricsRegistry;
use crate::planner::{
    co_search, BeamConfig, CoSearchConfig, ModelProfile, RobustObjective,
    TuneProfile, TuneRequest,
};
use crate::schedule::{plan_io, validate, Plan};
use crate::sim::{
    eval_plan, score_plan, CostModel, MemModel, Perturbation, RobustScratch,
};
use crate::util::gantt;
use crate::util::json::{obj, Json};
use crate::util::stats::parse_bytes;

use super::protocol::{
    error_line, num_field, str_field, uint_field, Op, Request,
};

/// Op payload plus cache disposition (`Some("hit"|"miss")` for
/// cacheable ops, `None` otherwise), or a client-facing error.
type OpResult = Result<(BTreeMap<String, Json>, Option<&'static str>), String>;

fn pairs(kv: Vec<(&str, Json)>) -> BTreeMap<String, Json> {
    kv.into_iter().map(|(k, v)| (k.to_string(), v)).collect()
}

/// Resident service state; see the module docs.
#[derive(Debug)]
pub struct Engine {
    threads: usize,
    next_seq: u64,
    profiles: BTreeMap<String, TuneProfile>,
    scratches: Vec<RobustScratch>,
    cache: BTreeMap<(u64, u64), BTreeMap<String, Json>>,
    done: BTreeMap<String, bool>,
    pub metrics: MetricsRegistry,
}

impl Engine {
    pub fn new(threads: usize) -> Engine {
        Engine {
            threads,
            next_seq: 0,
            profiles: BTreeMap::new(),
            scratches: Vec::new(),
            cache: BTreeMap::new(),
            done: BTreeMap::new(),
            metrics: MetricsRegistry::new(),
        }
    }

    /// Next default-id counter (one per submitted line, so generated
    /// ids are unique across batches of a session).
    pub fn bump_seq(&mut self) -> u64 {
        let s = self.next_seq;
        self.next_seq += 1;
        s
    }

    /// Completion status of a previously executed job id.
    pub fn done_status(&self, id: &str) -> Option<bool> {
        self.done.get(id).copied()
    }

    pub fn mark_done(&mut self, id: &str, ok: bool) {
        self.done.insert(id.to_string(), ok);
    }

    /// Execute one job: response line + success flag.  Wall-clock goes
    /// only under the response's `"wall"` key and the registry's wall
    /// series, keeping everything else byte-reproducible on replay.
    pub fn execute(&mut self, req: &Request) -> (String, bool) {
        self.metrics.counter_add("serve.jobs", 1);
        let t0 = Instant::now();
        match self.run_op(req) {
            Ok((mut payload, cache)) => {
                if let Some(c) = cache {
                    payload.insert("cache".to_string(), Json::Str(c.to_string()));
                }
                payload.insert("id".to_string(), Json::Str(req.id.clone()));
                payload.insert("ok".to_string(), Json::Bool(true));
                let wall = t0.elapsed().as_secs_f64();
                self.metrics.hist_record_wall("serve.job_s", wall);
                payload.insert(
                    "wall".to_string(),
                    obj(vec![("elapsed_s", Json::Num(wall))]),
                );
                (Json::Obj(payload).to_string(), true)
            }
            Err(e) => {
                self.metrics.counter_add("serve.errors", 1);
                (error_line(Some(&req.id), &e), false)
            }
        }
    }

    fn run_op(&mut self, req: &Request) -> OpResult {
        match req.op {
            Op::Calibrate => self.op_calibrate(&req.raw),
            Op::Tune => self.op_tune(&req.raw),
            Op::Score => self.op_score(&req.raw),
            Op::Gantt => self.op_gantt(&req.raw),
            Op::Shutdown => {
                self.metrics.counter_add("serve.shutdowns", 1);
                Ok((pairs(vec![("op", Json::Str("shutdown".to_string()))]), None))
            }
        }
    }

    // --- ops ---------------------------------------------------------

    /// `calibrate`: register a resident ratio profile under `"name"`.
    /// Ratio defaults match `twobp tune` (`fwd 1.0 : p1 1.05 : p2 0.95,
    /// comm 0.05`), so an all-defaults calibrate + tune pair reproduces
    /// the CLI one-shot.
    fn op_calibrate(&mut self, raw: &Json) -> OpResult {
        let name = str_field(raw, "name")?
            .ok_or("calibrate needs a \"name\" for the profile")?
            .to_string();
        let ranks = uint_field(raw, "ranks", 4)? as usize;
        if ranks < 2 {
            return Err("\"ranks\" must be >= 2".to_string());
        }
        let fwd = num_field(raw, "fwd", 1.0)?;
        let p1 = num_field(raw, "p1", 1.05)?;
        let p2 = num_field(raw, "p2", 0.95)?;
        let comm = num_field(raw, "comm", 0.05)?;
        let mut profile = TuneProfile::from_ratios(ranks, fwd, p1, p2, comm);
        profile.name = name.clone();
        let fp = profile.fingerprint();
        self.profiles.insert(name.clone(), profile);
        self.metrics.counter_add("serve.calibrations", 1);
        Ok((
            pairs(vec![
                ("name", Json::Str(name)),
                ("op", Json::Str("calibrate".to_string())),
                ("profile_fp", Json::Str(format!("{fp:016x}"))),
                ("ranks", Json::Num(ranks as f64)),
            ]),
            None,
        ))
    }

    /// `tune`: run (or cache-hit) one beam search.  Knob names and
    /// defaults mirror the `twobp tune` CLI so the service and the CLI
    /// produce identical winners for identical inputs.
    fn op_tune(&mut self, raw: &Json) -> OpResult {
        if raw.get("co_search").is_some() {
            return self.op_tune_cosearch(raw);
        }
        let profile = self.resolve_profile(raw)?;
        let n_ranks = profile.costs.fwd.len();
        let beam = Self::beam_field(raw, self.threads)?;
        let profile_fp = profile.fingerprint();
        let request = TuneRequest::new(&profile, n_ranks, beam);
        let key = (request.fingerprint(), profile_fp);
        if let Some(hit) = self.cache.get(&key) {
            self.metrics.counter_add("serve.cache_hits", 1);
            return Ok((hit.clone(), Some("hit")));
        }
        self.metrics.counter_add("serve.cache_misses", 1);
        self.metrics.counter_add("serve.tunes", 1);
        let report = {
            let Engine { scratches, metrics, .. } = self;
            request.run_with_pool(metrics, scratches)
        }
        .map_err(|e| format!("planner: {e}"))?;
        let payload = pairs(vec![
            ("evaluated", Json::Num(report.evaluated as f64)),
            (
                "gain_vs_named",
                report.gain_vs_named().map_or(Json::Null, Json::Num),
            ),
            ("generations", Json::Num(report.generations_run as f64)),
            ("makespan", Json::Num(report.best.makespan)),
            ("max_peak", Json::Num(report.best.max_peak as f64)),
            ("op", Json::Str("tune".to_string())),
            ("origin", Json::Str(report.best.origin.clone())),
            ("plan", Json::Str(report.best.text.clone())),
            ("profile", Json::Str(profile.name.clone())),
            ("profile_fp", Json::Str(format!("{profile_fp:016x}"))),
            ("ranks", Json::Num(n_ranks as f64)),
            ("request_fp", Json::Str(format!("{:016x}", key.0))),
            ("throughput", Json::Num(report.best.throughput)),
            ("winner", Json::Str(report.best.plan.describe())),
        ]);
        self.cache.insert(key, payload.clone());
        Ok((payload, Some("miss")))
    }

    /// `tune` with a `"co_search"` sub-object: the joint partition ×
    /// schedule search ([`co_search`]) instead of one fixed-stage beam.
    /// The resolved profile's stages become the per-layer model
    /// (`devices` then splits over every dp×pp divisor cell), so knob
    /// names mirror the CLI's `--co-search` cluster.  Cached like plain
    /// tune, with the co-search knobs mixed into the request
    /// fingerprint and the *per-layer* [`ModelProfile::fingerprint`]
    /// as the profile half of the key.
    fn op_tune_cosearch(&mut self, raw: &Json) -> OpResult {
        let cs = raw.get("co_search").expect("caller checked");
        if !matches!(cs, Json::Obj(_)) {
            return Err(
                "\"co_search\" must be an object of partition-search \
                 knobs (devices/layers/allreduce_per_byte/migrations)"
                    .to_string(),
            );
        }
        if raw.get("ranks").is_some() {
            return Err(
                "\"ranks\" fixes the stage count, but co_search searches \
                 the whole dp×pp grid (pipeline depth included); use \
                 co_search.devices and co_search.layers"
                    .to_string(),
            );
        }
        let devices = uint_field(cs, "devices", 4)? as usize;
        if devices == 0 {
            return Err("\"devices\" must be >= 1".to_string());
        }
        let profile = match str_field(raw, "profile")? {
            // default model: LLaMa-like at co_search.layers layers
            // (defaulting to 2 × devices — room for every depth)
            None | Some("llama") => {
                let layers =
                    uint_field(cs, "layers", (2 * devices) as u64)? as usize;
                if layers < 2 {
                    return Err("\"layers\" must be >= 2".to_string());
                }
                TuneProfile::llama_like(layers)
            }
            // a resident profile's stage count *is* the layer count
            Some(name) => {
                let p = self.profiles.get(name).ok_or_else(|| {
                    format!(
                        "unknown profile '{name}' — submit a calibrate job \
                         for it first"
                    )
                })?;
                if let Some(l) = cs.get("layers").and_then(|v| v.as_u64()) {
                    let have = p.costs.fwd.len() as u64;
                    if l != have {
                        return Err(format!(
                            "\"layers\" {l} conflicts with profile \
                             '{name}' ({have} stages = layers); drop \
                             \"layers\""
                        ));
                    }
                }
                p.clone()
            }
        };
        let allreduce = num_field(cs, "allreduce_per_byte", 2e-11)?;
        if allreduce < 0.0 {
            return Err("\"allreduce_per_byte\" must be >= 0".to_string());
        }
        let migrations = uint_field(cs, "migrations", 8)? as usize;
        let beam = Self::beam_field(raw, self.threads)?;
        let mut model = ModelProfile::from_profile(&profile);
        model.allreduce_per_byte = allreduce;
        let model_fp = model.fingerprint();
        // cache key: the fixed-stage request fingerprint (beam knobs +
        // layer count) with the co-search knobs FNV-mixed in under a
        // domain tag, × the per-layer model fingerprint
        let key_fp = {
            let mut h =
                TuneRequest::new(&profile, profile.costs.fwd.len(), beam.clone())
                    .fingerprint();
            let mut mix = |v: u64| {
                h ^= v;
                h = h.wrapping_mul(0x0000_0100_0000_01b3);
            };
            mix(7); // co-search domain tag
            mix(devices as u64);
            mix(migrations as u64);
            mix(allreduce.to_bits());
            h
        };
        let key = (key_fp, model_fp);
        if let Some(hit) = self.cache.get(&key) {
            self.metrics.counter_add("serve.cache_hits", 1);
            return Ok((hit.clone(), Some("hit")));
        }
        self.metrics.counter_add("serve.cache_misses", 1);
        self.metrics.counter_add("serve.tunes", 1);
        let mut cfg = CoSearchConfig::new(devices, beam);
        cfg.max_migrations = migrations;
        let report = co_search(&model, &cfg, &mut self.metrics)
            .map_err(|e| format!("co-search: {e}"))?;
        let best = report.best();
        let payload = pairs(vec![
            ("allreduce_s", Json::Num(best.allreduce_s)),
            ("cells", Json::Num(report.cells.len() as f64)),
            ("devices", Json::Num(devices as f64)),
            ("dp", Json::Num(best.dp as f64)),
            ("makespan", Json::Num(best.makespan)),
            ("max_peak", Json::Num(best.max_peak as f64)),
            ("migrations", Json::Num(best.migrations as f64)),
            ("model_fp", Json::Str(format!("{model_fp:016x}"))),
            ("op", Json::Str("tune".to_string())),
            ("partition", Json::Str(best.partition.describe())),
            ("plan", Json::Str(best.candidate.text.clone())),
            ("pp", Json::Num(best.pp as f64)),
            ("profile", Json::Str(profile.name.clone())),
            ("request_fp", Json::Str(format!("{key_fp:016x}"))),
            ("step_time", Json::Num(best.step_time)),
            ("throughput", Json::Num(best.throughput)),
            ("winner", Json::Str(best.candidate.plan.describe())),
        ]);
        self.cache.insert(key, payload.clone());
        Ok((payload, Some("miss")))
    }

    /// `score`: Tier-A evaluation of one submitted plan.
    fn op_score(&mut self, raw: &Json) -> OpResult {
        let plan = Self::plan_field(raw)?;
        let budget = Self::budget_field(raw)?;
        let (costs, mem, samples) = self.cost_stack(raw, &plan)?;
        if self.scratches.is_empty() {
            self.scratches.push(RobustScratch::new());
        }
        let score = score_plan(
            &plan,
            &costs,
            mem.as_ref(),
            budget,
            self.scratches[0].sim_mut(),
        )
        .map_err(|e| format!("sim: {e}"))?;
        self.metrics.counter_add("serve.scores", 1);
        Ok((
            pairs(vec![
                ("bubble_ratio", Json::Num(score.bubble_ratio)),
                ("fits", Json::Bool(score.fits)),
                ("makespan", Json::Num(score.makespan)),
                ("max_peak", Json::Num(score.max_peak as f64)),
                ("op", Json::Str("score".to_string())),
                ("plan", Json::Str(plan.describe())),
                (
                    "throughput",
                    Json::Num(score.throughput(samples, plan.n_microbatches)),
                ),
            ]),
            None,
        ))
    }

    /// `gantt`: render one plan's simulated timeline as ASCII art.
    fn op_gantt(&mut self, raw: &Json) -> OpResult {
        let plan = Self::plan_field(raw)?;
        let cols = uint_field(raw, "cols", 96)? as usize;
        if cols == 0 {
            return Err("\"cols\" must be positive".to_string());
        }
        let (costs, _, _) = self.cost_stack(raw, &plan)?;
        let eval = eval_plan(&plan, &costs, None, None)
            .map_err(|e| format!("sim: {e}"))?;
        self.metrics.counter_add("serve.gantts", 1);
        Ok((
            pairs(vec![
                ("cols", Json::Num(cols as f64)),
                ("gantt", Json::Str(gantt::render(&eval.result.spans, cols))),
                ("makespan", Json::Num(eval.result.makespan)),
                ("op", Json::Str("gantt".to_string())),
                ("plan", Json::Str(plan.describe())),
            ]),
            None,
        ))
    }

    // --- field readers ----------------------------------------------

    /// Profile for `tune`: absent or `"llama"` builds the default
    /// LLaMa-like profile at `"ranks"` (default 4); any other name must
    /// be resident (registered by an earlier `calibrate` job).
    fn resolve_profile(&self, raw: &Json) -> Result<TuneProfile, String> {
        match str_field(raw, "profile")? {
            None | Some("llama") => {
                let ranks = uint_field(raw, "ranks", 4)? as usize;
                if ranks < 2 {
                    return Err("\"ranks\" must be >= 2".to_string());
                }
                Ok(TuneProfile::llama_like(ranks))
            }
            Some(name) => {
                let p = self.profiles.get(name).ok_or_else(|| {
                    format!(
                        "unknown profile '{name}' — submit a calibrate job \
                         for it first (resident: [{}])",
                        self.profiles
                            .keys()
                            .cloned()
                            .collect::<Vec<_>>()
                            .join(", ")
                    )
                })?;
                if let Some(r) = raw.get("ranks").and_then(|v| v.as_u64()) {
                    let have = p.costs.fwd.len() as u64;
                    if r != have {
                        return Err(format!(
                            "\"ranks\" {r} conflicts with profile '{name}' \
                             ({have} ranks); drop \"ranks\""
                        ));
                    }
                }
                Ok(p.clone())
            }
        }
    }

    /// Cost/memory stack for `score`/`gantt`: a resident or `"llama"`
    /// profile by name, else bare ratios (`fwd`/`p1`/`p2`/`comm`,
    /// defaulting to the unit model `1 : 1 : 1, comm 0`).
    fn cost_stack(
        &self,
        raw: &Json,
        plan: &Plan,
    ) -> Result<(CostModel, Option<MemModel>, usize), String> {
        match str_field(raw, "profile")? {
            None => {
                let fwd = num_field(raw, "fwd", 1.0)?;
                let p1 = num_field(raw, "p1", 1.0)?;
                let p2 = num_field(raw, "p2", 1.0)?;
                let mut c = CostModel::ratios(plan.n_ranks, fwd, p1, p2);
                c.comm = num_field(raw, "comm", 0.0)?;
                Ok((c, None, 1))
            }
            Some("llama") => {
                let p = TuneProfile::llama_like(plan.n_ranks);
                Ok((p.costs, Some(p.mem), p.samples_per_microbatch))
            }
            Some(name) => {
                let p = self.profiles.get(name).ok_or_else(|| {
                    format!(
                        "unknown profile '{name}' — submit a calibrate job \
                         for it first"
                    )
                })?;
                if p.costs.fwd.len() != plan.n_ranks {
                    return Err(format!(
                        "plan has {} ranks but profile '{name}' has {}",
                        plan.n_ranks,
                        p.costs.fwd.len()
                    ));
                }
                Ok((
                    p.costs.clone(),
                    Some(p.mem.clone()),
                    p.samples_per_microbatch,
                ))
            }
        }
    }

    fn plan_field(raw: &Json) -> Result<Plan, String> {
        let text = str_field(raw, "plan")?.ok_or(
            "needs a \"plan\" field (plan DSL text; docs/PLAN_FORMAT.md)",
        )?;
        let plan = plan_io::parse(text).map_err(|e| format!("plan: {e}"))?;
        validate::validate(&plan).map_err(|e| format!("plan: {e}"))?;
        Ok(plan)
    }

    fn budget_field(raw: &Json) -> Result<Option<u64>, String> {
        match raw.get("budget") {
            None => Ok(None),
            Some(Json::Str(s)) => parse_bytes(s)
                .map(Some)
                .map_err(|e| format!("\"budget\": {e}")),
            Some(v) => v.as_u64().map(Some).ok_or_else(|| {
                "\"budget\" must be bytes (number) or a string like \"12GiB\""
                    .to_string()
            }),
        }
    }

    /// Beam knobs, defaulting exactly like `twobp tune`'s CLI flags.
    fn beam_field(raw: &Json, threads: usize) -> Result<BeamConfig, String> {
        let d = BeamConfig::default();
        Ok(BeamConfig {
            beam_width: uint_field(raw, "beam", d.beam_width as u64)? as usize,
            generations: uint_field(raw, "gens", d.generations as u64)?
                as usize,
            mutations_per_parent: uint_field(
                raw,
                "mutations",
                d.mutations_per_parent as u64,
            )? as usize,
            max_microbatches: uint_field(
                raw,
                "microbatches_max",
                d.max_microbatches as u64,
            )? as usize,
            seed: uint_field(raw, "seed", d.seed)?,
            threads,
            budget_bytes: Self::budget_field(raw)?,
            patience: uint_field(raw, "patience", d.patience as u64)? as usize,
            robust: Self::robust_field(raw)?,
        })
    }

    /// `"robust"` sub-object, mirroring the CLI's `--robust` knob
    /// cluster ([`crate::config::RobustConfig`]) and its defaults.
    fn robust_field(raw: &Json) -> Result<Option<RobustObjective>, String> {
        let Some(r) = raw.get("robust") else { return Ok(None) };
        if !matches!(r, Json::Obj(_)) {
            return Err(
                "\"robust\" must be an object of perturbation knobs"
                    .to_string(),
            );
        }
        let base = Perturbation::default();
        let stragglers = match r.get("stragglers") {
            None => Vec::new(),
            Some(v) => v
                .as_arr()
                .ok_or("\"stragglers\" must be an array of [rank, mult] pairs")?
                .iter()
                .map(|pair| {
                    let rank = pair.idx(0).and_then(|x| x.as_u64());
                    let mult = pair.idx(1).and_then(|x| x.as_f64());
                    match (rank, mult) {
                        (Some(rk), Some(m)) if m > 0.0 => Ok((rk as usize, m)),
                        _ => Err("\"stragglers\" entries must be \
                                  [rank, mult>0] pairs"
                            .to_string()),
                    }
                })
                .collect::<Result<Vec<_>, _>>()?,
        };
        let pert = Perturbation {
            jitter: num_field(r, "jitter", 0.05)?,
            stragglers,
            comm_spike_prob: num_field(r, "spike_prob", base.comm_spike_prob)?,
            comm_spike_mult: num_field(r, "spike_mult", base.comm_spike_mult)?,
            seed: uint_field(r, "pert_seed", base.seed)?,
        };
        if !(0.0..=1.0).contains(&pert.comm_spike_prob) {
            return Err("\"spike_prob\" must be in [0, 1]".to_string());
        }
        let trials =
            uint_field(r, "trials", RobustObjective::default().trials as u64)?
                as usize;
        Ok(Some(RobustObjective { pert, trials: trials.max(1) }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(line: &str) -> Request {
        Request::parse(line, "t").unwrap()
    }

    fn tiny_tune(id: &str) -> String {
        format!(
            r#"{{"op":"tune","id":"{id}","ranks":2,"beam":2,"gens":1,
                "mutations":1}}"#
        )
    }

    #[test]
    fn repeated_tune_is_a_cache_hit_without_re_search() {
        let mut e = Engine::new(1);
        let (first, ok) = e.execute(&req(&tiny_tune("a")));
        assert!(ok, "{first}");
        assert!(first.contains("\"cache\":\"miss\""), "{first}");
        let seeds = e.metrics.counter("beam.seeds");
        let evaluated = e.metrics.counter("beam.evaluated");
        assert!(seeds > 0 && evaluated > 0);

        let (second, ok) = e.execute(&req(&tiny_tune("b")));
        assert!(ok, "{second}");
        assert!(second.contains("\"cache\":\"hit\""), "{second}");
        // No re-search: the beam counters did not move.
        assert_eq!(e.metrics.counter("beam.seeds"), seeds);
        assert_eq!(e.metrics.counter("beam.evaluated"), evaluated);
        assert_eq!(e.metrics.counter("serve.cache_hits"), 1);
        assert_eq!(e.metrics.counter("serve.cache_misses"), 1);

        // Identical payloads modulo id + cache disposition + wall.
        let norm = |line: &str, id: &str| {
            super::super::protocol::strip_wall(line)
                .replace(&format!("\"id\":\"{id}\""), "\"id\":\"_\"")
                .replace("\"cache\":\"hit\"", "\"cache\":\"_\"")
                .replace("\"cache\":\"miss\"", "\"cache\":\"_\"")
        };
        assert_eq!(norm(&first, "a"), norm(&second, "b"));
    }

    #[test]
    fn calibrated_profile_changes_the_cache_key() {
        let mut e = Engine::new(1);
        let (line, ok) = e.execute(&req(
            r#"{"op":"calibrate","id":"c","name":"p","ranks":2,"p1":1.3}"#,
        ));
        assert!(ok, "{line}");
        // Same beam knobs, different profile: a miss, not a hit.
        let (a, ok) = e.execute(&req(&tiny_tune("a")));
        assert!(ok, "{a}");
        let (b, ok) = e.execute(&req(
            r#"{"op":"tune","id":"b","profile":"p","beam":2,"gens":1,
                "mutations":1}"#,
        ));
        assert!(ok, "{b}");
        assert!(b.contains("\"cache\":\"miss\""), "{b}");
        assert_eq!(e.metrics.counter("serve.cache_misses"), 2);

        // Unknown profile is a client error listing residents.
        let (err, ok) =
            e.execute(&req(r#"{"op":"tune","id":"x","profile":"nope"}"#));
        assert!(!ok);
        assert!(err.contains("unknown profile 'nope'"), "{err}");
        assert!(err.contains("resident: [p]"), "{err}");
    }

    #[test]
    fn co_search_tune_jobs_cache_on_partition_knobs() {
        let mut e = Engine::new(1);
        let job = |id: &str, devices: u64| {
            format!(
                r#"{{"op":"tune","id":"{id}","beam":2,"gens":1,
                    "mutations":1,
                    "co_search":{{"devices":{devices},"layers":4}}}}"#
            )
        };
        let (a, ok) = e.execute(&req(&job("a", 2)));
        assert!(ok, "{a}");
        assert!(a.contains("\"cache\":\"miss\""), "{a}");
        // the winner carries its partition (payload field + v2 plan)
        assert!(a.contains("\"partition\":\"dp="), "{a}");
        assert!(a.contains("part dp"), "{a}");
        assert!(e.metrics.counter("partition.cells") > 0);

        // identical knobs: served from cache, no new search
        let beams = e.metrics.counter("partition.beams");
        let (b, ok) = e.execute(&req(&job("b", 2)));
        assert!(ok, "{b}");
        assert!(b.contains("\"cache\":\"hit\""), "{b}");
        assert_eq!(e.metrics.counter("partition.beams"), beams);

        // a different device count is a different cache key
        let (c, ok) = e.execute(&req(&job("c", 4)));
        assert!(ok, "{c}");
        assert!(c.contains("\"cache\":\"miss\""), "{c}");

        // plain tune with the same beam knobs does not collide either
        let (d, ok) = e.execute(&req(
            r#"{"op":"tune","id":"d","ranks":4,"beam":2,"gens":1,
                "mutations":1}"#,
        ));
        assert!(ok, "{d}");
        assert!(d.contains("\"cache\":\"miss\""), "{d}");
    }

    #[test]
    fn co_search_jobs_reject_malformed_knobs() {
        let mut e = Engine::new(1);
        for (line, needle) in [
            (
                r#"{"op":"tune","id":"x","co_search":"yes"}"#,
                "must be an object",
            ),
            (
                r#"{"op":"tune","id":"x","ranks":4,"co_search":{}}"#,
                "\"ranks\" fixes the stage count",
            ),
            (
                r#"{"op":"tune","id":"x","co_search":{"devices":0}}"#,
                "\"devices\" must be >= 1",
            ),
            (
                r#"{"op":"tune","id":"x",
                    "co_search":{"allreduce_per_byte":-1}}"#,
                "must be >= 0",
            ),
        ] {
            let (err, ok) = e.execute(&req(line));
            assert!(!ok, "{line} -> {err}");
            assert!(err.contains(needle), "{line} -> {err}");
        }
        // a resident profile's stage count is the layer count
        let (line, ok) = e.execute(&req(
            r#"{"op":"calibrate","id":"c","name":"p","ranks":4}"#,
        ));
        assert!(ok, "{line}");
        let (err, ok) = e.execute(&req(
            r#"{"op":"tune","id":"x","profile":"p",
                "co_search":{"devices":2,"layers":8}}"#,
        ));
        assert!(!ok);
        assert!(err.contains("conflicts with profile 'p'"), "{err}");
        let (fine, ok) = e.execute(&req(
            r#"{"op":"tune","id":"y","profile":"p","beam":2,"gens":1,
                "mutations":1,"co_search":{"devices":2}}"#,
        ));
        assert!(ok, "{fine}");
        assert!(fine.contains("\"profile\":\"p\""), "{fine}");
    }

    #[test]
    fn score_and_gantt_evaluate_submitted_plans() {
        let mut e = Engine::new(1);
        let plan = crate::schedule::generate(
            crate::schedule::ScheduleKind::GPipe,
            true,
            2,
            4,
            false,
        );
        let text = plan_io::to_text(&plan).replace('\n', "\\n");
        let (line, ok) = e.execute(&req(&format!(
            r#"{{"op":"score","id":"s","plan":"{text}"}}"#
        )));
        assert!(ok, "{line}");
        assert!(line.contains("\"makespan\":"), "{line}");
        let (line, ok) = e.execute(&req(&format!(
            r#"{{"op":"gantt","id":"g","plan":"{text}","cols":40}}"#
        )));
        assert!(ok, "{line}");
        assert!(line.contains("\"gantt\":"), "{line}");

        let (err, ok) =
            e.execute(&req(r#"{"op":"score","id":"bad","plan":"garbage"}"#));
        assert!(!ok);
        assert!(err.contains("\"ok\":false"), "{err}");
    }
}
