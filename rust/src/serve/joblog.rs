//! Deterministic job log: every *accepted* job is appended as its
//! normalized sorted-key JSON line, in submission order, flushed per
//! line so the log survives an abrupt exit.
//!
//! Rejected lines (parse errors, duplicate ids) never reach the log,
//! so `twobp serve --replay <log>` re-parses exactly the accepted
//! stream: same ids (defaults were materialized at accept time), same
//! relative submission order, hence the same heap order and the same
//! responses byte-for-byte — modulo the `"wall"` quarantine key
//! ([`super::protocol::strip_wall`]).

use std::fs::{File, OpenOptions};
use std::io::{BufWriter, Write};
use std::path::Path;

use crate::util::json::Json;

/// Append-only job log writer.
#[derive(Debug)]
pub struct JobLog {
    out: BufWriter<File>,
}

impl JobLog {
    /// Open (create-or-append) the log at `path`.
    pub fn open(path: &Path) -> std::io::Result<JobLog> {
        let f = OpenOptions::new().create(true).append(true).open(path)?;
        Ok(JobLog { out: BufWriter::new(f) })
    }

    /// Append one accepted job's normalized form and flush.
    pub fn append(&mut self, job: &Json) -> std::io::Result<()> {
        writeln!(self.out, "{}", job.to_string())?;
        self.out.flush()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn appends_normalized_lines_in_order() {
        let dir = std::env::temp_dir().join("twobp-joblog-test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("jobs.jsonl");
        let _ = std::fs::remove_file(&path);

        let mut log = JobLog::open(&path).unwrap();
        let a = Json::parse(r#"{"op":"shutdown","id":"z"}"#).unwrap();
        let b = Json::parse(r#"{"id":"a","op":"calibrate","name":"p"}"#)
            .unwrap();
        log.append(&a).unwrap();
        log.append(&b).unwrap();
        drop(log);

        let text = std::fs::read_to_string(&path).unwrap();
        // Sorted-key normalization, submission order preserved.
        assert_eq!(
            text,
            "{\"id\":\"z\",\"op\":\"shutdown\"}\n\
             {\"id\":\"a\",\"name\":\"p\",\"op\":\"calibrate\"}\n"
        );

        // Re-opening appends rather than truncating.
        let mut log = JobLog::open(&path).unwrap();
        log.append(&a).unwrap();
        drop(log);
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 3);
        let _ = std::fs::remove_file(&path);
    }
}
