//! Deadline- and priority-aware job queue.
//!
//! A thin [`BinaryHeap`] ordered so that [`JobQueue::pop`] yields the
//! most urgent *ready* job: earliest deadline first, then highest
//! priority within a deadline, then submission order (`seq`) as the
//! final FIFO tie-break.  Dependency gating happens in the scheduler
//! ([`super::run_batch`]): a job enters the queue only once every job
//! it depends on has completed, so calibration jobs always drain before
//! the tune jobs they gate.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct Entry {
    deadline: u64,
    priority: i64,
    seq: usize,
}

impl Ord for Entry {
    // BinaryHeap is a max-heap, so "greater" means "scheduled sooner".
    fn cmp(&self, other: &Self) -> Ordering {
        other
            .deadline
            .cmp(&self.deadline)
            .then_with(|| self.priority.cmp(&other.priority))
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Priority queue over ready jobs, identified by their submission
/// index (`seq`) into the batch's accepted-job vector.
#[derive(Debug, Default)]
pub struct JobQueue {
    heap: BinaryHeap<Entry>,
}

impl JobQueue {
    pub fn new() -> JobQueue {
        JobQueue::default()
    }

    pub fn push(&mut self, deadline: u64, priority: i64, seq: usize) {
        self.heap.push(Entry { deadline, priority, seq });
    }

    /// Most urgent ready job's `seq`, or `None` when drained.
    pub fn pop(&mut self) -> Option<usize> {
        self.heap.pop().map(|e| e.seq)
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn earliest_deadline_pops_first() {
        let mut q = JobQueue::new();
        q.push(u64::MAX, 0, 0);
        q.push(5, 0, 1);
        q.push(50, 0, 2);
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), Some(0));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn priority_breaks_deadline_ties() {
        let mut q = JobQueue::new();
        q.push(10, 0, 0);
        q.push(10, 7, 1);
        q.push(10, -3, 2);
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(0));
        assert_eq!(q.pop(), Some(2));
    }

    #[test]
    fn submission_order_is_the_final_tiebreak() {
        let mut q = JobQueue::new();
        q.push(10, 1, 2);
        q.push(10, 1, 0);
        q.push(10, 1, 1);
        assert_eq!(q.pop(), Some(0));
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
        assert!(q.is_empty());
        assert_eq!(q.len(), 0);
    }
}
