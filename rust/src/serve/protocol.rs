//! The serve wire protocol: line-delimited JSON jobs in, line-delimited
//! JSON responses out (`docs/SERVE.md` has the full grammar).
//!
//! A job is one JSON object per line.  Required: `"op"` (one of
//! `calibrate | tune | score | gantt | shutdown`).  Optional scheduling
//! envelope: `"id"` (string, defaulted to `job-<seq>` and materialized
//! into the logged form so replay sees the same ids), `"deadline"`
//! (u64, smaller runs sooner; default "none" = `u64::MAX`),
//! `"priority"` (i64, larger runs sooner within a deadline; default 0),
//! and `"deps"` (array of job-id strings that must complete `ok:true`
//! first).  Op-specific fields are read by the engine
//! ([`super::engine`]); unknown fields are ignored, so clients can
//! annotate jobs freely.
//!
//! Responses are one sorted-key JSON object per line: `{"id", "ok",
//! ...}` plus op payload fields on success (and `"cache": "hit"|"miss"`
//! for cacheable ops), or `{"error", "id", "ok": false}` on failure.
//! The only nondeterministic value a response may carry lives under the
//! `"wall"` key — the same quarantine contract as
//! [`crate::metrics::registry`] — so byte-comparing replayed output
//! only requires stripping `"wall"` ([`strip_wall`]).

use crate::util::json::{obj, Json};

/// The job kinds the service executes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Op {
    /// Register a resident [`crate::planner::TuneProfile`] from cost
    /// ratios under a name later `tune`/`score` jobs can reference.
    Calibrate,
    /// Run the beam-search auto-tuner ([`crate::planner::TuneRequest`]).
    Tune,
    /// Score one plan (Tier-A simulate) against a profile or ratios.
    Score,
    /// Render an ASCII gantt chart for one plan.
    Gantt,
    /// Acknowledge, finish draining the queue, then stop accepting.
    Shutdown,
}

impl Op {
    pub fn name(self) -> &'static str {
        match self {
            Op::Calibrate => "calibrate",
            Op::Tune => "tune",
            Op::Score => "score",
            Op::Gantt => "gantt",
            Op::Shutdown => "shutdown",
        }
    }

    fn parse(s: &str) -> Result<Op, String> {
        match s {
            "calibrate" => Ok(Op::Calibrate),
            "tune" => Ok(Op::Tune),
            "score" => Ok(Op::Score),
            "gantt" => Ok(Op::Gantt),
            "shutdown" => Ok(Op::Shutdown),
            other => Err(format!(
                "unknown op '{other}' (calibrate|tune|score|gantt|shutdown)"
            )),
        }
    }
}

/// One parsed, normalized job.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: String,
    pub op: Op,
    /// Smaller deadlines are scheduled first; absent = `u64::MAX`.
    pub deadline: u64,
    /// Larger priorities break deadline ties; absent = 0.
    pub priority: i64,
    /// Ids of jobs that must complete `ok` before this one runs.
    pub deps: Vec<String>,
    /// The job object as submitted, with a defaulted `"id"`
    /// materialized — this exact form goes to the job log, so replay
    /// re-parses to an identical `Request`.
    pub raw: Json,
}

impl Request {
    /// Parse one job line.  `default_id` is used (and written back into
    /// the normalized form) when the client did not name the job.
    pub fn parse(line: &str, default_id: &str) -> Result<Request, String> {
        let v = Json::parse(line).map_err(|e| format!("bad job json: {e}"))?;
        let Json::Obj(mut m) = v else {
            return Err("job must be a JSON object".to_string());
        };
        let op = match m.get("op") {
            Some(Json::Str(s)) => Op::parse(s)?,
            Some(_) => return Err("\"op\" must be a string".to_string()),
            None => return Err("job needs an \"op\" field".to_string()),
        };
        let id = match m.get("id") {
            Some(Json::Str(s)) if !s.is_empty() => s.clone(),
            Some(_) => return Err("\"id\" must be a non-empty string".to_string()),
            None => {
                m.insert("id".to_string(), Json::Str(default_id.to_string()));
                default_id.to_string()
            }
        };
        let deadline = match m.get("deadline") {
            None => u64::MAX,
            Some(v) => v
                .as_u64()
                .ok_or("\"deadline\" must be a non-negative integer")?,
        };
        let priority = match m.get("priority") {
            None => 0,
            Some(v) => v.as_i64().ok_or("\"priority\" must be an integer")?,
        };
        let deps = match m.get("deps") {
            None => Vec::new(),
            Some(v) => v
                .as_arr()
                .ok_or("\"deps\" must be an array of job-id strings")?
                .iter()
                .map(|d| {
                    d.as_str().map(str::to_string).ok_or_else(|| {
                        "\"deps\" entries must be job-id strings".to_string()
                    })
                })
                .collect::<Result<Vec<_>, _>>()?,
        };
        Ok(Request { id, op, deadline, priority, deps, raw: Json::Obj(m) })
    }
}

/// Build an error response line.  `id` is `None` only for lines that
/// failed to parse far enough to have one.
pub fn error_line(id: Option<&str>, msg: &str) -> String {
    obj(vec![
        ("error", Json::Str(msg.to_string())),
        ("id", id.map_or(Json::Null, |s| Json::Str(s.to_string()))),
        ("ok", Json::Bool(false)),
    ])
    .to_string()
}

/// Drop the `"wall"` quarantine key from a response line so replayed
/// output can be byte-compared deterministically.  Non-JSON lines pass
/// through unchanged.
pub fn strip_wall(line: &str) -> String {
    match Json::parse(line) {
        Ok(Json::Obj(mut m)) => {
            m.remove("wall");
            Json::Obj(m).to_string()
        }
        _ => line.to_string(),
    }
}

// --- typed field accessors shared by the engine's op readers ---------

pub fn num_field(raw: &Json, key: &str, default: f64) -> Result<f64, String> {
    match raw.get(key) {
        None => Ok(default),
        Some(v) => v
            .as_f64()
            .ok_or_else(|| format!("\"{key}\" must be a number")),
    }
}

pub fn uint_field(raw: &Json, key: &str, default: u64) -> Result<u64, String> {
    match raw.get(key) {
        None => Ok(default),
        Some(v) => v
            .as_u64()
            .ok_or_else(|| format!("\"{key}\" must be a non-negative integer")),
    }
}

pub fn str_field<'a>(
    raw: &'a Json,
    key: &str,
) -> Result<Option<&'a str>, String> {
    match raw.get(key) {
        None => Ok(None),
        Some(v) => v
            .as_str()
            .map(Some)
            .ok_or_else(|| format!("\"{key}\" must be a string")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_a_full_job_and_defaults() {
        let r = Request::parse(
            r#"{"op":"tune","id":"t1","deadline":5,"priority":2,
                "deps":["c0"],"profile":"p"}"#,
            "job-9",
        )
        .unwrap();
        assert_eq!(r.id, "t1");
        assert_eq!(r.op, Op::Tune);
        assert_eq!(r.deadline, 5);
        assert_eq!(r.priority, 2);
        assert_eq!(r.deps, vec!["c0".to_string()]);

        let d = Request::parse(r#"{"op":"shutdown"}"#, "job-3").unwrap();
        assert_eq!(d.id, "job-3");
        assert_eq!(d.deadline, u64::MAX);
        assert_eq!(d.priority, 0);
        assert!(d.deps.is_empty());
        // The defaulted id is materialized into the logged form.
        assert!(d.raw.to_string().contains("\"id\":\"job-3\""));
    }

    #[test]
    fn rejects_malformed_jobs() {
        for (line, needle) in [
            ("nonsense", "bad job json"),
            ("[1,2]", "must be a JSON object"),
            (r#"{"id":"x"}"#, "needs an \"op\""),
            (r#"{"op":"dance"}"#, "unknown op 'dance'"),
            (r#"{"op":"tune","id":""}"#, "non-empty"),
            (r#"{"op":"tune","deadline":-1}"#, "\"deadline\""),
            (r#"{"op":"tune","deps":"c0"}"#, "\"deps\" must be an array"),
            (r#"{"op":"tune","deps":[1]}"#, "job-id strings"),
        ] {
            let err = Request::parse(line, "j").unwrap_err();
            assert!(err.contains(needle), "{line} -> {err}");
        }
    }

    #[test]
    fn strip_wall_removes_only_the_quarantine_key() {
        let line = r#"{"id":"a","ok":true,"wall":{"elapsed_s":0.12}}"#;
        assert_eq!(strip_wall(line), r#"{"id":"a","ok":true}"#);
        assert_eq!(strip_wall("not json"), "not json");
    }
}
