//! Local plan mutations — the beam search's neighborhood.
//!
//! Every move returns a *candidate* plan; [`mutate`] gates it through
//! **incremental revalidation** so only legal plans leave this module.
//! Note that validity (per-rank op coherence + cross-rank order
//! consistency) does not guarantee liveness: a validated plan can still
//! deadlock the pipeline (rank r waiting on a forward rank r−1 has
//! scheduled after a backward that waits on rank r).  The simulator
//! detects that as a `SimError`, and the beam discards such candidates
//! at evaluation — liveness is a *scoring* concern, not a validity one.
//!
//! # Incremental revalidation
//!
//! A full `schedule::validate` pass walks every rank and rebuilds the
//! cross-rank forward/backward order vectors — O(total ops) plus
//! allocations, paid once per *candidate* in the old beam.  But each
//! local move knows exactly which validator invariants it can break,
//! and declares that as a [`Recheck`]:
//!
//! * **swap-adjacent** swaps two neighboring ops *of different kinds*
//!   on one rank.  Ops of one kind keep their relative order, so the
//!   cross-rank forward order, backward order, and mb multiset are
//!   untouched; only that rank's local invariants (fwd-before-p1,
//!   p2-after-p1, flush coverage) can break → `Recheck::Rank(r)`.
//! * **shift-flush-point / insert-flush / remove-flush** edit `Flush`
//!   ops on one rank.  `Flush` takes no part in the cross-rank orders,
//!   so only that rank's coverage/position invariants can break →
//!   `Recheck::Rank(r)`.
//! * **toggle-concat** flips a flag the validator never reads →
//!   `Recheck::None`.
//!
//! [`mutate`] runs only the declared recheck (via
//! `validate::validate_rank`); a `debug_assert` holds the incremental
//! decision equal to a full `validate` pass on every candidate, and a
//! differential proptest below fuzzes the agreement per move kind.
//! The caller must pass a plan that is itself valid — the beam
//! guarantees this by fully validating seeds once and mutating only
//! accepted candidates.
//!
//! The move set:
//!
//! * **swap-adjacent** — swap two neighboring ops of different kinds on
//!   one rank (changes the fwd/bwd interleave, e.g. warmup depth,
//!   without touching the cross-rank forward/backward orders);
//! * **shift-flush-point** — move a partial flush's `upto` bound ±1
//!   (trades stash headroom against mid-step p2 stalls, Fig 5's knob);
//! * **insert-flush / remove-flush** — add a partial flush after some
//!   `b<k>` or delete one (memory reducer / throughput raiser);
//! * **toggle-concat** — flip a flush between per-mb p2 calls and one
//!   concatenated call (Table 3's trade, live when `concat_factor ≠ 1`).

use crate::schedule::validate::{validate, validate_rank};
use crate::schedule::{Op, Partition, Plan};
use crate::util::prng::SplitMix64;

/// The validator work a move's candidate still owes — declared by the
/// move itself, from a per-move argument about which invariants it can
/// possibly break (see the module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Recheck {
    /// The move cannot break any validator invariant (e.g. toggling a
    /// concat flag, which validation never reads).
    None,
    /// The move touched a single rank's op list and provably preserved
    /// the mb multiset and the cross-rank per-kind orders; only that
    /// rank's local invariants need rechecking.
    Rank(usize),
}

/// Apply one randomly chosen local move.  Returns `None` when the
/// sampled move is inapplicable, is a no-op, or yields a plan the
/// (incremental) validation rejects; callers just retry with fresh
/// randomness.  `plan` itself must be valid.
pub fn mutate(plan: &Plan, rng: &mut SplitMix64) -> Option<(Plan, &'static str)> {
    let (cand, name, recheck) = propose(plan, rng)?;
    if cand == *plan {
        return None;
    }
    let ok = match recheck {
        Recheck::None => true,
        Recheck::Rank(r) => validate_rank(&cand, r).is_ok(),
    };
    // the incremental decision must equal the full validator's —
    // the differential safety net under the per-move arguments above
    debug_assert_eq!(
        ok,
        validate(&cand).is_ok(),
        "incremental revalidation diverged from full validate ({name})"
    );
    if !ok {
        return None;
    }
    Some((cand, name))
}

/// Sample one move and build its candidate *without* any validation —
/// the raw proposal plus the move's declared [`Recheck`].  Exposed for
/// the differential proptest; external callers use [`mutate`].
pub(crate) fn propose(
    plan: &Plan,
    rng: &mut SplitMix64,
) -> Option<(Plan, &'static str, Recheck)> {
    Some(match rng.below(8) {
        // swap carries most of the throughput exploration — weight it up
        0..=3 => {
            let (p, r) = swap_adjacent(plan, rng)?;
            (p, "swap-adjacent", Recheck::Rank(r))
        }
        4 => {
            let (p, r) = shift_flush_point(plan, rng)?;
            (p, "shift-flush-point", Recheck::Rank(r))
        }
        5 => {
            let (p, r) = insert_partial_flush(plan, rng)?;
            (p, "insert-flush", Recheck::Rank(r))
        }
        6 => {
            let (p, r) = remove_partial_flush(plan, rng)?;
            (p, "remove-flush", Recheck::Rank(r))
        }
        _ => (toggle_flush_concat(plan, rng)?, "toggle-concat",
              Recheck::None),
    })
}

/// Positions of `Flush` ops, optionally only partial ones.
fn flush_positions(plan: &Plan, partial_only: bool) -> Vec<(usize, usize)> {
    let mut out = Vec::new();
    for (r, ops) in plan.ranks.iter().enumerate() {
        for (i, op) in ops.iter().enumerate() {
            if let Op::Flush { upto, .. } = op {
                if !partial_only || upto.is_some() {
                    out.push((r, i));
                }
            }
        }
    }
    out
}

fn swap_adjacent(plan: &Plan, rng: &mut SplitMix64) -> Option<(Plan, usize)> {
    let r = rng.below(plan.n_ranks as u64) as usize;
    let ops = &plan.ranks[r];
    if ops.len() < 2 {
        return None;
    }
    let i = rng.below(ops.len() as u64 - 1) as usize;
    let (a, b) = (&ops[i], &ops[i + 1]);
    // same-kind swaps either permute the cross-rank order (invalid on
    // N > 1) or reorder interchangeable p2 work (a no-op for timing);
    // OptStep must stay last — skip them all cheaply.  Different-kind
    // swaps are also what keeps `Recheck::Rank` sound: they never
    // reorder ops *within* a kind, so the cross-rank order vectors are
    // unchanged by construction.
    if std::mem::discriminant(a) == std::mem::discriminant(b)
        || matches!(a, Op::OptStep)
        || matches!(b, Op::OptStep)
    {
        return None;
    }
    let mut out = plan.clone();
    out.ranks[r].swap(i, i + 1);
    Some((out, r))
}

fn shift_flush_point(
    plan: &Plan,
    rng: &mut SplitMix64,
) -> Option<(Plan, usize)> {
    let pts = flush_positions(plan, true);
    if pts.is_empty() {
        return None;
    }
    let (r, i) = pts[rng.below(pts.len() as u64) as usize];
    let delta: i64 = if rng.next_u64() & 1 == 1 { 1 } else { -1 };
    let mut out = plan.clone();
    if let Op::Flush { upto: Some(k), .. } = &mut out.ranks[r][i] {
        let nk = *k as i64 + delta;
        if nk < 0 || nk >= plan.n_microbatches as i64 {
            return None;
        }
        *k = nk as u32;
    }
    Some((out, r))
}

fn insert_partial_flush(
    plan: &Plan,
    rng: &mut SplitMix64,
) -> Option<(Plan, usize)> {
    // only meaningful with deferred p2 (otherwise nothing is pending)
    if !plan.greedy_p2 || plan.n_microbatches < 2 {
        return None;
    }
    let r = rng.below(plan.n_ranks as u64) as usize;
    let k = rng.below(plan.n_microbatches as u64) as u32;
    let mut out = plan.clone();
    if !crate::schedule::insert_partial_flush(&mut out.ranks[r], k, false) {
        return None;
    }
    Some((out, r))
}

fn remove_partial_flush(
    plan: &Plan,
    rng: &mut SplitMix64,
) -> Option<(Plan, usize)> {
    let pts = flush_positions(plan, true);
    if pts.is_empty() {
        return None;
    }
    let (r, i) = pts[rng.below(pts.len() as u64) as usize];
    let mut out = plan.clone();
    out.ranks[r].remove(i);
    Some((out, r))
}

fn toggle_flush_concat(plan: &Plan, rng: &mut SplitMix64) -> Option<Plan> {
    let pts = flush_positions(plan, false);
    if pts.is_empty() {
        return None;
    }
    let (r, i) = pts[rng.below(pts.len() as u64) as usize];
    let mut out = plan.clone();
    if let Op::Flush { concat, .. } = &mut out.ranks[r][i] {
        *concat = !*concat;
    }
    Some(out)
}

/// Insert `flush@k` right after `b<k>` on **every** rank — the seeding
/// helper that generalizes the Fig 5 eager-p2 variant to an arbitrary
/// flush point.  `None` if any rank lacks `b<k>` (k out of range).
/// Placement is the generator's own `insert_partial_flush`, so seeded
/// variants can never drift from the eager-p2 generator.
pub fn with_partial_flush(plan: &Plan, k: u32, concat: bool) -> Option<Plan> {
    let mut out = plan.clone();
    for ops in &mut out.ranks {
        if !crate::schedule::insert_partial_flush(ops, k, concat) {
            return None;
        }
    }
    Some(out)
}

/// Boundary-migration neighborhood of a partition: every interior cut
/// shifted ±1 where both adjacent stages stay non-empty, in
/// deterministic (cut index, −1 then +1) order — the co-search's
/// hill-climb moves (BaPipe's repartitioning step).  `dp` is never
/// changed here; the DP axis is enumerated by the divisor grid
/// (`experiments::sweep::dp_pp_cells`), not hill-climbed.
pub fn partition_neighbors(part: &Partition) -> Vec<Partition> {
    let mut out = Vec::new();
    // cuts[0] == 0 and cuts[last] == n_layers are fixed endpoints
    for c in 1..part.cuts.len().saturating_sub(1) {
        for delta in [-1i64, 1] {
            let nc = part.cuts[c] as i64 + delta;
            if nc > part.cuts[c - 1] as i64 && nc < part.cuts[c + 1] as i64 {
                let mut p = part.clone();
                p.cuts[c] = nc as usize;
                debug_assert!(p.check().is_ok());
                out.push(p);
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::{generate, ScheduleKind};
    use crate::util::proptest::{check, gen};

    #[test]
    fn partition_neighbors_shift_interior_cuts_only() {
        let p = Partition { cuts: vec![0, 2, 4, 6], dp: 2 };
        let ns = partition_neighbors(&p);
        assert_eq!(ns.len(), 4);
        let cuts: Vec<Vec<usize>> =
            ns.iter().map(|n| n.cuts.clone()).collect();
        assert_eq!(cuts, vec![
            vec![0, 1, 4, 6],
            vec![0, 3, 4, 6],
            vec![0, 2, 3, 6],
            vec![0, 2, 5, 6],
        ]);
        for n in &ns {
            n.check().unwrap();
            assert_eq!(n.dp, 2, "migration never touches dp");
            assert_eq!(n.n_layers(), p.n_layers());
        }
        // a move that would empty a stage is not proposed
        let tight = Partition::trivial(3);
        assert!(partition_neighbors(&tight).is_empty());
        // single-stage partitions have no interior cuts at all
        assert!(partition_neighbors(
            &Partition { cuts: vec![0, 5], dp: 1 }
        )
        .is_empty());
    }

    #[test]
    fn with_partial_flush_reproduces_the_eager_generator() {
        // inserting the Fig 5 flush point into plain 1F1B-2 must yield
        // exactly the eager-p2 generator's op lists
        for n in [1usize, 2, 4, 6] {
            let m = 2 * n;
            let plain = generate(ScheduleKind::OneF1B2, true, n, m, false);
            let eager =
                generate(ScheduleKind::OneF1B2EagerP2, true, n, m, false);
            let k = (m / 2).max(1) as u32 - 1;
            let enriched = with_partial_flush(&plain, k, false).unwrap();
            assert_eq!(enriched.ranks, eager.ranks, "n={n}");
        }
    }

    #[test]
    fn with_partial_flush_rejects_out_of_range() {
        let plan = generate(ScheduleKind::OneF1B1, true, 2, 2, false);
        assert!(with_partial_flush(&plan, 99, false).is_none());
    }

    /// Every accepted mutation validates, preserves the plan's shape
    /// parameters, and chains of mutations stay legal.
    #[test]
    fn prop_mutations_preserve_validity() {
        check(
            "chained planner mutations always validate",
            120,
            |rng| {
                let kind = *gen::pick(rng, &ScheduleKind::all_variants());
                let two_bp = if kind == ScheduleKind::OneF1B2EagerP2 {
                    true
                } else {
                    gen::bool(rng)
                };
                let n = gen::usize_in(rng, 1, 6);
                let m = gen::usize_in(rng, 1, 12);
                let seed = rng.next_u64();
                (kind, two_bp, n, m, seed)
            },
            |&(kind, two_bp, n, m, seed)| {
                let mut plan = generate(kind, two_bp, n, m, two_bp);
                let mut rng = SplitMix64::new(seed);
                let mut accepted = 0;
                for _ in 0..40 {
                    if let Some((next, _name)) = mutate(&plan, &mut rng) {
                        validate(&next).map_err(|e| {
                            format!("mutation escaped validation: {e}")
                        })?;
                        if next.n_ranks != plan.n_ranks
                            || next.n_microbatches != plan.n_microbatches
                            || next.two_bp != plan.two_bp
                            || next.greedy_p2 != plan.greedy_p2
                        {
                            return Err("mutation changed plan shape".into());
                        }
                        plan = next;
                        accepted += 1;
                    }
                }
                // non-degeneracy: 2BP plans with m >= 2 always admit
                // insert-flush and toggle-concat, so 40 tries accepting
                // nothing would mean the move set is broken
                if two_bp && m >= 2 && accepted == 0 {
                    return Err("no mutation ever accepted".into());
                }
                Ok(())
            },
        );
    }

    /// Satellite: the incremental revalidation decision agrees with a
    /// full `validate` pass on accept *and* reject, for every move
    /// kind, walking chains of accepted candidates exactly like the
    /// beam does.
    #[test]
    fn prop_incremental_revalidation_matches_full_validate() {
        check(
            "incremental recheck == full validate for every move kind",
            200,
            |rng| {
                let kind = *gen::pick(rng, &ScheduleKind::all_variants());
                let two_bp = if kind == ScheduleKind::OneF1B2EagerP2 {
                    true
                } else {
                    gen::bool(rng)
                };
                let n = gen::usize_in(rng, 1, 6);
                let m = gen::usize_in(rng, 1, 12);
                let seed = rng.next_u64();
                (kind, two_bp, n, m, seed)
            },
            |&(kind, two_bp, n, m, seed)| {
                let mut plan = generate(kind, two_bp, n, m, two_bp);
                let mut rng = SplitMix64::new(seed);
                for _ in 0..60 {
                    let (cand, name, recheck) =
                        match propose(&plan, &mut rng) {
                            Some(p) => p,
                            None => continue,
                        };
                    if cand == plan {
                        continue;
                    }
                    let incremental = match recheck {
                        Recheck::None => true,
                        Recheck::Rank(r) => validate_rank(&cand, r).is_ok(),
                    };
                    let full = validate(&cand).is_ok();
                    if incremental != full {
                        return Err(format!(
                            "{name}: incremental said {incremental}, \
                             full validate said {full}"
                        ));
                    }
                    if full {
                        plan = cand;
                    }
                }
                Ok(())
            },
        );
    }
}
