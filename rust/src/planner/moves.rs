//! Local plan mutations — the beam search's neighborhood.
//!
//! Every move returns a *candidate* plan; [`mutate`] gates it through
//! `schedule::validate` so only legal plans leave this module.  Note
//! that validity (per-rank op coherence + cross-rank order consistency)
//! does not guarantee liveness: a validated plan can still deadlock the
//! pipeline (rank r waiting on a forward rank r−1 has scheduled after a
//! backward that waits on rank r).  The simulator detects that as a
//! `SimError`, and the beam discards such candidates at evaluation —
//! liveness is a *scoring* concern, not a validity one.
//!
//! The move set:
//!
//! * **swap-adjacent** — swap two neighboring ops of different kinds on
//!   one rank (changes the fwd/bwd interleave, e.g. warmup depth,
//!   without touching the cross-rank forward/backward orders);
//! * **shift-flush-point** — move a partial flush's `upto` bound ±1
//!   (trades stash headroom against mid-step p2 stalls, Fig 5's knob);
//! * **insert-flush / remove-flush** — add a partial flush after some
//!   `b<k>` or delete one (memory reducer / throughput raiser);
//! * **toggle-concat** — flip a flush between per-mb p2 calls and one
//!   concatenated call (Table 3's trade, live when `concat_factor ≠ 1`).

use crate::schedule::{validate::validate, Op, Plan};
use crate::util::prng::SplitMix64;

/// Apply one randomly chosen local move.  Returns `None` when the
/// sampled move is inapplicable, is a no-op, or yields a plan the
/// validator rejects; callers just retry with fresh randomness.
pub fn mutate(plan: &Plan, rng: &mut SplitMix64) -> Option<(Plan, &'static str)> {
    let (cand, name) = match rng.below(8) {
        // swap carries most of the throughput exploration — weight it up
        0..=3 => (swap_adjacent(plan, rng)?, "swap-adjacent"),
        4 => (shift_flush_point(plan, rng)?, "shift-flush-point"),
        5 => (insert_partial_flush(plan, rng)?, "insert-flush"),
        6 => (remove_partial_flush(plan, rng)?, "remove-flush"),
        _ => (toggle_flush_concat(plan, rng)?, "toggle-concat"),
    };
    if cand == *plan {
        return None;
    }
    validate(&cand).ok()?;
    Some((cand, name))
}

/// Positions of `Flush` ops, optionally only partial ones.
fn flush_positions(plan: &Plan, partial_only: bool) -> Vec<(usize, usize)> {
    let mut out = Vec::new();
    for (r, ops) in plan.ranks.iter().enumerate() {
        for (i, op) in ops.iter().enumerate() {
            if let Op::Flush { upto, .. } = op {
                if !partial_only || upto.is_some() {
                    out.push((r, i));
                }
            }
        }
    }
    out
}

fn swap_adjacent(plan: &Plan, rng: &mut SplitMix64) -> Option<Plan> {
    let r = rng.below(plan.n_ranks as u64) as usize;
    let ops = &plan.ranks[r];
    if ops.len() < 2 {
        return None;
    }
    let i = rng.below(ops.len() as u64 - 1) as usize;
    let (a, b) = (&ops[i], &ops[i + 1]);
    // same-kind swaps either permute the cross-rank order (invalid on
    // N > 1) or reorder interchangeable p2 work (a no-op for timing);
    // OptStep must stay last — skip them all cheaply.
    if std::mem::discriminant(a) == std::mem::discriminant(b)
        || matches!(a, Op::OptStep)
        || matches!(b, Op::OptStep)
    {
        return None;
    }
    let mut out = plan.clone();
    out.ranks[r].swap(i, i + 1);
    Some(out)
}

fn shift_flush_point(plan: &Plan, rng: &mut SplitMix64) -> Option<Plan> {
    let pts = flush_positions(plan, true);
    if pts.is_empty() {
        return None;
    }
    let (r, i) = pts[rng.below(pts.len() as u64) as usize];
    let delta: i64 = if rng.next_u64() & 1 == 1 { 1 } else { -1 };
    let mut out = plan.clone();
    if let Op::Flush { upto: Some(k), .. } = &mut out.ranks[r][i] {
        let nk = *k as i64 + delta;
        if nk < 0 || nk >= plan.n_microbatches as i64 {
            return None;
        }
        *k = nk as u32;
    }
    Some(out)
}

fn insert_partial_flush(plan: &Plan, rng: &mut SplitMix64) -> Option<Plan> {
    // only meaningful with deferred p2 (otherwise nothing is pending)
    if !plan.greedy_p2 || plan.n_microbatches < 2 {
        return None;
    }
    let r = rng.below(plan.n_ranks as u64) as usize;
    let k = rng.below(plan.n_microbatches as u64) as u32;
    let mut out = plan.clone();
    if !crate::schedule::insert_partial_flush(&mut out.ranks[r], k, false) {
        return None;
    }
    Some(out)
}

fn remove_partial_flush(plan: &Plan, rng: &mut SplitMix64) -> Option<Plan> {
    let pts = flush_positions(plan, true);
    if pts.is_empty() {
        return None;
    }
    let (r, i) = pts[rng.below(pts.len() as u64) as usize];
    let mut out = plan.clone();
    out.ranks[r].remove(i);
    Some(out)
}

fn toggle_flush_concat(plan: &Plan, rng: &mut SplitMix64) -> Option<Plan> {
    let pts = flush_positions(plan, false);
    if pts.is_empty() {
        return None;
    }
    let (r, i) = pts[rng.below(pts.len() as u64) as usize];
    let mut out = plan.clone();
    if let Op::Flush { concat, .. } = &mut out.ranks[r][i] {
        *concat = !*concat;
    }
    Some(out)
}

/// Insert `flush@k` right after `b<k>` on **every** rank — the seeding
/// helper that generalizes the Fig 5 eager-p2 variant to an arbitrary
/// flush point.  `None` if any rank lacks `b<k>` (k out of range).
/// Placement is the generator's own `insert_partial_flush`, so seeded
/// variants can never drift from the eager-p2 generator.
pub fn with_partial_flush(plan: &Plan, k: u32, concat: bool) -> Option<Plan> {
    let mut out = plan.clone();
    for ops in &mut out.ranks {
        if !crate::schedule::insert_partial_flush(ops, k, concat) {
            return None;
        }
    }
    Some(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::{generate, ScheduleKind};
    use crate::util::proptest::{check, gen};

    #[test]
    fn with_partial_flush_reproduces_the_eager_generator() {
        // inserting the Fig 5 flush point into plain 1F1B-2 must yield
        // exactly the eager-p2 generator's op lists
        for n in [1usize, 2, 4, 6] {
            let m = 2 * n;
            let plain = generate(ScheduleKind::OneF1B2, true, n, m, false);
            let eager =
                generate(ScheduleKind::OneF1B2EagerP2, true, n, m, false);
            let k = (m / 2).max(1) as u32 - 1;
            let enriched = with_partial_flush(&plain, k, false).unwrap();
            assert_eq!(enriched.ranks, eager.ranks, "n={n}");
        }
    }

    #[test]
    fn with_partial_flush_rejects_out_of_range() {
        let plan = generate(ScheduleKind::OneF1B1, true, 2, 2, false);
        assert!(with_partial_flush(&plan, 99, false).is_none());
    }

    /// Every accepted mutation validates, preserves the plan's shape
    /// parameters, and chains of mutations stay legal.
    #[test]
    fn prop_mutations_preserve_validity() {
        check(
            "chained planner mutations always validate",
            120,
            |rng| {
                let kind = *gen::pick(rng, &ScheduleKind::all_variants());
                let two_bp = if kind == ScheduleKind::OneF1B2EagerP2 {
                    true
                } else {
                    gen::bool(rng)
                };
                let n = gen::usize_in(rng, 1, 6);
                let m = gen::usize_in(rng, 1, 12);
                let seed = rng.next_u64();
                (kind, two_bp, n, m, seed)
            },
            |&(kind, two_bp, n, m, seed)| {
                let mut plan = generate(kind, two_bp, n, m, two_bp);
                let mut rng = SplitMix64::new(seed);
                let mut accepted = 0;
                for _ in 0..40 {
                    if let Some((next, _name)) = mutate(&plan, &mut rng) {
                        validate(&next).map_err(|e| {
                            format!("mutation escaped validation: {e}")
                        })?;
                        if next.n_ranks != plan.n_ranks
                            || next.n_microbatches != plan.n_microbatches
                            || next.two_bp != plan.two_bp
                            || next.greedy_p2 != plan.greedy_p2
                        {
                            return Err("mutation changed plan shape".into());
                        }
                        plan = next;
                        accepted += 1;
                    }
                }
                // non-degeneracy: 2BP plans with m >= 2 always admit
                // insert-flush and toggle-concat, so 40 tries accepting
                // nothing would mean the move set is broken
                if two_bp && m >= 2 && accepted == 0 {
                    return Err("no mutation ever accepted".into());
                }
                Ok(())
            },
        );
    }
}
