//! Deterministic beam search over the legal-plan space.
//!
//! **Seeding** covers (schedule kind × 2BP × microbatch count × flush
//! point): every generator combo from `experiments::sweep::combos()` at
//! several microbatch counts, plus partial-flush-enriched variants of
//! each 2BP seed (the Fig 5 memory knob at arbitrary points).  Seeds
//! are fully validated once; mutated candidates are gated by the moves'
//! incremental revalidation (see [`super::moves`]).
//! **Evaluation** rides the Tier A scoring fast path:
//! [`crate::sim::score_plan`] under the profile's cost and memory
//! models, with one reusable [`Scratch`] per worker thread
//! (`run_grid_with`), so a candidate costs one span-free simulation and
//! zero allocations — candidates whose `max_peak` exceeds the budget
//! are rejected outright, as are plans the simulator reports as
//! deadlocked (see [`super::moves`] on validity vs liveness).
//! **Objectives**: by default candidates rank on clean-world
//! throughput; with [`BeamConfig::robust`] set they rank on tail
//! throughput — samples/sec at the p95 makespan over K seeded
//! Monte-Carlo perturbation draws ([`crate::sim::score_plan_robust`]),
//! with budget fit required in every draw.
//! **Search** keeps the `beam_width` best by throughput and expands
//! each survivor with validated local moves for up to `generations`
//! rounds, stopping early after `patience` rounds without improvement.
//!
//! Everything is deterministic for a fixed [`BeamConfig::seed`]: the
//! PRNG is consumed only in the sequential mutation loop, candidate
//! evaluation fans out through the order-preserving
//! `experiments::sweep::run_grid_with_pool`, the candidate pool and
//! dedup sets are keyed by [`Plan::fingerprint`] (a stable structural
//! hash — no per-candidate DSL serialization or `String` clone), and
//! ranking ties break on canonical DSL text, computed lazily only when
//! two candidates actually tie on (throughput, peak).  Thread count
//! never changes the result, and for a fixed seed the winner is the
//! same plan the text-keyed implementation found.
//!
//! **Entry point** (PR 9 API redesign): one [`TuneRequest`] — profile
//! + rank count + [`BeamConfig`] — run against any
//! [`Observer`](crate::metrics::observer::Observer) sink.  The
//! free-function [`tune`] remains as the telemetry-free convenience
//! wrapper; the old `tune_with(..., Option<&mut MetricsRegistry>)`
//! form is gone — pass a `&mut MetricsRegistry` (it implements
//! `Observer`) or a [`NullObserver`] instead.

use std::collections::{BTreeMap, BTreeSet};

use crate::experiments::sweep::{combos, default_threads,
                                run_grid_with_pool};
use crate::metrics::observer::{NullObserver, Observer};
use crate::schedule::{generate, plan_io, validate::validate, Partition,
                      Plan};
use crate::sim::{score_plan, score_plan_robust, Perturbation, RobustScratch};
use crate::util::prng::SplitMix64;

use super::{moves, TuneProfile};

/// Tail-makespan objective for robust tuning: rank candidates by their
/// p95 makespan over `trials` Monte-Carlo draws of `pert` instead of
/// the clean-world makespan.  Draw seeds are a pure function of
/// `(pert.seed, draw)` (see [`crate::sim::perturb`]), so every
/// candidate is scored against the *same* perturbed worlds and the
/// search stays deterministic per seed and thread count.
#[derive(Debug, Clone)]
pub struct RobustObjective {
    pub pert: Perturbation,
    /// Monte-Carlo draws per candidate (clamped to ≥ 1).
    pub trials: usize,
}

impl Default for RobustObjective {
    fn default() -> Self {
        RobustObjective { pert: Perturbation::default(), trials: 32 }
    }
}

/// Search hyper-parameters.  The defaults finish in well under a second
/// on the event-driven engine at paper scales (N ≤ 16).
#[derive(Debug, Clone)]
pub struct BeamConfig {
    pub beam_width: usize,
    pub generations: usize,
    pub mutations_per_parent: usize,
    /// Largest microbatch count seeded (0 = 4 × n_ranks).
    pub max_microbatches: usize,
    pub seed: u64,
    /// Worker threads for candidate evaluation (0 = one per core).
    pub threads: usize,
    /// Per-rank peak-byte budget; `None` = unconstrained.
    pub budget_bytes: Option<u64>,
    /// Stop after this many generations without a throughput gain.
    pub patience: usize,
    /// `Some` switches scoring to the tail objective: candidates rank
    /// on p95 makespan under the perturbation, a candidate must fit
    /// the budget in **every** draw, and the reported
    /// [`Candidate::makespan`] carries the p95 (throughput follows).
    pub robust: Option<RobustObjective>,
}

impl Default for BeamConfig {
    fn default() -> Self {
        BeamConfig {
            beam_width: 8,
            generations: 10,
            mutations_per_parent: 6,
            max_microbatches: 0,
            seed: 0x2B9,
            threads: 0,
            budget_bytes: None,
            patience: 4,
            robust: None,
        }
    }
}

/// The single entry point of the tune API: everything one search needs,
/// in one value.  `run` it against any
/// [`Observer`](crate::metrics::observer::Observer) — a
/// `&mut MetricsRegistry` to record telemetry, a
/// [`NullObserver`] when nobody is listening — and it returns a
/// [`TuneOutcome`].  Both the CLI (`twobp tune`) and the `twobp serve`
/// daemon are thin callers of this type.
#[derive(Debug, Clone)]
pub struct TuneRequest<'a> {
    pub profile: &'a TuneProfile,
    pub n_ranks: usize,
    pub beam: BeamConfig,
    /// Layer→stage partition to stamp on every candidate (the
    /// co-search sets this so winners carry their own provenance —
    /// DSL v2, gantt headers, fingerprints).  `None` = the classic
    /// per-stage world; the search itself is identical either way,
    /// since the profile is already rolled up per stage.
    pub partition: Option<Partition>,
}

impl<'a> TuneRequest<'a> {
    pub fn new(
        profile: &'a TuneProfile,
        n_ranks: usize,
        beam: BeamConfig,
    ) -> TuneRequest<'a> {
        TuneRequest { profile, n_ranks, beam, partition: None }
    }

    /// Builder: stamp `part` on every seeded/mutated candidate.
    pub fn with_partition(mut self, part: Partition) -> TuneRequest<'a> {
        self.partition = Some(part);
        self
    }

    /// Run the search.  `Err` when the profile shape mismatches
    /// `n_ranks` or when *no* candidate fits the budget.
    pub fn run(&self, obs: &mut dyn Observer) -> Result<TuneOutcome, String> {
        self.run_with_pool(obs, &mut Vec::new())
    }

    /// [`TuneRequest::run`] borrowing worker scratches from a
    /// caller-owned pool, so a long-lived caller (the serve engine)
    /// pays the simulation-buffer warm-up once across many searches.
    pub fn run_with_pool(
        &self,
        obs: &mut dyn Observer,
        scratches: &mut Vec<RobustScratch>,
    ) -> Result<TuneOutcome, String> {
        search(self, obs, scratches)
    }

    /// Stable structural fingerprint of everything that determines the
    /// search *result*: rank count and every [`BeamConfig`] knob
    /// except `threads` (thread count never changes the winner, so it
    /// must not split a result cache).  Same FNV-1a construction as
    /// [`Plan::fingerprint`]; pair it with
    /// [`TuneProfile::fingerprint`](super::TuneProfile::fingerprint)
    /// for a complete cache key — the request does not hash the
    /// profile it borrows.
    pub fn fingerprint(&self) -> u64 {
        const PRIME: u64 = 0x0000_0100_0000_01b3;
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        let mut mix = |x: u64| {
            for b in x.to_le_bytes() {
                h = (h ^ b as u64).wrapping_mul(PRIME);
            }
        };
        let b = &self.beam;
        mix(self.n_ranks as u64);
        mix(b.beam_width as u64);
        mix(b.generations as u64);
        mix(b.mutations_per_parent as u64);
        mix(b.max_microbatches as u64);
        mix(b.seed);
        match b.budget_bytes {
            None => mix(0),
            Some(v) => {
                mix(1);
                mix(v);
            }
        }
        mix(b.patience as u64);
        // mix nothing when partition is None, so every fingerprint
        // persisted before partitions existed is unchanged
        if let Some(p) = &self.partition {
            mix(6);
            mix(p.dp as u64);
            mix(p.cuts.len() as u64);
            for &c in &p.cuts {
                mix(c as u64);
            }
        }
        match &b.robust {
            None => mix(0),
            Some(ro) => {
                mix(1);
                mix(ro.pert.jitter.to_bits());
                mix(ro.pert.stragglers.len() as u64);
                for (rank, mult) in &ro.pert.stragglers {
                    mix(*rank as u64);
                    mix(mult.to_bits());
                }
                mix(ro.pert.comm_spike_prob.to_bits());
                mix(ro.pert.comm_spike_mult.to_bits());
                mix(ro.pert.seed);
                mix(ro.trials as u64);
            }
        }
        h
    }
}

/// One evaluated, budget-fitting plan as reported to callers.  During
/// the search candidates live as the text-free [`SearchCand`]; the DSL
/// `text` here is serialized once, at report time, for the winner and
/// the named-best only.
#[derive(Debug, Clone)]
pub struct Candidate {
    pub plan: Plan,
    /// Canonical DSL text, ready to write as a `.plan` file.
    pub text: String,
    pub makespan: f64,
    /// Samples/sec under the profile.
    pub throughput: f64,
    pub max_peak: u64,
    /// The seed schedule this candidate descends from.
    pub seed: String,
    /// "seed", or "g<generation>:<move>" for mutated candidates.
    pub origin: String,
}

/// A candidate as the search holds it: the plan, its structural
/// fingerprint (the pool/dedup key), and its scores.  The DSL text is
/// *not* part of evaluation — `text_cache` fills lazily, only when a
/// ranking tie actually needs it (and then at most once per
/// candidate, surviving clones into the beam).
#[derive(Debug, Clone)]
struct SearchCand {
    plan: Plan,
    fp: u64,
    makespan: f64,
    throughput: f64,
    max_peak: u64,
    seed: String,
    origin: String,
    text_cache: std::cell::OnceCell<String>,
}

impl SearchCand {
    /// Canonical DSL text, serialized on first use and cached.
    fn text(&self) -> &str {
        self.text_cache.get_or_init(|| plan_io::to_text(&self.plan))
    }

    fn publish(&self) -> Candidate {
        Candidate {
            plan: self.plan.clone(),
            text: self.text().to_string(),
            makespan: self.makespan,
            throughput: self.throughput,
            max_peak: self.max_peak,
            seed: self.seed.clone(),
            origin: self.origin.clone(),
        }
    }
}

/// Total ranking order: throughput desc, then peak asc, then canonical
/// DSL text — serialized lazily (and cached per candidate), only for
/// the exact ties on both numbers, so the hot path never materializes
/// plan text.  (Distinct pool entries always have distinct
/// fingerprints, so equal fingerprints mean the same plan.)
fn better(a: &SearchCand, b: &SearchCand) -> std::cmp::Ordering {
    b.throughput
        .total_cmp(&a.throughput)
        .then_with(|| a.max_peak.cmp(&b.max_peak))
        .then_with(|| {
            if a.fp == b.fp {
                std::cmp::Ordering::Equal
            } else {
                a.text().cmp(b.text())
            }
        })
}

/// What [`tune`] found.
#[derive(Debug, Clone)]
pub struct TuneReport {
    pub profile_name: String,
    pub n_ranks: usize,
    pub budget_bytes: Option<u64>,
    /// The winner.  Always `>=` every fitting generator schedule on
    /// throughput, because all generator combos are in the seed pool.
    pub best: Candidate,
    /// Best *unmodified* generator schedule that fits the budget
    /// (`None` when none does while an enriched/mutated plan still
    /// could — e.g. only a planner-inserted flush point fits).
    pub named_best: Option<Candidate>,
    pub evaluated: usize,
    pub rejected_budget: usize,
    pub rejected_sim: usize,
    pub generations_run: usize,
    /// Best throughput after seeding (index 0) and each generation.
    pub history: Vec<f64>,
}

impl TuneReport {
    /// Winner's throughput gain over the best fitting named schedule.
    pub fn gain_vs_named(&self) -> Option<f64> {
        self.named_best
            .as_ref()
            .map(|nb| self.best.throughput / nb.throughput)
    }
}

/// What a [`TuneRequest`] resolves to.  An alias rather than a new
/// struct: the report's shape did not change in the API redesign, only
/// how a search is invoked.
pub type TuneOutcome = TuneReport;

/// One unevaluated candidate: (plan, fingerprint, seed, origin).
type Pending = (Plan, u64, String, String);

enum EvalOut {
    Fit(Box<SearchCand>),
    OverBudget,
    SimFail,
}

#[derive(Default)]
struct Tally {
    evaluated: usize,
    rejected_budget: usize,
    rejected_sim: usize,
}

/// Fold one evaluation batch into the candidate pool, the named-plan
/// leader, and the rejection tally.
fn absorb(
    outs: Vec<EvalOut>,
    named_fps: &BTreeSet<u64>,
    pool: &mut BTreeMap<u64, SearchCand>,
    named_best: &mut Option<SearchCand>,
    tally: &mut Tally,
) {
    for out in outs {
        tally.evaluated += 1;
        match out {
            EvalOut::OverBudget => tally.rejected_budget += 1,
            EvalOut::SimFail => tally.rejected_sim += 1,
            EvalOut::Fit(cand) => {
                if named_fps.contains(&cand.fp) {
                    let replace = named_best
                        .as_ref()
                        .map(|nb| {
                            better(&cand, nb) == std::cmp::Ordering::Less
                        })
                        .unwrap_or(true);
                    if replace {
                        *named_best = Some((*cand).clone());
                    }
                }
                pool.entry(cand.fp).or_insert(*cand);
            }
        }
    }
}

/// Score one batch of already-validated candidates on the Tier A fast
/// path: each worker borrows a [`RobustScratch`] (whose inner `Scratch`
/// serves the plain objective) from the caller's pool and reuses it
/// across every candidate it pulls, so the per-candidate cost is one
/// span-free simulation (or K of them under [`BeamConfig::robust`]) —
/// no validate pass, no span vectors, no allocations once the pool is
/// warm.
fn evaluate(
    pending: &[Pending],
    profile: &TuneProfile,
    cfg: &BeamConfig,
    threads: usize,
    scratches: &mut Vec<RobustScratch>,
) -> Vec<EvalOut> {
    run_grid_with_pool(
        pending,
        threads,
        scratches,
        RobustScratch::new,
        |scratch, _, (plan, fp, seed, origin)| {
            let cand = |makespan: f64, throughput: f64, max_peak: u64| {
                EvalOut::Fit(Box::new(SearchCand {
                    plan: plan.clone(),
                    fp: *fp,
                    makespan,
                    throughput,
                    max_peak,
                    seed: seed.clone(),
                    origin: origin.clone(),
                    text_cache: std::cell::OnceCell::new(),
                }))
            };
            match &cfg.robust {
                None => match score_plan(
                    plan,
                    &profile.costs,
                    Some(&profile.mem),
                    cfg.budget_bytes,
                    scratch.sim_mut(),
                ) {
                    Err(_) => EvalOut::SimFail,
                    Ok(score) if !score.fits => EvalOut::OverBudget,
                    Ok(score) => cand(
                        score.makespan,
                        score.throughput(
                            profile.samples_per_microbatch,
                            plan.n_microbatches,
                        ),
                        score.max_peak,
                    ),
                },
                Some(ro) => match score_plan_robust(
                    plan,
                    &profile.costs,
                    Some(&profile.mem),
                    cfg.budget_bytes,
                    &ro.pert,
                    ro.trials,
                    scratch,
                ) {
                    Err(_) => EvalOut::SimFail,
                    // a robust plan must fit in every perturbed world
                    Ok(rs) if rs.fit_fraction < 1.0 => EvalOut::OverBudget,
                    Ok(rs) => cand(
                        rs.p95,
                        rs.throughput_p95(
                            profile.samples_per_microbatch,
                            plan.n_microbatches,
                        ),
                        rs.max_peak,
                    ),
                },
            }
        },
    )
}

/// The microbatch counts seeded for `n` ranks (ascending, deduped,
/// capped at `max_m`): {N, 3N/2, 2N, 3N, 4N}.  Public so the
/// `planner_throughput` bench builds its corpus from exactly the
/// shapes the beam seeds — retuning this grid retunes the bench too.
pub fn microbatch_grid(n: usize, max_m: usize) -> Vec<usize> {
    let mut ms: Vec<usize> = [n, 3 * n / 2, 2 * n, 3 * n, 4 * n]
        .into_iter()
        .filter(|&m| m >= 1 && m <= max_m)
        .collect();
    ms.sort_unstable();
    ms.dedup();
    if ms.is_empty() {
        ms.push(max_m.max(1));
    }
    ms
}

/// Per-move-kind accept/reject bookkeeping for one evaluation batch.
/// Runs *outside* the parallel Tier-A evaluation (over its results),
/// so telemetry costs nothing on the scoring fast path — and call
/// sites gate it on [`Observer::enabled`], so a null sink never pays
/// the per-candidate name formatting either.
fn record_batch(obs: &mut dyn Observer, outs: &[EvalOut], batch: &[Pending]) {
    for (out, (_, _, _, origin)) in outs.iter().zip(batch) {
        // origin is "seed" or "g<generation>:<move kind>"
        let mv = origin
            .split_once(':')
            .map(|(_, mv)| mv)
            .unwrap_or(origin.as_str());
        let bucket = match out {
            EvalOut::Fit(_) => "accept",
            EvalOut::OverBudget => "reject_budget",
            EvalOut::SimFail => "reject_sim",
        };
        obs.counter_add(&format!("beam.{bucket}.{mv}"), 1);
    }
}

/// One `beam.generation` event: generation index (0 = seeding), batch
/// size, pool size, and the incumbent best.  The best's peak bytes are
/// byte-exact model arithmetic — deterministic even for measured
/// profiles — but its makespan/throughput derive from the profile's
/// costs, so for a measured profile they are wall-clock-tainted and go
/// under `"wall"`.
fn record_generation(
    obs: &mut dyn Observer,
    gen: usize,
    batch: usize,
    pool_size: usize,
    best: &SearchCand,
    profile: &TuneProfile,
) {
    use crate::metrics::registry::Value;
    let fields = vec![
        ("gen", Value::from(gen)),
        ("batch", Value::from(batch)),
        ("pool_size", Value::from(pool_size)),
        ("best_peak", Value::from(best.max_peak)),
        ("best_origin", Value::from(best.origin.as_str())),
    ];
    let scores = [
        ("best_throughput", best.throughput),
        ("best_makespan", best.makespan),
    ];
    if profile.measured {
        obs.event_mixed("beam.generation", fields, scores.to_vec());
    } else {
        let mut fields = fields;
        for (k, v) in scores {
            fields.push((k, Value::from(v)));
        }
        obs.event("beam.generation", fields);
    }
}

/// Telemetry-free convenience wrapper: build a [`TuneRequest`] and run
/// it against a [`NullObserver`].  `Err` when the profile shape
/// mismatches `n_ranks` or when *no* candidate fits the budget.
pub fn tune(
    profile: &TuneProfile,
    n_ranks: usize,
    cfg: &BeamConfig,
) -> Result<TuneReport, String> {
    TuneRequest::new(profile, n_ranks, cfg.clone()).run(&mut NullObserver)
}

/// The search core behind [`TuneRequest::run`].  The observer records
/// seeding/candidate/dedup counters, per-move-kind accept/reject
/// tallies, and one `beam.generation` event per round (best score under
/// `"wall"` when the profile is measured — see `metrics::registry`).
/// The Tier A scoring path itself stays telemetry-free by contract:
/// every hook sits in the sequential search loop, and none of them
/// touches the PRNG, so attaching an observer can never change the
/// winner.
fn search(
    req: &TuneRequest<'_>,
    obs: &mut dyn Observer,
    scratches: &mut Vec<RobustScratch>,
) -> Result<TuneReport, String> {
    let profile = req.profile;
    let n_ranks = req.n_ranks;
    let cfg = &req.beam;
    if profile.costs.fwd.len() != n_ranks
        || profile.mem.static_bytes.len() != n_ranks
    {
        return Err(format!(
            "profile '{}' is shaped for {} ranks, tune asked for {n_ranks}",
            profile.name,
            profile.costs.fwd.len()
        ));
    }
    if let Some(p) = &req.partition {
        p.check()?;
        if p.n_stages() != n_ranks {
            return Err(format!(
                "partition has {} stages, tune asked for {n_ranks} ranks",
                p.n_stages()
            ));
        }
    }
    let threads = if cfg.threads == 0 {
        default_threads()
    } else {
        cfg.threads
    };
    // a 0-wide beam (e.g. `twobp tune --beam 0`) would make every
    // select() empty and panic; treat it as the narrowest search
    let beam_width = cfg.beam_width.max(1);
    let max_m = if cfg.max_microbatches == 0 {
        4 * n_ranks
    } else {
        cfg.max_microbatches
    };

    // -- seeding -----------------------------------------------------------
    // Seeds take the one full `validate` pass of their lifetime here;
    // everything descending from them is incrementally revalidated by
    // the move that produced it, so `score_plan` never validates.
    let mut tally = Tally::default();
    let mut pending: Vec<Pending> = Vec::new();
    let mut seen: BTreeSet<u64> = BTreeSet::new();
    let mut named_fps: BTreeSet<u64> = BTreeSet::new();
    for (kind, two_bp) in combos() {
        for &m in &microbatch_grid(n_ranks, max_m) {
            let mut plan = generate(kind, two_bp, n_ranks, m, false);
            // stamped before fingerprinting, so dedup, the DSL text,
            // and the winner all carry the partition; mutations clone
            // the plan, so descendants inherit it for free
            plan.partition = req.partition.clone();
            let fp = plan.fingerprint();
            let desc = plan.describe();
            if seen.insert(fp) {
                named_fps.insert(fp);
                if validate(&plan).is_ok() {
                    pending.push((plan.clone(), fp, desc.clone(),
                                  "seed".into()));
                } else {
                    // generators always validate (tested); count a
                    // hypothetical failure exactly like the old
                    // validate-at-eval path did
                    tally.evaluated += 1;
                    tally.rejected_sim += 1;
                }
            }
            // flush-point-enriched 2BP variants (generalized Fig 5)
            if two_bp && m >= 3 {
                for k in [m / 4, m / 2, 3 * m / 4] {
                    let k = k.clamp(1, m - 2) as u32;
                    if let Some(enriched) =
                        moves::with_partial_flush(&plan, k, false)
                    {
                        let efp = enriched.fingerprint();
                        if seen.insert(efp) {
                            if validate(&enriched).is_ok() {
                                pending.push((
                                    enriched,
                                    efp,
                                    format!("{desc} +flush@{k}"),
                                    "seed".into(),
                                ));
                            } else {
                                tally.evaluated += 1;
                                tally.rejected_sim += 1;
                            }
                        }
                    }
                }
            }
        }
    }

    let mut pool: BTreeMap<u64, SearchCand> = BTreeMap::new();
    let mut named_best: Option<SearchCand> = None;

    obs.counter_add("beam.seeds", pending.len() as u64);
    obs.counter_add("beam.candidates_proposed", pending.len() as u64);
    let outs = evaluate(&pending, profile, cfg, threads, scratches);
    if obs.enabled() {
        record_batch(obs, &outs, &pending);
    }
    absorb(outs, &named_fps, &mut pool, &mut named_best, &mut tally);

    if pool.is_empty() {
        return Err(format!(
            "no schedule fits the budget: all {} seed candidates \
             rejected ({} over budget, {} simulation failures)",
            tally.evaluated, tally.rejected_budget, tally.rejected_sim
        ));
    }

    let select = |pool: &BTreeMap<u64, SearchCand>| -> Vec<SearchCand> {
        let mut all: Vec<SearchCand> = pool.values().cloned().collect();
        all.sort_by(better);
        all.truncate(beam_width);
        all
    };

    // -- beam loop ---------------------------------------------------------
    let mut beam = select(&pool);
    let mut history = vec![beam[0].throughput];
    if obs.enabled() {
        record_generation(obs, 0, pending.len(), pool.len(), &beam[0],
                          profile);
    }
    let mut best_tput = beam[0].throughput;
    let mut rng = SplitMix64::new(cfg.seed ^ 0x2B97_C4E5);
    let mut stale = 0usize;
    let mut generations_run = 0usize;

    for g in 1..=cfg.generations {
        let mut children: Vec<Pending> = Vec::new();
        for parent in &beam {
            for _ in 0..cfg.mutations_per_parent {
                for _attempt in 0..8 {
                    if let Some((child, mv)) =
                        moves::mutate(&parent.plan, &mut rng)
                    {
                        let fp = child.fingerprint();
                        if seen.contains(&fp) {
                            // duplicate of an already-tried plan: retry
                            // with fresh randomness rather than forfeit
                            // this mutation slot
                            obs.counter_add("beam.dedup_hits", 1);
                            continue;
                        }
                        seen.insert(fp);
                        children.push((
                            child,
                            fp,
                            parent.seed.clone(),
                            format!("g{g}:{mv}"),
                        ));
                        break;
                    }
                }
            }
        }
        obs.counter_add("beam.candidates_proposed", children.len() as u64);
        let outs = evaluate(&children, profile, cfg, threads, scratches);
        if obs.enabled() {
            record_batch(obs, &outs, &children);
        }
        absorb(outs, &named_fps, &mut pool, &mut named_best, &mut tally);

        beam = select(&pool);
        history.push(beam[0].throughput);
        if obs.enabled() {
            record_generation(obs, g, children.len(), pool.len(), &beam[0],
                              profile);
        }
        generations_run = g;
        if beam[0].throughput > best_tput * (1.0 + 1e-12) {
            best_tput = beam[0].throughput;
            stale = 0;
        } else {
            stale += 1;
            if stale >= cfg.patience {
                break;
            }
        }
    }

    obs.counter_add("beam.evaluated", tally.evaluated as u64);
    obs.counter_add("beam.rejected_budget", tally.rejected_budget as u64);
    obs.counter_add("beam.rejected_sim", tally.rejected_sim as u64);
    obs.counter_add("beam.generations_run", generations_run as u64);
    Ok(TuneReport {
        profile_name: profile.name.clone(),
        n_ranks,
        budget_bytes: cfg.budget_bytes,
        best: beam[0].publish(),
        named_best: named_best.as_ref().map(SearchCand::publish),
        evaluated: tally.evaluated,
        rejected_budget: tally.rejected_budget,
        rejected_sim: tally.rejected_sim,
        generations_run,
        history,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::validate::validate;

    fn quick_cfg() -> BeamConfig {
        BeamConfig {
            beam_width: 6,
            generations: 4,
            mutations_per_parent: 4,
            seed: 7,
            ..BeamConfig::default()
        }
    }

    #[test]
    fn unconstrained_tune_finds_a_valid_winner() {
        let profile = TuneProfile::llama_like(4);
        let report = tune(&profile, 4, &quick_cfg()).unwrap();
        validate(&report.best.plan).unwrap();
        let nb = report.named_best.as_ref().expect("some named plan fits");
        assert!(
            report.best.throughput >= nb.throughput,
            "winner {} < named {}",
            report.best.throughput,
            nb.throughput
        );
        // round-trips through the DSL
        let back = plan_io::parse(&report.best.text).unwrap();
        assert_eq!(back, report.best.plan);
    }

    #[test]
    fn tune_is_deterministic_per_seed() {
        let profile = TuneProfile::llama_like(2);
        let cfg = BeamConfig { threads: 1, ..quick_cfg() };
        let a = tune(&profile, 2, &cfg).unwrap();
        let cfg4 = BeamConfig { threads: 4, ..quick_cfg() };
        let b = tune(&profile, 2, &cfg4).unwrap();
        assert_eq!(a.best.text, b.best.text, "thread count changed result");
        assert_eq!(
            a.best.makespan.to_bits(),
            b.best.makespan.to_bits()
        );
        assert_eq!(a.history.len(), b.history.len());
    }

    #[test]
    fn impossible_budget_errors_out() {
        let profile = TuneProfile::llama_like(2);
        let cfg = BeamConfig {
            budget_bytes: Some(1), // nothing fits one byte
            ..quick_cfg()
        };
        let err = tune(&profile, 2, &cfg).unwrap_err();
        assert!(err.contains("no schedule fits"), "{err}");
    }

    #[test]
    fn rank_mismatch_errors_out() {
        let profile = TuneProfile::llama_like(2);
        assert!(tune(&profile, 4, &quick_cfg()).is_err());
    }

    #[test]
    fn budget_is_a_hard_constraint() {
        let profile = TuneProfile::llama_like(4);
        // binding budget: 90% of the unconstrained winner's peak
        let unconstrained = tune(&profile, 4, &quick_cfg()).unwrap();
        let budget = unconstrained.best.max_peak * 9 / 10;
        let cfg = BeamConfig {
            budget_bytes: Some(budget),
            ..quick_cfg()
        };
        let constrained = tune(&profile, 4, &cfg).unwrap();
        assert!(
            constrained.best.max_peak <= budget,
            "winner peak {} exceeds budget {budget}",
            constrained.best.max_peak
        );
        assert!(constrained.rejected_budget > 0, "budget never rejected");
        if let Some(nb) = &constrained.named_best {
            assert!(constrained.best.throughput >= nb.throughput);
        }
    }

    /// The winner's scores come from the span-free Tier A path; they
    /// must replay bit-identically through the Tier B `eval_plan`
    /// (validate + full simulate) — the two-tier contract end-to-end
    /// at the planner level.
    #[test]
    fn winner_scores_replay_through_tier_b() {
        let profile = TuneProfile::llama_like(4);
        let report = tune(&profile, 4, &quick_cfg()).unwrap();
        let replay = crate::sim::eval_plan(
            &report.best.plan,
            &profile.costs,
            Some(&profile.mem),
            None,
        )
        .unwrap();
        assert_eq!(replay.result.makespan.to_bits(),
                   report.best.makespan.to_bits());
        assert_eq!(replay.max_peak, report.best.max_peak);
        let tput = replay.result.throughput(
            profile.samples_per_microbatch,
            report.best.plan.n_microbatches,
        );
        assert_eq!(tput.to_bits(), report.best.throughput.to_bits());
    }

    /// Robust tuning must be deterministic per seed across `--threads`
    /// values (per-draw seeds are pure functions of the perturbation
    /// seed and draw index, evaluation order never feeds the PRNG).
    #[test]
    fn robust_tune_is_deterministic_across_threads() {
        let profile = TuneProfile::llama_like(2);
        let robust = Some(RobustObjective {
            pert: Perturbation {
                jitter: 0.08,
                stragglers: vec![(1, 1.4)],
                comm_spike_prob: 0.25,
                comm_spike_mult: 6.0,
                seed: 42,
            },
            trials: 12,
        });
        let a = tune(
            &profile,
            2,
            &BeamConfig { threads: 1, robust: robust.clone(), ..quick_cfg() },
        )
        .unwrap();
        let b = tune(
            &profile,
            2,
            &BeamConfig { threads: 4, robust, ..quick_cfg() },
        )
        .unwrap();
        assert_eq!(a.best.text, b.best.text, "thread count changed result");
        assert_eq!(a.best.makespan.to_bits(), b.best.makespan.to_bits());
        assert_eq!(a.best.throughput.to_bits(), b.best.throughput.to_bits());
        assert_eq!(a.history.len(), b.history.len());
    }

    /// Under the robust objective the winner's reported makespan is
    /// the p95 over the draws — never better than its own clean-world
    /// makespan — and the winner is still a valid plan.
    #[test]
    fn robust_winner_is_valid_and_reports_tail_makespan() {
        let profile = TuneProfile::llama_like(4);
        let robust = Some(RobustObjective {
            pert: Perturbation {
                jitter: 0.1,
                stragglers: vec![(2, 1.5)],
                ..Perturbation::default()
            },
            trials: 16,
        });
        let report = tune(
            &profile,
            4,
            &BeamConfig { robust, ..quick_cfg() },
        )
        .unwrap();
        validate(&report.best.plan).unwrap();
        let clean = crate::sim::eval_plan(
            &report.best.plan,
            &profile.costs,
            Some(&profile.mem),
            None,
        )
        .unwrap();
        assert!(
            report.best.makespan >= clean.result.makespan,
            "p95 {} below the clean makespan {}",
            report.best.makespan,
            clean.result.makespan
        );
    }

    /// Telemetry is an observer: attaching a registry must not change
    /// the search result, and the counters must agree with the report's
    /// own tallies (same numbers, independently accumulated).
    #[test]
    fn telemetry_observes_without_perturbing() {
        let profile = TuneProfile::llama_like(4);
        let plain = tune(&profile, 4, &quick_cfg()).unwrap();
        let mut obs = crate::metrics::registry::MetricsRegistry::new();
        let observed = TuneRequest::new(&profile, 4, quick_cfg())
            .run(&mut obs)
            .unwrap();
        assert_eq!(plain.best.text, observed.best.text);
        assert_eq!(
            plain.best.makespan.to_bits(),
            observed.best.makespan.to_bits()
        );
        assert_eq!(plain.history, observed.history);
        assert_eq!(obs.counter("beam.evaluated"), observed.evaluated as u64);
        assert_eq!(
            obs.counter("beam.rejected_budget"),
            observed.rejected_budget as u64
        );
        assert_eq!(
            obs.counter("beam.rejected_sim"),
            observed.rejected_sim as u64
        );
        assert_eq!(
            obs.counter("beam.generations_run"),
            observed.generations_run as u64
        );
        assert!(obs.counter("beam.seeds") > 0);
        assert!(
            obs.counter("beam.candidates_proposed")
                >= obs.counter("beam.seeds")
        );
        // one generation event per history entry (index 0 = seeding)
        assert_eq!(obs.n_events(), observed.history.len());
        // ratio profiles are deterministic, so the whole log must be
        // reproducible byte-for-byte
        let mut obs2 = crate::metrics::registry::MetricsRegistry::new();
        TuneRequest::new(&profile, 4, quick_cfg())
            .run(&mut obs2)
            .unwrap();
        assert_eq!(obs.to_jsonl(), obs2.to_jsonl());
        assert!(!obs.to_jsonl().contains("\"wall\""));
    }

    /// API-redesign regression pin: every route into the search — the
    /// `tune` free function, `TuneRequest::run` with a null sink,
    /// `run` with a recording registry, and `run_with_pool` over a
    /// pre-warmed scratch pool — must produce byte/bit-identical
    /// winners for a fixed seed.
    #[test]
    fn all_tune_routes_are_byte_identical() {
        let profile = TuneProfile::llama_like(4);
        let cfg = BeamConfig {
            budget_bytes: Some(6 << 30),
            ..quick_cfg()
        };
        let via_fn = tune(&profile, 4, &cfg).unwrap();
        let req = TuneRequest::new(&profile, 4, cfg.clone());
        let via_null = req.run(&mut crate::metrics::observer::NullObserver)
            .unwrap();
        let mut reg = crate::metrics::registry::MetricsRegistry::new();
        let via_reg = req.run(&mut reg).unwrap();
        let mut pool: Vec<RobustScratch> = Vec::new();
        let via_pool_cold = req
            .run_with_pool(&mut crate::metrics::observer::NullObserver,
                           &mut pool)
            .unwrap();
        assert!(!pool.is_empty(), "pool never warmed");
        let via_pool_warm = req
            .run_with_pool(&mut crate::metrics::observer::NullObserver,
                           &mut pool)
            .unwrap();
        for other in [&via_null, &via_reg, &via_pool_cold, &via_pool_warm] {
            assert_eq!(via_fn.best.text, other.best.text);
            assert_eq!(via_fn.best.makespan.to_bits(),
                       other.best.makespan.to_bits());
            assert_eq!(via_fn.best.throughput.to_bits(),
                       other.best.throughput.to_bits());
            assert_eq!(via_fn.best.max_peak, other.best.max_peak);
            assert_eq!(via_fn.history, other.history);
            assert_eq!(via_fn.evaluated, other.evaluated);
            assert_eq!(via_fn.rejected_budget, other.rejected_budget);
        }
    }

    /// The request fingerprint is the cache key: stable across threads
    /// (which never change the result), moved by every knob that does.
    #[test]
    fn request_fingerprint_tracks_result_knobs_only() {
        let profile = TuneProfile::llama_like(4);
        let base = TuneRequest::new(&profile, 4, quick_cfg());
        let fp = base.fingerprint();
        assert_eq!(fp, base.fingerprint());

        let mut threads = base.clone();
        threads.beam.threads = 7;
        assert_eq!(threads.fingerprint(), fp,
                   "threads must not split the cache");

        let mut ranks = base.clone();
        ranks.n_ranks = 8;
        assert_ne!(ranks.fingerprint(), fp);
        let mut seed = base.clone();
        seed.beam.seed ^= 1;
        assert_ne!(seed.fingerprint(), fp);
        let mut budget = base.clone();
        budget.beam.budget_bytes = Some(0);
        assert_ne!(budget.fingerprint(), fp, "None vs Some(0) must differ");
        let mut gens = base.clone();
        gens.beam.generations += 1;
        assert_ne!(gens.fingerprint(), fp);
        let mut robust = base.clone();
        robust.beam.robust = Some(RobustObjective::default());
        assert_ne!(robust.fingerprint(), fp);
        let mut trials = robust.clone();
        trials.beam.robust.as_mut().unwrap().trials += 1;
        assert_ne!(trials.fingerprint(), robust.fingerprint());
    }

    /// A partitioned request stamps every candidate (the winner's plan
    /// and DSL text carry it), splits the cache fingerprint, and
    /// rejects stage-count mismatches up front.
    #[test]
    fn partitioned_request_stamps_the_winner() {
        let profile = TuneProfile::llama_like(4);
        let part = Partition::balanced(8, 4, 2);
        let req = TuneRequest::new(&profile, 4, quick_cfg())
            .with_partition(part.clone());
        assert_ne!(
            req.fingerprint(),
            TuneRequest::new(&profile, 4, quick_cfg()).fingerprint(),
            "partition must split the cache key"
        );
        let report = req.run(&mut NullObserver).unwrap();
        assert_eq!(report.best.plan.partition.as_ref(), Some(&part));
        assert!(report.best.text.contains("plan v2"), "{}",
                report.best.text);
        assert!(report.best.text.contains("part dp 2 layers"));
        // and the partitioned search finds the same schedule as the
        // plain one — the partition is provenance, not a constraint
        let plain = tune(&profile, 4, &quick_cfg()).unwrap();
        assert_eq!(report.best.plan.ranks, plain.best.plan.ranks);

        let bad = TuneRequest::new(&profile, 4, quick_cfg())
            .with_partition(Partition::balanced(8, 2, 1));
        let err = bad.run(&mut NullObserver).unwrap_err();
        assert!(err.contains("2 stages"), "{err}");
    }

    #[test]
    fn microbatch_grid_is_sane() {
        assert_eq!(microbatch_grid(4, 16), vec![4, 6, 8, 12, 16]);
        assert_eq!(microbatch_grid(1, 4), vec![1, 2, 3, 4]);
        assert_eq!(microbatch_grid(4, 2), vec![2]); // capped, fallback
    }
}
