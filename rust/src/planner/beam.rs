//! Deterministic beam search over the legal-plan space.
//!
//! **Seeding** covers (schedule kind × 2BP × microbatch count × flush
//! point): every generator combo from `experiments::sweep::combos()` at
//! several microbatch counts, plus partial-flush-enriched variants of
//! each 2BP seed (the Fig 5 memory knob at arbitrary points).
//! **Evaluation** is [`crate::sim::eval_plan`] under the profile's cost
//! and memory models — candidates whose `peak_bytes` exceed the budget
//! are rejected outright, as are plans the simulator reports as
//! deadlocked (see [`super::moves`] on validity vs liveness).
//! **Search** keeps the `beam_width` best by throughput and expands
//! each survivor with validated local moves for up to `generations`
//! rounds, stopping early after `patience` rounds without improvement.
//!
//! Everything is deterministic for a fixed [`BeamConfig::seed`]: the
//! PRNG is consumed only in the sequential mutation loop, candidate
//! evaluation fans out through the order-preserving
//! `experiments::sweep::run_grid`, the candidate pool is a `BTreeMap`
//! keyed by canonical DSL text, and ranking ties break on that text.
//! Thread count never changes the result.

use std::collections::BTreeMap;

use crate::experiments::sweep::{combos, default_threads, run_grid};
use crate::schedule::{generate, plan_io, Plan};
use crate::sim::eval_plan;
use crate::util::prng::SplitMix64;

use super::{moves, TuneProfile};

/// Search hyper-parameters.  The defaults finish in well under a second
/// on the event-driven engine at paper scales (N ≤ 16).
#[derive(Debug, Clone)]
pub struct BeamConfig {
    pub beam_width: usize,
    pub generations: usize,
    pub mutations_per_parent: usize,
    /// Largest microbatch count seeded (0 = 4 × n_ranks).
    pub max_microbatches: usize,
    pub seed: u64,
    /// Worker threads for candidate evaluation (0 = one per core).
    pub threads: usize,
    /// Per-rank peak-byte budget; `None` = unconstrained.
    pub budget_bytes: Option<u64>,
    /// Stop after this many generations without a throughput gain.
    pub patience: usize,
}

impl Default for BeamConfig {
    fn default() -> Self {
        BeamConfig {
            beam_width: 8,
            generations: 10,
            mutations_per_parent: 6,
            max_microbatches: 0,
            seed: 0x2B9,
            threads: 0,
            budget_bytes: None,
            patience: 4,
        }
    }
}

/// One evaluated, budget-fitting plan.
#[derive(Debug, Clone)]
pub struct Candidate {
    pub plan: Plan,
    /// Canonical DSL text — also the dedup fingerprint and the ranking
    /// tie-break, and ready to write as a `.plan` file.
    pub text: String,
    pub makespan: f64,
    /// Samples/sec under the profile.
    pub throughput: f64,
    pub max_peak: u64,
    /// The seed schedule this candidate descends from.
    pub seed: String,
    /// "seed", or "g<generation>:<move>" for mutated candidates.
    pub origin: String,
}

/// Total ranking order: throughput desc, then peak asc, then DSL text.
fn better(a: &Candidate, b: &Candidate) -> std::cmp::Ordering {
    b.throughput
        .total_cmp(&a.throughput)
        .then_with(|| a.max_peak.cmp(&b.max_peak))
        .then_with(|| a.text.cmp(&b.text))
}

/// What [`tune`] found.
#[derive(Debug, Clone)]
pub struct TuneReport {
    pub profile_name: String,
    pub n_ranks: usize,
    pub budget_bytes: Option<u64>,
    /// The winner.  Always `>=` every fitting generator schedule on
    /// throughput, because all generator combos are in the seed pool.
    pub best: Candidate,
    /// Best *unmodified* generator schedule that fits the budget
    /// (`None` when none does while an enriched/mutated plan still
    /// could — e.g. only a planner-inserted flush point fits).
    pub named_best: Option<Candidate>,
    pub evaluated: usize,
    pub rejected_budget: usize,
    pub rejected_sim: usize,
    pub generations_run: usize,
    /// Best throughput after seeding (index 0) and each generation.
    pub history: Vec<f64>,
}

impl TuneReport {
    /// Winner's throughput gain over the best fitting named schedule.
    pub fn gain_vs_named(&self) -> Option<f64> {
        self.named_best
            .as_ref()
            .map(|nb| self.best.throughput / nb.throughput)
    }
}

/// One unevaluated candidate: (plan, canonical text, seed, origin).
type Pending = (Plan, String, String, String);

enum EvalOut {
    Fit(Box<Candidate>),
    OverBudget,
    SimFail,
}

#[derive(Default)]
struct Tally {
    evaluated: usize,
    rejected_budget: usize,
    rejected_sim: usize,
}

/// Fold one evaluation batch into the candidate pool, the named-plan
/// leader, and the rejection tally.
fn absorb(
    outs: Vec<EvalOut>,
    named_texts: &std::collections::BTreeSet<String>,
    pool: &mut BTreeMap<String, Candidate>,
    named_best: &mut Option<Candidate>,
    tally: &mut Tally,
) {
    for out in outs {
        tally.evaluated += 1;
        match out {
            EvalOut::OverBudget => tally.rejected_budget += 1,
            EvalOut::SimFail => tally.rejected_sim += 1,
            EvalOut::Fit(cand) => {
                if named_texts.contains(&cand.text) {
                    let replace = named_best
                        .as_ref()
                        .map(|nb| {
                            better(&cand, nb) == std::cmp::Ordering::Less
                        })
                        .unwrap_or(true);
                    if replace {
                        *named_best = Some((*cand).clone());
                    }
                }
                pool.entry(cand.text.clone()).or_insert(*cand);
            }
        }
    }
}

fn evaluate(
    pending: &[Pending],
    profile: &TuneProfile,
    cfg: &BeamConfig,
    threads: usize,
) -> Vec<EvalOut> {
    run_grid(pending, threads, |_, (plan, text, seed, origin)| {
        match eval_plan(
            plan,
            &profile.costs,
            Some(&profile.mem),
            cfg.budget_bytes,
        ) {
            Err(_) => EvalOut::SimFail,
            Ok(ev) if !ev.fits => EvalOut::OverBudget,
            Ok(ev) => EvalOut::Fit(Box::new(Candidate {
                plan: plan.clone(),
                text: text.clone(),
                makespan: ev.result.makespan,
                throughput: ev.result.throughput(
                    profile.samples_per_microbatch,
                    plan.n_microbatches,
                ),
                max_peak: ev.max_peak,
                seed: seed.clone(),
                origin: origin.clone(),
            })),
        }
    })
}

/// The microbatch counts seeded for `n` ranks (ascending, deduped,
/// capped at `max_m`): {N, 3N/2, 2N, 3N, 4N}.
fn microbatch_grid(n: usize, max_m: usize) -> Vec<usize> {
    let mut ms: Vec<usize> = [n, 3 * n / 2, 2 * n, 3 * n, 4 * n]
        .into_iter()
        .filter(|&m| m >= 1 && m <= max_m)
        .collect();
    ms.sort_unstable();
    ms.dedup();
    if ms.is_empty() {
        ms.push(max_m.max(1));
    }
    ms
}

/// Run the search.  `Err` when the profile shape mismatches `n_ranks`
/// or when *no* candidate fits the budget.
pub fn tune(
    profile: &TuneProfile,
    n_ranks: usize,
    cfg: &BeamConfig,
) -> Result<TuneReport, String> {
    if profile.costs.fwd.len() != n_ranks
        || profile.mem.static_bytes.len() != n_ranks
    {
        return Err(format!(
            "profile '{}' is shaped for {} ranks, tune asked for {n_ranks}",
            profile.name,
            profile.costs.fwd.len()
        ));
    }
    let threads = if cfg.threads == 0 {
        default_threads()
    } else {
        cfg.threads
    };
    // a 0-wide beam (e.g. `twobp tune --beam 0`) would make every
    // select() empty and panic; treat it as the narrowest search
    let beam_width = cfg.beam_width.max(1);
    let max_m = if cfg.max_microbatches == 0 {
        4 * n_ranks
    } else {
        cfg.max_microbatches
    };

    // -- seeding -----------------------------------------------------------
    let mut pending: Vec<Pending> = Vec::new();
    let mut seen: std::collections::BTreeSet<String> =
        std::collections::BTreeSet::new();
    let mut named_texts: std::collections::BTreeSet<String> =
        std::collections::BTreeSet::new();
    for (kind, two_bp) in combos() {
        for &m in &microbatch_grid(n_ranks, max_m) {
            let plan = generate(kind, two_bp, n_ranks, m, false);
            let text = plan_io::to_text(&plan);
            let desc = plan.describe();
            if seen.insert(text.clone()) {
                named_texts.insert(text.clone());
                pending.push((plan.clone(), text, desc.clone(), "seed".into()));
            }
            // flush-point-enriched 2BP variants (generalized Fig 5)
            if two_bp && m >= 3 {
                for k in [m / 4, m / 2, 3 * m / 4] {
                    let k = k.clamp(1, m - 2) as u32;
                    if let Some(enriched) =
                        moves::with_partial_flush(&plan, k, false)
                    {
                        let etext = plan_io::to_text(&enriched);
                        if seen.insert(etext.clone()) {
                            pending.push((
                                enriched,
                                etext,
                                format!("{desc} +flush@{k}"),
                                "seed".into(),
                            ));
                        }
                    }
                }
            }
        }
    }

    let mut tally = Tally::default();
    let mut pool: BTreeMap<String, Candidate> = BTreeMap::new();
    let mut named_best: Option<Candidate> = None;

    let outs = evaluate(&pending, profile, cfg, threads);
    absorb(outs, &named_texts, &mut pool, &mut named_best, &mut tally);

    if pool.is_empty() {
        return Err(format!(
            "no schedule fits the budget: all {} seed candidates \
             rejected ({} over budget, {} simulation failures)",
            tally.evaluated, tally.rejected_budget, tally.rejected_sim
        ));
    }

    let select = |pool: &BTreeMap<String, Candidate>| -> Vec<Candidate> {
        let mut all: Vec<Candidate> = pool.values().cloned().collect();
        all.sort_by(better);
        all.truncate(beam_width);
        all
    };

    // -- beam loop ---------------------------------------------------------
    let mut beam = select(&pool);
    let mut history = vec![beam[0].throughput];
    let mut best_tput = beam[0].throughput;
    let mut rng = SplitMix64::new(cfg.seed ^ 0x2B97_C4E5);
    let mut stale = 0usize;
    let mut generations_run = 0usize;

    for g in 1..=cfg.generations {
        let mut children: Vec<Pending> = Vec::new();
        for parent in &beam {
            for _ in 0..cfg.mutations_per_parent {
                for _attempt in 0..8 {
                    if let Some((child, mv)) =
                        moves::mutate(&parent.plan, &mut rng)
                    {
                        let text = plan_io::to_text(&child);
                        if seen.contains(&text) {
                            // duplicate of an already-tried plan: retry
                            // with fresh randomness rather than forfeit
                            // this mutation slot
                            continue;
                        }
                        seen.insert(text.clone());
                        children.push((
                            child,
                            text,
                            parent.seed.clone(),
                            format!("g{g}:{mv}"),
                        ));
                        break;
                    }
                }
            }
        }
        let outs = evaluate(&children, profile, cfg, threads);
        absorb(outs, &named_texts, &mut pool, &mut named_best, &mut tally);

        beam = select(&pool);
        history.push(beam[0].throughput);
        generations_run = g;
        if beam[0].throughput > best_tput * (1.0 + 1e-12) {
            best_tput = beam[0].throughput;
            stale = 0;
        } else {
            stale += 1;
            if stale >= cfg.patience {
                break;
            }
        }
    }

    Ok(TuneReport {
        profile_name: profile.name.clone(),
        n_ranks,
        budget_bytes: cfg.budget_bytes,
        best: beam[0].clone(),
        named_best,
        evaluated: tally.evaluated,
        rejected_budget: tally.rejected_budget,
        rejected_sim: tally.rejected_sim,
        generations_run,
        history,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::validate::validate;

    fn quick_cfg() -> BeamConfig {
        BeamConfig {
            beam_width: 6,
            generations: 4,
            mutations_per_parent: 4,
            seed: 7,
            ..BeamConfig::default()
        }
    }

    #[test]
    fn unconstrained_tune_finds_a_valid_winner() {
        let profile = TuneProfile::llama_like(4);
        let report = tune(&profile, 4, &quick_cfg()).unwrap();
        validate(&report.best.plan).unwrap();
        let nb = report.named_best.as_ref().expect("some named plan fits");
        assert!(
            report.best.throughput >= nb.throughput,
            "winner {} < named {}",
            report.best.throughput,
            nb.throughput
        );
        // round-trips through the DSL
        let back = plan_io::parse(&report.best.text).unwrap();
        assert_eq!(back, report.best.plan);
    }

    #[test]
    fn tune_is_deterministic_per_seed() {
        let profile = TuneProfile::llama_like(2);
        let cfg = BeamConfig { threads: 1, ..quick_cfg() };
        let a = tune(&profile, 2, &cfg).unwrap();
        let cfg4 = BeamConfig { threads: 4, ..quick_cfg() };
        let b = tune(&profile, 2, &cfg4).unwrap();
        assert_eq!(a.best.text, b.best.text, "thread count changed result");
        assert_eq!(
            a.best.makespan.to_bits(),
            b.best.makespan.to_bits()
        );
        assert_eq!(a.history.len(), b.history.len());
    }

    #[test]
    fn impossible_budget_errors_out() {
        let profile = TuneProfile::llama_like(2);
        let cfg = BeamConfig {
            budget_bytes: Some(1), // nothing fits one byte
            ..quick_cfg()
        };
        let err = tune(&profile, 2, &cfg).unwrap_err();
        assert!(err.contains("no schedule fits"), "{err}");
    }

    #[test]
    fn rank_mismatch_errors_out() {
        let profile = TuneProfile::llama_like(2);
        assert!(tune(&profile, 4, &quick_cfg()).is_err());
    }

    #[test]
    fn budget_is_a_hard_constraint() {
        let profile = TuneProfile::llama_like(4);
        // binding budget: 90% of the unconstrained winner's peak
        let unconstrained = tune(&profile, 4, &quick_cfg()).unwrap();
        let budget = unconstrained.best.max_peak * 9 / 10;
        let cfg = BeamConfig {
            budget_bytes: Some(budget),
            ..quick_cfg()
        };
        let constrained = tune(&profile, 4, &cfg).unwrap();
        assert!(
            constrained.best.max_peak <= budget,
            "winner peak {} exceeds budget {budget}",
            constrained.best.max_peak
        );
        assert!(constrained.rejected_budget > 0, "budget never rejected");
        if let Some(nb) = &constrained.named_best {
            assert!(constrained.best.throughput >= nb.throughput);
        }
    }

    #[test]
    fn microbatch_grid_is_sane() {
        assert_eq!(microbatch_grid(4, 16), vec![4, 6, 8, 12, 16]);
        assert_eq!(microbatch_grid(1, 4), vec![1, 2, 3, 4]);
        assert_eq!(microbatch_grid(4, 2), vec![2]); // capped, fallback
    }
}
