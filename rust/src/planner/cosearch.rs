//! Joint partition × schedule co-search over a DP×PP device grid.
//!
//! Given `devices` accelerators and a per-layer [`ModelProfile`], this
//! answers the question the fixed-stage planner cannot: **how should
//! the devices be split** between data-parallel replication and
//! pipeline depth, **where should the layer cuts go**, and what
//! schedule runs best on the result (DAPPLE's joint search + BaPipe's
//! repartitioning, see PAPERS.md).
//!
//! Per divisor cell `dp × pp == devices` (with `pp <= n_layers`):
//!
//! 1. start from the balanced contiguous partition
//!    ([`Partition::balanced`]) and beam-search a schedule for its
//!    rolled-up per-stage profile ([`ModelProfile::roll_up`] →
//!    [`TuneRequest`] — the existing search, untouched);
//! 2. **hill-climb the layer boundaries**: re-score the incumbent
//!    winner plan under every neighbor partition
//!    ([`moves::partition_neighbors`], one cheap Tier A
//!    [`score_plan`] each — no beam), take the best strict
//!    improvement in step time, repeat up to
//!    [`CoSearchConfig::max_migrations`] times;
//! 3. if any boundary moved, re-beam once on the final partition and
//!    keep the better of the two winners.
//!
//! A cell's **step time** is the plan makespan plus the DP gradient
//! allreduce ([`crate::sim::allreduce_time`] on the fattest stage's
//! param bytes) — added *outside* the sim kernel, so the two-tier
//! contract is untouched.  Cells rank on **effective throughput**
//! `dp · samples / step_time` (a dp-way replica processes dp
//! microbatch streams per step), ties on peak asc, then dp asc.
//!
//! Hill-climb comparisons always use the clean-world Tier A score,
//! even when the inner beam runs a robust objective — the boundary
//! move is a cost/memory trade, not a tail-risk one.
//!
//! Everything is deterministic: cells are enumerated in divisor order,
//! neighbors in cut order, and the inner beam is the deterministic
//! seeded search.

use crate::experiments::sweep::dp_pp_cells;
use crate::metrics::observer::{NullObserver, Observer};
use crate::schedule::Partition;
use crate::sim::{allreduce_time, score_plan, Scratch};

use super::moves::partition_neighbors;
use super::{BeamConfig, Candidate, ModelProfile, TuneRequest};

/// Co-search knobs on top of the inner beam's [`BeamConfig`].
#[derive(Debug, Clone)]
pub struct CoSearchConfig {
    /// Total devices to split as dp × pp.
    pub devices: usize,
    /// Boundary-migration budget per cell (0 disables the climb).
    pub max_migrations: usize,
    /// The inner schedule search, reused per cell (its `budget_bytes`
    /// is the per-device byte budget — the memory force that pushes
    /// against deep stages).
    pub beam: BeamConfig,
}

impl CoSearchConfig {
    pub fn new(devices: usize, beam: BeamConfig) -> CoSearchConfig {
        CoSearchConfig { devices, max_migrations: 8, beam }
    }
}

/// One evaluated DP×PP cell: its final partition, schedule winner, and
/// the step-time decomposition the ranking runs on.
#[derive(Debug, Clone)]
pub struct CellReport {
    pub dp: u32,
    pub pp: usize,
    pub partition: Partition,
    /// The inner beam's winner under the final partition.
    pub candidate: Candidate,
    /// Plan makespan (no allreduce), from the beam's objective.
    pub makespan: f64,
    /// Ring-allreduce seconds for the fattest stage (0 when dp == 1).
    pub allreduce_s: f64,
    /// `makespan + allreduce_s` — what cells are compared on.
    pub step_time: f64,
    /// Effective samples/sec: `dp · samples_per_step / step_time`.
    pub throughput: f64,
    pub max_peak: u64,
    /// Boundary migrations the hill-climb accepted.
    pub migrations: usize,
}

/// What [`co_search`] found: every feasible cell (ranked, best first)
/// plus the per-cell diagnostics.
#[derive(Debug, Clone)]
pub struct CoSearchReport {
    pub model_name: String,
    pub devices: usize,
    /// Feasible cells, best first.  `best()` is `cells[0]`.
    pub cells: Vec<CellReport>,
    /// Cells where no schedule fit the budget (dp, pp, error).
    pub infeasible: Vec<(u32, usize, String)>,
}

impl CoSearchReport {
    pub fn best(&self) -> &CellReport {
        &self.cells[0]
    }
}

/// Cell ranking: effective throughput desc, peak asc, then smaller dp
/// (prefer the less replicated grid when truly tied), then the
/// partition's text form — a total order, so the report is stable.
fn better(a: &CellReport, b: &CellReport) -> std::cmp::Ordering {
    b.throughput
        .total_cmp(&a.throughput)
        .then_with(|| a.max_peak.cmp(&b.max_peak))
        .then_with(|| a.dp.cmp(&b.dp))
        .then_with(|| {
            a.partition.describe().cmp(&b.partition.describe())
        })
}

/// Step time of `cand`'s plan under `part` (clean Tier A score +
/// allreduce), or `None` when the rolled profile rejects the plan
/// (over budget / deadlock).  The hill-climb's evaluation primitive.
fn step_time_under(
    model: &ModelProfile,
    part: &Partition,
    cand: &Candidate,
    budget: Option<u64>,
    scratch: &mut Scratch,
) -> Option<(f64, f64, u64)> {
    let rolled = model.roll_up(part).ok()?;
    let score = score_plan(
        &cand.plan,
        &rolled.costs,
        Some(&rolled.mem),
        budget,
        scratch,
    )
    .ok()?;
    if !score.fits {
        return None;
    }
    let ar = allreduce_time(
        part.dp,
        model.max_stage_param_bytes(part),
        model.allreduce_per_byte,
    );
    Some((score.makespan + ar, score.makespan, score.max_peak))
}

/// Run the joint search (module docs).  `Err` only when *no* cell
/// yields a fitting schedule.
pub fn co_search(
    model: &ModelProfile,
    cfg: &CoSearchConfig,
    obs: &mut dyn Observer,
) -> Result<CoSearchReport, String> {
    if cfg.devices == 0 {
        return Err("co-search needs at least one device".into());
    }
    if model.n_layers() == 0 {
        return Err("co-search needs at least one layer".into());
    }
    let cells = dp_pp_cells(cfg.devices, model.n_layers());
    if cells.is_empty() {
        return Err(format!(
            "no dp×pp split of {} devices fits {} layers",
            cfg.devices,
            model.n_layers()
        ));
    }
    obs.counter_add("partition.cells", cells.len() as u64);

    let mut scratch = Scratch::new();
    let mut reports: Vec<CellReport> = Vec::new();
    let mut infeasible: Vec<(u32, usize, String)> = Vec::new();

    for (dp, pp) in cells {
        match run_cell(model, cfg, dp, pp, obs, &mut scratch) {
            Ok(cell) => reports.push(cell),
            Err(e) => infeasible.push((dp, pp, e)),
        }
    }

    if reports.is_empty() {
        let detail = infeasible
            .iter()
            .map(|(dp, pp, e)| format!("dp={dp}×pp={pp}: {e}"))
            .collect::<Vec<_>>()
            .join("; ");
        return Err(format!("every dp×pp cell infeasible: {detail}"));
    }
    reports.sort_by(better);

    if obs.enabled() {
        use crate::metrics::registry::Value;
        let best = &reports[0];
        let fields = vec![
            ("dp", Value::from(best.dp as u64)),
            ("pp", Value::from(best.pp)),
            ("partition", Value::from(best.partition.describe())),
            ("migrations", Value::from(best.migrations)),
            ("max_peak", Value::from(best.max_peak)),
        ];
        let scores = [
            ("step_time", best.step_time),
            ("throughput", best.throughput),
            ("allreduce_s", best.allreduce_s),
        ];
        if model.measured {
            obs.event_mixed("partition.winner", fields, scores.to_vec());
        } else {
            let mut fields = fields;
            for (k, v) in scores {
                fields.push((k, Value::from(v)));
            }
            obs.event("partition.winner", fields);
        }
    }

    Ok(CoSearchReport {
        model_name: model.name.clone(),
        devices: cfg.devices,
        cells: reports,
        infeasible,
    })
}

/// Beam + boundary hill-climb + (conditional) re-beam for one cell.
fn run_cell(
    model: &ModelProfile,
    cfg: &CoSearchConfig,
    dp: u32,
    pp: usize,
    obs: &mut dyn Observer,
    scratch: &mut Scratch,
) -> Result<CellReport, String> {
    let beam_once = |part: &Partition,
                     obs: &mut dyn Observer|
     -> Result<Candidate, String> {
        let rolled = model.roll_up(part)?;
        obs.counter_add("partition.beams", 1);
        let report = TuneRequest::new(&rolled, pp, cfg.beam.clone())
            .with_partition(part.clone())
            .run(&mut NullObserver)?;
        Ok(report.best)
    };

    let mut part = Partition::balanced(model.n_layers(), pp, dp);
    let mut cand = beam_once(&part, obs)?;
    let budget = cfg.beam.budget_bytes;
    let (mut step, _, _) =
        step_time_under(model, &part, &cand, budget, scratch)
            .ok_or_else(|| {
                "beam winner does not re-score under its own partition"
                    .to_string()
            })?;

    // -- boundary hill-climb (schedule held fixed) -------------------------
    let mut migrations = 0usize;
    while migrations < cfg.max_migrations {
        let mut best_move: Option<(Partition, f64)> = None;
        for nb in partition_neighbors(&part) {
            if let Some((s, _, _)) =
                step_time_under(model, &nb, &cand, budget, scratch)
            {
                let beats_incumbent = s < step;
                let beats_best = best_move
                    .as_ref()
                    .map(|(_, bs)| s < *bs)
                    .unwrap_or(true);
                if beats_incumbent && beats_best {
                    best_move = Some((nb, s));
                }
            }
        }
        match best_move {
            Some((nb, s)) => {
                part = nb;
                step = s;
                migrations += 1;
                obs.counter_add("partition.migrations", 1);
            }
            None => break,
        }
    }

    // -- re-beam on the migrated partition, keep the better winner ---------
    if migrations > 0 {
        if let Ok(rebeamed) = beam_once(&part, obs) {
            if let Some((s, _, _)) =
                step_time_under(model, &part, &rebeamed, budget, scratch)
            {
                if s < step {
                    cand = rebeamed;
                    step = s;
                }
            }
        }
    }

    let (step_time, makespan, max_peak) =
        step_time_under(model, &part, &cand, budget, scratch)
            .ok_or_else(|| "final winner stopped fitting".to_string())?;
    debug_assert_eq!(step_time.to_bits(), step.to_bits());
    // re-stamp: the winner must carry the *final* partition (text too)
    let mut cand = cand;
    cand.plan.partition = Some(part.clone());
    cand.text = crate::schedule::plan_io::to_text(&cand.plan);
    let allreduce_s = step_time - makespan;
    let samples = model.samples_per_microbatch as f64
        * cand.plan.n_microbatches as f64;
    Ok(CellReport {
        dp,
        pp,
        partition: part,
        candidate: cand,
        makespan,
        allreduce_s,
        step_time,
        throughput: dp as f64 * samples / step_time,
        max_peak,
        migrations,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::planner::TuneProfile;
    use crate::schedule::validate::validate;

    fn quick_beam() -> BeamConfig {
        BeamConfig {
            beam_width: 4,
            generations: 3,
            mutations_per_parent: 3,
            seed: 11,
            ..BeamConfig::default()
        }
    }

    /// A model whose layers are uniform — layer count divisible every
    /// which way, so all divisor cells are live.
    fn uniform_model(layers: usize) -> ModelProfile {
        let mut m =
            ModelProfile::from_profile(&TuneProfile::llama_like(layers));
        m.allreduce_per_byte = 2e-11;
        m
    }

    #[test]
    fn co_search_covers_every_divisor_cell() {
        let model = uniform_model(8);
        let cfg = CoSearchConfig::new(4, quick_beam());
        let rep =
            co_search(&model, &cfg, &mut NullObserver).unwrap();
        let seen: Vec<(u32, usize)> =
            rep.cells.iter().map(|c| (c.dp, c.pp)).collect();
        for cell in [(1u32, 4usize), (2, 2), (4, 1)] {
            assert!(seen.contains(&cell), "missing cell {cell:?}");
        }
        assert!(rep.infeasible.is_empty());
        let best = rep.best();
        validate(&best.candidate.plan).unwrap();
        assert_eq!(
            best.candidate.plan.partition.as_ref(),
            Some(&best.partition)
        );
        // ranked best-first on effective throughput
        for w in rep.cells.windows(2) {
            assert!(w[0].throughput >= w[1].throughput);
        }
    }

    #[test]
    fn dp_cells_pay_the_allreduce_term() {
        let model = uniform_model(8);
        let cfg = CoSearchConfig::new(4, quick_beam());
        let rep = co_search(&model, &cfg, &mut NullObserver).unwrap();
        for c in &rep.cells {
            if c.dp == 1 {
                assert_eq!(c.allreduce_s, 0.0);
            } else {
                assert!(c.allreduce_s > 0.0, "dp={} pays nothing", c.dp);
            }
            assert!((c.step_time - (c.makespan + c.allreduce_s)).abs()
                        < 1e-12);
        }
    }

    /// A model with one very expensive layer: the balanced 2-stage
    /// split leaves stage 0 with the hot layer *plus* peers, so the
    /// hill-climb must migrate boundaries toward it.
    #[test]
    fn hill_climb_migrates_toward_the_hot_layer() {
        let mut model = uniform_model(8);
        model.layers[0].fwd *= 6.0;
        model.layers[0].p1 *= 6.0;
        model.layers[0].p2 *= 6.0;
        let cfg = CoSearchConfig::new(2, quick_beam());
        let rep = co_search(&model, &cfg, &mut NullObserver).unwrap();
        let pp2 = rep
            .cells
            .iter()
            .find(|c| c.pp == 2)
            .expect("pp=2 cell present");
        assert!(pp2.migrations > 0, "no boundary ever moved");
        // stage 0 sheds layers until the hot layer dominates alone-ish
        assert!(
            pp2.partition.cuts[1] < 4,
            "boundary stayed at the balanced split: {:?}",
            pp2.partition.cuts
        );
    }

    #[test]
    fn co_search_is_deterministic() {
        let mut model = uniform_model(6);
        model.layers[3].p2 *= 2.5;
        let cfg = CoSearchConfig::new(6, quick_beam());
        let a = co_search(&model, &cfg, &mut NullObserver).unwrap();
        let b = co_search(&model, &cfg, &mut NullObserver).unwrap();
        assert_eq!(a.best().candidate.text, b.best().candidate.text);
        assert_eq!(a.best().step_time.to_bits(), b.best().step_time.to_bits());
        assert_eq!(a.cells.len(), b.cells.len());
    }

    #[test]
    fn telemetry_counts_cells_beams_and_migrations() {
        let mut model = uniform_model(8);
        model.layers[0].fwd *= 6.0;
        let cfg = CoSearchConfig::new(2, quick_beam());
        let mut reg = crate::metrics::registry::MetricsRegistry::new();
        let rep = co_search(&model, &cfg, &mut reg).unwrap();
        assert_eq!(reg.counter("partition.cells"), 2); // 1×2, 2×1
        assert!(reg.counter("partition.beams") >= 2);
        let migrations: usize =
            rep.cells.iter().map(|c| c.migrations).sum();
        assert_eq!(reg.counter("partition.migrations"), migrations as u64);
        assert!(reg.to_jsonl().contains("partition.winner"));
    }

    #[test]
    fn degenerate_inputs_error_out() {
        let model = uniform_model(4);
        assert!(co_search(
            &model,
            &CoSearchConfig::new(0, quick_beam()),
            &mut NullObserver
        )
        .is_err());
        // 5 devices over 4 layers: only dp=5×pp=1 fits (pp=5 > layers,
        // and 5 is prime) — still feasible, not an error
        let rep = co_search(
            &model,
            &CoSearchConfig::new(5, quick_beam()),
            &mut NullObserver,
        )
        .unwrap();
        assert_eq!(rep.cells.len(), 1);
        assert_eq!((rep.best().dp, rep.best().pp), (5, 1));
    }
}
